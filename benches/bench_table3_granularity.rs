//! Tab. 3 — allocation granularity ablation: linear-block vs expert-level
//! bitwidth allocation at 5-bit weight-activation.
//!
//! Paper shape: linear-block granularity gives lower PPL and higher
//! accuracy on both models.

use anyhow::Result;
use mxmoe::alloc::{allocate, calibrate, measure_sensitivity, AllocatorConfig, Granularity};
use mxmoe::costmodel::GpuSpec;
use mxmoe::harness::{build_quantized, evaluate, load_corpus, load_model, QuantMethod};
use mxmoe::quant::SchemeRegistry;

fn main() -> Result<()> {
    println!("# Tab. 3 — allocation granularity (5-bit weight-activation, r=1)");
    println!("| model        | PPL linear | PPL expert | avg linear | avg expert |");
    let models: Vec<&str> = if mxmoe::harness::fast_mode() {
        vec!["qwen15-mini"]
    } else {
        vec!["dsv2-mini", "qwen15-mini"]
    };
    for model in models {
        let (cfg, lm) = load_model(model)?;
        let corpus = load_corpus()?;
        let seqs = corpus.sequences("train", cfg.seq_len);
        let calib: Vec<&[u32]> = seqs.iter().take(8).copied().collect();
        let stats = calibrate(&lm, &calib, None)?;
        let registry = SchemeRegistry::weight_activation();
        let sens = measure_sensitivity(&lm, &stats, &registry)?;
        let gpu = GpuSpec::rtx4090();

        let mut results = Vec::new();
        for g in [Granularity::LinearBlock, Granularity::Expert] {
            let alloc = allocate(
                &lm,
                &gpu,
                &registry,
                &stats,
                &sens,
                &AllocatorConfig {
                    r: 1.0,
                    target_avg_bits: 5.0,
                    granularity: g,
                    batch_tokens: 512,
                },
            )?;
            let blocks = build_quantized(&lm, &alloc, QuantMethod::Gptq, &stats, 5)?;
            results.push(evaluate(&lm, &corpus, &alloc, &blocks, 24, 16));
        }
        println!(
            "| {model:<12} | {:>10.3} | {:>10.3} | {:>10.3} | {:>10.3} |",
            results[0].ppl,
            results[1].ppl,
            results[0].probes.mean(),
            results[1].probes.mean()
        );
        if results[0].ppl > results[1].ppl + 0.05 {
            println!("  WARNING: linear-block lost to expert-level on {model}");
        }
    }
    println!("\nSHAPE CHECK: paper Tab. 3 — linear ≤ expert PPL on both models");
    Ok(())
}
