//! §GroupGEMM-Dispatch — sequential vs grouped wave dispatch, closed loop.
//!
//! Scenario: a serving-shape model carries a mixed-precision plan that
//! spreads all four runtime families across the expert grid, so every MoE
//! block dispatch plans ≥ 4 distinct-executable waves. The same request
//! stream is served twice — once with the legacy expert-at-a-time loop,
//! once with grouped wave dispatch — and the bench reports wall-clock,
//! per-wave occupancy/fill, and the speedup (target: ≥ 1.5×). Outputs are
//! checked bit-for-bit between the two modes before timing counts.
//!
//! Also runs the `lit_f32` micro-guard: the bulk-copy literal payload must
//! not regress back to per-element conversion speed. Results land in
//! `BENCH_group_dispatch.json`.
//!
//! `--smoke` shrinks repetitions for CI and skips the speedup assertion
//! (shared runners have unpredictable core counts); the micro-guard is
//! enforced in both modes.

use std::time::Instant;

use anyhow::Result;
use mxmoe::alloc::Allocation;
use mxmoe::coordinator::ServingEngine;
use mxmoe::harness::require_artifacts;
use mxmoe::moe::{ModelConfig, MoeLm};
use mxmoe::quant::QuantScheme;
use mxmoe::runtime::{lit_f32, DispatchMode};
use mxmoe::ser::Json;
use mxmoe::tensor::Matrix;
use mxmoe::util::Rng;

const MODEL_SEED: u64 = 0x9805_D15B;

/// Serving-shape model (hidden=128, inter=64 — what the AOT export ships).
fn serving_cfg() -> ModelConfig {
    ModelConfig {
        name: "group-dispatch-bench".into(),
        vocab: 64,
        hidden: 128,
        layers: 2,
        heads: 4,
        n_experts: 4,
        n_shared: 1,
        topk: 2,
        inter: 64,
        dense_first: false,
        seq_len: 16,
    }
}

/// All four runtime families live in every block.
fn mixed_plan(cfg: &ModelConfig) -> Allocation {
    let fams =
        [QuantScheme::FP16, QuantScheme::W4A16, QuantScheme::W8A8, QuantScheme::W4A4];
    let mut plan = Allocation::uniform(cfg, QuantScheme::FP16);
    for (pos, block) in plan.schemes.iter_mut().enumerate() {
        for (e, schemes) in block.iter_mut().enumerate() {
            *schemes = [fams[(pos + e) % fams.len()]; 3];
        }
    }
    plan
}

/// One batch = 340 concatenated MoE rows (256 + 64 + 16 + 4): every
/// exported tile size appears, each routed expert decomposes into several
/// tiles, and the four families produce well over 4 waves per block.
fn batch(cfg: &ModelConfig, rng: &mut Rng) -> Vec<Vec<u32>> {
    [256usize, 64, 16, 4]
        .iter()
        .map(|&n| (0..n).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
        .collect()
}

fn run_mode(
    engine: &mut ServingEngine,
    mode: DispatchMode,
    batches: &[Vec<Vec<u32>>],
) -> Result<(f64, usize, Vec<Matrix>)> {
    engine.set_dispatch_mode(mode);
    // warmup pass (executable cache, allocator warm paths), output discarded
    let refs: Vec<&[u32]> = batches[0].iter().map(|s| s.as_slice()).collect();
    engine.forward_batch(&refs)?;
    let mut last = Vec::new();
    let mut tokens = 0usize;
    let start = Instant::now();
    for b in batches {
        let refs: Vec<&[u32]> = b.iter().map(|s| s.as_slice()).collect();
        last = engine.forward_batch(&refs)?;
        tokens += refs.iter().map(|s| s.len()).sum::<usize>();
    }
    Ok((start.elapsed().as_secs_f64(), tokens, last))
}

/// Micro-guard: bulk-copy literal payload vs the per-element conversion it
/// replaced. Returns (bulk_ns, per_element_ns) per 256×128 literal.
fn lit_micro_guard(iters: usize) -> Result<(f64, f64)> {
    let mut rng = Rng::new(0x117F_32);
    let tile = Matrix::randn(256, 128, 1.0, &mut rng);
    let dims = [tile.rows, tile.cols];

    let start = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(lit_f32(&dims, &tile.data)?);
    }
    let bulk_ns = start.elapsed().as_secs_f64() * 1e9 / iters as f64;

    let start = Instant::now();
    for _ in 0..iters {
        // the old per-element path, verbatim
        let bytes: Vec<u8> = tile.data.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::hint::black_box(
            xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &dims,
                &bytes,
            )
            .map_err(|e| anyhow::anyhow!("lit: {e}"))?,
        );
    }
    let per_element_ns = start.elapsed().as_secs_f64() * 1e9 / iters as f64;
    Ok((bulk_ns, per_element_ns))
}

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");

    // ---- micro-guard (no artifacts needed) ----
    let (bulk_ns, per_ns) = lit_micro_guard(if smoke { 100 } else { 2000 })?;
    println!("# §GroupGEMM-Dispatch — grouped wave dispatch vs sequential");
    println!("lit_f32 256×128: bulk {bulk_ns:>10.0} ns | per-element {per_ns:>10.0} ns | ratio {:.2}×", per_ns / bulk_ns);
    assert!(
        bulk_ns <= per_ns * 1.2,
        "bulk literal build ({bulk_ns:.0} ns) regressed vs per-element ({per_ns:.0} ns)"
    );

    let mut results = vec![
        ("schema", Json::str("mxmoe-bench-v1")),
        ("bench", Json::str("group_dispatch")),
        ("smoke", Json::Bool(smoke)),
        ("lit_f32_bulk_ns", Json::num(bulk_ns)),
        ("lit_f32_per_element_ns", Json::num(per_ns)),
    ];

    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping dispatch bench: artifacts not built (run `make artifacts`)");
        std::fs::write(
            "BENCH_group_dispatch.json",
            Json::obj(results.iter().map(|(k, v)| (*k, v.clone())).collect()).pretty(),
        )?;
        return Ok(());
    };

    // ---- macro bench: same stream, both modes ----
    let cfg = serving_cfg();
    let plan = mixed_plan(&cfg);
    let lm = MoeLm::random(&cfg, &mut Rng::new(MODEL_SEED));
    let mut engine = ServingEngine::new(lm, &artifacts, &plan)?;

    let mut rng = Rng::new(0xD15B);
    let reps = if smoke { 3 } else { 24 };
    let batches: Vec<Vec<Vec<u32>>> = (0..reps).map(|_| batch(&cfg, &mut rng)).collect();

    let (seq_s, tokens, out_seq) = run_mode(&mut engine, DispatchMode::Sequential, &batches)?;
    let (grp_s, _, out_grp) = run_mode(&mut engine, DispatchMode::Grouped, &batches)?;

    // timing only counts if the two paths agree bit-for-bit
    for (a, b) in out_seq.iter().zip(&out_grp) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!(x.to_bits() == y.to_bits(), "grouped diverged from sequential");
        }
    }

    let m = engine.metrics();
    let speedup = seq_s / grp_s;
    let waves_per_dispatch = m.waves as f64 / m.grouped_dispatches.max(1) as f64;
    println!(
        "| sequential | {tokens} tok | {seq_s:>8.3} s | {:>9.1} tok/s |",
        tokens as f64 / seq_s
    );
    println!(
        "| grouped    | {tokens} tok | {grp_s:>8.3} s | {:>9.1} tok/s | {:.1} waves/dispatch | max {} in flight | fill {:.3} |",
        tokens as f64 / grp_s,
        waves_per_dispatch,
        m.max_concurrent_waves,
        m.wave_fill_ratio()
    );
    for (scheme, s) in m.scheme_wave_stats() {
        println!(
            "|   wave[{scheme:>5}] | {:>4} waves | {:>5} tiles | fill {:.3} | busy {:.3} s |",
            s.waves,
            s.items,
            s.fill_ratio(),
            s.busy_s
        );
    }
    println!("speedup: {speedup:.2}×");

    assert!(
        m.max_concurrent_waves >= 4,
        "mixed plan exposed only {} concurrent waves — not a GroupGEMM scenario",
        m.max_concurrent_waves
    );
    if !smoke {
        assert!(
            speedup >= 1.5,
            "grouped dispatch speedup {speedup:.2}× below the 1.5× acceptance bar"
        );
    }

    results.extend([
        ("tokens_per_mode", Json::num(tokens as f64)),
        ("sequential_s", Json::num(seq_s)),
        ("grouped_s", Json::num(grp_s)),
        ("speedup", Json::num(speedup)),
        ("waves_per_dispatch", Json::num(waves_per_dispatch)),
        ("max_concurrent_waves", Json::num(m.max_concurrent_waves as f64)),
        ("wave_fill_ratio", Json::num(m.wave_fill_ratio())),
        (
            "p50_wave_s",
            Json::num(m.wave_latency_summary().map(|s| s.p50).unwrap_or(0.0)),
        ),
    ]);
    std::fs::write(
        "BENCH_group_dispatch.json",
        Json::obj(results.iter().map(|(k, v)| (*k, v.clone())).collect()).pretty(),
    )?;
    println!("\nwrote BENCH_group_dispatch.json");
    Ok(())
}
