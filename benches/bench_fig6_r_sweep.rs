//! Fig. 6 — the accuracy/performance trade-off hyper-parameter r:
//! sweep r ∈ {0, 0.25, 0.5, 0.75, 1} on dsv2-mini; report modeled MoE
//! time (simulator) and measured PPL.
//!
//! Paper shape: smaller r ⇒ faster, less accurate; r = 0.75 captures most
//! of the speedup at minimal accuracy loss.

use anyhow::Result;
use mxmoe::alloc::{allocate, calibrate, measure_sensitivity, AllocatorConfig, Granularity};
use mxmoe::costmodel::micro::Specialization;
use mxmoe::costmodel::GpuSpec;
use mxmoe::harness::{
    build_quantized, evaluate, expert_token_workload, load_corpus, load_model, QuantMethod,
};
use mxmoe::kernelgen::moe_problems;
use mxmoe::quant::SchemeRegistry;
use mxmoe::sim::run_fused;

fn main() -> Result<()> {
    let model = std::env::args().skip(1).find(|a| !a.starts_with('-')).unwrap_or_else(|| "dsv2-mini".into());
    let (cfg, lm) = load_model(&model)?;
    let corpus = load_corpus()?;
    let seqs = corpus.sequences("train", cfg.seq_len);
    let calib: Vec<&[u32]> = seqs.iter().take(8).copied().collect();
    let stats = calibrate(&lm, &calib, None)?;
    let registry = SchemeRegistry::weight_activation();
    let sens = measure_sensitivity(&lm, &stats, &registry)?;
    let gpu = GpuSpec::rtx4090();
    let sp = Specialization::Specialized;

    let batch = 512usize;
    let workload = expert_token_workload(&stats, &cfg, batch);
    let tokens = &workload[workload.len() / 2];
    let rs: Vec<f64> = if mxmoe::harness::fast_mode() {
        vec![0.0, 0.75, 1.0]
    } else {
        vec![0.0, 0.25, 0.5, 0.75, 1.0]
    };

    println!("# Fig. 6 — r sweep on {model} (5-bit W-A, {batch} tokens)");
    println!("| r    | avg bits W-A | modeled time (us) | PPL   |");
    let mut prev_time = f64::INFINITY;
    let mut rows = Vec::new();
    for &r in &rs {
        let alloc = allocate(
            &lm,
            &gpu,
            &registry,
            &stats,
            &sens,
            &AllocatorConfig {
                r,
                target_avg_bits: 5.0,
                granularity: Granularity::LinearBlock,
                batch_tokens: batch,
            },
        )?;
        let mid = alloc.schemes.len() / 2;
        let probs = moe_problems(tokens, &alloc.schemes[mid][..tokens.len()].to_vec(), 2048, 1408);
        let sim = run_fused(&gpu, &probs, sp);
        let blocks = build_quantized(&lm, &alloc, QuantMethod::Gptq, &stats, 6)?;
        let rep = evaluate(&lm, &corpus, &alloc, &blocks, 16, 12);
        println!(
            "| {r:<4} | {:>5.2}-{:<5.2}  | {:>17.1} | {:>5.3} |",
            alloc.avg_weight_bits(&cfg),
            alloc.avg_act_bits(&cfg),
            sim.time * 1e6,
            rep.ppl
        );
        rows.push((r, sim.time, rep.ppl));
        prev_time = prev_time.min(sim.time);
    }
    // shape: time at r=0 ≤ time at r=1
    let t0 = rows.first().unwrap().1;
    let t1 = rows.last().unwrap().1;
    assert!(t0 <= t1 * 1.001, "r=0 should be fastest: {t0} vs {t1}");
    println!("\nSHAPE CHECK OK: performance improves as r decreases (paper Fig. 6)");
    Ok(())
}
