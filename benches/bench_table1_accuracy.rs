//! Tab. 1 — main accuracy comparison across the four models:
//! GPTQ* (Hadamard+GPTQ, uniform) vs MxMoE at matched stored bits for
//! weight-only 2.xx and 3.xx; QuaRot (Hadamard+RTN W4A4) vs MxMoE W5A5 for
//! weight-activation. Metrics: held-out perplexity + probe accuracies.
//!
//! Paper shape to reproduce: at ~2.3 bits GPTQ* degrades sharply while
//! MxMoE recovers a large fraction; at ~3.3 bits both are close to fp16;
//! QuaRot W4A4 collapses while MxMoE ~5 bit is near-lossless.
//!
//! `MXMOE_FAST=1` restricts to one model. Full run covers all four.

use anyhow::Result;
use mxmoe::alloc::{allocate, calibrate, measure_sensitivity, Allocation, AllocatorConfig, Granularity};
use mxmoe::costmodel::GpuSpec;
use mxmoe::harness::{
    build_quantized, evaluate, evaluate_fp32, hadamard_signs_for_seed, load_corpus, load_model,
    AccuracyReport, QuantMethod,
};
use mxmoe::moe::ModelConfig;
use mxmoe::quant::{QuantScheme, SchemeRegistry};

const SEED: u64 = 11;
const EVAL_SEQS: usize = 24;
const PROBE_CASES: usize = 16;

fn row(label: &str, rep: &AccuracyReport) {
    println!(
        "| {label:<22} | {:>5.2}-{:<5.2} | {:>7.3} | {:>6.3} | {:>6.3} | {:>6.3} | {:>6.3} |",
        rep.avg_wbits,
        rep.avg_abits,
        rep.ppl,
        rep.probes.bigram,
        rep.probes.cloze,
        rep.probes.copy,
        rep.probes.mean()
    );
}

fn run_model(name: &str) -> Result<()> {
    let (cfg, lm) = load_model(name)?;
    let corpus = load_corpus()?;
    let seqs = corpus.sequences("train", cfg.seq_len);
    let calib: Vec<&[u32]> = seqs.iter().take(8).copied().collect();
    let gpu = GpuSpec::rtx4090();

    // calibration in both bases (plain for alloc stats, rotated for GPTQ*)
    let stats = calibrate(&lm, &calib, None)?;
    let signs = hadamard_signs_for_seed(&cfg, SEED);
    let stats_rot = calibrate(&lm, &calib, Some((&signs.0, &signs.1)))?;

    println!("\n## {name}  (experts {}+{}, top-{})", cfg.n_experts, cfg.n_shared, cfg.topk);
    println!("| method                 | #bits W-A   |   PPL↓  | bigram |  cloze |   copy |   avg↑ |");
    println!("|------------------------|-------------|---------|--------|--------|--------|--------|");
    row("baseline fp32", &evaluate_fp32(&lm, &corpus, EVAL_SEQS, PROBE_CASES));

    // ---- weight-only rows at matched stored bits ----
    // mini-dim storage floors: W2/W3 g128 clamp to k ⇒ ~2.33/3.33 avg bits
    let wo_registry = SchemeRegistry::weight_only();
    let sens = measure_sensitivity(&lm, &stats, &wo_registry)?;
    for (uniform, target, label_g, label_m) in [
        (QuantScheme::W3A16G128, 3.42, "GPTQ* 3.3b uniform", "MxMoE 3.3b mixed"),
        (QuantScheme::W2A16G128, 2.42, "GPTQ* 2.3b uniform", "MxMoE 2.3b mixed"),
    ] {
        let uni = Allocation::uniform(&cfg, uniform);
        let blocks = build_quantized(&lm, &uni, QuantMethod::HadamardGptq, &stats_rot, SEED)?;
        row(label_g, &evaluate(&lm, &corpus, &uni, &blocks, EVAL_SEQS, PROBE_CASES));

        let alloc = allocate(
            &lm,
            &gpu,
            &wo_registry,
            &stats,
            &sens,
            &AllocatorConfig {
                r: 1.0, // paper: r=1 for extreme low-bit weight-only
                target_avg_bits: target,
                granularity: Granularity::LinearBlock,
                batch_tokens: 512,
            },
        )?;
        let blocks = build_quantized(&lm, &alloc, QuantMethod::HadamardGptq, &stats_rot, SEED)?;
        row(label_m, &evaluate(&lm, &corpus, &alloc, &blocks, EVAL_SEQS, PROBE_CASES));
    }

    // ---- weight-activation rows ----
    let quarot = Allocation::uniform(&cfg, QuantScheme::W4A4);
    let blocks = build_quantized(&lm, &quarot, QuantMethod::HadamardRtn, &stats_rot, SEED)?;
    row("QuaRot w4a4 uniform", &evaluate(&lm, &corpus, &quarot, &blocks, EVAL_SEQS, PROBE_CASES));

    let wa_registry = SchemeRegistry::weight_activation();
    let sens_wa = measure_sensitivity(&lm, &stats, &wa_registry)?;
    let alloc = allocate(
        &lm,
        &gpu,
        &wa_registry,
        &stats,
        &sens_wa,
        &AllocatorConfig {
            r: 0.75,
            target_avg_bits: 5.0,
            granularity: Granularity::LinearBlock,
            batch_tokens: 512,
        },
    )?;
    let blocks = build_quantized(&lm, &alloc, QuantMethod::Gptq, &stats, SEED)?;
    row("MxMoE ~5b mixed W-A", &evaluate(&lm, &corpus, &alloc, &blocks, EVAL_SEQS, PROBE_CASES));
    Ok(())
}

fn main() -> Result<()> {
    println!("# Tab. 1 — accuracy across models (mini-model reproduction)");
    println!("# Tab. 2 — architectures:");
    for c in ModelConfig::all_minis() {
        println!(
            "#   {:14} params {:>5.1}M  experts {}+{}  topk {}",
            c.name,
            c.param_count() as f64 / 1e6,
            c.n_experts,
            c.n_shared,
            c.topk
        );
    }
    let models: Vec<&str> = if mxmoe::harness::fast_mode() {
        vec!["qwen15-mini"]
    } else {
        vec!["dsv2-mini", "qwen15-mini", "qwen2-mini", "mixtral-mini"]
    };
    for m in models {
        if let Err(e) = run_model(m) {
            println!("\n## {m}: SKIPPED ({e})");
        }
    }
    Ok(())
}
