//! §Scenario-Engine — the checked-in scenario suite, replayed end to end.
//!
//! Runs every spec under `scenarios/` through the trace-driven replay
//! driver (`harness::scenario::run_scenario`): arrival curves, QoS-mix
//! schedules, cancel storms, routing drift with online replanning, and
//! mid-run replica kill/restart, all against a mini-model cluster. Each
//! scenario writes its own `BENCH_scenario_<name>.json` with the ledger,
//! per-class SLO stats, and a pass/fail verdict; this runner additionally
//! writes a `BENCH_scenario_suite.json` roll-up and exits non-zero if any
//! verdict fails.
//!
//! `--smoke` keeps every determinism and accounting check enforced but
//! reports wall-clock checks (deadline-hit rate, per-class p99 bounds)
//! without gating on them — shared CI runners can't hold latency bars.

use anyhow::{bail, Result};
use mxmoe::harness::require_artifacts;
use mxmoe::harness::scenario::{list_specs, run_scenario, RunOptions};
use mxmoe::ser::Json;

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!("# §Scenario-Engine — trace-driven workload suite with SLO verdicts");

    let results = vec![
        ("schema", Json::str("mxmoe-bench-v1")),
        ("bench", Json::str("scenario-suite")),
        ("smoke", Json::Bool(smoke)),
    ];
    if require_artifacts().is_none() {
        eprintln!("skipping scenario suite: artifacts not built (run `make artifacts`)");
        let mut stub = results;
        stub.push(("skipped", Json::Bool(true)));
        std::fs::write(
            "BENCH_scenario_suite.json",
            Json::obj(stub.iter().map(|(k, v)| (*k, v.clone())).collect()).pretty(),
        )?;
        return Ok(());
    }

    let specs = list_specs()?;
    assert!(specs.len() >= 6, "scenario suite shrank: {} specs", specs.len());
    let opts = RunOptions { smoke, dispatch_threads: None };
    let mut rows = Vec::new();
    let mut failed = Vec::new();
    for spec in &specs {
        let outcome = run_scenario(spec, &opts)?;
        let path = outcome.write(std::path::Path::new("."))?;
        let l = &outcome.ledger;
        // bar: one '#' per ten arrivals so relative load is visible at a glance
        let bar = "#".repeat((l.arrivals / 10).max(1));
        println!(
            "| {:18} | {:4} | {:3} arrivals | {:3} served | {:3} shed | {:2} cancelled | \
             {:2} failed | {:2} replans | {:6.1}s | {}",
            spec.name,
            outcome.verdict.status().to_uppercase(),
            l.arrivals,
            l.responses,
            l.shed(),
            l.cancelled,
            l.failed,
            outcome.slo.replans,
            outcome.elapsed_s,
            bar,
        );
        for c in outcome.verdict.checks.iter().filter(|c| !c.pass) {
            println!(
                "|   {} '{}': {} {} {}",
                if c.enforced { "FAIL" } else { "warn" },
                c.name,
                c.value,
                c.op,
                c.bound
            );
        }
        if !outcome.verdict.passed() {
            failed.push(spec.name.clone());
        }
        rows.push((
            spec.name.clone(),
            Json::obj(vec![
                ("status", Json::str(outcome.verdict.status())),
                ("arrivals", Json::num(l.arrivals as f64)),
                ("served", Json::num(l.responses as f64)),
                ("shed", Json::num(l.shed() as f64)),
                ("elapsed_s", Json::num(outcome.elapsed_s)),
                ("file", Json::str(&path.display().to_string())),
            ]),
        ));
    }

    let mut out = results;
    out.push(("scenarios", Json::num(specs.len() as f64)));
    out.push(("failed", Json::num(failed.len() as f64)));
    out.push(("suite", Json::Obj(rows.into_iter().collect())));
    std::fs::write(
        "BENCH_scenario_suite.json",
        Json::obj(out.iter().map(|(k, v)| (*k, v.clone())).collect()).pretty(),
    )?;
    println!("\nwrote BENCH_scenario_suite.json + {} per-scenario files", specs.len());

    if !failed.is_empty() {
        bail!("{} scenario verdict(s) failed: {}", failed.len(), failed.join(", "));
    }
    Ok(())
}
