//! Fig. 5 — MoE-block throughput across models and precisions, for the
//! memory-bound (512 tokens) and compute-bound (8192 tokens) regimes, with
//! MxMoE's allocation-driven mixed precision vs uniform schemes.
//!
//! Paper shape: 512 tokens — W8A8 loses to W4A16; MxMoE mixed (~W4.25A15.5)
//! beats W4A16 by up to 25%. 8192 tokens — W4A4 fastest but lossy, W8A8
//! accurate but slow, MxMoE W5A5 up to 29.4% over W8A8. Mixed vs fp16:
//! 1.6–2.7× (memory-bound), 3–3.4× (compute-bound).

use anyhow::Result;
use mxmoe::alloc::{allocate, calibrate, measure_sensitivity, AllocatorConfig, Granularity};
use mxmoe::costmodel::micro::Specialization;
use mxmoe::costmodel::GpuSpec;
use mxmoe::harness::{expert_token_workload, load_corpus, load_model};
use mxmoe::kernelgen::moe_problems;
use mxmoe::quant::{QuantScheme, SchemeRegistry};
use mxmoe::sim::run_fused;

/// Paper-scale expert shapes per model family (mini models keep the expert
/// *topology*; the simulator evaluates the paper's real GEMM dimensions).
fn paper_dims(model: &str) -> (usize, usize) {
    match model {
        "qwen15-mini" => (2048, 1408),  // Qwen1.5-MoE hidden, moe-inter
        "qwen2-mini" => (3584, 2560),   // Qwen2-57B-A14
        "dsv2-mini" => (2048, 1408),    // DeepSeek-V2-Lite
        "mixtral-mini" => (4096, 14336), // Mixtral-8x7B
        _ => (2048, 1408),
    }
}

fn main() -> Result<()> {
    let gpu = GpuSpec::rtx4090();
    let sp = Specialization::Specialized;
    let models: Vec<&str> = if mxmoe::harness::fast_mode() {
        vec!["qwen15-mini"]
    } else {
        vec!["dsv2-mini", "qwen15-mini", "qwen2-mini", "mixtral-mini"]
    };

    println!("# Fig. 5 — MoE block throughput (simulator, {}, real activation skew)", gpu.name);
    for model in models {
        let (cfg, lm) = match load_model(model) {
            Ok(x) => x,
            Err(e) => {
                println!("## {model}: SKIPPED ({e})");
                continue;
            }
        };
        let corpus = load_corpus()?;
        let seqs = corpus.sequences("train", cfg.seq_len);
        let calib: Vec<&[u32]> = seqs.iter().take(8).copied().collect();
        let stats = calibrate(&lm, &calib, None)?;
        let (hidden, inter) = paper_dims(model);

        for &batch in &[512usize, 8192] {
            let regime = if batch == 512 { "memory-bound" } else { "compute-bound" };
            // real skewed per-expert token counts from calibration
            let workload = expert_token_workload(&stats, &cfg, batch);
            let tokens = &workload[workload.len() / 2];

            // MxMoE allocation for this regime (r = 0.75)
            let registry = if batch == 512 {
                SchemeRegistry::weight_only()
            } else {
                SchemeRegistry::weight_activation()
            };
            let sens = measure_sensitivity(&lm, &stats, &registry)?;
            let alloc = allocate(
                &lm,
                &gpu,
                &registry,
                &stats,
                &sens,
                &AllocatorConfig {
                    r: 0.75,
                    target_avg_bits: if batch == 512 { 4.5 } else { 5.0 },
                    granularity: Granularity::LinearBlock,
                    batch_tokens: batch,
                },
            )?;
            let mid = alloc.schemes.len() / 2;
            let mixed_schemes: Vec<[QuantScheme; 3]> = alloc.schemes[mid].clone();

            let mk_uniform =
                |s: QuantScheme| moe_problems(tokens, &vec![[s; 3]; tokens.len()], hidden, inter);
            let fp16 = run_fused(&gpu, &mk_uniform(QuantScheme::FP16), sp);
            let mixed = run_fused(
                &gpu,
                &moe_problems(tokens, &mixed_schemes[..tokens.len()].to_vec(), hidden, inter),
                sp,
            );
            println!(
                "\n## {model} [{hidden},{inter}] @ {batch} tokens ({regime}), avg W{:.2}A{:.2}",
                alloc.avg_weight_bits(&cfg),
                alloc.avg_act_bits(&cfg)
            );
            println!("| scheme        | TFLOPS | vs fp16 |");
            let report = |name: &str, r: &mxmoe::sim::SimReport| {
                println!(
                    "| {name:<13} | {:>6.1} | {:>6.2}x |",
                    r.tflops(),
                    r.tflops() / fp16.tflops()
                );
            };
            report("fp16", &fp16);
            report("w4a16", &run_fused(&gpu, &mk_uniform(QuantScheme::W4A16), sp));
            report("w8a8", &run_fused(&gpu, &mk_uniform(QuantScheme::W8A8), sp));
            report("w4a4", &run_fused(&gpu, &mk_uniform(QuantScheme::W4A4), sp));
            report("MxMoE mixed", &mixed);
            let speedup = mixed.tflops() / fp16.tflops();
            println!("mixed vs fp16: {:.2}x  (paper: 1.6–2.7x mem-bound, 3–3.4x compute-bound)", speedup);
        }
    }
    Ok(())
}
