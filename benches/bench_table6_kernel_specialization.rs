//! Tab. 6 (App. A.2) — specialized vs unified micro-kernels: achieved TOPS
//! of W4A4 per-channel and W4A4-g128 GEMM at [8192, 8192, 8192].
//!
//! Paper numbers (RTX-4090): specialized 1070.5 / 667.3 TOPS; unified
//! 929.2 / 412.0. Our pipeline model derives the same ordering and ratios
//! from branch + pipeline-depth penalties (see `costmodel::micro`).

use mxmoe::costmodel::micro::{achieved_tops, Specialization};
use mxmoe::costmodel::GpuSpec;
use mxmoe::quant::QuantScheme;

fn main() {
    let gpu = GpuSpec::rtx4090();
    println!("# Tab. 6 — W4A4 kernel specialization, [8192,8192,8192], {}", gpu.name);
    println!("| kernel type                    | per-channel TOPS | g128 TOPS |");
    let pc = QuantScheme::W4A4;
    let g = QuantScheme::W4A4G128;
    let rows = [
        ("specialized (per-scheme)", Specialization::Specialized),
        ("unified (single kernel)", Specialization::Unified),
    ];
    for (name, spec) in rows {
        println!(
            "| {name:<30} | {:>16.1} | {:>9.1} |",
            achieved_tops(gpu.int4_ops, &pc, spec),
            achieved_tops(gpu.int4_ops, &g, spec)
        );
    }
    let pc_s = achieved_tops(gpu.int4_ops, &pc, Specialization::Specialized);
    let pc_u = achieved_tops(gpu.int4_ops, &pc, Specialization::Unified);
    let g_s = achieved_tops(gpu.int4_ops, &g, Specialization::Specialized);
    let g_u = achieved_tops(gpu.int4_ops, &g, Specialization::Unified);
    println!("\npaper reference: 1070.5 / 667.3 (specialized), 929.2 / 412.0 (unified)");
    println!(
        "ratios — per-channel unified/specialized: {:.2} (paper 0.87); g128: {:.2} (paper 0.62)",
        pc_u / pc_s,
        g_u / g_s
    );
    println!(
        "\nkernel-count argument (App. A.2): 5 configurable micro-kernels vs {} handcrafted fused variants",
        (1..=5).product::<u32>()
    );
    assert!(pc_s > pc_u && g_s > g_u && pc_u / pc_s > g_u / g_s);
    println!("SHAPE CHECK OK: specialization wins, group kernels degrade most under unification");
}
