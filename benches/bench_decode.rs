//! §Decode-Loop — KV-cached continuous decode vs naive re-forward-per-token.
//!
//! Scenario: G concurrent generations (16-token prompts, N new tokens
//! each) on one serving engine under the standard mixed-precision plan.
//! Two ways to produce the same token streams:
//!
//! * **naive** — the pre-decode serving reality: every emitted token costs
//!   a *whole-sequence* forward of the growing sequence (each decode step
//!   is a scoring request). O(T²) rows per sequence, no cross-sequence
//!   step batching.
//! * **kv** — the decode subsystem: prefill once into the KV cache, then
//!   one single-token row per sequence per step, with all G sequences'
//!   rows concatenated into one mixed step batch per layer
//!   ([`DecodeScheduler`]). O(T) rows per sequence, tiles filled across
//!   sequences.
//!
//! The naive baseline is *teacher-forced* on the kv path's generated
//! streams, so both sides execute exactly the token sequences being
//! compared — a fair timing comparison that sidesteps argmax near-ties
//! between different tile executables (bit-identity of the decode path
//! itself is pinned in `tests/decode_generate.rs`).
//!
//! Reported: decode throughput (generated tokens/s) both ways + the
//! speedup. Full mode asserts the acceptance bar: kv ≥ 5× naive. `--smoke`
//! shrinks the workload for CI and skips the wall-clock bar (shared
//! runners), keeping the determinism and accounting assertions. Results
//! land in `BENCH_decode.json`.

use std::path::Path;
use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;
use mxmoe::coordinator::ServingEngine;
use mxmoe::harness::{mixed_runtime_plan, require_artifacts, save_model_mxt};
use mxmoe::moe::{ModelConfig, MoeLm};
use mxmoe::ser::Json;
use mxmoe::serve::{
    DecodePolicy, DecodeScheduler, GenSpec, Request, RequestKind, StreamEvent,
};
use mxmoe::util::Rng;

const MODEL_SEED: u64 = 0xDEC0_DE01;
const PROMPT_LEN: usize = 16;

/// Serving-shape model (hidden=128, inter=64 — what the AOT export ships).
fn serving_cfg() -> ModelConfig {
    ModelConfig {
        name: "decode-bench".into(),
        vocab: 64,
        hidden: 128,
        layers: 2,
        heads: 4,
        n_experts: 4,
        n_shared: 1,
        topk: 2,
        inter: 64,
        dense_first: false,
        seq_len: PROMPT_LEN,
    }
}

fn prompts(cfg: &ModelConfig, g: usize) -> Vec<Vec<u32>> {
    let mut rng = Rng::new(0xDEC0_0FFE);
    (0..g)
        .map(|_| (0..PROMPT_LEN).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
        .collect()
}

fn build_engine(cfg: &ModelConfig, weights: &Path, artifacts: &Path) -> Result<ServingEngine> {
    let file = mxmoe::ser::MxtFile::load(weights)?;
    let lm = MoeLm::load_mxt(cfg, &file)?;
    ServingEngine::new(lm, artifacts, &mixed_runtime_plan(cfg))
}

struct KvRun {
    streams: Vec<Vec<u32>>,
    elapsed_s: f64,
    steps: usize,
    rows: usize,
    kv_peak_tokens: usize,
}

/// Generate all sequences through the decode scheduler (one engine, G
/// concurrent sequences, mixed steps). Returns the streams + timing.
fn run_kv(
    cfg: &ModelConfig,
    engine: &mut ServingEngine,
    prompts: &[Vec<u32>],
    max_new: usize,
) -> KvRun {
    let mut sched = DecodeScheduler::new(
        cfg,
        DecodePolicy { max_active_seqs: prompts.len().max(1), ..DecodePolicy::default() },
    );
    let mut handles = Vec::new();
    for p in prompts {
        let (reply, _reply_rx) = mpsc::channel();
        let (stream, stream_rx) = mpsc::channel();
        sched.admit(Request {
            kind: RequestKind::Generate(GenSpec {
                max_new_tokens: max_new,
                stop: vec![],
                stream,
            }),
            ..Request::new(p.clone(), reply)
        });
        handles.push((stream_rx, _reply_rx));
    }
    let t0 = Instant::now();
    let mut steps = 0usize;
    let mut rows = 0usize;
    while sched.has_work() {
        let out = sched.step(|inputs| engine.forward_step_batch(inputs));
        if out.rows > 0 {
            steps += 1;
            rows += out.rows;
        }
        assert!(out.failed.is_empty() && out.cancelled.is_empty());
    }
    let elapsed_s = t0.elapsed().as_secs_f64();
    let kv_peak_tokens = sched.occupancy().peak_tokens;
    let streams: Vec<Vec<u32>> = handles
        .iter()
        .map(|(rx, _)| {
            let mut tokens = Vec::new();
            while let Ok(ev) = rx.try_recv() {
                match ev {
                    StreamEvent::Token { token, .. } => tokens.push(token),
                    StreamEvent::Done { generated, .. } => assert_eq!(generated, tokens.len()),
                }
            }
            tokens
        })
        .collect();
    KvRun { streams, elapsed_s, steps, rows, kv_peak_tokens }
}

/// The pre-decode baseline: each token of each stream costs one
/// whole-sequence forward of the growing sequence (teacher-forced on the
/// kv streams so both sides run identical token sequences).
fn run_naive(
    engine: &mut ServingEngine,
    prompts: &[Vec<u32>],
    streams: &[Vec<u32>],
) -> Result<(f64, usize)> {
    let t0 = Instant::now();
    let mut rows = 0usize;
    for (p, s) in prompts.iter().zip(streams) {
        let mut seq = p.clone();
        for &tok in s {
            let logits = engine.forward_batch(&[&seq])?;
            assert_eq!(logits[0].rows, seq.len());
            rows += seq.len();
            seq.push(tok);
        }
    }
    Ok((t0.elapsed().as_secs_f64(), rows))
}

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!("# §Decode-Loop — KV-cached continuous decode vs naive re-forward-per-token");

    let mut results = vec![
        ("schema", Json::str("mxmoe-bench-v1")),
        ("bench", Json::str("decode")),
        ("smoke", Json::Bool(smoke)),
    ];
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping decode bench: artifacts not built (run `make artifacts`)");
        std::fs::write(
            "BENCH_decode.json",
            Json::obj(results.iter().map(|(k, v)| (*k, v.clone())).collect()).pretty(),
        )?;
        return Ok(());
    };

    let cfg = serving_cfg();
    let weights = std::env::temp_dir().join("mxmoe_bench_decode.mxt");
    let lm = MoeLm::random(&cfg, &mut Rng::new(MODEL_SEED));
    save_model_mxt(&lm, &weights)?;
    let mut engine = build_engine(&cfg, &weights, &artifacts)?;

    let (g, max_new) = if smoke { (2usize, 4usize) } else { (8, 32) };
    let ps = prompts(&cfg, g);

    // warmup both paths outside the timed windows (executable load)
    let warm = run_kv(&cfg, &mut engine, &ps[..1], 1);
    run_naive(&mut engine, &ps[..1], &warm.streams)?;

    // timed: kv decode, twice (determinism check), then the naive replay
    let kv_a = run_kv(&cfg, &mut engine, &ps, max_new);
    let kv = run_kv(&cfg, &mut engine, &ps, max_new);
    assert_eq!(kv_a.streams, kv.streams, "kv decode must be run-to-run deterministic");
    let total_tokens = g * max_new;
    assert_eq!(kv.streams.iter().map(|s| s.len()).sum::<usize>(), total_tokens);
    // per sequence: prompt prefill rows + one row per further token
    assert_eq!(kv.rows, g * (PROMPT_LEN + max_new - 1), "O(T) rows per sequence");
    let (naive_s, naive_rows) = run_naive(&mut engine, &ps, &kv.streams)?;
    assert!(naive_rows > kv.rows, "the baseline re-forwards O(T²) rows");

    let kv_tps = total_tokens as f64 / kv.elapsed_s.max(1e-9);
    let naive_tps = total_tokens as f64 / naive_s.max(1e-9);
    let speedup = kv_tps / naive_tps.max(1e-9);
    println!(
        "| naive | {:>6} rows | {:>8.1} tok/s |",
        naive_rows, naive_tps
    );
    println!(
        "| kv    | {:>6} rows | {:>8.1} tok/s | {} steps | {:.1} rows/step | kv peak {} |",
        kv.rows,
        kv_tps,
        kv.steps,
        kv.rows as f64 / kv.steps.max(1) as f64,
        kv.kv_peak_tokens
    );
    println!("decode speedup: {speedup:.2}×");
    if !smoke {
        assert!(
            speedup >= 5.0,
            "KV-cached continuous decode must be ≥5× naive re-forwarding \
             (got {speedup:.2}×)"
        );
    }

    let _ = std::fs::remove_file(&weights);
    results.extend([
        ("sequences", Json::num(g as f64)),
        ("max_new_tokens", Json::num(max_new as f64)),
        ("prompt_len", Json::num(PROMPT_LEN as f64)),
        ("kv_tok_per_s", Json::num(kv_tps)),
        ("naive_tok_per_s", Json::num(naive_tps)),
        ("speedup", Json::num(speedup)),
        ("kv_rows", Json::num(kv.rows as f64)),
        ("naive_rows", Json::num(naive_rows as f64)),
        ("kv_steps", Json::num(kv.steps as f64)),
        ("kv_peak_tokens", Json::num(kv.kv_peak_tokens as f64)),
    ]);
    std::fs::write(
        "BENCH_decode.json",
        Json::obj(results.iter().map(|(k, v)| (*k, v.clone())).collect()).pretty(),
    )?;
    println!("\nwrote BENCH_decode.json");
    Ok(())
}
