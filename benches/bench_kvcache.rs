//! §KV-Paging — paged-lazy admission vs contiguous worst-case reservation.
//!
//! Scenario: how many concurrent generations fit one KV token budget? Two
//! admission disciplines over the same page pool:
//!
//! * **contiguous** — the pre-paging serving reality: admission reserves
//!   every sequence's worst case (`prompt + max_new` tokens) up front, so
//!   concurrency is `budget / worst_case` regardless of how many tokens
//!   the sequences ever materialize.
//! * **paged** — the page-table pool: admission claims only the prompt's
//!   pages plus one decode-headroom page; later pages are claimed between
//!   steps as sequences actually grow ([`KvCache::grow`]).
//!
//! A second, prefix-heavy workload (a 64-token system prompt shared by
//! every request) additionally exercises refcounted prefix sharing: after
//! the first sequence seals its prompt pages, every later admission
//! resolves the shared blocks to the same physical pages and only pays
//! for its distinct tail.
//!
//! Reported: admitted generations per budget for each discipline, the
//! concurrency ratios, and admission-wave timing. Full mode asserts the
//! acceptance bar: paged admits ≥8× the contiguous count on both
//! workloads (the order-of-magnitude claim). `--smoke` shrinks the budget
//! for CI and skips the bar. Results land in `BENCH_kvcache.json`.
//!
//! Pure allocator bench — no PJRT artifacts needed, so it never skips.

use std::time::Instant;

use anyhow::Result;
use mxmoe::ser::Json;
use mxmoe::serve::{KvCache, SeqKv};
use mxmoe::tensor::Matrix;
use mxmoe::util::Rng;

const PAGE: usize = 16;
const LAYERS: usize = 2;
const HIDDEN: usize = 32;
const VOCAB: u64 = 64;

/// Uniform workload: 16-token prompts growing to 512 tokens worst case.
const PROMPT_LEN: usize = 16;
/// Prefix workload: 64 shared + 16 distinct prompt tokens, same worst case.
const SHARED_LEN: usize = 64;
const WORST_CASE: usize = 512;

fn distinct_prompts(n: usize, len: usize, rng: &mut Rng) -> Vec<Vec<u32>> {
    (0..n).map(|_| (0..len).map(|_| rng.below(VOCAB) as u32).collect()).collect()
}

fn prefixed_prompts(n: usize, rng: &mut Rng) -> Vec<Vec<u32>> {
    let shared: Vec<u32> = (0..SHARED_LEN).map(|_| rng.below(VOCAB) as u32).collect();
    (0..n)
        .map(|_| {
            let mut p = shared.clone();
            p.extend((0..PROMPT_LEN).map(|_| rng.below(VOCAB) as u32));
            p
        })
        .collect()
}

/// Materialize the prompt into the sequence's pages and seal them —
/// deterministic rows keyed on the token value, so identical prompt
/// blocks produce identical page contents (what prefix sharing keys on).
fn fill_prompt(pool: &mut KvCache, kv: &mut SeqKv, tokens: &[u32]) {
    let rows = tokens.len();
    let mut k = Matrix::zeros(rows, HIDDEN);
    let mut v = Matrix::zeros(rows, HIDDEN);
    for (i, &t) in tokens.iter().enumerate() {
        for d in 0..HIDDEN {
            k.data[i * HIDDEN + d] = t as f32 + d as f32 * 1e-3;
            v.data[i * HIDDEN + d] = t as f32 - d as f32 * 1e-3;
        }
    }
    for l in 0..LAYERS {
        kv.append(l, &k, &v);
    }
    kv.advance(rows);
    pool.seal(kv);
}

struct Wave {
    admitted: usize,
    reserved_tokens: usize,
    shared_tokens: usize,
    elapsed_s: f64,
}

/// One admission wave: admit from `prompts` until the pool says no,
/// holding every grant (concurrent generations), then release everything
/// and check the pool accounts for every page.
fn admission_wave(budget: usize, prompts: &[Vec<u32>], capacity: usize, fill: bool) -> Wave {
    let mut pool = KvCache::with_config(LAYERS, HIDDEN, budget, PAGE, None);
    let mut held: Vec<SeqKv> = Vec::new();
    let t0 = Instant::now();
    for p in prompts {
        match pool.alloc_seq(p, capacity) {
            Some(mut kv) => {
                if fill {
                    fill_prompt(&mut pool, &mut kv, p);
                }
                held.push(kv);
            }
            None => break,
        }
    }
    let elapsed_s = t0.elapsed().as_secs_f64();
    let peak = pool.occupancy();
    let admitted = held.len();
    for kv in held {
        pool.free(kv);
    }
    let end = pool.occupancy();
    assert_eq!(end.reserved_tokens, 0, "every page returned to the pool");
    assert_eq!(end.seqs, 0);
    assert_eq!(end.freed_seqs, admitted);
    Wave {
        admitted,
        reserved_tokens: peak.reserved_tokens,
        shared_tokens: peak.shared_tokens,
        elapsed_s,
    }
}

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!("# §KV-Paging — paged-lazy admission vs contiguous worst-case reservation");

    let budget = if smoke { 512usize } else { 4096 };
    let candidates = budget / PAGE + 8;
    let mut rng = Rng::new(0x4B5A_6E01);

    // ---- uniform workload: distinct prompts, no sharing possible ----
    let uniform = distinct_prompts(candidates, PROMPT_LEN, &mut rng);
    let contig = admission_wave(budget, &uniform, WORST_CASE, false);
    let paged = admission_wave(budget, &uniform, PROMPT_LEN + 1, false);
    let uniform_ratio = paged.admitted as f64 / contig.admitted.max(1) as f64;
    println!(
        "| uniform | contiguous {:>4} | paged {:>4} | {:>5.1}× | wave {:.1} µs |",
        contig.admitted,
        paged.admitted,
        uniform_ratio,
        paged.elapsed_s * 1e6
    );

    // ---- prefix-heavy workload: shared system prompt ----
    let prefixed = prefixed_prompts(candidates, &mut rng);
    let prompt_len = SHARED_LEN + PROMPT_LEN;
    let contig_p = admission_wave(budget, &prefixed, WORST_CASE, false);
    let unshared = admission_wave(budget, &prefixed, prompt_len + 1, false);
    let shared = admission_wave(budget, &prefixed, prompt_len + 1, true);
    let prefix_ratio = shared.admitted as f64 / contig_p.admitted.max(1) as f64;
    assert!(shared.shared_tokens > 0, "the shared system prompt must share pages");
    assert!(
        shared.admitted > unshared.admitted,
        "prefix sharing must admit more than private pages ({} vs {})",
        shared.admitted,
        unshared.admitted
    );
    println!(
        "| prefix  | contiguous {:>4} | paged {:>4} | shared {:>4} | {:>5.1}× | {} tok shared |",
        contig_p.admitted, unshared.admitted, shared.admitted, prefix_ratio, shared.shared_tokens
    );
    println!("concurrency per budget: uniform {uniform_ratio:.1}×, prefix {prefix_ratio:.1}×");

    if !smoke {
        assert!(
            uniform_ratio >= 8.0,
            "paged admission must fit ≥8× the contiguous worst case (got {uniform_ratio:.2}×)"
        );
        assert!(
            prefix_ratio >= 8.0,
            "prefix sharing must fit ≥8× the contiguous worst case (got {prefix_ratio:.2}×)"
        );
    }

    let results = vec![
        ("schema", Json::str("mxmoe-bench-v1")),
        ("bench", Json::str("kvcache")),
        ("smoke", Json::Bool(smoke)),
        ("budget_tokens", Json::num(budget as f64)),
        ("page_tokens", Json::num(PAGE as f64)),
        ("worst_case_tokens", Json::num(WORST_CASE as f64)),
        ("uniform_contiguous", Json::num(contig.admitted as f64)),
        ("uniform_paged", Json::num(paged.admitted as f64)),
        ("uniform_ratio", Json::num(uniform_ratio)),
        ("uniform_reserved_tokens", Json::num(paged.reserved_tokens as f64)),
        ("prefix_contiguous", Json::num(contig_p.admitted as f64)),
        ("prefix_paged_private", Json::num(unshared.admitted as f64)),
        ("prefix_paged_shared", Json::num(shared.admitted as f64)),
        ("prefix_ratio", Json::num(prefix_ratio)),
        ("prefix_shared_tokens", Json::num(shared.shared_tokens as f64)),
        ("paged_wave_s", Json::num(paged.elapsed_s)),
        ("shared_wave_s", Json::num(shared.elapsed_s)),
        ("contiguous_wave_s", Json::num(contig.elapsed_s)),
    ];
    std::fs::write(
        "BENCH_kvcache.json",
        Json::obj(results.iter().map(|(k, v)| (*k, v.clone())).collect()).pretty(),
    )?;
    println!("\nwrote BENCH_kvcache.json");
    Ok(())
}
