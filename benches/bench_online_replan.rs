//! §Online-Serving — throughput under a workload shift, closed loop.
//!
//! Scenario: the model is calibrated and allocated against a *head-heavy*
//! routing trace (every request drawn from a tiny vocabulary head, so a
//! couple of experts absorb nearly all tokens). The live stream then
//! shifts to uniform routing. The engine must (1) detect the drift via
//! telemetry, (2) re-solve the MCKP with live frequencies (warm-started
//! from the serving plan), (3) hot-swap at least one expert's runtime
//! scheme, and (4) keep producing outputs that match a freshly built
//! engine on the new plan bit-for-bit.
//!
//! Runs directly against the engine (no server thread) so each phase's
//! throughput is attributable and the swap point is deterministic.

use std::time::Instant;

use anyhow::Result;
use mxmoe::alloc::{
    activation_frequencies, allocate, calibrate, measure_sensitivity, AllocatorConfig,
    Granularity,
};
use mxmoe::coordinator::ServingEngine;
use mxmoe::costmodel::GpuSpec;
use mxmoe::harness::require_artifacts;
use mxmoe::moe::{ModelConfig, MoeLm};
use mxmoe::quant::SchemeRegistry;
use mxmoe::serve::{ReplanConfig, Replanner};
use mxmoe::util::Rng;

const MODEL_SEED: u64 = 0x0511_CE;

/// Serving-shape model (hidden=128, inter=64 — what the AOT export ships).
fn serving_cfg() -> ModelConfig {
    ModelConfig {
        name: "online-bench".into(),
        vocab: 64,
        hidden: 128,
        layers: 2,
        heads: 4,
        n_experts: 6,
        n_shared: 1,
        topk: 2,
        inter: 64,
        dense_first: false,
        seq_len: 24,
    }
}

/// Head-heavy stream: tokens from a 3-symbol vocabulary head, so routing
/// concentrates on the few experts those embeddings select.
fn head_seq(cfg: &ModelConfig, rng: &mut Rng) -> Vec<u32> {
    (0..cfg.seq_len).map(|_| rng.below(3) as u32).collect()
}

/// Uniform stream over the whole vocabulary.
fn uniform_seq(cfg: &ModelConfig, rng: &mut Rng) -> Vec<u32> {
    (0..cfg.seq_len).map(|_| rng.below(cfg.vocab as u64) as u32).collect()
}

fn run_phase(
    engine: &mut ServingEngine,
    batches: &[Vec<Vec<u32>>],
    replanner: Option<&Replanner>,
) -> Result<(f64, usize)> {
    let mut tokens = 0usize;
    let start = Instant::now();
    for batch in batches {
        let refs: Vec<&[u32]> = batch.iter().map(|s| s.as_slice()).collect();
        engine.forward_batch(&refs)?;
        tokens += refs.iter().map(|s| s.len()).sum::<usize>();
        if let Some(rp) = replanner {
            engine.maybe_replan(rp)?;
        }
    }
    Ok((tokens as f64 / start.elapsed().as_secs_f64(), tokens))
}

fn scheme_histogram(engine: &ServingEngine) -> String {
    engine
        .scheme_counts()
        .iter()
        .map(|(s, n)| format!("{}×{n}", s.name()))
        .collect::<Vec<_>>()
        .join(" ")
}

fn main() -> Result<()> {
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return Ok(());
    };
    let cfg = serving_cfg();
    let lm = MoeLm::random(&cfg, &mut Rng::new(MODEL_SEED));

    // ---- offline half: calibrate + allocate on the head-heavy trace ----
    let mut rng = Rng::new(0x7AFF);
    let calib: Vec<Vec<u32>> = (0..8).map(|_| head_seq(&cfg, &mut rng)).collect();
    let calib_refs: Vec<&[u32]> = calib.iter().map(|s| s.as_slice()).collect();
    let stats = calibrate(&lm, &calib_refs, None)?;
    let registry = SchemeRegistry::weight_activation();
    let sens = measure_sensitivity(&lm, &stats, &registry)?;
    let alloc_cfg = AllocatorConfig {
        r: 0.5,
        target_avg_bits: 6.0,
        granularity: Granularity::Expert,
        batch_tokens: 96,
    };
    let gpu = GpuSpec::rtx4090();
    let plan_a = allocate(&lm, &gpu, &registry, &stats, &sens, &alloc_cfg)?;

    let mut engine = ServingEngine::new(lm, &artifacts, &plan_a)?;
    engine.set_baseline(activation_frequencies(&stats));
    engine.set_telemetry_alpha(0.25);
    let replanner = Replanner {
        gpu,
        registry,
        sens,
        cfg: ReplanConfig { drift_threshold: 0.08, min_tokens_between: 192, alloc: alloc_cfg },
    };

    println!("# §Online-Serving — continuous batching under a routing shift");
    println!("plan A (head-heavy calib): {}", scheme_histogram(&engine));

    // ---- phase 1: head-heavy traffic, matching the calibration trace ----
    let p1: Vec<Vec<Vec<u32>>> =
        (0..8).map(|_| (0..4).map(|_| head_seq(&cfg, &mut rng)).collect()).collect();
    let (tps1, tok1) = run_phase(&mut engine, &p1, Some(&replanner))?;
    let drift1 = engine.telemetry().max_drift();
    println!(
        "| phase 1 head-heavy | {tok1} tok | {tps1:>8.1} tok/s | drift {drift1:.3} | replans {} |",
        engine.metrics().replans
    );

    // ---- phase 2: uniform traffic — drift builds, loop must close ----
    let p2: Vec<Vec<Vec<u32>>> =
        (0..40).map(|_| (0..4).map(|_| uniform_seq(&cfg, &mut rng)).collect()).collect();
    let (tps2, tok2) = run_phase(&mut engine, &p2, Some(&replanner))?;
    println!(
        "| phase 2 uniform    | {tok2} tok | {tps2:>8.1} tok/s | drift {:.3} | replans {} swaps {} gen {} |",
        engine.metrics().last_drift,
        engine.metrics().replans,
        engine.metrics().swaps,
        engine.generation()
    );
    println!("plan B (live uniform):     {}", scheme_histogram(&engine));

    // ---- closed-loop acceptance ----
    assert!(
        engine.metrics().replans >= 1,
        "workload shift never crossed the drift threshold — loop did not close"
    );
    assert!(
        engine.metrics().swaps >= 1,
        "replan produced no runtime-scheme change — no hot-swap to demonstrate"
    );
    assert!(engine.generation() >= 1);

    // post-swap outputs must equal a freshly built engine on the new plan,
    // bit-for-bit: same weights (deterministic seed), same allocation
    let lm2 = MoeLm::random(&cfg, &mut Rng::new(MODEL_SEED));
    let plan_b = engine.allocation().clone();
    let mut fresh = ServingEngine::new(lm2, &artifacts, &plan_b)?;
    let probe: Vec<Vec<u32>> = (0..4).map(|_| uniform_seq(&cfg, &mut rng)).collect();
    let probe_refs: Vec<&[u32]> = probe.iter().map(|s| s.as_slice()).collect();
    let swapped = engine.forward_batch(&probe_refs)?;
    let rebuilt = fresh.forward_batch(&probe_refs)?;
    for (i, (a, b)) in swapped.iter().zip(&rebuilt).enumerate() {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!(
                x.to_bits() == y.to_bits(),
                "seq {i}: hot-swapped engine diverged from fresh engine on plan B"
            );
        }
    }
    println!("\nclosed loop OK — drift detected, plan re-solved + hot-swapped, post-swap outputs bit-identical to a fresh engine on plan B.");
    Ok(())
}
