//! Tab. 5 — QuaRot-style uniform bitwidth scaling (w4a4 … w8a8, RTN +
//! Hadamard) vs MxMoE mixed w5a5 on qwen15-mini.
//!
//! Paper shape: uniform w4a4 is catastrophic; PPL recovers with bits;
//! MxMoE's mixed ~5-bit beats uniform w5a5 while remaining hardware-
//! executable (only int4/int8 units needed).

use anyhow::Result;
use mxmoe::alloc::{allocate, calibrate, measure_sensitivity, Allocation, AllocatorConfig, Granularity};
use mxmoe::costmodel::GpuSpec;
use mxmoe::harness::{
    build_quantized, evaluate, hadamard_signs_for_seed, load_corpus, load_model, QuantMethod,
};
use mxmoe::quant::{QuantScheme, SchemeRegistry};

fn main() -> Result<()> {
    let model = "qwen15-mini";
    let (cfg, lm) = load_model(model)?;
    let corpus = load_corpus()?;
    let seqs = corpus.sequences("train", cfg.seq_len);
    let calib: Vec<&[u32]> = seqs.iter().take(8).copied().collect();
    let seed = 9;
    let stats = calibrate(&lm, &calib, None)?;
    let signs = hadamard_signs_for_seed(&cfg, seed);
    let stats_rot = calibrate(&lm, &calib, Some((&signs.0, &signs.1)))?;

    println!("# Tab. 5 — uniform (QuaRot/RTN) vs MxMoE mixed, {model}");
    println!("| setting        |   PPL↓  | note |");
    let bits: Vec<u8> = if mxmoe::harness::fast_mode() { vec![4, 5, 8] } else { vec![4, 5, 6, 7, 8] };
    let mut uniform_ppl = std::collections::BTreeMap::new();
    for b in bits {
        let alloc = Allocation::uniform(&cfg, QuantScheme::new(b, b, -1, -1, true));
        let blocks = build_quantized(&lm, &alloc, QuantMethod::HadamardRtn, &stats_rot, seed)?;
        let rep = evaluate(&lm, &corpus, &alloc, &blocks, 16, 4);
        println!("| QuaRot w{b}a{b}    | {:>7.3} | uniform (w{b}a{b} tensor units required) |", rep.ppl);
        uniform_ppl.insert(b, rep.ppl);
    }

    let registry = SchemeRegistry::weight_activation();
    let sens = measure_sensitivity(&lm, &stats, &registry)?;
    let alloc = allocate(
        &lm,
        &GpuSpec::rtx4090(),
        &registry,
        &stats,
        &sens,
        &AllocatorConfig {
            r: 0.75,
            target_avg_bits: 5.0,
            granularity: Granularity::LinearBlock,
            batch_tokens: 512,
        },
    )?;
    let blocks = build_quantized(&lm, &alloc, QuantMethod::HadamardRtn, &stats_rot, seed)?;
    let rep = evaluate(&lm, &corpus, &alloc, &blocks, 16, 4);
    println!(
        "| MxMoE mix ~5b  | {:>7.3} | W{:.2}A{:.2}, int4+int8 units only |",
        rep.ppl,
        alloc.avg_weight_bits(&cfg),
        alloc.avg_act_bits(&cfg)
    );

    let u4 = uniform_ppl[&4];
    let u5 = uniform_ppl[&5];
    assert!(u4 > u5, "w4a4 must be worse than w5a5");
    assert!(rep.ppl < u4, "mixed must beat uniform w4a4");
    println!(
        "\nSHAPE CHECK OK: w4a4 ≫ w5a5; MxMoE mixed {:.3} vs uniform-w5a5 {:.3} (paper: 7.16 vs 8.00)",
        rep.ppl, u5
    );
    Ok(())
}
