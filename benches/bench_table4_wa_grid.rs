//! Tab. 4 — perplexity grid over (weight bits × activation bits) with RTN
//! per-channel/token quantization on qwen15-mini.
//!
//! Paper shape: a cliff below 5-bit activations (the a4 column explodes);
//! weight bits matter far less than activation bits in this regime.

use anyhow::Result;
use mxmoe::alloc::Allocation;
use mxmoe::harness::{build_quantized, evaluate, evaluate_fp32, load_corpus, load_model, QuantMethod};
use mxmoe::alloc::calibrate;
use mxmoe::quant::QuantScheme;

fn main() -> Result<()> {
    let model = std::env::args().skip(1).find(|a| !a.starts_with('-')).unwrap_or_else(|| "qwen15-mini".into());
    let (cfg, lm) = load_model(&model)?;
    let corpus = load_corpus()?;
    let seqs = corpus.sequences("train", cfg.seq_len);
    let calib: Vec<&[u32]> = seqs.iter().take(4).copied().collect();
    let stats = calibrate(&lm, &calib, None)?;

    let fp32 = evaluate_fp32(&lm, &corpus, 16, 4);
    println!("# Tab. 4 — WikiText-2-analogue PPL, RTN token/channel, {model}");
    println!("# fp32 baseline: {:.3}", fp32.ppl);
    let wbits_list: Vec<u8> = if mxmoe::harness::fast_mode() {
        vec![4, 8]
    } else {
        vec![4, 5, 6, 7, 8]
    };
    let abits_list: Vec<u8> = if mxmoe::harness::fast_mode() {
        vec![4, 8]
    } else {
        vec![4, 5, 6, 7, 8]
    };
    print!("| W\\A |");
    for a in &abits_list {
        print!(" a={a:>6} |");
    }
    println!();
    let mut grid = vec![vec![0.0f64; abits_list.len()]; wbits_list.len()];
    for (wi, &w) in wbits_list.iter().enumerate() {
        print!("| w={w} |");
        for (ai, &a) in abits_list.iter().enumerate() {
            let scheme = QuantScheme::new(w, a, -1, -1, true);
            let alloc = Allocation::uniform(&cfg, scheme);
            let blocks = build_quantized(&lm, &alloc, QuantMethod::Rtn, &stats, 7)?;
            let rep = evaluate(&lm, &corpus, &alloc, &blocks, 16, 4);
            grid[wi][ai] = rep.ppl;
            print!(" {:>8.3} |", rep.ppl);
        }
        println!();
    }
    // shape: the a=min column is much worse than the a=max column
    let first_col: f64 = grid.iter().map(|r| r[0]).sum::<f64>() / grid.len() as f64;
    let last_col: f64 =
        grid.iter().map(|r| *r.last().unwrap()).sum::<f64>() / grid.len() as f64;
    println!(
        "\nactivation-bit cliff: mean PPL a={} col = {first_col:.2} vs a={} col = {last_col:.2}",
        abits_list[0],
        abits_list.last().unwrap()
    );
    assert!(first_col > last_col, "low-bit activations must hurt more");
    println!("SHAPE CHECK OK: PPL cliff at low activation bits (paper Tab. 4)");
    Ok(())
}
