//! Fig. 1b — (left) RTX-4090 roofline analysis with the scheme crossover
//! points the paper reports (W4A16 vs W8A8 at A≈83, W2A16 vs W4A4 at A≈42);
//! (right) expert activation-frequency distribution of a trained model.

use mxmoe::alloc::calibrate;
use mxmoe::costmodel::roofline::{crossover_m, gemm_tflops};
use mxmoe::costmodel::GpuSpec;
use mxmoe::harness::{load_corpus, load_model};
use mxmoe::quant::QuantScheme;

fn main() -> anyhow::Result<()> {
    let gpu = GpuSpec::rtx4090();
    let (n, k) = (8192, 8192);

    println!("# Fig. 1b (left): roofline on {} (n=k=8192)", gpu.name);
    println!("| m (≈AI) | fp16 | w8a8 | w4a16 | w4a4 | w2a16 |  best");
    let schemes = [
        QuantScheme::FP16,
        QuantScheme::W8A8,
        QuantScheme::W4A16,
        QuantScheme::W4A4,
        QuantScheme::W2A16G128,
    ];
    for m in [1usize, 8, 16, 32, 42, 64, 83, 128, 256, 512, 1024, 4096] {
        let tf: Vec<f64> = schemes.iter().map(|s| gemm_tflops(&gpu, s, m, n, k)).collect();
        let best = schemes
            .iter()
            .zip(&tf)
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        println!(
            "| {m:>7} | {:>6.1} | {:>6.1} | {:>6.1} | {:>6.1} | {:>6.1} |  {}",
            tf[0], tf[1], tf[2], tf[3], tf[4], best
        );
    }

    let c1 = crossover_m(&gpu, &QuantScheme::W4A16, &QuantScheme::W8A8, n, k).unwrap();
    let c2 = crossover_m(&gpu, &QuantScheme::W2A16G128, &QuantScheme::W4A4, n, k).unwrap();
    println!("\ncrossovers: W4A16→W8A8 at m={c1} (paper: 83), W2A16→W4A4 at m={c2} (paper: 42)");

    // ---- right panel: activation frequencies ----
    let model = std::env::args().skip(1).find(|a| !a.starts_with('-')).unwrap_or_else(|| "dsv2-mini".into());
    let (cfg, lm) = load_model(&model)?;
    let corpus = load_corpus()?;
    let seqs = corpus.sequences("train", cfg.seq_len);
    let calib: Vec<&[u32]> = seqs.iter().take(16).copied().collect();
    let stats = calibrate(&lm, &calib, None)?;
    let mid = stats.layers.len() / 2;
    let counts = &stats.layers[mid].activation_counts;
    let mut sorted = counts.clone();
    sorted.sort_unstable_by(|a, b| b.cmp(a));
    println!("\n# Fig. 1b (right): activation counts, {model} MoE layer idx {mid}");
    println!("top-8 experts : {:?}", &sorted[..8.min(sorted.len())]);
    println!("bottom-8      : {:?}", &sorted[sorted.len().saturating_sub(8)..]);
    let max = *sorted.first().unwrap() as f64;
    let min_nz = sorted.iter().rev().find(|&&c| c > 0).copied().unwrap_or(1) as f64;
    println!("max/min(+) activation ratio = {:.1}× (paper: >10×)", max / min_nz);
    Ok(())
}
