//! §Observability — lifecycle-tracing and observatory-sampler overhead on
//! the serving hot path.
//!
//! Scenario: a serving-shape model with a mixed-precision plan serves the
//! same fixed scoring trace three times — tracing off, tracing on, and
//! observatory sampler on. Both observers must be pure: responses
//! bit-identical to the baseline, and each instrumented run's throughput
//! within 3% of it (the trace collectors are lock-free per-thread rings;
//! the sampler is one polling thread reading already-published state).
//! The traced run's merged trace is exported to `trace.json` (Chrome
//! trace-event JSON, loadable at <https://ui.perfetto.dev>) and
//! structurally validated, so CI can upload it as an artifact. Results
//! land in `BENCH_trace_overhead.json`.
//!
//! `--smoke` shrinks the trace and measures without gating (shared CI
//! runners are too noisy for a 3% bound); bit-identity and trace validity
//! are enforced in both modes.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use anyhow::Result;
use mxmoe::coordinator::{Cluster, ClusterConfig, ClusterReport, ServeConfig};
use mxmoe::harness::{mixed_runtime_plan, require_artifacts, save_model_mxt};
use mxmoe::moe::{ModelConfig, MoeLm};
use mxmoe::obs::{validate_chrome_trace, SampleConfig, TraceConfig};
use mxmoe::ser::Json;
use mxmoe::util::Rng;

const MODEL_SEED: u64 = 0x7ACE_0BE4;
const OVERHEAD_BOUND: f64 = 0.03;

/// Serving-shape model (hidden=128, inter=64 — what the AOT export ships).
fn serving_cfg() -> ModelConfig {
    ModelConfig {
        name: "trace-overhead-bench".into(),
        vocab: 64,
        hidden: 128,
        layers: 2,
        heads: 4,
        n_experts: 4,
        n_shared: 1,
        topk: 2,
        inter: 64,
        dense_first: false,
        seq_len: 24,
    }
}

/// The fixed scoring trace: varying lengths, same seed for every run.
fn request_trace(cfg: &ModelConfig, n_requests: usize) -> Vec<Vec<u32>> {
    let mut rng = Rng::new(0x7ACE_5EED);
    (0..n_requests)
        .map(|i| {
            let len = [cfg.seq_len, 5, 16, 9, cfg.seq_len, 11][i % 6];
            (0..len).map(|_| rng.below(cfg.vocab as u64) as u32).collect()
        })
        .collect()
}

struct RunResult {
    elapsed_s: f64,
    tokens: usize,
    responses: Vec<(u32, u64)>,
    /// Time-series points the observatory sampler pushed (0 when off).
    samples: u64,
    report: ClusterReport,
}

/// Serve `reqs` on a 2-replica cluster with the given trace and sampler
/// switches: a warmup round (engine build, executable compilation) then
/// the timed trace.
fn run_cluster(
    cfg: &ModelConfig,
    weights: &PathBuf,
    artifacts: &PathBuf,
    trace: TraceConfig,
    sample: SampleConfig,
    reqs: &[Vec<u32>],
) -> Result<RunResult> {
    let cluster = Cluster::start(
        cfg.clone(),
        weights.clone(),
        artifacts.clone(),
        mixed_runtime_plan(cfg),
        ClusterConfig {
            replicas: 2,
            // one request per batch: identical batch composition whether
            // the observers are on or off, which is what makes
            // bit-identity (and a fair overhead comparison) well-defined
            serve: ServeConfig {
                max_batch_seqs: 1,
                max_wait: Duration::from_millis(1),
                trace,
                ..Default::default()
            },
            sample,
            ..Default::default()
        },
    )?;
    let warmup: Vec<_> = (0..4).map(|_| cluster.submit(reqs[0].clone())).collect::<Result<_>>()?;
    for rx in warmup {
        rx.recv_timeout(Duration::from_secs(600)).expect("warmup response");
    }
    let start = Instant::now();
    let receivers: Vec<_> =
        reqs.iter().map(|r| cluster.submit(r.clone())).collect::<Result<_>>()?;
    let responses: Vec<(u32, u64)> = receivers
        .iter()
        .map(|rx| {
            let r = rx.recv_timeout(Duration::from_secs(600)).expect("response");
            (r.next_token, r.mean_nll.to_bits())
        })
        .collect();
    let elapsed_s = start.elapsed().as_secs_f64();
    let tokens: usize = reqs.iter().map(|r| r.len()).sum();
    let samples: u64 = cluster.observatory().snapshot().series.iter().map(|s| s.pushed).sum();
    Ok(RunResult { elapsed_s, tokens, responses, samples, report: cluster.shutdown() })
}

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!("# §Observability — lifecycle-tracing overhead");

    let mut results = vec![
        ("schema", Json::str("mxmoe-bench-v1")),
        ("bench", Json::str("trace_overhead")),
        ("smoke", Json::Bool(smoke)),
    ];
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping trace-overhead bench: artifacts not built (run `make artifacts`)");
        std::fs::write(
            "BENCH_trace_overhead.json",
            Json::obj(results.iter().map(|(k, v)| (*k, v.clone())).collect()).pretty(),
        )?;
        return Ok(());
    };

    let cfg = serving_cfg();
    let weights = std::env::temp_dir().join("mxmoe_bench_trace_overhead.mxt");
    let lm = MoeLm::random(&cfg, &mut Rng::new(MODEL_SEED));
    save_model_mxt(&lm, &weights)?;
    let reqs = request_trace(&cfg, if smoke { 24 } else { 96 });
    // alternate off/on rounds and keep the best of each, so slow-machine
    // noise (cache state, frequency scaling) hits both switches equally
    let rounds = if smoke { 1 } else { 3 };

    // a tight interval so even a short run collects real samples; the
    // production default (250ms) is strictly cheaper
    let sampler_cfg = SampleConfig { enabled: true, interval_ms: 10, ..Default::default() };

    let mut off_best: Option<RunResult> = None;
    let mut on_best: Option<RunResult> = None;
    let mut sampled_best: Option<RunResult> = None;
    for round in 0..rounds {
        let off = run_cluster(
            &cfg,
            &weights,
            &artifacts,
            TraceConfig::default(),
            SampleConfig::default(),
            &reqs,
        )?;
        let on = run_cluster(
            &cfg,
            &weights,
            &artifacts,
            TraceConfig::on(),
            SampleConfig::default(),
            &reqs,
        )?;
        let sampled =
            run_cluster(&cfg, &weights, &artifacts, TraceConfig::default(), sampler_cfg, &reqs)?;
        assert_eq!(
            on.responses, off.responses,
            "round {round}: tracing changed a served bit — it must be a pure observer"
        );
        assert_eq!(
            sampled.responses, off.responses,
            "round {round}: the sampler changed a served bit — it must be a pure observer"
        );
        assert!(off.report.trace.is_empty(), "tracing off must record nothing");
        assert!(!on.report.trace.is_empty(), "tracing on must record the run");
        assert_eq!(off.samples, 0, "sampler off must record no series points");
        assert!(sampled.samples > 0, "sampler on must record series points");
        let off_better = match &off_best {
            None => true,
            Some(b) => off.elapsed_s < b.elapsed_s,
        };
        if off_better {
            off_best = Some(off);
        }
        let on_better = match &on_best {
            None => true,
            Some(b) => on.elapsed_s < b.elapsed_s,
        };
        if on_better {
            on_best = Some(on);
        }
        let sampled_better = match &sampled_best {
            None => true,
            Some(b) => sampled.elapsed_s < b.elapsed_s,
        };
        if sampled_better {
            sampled_best = Some(sampled);
        }
    }
    let off = off_best.expect("at least one round");
    let on = on_best.expect("at least one round");
    let sampled = sampled_best.expect("at least one round");
    let _ = std::fs::remove_file(&weights);

    // export + validate the traced run the same way `mxmoe trace-dump`
    // does, so CI can upload trace.json and inspect it in Perfetto
    let trace_out = PathBuf::from("trace.json");
    on.report.trace.write_chrome_trace(&trace_out)?;
    let check = validate_chrome_trace(&std::fs::read_to_string(&trace_out)?)?;
    assert_eq!(check.begins, check.ends, "unmatched async begin/end in exported trace");

    let t_off = off.tokens as f64 / off.elapsed_s;
    let t_on = on.tokens as f64 / on.elapsed_s;
    let t_sampled = sampled.tokens as f64 / sampled.elapsed_s;
    let overhead = on.elapsed_s / off.elapsed_s - 1.0;
    let sampler_overhead = sampled.elapsed_s / off.elapsed_s - 1.0;
    println!(
        "| trace off  | {:>4} req | {:>6} tok | {:>8.3} s | {:>9.1} tok/s |",
        reqs.len(),
        off.tokens,
        off.elapsed_s,
        t_off
    );
    println!(
        "| trace on   | {:>4} req | {:>6} tok | {:>8.3} s | {:>9.1} tok/s | {} events |",
        reqs.len(),
        on.tokens,
        on.elapsed_s,
        t_on,
        on.report.trace.len()
    );
    println!(
        "| sampler on | {:>4} req | {:>6} tok | {:>8.3} s | {:>9.1} tok/s | {} points |",
        reqs.len(),
        sampled.tokens,
        sampled.elapsed_s,
        t_sampled,
        sampled.samples
    );
    println!("trace overhead: {:.2}% (bound {:.0}%)", 100.0 * overhead, 100.0 * OVERHEAD_BOUND);
    println!(
        "sampler overhead: {:.2}% (bound {:.0}%)",
        100.0 * sampler_overhead,
        100.0 * OVERHEAD_BOUND
    );
    println!("wrote trace.json ({} chrome events, validated)", check.events);

    if !smoke {
        assert!(
            overhead <= OVERHEAD_BOUND,
            "tracing overhead {:.2}% exceeds the {:.0}% acceptance bound",
            100.0 * overhead,
            100.0 * OVERHEAD_BOUND
        );
        assert!(
            sampler_overhead <= OVERHEAD_BOUND,
            "sampler overhead {:.2}% exceeds the {:.0}% acceptance bound",
            100.0 * sampler_overhead,
            100.0 * OVERHEAD_BOUND
        );
    }

    results.extend([
        ("requests", Json::num(reqs.len() as f64)),
        ("tokens", Json::num(off.tokens as f64)),
        ("rounds", Json::num(rounds as f64)),
        ("trace_off_s", Json::num(off.elapsed_s)),
        ("trace_on_s", Json::num(on.elapsed_s)),
        ("trace_off_tok_per_s", Json::num(t_off)),
        ("trace_on_tok_per_s", Json::num(t_on)),
        ("overhead_frac", Json::num(overhead)),
        ("overhead_bound", Json::num(OVERHEAD_BOUND)),
        ("sampler_on_s", Json::num(sampled.elapsed_s)),
        ("sampler_on_tok_per_s", Json::num(t_sampled)),
        ("sampler_overhead_frac", Json::num(sampler_overhead)),
        ("sampler_points", Json::num(sampled.samples as f64)),
        ("trace_events", Json::num(on.report.trace.len() as f64)),
        ("trace_dropped", Json::num(on.report.trace.dropped as f64)),
        ("chrome_events", Json::num(check.events as f64)),
        ("bit_identical", Json::Bool(true)),
    ]);
    std::fs::write(
        "BENCH_trace_overhead.json",
        Json::obj(results.iter().map(|(k, v)| (*k, v.clone())).collect()).pretty(),
    )?;
    println!("\nwrote BENCH_trace_overhead.json");
    Ok(())
}
