//! §HTTP-Front-Door — connection-scale load bench for the streaming
//! front door.
//!
//! Three phases against live mini-model clusters on loopback:
//!
//! 1. **Bit-identity** — the same prompts generated in-process (direct
//!    [`Ticket`] streaming) and over HTTP SSE must stream the exact same
//!    token ids with the same finish reason: the wire format is a
//!    transport, not a reinterpretation.
//! 2. **Connection storm** — N concurrent SSE clients (1000 full,
//!    64 smoke) held simultaneously live on a barrier, with ≥25%
//!    disconnecting mid-stream. Disconnects must reconcile *exactly* as
//!    cancellations: the admission ledger identity
//!    `admitted == responses + cancelled + failed` is asserted on the
//!    drained cluster report.
//! 3. **Shed semantics** — against a deliberately tiny cluster
//!    (queue bound 2, KV pool 4 pages), queue sheds must come back as
//!    HTTP 429 and KV exhaustion as 503, both carrying `Retry-After`.
//!
//! Writes `BENCH_http.json` (mxmoe-bench-v1 envelope). `--smoke` shrinks
//! the storm; every correctness assertion stays enforced. Self-skips
//! (with a `skipped` stub) when the AOT artifacts are not built.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::{Arc, Barrier, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use mxmoe::coordinator::{Cluster, ClusterConfig, ServeConfig};
use mxmoe::harness::{self, mixed_runtime_plan, save_model_mxt, MINI_MODEL_SEED};
use mxmoe::moe::{ModelConfig, MoeLm};
use mxmoe::ser::Json;
use mxmoe::serve::{
    AdmissionConfig, DecodePolicy, FinishReason, HttpConfig, HttpServer, KV_PAGE_SIZE,
};
use mxmoe::util::Rng;

/// Generous server-side budgets: a 1-core runner decoding behind 1000
/// queued generations is slow, not wrong.
const LONG: Duration = Duration::from_secs(600);

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!("# §HTTP-Front-Door — SSE streaming, disconnect-as-cancel, connection-scale load");

    let envelope = vec![
        ("schema", Json::str("mxmoe-bench-v1")),
        ("bench", Json::str("http")),
        ("smoke", Json::Bool(smoke)),
    ];
    let Some(artifacts) = harness::require_artifacts() else {
        eprintln!("skipping http bench: artifacts not built (run `make artifacts`)");
        let mut stub = envelope;
        stub.push(("skipped", Json::Bool(true)));
        std::fs::write(
            "BENCH_http.json",
            Json::obj(stub.iter().map(|(k, v)| (*k, v.clone())).collect()).pretty(),
        )?;
        return Ok(());
    };

    let t0 = Instant::now();
    let (cfg, weights) = model_source()?;
    let clients = if smoke { 64 } else { 1000 };
    let disconnectors = clients / 4; // ≥25% of the storm drops mid-stream
    let max_new = if smoke { 8 } else { 16 };

    // ---- phases 1+2 share one cluster sized to hold the whole storm ----
    let cluster = Arc::new(Cluster::start(
        cfg.clone(),
        weights.clone(),
        artifacts.clone(),
        mixed_runtime_plan(&cfg),
        ClusterConfig {
            replicas: 2,
            serve: ServeConfig {
                max_batch_seqs: 4,
                max_wait: Duration::from_millis(2),
                ..Default::default()
            },
            admission: AdmissionConfig {
                max_queued_seqs: 2 * clients + 64,
                max_queued_tokens: 1 << 20,
                ..Default::default()
            },
            ..Default::default()
        },
    )?);
    let server = HttpServer::start(
        cluster.clone(),
        HttpConfig {
            max_connections: 2 * clients + 64,
            request_timeout: LONG,
            stream_event_timeout: LONG,
            ..HttpConfig::default()
        },
    )?;
    let addr = server.addr();

    // ---- phase 1: streamed tokens bit-identical to in-process tickets ----
    let n_prompts = 8;
    let mut rng = Rng::new(0xB17_1DE7);
    for i in 0..n_prompts {
        let prompt: Vec<u32> =
            (0..4 + i % 8).map(|_| rng.below(cfg.vocab as u64) as u32).collect();
        let ticket = cluster.generate(prompt.clone(), max_new, vec![])?;
        let (want, want_reason) = ticket.collect_tokens(LONG)?;
        let got = sse_generate(addr, &prompt, max_new)?;
        ensure!(
            got.tokens == want,
            "prompt {i}: HTTP stream diverged from in-process ticket \
             (http {:?} vs direct {:?})",
            got.tokens,
            want
        );
        ensure!(
            got.reason.as_deref() == Some(finish_name(want_reason)),
            "prompt {i}: finish reason diverged ({:?} vs {})",
            got.reason,
            finish_name(want_reason)
        );
    }
    println!("| bit-identity      | {n_prompts} prompts | HTTP SSE == in-process Ticket |");

    // ---- phase 2: the storm ----
    let barrier = Arc::new(Barrier::new(clients));
    let outcomes: Arc<Mutex<Vec<StormOutcome>>> = Arc::new(Mutex::new(Vec::new()));
    let mut rng = Rng::new(0x5707_4131);
    let mut handles = Vec::with_capacity(clients);
    for i in 0..clients {
        let prompt: Vec<u32> =
            (0..4 + i % 5).map(|_| rng.below(cfg.vocab as u64) as u32).collect();
        let barrier = barrier.clone();
        let outcomes = outcomes.clone();
        let disconnect = i < disconnectors;
        let h = thread::Builder::new()
            .name(format!("storm-{i}"))
            .stack_size(256 * 1024)
            .spawn(move || {
                let out = storm_client(addr, &prompt, max_new, disconnect, &barrier);
                outcomes.lock().unwrap().push(out);
            })
            .context("spawn storm client")?;
        handles.push(h);
    }
    for h in handles {
        h.join().map_err(|_| anyhow::anyhow!("storm client panicked"))?;
    }
    let outs = outcomes.lock().unwrap();
    let served = outs.iter().filter(|o| matches!(o, StormOutcome::Served)).count();
    let dropped = outs.iter().filter(|o| matches!(o, StormOutcome::Disconnected)).count();
    let shed = outs.iter().filter(|o| matches!(o, StormOutcome::Shed(_))).count();
    let errors: Vec<&String> = outs
        .iter()
        .filter_map(|o| match o {
            StormOutcome::Error(e) => Some(e),
            _ => None,
        })
        .collect();
    ensure!(errors.is_empty(), "{} storm client error(s): {:?}", errors.len(), &errors[..1]);
    ensure!(dropped == disconnectors, "every disconnector dropped mid-stream");
    ensure!(served + dropped + shed == clients, "every client accounted for");
    drop(outs);

    // every admitted request must reach a terminal before the ledger can
    // balance: poll the live report until it does
    settle(&cluster)?;
    let http = server.shutdown();
    let cluster = Arc::try_unwrap(cluster)
        .map_err(|_| anyhow::anyhow!("server shutdown left a live backend reference"))?;
    let report = cluster.shutdown();
    let admitted = report.admission.admitted;
    let responses = report.total_requests();
    let cancelled = report.admission.cancelled;
    let failed = report.admission.failed;
    ensure!(
        admitted == responses + cancelled + failed,
        "storm ledger must reconcile exactly: admitted {admitted} != \
         responses {responses} + cancelled {cancelled} + failed {failed}"
    );
    ensure!(failed == 0, "no engine failures expected, got {failed}");
    ensure!(
        cancelled >= 1,
        "a ≥25% disconnect storm must shed at least one generation as cancelled"
    );
    ensure!(http.disconnects >= 1, "the server must observe mid-stream disconnects");
    ensure!(
        http.peak_connections >= clients,
        "storm never held {clients} concurrent streams (peak {})",
        http.peak_connections
    );
    println!(
        "| storm             | {clients} clients | peak {} conns | {served} served | \
         {dropped} dropped | {cancelled} cancelled | ledger exact |",
        http.peak_connections
    );

    // ---- phase 3: shed semantics on a deliberately tiny cluster ----
    let shed_stats = shed_phase(&cfg, &weights, &artifacts)?;
    println!(
        "| shed semantics    | {} x 429 | {} x 503 | Retry-After on both |",
        shed_stats.seen_429, shed_stats.seen_503
    );

    let elapsed_s = t0.elapsed().as_secs_f64();
    let mut out = envelope;
    out.push(("clients", Json::num(clients as f64)));
    out.push(("elapsed_s", Json::num(elapsed_s)));
    out.push((
        "bit_identity",
        Json::obj(vec![
            ("prompts", Json::num(n_prompts as f64)),
            ("identical", Json::Bool(true)),
        ]),
    ));
    out.push((
        "storm",
        Json::obj(vec![
            ("clients", Json::num(clients as f64)),
            ("disconnectors", Json::num(disconnectors as f64)),
            ("peak_connections", Json::num(http.peak_connections as f64)),
            ("served", Json::num(served as f64)),
            ("dropped", Json::num(dropped as f64)),
            ("shed", Json::num(shed as f64)),
            ("admitted", Json::num(admitted as f64)),
            ("responses", Json::num(responses as f64)),
            ("cancelled", Json::num(cancelled as f64)),
            ("failed", Json::num(failed as f64)),
            ("ledger_balanced", Json::Bool(true)),
            ("server_disconnects", Json::num(http.disconnects as f64)),
            ("sse_events", Json::num(http.sse_events as f64)),
            ("bytes_out", Json::num(http.bytes_out as f64)),
        ]),
    ));
    out.push((
        "shed",
        Json::obj(vec![
            ("rejected_429", Json::num(shed_stats.seen_429 as f64)),
            ("rejected_503", Json::num(shed_stats.seen_503 as f64)),
            ("retry_after_seen", Json::Bool(true)),
        ]),
    ));
    std::fs::write(
        "BENCH_http.json",
        Json::obj(out.iter().map(|(k, v)| (*k, v.clone())).collect()).pretty(),
    )?;
    println!("\nwrote BENCH_http.json ({elapsed_s:.1}s)");
    Ok(())
}

/// Same checkpoint policy as the scenario engine: cached `ci-mini` when
/// built, else a seeded random one in a temp path.
fn model_source() -> Result<(ModelConfig, PathBuf)> {
    let mini = harness::artifacts_dir().join("model_ci-mini.mxt");
    if mini.exists() {
        let (cfg, _) = harness::load_model("ci-mini")?;
        return Ok((cfg, mini));
    }
    let cfg = ModelConfig::by_name("ci-mini")?;
    let lm = MoeLm::random(&cfg, &mut Rng::new(MINI_MODEL_SEED));
    let path = std::env::temp_dir().join("mxmoe_bench_http.mxt");
    save_model_mxt(&lm, &path)?;
    Ok((cfg, path))
}

fn finish_name(r: FinishReason) -> &'static str {
    match r {
        FinishReason::Stop => "stop",
        FinishReason::Length => "length",
        FinishReason::Cancelled => "cancelled",
        FinishReason::Failed => "failed",
    }
}

/// Poll the live report until every admitted request reached a terminal
/// and the admission queue drained.
fn settle(cluster: &Cluster) -> Result<()> {
    let t0 = Instant::now();
    loop {
        let r = cluster.live_report();
        if cluster.queued() == (0, 0) && r.admitted == r.requests + r.cancelled + r.failed {
            return Ok(());
        }
        ensure!(
            t0.elapsed() < LONG,
            "cluster failed to settle: admitted {} vs responses {} + cancelled {} + failed {}",
            r.admitted,
            r.requests,
            r.cancelled,
            r.failed
        );
        thread::sleep(Duration::from_millis(10));
    }
}

// ---------------------------------------------------------------------------
// Minimal HTTP/SSE client (std-only, mirrors the server's hand-rolled wire)
// ---------------------------------------------------------------------------

struct Reply {
    status: u16,
    headers: Vec<(String, String)>,
    body: String,
}

impl Reply {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }
}

/// Send raw bytes, read to EOF (the server closes every connection), and
/// split the reply.
fn roundtrip(addr: SocketAddr, raw: &[u8]) -> Result<Reply> {
    let mut s = TcpStream::connect(addr).context("connect")?;
    s.set_read_timeout(Some(LONG))?;
    s.write_all(raw).context("send request")?;
    let mut bytes = Vec::new();
    s.read_to_end(&mut bytes).context("read reply")?;
    parse_reply(&bytes)
}

fn parse_reply(bytes: &[u8]) -> Result<Reply> {
    let text = String::from_utf8_lossy(bytes);
    let (head, body) =
        text.split_once("\r\n\r\n").context("reply has no header/body separator")?;
    let mut lines = head.lines();
    let status_line = lines.next().context("empty reply")?;
    let status: u16 = status_line
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("bad status line '{status_line}'"))?;
    let headers = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(k, v)| (k.to_ascii_lowercase(), v.trim().to_string()))
        .collect();
    Ok(Reply { status, headers, body: body.to_string() })
}

fn post(addr: SocketAddr, path: &str, body: &str) -> Result<Reply> {
    roundtrip(
        addr,
        format!(
            "POST {path} HTTP/1.1\r\nhost: bench\r\ncontent-length: {}\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    )
}

fn generate_body(prompt: &[u32], max_new: usize) -> String {
    let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    format!("{{\"tokens\":[{}],\"max_new_tokens\":{max_new}}}", toks.join(","))
}

/// Parsed SSE generation stream.
struct SseOutcome {
    id: u64,
    tokens: Vec<u32>,
    reason: Option<String>,
}

fn parse_sse(body: &str) -> Result<SseOutcome> {
    let mut out = SseOutcome { id: 0, tokens: Vec::new(), reason: None };
    for frame in body.split("\n\n").filter(|f| !f.is_empty()) {
        let mut lines = frame.lines();
        let event = lines
            .next()
            .and_then(|l| l.strip_prefix("event: "))
            .with_context(|| format!("frame without event line: {frame:?}"))?;
        let data = lines
            .next()
            .and_then(|l| l.strip_prefix("data: "))
            .with_context(|| format!("frame without data line: {frame:?}"))?;
        ensure!(lines.next().is_none(), "multi-line SSE data: {frame:?}");
        let j = Json::parse(data).with_context(|| format!("bad frame JSON: {data:?}"))?;
        match event {
            "start" => out.id = j.req_usize("id")? as u64,
            "token" => {
                let tok = j.req_usize("token")?;
                let index = j.req_usize("index")?;
                ensure!(index == out.tokens.len(), "token index gap at {index}");
                out.tokens.push(tok as u32);
            }
            "done" => {
                ensure!(out.reason.is_none(), "two terminal events in one stream");
                out.reason = Some(j.req_str("reason")?.to_string());
                ensure!(j.req_usize("generated")? == out.tokens.len(), "generated count");
                ensure!(j.get("response").is_some(), "done event without response field");
            }
            other => bail!("unknown SSE event '{other}'"),
        }
    }
    ensure!(out.id != 0, "stream missing start event");
    ensure!(out.reason.is_some(), "stream missing terminal done event");
    Ok(out)
}

/// Full HTTP SSE generation: POST, stream to EOF, parse every frame.
fn sse_generate(addr: SocketAddr, prompt: &[u32], max_new: usize) -> Result<SseOutcome> {
    let reply = post(addr, "/v1/generate", &generate_body(prompt, max_new))?;
    ensure!(reply.status == 200, "generate returned {}: {}", reply.status, reply.body);
    parse_sse(&reply.body)
}

// ---------------------------------------------------------------------------
// Storm clients
// ---------------------------------------------------------------------------

enum StormOutcome {
    /// Streamed to the terminal `done` event.
    Served,
    /// Deliberately dropped the connection mid-stream.
    Disconnected,
    /// Admission shed the request (HTTP status).
    Shed(u16),
    Error(String),
}

/// One storm client. Every path reaches the barrier exactly once, after
/// the connection is live (post-admission, pre-token), so the whole storm
/// is simultaneously connected when it releases.
fn storm_client(
    addr: SocketAddr,
    prompt: &[u32],
    max_new: usize,
    disconnect: bool,
    barrier: &Barrier,
) -> StormOutcome {
    match storm_connect(addr, prompt, max_new) {
        Err(e) => {
            barrier.wait();
            StormOutcome::Error(format!("{e:#}"))
        }
        Ok(Conn::Shed(status)) => {
            barrier.wait();
            StormOutcome::Shed(status)
        }
        Ok(Conn::Streaming(mut s, mut buf)) => {
            barrier.wait();
            if disconnect {
                // read up to the first token frame, then vanish
                while !buf.contains("event: token") {
                    let mut chunk = [0u8; 1024];
                    match s.read(&mut chunk) {
                        Ok(0) => break, // tiny generation already finished
                        Ok(n) => buf.push_str(&String::from_utf8_lossy(&chunk[..n])),
                        Err(e) => return StormOutcome::Error(format!("mid-stream read: {e}")),
                    }
                }
                drop(s);
                return StormOutcome::Disconnected;
            }
            let mut rest = String::new();
            if let Err(e) = s.read_to_string(&mut rest) {
                return StormOutcome::Error(format!("stream read: {e}"));
            }
            buf.push_str(&rest);
            match parse_sse(&buf) {
                Ok(out) if out.reason.as_deref() == Some("stop")
                    || out.reason.as_deref() == Some("length") =>
                {
                    StormOutcome::Served
                }
                Ok(out) => StormOutcome::Error(format!("unexpected finish {:?}", out.reason)),
                Err(e) => StormOutcome::Error(format!("{e:#}")),
            }
        }
    }
}

enum Conn {
    /// Admitted: live SSE socket + everything read so far (headers
    /// stripped, ends just past the `start` frame).
    Streaming(TcpStream, String),
    Shed(u16),
}

fn storm_connect(addr: SocketAddr, prompt: &[u32], max_new: usize) -> Result<Conn> {
    let body = generate_body(prompt, max_new);
    let mut s = TcpStream::connect(addr).context("connect")?;
    s.set_read_timeout(Some(LONG))?;
    s.write_all(
        format!(
            "POST /v1/generate HTTP/1.1\r\nhost: bench\r\ncontent-length: {}\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    )?;
    // read headers + the start frame (proof of admission)
    let mut buf = String::new();
    loop {
        if let Some(head_end) = buf.find("\r\n\r\n") {
            let status: u16 = buf
                .lines()
                .next()
                .and_then(|l| l.split(' ').nth(1))
                .and_then(|c| c.parse().ok())
                .context("bad status line")?;
            if status != 200 {
                // drain the shed reply so the server's write completes
                let mut rest = String::new();
                let _ = s.read_to_string(&mut rest);
                return Ok(Conn::Shed(status));
            }
            if buf[head_end..].contains("\n\n") {
                return Ok(Conn::Streaming(s, buf.split_off(head_end + 4)));
            }
        }
        let mut chunk = [0u8; 1024];
        let n = s.read(&mut chunk).context("read stream head")?;
        ensure!(n > 0, "stream closed before start event");
        buf.push_str(&String::from_utf8_lossy(&chunk[..n]));
    }
}

// ---------------------------------------------------------------------------
// Shed-semantics phase
// ---------------------------------------------------------------------------

struct ShedStats {
    seen_429: usize,
    seen_503: usize,
}

/// Tiny cluster: admission queue of 2 sequences / 256 tokens, KV pool of
/// 4 pages. Concurrent scores must overflow the queue into 429s; a long
/// generation holding the KV pool must turn later prompts into 503s.
/// Both must carry `Retry-After`.
fn shed_phase(cfg: &ModelConfig, weights: &PathBuf, artifacts: &PathBuf) -> Result<ShedStats> {
    let cluster = Arc::new(Cluster::start(
        cfg.clone(),
        weights.clone(),
        artifacts.clone(),
        mixed_runtime_plan(cfg),
        ClusterConfig {
            replicas: 1,
            serve: ServeConfig {
                max_batch_seqs: 2,
                max_wait: Duration::from_millis(2),
                ..Default::default()
            },
            admission: AdmissionConfig {
                max_queued_seqs: 2,
                max_queued_tokens: 256,
                ..Default::default()
            },
            decode: DecodePolicy {
                kv_budget_tokens: 4 * KV_PAGE_SIZE,
                kv_page_size: KV_PAGE_SIZE,
                max_active_seqs: 2,
                ..Default::default()
            },
            ..Default::default()
        },
    )?);
    let server = HttpServer::start(
        cluster.clone(),
        HttpConfig { request_timeout: LONG, stream_event_timeout: LONG, ..HttpConfig::default() },
    )?;
    let addr = server.addr();

    // 429: flood the bounded queue with concurrent scores
    let mut rng = Rng::new(0x5EED_0429);
    let mut seen_429 = 0usize;
    let mut floods = Vec::new();
    for _ in 0..16 {
        let toks: Vec<String> =
            (0..64).map(|_| rng.below(cfg.vocab as u64).to_string()).collect();
        let body = format!("{{\"tokens\":[{}]}}", toks.join(","));
        floods.push(thread::spawn(move || post(addr, "/v1/score", &body)));
    }
    for f in floods {
        let reply = f.join().map_err(|_| anyhow::anyhow!("flood client panicked"))??;
        match reply.status {
            200 => {}
            429 => {
                let retry: u64 = reply
                    .header("retry-after")
                    .context("429 without Retry-After")?
                    .parse()
                    .context("Retry-After must be integral seconds")?;
                ensure!(retry >= 1, "Retry-After must be at least 1s");
                let j = Json::parse(&reply.body)?;
                ensure!(j.req_str("reason")? == "queue-full", "429 reason");
                j.req_usize("retry_after_ms")?;
                seen_429 += 1;
            }
            other => bail!("unexpected flood status {other}: {}", reply.body),
        }
    }
    ensure!(seen_429 >= 1, "queue flood produced no 429s");

    // 503: park a generation that grows to fill the 4-page KV pool
    // (1 prompt page + headroom + 32 decode tokens = the whole budget),
    // then probe with prompts that cannot fit next to it
    let parked = thread::Builder::new()
        .name("kv-parker".into())
        .spawn(move || {
            let prompt: Vec<u32> = (0..KV_PAGE_SIZE as u32).collect();
            // a probe may transiently hold the pool; retry until parked
            let mut last = sse_generate(addr, &prompt, 2 * KV_PAGE_SIZE);
            for _ in 0..100 {
                if last.is_ok() {
                    break;
                }
                thread::sleep(Duration::from_millis(20));
                last = sse_generate(addr, &prompt, 2 * KV_PAGE_SIZE);
            }
            last
        })
        .context("spawn kv parker")?;
    let mut seen_503 = 0usize;
    let probe: Vec<u32> = (0..(3 * KV_PAGE_SIZE) as u32).collect();
    for _ in 0..100 {
        let reply = post(addr, "/v1/generate", &generate_body(&probe, 2))?;
        match reply.status {
            503 => {
                let retry: u64 = reply
                    .header("retry-after")
                    .context("503 without Retry-After")?
                    .parse()
                    .context("Retry-After must be integral seconds")?;
                ensure!(retry >= 1, "Retry-After must be at least 1s");
                let j = Json::parse(&reply.body)?;
                ensure!(j.req_str("reason")? == "kv-exhausted", "503 reason");
                seen_503 += 1;
                break;
            }
            200 => {
                // probe squeezed in before the parked generation claimed
                // the pool — let its stream finish and try again
                parse_sse(&reply.body)?;
            }
            429 => {} // queue-full race with the parked generation
            other => bail!("unexpected probe status {other}: {}", reply.body),
        }
        thread::sleep(Duration::from_millis(20));
    }
    ensure!(seen_503 >= 1, "KV-pool probes never saw a 503");

    let out = parked
        .join()
        .map_err(|_| anyhow::anyhow!("kv parker panicked"))?
        .context("parked generation failed")?;
    ensure!(
        matches!(out.reason.as_deref(), Some("length") | Some("stop")),
        "parked generation should finish served, got {:?}",
        out.reason
    );

    settle(&cluster)?;
    server.shutdown();
    let cluster = Arc::try_unwrap(cluster)
        .map_err(|_| anyhow::anyhow!("server shutdown left a live backend reference"))?;
    let report = cluster.shutdown();
    let a = &report.admission;
    ensure!(
        a.admitted == report.total_requests() + a.cancelled + a.failed,
        "shed-phase ledger must reconcile exactly"
    );
    ensure!(a.rejected_kv >= 1, "admission must account the KV sheds");
    Ok(ShedStats { seen_429, seen_503 })
}
