//! Fig. 1a — quantization loss heterogeneity across experts and linear
//! blocks of one MoE layer under several schemes.
//!
//! Paper shape: per-expert Δ varies widely (e.g. expert 40 ≫ expert 37 on
//! DSv2-Lite layer 11), and within one expert the down_proj needs more
//! precision than gate_proj.

use mxmoe::alloc::{calibrate, measure_sensitivity};
use mxmoe::harness::{load_corpus, load_model};
use mxmoe::quant::{QuantScheme, SchemeRegistry};

fn main() -> anyhow::Result<()> {
    let model = std::env::args().skip(1).find(|a| !a.starts_with('-')).unwrap_or_else(|| "dsv2-mini".into());
    let (cfg, lm) = load_model(&model)?;
    let corpus = load_corpus()?;
    let seqs = corpus.sequences("train", cfg.seq_len);
    let calib: Vec<&[u32]> = seqs.iter().take(8).copied().collect();
    let stats = calibrate(&lm, &calib, None)?;
    let registry = SchemeRegistry {
        schemes: vec![
            QuantScheme::W8A8,
            QuantScheme::W4A4,
            QuantScheme::W4A16,
            QuantScheme::W2A16,
        ],
    };
    let sens = measure_sensitivity(&lm, &stats, &registry)?;

    let mid = sens.delta.len() / 2; // middle MoE layer (paper uses layer 11)
    println!("# Fig. 1a (top): quantization loss per expert, {model} MoE layer idx {mid}");
    println!("| expert | w8a8 | w4a4 | w4a16 | w2a16 |");
    let experts = sens.delta[mid].len().min(16);
    for e in 0..experts {
        // sum over the 3 linear blocks, like the paper's per-expert bars
        let row: Vec<f64> = registry
            .schemes
            .iter()
            .map(|s| (0..3).map(|j| sens.delta(mid, e, j, s)).sum::<f64>())
            .collect();
        println!(
            "| {e:>6} | {:>8.3} | {:>8.3} | {:>8.3} | {:>8.3} |",
            row[0], row[1], row[2], row[3]
        );
    }

    println!("\n# Fig. 1a (bottom): per-linear-block loss under w4a4_g-1_sym");
    println!("| expert | gate_proj | up_proj | down_proj |");
    let mut down_dominant = 0usize;
    let mut counted = 0usize;
    for e in 0..experts {
        let g = sens.delta(mid, e, 0, &QuantScheme::W4A4);
        let u = sens.delta(mid, e, 1, &QuantScheme::W4A4);
        let d = sens.delta(mid, e, 2, &QuantScheme::W4A4);
        if g + u + d > 0.0 {
            counted += 1;
            if d > g && d > u {
                down_dominant += 1;
            }
        }
        println!("| {e:>6} | {g:>9.4} | {u:>9.4} | {d:>9.4} |");
    }

    // heterogeneity statistics (the figure's message)
    let all: Vec<f64> = (0..sens.delta[mid].len())
        .flat_map(|e| (0..3).map(move |j| (e, j)))
        .map(|(e, j)| sens.delta(mid, e, j, &QuantScheme::W4A4))
        .filter(|&d| d > 0.0)
        .collect();
    let max = all.iter().cloned().fold(0.0, f64::max);
    let min = all.iter().cloned().fold(f64::INFINITY, f64::min);
    println!("\nheterogeneity: max/min per-linear Δ = {:.1}×", max / min);
    println!("down_proj most sensitive in {down_dominant}/{counted} experts");
    println!("SHAPE CHECK: paper reports large cross-expert variance and down_proj dominance");
    Ok(())
}
