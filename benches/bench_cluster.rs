//! §Sharded-Serving — N-replica cluster vs single replica, same trace.
//!
//! Scenario: a serving-shape model with a mixed-precision plan serves a
//! fixed scoring trace twice — once on a 1-replica cluster, once on a
//! 4-replica cluster with expert-affinity routing and work stealing. The
//! responses must match bit for bit (sharding is a pure throughput
//! transform); the bench reports per-shape wall-clock, scoring throughput,
//! the router's batch distribution, and the speedup (target: ≥ 2× on 4
//! replicas). Results land in `BENCH_cluster.json`.
//!
//! `--smoke` shrinks the trace for CI and skips the speedup assertion
//! (shared runners have unpredictable core counts); bit-identity is
//! enforced in both modes.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use anyhow::Result;
use mxmoe::coordinator::{Cluster, ClusterConfig, ClusterReport, ServeConfig};
use mxmoe::harness::{mixed_runtime_plan, require_artifacts, save_model_mxt};
use mxmoe::moe::{ModelConfig, MoeLm};
use mxmoe::ser::Json;
use mxmoe::util::Rng;

const MODEL_SEED: u64 = 0xC1_05_7E6;

/// Serving-shape model (hidden=128, inter=64 — what the AOT export ships).
fn serving_cfg() -> ModelConfig {
    ModelConfig {
        name: "cluster-bench".into(),
        vocab: 64,
        hidden: 128,
        layers: 2,
        heads: 4,
        n_experts: 4,
        n_shared: 1,
        topk: 2,
        inter: 64,
        dense_first: false,
        seq_len: 24,
    }
}

/// The fixed scoring trace: varying lengths, same seed for every shape.
fn trace(cfg: &ModelConfig, n_requests: usize) -> Vec<Vec<u32>> {
    let mut rng = Rng::new(0x7EACE);
    (0..n_requests)
        .map(|i| {
            let len = [cfg.seq_len, 5, 16, 9, cfg.seq_len, 11][i % 6];
            (0..len).map(|_| rng.below(cfg.vocab as u64) as u32).collect()
        })
        .collect()
}

struct RunResult {
    elapsed_s: f64,
    tokens: usize,
    responses: Vec<(u32, u64)>,
    report: ClusterReport,
}

/// Serve `reqs` on an N-replica cluster: a warmup round (engine build,
/// executable compilation) then the timed trace.
fn run_cluster(
    cfg: &ModelConfig,
    weights: &PathBuf,
    artifacts: &PathBuf,
    replicas: usize,
    reqs: &[Vec<u32>],
) -> Result<RunResult> {
    let cluster = Cluster::start(
        cfg.clone(),
        weights.clone(),
        artifacts.clone(),
        mixed_runtime_plan(cfg),
        ClusterConfig {
            replicas,
            // one request per batch: identical batch composition for every
            // cluster shape, which is what makes bit-identity well-defined
            serve: ServeConfig {
                max_batch_seqs: 1,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
            ..Default::default()
        },
    )?;
    // warmup: enough requests to touch every replica at least once
    let warmup: Vec<_> = (0..replicas * 2)
        .map(|_| cluster.submit(reqs[0].clone()))
        .collect::<Result<_>>()?;
    for rx in warmup {
        rx.recv_timeout(Duration::from_secs(600)).expect("warmup response");
    }
    // timed trace
    let start = Instant::now();
    let receivers: Vec<_> =
        reqs.iter().map(|r| cluster.submit(r.clone())).collect::<Result<_>>()?;
    let responses: Vec<(u32, u64)> = receivers
        .iter()
        .map(|rx| {
            let r = rx.recv_timeout(Duration::from_secs(600)).expect("response");
            (r.next_token, r.mean_nll.to_bits())
        })
        .collect();
    let elapsed_s = start.elapsed().as_secs_f64();
    let tokens: usize = reqs.iter().map(|r| r.len()).sum();
    Ok(RunResult { elapsed_s, tokens, responses, report: cluster.shutdown() })
}

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!("# §Sharded-Serving — N-replica cluster vs single replica");

    let mut results = vec![
        ("schema", Json::str("mxmoe-bench-v1")),
        ("bench", Json::str("cluster")),
        ("smoke", Json::Bool(smoke)),
    ];
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping cluster bench: artifacts not built (run `make artifacts`)");
        std::fs::write(
            "BENCH_cluster.json",
            Json::obj(results.iter().map(|(k, v)| (*k, v.clone())).collect()).pretty(),
        )?;
        return Ok(());
    };

    let cfg = serving_cfg();
    let weights = std::env::temp_dir().join("mxmoe_bench_cluster.mxt");
    let lm = MoeLm::random(&cfg, &mut Rng::new(MODEL_SEED));
    save_model_mxt(&lm, &weights)?;
    let reqs = trace(&cfg, if smoke { 24 } else { 96 });

    let single = run_cluster(&cfg, &weights, &artifacts, 1, &reqs)?;
    let sharded = run_cluster(&cfg, &weights, &artifacts, 4, &reqs)?;
    let _ = std::fs::remove_file(&weights);

    // speedup only counts if sharding changed nothing but the wall clock
    assert_eq!(
        single.responses, sharded.responses,
        "4-replica responses diverged from single-replica — sharding must be \
         a pure throughput transform"
    );

    let t1 = single.tokens as f64 / single.elapsed_s;
    let t4 = sharded.tokens as f64 / sharded.elapsed_s;
    let speedup = single.elapsed_s / sharded.elapsed_s;
    println!(
        "| 1 replica  | {:>4} req | {:>6} tok | {:>8.3} s | {:>9.1} tok/s |",
        reqs.len(),
        single.tokens,
        single.elapsed_s,
        t1
    );
    println!(
        "| 4 replicas | {:>4} req | {:>6} tok | {:>8.3} s | {:>9.1} tok/s | routed {:?} | {} stolen |",
        reqs.len(),
        sharded.tokens,
        sharded.elapsed_s,
        t4,
        sharded.report.router.routed,
        sharded.report.total_steals(),
    );
    println!("speedup: {speedup:.2}×");

    // the router must have spread the trace: no replica owns everything
    let executed: Vec<usize> =
        sharded.report.replicas.iter().map(|r| r.executed_batches).collect();
    assert!(
        executed.iter().filter(|&&e| e > 0).count() >= 2,
        "4-replica run executed everything on one replica: {executed:?}"
    );
    if !smoke {
        assert!(
            speedup >= 2.0,
            "4-replica speedup {speedup:.2}× below the 2× acceptance bar"
        );
    }

    results.extend([
        ("requests", Json::num(reqs.len() as f64)),
        ("tokens", Json::num(single.tokens as f64)),
        ("single_replica_s", Json::num(single.elapsed_s)),
        ("four_replica_s", Json::num(sharded.elapsed_s)),
        ("single_tok_per_s", Json::num(t1)),
        ("four_tok_per_s", Json::num(t4)),
        ("speedup", Json::num(speedup)),
        ("stolen_batches", Json::num(sharded.report.total_steals() as f64)),
        (
            "max_executed_share",
            Json::num(
                *executed.iter().max().unwrap_or(&0) as f64
                    / sharded.report.router.batches.max(1) as f64,
            ),
        ),
        ("bit_identical", Json::Bool(true)),
    ]);
    std::fs::write(
        "BENCH_cluster.json",
        Json::obj(results.iter().map(|(k, v)| (*k, v.clone())).collect()).pretty(),
    )?;
    println!("\nwrote BENCH_cluster.json");
    Ok(())
}
