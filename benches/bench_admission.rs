//! §Serving-API — bounded admission vs the unbounded legacy queue under
//! sustained overload.
//!
//! Scenario: a 1-replica serving cluster is offered a paced request
//! stream at ~2× its measured capacity (capacity is calibrated on the
//! same model/plan immediately before the timed runs). 25% of the stream
//! is `High` priority. Two policies serve the identical stream:
//!
//! * **unbounded** — the pre-redesign behavior: every request is
//!   admitted (bounds set astronomically high), the queue grows without
//!   limit, and tail latency grows with it.
//! * **bounded** — the QoS front door: a small queue-depth bound sheds
//!   load at admission (`try_submit` → `Rejected{QueueFull, retry_after}`),
//!   so admitted requests ride a short queue.
//!
//! Reported: p99 end-to-end latency of *admitted High-priority* requests
//! under both policies, the rejection counts (reconciled against
//! `ClusterReport`), and the improvement ratio. Full mode asserts the
//! acceptance bar: bounded-admission High-priority p99 at least 3× better
//! than the unbounded queue. `--smoke` shrinks the stream for CI and
//! skips the wall-clock assertion (shared runners), keeping the
//! accounting assertions. Results land in `BENCH_admission.json`.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use anyhow::Result;
use mxmoe::coordinator::{Cluster, ClusterConfig, ServeConfig};
use mxmoe::harness::{mixed_runtime_plan, require_artifacts, save_model_mxt};
use mxmoe::moe::{ModelConfig, MoeLm};
use mxmoe::ser::Json;
use mxmoe::serve::{Admission, AdmissionConfig, Priority, ServeRequest, Ticket};
use mxmoe::util::{Rng, Summary};

const MODEL_SEED: u64 = 0x0AD1_5510;
const SEQ_LEN: usize = 16;

/// Serving-shape model (hidden=128, inter=64 — what the AOT export ships).
fn serving_cfg() -> ModelConfig {
    ModelConfig {
        name: "admission-bench".into(),
        vocab: 64,
        hidden: 128,
        layers: 2,
        heads: 4,
        n_experts: 4,
        n_shared: 1,
        topk: 2,
        inter: 64,
        dense_first: false,
        seq_len: SEQ_LEN,
    }
}

/// The fixed offered stream: every 4th request is High priority.
fn stream(cfg: &ModelConfig, n: usize) -> Vec<(Vec<u32>, Priority)> {
    let mut rng = Rng::new(0x0FFE12);
    (0..n)
        .map(|i| {
            let tokens: Vec<u32> =
                (0..SEQ_LEN).map(|_| rng.below(cfg.vocab as u64) as u32).collect();
            let p = if i % 4 == 0 { Priority::High } else { Priority::Normal };
            (tokens, p)
        })
        .collect()
}

fn start(
    cfg: &ModelConfig,
    weights: &PathBuf,
    artifacts: &PathBuf,
    admission: AdmissionConfig,
) -> Result<Cluster> {
    Cluster::start(
        cfg.clone(),
        weights.clone(),
        artifacts.clone(),
        mixed_runtime_plan(cfg),
        ClusterConfig {
            serve: ServeConfig {
                max_batch_seqs: 1,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
            admission,
            ..Default::default()
        },
    )
}

/// Measured serving capacity, tokens/second: a short closed-loop run
/// (submit → wait → submit) on a fresh cluster, so the timed overload
/// runs know what "2×" means on this machine.
fn calibrate(cfg: &ModelConfig, weights: &PathBuf, artifacts: &PathBuf, n: usize) -> Result<f64> {
    let cluster = start(cfg, weights, artifacts, AdmissionConfig::default())?;
    let reqs = stream(cfg, n);
    // warmup: first request pays executable-load costs
    cluster
        .submit_request(ServeRequest::new(reqs[0].0.clone()))?
        .wait_timeout(Duration::from_secs(600))
        .expect("warmup");
    let t0 = Instant::now();
    let mut tokens = 0usize;
    for (seq, _) in &reqs {
        tokens += seq.len();
        cluster
            .submit_request(ServeRequest::new(seq.clone()))?
            .wait_timeout(Duration::from_secs(600))
            .expect("calibration response");
    }
    let tps = tokens as f64 / t0.elapsed().as_secs_f64();
    cluster.shutdown();
    Ok(tps)
}

struct OverloadResult {
    p99_high_s: f64,
    p99_all_s: f64,
    admitted: usize,
    rejected: usize,
    served: usize,
}

/// Offer the stream at `offered_tps` (≈2× capacity) against the given
/// admission policy; collect per-priority latencies of admitted requests.
fn run_overload(
    cfg: &ModelConfig,
    weights: &PathBuf,
    artifacts: &PathBuf,
    admission: AdmissionConfig,
    reqs: &[(Vec<u32>, Priority)],
    offered_tps: f64,
) -> Result<OverloadResult> {
    let cluster = start(cfg, weights, artifacts, admission)?;
    // warmup outside the timed window
    cluster
        .submit_request(ServeRequest::new(reqs[0].0.clone()))?
        .wait_timeout(Duration::from_secs(600))
        .expect("warmup");
    let interval = Duration::from_secs_f64(SEQ_LEN as f64 / offered_tps);
    let start_at = Instant::now();
    let mut tickets: Vec<(Ticket, Priority)> = Vec::new();
    let mut rejected = 0usize;
    for (i, (seq, priority)) in reqs.iter().enumerate() {
        // paced open-loop arrivals: sleep to the schedule, never to the queue
        let due = start_at + interval * i as u32;
        let now = Instant::now();
        if due > now {
            std::thread::sleep(due - now);
        }
        match cluster.try_submit(ServeRequest::new(seq.clone()).priority(*priority))? {
            Admission::Admitted(t) => tickets.push((t, *priority)),
            Admission::Rejected { .. } => rejected += 1,
        }
    }
    let mut high = Vec::new();
    let mut all = Vec::new();
    for (t, p) in &tickets {
        let r = t.wait_timeout(Duration::from_secs(600)).expect("admitted ⇒ served");
        let lat = r.latency.as_secs_f64();
        all.push(lat);
        if *p == Priority::High {
            high.push(lat);
        }
    }
    let report = cluster.shutdown();
    // the front door's accounting must reconcile with what we observed
    assert_eq!(report.admission.admitted, tickets.len() + 1, "admitted (incl. warmup)");
    assert_eq!(report.admission.rejected(), rejected, "rejections accounted in ClusterReport");
    assert_eq!(report.total_requests(), tickets.len() + 1, "every admitted request served");
    // same percentile definition as ClusterReport/Metrics, so the JSON is
    // directly comparable to the serving reports
    let p99 = |v: &[f64]| if v.is_empty() { 0.0 } else { Summary::of(v).p99 };
    Ok(OverloadResult {
        p99_high_s: p99(&high),
        p99_all_s: p99(&all),
        admitted: tickets.len(),
        rejected,
        served: report.total_requests() - 1,
    })
}

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!("# §Serving-API — bounded admission vs unbounded queue at 2× capacity");

    let mut results = vec![
        ("schema", Json::str("mxmoe-bench-v1")),
        ("bench", Json::str("admission")),
        ("smoke", Json::Bool(smoke)),
    ];
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping admission bench: artifacts not built (run `make artifacts`)");
        std::fs::write(
            "BENCH_admission.json",
            Json::obj(results.iter().map(|(k, v)| (*k, v.clone())).collect()).pretty(),
        )?;
        return Ok(());
    };

    let cfg = serving_cfg();
    let weights = std::env::temp_dir().join("mxmoe_bench_admission.mxt");
    let lm = MoeLm::random(&cfg, &mut Rng::new(MODEL_SEED));
    save_model_mxt(&lm, &weights)?;

    let (calib_n, n) = if smoke { (6, 24) } else { (16, 96) };
    let capacity_tps = calibrate(&cfg, &weights, &artifacts, calib_n)?;
    let offered_tps = 2.0 * capacity_tps;
    println!("capacity ≈ {capacity_tps:.0} tok/s; offering {offered_tps:.0} tok/s");

    let reqs = stream(&cfg, n);
    // pre-redesign behavior: bounds no stream of this size can reach
    let unbounded = run_overload(
        &cfg,
        &weights,
        &artifacts,
        AdmissionConfig {
            max_queued_seqs: usize::MAX / 2,
            max_queued_tokens: usize::MAX / 2,
            ..Default::default()
        },
        &reqs,
        offered_tps,
    )?;
    // QoS front door: queue bounded at 3 sequences
    let bounded = run_overload(
        &cfg,
        &weights,
        &artifacts,
        AdmissionConfig { max_queued_seqs: 3, ..Default::default() },
        &reqs,
        offered_tps,
    )?;
    let _ = std::fs::remove_file(&weights);

    println!(
        "| unbounded | {:>3} admitted | {:>3} rejected | p99(high) {:>8.1} ms | p99(all) {:>8.1} ms |",
        unbounded.admitted,
        unbounded.rejected,
        unbounded.p99_high_s * 1e3,
        unbounded.p99_all_s * 1e3,
    );
    println!(
        "| bounded   | {:>3} admitted | {:>3} rejected | p99(high) {:>8.1} ms | p99(all) {:>8.1} ms |",
        bounded.admitted,
        bounded.rejected,
        bounded.p99_high_s * 1e3,
        bounded.p99_all_s * 1e3,
    );
    let ratio = if bounded.p99_high_s > 0.0 {
        unbounded.p99_high_s / bounded.p99_high_s
    } else {
        f64::INFINITY
    };
    println!("high-priority p99 improvement: {ratio:.2}×");

    assert_eq!(unbounded.rejected, 0, "the unbounded baseline must admit everything");
    assert_eq!(unbounded.served, unbounded.admitted);
    assert!(
        bounded.rejected > 0,
        "2× overload against a 3-deep bound must load-shed"
    );
    if !smoke {
        assert!(
            ratio >= 3.0,
            "bounded-admission High-priority p99 must be ≥3× better under \
             2× overload (got {ratio:.2}×)"
        );
    }

    results.extend([
        ("requests", Json::num(n as f64)),
        ("capacity_tok_per_s", Json::num(capacity_tps)),
        ("offered_tok_per_s", Json::num(offered_tps)),
        ("unbounded_p99_high_s", Json::num(unbounded.p99_high_s)),
        ("unbounded_p99_all_s", Json::num(unbounded.p99_all_s)),
        ("unbounded_admitted", Json::num(unbounded.admitted as f64)),
        ("bounded_p99_high_s", Json::num(bounded.p99_high_s)),
        ("bounded_p99_all_s", Json::num(bounded.p99_all_s)),
        ("bounded_admitted", Json::num(bounded.admitted as f64)),
        ("bounded_rejected", Json::num(bounded.rejected as f64)),
        ("p99_high_improvement", Json::num(ratio)),
    ]);
    std::fs::write(
        "BENCH_admission.json",
        Json::obj(results.iter().map(|(k, v)| (*k, v.clone())).collect()).pretty(),
    )?;
    println!("\nwrote BENCH_admission.json");
    Ok(())
}
