//! Fig. 2 — MoE-block computation throughput of low-precision execution
//! strategies on the Qwen1.5-MoE shape: 60 experts × [N,K]=[2816,2048],
//! 512 tokens, top-4.
//!
//! Paper shape: HQQ (unfused dequant) < torch-fp16 ≤ vLLM-Marlin-MoE
//! (sequential W4) < MxMoE fused W4; W8A8 fused in between.

use mxmoe::costmodel::micro::Specialization;
use mxmoe::costmodel::GpuSpec;
use mxmoe::kernelgen::moe_problems;
use mxmoe::quant::QuantScheme;
use mxmoe::sim::{run_fused, run_sequential, run_unfused_dequant};

fn main() {
    let gpu = GpuSpec::rtx4090();
    let sp = Specialization::Specialized;
    // 512 tokens × top-4 over 60 experts ≈ 34 tokens/expert (uniform load,
    // like the paper's synthetic Fig. 2 setup)
    let tokens = vec![512 * 4 / 60; 60];
    let mk = |s: QuantScheme| moe_problems(&tokens, &vec![[s; 3]; 60], 2048, 2816);

    println!("# Fig. 2: 60 experts [2816,2048], 512 tokens top-4, {}", gpu.name);
    println!("| strategy                    | time (us) | TFLOPS | vs fp16 |");
    let fp16 = run_fused(&gpu, &mk(QuantScheme::FP16), sp);
    let rows = [
        ("torch-fp16 (CUTLASS group)", fp16.clone()),
        ("HQQ-like W4 (unfused dequant)", run_unfused_dequant(&gpu, &mk(QuantScheme::W4A16), sp)),
        ("vLLM-Marlin-MoE W4 (sequential)", run_sequential(&gpu, &mk(QuantScheme::W4A16), sp)),
        ("MxMoE W4 (fused group-GEMM)", run_fused(&gpu, &mk(QuantScheme::W4A16), sp)),
        ("MxMoE W8A8 (fused group-GEMM)", run_fused(&gpu, &mk(QuantScheme::W8A8), sp)),
    ];
    for (name, r) in &rows {
        println!(
            "| {name:<29} | {:>9.1} | {:>6.1} | {:>6.2}x |",
            r.time * 1e6,
            r.tflops(),
            r.tflops() / fp16.tflops()
        );
    }
    let hqq = rows[1].1.tflops();
    let seq = rows[2].1.tflops();
    let mx4 = rows[3].1.tflops();
    assert!(hqq < fp16.tflops(), "HQQ must underperform fp16");
    assert!(mx4 > seq && seq > 0.8 * fp16.tflops(), "ordering broken");
    println!("\nSHAPE CHECK OK: HQQ < fp16 ≤ sequential-W4 < fused-W4 (paper Fig. 2)");
}
