//! §Perf — L3 micro-benchmarks: the coordinator-side hot paths that must
//! never dominate PJRT execute time, plus the allocator/simulator/scheduler
//! speed targets of DESIGN.md §8.

use mxmoe::costmodel::micro::Specialization;
use mxmoe::costmodel::GpuSpec;
use mxmoe::kernelgen::{fused_plan, moe_problems};
use mxmoe::moe::route;
use mxmoe::quant::QuantScheme;
use mxmoe::sched::{fifo_makespan, lpt_makespan};
use mxmoe::tensor::matrix::matmul_nt;
use mxmoe::tensor::Matrix;
use mxmoe::util::timer::bench;
use mxmoe::util::Rng;

fn main() {
    let mut rng = Rng::new(0xBE);
    println!("# §Perf — L3 coordinator micro-benches");
    println!("| path | config | mean | p99 |");

    // routing (native hot path, per batch of 512 tokens, 60 experts)
    let x = Matrix::randn(512, 128, 1.0, &mut rng);
    let wr = Matrix::randn(60, 128, 0.2, &mut rng);
    let s = bench(3, 20, || {
        let r = route(&x, &wr, 4);
        std::hint::black_box(r.per_token.len());
    });
    println!("| route 512 tok → 60 experts | top-4 | {:>9.1}us | {:>9.1}us |", s.mean * 1e6, s.p99 * 1e6);

    // expert gather/scatter (dispatch bookkeeping)
    let routing = route(&x, &wr, 4);
    let s = bench(3, 20, || {
        let mut out = Matrix::zeros(512, 128);
        for (_e, (tokens, weights)) in routing.per_expert.iter().enumerate() {
            if tokens.is_empty() {
                continue;
            }
            let xe = x.gather_rows(tokens);
            out.scatter_add_rows(tokens, &xe, weights);
        }
        std::hint::black_box(out.data[0]);
    });
    println!("| gather+scatter 60 experts | 512 tok | {:>9.1}us | {:>9.1}us |", s.mean * 1e6, s.p99 * 1e6);

    // fused-plan generation (the kernel-generator analogue)
    let gpu = GpuSpec::rtx4090();
    let tokens = vec![34usize; 60];
    let probs = moe_problems(&tokens, &vec![[QuantScheme::W4A16; 3]; 60], 2048, 2816);
    let s = bench(2, 10, || {
        let p = fused_plan(&gpu, &probs, Specialization::Specialized);
        std::hint::black_box(p.tiles.len());
    });
    println!("| fused_plan 180 GEMMs | 60 experts | {:>9.1}us | {:>9.1}us |", s.mean * 1e6, s.p99 * 1e6);

    // LPT scheduler at simulator scale
    let costs: Vec<f64> = (0..100_000).map(|_| rng.range_f64(1e-7, 1e-5)).collect();
    let s = bench(1, 5, || {
        std::hint::black_box(lpt_makespan(&costs, 128));
    });
    println!("| LPT 100k tiles → 128 SMs | — | {:>9.1}ms | {:>9.1}ms |", s.mean * 1e3, s.p99 * 1e3);
    let s = bench(1, 5, || {
        std::hint::black_box(fifo_makespan(&costs, 128));
    });
    println!("| FIFO 100k tiles → 128 SMs | — | {:>9.1}ms | {:>9.1}ms |", s.mean * 1e3, s.p99 * 1e3);

    // native matmul substrate (calibration/GPTQ hot path)
    for (m, k, n) in [(512usize, 128usize, 64usize), (1024, 2048, 2048)] {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let bt = Matrix::randn(n, k, 1.0, &mut rng);
        let s = bench(2, 8, || {
            std::hint::black_box(matmul_nt(&a, &bt).data[0]);
        });
        let gflops = 2.0 * (m * n * k) as f64 / s.mean / 1e9;
        println!("| matmul_nt [{m},{k}]x[{n},{k}]ᵀ | {gflops:.1} GFLOP/s | {:>9.2}ms | {:>9.2}ms |", s.mean * 1e3, s.p99 * 1e3);
    }
}
