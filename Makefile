# MxMoE build entry points. `make artifacts` is the one CI depends on: it
# exports the AOT HLO executables that gate the PJRT integration tests and
# the serving benches (python/compile/aot.py → artifacts/*.hlo.txt).
# `corpus` and `models` are the heavier, dev-machine targets behind the
# end-to-end example and the accuracy benches.

PYTHON ?= python3
CARGO  ?= cargo

.PHONY: all artifacts corpus models mini-model build test bench-smoke scenario-smoke bench-validate trace-validate pytest clean

all: build

# AOT HLO export: every (runtime scheme, tile) expert-FFN executable plus
# the group-GEMM block executable and the smoke matmul. Pure function of
# python/compile/** — CI caches artifacts/ on hashFiles of that tree.
artifacts:
	cd python && $(PYTHON) -m compile.aot --out ../artifacts

# Synthetic Zipf–Markov corpus (rust is the source of truth).
corpus:
	$(CARGO) run --release --bin mxmoe -- gen-corpus --out artifacts/corpus.mxt

# Train the mini MoE LMs + parity tensors (slow; needs `make corpus`).
models:
	cd python && $(PYTHON) -m compile.train_lm --out ../artifacts

# Deterministic tiny `ci-mini` checkpoint (seeded random init, no
# training) in the exact layout `make models` writes — what lets CI
# exercise model-gated paths. Pure function of the rust model registry,
# RNG and MXT serializer; CI caches artifacts/model_ci-mini.mxt on a hash
# of those sources.
mini-model:
	$(CARGO) run --release --bin mxmoe -- gen-mini-model --out artifacts/model_ci-mini.mxt

build:
	$(CARGO) build --release

# Tier-1 gate. With artifacts present, the artifact-gated integration
# tests run for real; MXMOE_REQUIRE_ARTIFACTS=1 turns any self-skip into a
# failure (what CI uses so the gate can't go green by skipping).
test: build
	$(CARGO) test -q

# The serving benches CI runs on every push (BENCH_*.json outputs; the
# trace-overhead bench also exports trace.json, validated below).
bench-smoke:
	$(CARGO) bench --bench bench_group_dispatch -- --smoke
	$(CARGO) bench --bench bench_cluster -- --smoke
	$(CARGO) bench --bench bench_admission -- --smoke
	$(CARGO) bench --bench bench_decode -- --smoke
	$(CARGO) bench --bench bench_kvcache -- --smoke
	$(CARGO) bench --bench bench_trace_overhead -- --smoke
	$(CARGO) bench --bench bench_http -- --smoke

# The scenario suite (scenarios/*.json) replayed end to end in smoke
# mode: accounting and determinism checks enforced, wall-clock SLO bars
# reported but not gated. One BENCH_scenario_<name>.json per spec plus
# the suite roll-up; exits non-zero on any fail verdict.
scenario-smoke:
	$(CARGO) bench --bench bench_scenarios -- --smoke

# Shared schema check over every BENCH_*.json in the workspace (envelope
# for all benches, full ledger/SLO/verdict block for scenario files);
# exits non-zero on a malformed file or a fail verdict.
bench-validate:
	$(CARGO) run --release --bin mxmoe -- bench-validate --dir .

# CI-grade structural check of the Chrome trace the smoke benches export
# (well-formed JSON, monotonic timestamps, matched async begin/end pairs).
trace-validate:
	$(CARGO) run --release --bin mxmoe -- trace-validate --trace trace.json

# Python unit tests (mirrors the CI python job).
pytest:
	cd python && $(PYTHON) -m pytest tests -q

clean:
	rm -rf target BENCH_*.json
