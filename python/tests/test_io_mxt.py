"""MXT format: python↔python roundtrip + byte-layout pin shared with rust
(`rust/src/ser/mxt.rs` tests pin the same layout from the other side)."""

import struct

import numpy as np
import pytest

from compile.io_mxt import load_mxt, save_mxt


def test_roundtrip(tmp_path):
    path = str(tmp_path / "t.mxt")
    tensors = {
        "w": np.random.default_rng(0).normal(size=(3, 4)).astype(np.float32),
        "ids": np.arange(-2, 6, dtype=np.int32),
        "codes": np.arange(16, dtype=np.uint8).reshape(4, 4),
        "q": (np.arange(8, dtype=np.int64) - 4).astype(np.int8),
    }
    save_mxt(path, tensors)
    out = load_mxt(path)
    assert set(out) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(out[k], tensors[k])
        assert out[k].dtype == tensors[k].dtype


def test_exact_byte_layout(tmp_path):
    # one f32 tensor "a" of shape [2]: the byte stream is fully pinned
    path = str(tmp_path / "pin.mxt")
    save_mxt(path, {"a": np.array([1.0, -2.0], dtype=np.float32)})
    with open(path, "rb") as f:
        blob = f.read()
    expected = (
        b"MXT1"
        + struct.pack("<I", 1)
        + struct.pack("<I", 1)
        + b"a"
        + struct.pack("<B", 0)
        + struct.pack("<I", 1)
        + struct.pack("<Q", 2)
        + struct.pack("<Q", 8)
        + struct.pack("<ff", 1.0, -2.0)
    )
    assert blob == expected


def test_rejects_bad_magic(tmp_path):
    path = tmp_path / "bad.mxt"
    path.write_bytes(b"NOPE" + b"\x00" * 16)
    with pytest.raises(ValueError):
        load_mxt(str(path))


def test_rejects_unsupported_dtype(tmp_path):
    with pytest.raises(TypeError):
        save_mxt(str(tmp_path / "x.mxt"), {"f64": np.zeros(2, dtype=np.float64)})
