"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/bit-widths; assert_allclose against ref.py — the
core correctness signal of the build-time stack.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.act_quant import act_quant
from compile.kernels.dequant_gemm import dequant_gemm
from compile.kernels.group_gemm import group_gemm, group_gemm_w4a16
from compile.kernels.hadamard import hadamard_rotate
from compile.kernels.wa_gemm import wa_gemm, wa_gemm_grouped, wa_group_gemm_ref_scales

SETTINGS = dict(max_examples=12, deadline=None)


def rand(key, *shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------- packing ----------------

@given(
    bits=st.sampled_from([2, 4, 8]),
    n=st.integers(1, 6),
    kb=st.integers(1, 8),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_pack_unpack_roundtrip(bits, n, kb, seed):
    k = kb * 8  # divisible by any per_byte
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 2**bits, size=(n, k)), dtype=jnp.uint8)
    packed = ref.pack_codes(codes, bits)
    assert packed.shape == (n, k * bits // 8)
    un = ref.unpack_codes(packed, bits, k)
    np.testing.assert_array_equal(np.asarray(un), np.asarray(codes))


def test_pack_layout_matches_rust():
    # element 0 in the low nibble: [0xA, 0xB] -> 0xBA (rust quant::pack test)
    p = ref.pack_codes(jnp.array([[0xA, 0xB]], dtype=jnp.uint8), 4)
    assert int(p[0, 0]) == 0xBA


# ---------------- dequant GEMM (W{2,4,8}A16) ----------------

@given(
    bits=st.sampled_from([2, 4, 8]),
    m=st.sampled_from([1, 4, 16]),
    n=st.sampled_from([8, 64]),
    k=st.sampled_from([64, 128]),
    group=st.sampled_from([-1, 32]),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_dequant_gemm_matches_ref(bits, m, n, k, group, seed):
    w = rand(seed, n, k)
    x = rand(seed + 1, m, k)
    codes, scales, zeros = ref.quantize_asym_grouped(w, bits, group)
    packed = ref.pack_codes(codes, bits)
    y = dequant_gemm(x, packed, scales, zeros, bits=bits, group=group)
    y_ref = ref.dequant_gemm_ref(x, codes, scales, zeros)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-4)


def test_dequant_gemm_tiled_grid():
    # multi-tile grid must agree with single-tile
    w = rand(7, 64, 128)
    x = rand(8, 32, 128)
    codes, scales, zeros = ref.quantize_asym_grouped(w, 4, -1)
    packed = ref.pack_codes(codes, 4)
    y1 = dequant_gemm(x, packed, scales, zeros, bits=4)
    y2 = dequant_gemm(x, packed, scales, zeros, bits=4, block_m=8, block_n=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)


def test_dequant_gemm_quantization_error_reasonable():
    # end-to-end: 4-bit output close to fp32 GEMM in relative terms
    w = rand(9, 64, 128, scale=0.1)
    x = rand(10, 16, 128)
    codes, scales, zeros = ref.quantize_asym_grouped(w, 4, 32)
    packed = ref.pack_codes(codes, 4)
    y = np.asarray(dequant_gemm(x, packed, scales, zeros, bits=4, group=32))
    y_fp = np.asarray(x @ w.T)
    rel = np.linalg.norm(y - y_fp) / np.linalg.norm(y_fp)
    assert rel < 0.15, rel  # 4-bit RTN noise floor on N(0,0.1) weights


# ---------------- weight-activation GEMM ----------------

@given(
    bits=st.sampled_from([4, 8]),
    m=st.sampled_from([1, 8, 32]),
    n=st.sampled_from([16, 64]),
    k=st.sampled_from([64, 128]),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_wa_gemm_matches_ref(bits, m, n, k, seed):
    x = rand(seed, m, k)
    w = rand(seed + 1, n, k, scale=0.1)
    wq, ws = ref.quantize_sym(w, bits, axis=-1)
    y = wa_gemm(x, wq, ws, bits=bits)
    y_ref = ref.wa_gemm_ref(x, wq, ws, bits)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-4)


@given(
    m=st.sampled_from([2, 8]),
    n=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_wa_gemm_grouped_matches_ref(m, n, seed):
    k, group = 256, 128
    x = rand(seed, m, k)
    w = rand(seed + 1, n, k, scale=0.1)
    # group-quantized weights
    wg = w.reshape(n, k // group, group)
    qmax = 7
    ws = jnp.maximum(jnp.max(jnp.abs(wg), axis=-1), 1e-9) / qmax
    wq = jnp.clip(jnp.round(wg / ws[:, :, None]), -8, 7).astype(jnp.int8).reshape(n, k)
    y = wa_gemm_grouped(x, wq, ws, bits=4, group=group)
    y_ref = wa_group_gemm_ref_scales(x, wq, ws, 4, group)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-3, atol=1e-3)


def test_wa_gemm_w8a8_accuracy_vs_fp32():
    x = rand(11, 32, 128)
    w = rand(12, 64, 128, scale=0.1)
    wq, ws = ref.quantize_sym(w, 8, axis=-1)
    y = np.asarray(wa_gemm(x, wq, ws, bits=8))
    y_fp = np.asarray(x @ w.T)
    rel = np.linalg.norm(y - y_fp) / np.linalg.norm(y_fp)
    assert rel < 0.02, rel


# ---------------- act quant ----------------

@given(
    bits=st.sampled_from([4, 8]),
    m=st.sampled_from([1, 8, 64]),
    k=st.sampled_from([32, 128]),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_act_quant_matches_ref(bits, m, k, seed):
    x = rand(seed, m, k, scale=3.0)
    q, s = act_quant(x, bits=bits)
    q_ref, s_ref = ref.quantize_sym(x, bits, axis=-1)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-6)
    # reconstruction bounded by half a step per element
    recon = np.asarray(q, dtype=np.float32) * np.asarray(s)
    assert np.max(np.abs(recon - np.asarray(x))) <= np.max(np.asarray(s)) * 0.5 + 1e-6


# ---------------- hadamard ----------------

@given(
    m=st.sampled_from([1, 4, 16]),
    k=st.sampled_from([32, 64, 128]),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_hadamard_matches_ref(m, k, seed):
    x = rand(seed, m, k)
    rng = np.random.default_rng(seed)
    signs = jnp.asarray(rng.choice([-1.0, 1.0], size=k).astype(np.float32))
    y = hadamard_rotate(x, signs)
    y_ref = ref.hadamard_ref(x, signs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-4)


def test_hadamard_preserves_gemm():
    # (x·Q)·(W·Q)ᵀ == x·Wᵀ
    x = rand(13, 8, 64)
    w = rand(14, 16, 64)
    rng = np.random.default_rng(5)
    signs = jnp.asarray(rng.choice([-1.0, 1.0], size=64).astype(np.float32))
    xr = hadamard_rotate(x, signs)
    wr = hadamard_rotate(w, signs)
    np.testing.assert_allclose(
        np.asarray(xr @ wr.T), np.asarray(x @ w.T), rtol=1e-3, atol=1e-3
    )


# ---------------- group GEMM ----------------

@given(
    t=st.sampled_from([1, 4, 8]),
    e=st.sampled_from([2, 5]),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_group_gemm_matches_ref(t, e, seed):
    tile_m, k, n = 8, 64, 32
    x = rand(seed, t, tile_m, k)
    w = rand(seed + 1, e, n, k)
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, e, size=t), dtype=jnp.int32)
    y = group_gemm(x, ids, w)
    y_ref = ref.group_gemm_ref(x, ids, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-4)


def test_group_gemm_w4a16_matches_dequant():
    t, tile_m, k, n, e = 6, 8, 128, 32, 3
    x = rand(15, t, tile_m, k)
    rng = np.random.default_rng(6)
    ids = jnp.asarray(rng.integers(0, e, size=t), dtype=jnp.int32)
    packed = []
    scales = []
    zeros = []
    ws = []
    for ei in range(e):
        w = rand(20 + ei, n, k, scale=0.1)
        codes, s, z = ref.quantize_asym_grouped(w, 4, -1)
        packed.append(ref.pack_codes(codes, 4))
        scales.append(s)
        zeros.append(z)
        ws.append(ref.dequant_grouped(codes, s, z))
    packed, scales, zeros = jnp.stack(packed), jnp.stack(scales), jnp.stack(zeros)
    wdq = jnp.stack(ws)
    y = group_gemm_w4a16(x, ids, packed, scales, zeros, bits=4)
    y_ref = jnp.einsum("tmk,tnk->tmn", x, wdq[ids])
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-4)
