"""L2 trainer-model sanity: shapes, causality, routing semantics, and the
dense-vs-topk equivalence that ties the JAX trainer to the rust runtime."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.moe_lm import CONFIGS, Config, forward, init_params, loss_fn, moe_ffn, rmsnorm, rope


def tiny_cfg():
    return Config("tiny", vocab=32, hidden=16, layers=2, heads=2,
                  n_experts=4, n_shared=1, topk=2, inter=8, seq_len=12)


def test_forward_shapes_finite():
    cfg = tiny_cfg()
    p = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.array([1, 5, 9, 2, 0, 31])
    logits = forward(p, tokens, cfg)
    assert logits.shape == (6, 32)
    assert bool(jnp.isfinite(logits).all())


def test_causality():
    cfg = tiny_cfg()
    p = init_params(cfg, jax.random.PRNGKey(1))
    t1 = jnp.array([3, 1, 4, 1, 5, 9])
    t2 = t1.at[-1].set((t1[-1] + 1) % 32)
    l1 = forward(p, t1, cfg)
    l2 = forward(p, t2, cfg)
    np.testing.assert_allclose(np.asarray(l1[:-1]), np.asarray(l2[:-1]), atol=1e-5)


def test_rope_matches_rust_convention():
    # position 0 unchanged; norms preserved (same checks as rust lm tests)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 16))
    y = rope(x, heads=2, head_dim=8)
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(x[0]), atol=1e-6)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-5,
    )


def test_moe_weights_are_topk_sparse():
    # non-selected experts must contribute nothing: perturbing an unselected
    # expert's weights leaves the output unchanged
    cfg = tiny_cfg()
    p = init_params(cfg, jax.random.PRNGKey(3))
    x = jax.random.normal(jax.random.PRNGKey(4), (5, cfg.hidden))
    probs = jax.nn.softmax(x @ p["layers.0.router"].T, axis=-1)
    _, topi = jax.lax.top_k(probs, cfg.topk)
    unselected = next(
        e for e in range(cfg.n_experts) if not bool(jnp.any(topi == e))
    ) if int(jnp.unique(topi).size) < cfg.n_experts else None
    if unselected is None:
        return  # every expert selected by some token: nothing to assert
    y1 = moe_ffn(p, "layers.0.", x, cfg)
    p2 = dict(p)
    p2[f"layers.0.expert.{unselected}.gate"] = p[f"layers.0.expert.{unselected}.gate"] + 10.0
    y2 = moe_ffn(p2, "layers.0.", x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_loss_decreases_one_step():
    cfg = tiny_cfg()
    p = init_params(cfg, jax.random.PRNGKey(5))
    batch = jax.random.randint(jax.random.PRNGKey(6), (2, cfg.seq_len), 0, cfg.vocab)
    loss, grads = jax.value_and_grad(lambda q: loss_fn(q, batch, cfg))(p)
    p2 = {k: p[k] - 0.05 * grads[k] for k in p}
    loss2 = loss_fn(p2, batch, cfg)
    assert float(loss2) < float(loss)


def test_rmsnorm_unit():
    x = jnp.full((1, 4), 2.0)
    y = rmsnorm(x, jnp.ones(4))
    np.testing.assert_allclose(np.asarray(y), np.ones((1, 4)), rtol=1e-4)


def test_registry_topologies():
    assert CONFIGS["qwen15-mini"].n_experts == 60
    assert CONFIGS["dsv2-mini"].dense_first
    assert CONFIGS["mixtral-mini"].topk == 2
