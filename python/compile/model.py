"""L2: the JAX compute graph exported to the rust runtime.

Each exported function is one *expert FFN tile* under one quantization
scheme: the unit the L3 coordinator schedules (a padded token tile through
one expert's gate/up/down). Kernels from `kernels/` lower into the same
HLO, so the whole expert is a single fused executable per (scheme, tile_m).

Also exports the fused Group-GEMM whole-block executables (one launch for
all experts of one scheme) used by the serving engine's batch path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.dequant_gemm import dequant_gemm
from .kernels.group_gemm import group_gemm
from .kernels.hadamard import hadamard_rotate
from .kernels.wa_gemm import wa_gemm
from .kernels import ref

# Schemes the runtime ships executables for (perf-path set; odd bitwidths
# like GPTQ-3bit are accuracy-side only and never need a kernel).
RUNTIME_SCHEMES = ("fp16", "w4a16", "w8a8", "w4a4")


def _silu(x):
    return x * (1.0 / (1.0 + jnp.exp(-x)))


# ---------------- expert FFN per scheme ----------------
# Weight layouts per scheme (prepared offline by the rust quantizer or
# `prepare_expert_weights` below):
#   fp16  : gate/up `[inter, hidden]`, down `[hidden, inter]` f32
#   w4a16 : packed uint8 + per-channel scales/zeros
#   w8a8  : int8 codes + per-channel sym scales
#   w4a4  : int8 carriers (int4 codes) + per-channel sym scales


def expert_ffn_fp16(x, gate, up, down):
    g = jnp.dot(x, gate.T, preferred_element_type=jnp.float32)
    u = jnp.dot(x, up.T, preferred_element_type=jnp.float32)
    h = _silu(g) * u
    return (jnp.dot(h, down.T, preferred_element_type=jnp.float32),)


def expert_ffn_w4a16(x, gate_p, gate_s, gate_z, up_p, up_s, up_z, down_p, down_s, down_z):
    g = dequant_gemm(x, gate_p, gate_s, gate_z, bits=4)
    u = dequant_gemm(x, up_p, up_s, up_z, bits=4)
    h = _silu(g) * u
    return (dequant_gemm(h, down_p, down_s, down_z, bits=4),)


def expert_ffn_w8a8(x, gate_q, gate_s, up_q, up_s, down_q, down_s):
    g = wa_gemm(x, gate_q, gate_s, bits=8)
    u = wa_gemm(x, up_q, up_s, bits=8)
    h = _silu(g) * u
    return (wa_gemm(h, down_q, down_s, bits=8),)


def expert_ffn_w4a4(x, gate_q, gate_s, up_q, up_s, down_q, down_s):
    g = wa_gemm(x, gate_q, gate_s, bits=4)
    u = wa_gemm(x, up_q, up_s, bits=4)
    h = _silu(g) * u
    return (wa_gemm(h, down_q, down_s, bits=4),)


def expert_ffn_w4a4_rot(x, signs_h, signs_i, gate_q, gate_s, up_q, up_s, down_q, down_s):
    """W4A4 with online Hadamard rotation on both quantized axes (weights
    must be pre-rotated to match)."""
    xr = hadamard_rotate(x, signs_h)
    g = wa_gemm(xr, gate_q, gate_s, bits=4)
    u = wa_gemm(xr, up_q, up_s, bits=4)
    h = _silu(g) * u
    hr = hadamard_rotate(h, signs_i)
    return (wa_gemm(hr, down_q, down_s, bits=4),)


def moe_group_fp16(x_tiles, expert_ids, gates, ups, downs):
    """Whole-block fused Group-GEMM (fp16): every expert's padded token
    tile in one launch per linear."""
    g = group_gemm(x_tiles, expert_ids, gates)
    u = group_gemm(x_tiles, expert_ids, ups)
    h = _silu(g) * u
    return (group_gemm(h, expert_ids, downs),)


# ---------------- offline weight preparation ----------------

def prepare_expert_weights(scheme: str, gate, up, down):
    """Quantize + lay out one expert's weights for `scheme`.

    Returns the tuple of arrays the matching `expert_ffn_*` expects after
    `x` (and after the sign vectors for rotated variants)."""
    if scheme == "fp16":
        return (gate, up, down)
    if scheme == "w4a16":
        out = []
        for w in (gate, up, down):
            codes, scales, zeros = ref.quantize_asym_grouped(w, 4, -1)
            out += [ref.pack_codes(codes, 4), scales, zeros]
        return tuple(out)
    if scheme in ("w8a8", "w4a4"):
        bits = 8 if scheme == "w8a8" else 4
        out = []
        for w in (gate, up, down):
            q, s = ref.quantize_sym(w, bits, axis=-1)
            out += [q, s]
        return tuple(out)
    raise ValueError(f"unknown runtime scheme '{scheme}'")


def expert_ffn_fn(scheme: str):
    """The jittable expert-FFN function for a runtime scheme."""
    return {
        "fp16": expert_ffn_fp16,
        "w4a16": expert_ffn_w4a16,
        "w8a8": expert_ffn_w8a8,
        "w4a4": expert_ffn_w4a4,
    }[scheme]


def expert_ffn_ref(x, gate, up, down):
    """fp32 oracle of the whole expert (shared with kernel tests)."""
    return ref.expert_ffn_ref(x, gate, up, down)


def example_args(scheme: str, m: int, hidden: int, inter: int):
    """ShapeDtypeStructs for lowering `expert_ffn_fn(scheme)` at tile_m=m."""
    f32 = jnp.float32
    x = jax.ShapeDtypeStruct((m, hidden), f32)
    if scheme == "fp16":
        return (
            x,
            jax.ShapeDtypeStruct((inter, hidden), f32),
            jax.ShapeDtypeStruct((inter, hidden), f32),
            jax.ShapeDtypeStruct((hidden, inter), f32),
        )
    if scheme == "w4a16":
        def trio(n, k):
            return (
                jax.ShapeDtypeStruct((n, k // 2), jnp.uint8),
                jax.ShapeDtypeStruct((n, 1), f32),
                jax.ShapeDtypeStruct((n, 1), f32),
            )
        return (x, *trio(inter, hidden), *trio(inter, hidden), *trio(hidden, inter))
    if scheme in ("w8a8", "w4a4"):
        def duo(n, k):
            return (
                jax.ShapeDtypeStruct((n, k), jnp.int8),
                jax.ShapeDtypeStruct((n, 1), f32),
            )
        return (x, *duo(inter, hidden), *duo(inter, hidden), *duo(hidden, inter))
    raise ValueError(scheme)
