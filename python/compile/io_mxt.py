"""MXT tensor container — numpy side of the rust `ser::mxt` format.

Layout (little-endian):
    magic  b"MXT1"
    u32    tensor count
    per tensor:
        u32 name_len, utf-8 name
        u8  dtype (0=f32, 1=i8, 2=i32, 3=u8)
        u32 ndim, u64 × ndim shape
        u64 payload bytes, payload

Byte-compatibility with rust is pinned by `python/tests/test_io_mxt.py`
and `tests/mxt_roundtrip.rs`.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"MXT1"

_DTYPES = {0: np.float32, 1: np.int8, 2: np.int32, 3: np.uint8}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.int8): 1, np.dtype(np.int32): 2, np.dtype(np.uint8): 3}


def save_mxt(path: str, tensors: dict[str, np.ndarray]) -> None:
    """Write named arrays (f32/i8/i32/u8) to an MXT file."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in _CODES:
                raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            name_b = name.encode("utf-8")
            f.write(struct.pack("<I", len(name_b)))
            f.write(name_b)
            f.write(struct.pack("<B", _CODES[arr.dtype]))
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            payload = arr.tobytes()
            f.write(struct.pack("<Q", len(payload)))
            f.write(payload)


def load_mxt(path: str) -> dict[str, np.ndarray]:
    """Read an MXT file into a dict of arrays."""
    out: dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError("bad MXT magic")
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (name_len,) = struct.unpack("<I", f.read(4))
            name = f.read(name_len).decode("utf-8")
            (code,) = struct.unpack("<B", f.read(1))
            (ndim,) = struct.unpack("<I", f.read(4))
            shape = tuple(struct.unpack("<Q", f.read(8))[0] for _ in range(ndim))
            (nbytes,) = struct.unpack("<Q", f.read(8))
            dtype = _DTYPES[code]
            expected = int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize if shape else np.dtype(dtype).itemsize
            if ndim == 0:
                expected = np.dtype(dtype).itemsize
            if nbytes != int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize:
                raise ValueError(f"{name}: payload {nbytes} != shape {shape}")
            out[name] = np.frombuffer(f.read(nbytes), dtype=dtype).reshape(shape).copy()
            del expected
    return out
