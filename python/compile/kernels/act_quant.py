"""L1 Pallas kernel: standalone per-token dynamic activation quantization.

Used when the runtime wants quantized activations as an explicit artifact
(e.g. feeding several same-precision GEMMs from one quantization pass,
amortizing the amax reduction — the paper's runtime does the same before
dispatching a token group to multiple experts)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _act_quant_kernel(x_ref, q_ref, s_ref, *, bits):
    x = x_ref[...]
    qmax = 2 ** (bits - 1) - 1
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    s = jnp.where(amax > 0, amax / qmax, 1.0)
    q_ref[...] = jnp.clip(jnp.round(x / s), -(2 ** (bits - 1)), qmax).astype(jnp.int8)
    s_ref[...] = s


def act_quant(x, *, bits, block_m=None):
    """Per-token symmetric quantization: returns (codes int8 `[m,k]`,
    scales f32 `[m,1]`)."""
    m, k = x.shape
    bm = block_m or m
    assert m % bm == 0
    return pl.pallas_call(
        functools.partial(_act_quant_kernel, bits=bits),
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.int8),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
        ],
        interpret=True,
    )(x)
