"""L1 Pallas kernel: fused-dequant weight-only GEMM (W{2,4,8}A16).

TPU adaptation of the paper's Marlin-class CUDA micro-kernel (§4.3,
DESIGN.md §Hardware-Adaptation):

* CUDA CTA tile + warp layout      → BlockSpec grid over (m-tile, n-tile);
* shared-memory staging            → VMEM blocks (whole k panel per tile —
  at the mini-model shapes a (bm=64, k=2048) int4 panel is 64 KiB, well
  inside the ~16 MiB VMEM budget; DESIGN.md §8 documents footprints);
* fused dequant in the MMA pipe    → in-kernel nibble/crumb unpacking with
  shift/mask (the Kim et al. 2022 bit trick, vectorized) + scale/zero
  multiply before the MXU `jnp.dot`;
* tensor-core fp16 MMA             → `jnp.dot(..., preferred_element_type=f32)`.

Weights arrive *physically packed* (uint8 carriers, little-end first,
matching rust `quant::pack` and `ref.pack_codes`).

interpret=True everywhere: real-TPU lowering emits a Mosaic custom-call the
CPU PJRT plugin cannot execute (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _unpack(packed, bits, k):
    """In-kernel unpack of uint8 carriers to uint codes `[n, k]`."""
    per_byte = 8 // bits
    shifts = (jnp.arange(per_byte) * bits).astype(jnp.uint32)
    mask = jnp.uint32(2**bits - 1)
    un = (packed.astype(jnp.uint32)[:, :, None] >> shifts[None, None, :]) & mask
    return un.reshape(packed.shape[0], -1)[:, :k]


def _dequant_gemm_kernel(x_ref, p_ref, s_ref, z_ref, o_ref, *, bits, group, k):
    """One (bm, bn) output tile: unpack → dequant → MXU dot."""
    codes = _unpack(p_ref[...], bits, k).astype(jnp.float32)  # [bn, k]
    groups = k // group
    cg = codes.reshape(codes.shape[0], groups, group)
    w = (cg * s_ref[...][:, :, None] + z_ref[...][:, :, None]).reshape(codes.shape[0], k)
    o_ref[...] = jnp.dot(x_ref[...], w.T, preferred_element_type=jnp.float32)


def dequant_gemm(x, packed, scales, zeros, *, bits, group=-1, block_m=None, block_n=None):
    """`y = x · dequant(W)ᵀ` with packed low-bit weights.

    x: `[m, k]` f32; packed: `[n, k*bits/8]` uint8; scales/zeros:
    `[n, k/group]` f32 (group ≤ 0 ⇒ one group of k). Returns `[m, n]` f32.
    """
    m, k = x.shape
    n = packed.shape[0]
    g = k if group <= 0 else group
    assert k % g == 0 and scales.shape == (n, k // g) == zeros.shape
    bm = block_m or m
    bn = block_n or n
    assert m % bm == 0 and n % bn == 0
    per_byte = 8 // bits
    kp = k // per_byte
    gpb = k // g  # groups per row
    kernel = functools.partial(_dequant_gemm_kernel, bits=bits, group=g, k=k)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, kp), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, gpb), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, gpb), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, packed, scales, zeros)
