"""Pure-jnp oracles for every Pallas kernel (the L1 correctness ground
truth). pytest checks kernel-vs-ref allclose under hypothesis sweeps."""

from __future__ import annotations

import jax.numpy as jnp


# ---------------- quantization primitives ----------------

def quant_params_sym(x, bits, axis=-1, keepdims=True):
    """Symmetric per-slice scale: amax / qmax."""
    qmax = 2 ** (bits - 1) - 1
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=keepdims)
    return jnp.where(amax > 0, amax / qmax, 1.0)


def fake_quant_sym(x, bits, axis=-1):
    """Quantize→dequantize, symmetric, per-slice along `axis`."""
    qmax = 2 ** (bits - 1) - 1
    qmin = -(2 ** (bits - 1))
    scale = quant_params_sym(x, bits, axis)
    q = jnp.clip(jnp.round(x / scale), qmin, qmax)
    return q * scale


def quantize_sym(x, bits, axis=-1):
    """Integer codes + scale, symmetric."""
    qmax = 2 ** (bits - 1) - 1
    qmin = -(2 ** (bits - 1))
    scale = quant_params_sym(x, bits, axis)
    q = jnp.clip(jnp.round(x / scale), qmin, qmax).astype(jnp.int8)
    return q, scale


def quantize_asym_grouped(w, bits, group):
    """Asymmetric (min-max) grouped weight quantization of `[n, k]` along k.

    Returns (codes uint8 `[n, k]`, scales `[n, k//group]`, zeros same shape)
    with dequant `w ≈ codes * scale + zero` (range forced to include 0).
    """
    n, k = w.shape
    g = k if group <= 0 else group
    assert k % g == 0
    wg = w.reshape(n, k // g, g)
    qmax = 2**bits - 1
    lo = jnp.minimum(wg.min(axis=-1, keepdims=True), 0.0)
    hi = jnp.maximum(wg.max(axis=-1, keepdims=True), 0.0)
    scale = jnp.where(hi > lo, (hi - lo) / qmax, 1.0)
    q = jnp.clip(jnp.round((wg - lo) / scale), 0, qmax).astype(jnp.uint8)
    return (
        q.reshape(n, k),
        scale.squeeze(-1).astype(jnp.float32),
        lo.squeeze(-1).astype(jnp.float32),
    )


def dequant_grouped(codes, scales, zeros):
    """Inverse of `quantize_asym_grouped`."""
    n, k = codes.shape
    groups = scales.shape[1]
    g = k // groups
    cg = codes.reshape(n, groups, g).astype(jnp.float32)
    return (cg * scales[:, :, None] + zeros[:, :, None]).reshape(n, k)


# ---------------- packing ----------------

def pack_codes(codes, bits):
    """Pack uint codes into uint8, little-end first (matches rust
    `quant::pack`): element 0 in the low bits of byte 0."""
    per_byte = 8 // bits
    n, k = codes.shape
    assert k % per_byte == 0
    c = codes.reshape(n, k // per_byte, per_byte).astype(jnp.uint32)
    shifts = (jnp.arange(per_byte) * bits).astype(jnp.uint32)
    packed = jnp.sum(c << shifts[None, None, :], axis=-1)
    return packed.astype(jnp.uint8)


def unpack_codes(packed, bits, k):
    """Inverse of `pack_codes`."""
    per_byte = 8 // bits
    n = packed.shape[0]
    p = packed.astype(jnp.uint32)
    shifts = (jnp.arange(per_byte) * bits).astype(jnp.uint32)
    mask = jnp.uint32(2**bits - 1)
    un = (p[:, :, None] >> shifts[None, None, :]) & mask
    return un.reshape(n, -1)[:, :k].astype(jnp.uint8)


# ---------------- GEMM references ----------------

def dequant_gemm_ref(x, codes, scales, zeros):
    """W{2,4,8}A16 fused-dequant GEMM reference: y = x · dequant(W)ᵀ."""
    w = dequant_grouped(codes, scales, zeros)
    return x @ w.T


def wa_gemm_ref(x, wq, wscale, bits):
    """W{4,8}A{4,8} reference: dynamic per-token sym act quant, integer
    matmul, rescale. `wq` int8 codes `[n, k]`, `wscale` `[n, 1]`."""
    xq, xscale = quantize_sym(x, bits, axis=-1)
    acc = jnp.dot(xq.astype(jnp.int32), wq.astype(jnp.int32).T)
    return acc.astype(jnp.float32) * xscale * wscale.T


def hadamard_matrix(k):
    """Sylvester-construction Hadamard matrix (k a power of two)."""
    assert k & (k - 1) == 0
    h = jnp.array([[1.0]], dtype=jnp.float32)
    while h.shape[0] < k:
        h = jnp.block([[h, h], [h, -h]])
    return h


def hadamard_ref(x, signs):
    """x · Q with Q = diag(signs)·H/√k (matches rust `quant::hadamard`)."""
    k = x.shape[-1]
    h = hadamard_matrix(k)
    return (x * signs[None, :]) @ h / jnp.sqrt(jnp.float32(k))


def expert_ffn_ref(x, gate_w, up_w, down_w):
    """fp32 SwiGLU expert reference (Eq. 1)."""
    g = x @ gate_w.T
    u = x @ up_w.T
    h = g * (1.0 / (1.0 + jnp.exp(-g))) * u
    return h @ down_w.T


def group_gemm_ref(x_tiles, expert_ids, weights):
    """Grouped GEMM reference: tile i of `x_tiles` `[tiles, tile_m, k]`
    (tokens grouped per expert and padded by the host) multiplies expert
    `expert_ids[i]`'s weight from `weights` `[E, n, k]`."""
    return jnp.einsum("tmk,tnk->tmn", x_tiles, weights[expert_ids])
