"""L1 Pallas kernel: the horizontally-fused Group-GEMM (§4.3's headline).

One launch processes every expert's token tile: the host (rust L3
coordinator) groups tokens by expert, pads each group to `tile_m`, and
ships a flat tile list plus a per-tile expert-id vector. The kernel uses
**scalar prefetch** to gather the right expert's weight block per tile —
the TPU analogue of the paper's precision-aware tile scheduler routing CTA
indices to micro-kernels (DESIGN.md §Hardware-Adaptation).

Two variants: fp16 (fp32 carriers on CPU) and W4A16 fused-dequant. Mixed
precision across *kernels* is the L3 scheduler's job (one executable per
scheme, one shared task queue); within a scheme this kernel is the fused
Group-GEMM."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _group_gemm_kernel(ids_ref, x_ref, w_ref, o_ref):
    del ids_ref  # consumed by the index maps
    o_ref[...] = jnp.dot(x_ref[0], w_ref[0].T, preferred_element_type=jnp.float32)[None]


def group_gemm(x_tiles, expert_ids, weights):
    """Grouped GEMM: `x_tiles [t, tile_m, k]`, `expert_ids [t] i32`,
    `weights [E, n, k]` → `[t, tile_m, n]`. Tile i multiplies
    `weights[expert_ids[i]]`."""
    t, tile_m, k = x_tiles.shape
    e, n, k2 = weights.shape
    assert k == k2
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((1, tile_m, k), lambda i, ids: (i, 0, 0)),
            pl.BlockSpec((1, n, k), lambda i, ids: (ids[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_m, n), lambda i, ids: (i, 0, 0)),
    )
    return pl.pallas_call(
        _group_gemm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, tile_m, n), jnp.float32),
        interpret=True,
    )(expert_ids, x_tiles, weights)


def _group_dequant_kernel(ids_ref, x_ref, p_ref, s_ref, z_ref, o_ref, *, bits, group, k):
    del ids_ref
    from .dequant_gemm import _unpack

    codes = _unpack(p_ref[0], bits, k).astype(jnp.float32)
    groups = k // group
    cg = codes.reshape(codes.shape[0], groups, group)
    w = (cg * s_ref[0][:, :, None] + z_ref[0][:, :, None]).reshape(codes.shape[0], k)
    o_ref[...] = jnp.dot(x_ref[0], w.T, preferred_element_type=jnp.float32)[None]


def group_gemm_w4a16(x_tiles, expert_ids, packed, scales, zeros, *, bits=4, group=-1):
    """Fused-dequant grouped GEMM: per-tile expert gather of *packed*
    low-bit weights. packed `[E, n, k*bits/8]`, scales/zeros `[E, n, k/g]`."""
    t, tile_m, k = x_tiles.shape
    e, n, kp = packed.shape
    g = k if group <= 0 else group
    gpb = k // g
    assert scales.shape == (e, n, gpb) == zeros.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((1, tile_m, k), lambda i, ids: (i, 0, 0)),
            pl.BlockSpec((1, n, kp), lambda i, ids: (ids[i], 0, 0)),
            pl.BlockSpec((1, n, gpb), lambda i, ids: (ids[i], 0, 0)),
            pl.BlockSpec((1, n, gpb), lambda i, ids: (ids[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_m, n), lambda i, ids: (i, 0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_group_dequant_kernel, bits=bits, group=g, k=k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, tile_m, n), jnp.float32),
        interpret=True,
    )(expert_ids, x_tiles, packed, scales, zeros)
