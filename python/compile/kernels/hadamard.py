"""L1 Pallas kernel: randomized Hadamard rotation `x ← x·diag(s)·H/√k`.

The online half of QuaRot incoherence processing (§4.2.2): activations are
rotated on the fly before weight-activation quantization. In-kernel FWHT
butterflies (log₂k static stages over the VMEM tile) instead of a dense
k×k matmul — O(k log k) VPU work, no MXU, no extra HBM traffic."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hadamard_kernel(x_ref, s_ref, o_ref, *, k):
    v = x_ref[...] * s_ref[...]
    m = v.shape[0]
    # FWHT: static unrolled butterfly stages (k is a compile-time constant)
    h = 1
    while h < k:
        vg = v.reshape(m, k // (2 * h), 2, h)
        a = vg[:, :, 0, :]
        b = vg[:, :, 1, :]
        v = jnp.stack([a + b, a - b], axis=2).reshape(m, k)
        h *= 2
    o_ref[...] = v * (1.0 / jnp.sqrt(jnp.float32(k)))


def hadamard_rotate(x, signs, *, block_m=None):
    """Rotate rows of `[m, k]` by `diag(signs)·H/√k` (k a power of two)."""
    m, k = x.shape
    assert k & (k - 1) == 0, "hadamard needs power-of-two k"
    assert signs.shape == (k,)
    bm = block_m or m
    assert m % bm == 0
    return pl.pallas_call(
        functools.partial(_hadamard_kernel, k=k),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        interpret=True,
    )(x, signs)
