"""L1 Pallas kernel: weight-activation integer GEMM (W8A8 / W4A4 /
W4A4-g128) with fused dynamic per-token activation quantization.

TPU adaptation of the paper's QServe/Atom-class CUDA kernels:

* dp4a/int tensor-core MMA → integer `jnp.dot` with
  `preferred_element_type=int32` (int8 operands; int4 codes ride in int8
  carriers — the MXU consumes int8 natively, int4 via the same path);
* per-token dynamic act quant fused at tile load (no fp activation ever
  leaves VMEM);
* per-group (g128) variant rescales partial sums per k-group inside the
  MAC loop — exactly the pipeline constraint Tab. 6 measures.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wa_kernel(x_ref, wq_ref, ws_ref, o_ref, *, bits):
    """Per-channel symmetric: quantize the act tile per token, int-dot,
    rescale by (act scale × weight scale)."""
    x = x_ref[...]
    qmax = 2 ** (bits - 1) - 1
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    xs = jnp.where(amax > 0, amax / qmax, 1.0)
    xq = jnp.clip(jnp.round(x / xs), -(2 ** (bits - 1)), qmax).astype(jnp.int8)
    acc = jnp.dot(xq.astype(jnp.int32), wq_ref[...].astype(jnp.int32).T,
                  preferred_element_type=jnp.int32)
    o_ref[...] = acc.astype(jnp.float32) * xs * ws_ref[...].T


def wa_gemm(x, wq, wscale, *, bits, block_m=None, block_n=None):
    """`y ≈ x · Wᵀ` with W pre-quantized symmetric per-channel.

    x: `[m, k]` f32; wq: `[n, k]` int8 codes; wscale: `[n, 1]` f32.
    """
    m, k = x.shape
    n = wq.shape[0]
    assert wscale.shape == (n, 1)
    bm = block_m or m
    bn = block_n or n
    assert m % bm == 0 and n % bn == 0
    return pl.pallas_call(
        functools.partial(_wa_kernel, bits=bits),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, wq, wscale)


def _wa_group_kernel(x_ref, wq_ref, ws_ref, o_ref, *, bits, group, k):
    """Group-128 variant: int partial sums per k-group, rescaled and
    accumulated in fp32 (the Atom-style pipeline)."""
    x = x_ref[...]
    groups = k // group
    qmax = 2 ** (bits - 1) - 1
    xg = x.reshape(x.shape[0], groups, group)
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    xs = jnp.where(amax > 0, amax / qmax, 1.0)  # [bm, groups, 1]
    xq = jnp.clip(jnp.round(xg / xs), -(2 ** (bits - 1)), qmax).astype(jnp.int8)
    wg = wq_ref[...].reshape(wq_ref.shape[0], groups, group)  # [bn, groups, g]
    # per-group integer dots, rescaled then summed over groups
    acc = jnp.einsum(
        "mgk,ngk->gmn",
        xq.astype(jnp.int32),
        wg.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)
    scale = xs.transpose(1, 0, 2) * ws_ref[...].T[:, None, :]  # [groups, bm, bn]
    o_ref[...] = jnp.sum(acc * scale, axis=0)


def wa_gemm_grouped(x, wq, wscale, *, bits, group=128, block_m=None, block_n=None):
    """Group-quantized W/A GEMM: `wscale` is `[n, k/group]`, activations are
    quantized per (token, k-group) on the fly."""
    m, k = x.shape
    n = wq.shape[0]
    g = k if group <= 0 else group
    assert k % g == 0 and wscale.shape == (n, k // g)
    bm = block_m or m
    bn = block_n or n
    assert m % bm == 0 and n % bn == 0
    return pl.pallas_call(
        functools.partial(_wa_group_kernel, bits=bits, group=g, k=k),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, k // g), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, wq, wscale)


def wa_group_gemm_ref_scales(x, wq, wscale, bits, group):
    """Oracle for `wa_gemm_grouped` (lives here because it needs the same
    group layout; re-exported via tests)."""
    m, k = x.shape
    groups = k // group
    qmax = 2 ** (bits - 1) - 1
    xg = x.reshape(m, groups, group)
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    xs = jnp.where(amax > 0, amax / qmax, 1.0)
    xq = jnp.clip(jnp.round(xg / xs), -(2 ** (bits - 1)), qmax)
    wg = wq.reshape(wq.shape[0], groups, group).astype(jnp.float32)
    acc = jnp.einsum("mgk,ngk->gmn", xq, wg)
    scale = xs.transpose(1, 0, 2) * wscale.T[:, None, :]
    return jnp.sum(acc * scale, axis=0)
