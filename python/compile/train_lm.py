"""Train the mini MoE LMs on the synthetic corpus and export MXT weights.

Build-time only (`make models`). Reads `artifacts/corpus.mxt` written by
`mxmoe gen-corpus`, trains with Adam on next-token CE, writes:

* `artifacts/model_<name>.mxt`  — weights in the rust naming scheme
* `artifacts/parity_<name>.mxt` — a fixed token sequence + this trainer's
  logits, pinning python↔rust forward parity in `tests/python_rust_parity.rs`

Usage: python -m compile.train_lm [--models a,b] [--steps N] [--out DIR]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from .io_mxt import load_mxt, save_mxt
from .moe_lm import CONFIGS, Config, forward, init_params, loss_fn


def adam_init(p):
    zeros = {k: jnp.zeros_like(v) for k, v in p.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in p.items()}, "t": 0}


def adam_step(p, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in p}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in p}
    mhat = {k: m[k] / (1 - b1**t) for k in p}
    vhat = {k: v[k] / (1 - b2**t) for k in p}
    new_p = {k: p[k] - lr * mhat[k] / (jnp.sqrt(vhat[k]) + eps) for k in p}
    return new_p, {"m": m, "v": v, "t": t}


def batches(train: np.ndarray, seq_len: int, batch: int, steps: int, seed: int):
    """Deterministic batch sampler over the token stream."""
    rng = np.random.default_rng(seed)
    n_seq = len(train) // seq_len
    view = train[: n_seq * seq_len].reshape(n_seq, seq_len)
    for _ in range(steps):
        idx = rng.integers(0, n_seq, size=batch)
        yield jnp.asarray(view[idx])


def train_one(name: str, corpus: dict, steps: int, batch: int, lr: float, out_dir: str):
    cfg: Config = CONFIGS[name]
    key = jax.random.PRNGKey(hash(name) % (2**31))
    params = init_params(cfg, key)
    opt = adam_init(params)
    train = corpus["train"].astype(np.int32)

    @jax.jit
    def step(p, o, b):
        loss, grads = jax.value_and_grad(lambda q: loss_fn(q, b, cfg))(p)
        p2, o2 = adam_step(p, grads, o, lr)
        return p2, o2, loss

    t0 = time.time()
    losses = []
    for i, b in enumerate(batches(train, cfg.seq_len, batch, steps, seed=42)):
        params, opt, loss = step(params, opt, b)
        losses.append(float(loss))
        if i % 20 == 0 or i == steps - 1:
            print(f"[{name}] step {i:4d}/{steps} loss {float(loss):.4f} ({time.time()-t0:.0f}s)")
    print(f"[{name}] loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0] * 0.9, f"{name}: training did not reduce loss"

    # export weights
    tensors = {k: np.asarray(v, dtype=np.float32) for k, v in params.items()}
    save_mxt(f"{out_dir}/model_{name}.mxt", tensors)

    # export parity pin: fixed sequence + logits
    seq = np.asarray(corpus["valid"][: cfg.seq_len], dtype=np.int32)
    logits = np.asarray(forward(params, jnp.asarray(seq), cfg), dtype=np.float32)
    save_mxt(
        f"{out_dir}/parity_{name}.mxt",
        {"tokens": seq, "logits": logits, "final_loss": np.float32([losses[-1]])},
    )
    print(f"[{name}] wrote model + parity to {out_dir}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default=",".join(CONFIGS))
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--corpus", default="../artifacts/corpus.mxt")
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    corpus = load_mxt(args.corpus)
    for name in args.models.split(","):
        train_one(name.strip(), corpus, args.steps, args.batch, args.lr, args.out)


if __name__ == "__main__":
    main()
