"""Build-time JAX mini MoE LM — trainer-side twin of rust `moe::lm`.

Architecture and weight naming are pinned to the rust implementation
(`rust/src/moe/lm.rs`); parity is enforced by `tests/python_rust_parity.rs`
against logits exported at training time. Training uses a dense
(mask-weighted) mixture so routing stays differentiable; inference-time
top-k dispatch in rust computes exactly the same function because
non-selected experts get weight 0.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Config:
    name: str
    vocab: int = 512
    hidden: int = 128
    layers: int = 4
    heads: int = 4
    n_experts: int = 8
    n_shared: int = 0
    topk: int = 2
    inter: int = 256
    dense_first: bool = False
    seq_len: int = 128


CONFIGS = {
    "mixtral-mini": Config("mixtral-mini", n_experts=8, n_shared=0, topk=2, inter=256),
    "qwen15-mini": Config("qwen15-mini", n_experts=60, n_shared=4, topk=4, inter=64),
    "qwen2-mini": Config("qwen2-mini", n_experts=64, n_shared=8, topk=8, inter=64),
    "dsv2-mini": Config("dsv2-mini", n_experts=64, n_shared=2, topk=6, inter=64, dense_first=True),
}


def init_params(cfg: Config, key) -> dict:
    """Initialize with the rust naming scheme (flat dict of arrays)."""
    p = {}
    h = cfg.hidden
    std = 1.0 / np.sqrt(h)
    keys = iter(jax.random.split(key, 16 + cfg.layers * (8 + 3 * (cfg.n_experts + cfg.n_shared + 1))))
    p["embed"] = jax.random.normal(next(keys), (cfg.vocab, h)) * 1.0
    p["head"] = jax.random.normal(next(keys), (cfg.vocab, h)) * std
    p["ln_f"] = jnp.ones((h,))
    for l in range(cfg.layers):
        pre = f"layers.{l}."
        p[pre + "ln1"] = jnp.ones((h,))
        p[pre + "ln2"] = jnp.ones((h,))
        for w in ("wq", "wk", "wv", "wo"):
            p[pre + w] = jax.random.normal(next(keys), (h, h)) * std
        if cfg.dense_first and l == 0:
            di = cfg.inter * cfg.topk
            p[pre + "dense.gate"] = jax.random.normal(next(keys), (di, h)) * std
            p[pre + "dense.up"] = jax.random.normal(next(keys), (di, h)) * std
            p[pre + "dense.down"] = jax.random.normal(next(keys), (h, di)) * (1.0 / np.sqrt(di))
        else:
            p[pre + "router"] = jax.random.normal(next(keys), (cfg.n_experts, h)) * std
            sub = jax.random.split(next(keys), cfg.n_experts + cfg.n_shared)
            for e in range(cfg.n_experts):
                k1, k2, k3 = jax.random.split(sub[e], 3)
                p[pre + f"expert.{e}.gate"] = jax.random.normal(k1, (cfg.inter, h)) * std
                p[pre + f"expert.{e}.up"] = jax.random.normal(k2, (cfg.inter, h)) * std
                p[pre + f"expert.{e}.down"] = jax.random.normal(k3, (h, cfg.inter)) * (1.0 / np.sqrt(cfg.inter))
            for s in range(cfg.n_shared):
                k1, k2, k3 = jax.random.split(sub[cfg.n_experts + s], 3)
                p[pre + f"shared.{s}.gate"] = jax.random.normal(k1, (cfg.inter, h)) * std
                p[pre + f"shared.{s}.up"] = jax.random.normal(k2, (cfg.inter, h)) * std
                p[pre + f"shared.{s}.down"] = jax.random.normal(k3, (h, cfg.inter)) * (1.0 / np.sqrt(cfg.inter))
    return p


def rmsnorm(x, gain, eps=1e-6):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x / jnp.sqrt(ms + eps) * gain


def rope(x, heads, head_dim):
    """Identical to rust `moe::lm::apply_rope` (pairs (2i, 2i+1), θ=10⁴)."""
    t = x.shape[0]
    pos = jnp.arange(t, dtype=jnp.float32)[:, None]
    i = jnp.arange(head_dim // 2, dtype=jnp.float32)[None, :]
    theta = pos / jnp.power(10000.0, 2.0 * i / head_dim)
    sin, cos = jnp.sin(theta), jnp.cos(theta)  # [t, hd/2]
    xh = x.reshape(t, heads, head_dim // 2, 2)
    a, b = xh[..., 0], xh[..., 1]
    ar = a * cos[:, None, :] - b * sin[:, None, :]
    br = a * sin[:, None, :] + b * cos[:, None, :]
    return jnp.stack([ar, br], axis=-1).reshape(t, heads * head_dim)


def silu(x):
    return x * (1.0 / (1.0 + jnp.exp(-x)))


def attention(p, pre, x, cfg: Config):
    t = x.shape[0]
    hd = cfg.hidden // cfg.heads
    q = rope(x @ p[pre + "wq"].T, cfg.heads, hd)
    k = rope(x @ p[pre + "wk"].T, cfg.heads, hd)
    v = x @ p[pre + "wv"].T
    qh = q.reshape(t, cfg.heads, hd).transpose(1, 0, 2)
    kh = k.reshape(t, cfg.heads, hd).transpose(1, 0, 2)
    vh = v.reshape(t, cfg.heads, hd).transpose(1, 0, 2)
    scores = jnp.einsum("htd,hsd->hts", qh, kh) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask[None], scores, -jnp.inf)
    att = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("hts,hsd->htd", att, vh).transpose(1, 0, 2).reshape(t, cfg.hidden)
    return ctx @ p[pre + "wo"].T


def moe_ffn(p, pre, x, cfg: Config):
    """Dense mask-weighted MoE (differentiable twin of top-k dispatch)."""
    probs = jax.nn.softmax(x @ p[pre + "router"].T, axis=-1)  # [t, E]
    topv, topi = jax.lax.top_k(probs, cfg.topk)
    w = jnp.zeros_like(probs)
    w = jnp.take_along_axis(
        w, topi, axis=-1
    )  # placeholder to keep shapes clear
    weights = jnp.zeros_like(probs).at[jnp.arange(probs.shape[0])[:, None], topi].set(
        topv / topv.sum(axis=-1, keepdims=True)
    )
    del w
    gates = jnp.stack([p[pre + f"expert.{e}.gate"] for e in range(cfg.n_experts)])
    ups = jnp.stack([p[pre + f"expert.{e}.up"] for e in range(cfg.n_experts)])
    downs = jnp.stack([p[pre + f"expert.{e}.down"] for e in range(cfg.n_experts)])
    g = jnp.einsum("th,eih->tei", x, gates)
    u = jnp.einsum("th,eih->tei", x, ups)
    hmid = silu(g) * u
    y = jnp.einsum("tei,ehi->teh", hmid, downs)
    out = jnp.einsum("teh,te->th", y, weights)
    for s in range(cfg.n_shared):
        gw, uw, dw = (p[pre + f"shared.{s}.{n}"] for n in ("gate", "up", "down"))
        out = out + (silu(x @ gw.T) * (x @ uw.T)) @ dw.T
    return out


def dense_ffn(p, pre, x):
    g = x @ p[pre + "dense.gate"].T
    u = x @ p[pre + "dense.up"].T
    return (silu(g) * u) @ p[pre + "dense.down"].T


def forward(p, tokens, cfg: Config):
    """Logits `[t, vocab]` for one sequence."""
    x = p["embed"][tokens]
    for l in range(cfg.layers):
        pre = f"layers.{l}."
        x = x + attention(p, pre, rmsnorm(x, p[pre + "ln1"]), cfg)
        xn = rmsnorm(x, p[pre + "ln2"])
        if cfg.dense_first and l == 0:
            x = x + dense_ffn(p, pre, xn)
        else:
            x = x + moe_ffn(p, pre, xn, cfg)
    return rmsnorm(x, p["ln_f"]) @ p["head"].T


def loss_fn(p, batch, cfg: Config):
    """Mean next-token cross-entropy over a `[b, t]` batch."""
    logits = jax.vmap(lambda seq: forward(p, seq, cfg))(batch)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = batch[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1).squeeze(-1)
    return nll.mean()
