"""AOT lowering: every runtime executable → HLO *text* in `artifacts/`.

HLO text (NOT `lowered.compiler_ir("hlo")` protos, NOT `.serialize()`):
jax ≥ 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Exports, per runtime scheme s ∈ {fp16, w4a16, w8a8, w4a4} and tile size
m ∈ {16, 64, 256}: `expert_ffn_{s}_m{m}.hlo.txt` — one fused executable
for a padded token tile through one expert (serving-model shapes:
hidden=128, inter=64 — qwen15-mini). Plus the fused Group-GEMM whole-block
executable and a smoke matmul for runtime tests.

Usage: cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.group_gemm import group_gemm
from .model import RUNTIME_SCHEMES, example_args, expert_ffn_fn

# serving-model shapes (qwen15-mini)
HIDDEN = 128
INTER = 64
TILE_MS = (4, 16, 64, 256)
# group-GEMM executable: fixed tile budget per launch
GROUP_TILES = 64
GROUP_TILE_M = 16


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write(path: str, lowered) -> None:
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")


def smoke_fn(x, y):
    return (jnp.matmul(x, y) + 2.0,)


def group_fp16_fn(x_tiles, expert_ids, gates, ups, downs):
    g = group_gemm(x_tiles, expert_ids, gates)
    u = group_gemm(x_tiles, expert_ids, ups)
    h = g * (1.0 / (1.0 + jnp.exp(-g))) * u
    return (group_gemm(h, expert_ids, downs),)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--experts", type=int, default=64, help="experts in the group executable")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    # smoke test artifact (runtime unit tests)
    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    write(f"{args.out}/smoke_matmul.hlo.txt", jax.jit(smoke_fn).lower(spec, spec))

    # per-scheme expert FFN tiles
    for scheme in RUNTIME_SCHEMES:
        fn = expert_ffn_fn(scheme)
        for m in TILE_MS:
            lowered = jax.jit(fn).lower(*example_args(scheme, m, HIDDEN, INTER))
            write(f"{args.out}/expert_ffn_{scheme}_m{m}.hlo.txt", lowered)

    # fused fp16 Group-GEMM whole-block executable
    f32 = jnp.float32
    e = args.experts
    lowered = jax.jit(group_fp16_fn).lower(
        jax.ShapeDtypeStruct((GROUP_TILES, GROUP_TILE_M, HIDDEN), f32),
        jax.ShapeDtypeStruct((GROUP_TILES,), jnp.int32),
        jax.ShapeDtypeStruct((e, INTER, HIDDEN), f32),
        jax.ShapeDtypeStruct((e, INTER, HIDDEN), f32),
        jax.ShapeDtypeStruct((e, HIDDEN, INTER), f32),
    )
    write(f"{args.out}/moe_group_fp16_t{GROUP_TILES}_m{GROUP_TILE_M}.hlo.txt", lowered)
    print("AOT export complete")


if __name__ == "__main__":
    main()
