//! Integration: the full allocation pipeline (calibrate → sensitivity →
//! MCKP) on a small random model, asserting the paper's structural claims:
//! budget adherence, r-monotonicity, and linear-block > expert granularity.

use mxmoe::alloc::{
    allocate, calibrate, measure_sensitivity, AllocatorConfig, Granularity,
};
use mxmoe::costmodel::GpuSpec;
use mxmoe::moe::{ModelConfig, MoeLm};
use mxmoe::quant::{QuantScheme, SchemeRegistry};
use mxmoe::util::Rng;

fn setup() -> (ModelConfig, MoeLm, Vec<Vec<u32>>) {
    let cfg = ModelConfig {
        name: "alloc-test".into(),
        vocab: 64,
        hidden: 64,
        layers: 2,
        heads: 2,
        n_experts: 8,
        n_shared: 1,
        topk: 2,
        inter: 32,
        dense_first: false,
        seq_len: 32,
    };
    let mut rng = Rng::new(0xA110C);
    let lm = MoeLm::random(&cfg, &mut rng);
    let seqs: Vec<Vec<u32>> = (0..6)
        .map(|_| (0..32).map(|_| rng.below(64) as u32).collect())
        .collect();
    (cfg, lm, seqs)
}

#[test]
fn full_pipeline_respects_budget_and_tradeoff() {
    let (cfg, lm, seqs) = setup();
    let refs: Vec<&[u32]> = seqs.iter().map(|s| s.as_slice()).collect();
    let stats = calibrate(&lm, &refs, None).unwrap();
    let registry = SchemeRegistry::weight_activation();
    let sens = measure_sensitivity(&lm, &stats, &registry).unwrap();
    let gpu = GpuSpec::rtx4090();

    let mut alloc_cfg = AllocatorConfig {
        r: 0.75,
        target_avg_bits: 5.0,
        granularity: Granularity::LinearBlock,
        batch_tokens: 256,
    };

    let a5 = allocate(&lm, &gpu, &registry, &stats, &sens, &alloc_cfg).unwrap();
    let bits5 = a5.avg_weight_bits(&cfg);
    assert!(bits5 <= 5.3, "avg bits {bits5} exceeds ~5 target");
    assert!(bits5 >= 4.0, "degenerate allocation: {bits5}");

    // tighter budget ⇒ fewer bits
    alloc_cfg.target_avg_bits = 4.5;
    let a45 = allocate(&lm, &gpu, &registry, &stats, &sens, &alloc_cfg).unwrap();
    assert!(a45.avg_weight_bits(&cfg) <= bits5 + 1e-9);

    // mixed output: at 5 bits with {w4a4, w4a4g128, w8a8} candidates we
    // expect both 4-bit and 8-bit schemes present (Tab. 7's shape)
    let mut has4 = false;
    let mut has8 = false;
    for block in &a5.schemes {
        for ex in block {
            for s in ex {
                if s.wbits == 4 {
                    has4 = true;
                }
                if s.wbits == 8 {
                    has8 = true;
                }
            }
        }
    }
    assert!(has4 && has8, "allocation is not mixed: has4={has4} has8={has8}");
}

#[test]
fn r_controls_accuracy_vs_time() {
    let (_cfg, lm, seqs) = setup();
    let refs: Vec<&[u32]> = seqs.iter().map(|s| s.as_slice()).collect();
    let stats = calibrate(&lm, &refs, None).unwrap();
    let registry = SchemeRegistry::weight_activation();
    let sens = measure_sensitivity(&lm, &stats, &registry).unwrap();
    let gpu = GpuSpec::rtx4090();

    // evaluate L and T of an allocation under the same tables
    let eval = |alloc: &mxmoe::alloc::Allocation| -> (f64, f64) {
        let mut l = 0.0;
        let mut t = 0.0;
        for (bi, block) in alloc.schemes.iter().enumerate() {
            let counts = &stats.layers[bi].activation_counts;
            let total: usize = counts.iter().sum();
            for (e, ex) in block.iter().enumerate() {
                let m = if e >= counts.len() {
                    256
                } else {
                    ((counts[e] as f64 / total as f64) * 256.0 * lm.cfg.topk as f64).max(1.0)
                        as usize
                };
                for (j, s) in ex.iter().enumerate() {
                    l += sens.delta(bi, e, j, s);
                    let (n, k) = if j == 2 {
                        (lm.cfg.hidden, lm.cfg.inter)
                    } else {
                        (lm.cfg.inter, lm.cfg.hidden)
                    };
                    let (cost, _) = mxmoe::costmodel::tile::best_tile(
                        &gpu,
                        s,
                        m,
                        n,
                        k,
                        None,
                        mxmoe::costmodel::Specialization::Specialized,
                    );
                    t += cost / gpu.sms as f64;
                }
            }
        }
        (l, t)
    };

    let mk = |r: f64| {
        allocate(
            &lm,
            &gpu,
            &registry,
            &stats,
            &sens,
            &AllocatorConfig {
                r,
                target_avg_bits: 6.0,
                granularity: Granularity::LinearBlock,
                batch_tokens: 256,
            },
        )
        .unwrap()
    };
    let (l1, t1) = eval(&mk(1.0)); // pure accuracy
    let (l0, t0) = eval(&mk(0.0)); // pure speed
    assert!(l1 <= l0 + 1e-12, "r=1 must minimize loss: {l1} vs {l0}");
    assert!(t0 <= t1 + 1e-12, "r=0 must minimize time: {t0} vs {t1}");
}

#[test]
fn linear_granularity_beats_expert_granularity() {
    // Tab. 3: finer granularity achieves lower loss at the same budget
    let (_cfg, lm, seqs) = setup();
    let refs: Vec<&[u32]> = seqs.iter().map(|s| s.as_slice()).collect();
    let stats = calibrate(&lm, &refs, None).unwrap();
    let registry = SchemeRegistry::weight_activation();
    let sens = measure_sensitivity(&lm, &stats, &registry).unwrap();
    let gpu = GpuSpec::rtx4090();

    let loss_of = |g: Granularity| -> f64 {
        let alloc = allocate(
            &lm,
            &gpu,
            &registry,
            &stats,
            &sens,
            &AllocatorConfig {
                r: 1.0,
                target_avg_bits: 5.0,
                granularity: g,
                batch_tokens: 256,
            },
        )
        .unwrap();
        let mut l = 0.0;
        for (bi, block) in alloc.schemes.iter().enumerate() {
            for (e, ex) in block.iter().enumerate() {
                for (j, s) in ex.iter().enumerate() {
                    l += sens.delta(bi, e, j, s);
                }
            }
        }
        l
    };
    let l_linear = loss_of(Granularity::LinearBlock);
    let l_expert = loss_of(Granularity::Expert);
    assert!(
        l_linear <= l_expert + 1e-9,
        "linear {l_linear} must not lose to expert {l_expert}"
    );
}

#[test]
fn weight_only_low_bit_allocations() {
    // the 2.25 / 3.25-bit regimes of Tab. 1. At mini-model dims the
    // scale/zero overhead of g128 doesn't amortize (down-proj k=64 ⇒
    // +0.5 bits), so the achievable floor is ≈2.33/3.33; we target the
    // matched 2.4/3.4 budgets (the GPTQ baseline pays the identical
    // overhead, so Tab. 1 comparisons stay at equal stored bits).
    let (cfg, lm, seqs) = setup();
    let refs: Vec<&[u32]> = seqs.iter().map(|s| s.as_slice()).collect();
    let stats = calibrate(&lm, &refs, None).unwrap();
    let registry = SchemeRegistry::weight_only();
    let sens = measure_sensitivity(&lm, &stats, &registry).unwrap();
    let gpu = GpuSpec::rtx4090();
    for target in [2.7f64, 3.7] { // tiny-dim overhead floor ≈2.67
        let alloc = allocate(
            &lm,
            &gpu,
            &registry,
            &stats,
            &sens,
            &AllocatorConfig {
                r: 1.0,
                target_avg_bits: target,
                granularity: Granularity::LinearBlock,
                batch_tokens: 256,
            },
        )
        .unwrap();
        let bits = alloc.avg_weight_bits(&cfg);
        assert!(bits <= target + 0.05, "target {target}: got {bits}");
        // all chosen schemes are weight-only
        for block in &alloc.schemes {
            for ex in block {
                for s in ex {
                    assert!(s.weight_only());
                }
            }
        }
    }
}
