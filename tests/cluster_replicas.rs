//! Integration: multi-replica sharded serving (DESIGN.md §Sharded-Serving)
//! must be a pure throughput transform — for the same request stream, an
//! N-replica cluster's responses are bit-identical to a single replica's,
//! for N ∈ {1, 2, 4} and any dispatch thread count, while the router's
//! accounting stays consistent (every batch routed, executed exactly once).

use std::path::PathBuf;
use std::time::Duration;

use mxmoe::coordinator::{Cluster, ClusterConfig, ServeConfig};
use mxmoe::harness::{mixed_runtime_plan, require_artifacts, save_model_mxt};
use mxmoe::moe::{ModelConfig, MoeLm};
use mxmoe::util::Rng;

/// Serving-shape model (hidden=128, inter=64 — what the AOT export ships).
fn serving_cfg() -> ModelConfig {
    ModelConfig {
        name: "cluster-test".into(),
        vocab: 64,
        hidden: 128,
        layers: 2,
        heads: 4,
        n_experts: 4,
        n_shared: 1,
        topk: 2,
        inter: 64,
        dense_first: false,
        seq_len: 16,
    }
}

/// The fixed request stream every cluster size serves: varying lengths so
/// tile decomposition differs per request, same seed every run.
fn request_stream(cfg: &ModelConfig) -> Vec<Vec<u32>> {
    let mut rng = Rng::new(0xC1_05_7E12);
    let lens = [16usize, 5, 16, 11, 2, 16, 9, 16, 7, 13];
    lens.iter()
        .map(|&n| (0..n).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
        .collect()
}

/// Serve the stream on an N-replica cluster and return per-request
/// `(next_token, mean_nll bits)` plus the cluster report.
fn serve_stream(
    cfg: &ModelConfig,
    weights: &PathBuf,
    artifacts: &PathBuf,
    replicas: usize,
    dispatch_threads: Option<usize>,
) -> (Vec<(u32, u64)>, mxmoe::coordinator::ClusterReport) {
    // max_batch_seqs = 1: every request is its own batch, so batch
    // composition (and therefore tiling) is identical for every cluster
    // shape — what makes bit-identity well-defined across N
    let cluster = Cluster::start(
        cfg.clone(),
        weights.clone(),
        artifacts.clone(),
        mixed_runtime_plan(cfg),
        ClusterConfig {
            replicas,
            serve: ServeConfig {
                max_batch_seqs: 1,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
            dispatch_threads,
            ..Default::default()
        },
    )
    .unwrap();
    let receivers: Vec<_> = request_stream(cfg)
        .into_iter()
        .map(|seq| cluster.submit(seq).unwrap())
        .collect();
    let responses: Vec<(u32, u64)> = receivers
        .iter()
        .map(|rx| {
            let r = rx.recv_timeout(Duration::from_secs(300)).expect("response");
            (r.next_token, r.mean_nll.to_bits())
        })
        .collect();
    (responses, cluster.shutdown())
}

#[test]
fn n_replicas_bit_identical_to_single_replica() {
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let cfg = serving_cfg();
    let weights = std::env::temp_dir().join("mxmoe_cluster_test.mxt");
    let lm = MoeLm::random(&cfg, &mut Rng::new(0xC1_05));
    save_model_mxt(&lm, &weights).unwrap();

    let (reference, ref_report) = serve_stream(&cfg, &weights, &artifacts, 1, None);
    assert_eq!(ref_report.replicas.len(), 1);
    assert_eq!(ref_report.total_requests(), reference.len());

    // N ∈ {2, 4} × differing grouped-dispatch thread counts: responses
    // must match the single replica bit for bit
    for (n, threads) in [(2usize, Some(1usize)), (2, Some(3)), (4, Some(2))] {
        let (out, report) = serve_stream(&cfg, &weights, &artifacts, n, threads);
        assert_eq!(
            out, reference,
            "{n}-replica (threads {threads:?}) responses diverged from single-replica"
        );
        // accounting: every batch routed once, executed exactly once
        assert_eq!(report.replicas.len(), n);
        assert_eq!(report.router.routed.len(), n);
        assert_eq!(report.router.routed.iter().sum::<usize>(), report.router.batches);
        let executed: usize = report.replicas.iter().map(|r| r.executed_batches).sum();
        assert_eq!(executed, report.router.batches, "batches lost or duplicated");
        assert_eq!(report.total_requests(), reference.len());
        assert_eq!(report.total_tokens(), ref_report.total_tokens());
        // every replica served the same boot generation (no online loop)
        assert!(report.replicas.iter().all(|r| r.generation == 0));
        let flat = report.flatten();
        assert_eq!(flat.replicas, n);
        assert_eq!(flat.requests, reference.len());
        assert!(flat.throughput_tps > 0.0);
    }
    let _ = std::fs::remove_file(&weights);
}
