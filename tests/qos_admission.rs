//! Integration: the QoS-aware serving front door (DESIGN.md §Serving-API).
//!
//! Exercises the typed request surface against a real 1-replica cluster:
//! bounded admission load-sheds under synthetic overload (rejections
//! accounted), tickets cancelled mid-queue never execute (and the
//! accounting ties out: `admitted == responses + cancelled`), priority
//! orders the cut under backlog, and the legacy `submit` shim stays
//! bit-identical to the typed path.

use std::path::PathBuf;
use std::time::Duration;

use mxmoe::coordinator::{Cluster, ClusterConfig, ServeConfig};
use mxmoe::harness::{mixed_runtime_plan, require_artifacts, save_model_mxt};
use mxmoe::moe::{ModelConfig, MoeLm};
use mxmoe::serve::{Admission, AdmissionConfig, Priority, QosClass, RejectReason, ServeRequest};
use mxmoe::util::Rng;

#[test]
fn class_quota_reserves_queue_room_for_interactive_traffic() {
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (cfg, weights) = boot_weights("quota");
    // 4 slots, half reserved: a Low flood stops at 2 queued, yet a High
    // burst right behind it still finds the reserved room
    let cluster = start_cluster(
        &cfg,
        &weights,
        &artifacts,
        AdmissionConfig { max_queued_seqs: 4, privileged_reserve: 0.5, ..Default::default() },
    );
    let mut rng = Rng::new(0x0F41);
    let mut tickets = Vec::new();
    let mut quota_rejected = 0usize;
    let mut other_rejected = 0usize;
    for _ in 0..12 {
        match cluster
            .try_submit(ServeRequest::new(seq(&cfg, &mut rng, 16)).priority(Priority::Low))
            .unwrap()
        {
            Admission::Admitted(t) => tickets.push(t),
            Admission::Rejected { reason: RejectReason::ClassQuota, .. } => quota_rejected += 1,
            Admission::Rejected { .. } => other_rejected += 1,
        }
    }
    assert!(
        quota_rejected >= 1,
        "a Low flood against a half-reserved 4-deep bound must hit the quota"
    );
    // privileged traffic (High / Interactive) can still be admitted into
    // the reserved share the flood could not touch
    let mut privileged_admitted = 0usize;
    for privileged in [
        ServeRequest::new(seq(&cfg, &mut rng, 16)).priority(Priority::High),
        ServeRequest::new(seq(&cfg, &mut rng, 16)).qos(QosClass::Interactive),
    ] {
        if let Admission::Admitted(t) = cluster.try_submit(privileged).unwrap() {
            privileged_admitted += 1;
            tickets.push(t);
        }
    }
    assert!(
        privileged_admitted >= 1,
        "reserved slots must admit High/Interactive even after a Low flood \
         (the queue drains concurrently, so at least one must fit)"
    );
    for t in &tickets {
        t.wait_timeout(Duration::from_secs(300)).expect("admitted ⇒ served");
    }
    let report = cluster.shutdown();
    assert_eq!(report.admission.rejected_quota, quota_rejected);
    assert_eq!(report.admission.rejected_queue_full, other_rejected);
    assert_eq!(report.admission.admitted, tickets.len());
    assert_eq!(report.flatten().rejected_quota, quota_rejected, "quota surfaces in the report");
    let _ = std::fs::remove_file(&weights);
}

/// Serving-shape model (hidden=128, inter=64 — what the AOT export ships).
fn serving_cfg() -> ModelConfig {
    ModelConfig {
        name: "qos-test".into(),
        vocab: 64,
        hidden: 128,
        layers: 2,
        heads: 4,
        n_experts: 4,
        n_shared: 1,
        topk: 2,
        inter: 64,
        dense_first: false,
        seq_len: 16,
    }
}

fn boot_weights(name: &str) -> (ModelConfig, PathBuf) {
    let cfg = serving_cfg();
    let weights = std::env::temp_dir().join(format!("mxmoe_qos_{name}.mxt"));
    let lm = MoeLm::random(&cfg, &mut Rng::new(0x0A05));
    save_model_mxt(&lm, &weights).unwrap();
    (cfg, weights)
}

fn seq(cfg: &ModelConfig, rng: &mut Rng, len: usize) -> Vec<u32> {
    (0..len).map(|_| rng.below(cfg.vocab as u64) as u32).collect()
}

/// One-request-per-batch cluster with the given admission policy.
fn start_cluster(
    cfg: &ModelConfig,
    weights: &PathBuf,
    artifacts: &PathBuf,
    admission: AdmissionConfig,
) -> Cluster {
    Cluster::start(
        cfg.clone(),
        weights.clone(),
        artifacts.clone(),
        mixed_runtime_plan(cfg),
        ClusterConfig {
            serve: ServeConfig {
                max_batch_seqs: 1,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
            admission,
            ..Default::default()
        },
    )
    .unwrap()
}

#[test]
fn admission_rejects_under_synthetic_overload() {
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (cfg, weights) = boot_weights("overload");
    // bound the queue at 2 sequences: a burst of 16 must shed most of it
    let cluster = start_cluster(
        &cfg,
        &weights,
        &artifacts,
        AdmissionConfig { max_queued_seqs: 2, ..Default::default() },
    );
    let mut rng = Rng::new(0x0BEE);
    let mut tickets = Vec::new();
    let mut rejected = 0usize;
    for _ in 0..16 {
        match cluster.try_submit(ServeRequest::new(seq(&cfg, &mut rng, 16))).unwrap() {
            Admission::Admitted(t) => tickets.push(t),
            Admission::Rejected { id, reason, retry_after } => {
                assert_eq!(reason, RejectReason::QueueFull);
                assert!(retry_after > Duration::ZERO, "retry_after must be actionable");
                assert!(id > 0, "rejections carry an attributable request id");
                rejected += 1;
            }
        }
    }
    assert!(
        rejected >= 1,
        "a 16-request burst against a 2-deep bound must shed something"
    );
    assert_eq!(tickets.len() + rejected, 16);
    // every admitted ticket gets a response; polling flips from None to
    // Some as they land
    let mut responses = 0usize;
    for t in &tickets {
        let r = t.wait_timeout(Duration::from_secs(300)).expect("admitted ⇒ served");
        assert!(r.mean_nll.is_finite());
        responses += 1;
        assert!(t.poll().is_none(), "single response per ticket");
    }
    let report = cluster.shutdown();
    assert_eq!(report.admission.admitted, tickets.len());
    assert_eq!(report.admission.rejected_queue_full, rejected);
    assert_eq!(report.admission.rejected_deadline, 0);
    assert_eq!(report.admission.cancelled, 0);
    assert_eq!(report.total_requests(), responses, "rejections never executed");
    let flat = report.flatten();
    assert_eq!(flat.rejected_queue_full, rejected, "rejections surface in ServerReport");
    let _ = std::fs::remove_file(&weights);
}

#[test]
fn cancelled_tickets_never_yield_responses_and_accounting_ties_out() {
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (cfg, weights) = boot_weights("cancel");
    let cluster = start_cluster(&cfg, &weights, &artifacts, AdmissionConfig::default());
    let mut rng = Rng::new(0x0DEAD);
    // enough work that the tail is still queued when the cancels land
    let tickets: Vec<_> = (0..8)
        .map(|_| cluster.submit_request(ServeRequest::new(seq(&cfg, &mut rng, 16))).unwrap())
        .collect();
    // cancel every other ticket while the first batch is still executing
    let mut cancelled_ids = Vec::new();
    for t in tickets.iter().skip(1).step_by(2) {
        t.cancel();
        cancelled_ids.push(t.id());
    }
    let mut responses = 0usize;
    for (i, t) in tickets.iter().enumerate() {
        if cancelled_ids.contains(&t.id()) {
            assert!(t.is_cancelled());
            assert!(t.poll().is_none(), "cancelled ticket {i} must never yield a response");
            assert!(t.wait_timeout(Duration::from_millis(10)).is_err());
        } else {
            t.wait_timeout(Duration::from_secs(300)).expect("live ticket served");
            responses += 1;
        }
    }
    let report = cluster.shutdown();
    // the invariant the redesign guarantees: every admitted request either
    // produced exactly one response or was counted cancelled/failed —
    // whether it was shed at the cut, shed at a replica pop, or
    // suppressed at reply
    assert_eq!(report.admission.admitted, 8);
    assert_eq!(
        report.total_requests() + report.admission.unserved(),
        report.admission.admitted,
        "admitted must equal responses + cancelled + failed"
    );
    assert_eq!(report.admission.failed, 0, "no engine errors expected here");
    // cancels land while the first batch executes, so the backlog sheds —
    // but a cancel can in principle race a very fast reply (the ticket
    // still never yields it), so bound rather than pin the exact count
    assert!(
        report.admission.cancelled >= 1 && report.admission.cancelled <= cancelled_ids.len(),
        "cancelled count out of range: {}",
        report.admission.cancelled
    );
    // live tickets all got responses; any response sent to a
    // cancelled-too-late ticket is suppressed at the API, never surfaced
    assert!(report.total_requests() >= responses);
    // shed work is visible in the router/replica counters too
    let shed_at_cut = report.router.shed_cancelled;
    let shed_at_replica: usize = report.replicas.iter().map(|r| r.shed_cancelled).sum();
    assert!(
        shed_at_cut + shed_at_replica <= cancelled_ids.len(),
        "shed counters only count work dropped before execution"
    );
    let _ = std::fs::remove_file(&weights);
}

#[test]
fn high_priority_overtakes_queued_low_priority() {
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (cfg, weights) = boot_weights("priority");
    let cluster = start_cluster(&cfg, &weights, &artifacts, AdmissionConfig::default());
    let mut rng = Rng::new(0x0CAFE);
    // flood with Low, then drop one High on the backlog: the High request
    // must cut ahead of the still-queued Lows
    let lows: Vec<_> = (0..6)
        .map(|_| {
            cluster
                .submit_request(
                    ServeRequest::new(seq(&cfg, &mut rng, 16)).priority(Priority::Low),
                )
                .unwrap()
        })
        .collect();
    let high = cluster
        .submit_request(
            ServeRequest::new(seq(&cfg, &mut rng, 16))
                .priority(Priority::High)
                .qos(QosClass::Interactive),
        )
        .unwrap();
    let high_resp = high.wait_timeout(Duration::from_secs(300)).unwrap();
    let low_waits: Vec<Duration> = lows
        .iter()
        .map(|t| t.wait_timeout(Duration::from_secs(300)).unwrap().queue_wait)
        .collect();
    let max_low = low_waits.iter().max().unwrap();
    assert!(
        high_resp.queue_wait < *max_low,
        "High arrived last but must not wait out the whole Low backlog \
         (high {:?} vs worst low {:?})",
        high_resp.queue_wait,
        max_low
    );
    let report = cluster.shutdown();
    // per-priority queue-wait percentiles are split out in the report
    let p99 = report.queue_wait_p99_by_priority();
    assert!(p99[Priority::Low.index()] > 0.0, "Low samples recorded");
    assert!(p99[Priority::High.index()] > 0.0, "High samples recorded");
    // the Interactive QoS tag reached the replica's served-mix counters
    let flat = report.flatten();
    assert_eq!(flat.qos_served[QosClass::Interactive.index()], 1);
    assert_eq!(flat.qos_served[QosClass::Standard.index()], 6, "untagged counts as Standard");
    let _ = std::fs::remove_file(&weights);
}

#[test]
fn legacy_submit_shim_is_bit_identical_to_typed_path() {
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (cfg, weights) = boot_weights("shim");
    let stream: Vec<Vec<u32>> = {
        let mut rng = Rng::new(0x51313);
        [16usize, 5, 11, 16, 2, 9].iter().map(|&n| seq(&cfg, &mut rng, n)).collect()
    };
    // run 1: legacy untyped submit
    let cluster = start_cluster(&cfg, &weights, &artifacts, AdmissionConfig::default());
    let receivers: Vec<_> = stream.iter().map(|s| cluster.submit(s.clone()).unwrap()).collect();
    let legacy: Vec<(u32, u64)> = receivers
        .iter()
        .map(|rx| {
            let r = rx.recv_timeout(Duration::from_secs(300)).expect("legacy response");
            (r.next_token, r.mean_nll.to_bits())
        })
        .collect();
    cluster.shutdown();
    // run 2: typed path with the shim's defaults
    let cluster = start_cluster(&cfg, &weights, &artifacts, AdmissionConfig::default());
    let tickets: Vec<_> = stream
        .iter()
        .map(|s| cluster.submit_request(ServeRequest::new(s.clone())).unwrap())
        .collect();
    let typed: Vec<(u32, u64)> = tickets
        .iter()
        .map(|t| {
            let r = t.wait_timeout(Duration::from_secs(300)).expect("typed response");
            (r.next_token, r.mean_nll.to_bits())
        })
        .collect();
    let report = cluster.shutdown();
    assert_eq!(legacy, typed, "legacy shim must be bit-identical to the typed path");
    assert_eq!(report.admission.admitted, stream.len());
    let _ = std::fs::remove_file(&weights);
}
