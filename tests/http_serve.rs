//! Integration: the HTTP front door's wire rigor (DESIGN.md
//! §HTTP-Front-Door) from outside the crate — the malformed-HTTP and
//! malformed-body catalogs, the JSON escape/parse inverse pair on every
//! hostile string class, and the RejectReason → 429/503 + `Retry-After`
//! mapping. Everything here runs against an always-rejecting stub
//! backend (no engine needed); the final test drives a real mini-model
//! cluster end to end and self-skips without the AOT artifacts.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use mxmoe::coordinator::ServerReport;
use mxmoe::ser::json::Json;
use mxmoe::ser::jsonwire;
use mxmoe::serve::{Admission, HttpBackend, HttpConfig, HttpServer, RejectReason, ServeRequest};

// ---------------------------------------------------------------------------
// Stub backend: every submission is shed with the next scripted reason
// ---------------------------------------------------------------------------

struct RejectingBackend {
    reasons: Mutex<VecDeque<RejectReason>>,
}

impl RejectingBackend {
    fn server(reasons: Vec<RejectReason>) -> HttpServer {
        let backend = Arc::new(RejectingBackend { reasons: Mutex::new(reasons.into()) });
        HttpServer::start(backend, HttpConfig::default()).unwrap()
    }
}

impl HttpBackend for RejectingBackend {
    fn try_submit(&self, _req: ServeRequest) -> anyhow::Result<Admission> {
        let reason = self
            .reasons
            .lock()
            .unwrap()
            .pop_front()
            .expect("request reached the backend unexpectedly");
        Ok(Admission::Rejected { id: 7, reason, retry_after: Duration::from_millis(2500) })
    }

    fn live_report(&self) -> ServerReport {
        ServerReport::default()
    }

    fn replicas(&self) -> usize {
        0
    }
}

// ---------------------------------------------------------------------------
// Tiny raw client
// ---------------------------------------------------------------------------

fn raw(addr: SocketAddr, bytes: &[u8]) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    s.write_all(bytes).unwrap();
    let mut out = Vec::new();
    s.read_to_end(&mut out).unwrap();
    String::from_utf8_lossy(&out).to_string()
}

fn post(addr: SocketAddr, path: &str, body: &str) -> String {
    raw(
        addr,
        format!(
            "POST {path} HTTP/1.1\r\nhost: t\r\ncontent-length: {}\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    )
}

fn status_of(reply: &str) -> u16 {
    reply
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("no status in {reply:?}"))
}

fn header<'a>(reply: &'a str, name: &str) -> Option<&'a str> {
    reply
        .split("\r\n\r\n")
        .next()?
        .lines()
        .skip(1)
        .filter_map(|l| l.split_once(':'))
        .find(|(k, _)| k.eq_ignore_ascii_case(name))
        .map(|(_, v)| v.trim())
}

fn body_of(reply: &str) -> &str {
    reply.split("\r\n\r\n").nth(1).unwrap_or("")
}

// ---------------------------------------------------------------------------
// Malformed-HTTP catalog: nothing here may reach the backend
// ---------------------------------------------------------------------------

#[test]
fn malformed_http_catalog() {
    let server = RejectingBackend::server(vec![]);
    let addr = server.addr();
    let catalog: Vec<(&str, String, u16)> = vec![
        ("garbage request line", "GARBAGE\r\n\r\n".into(), 400),
        ("too many request-line parts", "POST /v1/score HTTP/1.1 extra\r\n\r\n".into(), 400),
        ("path without leading slash", "POST v1/score HTTP/1.1\r\n\r\n".into(), 400),
        ("unsupported protocol", "POST /v1/score SPDY/3\r\n\r\n".into(), 400),
        ("header without colon", "POST /v1/score HTTP/1.1\r\nbadheader\r\n\r\n".into(), 400),
        (
            "header name with space",
            "POST /v1/score HTTP/1.1\r\nbad name: x\r\ncontent-length: 2\r\n\r\n{}".into(),
            400,
        ),
        ("post without content-length", "POST /v1/score HTTP/1.1\r\nhost: t\r\n\r\n".into(), 411),
        (
            "unparseable content-length",
            "POST /v1/score HTTP/1.1\r\ncontent-length: banana\r\n\r\n".into(),
            400,
        ),
        (
            "chunked transfer-encoding",
            "POST /v1/score HTTP/1.1\r\ntransfer-encoding: chunked\r\ncontent-length: 2\r\n\r\n{}"
                .into(),
            400,
        ),
        (
            "oversized declared body",
            format!("POST /v1/score HTTP/1.1\r\ncontent-length: {}\r\n\r\n", 1 << 30),
            413,
        ),
        (
            "truncated body",
            "POST /v1/score HTTP/1.1\r\ncontent-length: 100\r\n\r\n{\"tokens\":[1]}".into(),
            400,
        ),
        (
            "request line over the bound",
            format!("POST /{} HTTP/1.1\r\n\r\n", "a".repeat(10_000)),
            400,
        ),
        (
            "too many headers",
            format!(
                "POST /v1/score HTTP/1.1\r\n{}content-length: 2\r\n\r\n{{}}",
                "x-h: v\r\n".repeat(100)
            ),
            400,
        ),
    ];
    for (name, req, want) in catalog {
        let reply = raw(addr, req.as_bytes());
        assert_eq!(status_of(&reply), want, "case '{name}': {reply}");
    }
    // routing errors, same guarantee
    let reply = raw(addr, "GET /nope HTTP/1.1\r\nhost: t\r\n\r\n");
    assert_eq!(status_of(&reply), 404);
    let reply = raw(addr, "GET /v1/score HTTP/1.1\r\nhost: t\r\n\r\n");
    assert_eq!(status_of(&reply), 405, "wrong method is 405: {reply}");
    assert_eq!(header(&reply, "allow"), Some("POST"), "405 carries Allow");
    let reply = post(addr, "/v1/cancel/notanumber", "{}");
    assert_eq!(status_of(&reply), 400);
    let reply = post(addr, "/v1/cancel/12345", "{}");
    assert_eq!(status_of(&reply), 404, "unknown id is 404");
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Malformed-body catalog: parsed strictly, still never reaches the backend
// ---------------------------------------------------------------------------

#[test]
fn malformed_body_catalog() {
    let server = RejectingBackend::server(vec![]);
    let addr = server.addr();
    let catalog: Vec<(&str, &str, String)> = vec![
        ("not json", "/v1/score", "tokens=1,2,3".into()),
        ("json array body", "/v1/score", "[1,2,3]".into()),
        ("unknown field", "/v1/score", r#"{"tokens":[1],"temperature":0.7}"#.into()),
        ("missing tokens", "/v1/score", r#"{"priority":"high"}"#.into()),
        ("empty tokens", "/v1/score", r#"{"tokens":[]}"#.into()),
        ("tokens not an array", "/v1/score", r#"{"tokens":"abc"}"#.into()),
        ("fractional token id", "/v1/score", r#"{"tokens":[1.5]}"#.into()),
        ("negative token id", "/v1/score", r#"{"tokens":[-1]}"#.into()),
        ("token above u32", "/v1/score", r#"{"tokens":[4294967296]}"#.into()),
        ("unknown priority", "/v1/score", r#"{"tokens":[1],"priority":"urgent"}"#.into()),
        ("ill-typed qos", "/v1/score", r#"{"tokens":[1],"qos":3}"#.into()),
        ("zero deadline", "/v1/score", r#"{"tokens":[1],"deadline_ms":0}"#.into()),
        ("generate without max_new", "/v1/generate", r#"{"tokens":[1]}"#.into()),
        ("zero max_new", "/v1/generate", r#"{"tokens":[1],"max_new_tokens":0}"#.into()),
        ("stop not array", "/v1/generate", r#"{"tokens":[1],"max_new_tokens":2,"stop":5}"#.into()),
        ("score with generate field", "/v1/score", r#"{"tokens":[1],"max_new_tokens":4}"#.into()),
        ("lone high surrogate escape", "/v1/score", r#"{"tokens":[1],"qos":"\ud83d"}"#.into()),
        ("lone low surrogate escape", "/v1/score", r#"{"tokens":[1],"qos":"\udca9"}"#.into()),
        ("truncated unicode escape", "/v1/score", r#"{"tokens":[1],"qos":"\u12"}"#.into()),
        ("raw control char in string", "/v1/score", "{\"tokens\":[1],\"qos\":\"\u{1}\"}".into()),
        (
            "nesting bomb",
            "/v1/score",
            format!("{}1{}", "[".repeat(100_000), "]".repeat(100_000)),
        ),
        ("invalid utf-8", "/v1/score", String::from_utf8_lossy(b"{\"tokens\":[1]}").into_owned()),
    ];
    for (name, path, body) in &catalog {
        // the invalid-utf-8 case needs raw bytes
        let reply = if *name == "invalid utf-8" {
            let bytes = b"{\"tokens\":[\xff\xfe]}";
            raw(
                addr,
                format!("POST {path} HTTP/1.1\r\ncontent-length: {}\r\n\r\n", bytes.len())
                    .into_bytes()
                    .into_iter()
                    .chain(bytes.iter().copied())
                    .collect::<Vec<u8>>()
                    .as_slice(),
            )
        } else {
            post(addr, path, body)
        };
        assert_eq!(status_of(&reply), 400, "case '{name}': {reply}");
        assert!(
            Json::parse(body_of(&reply)).is_ok(),
            "error body must itself be valid JSON: {reply}"
        );
    }
    server.shutdown();
}

// ---------------------------------------------------------------------------
// RejectReason → HTTP status + Retry-After
// ---------------------------------------------------------------------------

#[test]
fn reject_mapping_and_retry_after() {
    let server = RejectingBackend::server(vec![
        RejectReason::QueueFull,
        RejectReason::DeadlineUnmeetable,
        RejectReason::ClassQuota,
        RejectReason::KvExhausted,
    ]);
    let addr = server.addr();
    let cases = [
        ("queue-full", 429u16),
        ("deadline-unmeetable", 429),
        ("class-quota", 429),
        ("kv-exhausted", 503),
    ];
    for (want_reason, want_status) in cases {
        let reply = post(addr, "/v1/score", r#"{"tokens":[1,2]}"#);
        assert_eq!(status_of(&reply), want_status, "{want_reason}: {reply}");
        // 2500ms rounds up to a whole-second Retry-After
        assert_eq!(header(&reply, "retry-after"), Some("3"), "{want_reason}: {reply}");
        let j = Json::parse(body_of(&reply)).unwrap();
        assert_eq!(j.req_str("error").unwrap(), "rejected");
        assert_eq!(j.req_str("reason").unwrap(), want_reason);
        assert_eq!(j.req_usize("retry_after_ms").unwrap(), 2500);
        assert_eq!(j.req_usize("id").unwrap(), 7);
    }
    server.shutdown();
}

#[test]
fn status_and_debug_work_without_an_engine() {
    let server = RejectingBackend::server(vec![]);
    let addr = server.addr();
    // the stub keeps the trait's default observatory()/provenance()
    // (both None): the pages must degrade, not 500
    let reply = raw(addr, "GET /v1/status HTTP/1.1\r\nhost: t\r\n\r\n");
    assert_eq!(status_of(&reply), 200, "{reply}");
    let j = Json::parse(body_of(&reply)).unwrap();
    assert_eq!(j.req_str("version").unwrap(), "mxmoe-status-v1");
    assert!(j.get("report").is_some(), "live counters must always be present");
    assert_eq!(j.get("series").and_then(Json::as_arr).unwrap().len(), 0);
    assert_eq!(j.get("plans").and_then(Json::as_arr).unwrap().len(), 0);
    let reply = raw(addr, "GET /debug HTTP/1.1\r\nhost: t\r\n\r\n");
    assert_eq!(status_of(&reply), 200, "{reply}");
    let body = body_of(&reply);
    assert!(body.starts_with("<!doctype html>"), "{body}");
    assert!(!body.contains("http://") && !body.contains("https://"), "self-contained");
    assert!(!body.contains("<script"), "no scripts");
    server.shutdown();
}

#[test]
fn healthz_and_metrics_work_without_an_engine() {
    let server = RejectingBackend::server(vec![]);
    let addr = server.addr();
    let reply = raw(addr, "GET /healthz HTTP/1.1\r\nhost: t\r\n\r\n");
    assert_eq!(status_of(&reply), 200);
    let reply = raw(addr, "GET /metrics HTTP/1.1\r\nhost: t\r\n\r\n");
    assert_eq!(status_of(&reply), 200);
    for metric in [
        "mxmoe_http_connections_total",
        "mxmoe_http_disconnects_total",
        "mxmoe_http_sse_events_total",
        "mxmoe_http_peak_connections",
        "mxmoe_rejected_total",
    ] {
        assert!(body_of(&reply).contains(metric), "metrics must export {metric}");
    }
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Escape path properties: jsonwire::escape must be the exact inverse of
// the strict parser, for every hostile string class
// ---------------------------------------------------------------------------

fn roundtrips(s: &str) {
    let quoted = format!("\"{}\"", jsonwire::escape(s));
    assert!(quoted.is_ascii(), "escaped form must be pure ASCII: {quoted:?}");
    assert!(
        !quoted.bytes().any(|b| b < 0x20),
        "escaped form may not contain raw control bytes: {quoted:?}"
    );
    match Json::parse(&quoted) {
        Ok(Json::Str(back)) => assert_eq!(back, s, "escape/parse must be inverse for {s:?}"),
        other => panic!("parse of {quoted:?} gave {other:?}"),
    }
}

#[test]
fn escape_every_control_char() {
    for b in 0u8..0x20 {
        roundtrips(&format!("a{}b", b as char));
    }
    roundtrips("\u{7f}"); // DEL survives too
}

#[test]
fn escape_quotes_backslashes_and_separators() {
    roundtrips(r#"quote " backslash \ slash / done"#);
    roundtrips("line\nfeed\rreturn\ttab");
    // U+2028/U+2029 are legal raw in JSON but must still round-trip
    roundtrips("para\u{2028}sep\u{2029}end");
}

#[test]
fn escape_astral_and_bmp_unicode() {
    roundtrips("caf\u{e9} na\u{ef}ve");
    roundtrips("\u{1F600}\u{1F680}"); // astral: must emit surrogate pairs
    roundtrips("\u{FFFD}\u{FFFF}"); // BMP edge
    roundtrips("mixed \u{1F410} ascii \u{430}\u{431} end");
    // boundary of the astral plane
    roundtrips("\u{FFFF}\u{10000}\u{10FFFF}");
}

#[test]
fn parser_rejects_lone_surrogates_writer_never_emits_them() {
    assert!(Json::parse(r#""\ud800""#).is_err(), "lone high surrogate");
    assert!(Json::parse(r#""\udfff""#).is_err(), "lone low surrogate");
    assert!(Json::parse(r#""\ud800\ud800""#).is_err(), "high followed by high");
    assert!(Json::parse(r#""\ud83dx""#).is_err(), "high then garbage");
    // a correct pair parses to the astral char, and re-escaping it gives
    // back a pair (not a lone unit)
    match Json::parse(r#""😀""#) {
        Ok(Json::Str(s)) => {
            assert_eq!(s, "\u{1F600}");
            let re = jsonwire::escape(&s);
            assert_eq!(re, "\\ud83d\\ude00");
        }
        other => panic!("surrogate pair should parse, got {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Real cluster end to end (self-skips without AOT artifacts)
// ---------------------------------------------------------------------------

#[test]
fn real_cluster_http_roundtrip() {
    use mxmoe::coordinator::{Cluster, ClusterConfig, ServeConfig};
    use mxmoe::harness::{self, mixed_runtime_plan, save_model_mxt, MINI_MODEL_SEED};
    use mxmoe::moe::{ModelConfig, MoeLm};
    use mxmoe::obs::SampleConfig;
    use mxmoe::util::Rng;

    let Some(artifacts) = harness::require_artifacts() else {
        eprintln!("skipping real_cluster_http_roundtrip: artifacts not built");
        return;
    };
    let cfg = ModelConfig::by_name("ci-mini").unwrap();
    let lm = MoeLm::random(&cfg, &mut Rng::new(MINI_MODEL_SEED));
    let weights = std::env::temp_dir().join("mxmoe_http_serve_test.mxt");
    save_model_mxt(&lm, &weights).unwrap();
    drop(lm);
    let cluster = Arc::new(
        Cluster::start(
            cfg.clone(),
            weights,
            artifacts,
            mixed_runtime_plan(&cfg),
            ClusterConfig {
                replicas: 1,
                serve: ServeConfig {
                    max_batch_seqs: 4,
                    max_wait: Duration::from_millis(2),
                    ..Default::default()
                },
                // sampler on, so /v1/status and /debug carry real series
                sample: SampleConfig { enabled: true, interval_ms: 5, ..Default::default() },
                ..Default::default()
            },
        )
        .unwrap(),
    );
    let server = HttpServer::start(cluster.clone(), HttpConfig::default()).unwrap();
    let addr = server.addr();

    let reply = post(addr, "/v1/score", r#"{"tokens":[3,1,4,1,5],"qos":"interactive"}"#);
    assert_eq!(status_of(&reply), 200, "{reply}");
    let j = Json::parse(body_of(&reply)).unwrap();
    assert!(j.req_usize("id").unwrap() >= 1);
    j.req_usize("next_token").unwrap();
    j.req_f64("mean_nll").unwrap();

    let reply = post(addr, "/v1/generate", r#"{"tokens":[2,7,1],"max_new_tokens":4}"#);
    assert_eq!(status_of(&reply), 200, "{reply}");
    let frames: Vec<&str> = body_of(&reply).split("\n\n").filter(|f| !f.is_empty()).collect();
    assert!(frames.len() >= 3, "start + tokens + done: {frames:?}");
    assert!(frames[0].starts_with("event: start"));
    assert!(frames.last().unwrap().starts_with("event: done"), "{frames:?}");

    // with the sampler on, both observability pages carry recorded state:
    // series with points, the boot plan, and inline SVG sparklines
    std::thread::sleep(Duration::from_millis(15));
    let reply = raw(addr, "GET /v1/status HTTP/1.1\r\nhost: t\r\n\r\n");
    assert_eq!(status_of(&reply), 200, "{reply}");
    let j = Json::parse(body_of(&reply)).unwrap();
    assert_eq!(j.req_str("version").unwrap(), "mxmoe-status-v1");
    let series = j.get("series").and_then(Json::as_arr).unwrap();
    assert!(!series.is_empty(), "sampled cluster must report series");
    assert!(
        series.iter().any(|s| s.req_str("name").map(|n| n == "queue_depth").unwrap_or(false)),
        "queue_depth series must be present"
    );
    let plans = j.get("plans").and_then(Json::as_arr).unwrap();
    assert!(!plans.is_empty(), "boot plan must be in the provenance block");
    let reply = raw(addr, "GET /debug HTTP/1.1\r\nhost: t\r\n\r\n");
    assert_eq!(status_of(&reply), 200, "{reply}");
    let body = body_of(&reply);
    assert!(body.starts_with("<!doctype html>"), "{body}");
    assert!(body.contains("<svg"), "sampled series must render sparklines");
    assert!(!body.contains("http://") && !body.contains("https://"), "self-contained");

    server.shutdown();
    let cluster = Arc::try_unwrap(cluster).ok().expect("backend still referenced");
    let report = cluster.shutdown();
    let a = &report.admission;
    assert_eq!(
        a.admitted,
        report.total_requests() + a.cancelled + a.failed,
        "HTTP round-trips must keep the ledger exact"
    );
}
