//! Integration: the fleet observatory end to end — ring-bounded series
//! semantics (eviction, counter wraparound, point-in-time queries), the
//! sampler thread's start/stop lifecycle, and — artifact-gated — a
//! sampled cluster run answering "what was queue depth / KV occupancy at
//! time T?" and "why does expert (l, e) run at its scheme?" purely from
//! recorded data, plus the determinism anchor: a deterministic scenario's
//! ledger is bit-identical with the sampler on and off.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use mxmoe::coordinator::{Cluster, ClusterConfig, ServeConfig};
use mxmoe::harness::scenario::{run_scenario, validate_bench_json, RunOptions, ScenarioSpec};
use mxmoe::harness::{mixed_runtime_plan, require_artifacts, save_model_mxt};
use mxmoe::moe::{ModelConfig, MoeLm};
use mxmoe::obs::{Observatory, SampleConfig, Sampler};
use mxmoe::util::Rng;

// ---- series core (no artifacts needed) ---------------------------------

#[test]
fn ring_bounds_series_and_evicts_oldest() {
    let obs = Observatory::new(4);
    for i in 0..10 {
        obs.gauge("depth", i as f64, (i * 10) as f64);
    }
    let pts = obs.points("depth");
    assert_eq!(pts.len(), 4, "ring must retain exactly `capacity` points");
    assert_eq!(obs.pushed("depth"), 10, "evictions are counted, not silent");
    let times: Vec<f64> = pts.iter().map(|p| p.t_s).collect();
    assert_eq!(times, vec![6.0, 7.0, 8.0, 9.0], "oldest points must go first, order kept");
    assert_eq!(pts[0].v, 60.0);
}

#[test]
fn counter_stores_deltas_and_survives_wraparound() {
    let obs = Observatory::new(16);
    obs.counter("reqs_total", 0.0, 5);
    let rate = obs.counter("reqs_total", 2.0, 12);
    let pts = obs.points("reqs_total");
    assert_eq!(pts[0].v, 5.0, "first sample stores the raw total");
    assert_eq!(pts[1].v, 7.0, "later samples store the delta");
    assert!((rate - 3.5).abs() < 1e-9, "per-second rate over the 2 s interval");
    // a u64 wraparound still yields the true increment
    obs.counter("wrap_total", 0.0, u64::MAX - 1);
    obs.counter("wrap_total", 1.0, 2);
    let pts = obs.points("wrap_total");
    assert_eq!(pts[1].v, 4.0, "wrapping_sub must recover the increment across the wrap");
}

#[test]
fn counter_treats_restart_shrinkage_as_reset_not_wraparound() {
    // a replica respawn zeroes its ReplicaStatus slot, so cluster-summed
    // totals can shrink without wrapping; the series must record a zero
    // delta, not a ~u64::MAX one
    let obs = Observatory::new(16);
    obs.counter("reqs_total", 0.0, 500);
    obs.counter("reqs_total", 1.0, 900);
    obs.counter("reqs_total", 2.0, 450); // one of two replicas respawned
    let rate = obs.counter("reqs_total", 3.0, 520);
    let pts = obs.points("reqs_total");
    assert_eq!(pts[2].v, 0.0, "shrinkage is a reset: zero delta");
    assert_eq!(pts[3].v, 70.0, "deltas resume from the post-reset baseline");
    assert!((rate - 70.0).abs() < 1e-9);
}

#[test]
fn value_at_answers_point_in_time_queries() {
    let obs = Observatory::new(16);
    obs.gauge("depth", 1.0, 3.0);
    obs.gauge("depth", 2.0, 8.0);
    obs.gauge("depth", 3.0, 2.0);
    assert_eq!(obs.value_at("depth", 2.5), Some(8.0), "newest point at-or-before T");
    assert_eq!(obs.value_at("depth", 2.0), Some(8.0), "an exact-time sample counts");
    assert_eq!(obs.value_at("depth", 99.0), Some(2.0));
    assert_eq!(obs.value_at("depth", 0.5), None, "before the first sample");
    assert_eq!(obs.value_at("unknown", 2.0), None);
}

#[test]
fn sampler_lifecycle_ticks_then_stops() {
    let ticks = Arc::new(AtomicU64::new(0));
    let seen = Arc::clone(&ticks);
    let sampler = Sampler::spawn(Duration::from_millis(1), move |t_s| {
        assert!(t_s >= 0.0);
        seen.fetch_add(1, Ordering::SeqCst);
    });
    // the first tick fires immediately; wait for a few more
    while ticks.load(Ordering::SeqCst) < 3 {
        std::thread::sleep(Duration::from_millis(1));
    }
    let reported = sampler.stop();
    let frozen = ticks.load(Ordering::SeqCst);
    assert!(reported >= 3, "sampler must keep ticking until stopped");
    assert_eq!(reported, frozen, "stop() must report exactly the ticks that ran");
    std::thread::sleep(Duration::from_millis(10));
    assert_eq!(ticks.load(Ordering::SeqCst), frozen, "no ticks after stop()");
}

// ---- sampled cluster queries (artifact-gated) --------------------------

/// Serving-shape model (hidden=128, inter=64 — the tile shapes the AOT
/// export ships).
fn observatory_cfg() -> ModelConfig {
    ModelConfig {
        name: "observatory-test".into(),
        vocab: 64,
        hidden: 128,
        layers: 2,
        heads: 4,
        n_experts: 4,
        n_shared: 1,
        topk: 2,
        inter: 64,
        dense_first: false,
        seq_len: 16,
    }
}

#[test]
fn sampled_cluster_answers_time_and_provenance_queries() {
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping sampled-cluster test: artifacts not built");
        return;
    };
    let cfg = observatory_cfg();
    let mut rng = Rng::new(0x0B5E_7A70);
    let lm = MoeLm::random(&cfg, &mut rng);
    let weights = std::env::temp_dir().join("mxmoe_test_observatory.mxt");
    save_model_mxt(&lm, &weights).expect("save weights");

    let cluster = Cluster::start(
        cfg.clone(),
        weights.clone(),
        artifacts,
        mixed_runtime_plan(&cfg),
        ClusterConfig {
            // two replicas: the sampler must sum per-replica wave rows and
            // counters into one total per series, not interleave them
            replicas: 2,
            serve: ServeConfig {
                max_batch_seqs: 2,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
            sample: SampleConfig { enabled: true, interval_ms: 5, ..Default::default() },
            ..Default::default()
        },
    )
    .expect("cluster start");

    let receivers: Vec<_> = (0..12)
        .map(|_| {
            let seq: Vec<u32> =
                (0..cfg.seq_len).map(|_| rng.below(cfg.vocab as u64) as u32).collect();
            cluster.submit(seq).expect("submit")
        })
        .collect();
    for rx in receivers {
        rx.recv_timeout(Duration::from_secs(600)).expect("response");
    }
    // at least two full sampler intervals after the work drained, so the
    // post-drain state is definitely on record
    std::thread::sleep(Duration::from_millis(25));

    // "what was queue depth / shed rate / kv occupancy at time T?" — all
    // answered from recorded data alone
    let obs = cluster.observatory();
    let names = obs.series_names();
    for required in ["queue_depth", "admitted_total", "kv_used_tokens", "rejected_kv_total"] {
        assert!(names.iter().any(|n| n == required), "series '{required}' missing: {names:?}");
    }
    let pts = obs.points("queue_depth");
    assert!(!pts.is_empty(), "sampler must have recorded queue depth");
    let t_last = pts.last().unwrap().t_s;
    assert_eq!(obs.value_at("queue_depth", t_last), Some(pts.last().unwrap().v));
    assert_eq!(obs.value_at("queue_depth", t_last + 60.0), Some(pts.last().unwrap().v));
    assert!(obs.value_at("queue_depth", -1.0).is_none(), "no data before the sampler started");
    assert!(obs.value_at("kv_used_tokens", t_last).is_some());
    assert!(obs.value_at("rejected_queue_full_total", t_last).is_some());
    let snap = obs.snapshot();
    let admitted = snap.series.iter().find(|s| s.name == "admitted_total").unwrap();
    assert_eq!(admitted.total, 12, "counter raw total must match the requests admitted");
    assert!(
        snap.histograms.iter().any(|h| h.name == "queue_depth_hist" && h.count > 0),
        "queue-depth histogram must have observations"
    );
    // with >1 replica, interleaving per-replica totals into one series
    // would wrap into ~1.8e19 deltas; every recorded delta must stay sane
    for s in &snap.series {
        for p in &s.points {
            assert!(
                p.v.is_finite() && p.v >= 0.0 && p.v < 1e15,
                "series '{}' holds a garbage delta {} — per-replica totals \
                 must be summed before sampling",
                s.name,
                p.v
            );
        }
    }

    // "why does expert (l, e) run at its scheme?" — from the ledger alone
    let ledger = cluster.provenance();
    let rec = ledger.latest().expect("boot plan must be recorded");
    assert_eq!(rec.generation, 0, "first record is the boot plan");
    assert!(!rec.decisions.is_empty(), "boot plan must carry per-slot decisions");
    let d = &rec.decisions[0];
    let why = ledger.explain(d.layer, d.expert).expect("slot must be explainable");
    assert_eq!(why.decision.scheme, d.scheme);
    let text = why.describe();
    assert!(
        text.contains(d.scheme.name()) && text.contains("boot"),
        "explanation must name the scheme and the trigger: {text}"
    );
    assert!(ledger.explain(usize::MAX, usize::MAX).is_none());

    cluster.shutdown();
    let _ = std::fs::remove_file(&weights);
}

// ---- sampler determinism (artifact-gated) ------------------------------

fn tiny_deterministic_spec() -> ScenarioSpec {
    ScenarioSpec::parse(
        r#"{
          "schema": "mxmoe-scenario-v1",
          "name": "observatory_anchor",
          "description": "sampler on/off determinism anchor",
          "seed": 4242,
          "ticks": 6,
          "replicas": 1,
          "deterministic": true,
          "arrival": {"curve": "constant", "rate": 2.0},
          "mix": [{"from_tick": 0, "interactive": 0.5, "standard": 0.3, "batch": 0.2}],
          "prompt_tokens": {"min": 4, "max": 12},
          "generate_fraction": 0.25,
          "max_new_tokens": 4,
          "admission": {"max_queued_seqs": 16, "max_queued_tokens": 4096,
                        "privileged_reserve": 0.0, "auto_reserve": false},
          "slo": {"max_shed_rate": 0.0, "min_served": 12}
        }"#,
    )
    .expect("tiny spec parses")
}

#[test]
fn deterministic_ledger_is_bit_identical_with_sampler_on() {
    if require_artifacts().is_none() {
        eprintln!("skipping sampler-determinism test: artifacts not built");
        return;
    }
    let spec_off = tiny_deterministic_spec();
    let mut spec_on = spec_off.clone();
    spec_on.sample_interval_ms = Some(5);
    spec_on.validate().expect("sampling is allowed in deterministic specs");

    let opts = RunOptions { smoke: true, dispatch_threads: None };
    let off = run_scenario(&spec_off, &opts).expect("sampler-off run");
    let on = run_scenario(&spec_on, &opts).expect("sampler-on run");

    // the sampler is a pure observer: the entire ledger must not move
    assert_eq!(off.ledger, on.ledger, "sampling must not change the ledger by a single bit");
    assert_eq!(off.verdict.status(), "pass");
    assert_eq!(on.verdict.status(), "pass");

    // ...but only the sampled run carries the recorded series
    assert!(off.timeseries.is_none(), "no sample_interval_ms → no timeseries block");
    let ts = on.timeseries.as_ref().expect("sampled run must carry its series");
    assert!(
        ts.series.iter().any(|s| s.name == "queue_depth" && !s.points.is_empty()),
        "sampled run must have queue-depth points"
    );

    // the bench JSON gains a `timeseries` block and still validates
    let j = on.to_json();
    assert!(j.get("timeseries").is_some(), "bench JSON must carry the timeseries block");
    let check = validate_bench_json(&j.pretty()).expect("bench JSON with timeseries validates");
    assert_eq!(check.verdict.as_deref(), Some("pass"));
}
