//! Integration: the full serving path — dynamic batcher + engine + PJRT
//! executables — against the native model.

use std::path::PathBuf;
use std::time::Duration;

use mxmoe::alloc::Allocation;
use mxmoe::coordinator::{ServeConfig, Server};
use mxmoe::harness::{require_artifacts, save_model_mxt};
use mxmoe::moe::{ModelConfig, MoeLm};
use mxmoe::quant::QuantScheme;
use mxmoe::util::Rng;

/// Serving-shape model (hidden=128, inter=64 — what the AOT export ships),
/// small expert count to keep the test fast.
fn serving_cfg() -> ModelConfig {
    ModelConfig {
        name: "serve-test".into(),
        vocab: 64,
        hidden: 128,
        layers: 2,
        heads: 4,
        n_experts: 6,
        n_shared: 1,
        topk: 2,
        inter: 64,
        dense_first: false,
        seq_len: 24,
    }
}

fn save_random_model(cfg: &ModelConfig, path: &PathBuf, rng: &mut Rng) -> MoeLm {
    let lm = MoeLm::random(cfg, rng);
    save_model_mxt(&lm, path).unwrap();
    lm
}

#[test]
fn serve_fp16_matches_native_forward() {
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let cfg = serving_cfg();
    let mut rng = Rng::new(0x5EB5);
    let weights_path = std::env::temp_dir().join("mxmoe_serve_test.mxt");
    let lm = save_random_model(&cfg, &weights_path, &mut rng);

    let server = Server::start(
        cfg.clone(),
        weights_path.clone(),
        artifacts,
        Allocation::uniform(&cfg, QuantScheme::FP16),
        ServeConfig { max_batch_seqs: 4, max_wait: Duration::from_millis(5), ..Default::default() },
    )
    .unwrap();

    // submit a few requests and compare predictions with the native model
    let mut receivers = Vec::new();
    let mut seqs = Vec::new();
    for _ in 0..6 {
        let seq: Vec<u32> = (0..cfg.seq_len).map(|_| rng.below(64) as u32).collect();
        receivers.push(server.submit(seq.clone()).unwrap());
        seqs.push(seq);
    }
    for (rx, seq) in receivers.iter().zip(&seqs) {
        let resp = rx.recv_timeout(Duration::from_secs(120)).expect("response");
        let logits = lm.forward(seq);
        let last = logits.row(seq.len() - 1);
        let native_argmax =
            (0..last.len()).max_by(|&a, &b| last[a].partial_cmp(&last[b]).unwrap()).unwrap();
        assert_eq!(
            resp.next_token as usize, native_argmax,
            "served prediction diverged from native model"
        );
        assert!(resp.mean_nll.is_finite() && resp.mean_nll > 0.0);
    }
    let report = server.shutdown();
    assert_eq!(report.requests, 6);
    assert!(report.throughput_tps > 0.0);
    assert!(report.expert_calls > 0);
    let _ = std::fs::remove_file(&weights_path);
}

#[test]
fn serve_quantized_stays_close_but_not_identical() {
    let Some(artifacts) = require_artifacts() else {
        return;
    };
    let cfg = serving_cfg();
    let mut rng = Rng::new(0x5EB6);
    let weights_path = std::env::temp_dir().join("mxmoe_serve_test_q.mxt");
    let lm = save_random_model(&cfg, &weights_path, &mut rng);

    let server = Server::start(
        cfg.clone(),
        weights_path.clone(),
        artifacts,
        Allocation::uniform(&cfg, QuantScheme::W8A8),
        ServeConfig::default(),
    )
    .unwrap();
    let seq: Vec<u32> = (0..cfg.seq_len).map(|_| rng.below(64) as u32).collect();
    let rx = server.submit(seq.clone()).unwrap();
    let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
    // compare NLL with the native fp32 value: close (8-bit) but finite
    let logits = lm.forward(&seq);
    let mut nll = 0.0f64;
    for pos in 0..seq.len() - 1 {
        let row = logits.row(pos);
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
        let z: f64 = row.iter().map(|&v| ((v as f64) - m).exp()).sum();
        nll -= (logits.at(pos, seq[pos + 1] as usize) as f64 - m) - z.ln();
    }
    let native = nll / (seq.len() - 1) as f64;
    assert!(
        (resp.mean_nll - native).abs() / native < 0.1,
        "8-bit NLL {} too far from native {native}",
        resp.mean_nll
    );
    server.shutdown();
    let _ = std::fs::remove_file(&weights_path);
}
