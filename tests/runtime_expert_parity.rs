//! Integration: the AOT PJRT expert-FFN executables must compute exactly
//! what the rust fake-quant reference computes — this pins the whole
//! L1 (Pallas) → L2 (jax) → HLO text → PJRT → rust chain end to end.

use mxmoe::harness::require_artifacts;
use mxmoe::moe::ExpertWeights;
use mxmoe::runtime::{PreparedExpert, Runtime, RuntimeScheme};
use mxmoe::tensor::Matrix;
use mxmoe::util::Rng;

/// Serving shapes the AOT export used (qwen15-mini).
const HIDDEN: usize = 128;
const INTER: usize = 64;

fn check_scheme(scheme: RuntimeScheme, tol: f32) {
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = Runtime::cpu(&artifacts).unwrap();
    let mut rng = Rng::new(0xE0 + scheme as u64);
    let e = ExpertWeights::random(HIDDEN, INTER, &mut rng);
    let prepared = PreparedExpert::prepare(&e, scheme).unwrap();
    for tile_m in [16usize, 64] {
        let x = Matrix::randn(tile_m, HIDDEN, 1.0, &mut rng);
        let y = rt.run_expert_ffn(scheme, tile_m, &x, &prepared.literals).unwrap();
        let y_ref = PreparedExpert::reference_forward(&e, scheme, &x);
        assert_eq!((y.rows, y.cols), (tile_m, HIDDEN));
        let denom = y_ref.frob_norm().max(1e-6);
        let rel = y.l2_distance(&y_ref) / denom;
        assert!(
            rel < tol as f64,
            "{scheme:?} m={tile_m}: PJRT vs native rel err {rel}"
        );
    }
}

#[test]
fn fp16_executable_matches_native() {
    check_scheme(RuntimeScheme::Fp16, 1e-4);
}

#[test]
fn w4a16_executable_matches_native() {
    check_scheme(RuntimeScheme::W4A16, 1e-3);
}

#[test]
fn w8a8_executable_matches_native() {
    check_scheme(RuntimeScheme::W8A8, 1e-3);
}

#[test]
fn w4a4_executable_matches_native() {
    check_scheme(RuntimeScheme::W4A4, 1e-3);
}

#[test]
fn quantized_schemes_actually_differ_from_fp16() {
    // guard against the executables silently ignoring quantization
    let Some(artifacts) = require_artifacts() else {
        return;
    };
    let rt = Runtime::cpu(&artifacts).unwrap();
    let mut rng = Rng::new(0xF0);
    let e = ExpertWeights::random(HIDDEN, INTER, &mut rng);
    let x = Matrix::randn(16, HIDDEN, 1.0, &mut rng);
    let run = |s: RuntimeScheme| {
        let p = PreparedExpert::prepare(&e, s).unwrap();
        rt.run_expert_ffn(s, 16, &x, &p.literals).unwrap()
    };
    let y16 = run(RuntimeScheme::Fp16);
    let y4 = run(RuntimeScheme::W4A4);
    let y8 = run(RuntimeScheme::W8A8);
    let d4 = y16.l2_distance(&y4) / y16.frob_norm();
    let d8 = y16.l2_distance(&y8) / y16.frob_norm();
    assert!(d4 > 1e-3, "w4a4 indistinguishable from fp16: {d4}");
    assert!(d8 > 1e-6 && d8 < d4, "w8a8 error {d8} should be small but nonzero, < w4a4 {d4}");
}
