//! Integration: the scenario engine end to end — the checked-in spec
//! suite parses, validates, and round-trips; malformed specs are
//! rejected; and the determinism anchor holds: same spec + same seed
//! produce an identical admission/termination ledger across repeated
//! runs and across dispatch-thread counts. A replica-kill replay pins
//! the fault-accounting identity the verdict gates on.

use mxmoe::harness::require_artifacts;
use mxmoe::harness::scenario::{
    list_specs, load_named_spec, run_scenario, RunOptions, ScenarioSpec,
};

// ---- spec surface (no artifacts needed) --------------------------------

#[test]
fn checked_in_suite_parses_and_round_trips() {
    let specs = list_specs().expect("scenarios/ must parse");
    assert!(specs.len() >= 6, "suite shrank: {} specs", specs.len());
    for spec in &specs {
        let text = spec.to_json().pretty();
        let back = ScenarioSpec::parse(&text)
            .unwrap_or_else(|e| panic!("{} does not round-trip: {e:#}", spec.name));
        assert_eq!(&back, spec, "{} round-trips to a different spec", spec.name);
    }
    // the suite must exercise every workload axis the engine supports
    assert!(specs.iter().any(|s| s.deterministic), "no deterministic spec");
    assert!(specs.iter().any(|s| !s.cancel_storms.is_empty()), "no cancel-storm spec");
    assert!(specs.iter().any(|s| !s.replica_events.is_empty()), "no replica-fault spec");
    assert!(specs.iter().any(|s| s.online.is_some()), "no online-replan spec");
}

#[test]
fn malformed_specs_are_rejected() {
    let good = load_named_spec("steady_interactive").unwrap().to_json().pretty();

    // not JSON at all
    assert!(ScenarioSpec::parse("not json {").is_err());
    // wrong schema tag
    let wrong = good.replace("mxmoe-scenario-v1", "mxmoe-scenario-v9");
    assert!(ScenarioSpec::parse(&wrong).is_err(), "wrong schema must be rejected");
    // present-but-wrong-type field
    let wrong = good.replace("\"ticks\": 10", "\"ticks\": \"ten\"");
    assert!(ScenarioSpec::parse(&wrong).is_err(), "string ticks must be rejected");
    // determinism contract: a deterministic spec may not carry cancel storms
    let wrong = good.replace(
        "\"deterministic\": true",
        "\"deterministic\": true, \"cancel_storms\": [{\"tick\": 1, \"fraction\": 0.5}]",
    );
    assert!(ScenarioSpec::parse(&wrong).is_err(), "deterministic + storms must be rejected");
}

// ---- replay determinism (artifact-gated) -------------------------------

/// A deliberately small deterministic spec so three full replays stay
/// cheap: 6 ticks × 2 arrivals on one replica.
fn tiny_deterministic_spec() -> ScenarioSpec {
    ScenarioSpec::parse(
        r#"{
          "schema": "mxmoe-scenario-v1",
          "name": "tiny_replay",
          "description": "determinism anchor for the integration test",
          "seed": 9901,
          "ticks": 6,
          "replicas": 1,
          "deterministic": true,
          "arrival": {"curve": "constant", "rate": 2.0},
          "mix": [{"from_tick": 0, "interactive": 0.5, "standard": 0.3, "batch": 0.2}],
          "prompt_tokens": {"min": 4, "max": 12},
          "generate_fraction": 0.25,
          "max_new_tokens": 4,
          "admission": {"max_queued_seqs": 16, "max_queued_tokens": 4096,
                        "privileged_reserve": 0.0, "auto_reserve": false},
          "slo": {"max_shed_rate": 0.0, "min_served": 12}
        }"#,
    )
    .expect("tiny spec parses")
}

#[test]
fn same_seed_reproduces_ledger_across_runs_and_thread_counts() {
    if require_artifacts().is_none() {
        eprintln!("skipping scenario replay test: artifacts not built");
        return;
    }
    let spec = tiny_deterministic_spec();

    let base = run_scenario(&spec, &RunOptions { smoke: true, dispatch_threads: None })
        .expect("run 1");
    let rerun = run_scenario(&spec, &RunOptions { smoke: true, dispatch_threads: None })
        .expect("run 2");
    let threaded = run_scenario(&spec, &RunOptions { smoke: true, dispatch_threads: Some(2) })
        .expect("run 3 (2 dispatch threads)");

    assert_eq!(base.ledger, rerun.ledger, "same seed must reproduce the ledger");
    assert_eq!(
        base.ledger, threaded.ledger,
        "ledger must be independent of dispatch-thread count"
    );
    assert_eq!(base.verdict.status(), rerun.verdict.status());
    assert_eq!(base.verdict.status(), threaded.verdict.status());
    assert_eq!(base.verdict.status(), "pass", "tiny replay must pass its own SLOs");

    // 6 ticks × rate 2.0 with fractional carry is exactly 12 arrivals,
    // all admitted and served (no storms, no faults, no deadlines)
    assert_eq!(base.ledger.arrivals, 12);
    assert_eq!(base.ledger.admitted, 12);
    assert_eq!(base.ledger.responses, 12);
    assert_eq!(base.ledger.shed(), 0);
}

#[test]
fn replica_kill_replay_keeps_accounting_identity() {
    if require_artifacts().is_none() {
        eprintln!("skipping replica-kill replay test: artifacts not built");
        return;
    }
    let spec = load_named_spec("replica_flap").expect("replica_flap spec");
    let outcome = run_scenario(&spec, &RunOptions { smoke: true, dispatch_threads: None })
        .expect("replica_flap replay");

    let l = &outcome.ledger;
    assert_eq!(l.kills, 1, "exactly one kill event");
    assert_eq!(l.restarts, 1, "exactly one restart event");
    // every admitted request terminates exactly once, even across the
    // kill (evicted in-flight work surfaces as `failed`, stolen queued
    // batches as `responses`)
    assert_eq!(
        l.admitted,
        l.responses + l.cancelled + l.failed,
        "admitted must equal responses + cancelled + failed across a kill"
    );
    assert_eq!(outcome.verdict.status(), "pass", "replica_flap verdict must pass in smoke");
}
