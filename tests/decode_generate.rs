//! Integration: the token-level decode subsystem (DESIGN.md §Decode-Loop).
//!
//! Correctness anchor first: prefill-then-decode through the KV cache must
//! be *bit-identical* to whole-sequence `forward_capture` on the same
//! token sequence — natively for the raw fp16 model and for quantized
//! blocks under mixed precision plans, where every op is row-independent
//! and runs in the same accumulation order. Through the serving engine the
//! same anchor holds per step composition: a cluster generation must match
//! a directly-driven engine decode loop bit for bit, at 1 and 4 replicas,
//! and a `max_new_tokens = 0` generation must reproduce the scoring path's
//! response exactly. On top of that: stop-token/max-token termination,
//! step-granular cancellation with KV reclamation (liveness: the freed
//! budget admits the next generation), and the admission invariant
//! `admitted == responses + cancelled + failed` extended to generations.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Duration;

use mxmoe::coordinator::{Cluster, ClusterConfig, ServeConfig, ServingEngine};
use mxmoe::harness::{mixed_runtime_plan, require_artifacts, require_mini_model, save_model_mxt};
use mxmoe::moe::block::{uniform_schemes, WeightQuantizer};
use mxmoe::moe::{ModelConfig, MoeLm, QuantizedMoeBlock};
use mxmoe::quant::QuantScheme;
use mxmoe::serve::{
    DecodePolicy, DecodeScheduler, FinishReason, GenSpec, Request, RequestKind, SeqKv,
    ServeRequest, StreamEvent, Ticket,
};
use mxmoe::util::Rng;

const WAIT: Duration = Duration::from_secs(300);

/// Serving-shape model (hidden=128, inter=64 — what the AOT export ships).
fn serving_cfg() -> ModelConfig {
    ModelConfig {
        name: "decode-test".into(),
        vocab: 64,
        hidden: 128,
        layers: 2,
        heads: 4,
        n_experts: 4,
        n_shared: 1,
        topk: 2,
        inter: 64,
        dense_first: false,
        seq_len: 16,
    }
}

fn seq(cfg: &ModelConfig, rng: &mut Rng, len: usize) -> Vec<u32> {
    (0..len).map(|_| rng.below(cfg.vocab as u64) as u32).collect()
}

fn boot_weights(name: &str, seed: u64) -> (ModelConfig, MoeLm, PathBuf) {
    let cfg = serving_cfg();
    let weights = std::env::temp_dir().join(format!("mxmoe_decode_{name}.mxt"));
    let lm = MoeLm::random(&cfg, &mut Rng::new(seed));
    save_model_mxt(&lm, &weights).unwrap();
    (cfg, lm, weights)
}

fn start_cluster(
    cfg: &ModelConfig,
    weights: &PathBuf,
    artifacts: &PathBuf,
    replicas: usize,
    decode: DecodePolicy,
) -> Cluster {
    Cluster::start(
        cfg.clone(),
        weights.clone(),
        artifacts.clone(),
        mixed_runtime_plan(cfg),
        ClusterConfig {
            replicas,
            serve: ServeConfig {
                max_batch_seqs: 1,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
            decode,
            ..Default::default()
        },
    )
    .unwrap()
}

/// Drain a generation ticket: stream tokens + finish reason + final
/// response bits.
fn collect_generation(ticket: &Ticket) -> (Vec<u32>, FinishReason, (u32, u64)) {
    let (tokens, reason) = ticket.collect_tokens(WAIT).expect("token stream");
    let resp = ticket.wait_timeout(WAIT).expect("final response");
    (tokens, reason, (resp.next_token, resp.mean_nll.to_bits()))
}

// ---------------------------------------------------------------- native

#[test]
fn native_prefill_decode_bit_identical_to_forward_capture() {
    // the correctness anchor, fp16: every split of prefill+decode must
    // reproduce forward_capture's logits bit for bit (serving-shape model)
    let (cfg, lm, _) = boot_weights("native", 0xDEC0);
    let mut rng = Rng::new(0xDEC1);
    let tokens = seq(&cfg, &mut rng, 12);
    let (full, caps) = lm.forward_capture(&tokens);
    assert_eq!(caps.len(), cfg.layers);
    // every (page size, split) combination must land on the same bits:
    // fp32 paging moves rows between pages, never an arithmetic operation
    for page in [2usize, 16] {
        for split in [1usize, 4, 11] {
            let mut cache =
                SeqKv::with_page_size(cfg.layers, cfg.hidden, tokens.len(), page);
            let prefill = lm.forward_step(&tokens[..split], &mut cache);
            for pos in 0..split {
                for c in 0..cfg.vocab {
                    assert_eq!(prefill.at(pos, c).to_bits(), full.at(pos, c).to_bits());
                }
            }
            for pos in split..tokens.len() {
                let step = lm.forward_step(&tokens[pos..pos + 1], &mut cache);
                for c in 0..cfg.vocab {
                    assert_eq!(
                        step.at(0, c).to_bits(),
                        full.at(pos, c).to_bits(),
                        "page {page}, split {split}: decode logits diverged at ({pos}, {c})"
                    );
                }
            }
        }
    }
}

#[test]
fn native_decode_matches_quantized_forward_across_mixed_plans() {
    // mixed precision plans: per-layer scheme mixes through fake-quantized
    // blocks — the decode path must track forward_quantized bit for bit
    let (cfg, lm, _) = boot_weights("native_q", 0xDEC2);
    let mut rng = Rng::new(0xDEC3);
    let tokens = seq(&cfg, &mut rng, 10);
    let plans: [Vec<QuantScheme>; 2] = [
        vec![QuantScheme::W4A4, QuantScheme::W8A8],
        vec![QuantScheme::W8A8, QuantScheme::FP16],
    ];
    for plan in &plans {
        let blocks: Vec<QuantizedMoeBlock> = lm
            .moe_blocks()
            .iter()
            .enumerate()
            .map(|(pos, (_, b))| {
                QuantizedMoeBlock::build(
                    b,
                    &uniform_schemes(b.total_experts(), plan[pos]),
                    &WeightQuantizer::Rtn,
                    None,
                )
                .unwrap()
            })
            .collect();
        let replacements: HashMap<usize, &QuantizedMoeBlock> =
            lm.moe_blocks().iter().map(|(l, _)| *l).zip(blocks.iter()).collect();
        let full = lm.forward_quantized(&tokens, &replacements);
        let mut cache = SeqKv::new(cfg.layers, cfg.hidden, tokens.len());
        let prefill = lm.forward_step_quantized(&tokens[..6], &mut cache, &replacements);
        for pos in 0..6 {
            for c in 0..cfg.vocab {
                assert_eq!(prefill.at(pos, c).to_bits(), full.at(pos, c).to_bits());
            }
        }
        for pos in 6..tokens.len() {
            let step =
                lm.forward_step_quantized(&tokens[pos..pos + 1], &mut cache, &replacements);
            for c in 0..cfg.vocab {
                assert_eq!(step.at(0, c).to_bits(), full.at(pos, c).to_bits());
            }
        }
    }
}

// ---------------------------------------------------------------- engine

/// Drive a generation through a locally-owned engine + decode scheduler —
/// the reference the cluster paths are compared against bit for bit.
fn engine_reference_generation(
    cfg: &ModelConfig,
    weights: &PathBuf,
    artifacts: &PathBuf,
    prompt: &[u32],
    max_new: usize,
    stop: Vec<u32>,
) -> (Vec<u32>, FinishReason, (u32, u64)) {
    let weights_file = mxmoe::ser::MxtFile::load(weights).unwrap();
    let lm = MoeLm::load_mxt(cfg, &weights_file).unwrap();
    let mut engine = ServingEngine::new(lm, artifacts, &mixed_runtime_plan(cfg)).unwrap();
    let mut sched = DecodeScheduler::new(cfg, DecodePolicy::default());
    let (reply, reply_rx) = mpsc::channel();
    let (stream, stream_rx) = mpsc::channel();
    sched.admit(Request {
        kind: RequestKind::Generate(GenSpec { max_new_tokens: max_new, stop, stream }),
        ..Request::new(prompt.to_vec(), reply)
    });
    let mut finished = Vec::new();
    while sched.has_work() {
        let out = sched.step(|inputs| engine.forward_step_batch(inputs));
        finished.extend(out.finished);
    }
    drop(reply_rx);
    assert_eq!(finished.len(), 1);
    let fin = &finished[0];
    let mut tokens = Vec::new();
    let mut reason = None;
    while let Ok(ev) = stream_rx.try_recv() {
        match ev {
            StreamEvent::Token { token, .. } => tokens.push(token),
            StreamEvent::Done { reason: r, generated } => {
                assert_eq!(generated, tokens.len());
                reason = Some(r);
            }
        }
    }
    (
        tokens,
        reason.expect("terminal event"),
        (fin.last_token.unwrap_or(0), fin.mean_prompt_nll.to_bits()),
    )
}

#[test]
fn cluster_generation_bit_identical_to_engine_reference_at_1_and_4_replicas() {
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (cfg, _, weights) = boot_weights("cluster", 0xDEC4);
    let mut rng = Rng::new(0xDEC5);
    let prompts: Vec<Vec<u32>> = vec![seq(&cfg, &mut rng, 9), seq(&cfg, &mut rng, 14)];
    let max_new = 6usize;
    let reference: Vec<_> = prompts
        .iter()
        .map(|p| engine_reference_generation(&cfg, &weights, &artifacts, p, max_new, vec![]))
        .collect();
    for replicas in [1usize, 4] {
        let cluster =
            start_cluster(&cfg, &weights, &artifacts, replicas, DecodePolicy::default());
        // sequential submissions: one generation in flight at a time keeps
        // every step's batch composition (and therefore its tiling)
        // identical to the reference — the same discipline
        // tests/cluster_replicas.rs uses for scoring bit-identity
        for (p, want) in prompts.iter().zip(&reference) {
            let ticket = cluster.generate(p.clone(), max_new, vec![]).unwrap();
            assert!(ticket.is_generation());
            let got = collect_generation(&ticket);
            assert_eq!(got.0, want.0, "{replicas}-replica token stream diverged");
            assert_eq!(got.1, want.1);
            assert_eq!(got.2, want.2, "{replicas}-replica response bits diverged");
        }
        let report = cluster.shutdown();
        assert_eq!(report.admission.admitted, prompts.len());
        assert_eq!(report.total_requests(), prompts.len(), "one response per generation");
        let flat = report.flatten();
        assert_eq!(flat.generations, prompts.len());
        assert_eq!(
            flat.generated_tokens,
            prompts.len() * max_new,
            "every generation ran to its token budget"
        );
        assert!(flat.decode_steps > 0 && flat.p50_step_s >= 0.0);
        assert!(flat.kv_peak_tokens > 0, "KV reservations surfaced in the report");
        assert_eq!(flat.kv_preemptions, 0, "an uncontended pool never preempts");
        assert!(flat.decode_tps > 0.0);
    }
    let _ = std::fs::remove_file(&weights);
}

#[test]
fn zero_token_generation_matches_scoring_bit_for_bit() {
    // max_new_tokens = 0 degrades to scoring: same next_token argmax, same
    // mean prompt NLL, through the decode machinery
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (cfg, _, weights) = boot_weights("scorepar", 0xDEC6);
    let mut rng = Rng::new(0xDEC7);
    let prompt = seq(&cfg, &mut rng, 11);
    let cluster = start_cluster(&cfg, &weights, &artifacts, 1, DecodePolicy::default());
    let score = cluster
        .submit_request(ServeRequest::new(prompt.clone()))
        .unwrap()
        .wait_timeout(WAIT)
        .unwrap();
    let ticket = cluster.generate(prompt, 0, vec![]).unwrap();
    let (tokens, reason) = ticket.collect_tokens(WAIT).unwrap();
    assert!(tokens.is_empty());
    assert_eq!(reason, FinishReason::Length);
    let gen = ticket.wait_timeout(WAIT).unwrap();
    assert_eq!(gen.next_token, score.next_token, "argmax continuation must match scoring");
    assert_eq!(
        gen.mean_nll.to_bits(),
        score.mean_nll.to_bits(),
        "prompt NLL must match scoring bit for bit"
    );
    let report = cluster.shutdown();
    assert_eq!(report.total_requests(), 2);
    let _ = std::fs::remove_file(&weights);
}

#[test]
fn stop_token_and_max_token_termination() {
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (cfg, _, weights) = boot_weights("stop", 0xDEC8);
    let mut rng = Rng::new(0xDEC9);
    let prompt = seq(&cfg, &mut rng, 8);
    let cluster = start_cluster(&cfg, &weights, &artifacts, 1, DecodePolicy::default());
    // free-running generation: Length at exactly max_new tokens
    let ticket = cluster.generate(prompt.clone(), 5, vec![]).unwrap();
    let (free_run, reason, _) = collect_generation(&ticket);
    assert_eq!(free_run.len(), 5, "length-terminated at the token budget");
    assert_eq!(reason, FinishReason::Length);
    // rerun with the 3rd greedy token as a stop token: decoding is
    // deterministic, so the rerun must stop right there
    let stop = free_run[2];
    let ticket = cluster.generate(prompt, 5, vec![stop]).unwrap();
    let (stopped, reason, _) = collect_generation(&ticket);
    assert_eq!(stopped, free_run[..3].to_vec(), "prefix up to and incl. the stop token");
    assert_eq!(*stopped.last().unwrap(), stop, "stop token itself is streamed");
    assert_eq!(reason, FinishReason::Stop);
    let report = cluster.shutdown();
    assert_eq!(report.total_requests(), 2);
    assert_eq!(report.flatten().generations, 2);
    let _ = std::fs::remove_file(&weights);
}

#[test]
fn mid_generation_cancellation_stops_within_a_step_and_frees_kv() {
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (cfg, _, weights) = boot_weights("cancel", 0xDECA);
    let mut rng = Rng::new(0xDECB);
    let prompt = seq(&cfg, &mut rng, 8);
    // KV budget fits the long generation's (8 + 2048)-token reservation
    // but NOT that plus the follow-up's (8 + 512): the second generation
    // can only run once the cancelled one's reservation is reclaimed
    let long_new = 2048usize;
    let next_new = 512usize;
    let prompt_len = prompt.len();
    let decode =
        DecodePolicy { kv_budget_tokens: prompt_len + long_new, ..DecodePolicy::default() };
    let cluster = start_cluster(&cfg, &weights, &artifacts, 1, decode);
    let long = cluster.generate(prompt.clone(), long_new, vec![]).unwrap();
    // wait until the generation is demonstrably mid-decode…
    let mut seen = 0usize;
    while seen < 3 {
        match long.wait_event(WAIT).unwrap() {
            StreamEvent::Token { .. } => seen += 1,
            StreamEvent::Done { .. } => panic!("2048-token generation finished too early"),
        }
    }
    // …then cancel: eviction happens between decode steps (the remaining
    // ~2045 steps of work are dropped, not executed)
    long.cancel();
    assert!(long.try_next_event().is_none(), "cancelled ticket yields no events");
    // liveness proof of the KV free: the follow-up reservation only fits
    // after the cancelled one is reclaimed between steps
    let next = cluster.generate(prompt, next_new, vec![]).unwrap();
    let (tokens, reason, _) = collect_generation(&next);
    assert_eq!(tokens.len(), next_new);
    assert_eq!(reason, FinishReason::Length);
    assert!(long.wait_timeout(Duration::from_millis(50)).is_err(), "no response after cancel");
    let report = cluster.shutdown();
    // admitted == responses + cancelled + failed, with the cancelled
    // generation counted exactly once
    assert_eq!(report.admission.admitted, 2);
    assert_eq!(report.admission.failed, 0);
    assert_eq!(report.admission.cancelled, 1);
    assert_eq!(
        report.total_requests() + report.admission.unserved(),
        report.admission.admitted
    );
    let flat = report.flatten();
    assert!(
        flat.generated_tokens >= next_new + seen && flat.generated_tokens < next_new + long_new,
        "cancelled generation stopped early ({} tokens streamed overall)",
        flat.generated_tokens
    );
    assert!(
        flat.kv_peak_tokens <= prompt_len + long_new,
        "reservations never overlapped: peak {}",
        flat.kv_peak_tokens
    );
    let _ = std::fs::remove_file(&weights);
}

#[test]
fn mini_model_checkpoint_serves_generations() {
    // exercises the `make models`-gated path via the cached `make
    // mini-model` artifact: load the deterministic ci-mini checkpoint and
    // serve a generation on it end to end
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let Some((cfg, lm)) = require_mini_model() else {
        eprintln!("skipping: mini model not built (run `make mini-model`)");
        return;
    };
    assert_eq!(cfg.name, "ci-mini");
    // the checkpoint is deterministic: same seed ⇒ same weights
    let twin = MoeLm::random(&cfg, &mut Rng::new(mxmoe::harness::MINI_MODEL_SEED));
    assert_eq!(lm.embed.data, twin.embed.data, "mini-model must be seed-deterministic");
    let weights = mxmoe::harness::artifacts_dir().join("model_ci-mini.mxt");
    let cluster = start_cluster(&cfg, &weights, &artifacts, 1, DecodePolicy::default());
    let mut rng = Rng::new(0xDECC);
    let prompt = seq(&cfg, &mut rng, 6);
    let ticket = cluster.generate(prompt, 4, vec![]).unwrap();
    let (tokens, reason, (next, nll_bits)) = collect_generation(&ticket);
    assert_eq!(tokens.len(), 4);
    assert_eq!(reason, FinishReason::Length);
    assert_eq!(next, *tokens.last().unwrap());
    assert!(f64::from_bits(nll_bits).is_finite());
    let report = cluster.shutdown();
    assert_eq!(report.flatten().generations, 1);
}
