//! Integration: hot-swap correctness — swapping expert runtime schemes in
//! a live engine must be indistinguishable from building a fresh engine on
//! the new plan.

use mxmoe::alloc::Allocation;
use mxmoe::coordinator::ServingEngine;
use mxmoe::harness::require_artifacts;
use mxmoe::moe::{ModelConfig, MoeLm};
use mxmoe::quant::QuantScheme;
use mxmoe::runtime::RuntimeScheme;
use mxmoe::serve::diff_plans;
use mxmoe::tensor::Matrix;
use mxmoe::util::Rng;

const MODEL_SEED: u64 = 0x5A0_11E;

/// Serving-shape model (hidden=128, inter=64 — what the AOT export ships).
fn serving_cfg() -> ModelConfig {
    ModelConfig {
        name: "hotswap-test".into(),
        vocab: 64,
        hidden: 128,
        layers: 2,
        heads: 4,
        n_experts: 4,
        n_shared: 1,
        topk: 2,
        inter: 64,
        dense_first: false,
        seq_len: 16,
    }
}

fn model() -> MoeLm {
    MoeLm::random(&serving_cfg(), &mut Rng::new(MODEL_SEED))
}

fn probe_batch(cfg: &ModelConfig, seed: u64) -> Vec<Vec<u32>> {
    let mut rng = Rng::new(seed);
    (0..3)
        .map(|_| (0..cfg.seq_len).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
        .collect()
}

fn forward(engine: &mut ServingEngine, batch: &[Vec<u32>]) -> Vec<Matrix> {
    let refs: Vec<&[u32]> = batch.iter().map(|s| s.as_slice()).collect();
    engine.forward_batch(&refs).expect("forward")
}

fn assert_bit_identical(a: &[Matrix], b: &[Matrix], what: &str) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!((x.rows, x.cols), (y.rows, y.cols));
        for (u, v) in x.data.iter().zip(&y.data) {
            assert!(u.to_bits() == v.to_bits(), "{what}: seq {i} diverged");
        }
    }
}

#[test]
fn hot_swap_matches_fresh_engine_bit_for_bit() {
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let cfg = serving_cfg();
    let plan_a = Allocation::uniform(&cfg, QuantScheme::FP16);
    let plan_b = Allocation::uniform(&cfg, QuantScheme::W8A8);
    let batch = probe_batch(&cfg, 1);

    let mut engine = ServingEngine::new(model(), &artifacts, &plan_a).unwrap();
    assert_eq!(engine.generation(), 0);
    let out_a = forward(&mut engine, &batch);

    // swap every slot FP16 → W8A8
    let changes = diff_plans(&plan_a, &plan_b);
    assert_eq!(changes.len(), 2 * 5, "2 layers × (4 routed + 1 shared)");
    let swapped = engine.install_plan(plan_b.clone(), &changes).unwrap();
    assert_eq!(swapped, changes.len());
    assert_eq!(engine.generation(), 1);
    assert_eq!(engine.scheme_of(0, 0), RuntimeScheme::W8A8);
    assert_eq!(engine.metrics().swaps, swapped);

    let out_swapped = forward(&mut engine, &batch);
    // quantization must actually have changed the computation
    assert!(
        out_a.iter().zip(&out_swapped).any(|(x, y)| x.data != y.data),
        "W8A8 swap produced identical outputs to fp16 — swap was a no-op"
    );

    // a fresh engine built directly on plan B must agree bit-for-bit
    let mut fresh = ServingEngine::new(model(), &artifacts, &plan_b).unwrap();
    let out_fresh = forward(&mut fresh, &batch);
    assert_bit_identical(&out_swapped, &out_fresh, "swapped vs fresh(plan B)");
}

#[test]
fn swap_back_restores_original_outputs() {
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let cfg = serving_cfg();
    let plan_a = Allocation::uniform(&cfg, QuantScheme::W4A16);
    let plan_b = Allocation::uniform(&cfg, QuantScheme::W4A4);
    let batch = probe_batch(&cfg, 2);

    let mut engine = ServingEngine::new(model(), &artifacts, &plan_a).unwrap();
    let out_a = forward(&mut engine, &batch);
    engine.install_plan(plan_b.clone(), &diff_plans(&plan_a, &plan_b)).unwrap();
    forward(&mut engine, &batch);
    engine.install_plan(plan_a.clone(), &diff_plans(&plan_b, &plan_a)).unwrap();
    assert_eq!(engine.generation(), 2);
    let out_back = forward(&mut engine, &batch);
    assert_bit_identical(&out_a, &out_back, "A → B → A roundtrip");
}

#[test]
fn empty_delta_is_a_noop() {
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let cfg = serving_cfg();
    let plan = Allocation::uniform(&cfg, QuantScheme::FP16);
    let mut engine = ServingEngine::new(model(), &artifacts, &plan).unwrap();
    let swapped = engine.install_plan(plan.clone(), &diff_plans(&plan, &plan)).unwrap();
    assert_eq!(swapped, 0);
    assert_eq!(engine.generation(), 0, "no-op delta must not bump the generation");
    assert_eq!(engine.metrics().swaps, 0);
}
