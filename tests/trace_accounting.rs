//! Integration: lifecycle tracing (DESIGN.md §Observability) must be a
//! pure observer. The merged trace restates the admission ledger — every
//! admitted request gets exactly one terminal span, and the terminal
//! outcomes sum back to `admitted == responses + cancelled + failed` —
//! the Chrome export passes the CI structural check, and flipping tracing
//! on changes no served bit.

use std::path::PathBuf;
use std::time::Duration;

use mxmoe::coordinator::{Cluster, ClusterConfig, ClusterReport, ServeConfig};
use mxmoe::harness::{mixed_runtime_plan, require_artifacts, save_model_mxt};
use mxmoe::moe::{ModelConfig, MoeLm};
use mxmoe::obs::{validate_chrome_trace, Outcome, TraceConfig};
use mxmoe::serve::{QosClass, ServeRequest};
use mxmoe::util::Rng;

/// Serving-shape model (hidden=128, inter=64 — what the AOT export ships).
fn serving_cfg() -> ModelConfig {
    ModelConfig {
        name: "trace-test".into(),
        vocab: 64,
        hidden: 128,
        layers: 2,
        heads: 4,
        n_experts: 4,
        n_shared: 1,
        topk: 2,
        inter: 64,
        dense_first: false,
        seq_len: 16,
    }
}

/// Fixed typed request stream: varying lengths and QoS classes, same seed
/// every run, so traced and untraced clusters serve identical work.
fn request_stream(cfg: &ModelConfig) -> Vec<ServeRequest> {
    let mut rng = Rng::new(0x7ACE_AC_C7);
    let lens = [16usize, 5, 16, 11, 2, 16, 9, 16, 7, 13];
    let qos = [QosClass::Interactive, QosClass::Standard, QosClass::Batch];
    lens.iter()
        .enumerate()
        .map(|(i, &n)| {
            let seq: Vec<u32> = (0..n).map(|_| rng.below(cfg.vocab as u64) as u32).collect();
            let mut req = ServeRequest::new(seq);
            if i % 2 == 0 {
                req = req.qos(qos[i % qos.len()]).deadline(Duration::from_secs(60));
            }
            req
        })
        .collect()
}

/// Serve the stream with tracing on or off; returns per-request
/// `(next_token, mean_nll bits)` plus the cluster report.
fn serve_stream(
    cfg: &ModelConfig,
    weights: &PathBuf,
    artifacts: &PathBuf,
    trace: TraceConfig,
) -> (Vec<(u32, u64)>, ClusterReport) {
    // max_batch_seqs = 1 keeps batch composition (and tiling) identical
    // across runs, which is what makes bit-identity well-defined
    let cluster = Cluster::start(
        cfg.clone(),
        weights.clone(),
        artifacts.clone(),
        mixed_runtime_plan(cfg),
        ClusterConfig {
            replicas: 2,
            serve: ServeConfig {
                max_batch_seqs: 1,
                max_wait: Duration::from_millis(1),
                trace,
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap();
    let tickets: Vec<_> = request_stream(cfg)
        .into_iter()
        .map(|req| cluster.submit_request(req).unwrap())
        .collect();
    let responses: Vec<(u32, u64)> = tickets
        .iter()
        .map(|t| {
            let r = t.wait_timeout(Duration::from_secs(300)).expect("response");
            (r.next_token, r.mean_nll.to_bits())
        })
        .collect();
    (responses, cluster.shutdown())
}

#[test]
fn trace_restates_admission_ledger_and_validates() {
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let cfg = serving_cfg();
    let weights = std::env::temp_dir().join("mxmoe_trace_acct_test.mxt");
    let lm = MoeLm::random(&cfg, &mut Rng::new(0x7ACE_01));
    save_model_mxt(&lm, &weights).unwrap();

    let (responses, report) = serve_stream(&cfg, &weights, &artifacts, TraceConfig::on());
    assert!(!report.trace.is_empty(), "tracing on must record events");
    assert_eq!(report.trace.dropped, 0, "ring capacity must hold this workload");

    // exactly one terminal span per admitted request
    let mut admitted = report.trace.admitted_ids();
    admitted.sort_unstable();
    let terminals = report.trace.terminals();
    let mut terminal_ids: Vec<u64> = terminals.iter().map(|(id, _)| *id).collect();
    terminal_ids.sort_unstable();
    admitted.dedup();
    assert_eq!(
        admitted.len(),
        report.trace.admitted_ids().len(),
        "admitted ids must be unique"
    );
    assert_eq!(terminal_ids, admitted, "exactly one terminal span per admitted request");

    // the trace restates the admission ledger: admitted == responses +
    // cancelled + failed, outcome by outcome
    let adm = &report.admission;
    assert_eq!(admitted.len(), adm.admitted, "trace admitted == ledger admitted");
    let done = terminals.iter().filter(|(_, o)| matches!(o, Outcome::Done)).count();
    let cancelled = terminals
        .iter()
        .filter(|(_, o)| matches!(o, Outcome::Cancelled | Outcome::Shed))
        .count();
    let failed = terminals.iter().filter(|(_, o)| matches!(o, Outcome::Failed)).count();
    assert_eq!(done, responses.len(), "one Done terminal per response");
    assert_eq!(cancelled, adm.cancelled, "Cancelled/Shed terminals == ledger cancelled");
    assert_eq!(failed, adm.failed, "Failed terminals == ledger failed");
    assert_eq!(done + cancelled + failed, adm.admitted, "terminals exhaust admissions");

    // SLO accounting rides the same terminals: served counts must agree
    let slo_served: usize = report.slo_by_class().iter().map(|s| s.served).sum();
    assert_eq!(slo_served, responses.len(), "every response lands in an SLO class");
    let by_gen: usize = report.served_by_generation().iter().map(|(_, n)| *n).sum();
    assert_eq!(by_gen, responses.len(), "served-bits attribution covers every response");

    // the Chrome export passes the same structural check CI runs
    let out = std::env::temp_dir().join("mxmoe_trace_acct_test.json");
    report.trace.write_chrome_trace(&out).unwrap();
    let check = validate_chrome_trace(&std::fs::read_to_string(&out).unwrap()).unwrap();
    assert_eq!(check.begins, admitted.len(), "one async begin per admitted request");
    assert_eq!(check.begins, check.ends, "every async begin has a matching end");
    assert!(check.events >= report.trace.len(), "export covers every recorded event");

    let _ = std::fs::remove_file(&out);
    let _ = std::fs::remove_file(&weights);
}

#[test]
fn tracing_is_bit_invisible_to_served_outputs() {
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let cfg = serving_cfg();
    let weights = std::env::temp_dir().join("mxmoe_trace_bits_test.mxt");
    let lm = MoeLm::random(&cfg, &mut Rng::new(0x7ACE_02));
    save_model_mxt(&lm, &weights).unwrap();

    let (off, off_report) = serve_stream(&cfg, &weights, &artifacts, TraceConfig::default());
    let (on, on_report) = serve_stream(&cfg, &weights, &artifacts, TraceConfig::on());

    assert!(off_report.trace.is_empty(), "tracing off must record nothing");
    assert!(!on_report.trace.is_empty(), "tracing on must record the run");
    assert_eq!(on, off, "tracing changed a served bit");
    assert_eq!(on_report.total_requests(), off_report.total_requests());

    let _ = std::fs::remove_file(&weights);
}
