//! Integration: grouped mixed-precision GroupGEMM dispatch must be
//! bit-for-bit indistinguishable from the sequential reference path —
//! across mixed schemes, uneven token counts, shared experts, and any
//! worker-thread count.

use mxmoe::alloc::Allocation;
use mxmoe::coordinator::ServingEngine;
use mxmoe::harness::require_artifacts;
use mxmoe::moe::{ModelConfig, MoeLm};
use mxmoe::quant::QuantScheme;
use mxmoe::runtime::{DispatchMode, RuntimeScheme};
use mxmoe::tensor::Matrix;
use mxmoe::util::Rng;

const MODEL_SEED: u64 = 0x6D15_BA7C;

/// Serving-shape model (hidden=128, inter=64 — what the AOT export ships).
fn serving_cfg() -> ModelConfig {
    ModelConfig {
        name: "group-dispatch-test".into(),
        vocab: 64,
        hidden: 128,
        layers: 2,
        heads: 4,
        n_experts: 4,
        n_shared: 1,
        topk: 2,
        inter: 64,
        dense_first: false,
        seq_len: 16,
    }
}

/// A plan that spreads all four runtime families across the expert grid,
/// so a single block dispatch plans waves of ≥ 4 distinct executables.
fn mixed_plan(cfg: &ModelConfig) -> Allocation {
    let fams =
        [QuantScheme::FP16, QuantScheme::W4A16, QuantScheme::W8A8, QuantScheme::W4A4];
    let mut plan = Allocation::uniform(cfg, QuantScheme::FP16);
    for (pos, block) in plan.schemes.iter_mut().enumerate() {
        for (e, schemes) in block.iter_mut().enumerate() {
            *schemes = [fams[(pos + e) % fams.len()]; 3];
        }
    }
    plan
}

/// Batches whose concatenated MoE row counts hit the tile-decomposition
/// edge cases: single padded tile, multi-tile with a ragged tail, exact
/// cover, and the full 256+64+16+4 grid.
fn uneven_batches(vocab: u64) -> Vec<Vec<Vec<u32>>> {
    let mut rng = Rng::new(0xBA7C);
    let mut seq = |n: usize| -> Vec<u32> {
        (0..n).map(|_| rng.below(vocab) as u32).collect()
    };
    vec![
        vec![seq(1)],                             // 1 row   → [4], 3 pad rows
        vec![seq(5)],                             // 5 rows  → [4, 4], ragged tail
        vec![seq(64), seq(4)],                    // 68 rows → [64, 4], dense
        vec![seq(256), seq(64), seq(16), seq(4)], // 340 rows → full tile grid
    ]
}

fn forward(engine: &mut ServingEngine, batch: &[Vec<u32>]) -> Vec<Matrix> {
    let refs: Vec<&[u32]> = batch.iter().map(|s| s.as_slice()).collect();
    engine.forward_batch(&refs).expect("forward")
}

fn assert_bit_identical(a: &[Matrix], b: &[Matrix], what: &str) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!((x.rows, x.cols), (y.rows, y.cols));
        for (u, v) in x.data.iter().zip(&y.data) {
            assert!(u.to_bits() == v.to_bits(), "{what}: seq {i} diverged");
        }
    }
}

#[test]
fn grouped_matches_sequential_bit_for_bit() {
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let cfg = serving_cfg();
    let plan = mixed_plan(&cfg);
    let lm = MoeLm::random(&cfg, &mut Rng::new(MODEL_SEED));
    let mut engine = ServingEngine::new(lm, &artifacts, &plan).unwrap();
    assert_eq!(engine.dispatch_mode(), DispatchMode::Grouped, "grouped is the default");
    // the mixed plan must actually exercise all four families
    let families: Vec<RuntimeScheme> = engine.scheme_counts().iter().map(|(s, _)| *s).collect();
    assert_eq!(families.len(), 4, "plan collapsed to {families:?}");

    for batch in uneven_batches(cfg.vocab as u64) {
        engine.set_dispatch_mode(DispatchMode::Sequential);
        let seq = forward(&mut engine, &batch);
        engine.set_dispatch_mode(DispatchMode::Grouped);
        let grouped = forward(&mut engine, &batch);
        let rows: usize = batch.iter().map(|s| s.len()).sum();
        assert_bit_identical(&seq, &grouped, &format!("{rows} concatenated rows"));
    }

    let m = engine.metrics();
    assert!(m.grouped_dispatches > 0, "grouped path never ran");
    assert!(m.waves >= m.grouped_dispatches, "each dispatch runs ≥ 1 wave");
    assert!(m.max_concurrent_waves >= 2, "mixed plan should expose concurrent waves");
    assert!(m.wave_fill_ratio() > 0.0 && m.wave_fill_ratio() <= 1.0);
    assert!(m.wave_latency_summary().is_some());
    // both paths count tiles identically
    assert!(m.padded_tokens >= m.useful_rows);
}

#[test]
fn grouped_deterministic_across_thread_counts() {
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let cfg = serving_cfg();
    let plan = mixed_plan(&cfg);
    let batch = &uneven_batches(cfg.vocab as u64)[3]; // 340 rows, every tile size
    let mut reference: Option<Vec<Matrix>> = None;
    for threads in [1usize, 2, 5, 11] {
        let lm = MoeLm::random(&cfg, &mut Rng::new(MODEL_SEED));
        let mut engine = ServingEngine::new(lm, &artifacts, &plan).unwrap();
        engine.set_dispatch_threads(threads);
        let out = forward(&mut engine, batch);
        match &reference {
            None => reference = Some(out),
            Some(r) => assert_bit_identical(r, &out, &format!("threads={threads}")),
        }
    }
}

#[test]
fn grouped_handles_shared_only_rows() {
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    // 1-token batch: most routed experts are empty; the shared expert and
    // at most topk routed experts carry the whole dispatch
    let cfg = serving_cfg();
    let plan = mixed_plan(&cfg);
    let lm = MoeLm::random(&cfg, &mut Rng::new(MODEL_SEED));
    let mut engine = ServingEngine::new(lm, &artifacts, &plan).unwrap();
    let batch = vec![vec![7u32]];
    engine.set_dispatch_mode(DispatchMode::Sequential);
    let seq = forward(&mut engine, &batch);
    engine.set_dispatch_mode(DispatchMode::Grouped);
    let grouped = forward(&mut engine, &batch);
    assert_bit_identical(&seq, &grouped, "single-token batch");
}
