//! Integration: the rust native model must reproduce the JAX trainer's
//! forward pass on the trained weights (parity tensors exported by
//! `python/compile/train_lm.py`).

use std::path::PathBuf;

use mxmoe::moe::{ModelConfig, MoeLm};
use mxmoe::ser::MxtFile;

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn check_model(name: &str) {
    let dir = artifacts();
    let model_path = dir.join(format!("model_{name}.mxt"));
    let parity_path = dir.join(format!("parity_{name}.mxt"));
    if !model_path.exists() || !parity_path.exists() {
        eprintln!("skipping {name}: run `make models` first");
        return;
    }
    let cfg = ModelConfig::by_name(name).unwrap();
    let lm = MoeLm::load_mxt(&cfg, &MxtFile::load(&model_path).unwrap()).unwrap();
    let parity = MxtFile::load(&parity_path).unwrap();
    let tokens: Vec<u32> = parity
        .get("tokens")
        .unwrap()
        .to_i32()
        .unwrap()
        .iter()
        .map(|&t| t as u32)
        .collect();
    let (shape, py_logits) = parity.f32("logits").unwrap();
    assert_eq!(shape, vec![tokens.len(), cfg.vocab]);

    let rust_logits = lm.forward(&tokens);
    // float-op ordering differs between XLA and our matmul: compare the
    // predictions and the numerical drift, not bit equality
    let mut max_abs = 0.0f32;
    let mut agree = 0usize;
    for pos in 0..tokens.len() {
        let rrow = rust_logits.row(pos);
        let prow = &py_logits[pos * cfg.vocab..(pos + 1) * cfg.vocab];
        let argmax = |row: &[f32]| {
            (0..row.len()).max_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap()).unwrap()
        };
        if argmax(rrow) == argmax(prow) {
            agree += 1;
        }
        for c in 0..cfg.vocab {
            max_abs = max_abs.max((rrow[c] - prow[c]).abs());
        }
    }
    let agree_frac = agree as f64 / tokens.len() as f64;
    assert!(
        max_abs < 2e-2,
        "{name}: jax/rust logit drift {max_abs} too large — architectures diverged"
    );
    assert!(
        agree_frac > 0.95,
        "{name}: argmax agreement only {agree_frac}"
    );
    println!("{name}: max |Δlogit| = {max_abs:.2e}, argmax agreement {agree_frac:.3}");
}

#[test]
fn parity_mixtral_mini() {
    check_model("mixtral-mini");
}

#[test]
fn parity_qwen15_mini() {
    check_model("qwen15-mini");
}

#[test]
fn parity_qwen2_mini() {
    check_model("qwen2-mini");
}

#[test]
fn parity_dsv2_mini() {
    check_model("dsv2-mini");
}
