//! Integration: the paged KV cache under serving load (DESIGN.md
//! §KV-Paging).
//!
//! The paging invariant anchors everything: fp32 paging changes where KV
//! rows live, never one arithmetic operation, so a cluster generation on
//! 4-token pages must reproduce the default-page engine reference bit for
//! bit, at 1 and 4 replicas. On top of that: refcounted prefix sharing
//! between generations of the same prompt (driven natively so the step
//! sequence is deterministic), liveness of a page pool half the naive
//! worst-case reservation, KV-exhausted admission backpressure with a
//! retry hint, sealed-page quantization end to end, and the occupancy
//! gauges flowing through to the Prometheus export.

use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Duration;

use mxmoe::coordinator::{Cluster, ClusterConfig, ServeConfig, ServingEngine};
use mxmoe::harness::{mixed_runtime_plan, require_artifacts, save_model_mxt};
use mxmoe::moe::{ModelConfig, MoeLm};
use mxmoe::obs::export::prometheus_text;
use mxmoe::serve::{
    Admission, DecodePolicy, DecodeScheduler, FinishReason, GenSpec, KvQuantConfig,
    RejectReason, Request, RequestKind, Response, ServeRequest, StepOutcome, StreamEvent,
    Ticket,
};
use mxmoe::util::Rng;

const WAIT: Duration = Duration::from_secs(300);

/// Serving-shape model (hidden=128, inter=64 — what the AOT export ships).
fn serving_cfg() -> ModelConfig {
    ModelConfig {
        name: "kvpage-test".into(),
        vocab: 64,
        hidden: 128,
        layers: 2,
        heads: 4,
        n_experts: 4,
        n_shared: 1,
        topk: 2,
        inter: 64,
        dense_first: false,
        seq_len: 16,
    }
}

fn seq(cfg: &ModelConfig, rng: &mut Rng, len: usize) -> Vec<u32> {
    (0..len).map(|_| rng.below(cfg.vocab as u64) as u32).collect()
}

fn boot_weights(name: &str, seed: u64) -> (ModelConfig, MoeLm, PathBuf) {
    let cfg = serving_cfg();
    let weights = std::env::temp_dir().join(format!("mxmoe_kvpage_{name}.mxt"));
    let lm = MoeLm::random(&cfg, &mut Rng::new(seed));
    save_model_mxt(&lm, &weights).unwrap();
    (cfg, lm, weights)
}

fn start_cluster(
    cfg: &ModelConfig,
    weights: &PathBuf,
    artifacts: &PathBuf,
    replicas: usize,
    decode: DecodePolicy,
) -> Cluster {
    Cluster::start(
        cfg.clone(),
        weights.clone(),
        artifacts.clone(),
        mixed_runtime_plan(cfg),
        ClusterConfig {
            replicas,
            serve: ServeConfig {
                max_batch_seqs: 1,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
            decode,
            ..Default::default()
        },
    )
    .unwrap()
}

fn collect_generation(ticket: &Ticket) -> (Vec<u32>, FinishReason, (u32, u64)) {
    let (tokens, reason) = ticket.collect_tokens(WAIT).expect("token stream");
    let resp = ticket.wait_timeout(WAIT).expect("final response");
    (tokens, reason, (resp.next_token, resp.mean_nll.to_bits()))
}

// ---------------------------------------------------------------- native

struct GenHandle {
    stream: mpsc::Receiver<StreamEvent>,
    _reply: mpsc::Receiver<Response>,
}

fn gen_request(prompt: Vec<u32>, max_new: usize) -> (Request, GenHandle) {
    let (reply, reply_rx) = mpsc::channel();
    let (stream, stream_rx) = mpsc::channel();
    let req = Request {
        kind: RequestKind::Generate(GenSpec { max_new_tokens: max_new, stop: vec![], stream }),
        ..Request::new(prompt, reply)
    };
    (req, GenHandle { stream: stream_rx, _reply: reply_rx })
}

/// One scheduler step against the native model (no PJRT).
fn native_step(sched: &mut DecodeScheduler, lm: &MoeLm) -> StepOutcome {
    sched.step(|inputs| {
        Ok(lm.forward_step_batch_with_moe(inputs, |_, block, x| block.forward(x)))
    })
}

fn drain(h: &GenHandle) -> (Vec<u32>, Option<FinishReason>) {
    let mut tokens = Vec::new();
    let mut reason = None;
    while let Ok(ev) = h.stream.try_recv() {
        match ev {
            StreamEvent::Token { token, .. } => tokens.push(token),
            StreamEvent::Done { reason: r, .. } => reason = Some(r),
        }
    }
    (tokens, reason)
}

#[test]
fn shared_prefix_pages_are_refcounted_and_reclaimed() {
    // two generations whose prompts share an 8-token (= two full 4-token
    // pages) prefix: the second admission must resolve those pages to the
    // first sequence's sealed pages (one physical copy), generate exactly
    // what a solo run generates, and release everything on retirement
    let cfg = serving_cfg();
    let lm = MoeLm::random(&cfg, &mut Rng::new(0x9A6E));
    let mut rng = Rng::new(0x9A6F);
    let prompt = seq(&cfg, &mut rng, 8);
    let mut longer = prompt.clone();
    longer.push((prompt[0] + 1) % cfg.vocab as u32);
    let policy = DecodePolicy { kv_page_size: 4, ..DecodePolicy::default() };

    // solo reference for the longer prompt
    let mut solo = DecodeScheduler::new(&cfg, policy.clone());
    let (req, h) = gen_request(longer.clone(), 3);
    solo.admit(req);
    while solo.has_work() {
        native_step(&mut solo, &lm);
    }
    let (want, want_reason) = drain(&h);
    assert_eq!(want.len(), 3);
    assert_eq!(want_reason, Some(FinishReason::Length));

    let mut sched = DecodeScheduler::new(&cfg, policy);
    let (req_a, ha) = gen_request(prompt.clone(), 3);
    sched.admit(req_a);
    // step 1: A prefills its prompt; both full prompt pages seal and
    // register their content hash in the share map
    native_step(&mut sched, &lm);
    let (req_b, hb) = gen_request(longer.clone(), 3);
    sched.admit(req_b);
    // step 2: B is promoted — its two full prompt blocks resolve to A's
    // sealed pages; only the divergent tail gets a fresh page
    native_step(&mut sched, &lm);
    let occ = sched.occupancy();
    assert_eq!(occ.shared_tokens, 8, "two 4-token pages shared: {occ:?}");
    assert_eq!(
        occ.reserved_tokens, 16,
        "B added one private page to A's three, not three more: {occ:?}"
    );
    while sched.has_work() {
        native_step(&mut sched, &lm);
    }
    let (got_b, reason_b) = drain(&hb);
    assert_eq!(got_b, want, "shared-prefix generation diverged from the solo run");
    assert_eq!(reason_b, Some(FinishReason::Length));
    let (got_a, reason_a) = drain(&ha);
    assert_eq!(got_a.len(), 3);
    assert_eq!(reason_a, Some(FinishReason::Length));
    let end = sched.occupancy();
    assert_eq!(
        (end.reserved_tokens, end.shared_tokens, end.seqs),
        (0, 0, 0),
        "retirement must return every page: {end:?}"
    );
    assert_eq!(end.freed_seqs, 2);
}

// ---------------------------------------------------------------- cluster

/// Drive a generation through a locally-owned engine + decode scheduler
/// with the *default* (16-token-page) policy — the reference the paged
/// cluster runs are compared against bit for bit.
fn engine_reference_generation(
    cfg: &ModelConfig,
    weights: &PathBuf,
    artifacts: &PathBuf,
    prompt: &[u32],
    max_new: usize,
) -> (Vec<u32>, FinishReason, (u32, u64)) {
    let weights_file = mxmoe::ser::MxtFile::load(weights).unwrap();
    let lm = MoeLm::load_mxt(cfg, &weights_file).unwrap();
    let mut engine = ServingEngine::new(lm, artifacts, &mixed_runtime_plan(cfg)).unwrap();
    let mut sched = DecodeScheduler::new(cfg, DecodePolicy::default());
    let (reply, reply_rx) = mpsc::channel();
    let (stream, stream_rx) = mpsc::channel();
    sched.admit(Request {
        kind: RequestKind::Generate(GenSpec { max_new_tokens: max_new, stop: vec![], stream }),
        ..Request::new(prompt.to_vec(), reply)
    });
    let mut finished = Vec::new();
    while sched.has_work() {
        let out = sched.step(|inputs| engine.forward_step_batch(inputs));
        finished.extend(out.finished);
    }
    drop(reply_rx);
    assert_eq!(finished.len(), 1);
    let fin = &finished[0];
    let mut tokens = Vec::new();
    let mut reason = None;
    while let Ok(ev) = stream_rx.try_recv() {
        match ev {
            StreamEvent::Token { token, .. } => tokens.push(token),
            StreamEvent::Done { reason: r, generated } => {
                assert_eq!(generated, tokens.len());
                reason = Some(r);
            }
        }
    }
    (
        tokens,
        reason.expect("terminal event"),
        (fin.last_token.unwrap_or(0), fin.mean_prompt_nll.to_bits()),
    )
}

#[test]
fn small_page_cluster_bit_identical_to_default_page_reference_at_1_and_4_replicas() {
    // the tentpole invariant end to end: a cluster storing KV in 4-token
    // pages (4× more page-table traversals, different physical layout)
    // must reproduce the 16-token-page engine reference bit for bit
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (cfg, _, weights) = boot_weights("smallpage", 0x9A60);
    let mut rng = Rng::new(0x9A61);
    let prompts: Vec<Vec<u32>> = vec![seq(&cfg, &mut rng, 9), seq(&cfg, &mut rng, 14)];
    let max_new = 6usize;
    let reference: Vec<_> = prompts
        .iter()
        .map(|p| engine_reference_generation(&cfg, &weights, &artifacts, p, max_new))
        .collect();
    let decode = DecodePolicy { kv_page_size: 4, ..DecodePolicy::default() };
    for replicas in [1usize, 4] {
        let cluster = start_cluster(&cfg, &weights, &artifacts, replicas, decode.clone());
        for (p, want) in prompts.iter().zip(&reference) {
            let ticket = cluster.generate(p.clone(), max_new, vec![]).unwrap();
            let got = collect_generation(&ticket);
            assert_eq!(got.0, want.0, "{replicas}-replica paged token stream diverged");
            assert_eq!(got.1, want.1);
            assert_eq!(got.2, want.2, "{replicas}-replica paged response bits diverged");
        }
        let report = cluster.shutdown();
        let flat = report.flatten();
        assert_eq!(flat.generations, prompts.len());
        assert!(flat.kv_peak_tokens > 0);
        assert_eq!(flat.kv_preemptions, 0, "an uncontended pool never preempts");
    }
    let _ = std::fs::remove_file(&weights);
}

#[test]
fn tight_page_pool_serves_concurrent_generations_to_completion() {
    // three concurrent generations, each growing to 16 tokens (48-token
    // naive worst case), on a 24-token page pool: lazy claiming, deferral
    // and preempt-youngest must drive all three to completion
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (cfg, _, weights) = boot_weights("tightpool", 0x9A62);
    let mut rng = Rng::new(0x9A63);
    let max_new = 8usize;
    let decode =
        DecodePolicy { kv_budget_tokens: 24, kv_page_size: 4, ..DecodePolicy::default() };
    let cluster = start_cluster(&cfg, &weights, &artifacts, 1, decode);
    let tickets: Vec<Ticket> = (0..3)
        .map(|_| cluster.generate(seq(&cfg, &mut rng, 8), max_new, vec![]).unwrap())
        .collect();
    for ticket in &tickets {
        let (tokens, reason, _) = collect_generation(ticket);
        assert_eq!(tokens.len(), max_new, "every generation runs to its budget");
        assert_eq!(reason, FinishReason::Length);
    }
    let report = cluster.shutdown();
    assert_eq!(report.admission.admitted, 3);
    let flat = report.flatten();
    assert_eq!(flat.generations, 3);
    assert_eq!(flat.generated_tokens, 3 * max_new);
    let _ = std::fs::remove_file(&weights);
}

#[test]
fn kv_exhausted_generations_shed_with_retry_hint() {
    // a page-starved pool must turn `try_submit` generations away at the
    // front door (reason `KvExhausted`, retry hint > 0) instead of
    // deepening the decode FIFO — and keep serving once pages free up
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (cfg, _, weights) = boot_weights("kvshed", 0x9A64);
    let mut rng = Rng::new(0x9A65);
    let prompt = seq(&cfg, &mut rng, 8);
    let decode =
        DecodePolicy { kv_budget_tokens: 32, kv_page_size: 16, ..DecodePolicy::default() };
    let cluster = start_cluster(&cfg, &weights, &artifacts, 1, decode);
    let long = cluster.generate(prompt.clone(), 256, vec![]).unwrap();
    // wait until the long generation demonstrably holds pages…
    let mut seen = 0usize;
    while seen < 3 {
        match long.wait_event(WAIT).unwrap() {
            StreamEvent::Token { .. } => seen += 1,
            StreamEvent::Done { .. } => panic!("256-token generation finished too early"),
        }
    }
    // …then the follow-up needs prompt + headroom = 32 tokens of pages,
    // more than the pool has left: shed, not queued
    let verdict =
        cluster.try_submit(ServeRequest::generate(prompt.clone(), 4, vec![])).unwrap();
    match verdict {
        Admission::Rejected { reason, retry_after, .. } => {
            assert_eq!(reason, RejectReason::KvExhausted);
            assert!(retry_after >= Duration::from_millis(1), "retry hint: {retry_after:?}");
        }
        Admission::Admitted(_) => panic!("page-starved pool must shed the generation"),
    }
    // cancel the page holder: the freed pool serves the next generation
    long.cancel();
    let next = cluster.generate(prompt, 4, vec![]).unwrap();
    let (tokens, reason, _) = collect_generation(&next);
    assert_eq!(tokens.len(), 4);
    assert_eq!(reason, FinishReason::Length);
    let report = cluster.shutdown();
    assert_eq!(report.admission.admitted, 2);
    assert_eq!(report.admission.cancelled, 1);
    let flat = report.flatten();
    assert_eq!(flat.rejected_kv, 1, "the shed generation lands in the KV reject counter");
    assert_eq!(flat.generations, 1);
    let _ = std::fs::remove_file(&weights);
}

#[test]
fn quantized_kv_policy_serves_and_exports_occupancy_gauges() {
    // sealed-page quantization through the full serving stack: an 8-bit
    // uniform KV plan still completes generations, and the new occupancy
    // gauges/counters appear in the Prometheus rendering of the report
    let Some(artifacts) = require_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (cfg, _, weights) = boot_weights("kvquant", 0x9A66);
    let mut rng = Rng::new(0x9A67);
    let prompt = seq(&cfg, &mut rng, 8);
    let decode = DecodePolicy {
        kv_page_size: 4,
        kv_quant: Some(KvQuantConfig::uniform(cfg.layers, 8, -1)),
        ..DecodePolicy::default()
    };
    let cluster = start_cluster(&cfg, &weights, &artifacts, 1, decode);
    let ticket = cluster.generate(prompt, 6, vec![]).unwrap();
    let (tokens, reason, (_, nll_bits)) = collect_generation(&ticket);
    assert_eq!(tokens.len(), 6, "quantized KV pages still complete the generation");
    assert_eq!(reason, FinishReason::Length);
    assert!(f64::from_bits(nll_bits).is_finite());
    let report = cluster.shutdown();
    let flat = report.flatten();
    assert_eq!(flat.generations, 1);
    let text = prometheus_text(&flat);
    for needle in [
        "mxmoe_kv_used_tokens",
        "mxmoe_kv_shared_tokens",
        "mxmoe_kv_avg_bits",
        "mxmoe_kv_preemptions_total",
        "mxmoe_rejected_total{reason=\"kv_exhausted\"}",
    ] {
        assert!(text.contains(needle), "prometheus export missing {needle}");
    }
    let _ = std::fs::remove_file(&weights);
}
