//! Tile scheduling — the makespan-minimization component of §4.3.
//!
//! Tiles with heterogeneous costs (different precisions, different tile
//! shapes) must be mapped onto `P` SMs. The paper uses Graham's greedy LPT
//! (longest processing time first) heuristic, near-optimal because the tile
//! count far exceeds the SM count; we also provide FIFO (the naive order)
//! and an exact branch-and-bound for small instances to quantify LPT's gap
//! in tests.

/// Greedy list scheduling in the given order: each task goes to the
/// earliest-available machine. Returns the makespan.
pub fn list_makespan(costs: &[f64], machines: usize) -> f64 {
    assert!(machines > 0);
    // binary-heap of (finish_time, machine) — use a simple Vec-based heap
    // keyed on f64 via ordered wrapper
    let mut finish = vec![0.0f64; machines];
    for &c in costs {
        // pick min-finish machine (machines ≤ a few hundred: linear scan is
        // faster than heap churn for our sizes and trivially correct)
        let (idx, _) = finish
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        finish[idx] += c;
    }
    finish.iter().cloned().fold(0.0, f64::max)
}

/// FIFO: list scheduling in submission order.
pub fn fifo_makespan(costs: &[f64], machines: usize) -> f64 {
    list_makespan(costs, machines)
}

/// LPT: sort descending, then list-schedule. Graham bound: ≤ 4/3 − 1/(3P)
/// of optimal.
pub fn lpt_makespan(costs: &[f64], machines: usize) -> f64 {
    let mut sorted = costs.to_vec();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    list_makespan(&sorted, machines)
}

/// LPT that also returns the per-machine assignment (simulator uses this to
/// attribute tiles to SMs).
pub fn lpt_assign(costs: &[f64], machines: usize) -> (f64, Vec<usize>) {
    let mut order: Vec<usize> = (0..costs.len()).collect();
    order.sort_by(|&a, &b| costs[b].partial_cmp(&costs[a]).unwrap());
    let mut finish = vec![0.0f64; machines];
    let mut assign = vec![0usize; costs.len()];
    for &i in &order {
        let (idx, _) = finish
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        finish[idx] += costs[i];
        assign[i] = idx;
    }
    (finish.iter().cloned().fold(0.0, f64::max), assign)
}

/// Exact minimum makespan by branch-and-bound (small instances only — used
/// to verify LPT's near-optimality, and mirroring the paper's remark that
/// dynamic programming is optimal but too expensive).
pub fn optimal_makespan_small(costs: &[f64], machines: usize) -> f64 {
    assert!(costs.len() <= 16, "exact solver is exponential; use LPT");
    let mut sorted = costs.to_vec();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let lower = {
        let sum: f64 = sorted.iter().sum();
        (sum / machines as f64).max(sorted.first().copied().unwrap_or(0.0))
    };
    let mut best = lpt_makespan(costs, machines);
    let mut loads = vec![0.0f64; machines];
    fn bb(sorted: &[f64], i: usize, loads: &mut [f64], best: &mut f64, lower: f64) {
        if *best <= lower {
            return; // provably optimal already
        }
        if i == sorted.len() {
            let mk = loads.iter().cloned().fold(0.0, f64::max);
            if mk < *best {
                *best = mk;
            }
            return;
        }
        let mut tried = Vec::new();
        for m in 0..loads.len() {
            // symmetry breaking: skip machines with identical load
            if tried.iter().any(|&l: &f64| (l - loads[m]).abs() < 1e-12) {
                continue;
            }
            tried.push(loads[m]);
            if loads[m] + sorted[i] >= *best {
                continue;
            }
            loads[m] += sorted[i];
            bb(sorted, i + 1, loads, best, lower);
            loads[m] -= sorted[i];
        }
    }
    bb(&sorted, 0, &mut loads, &mut best, lower);
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn single_machine_is_sum() {
        let costs = [3.0, 1.0, 2.0];
        assert!((lpt_makespan(&costs, 1) - 6.0).abs() < 1e-12);
    }

    #[test]
    fn lpt_beats_bad_fifo_order() {
        // classic adversarial order: many small then one huge
        let mut costs = vec![1.0; 16];
        costs.push(8.0);
        let fifo = fifo_makespan(&costs, 4);
        let lpt = lpt_makespan(&costs, 4);
        assert!(lpt <= fifo);
        assert!((lpt - 8.0).abs() < 1e-9, "lpt {lpt}"); // 8 dominates; rest fit in parallel
    }

    #[test]
    fn lpt_within_graham_bound_of_optimal() {
        let mut rng = Rng::new(130);
        for _ in 0..20 {
            let n = 3 + rng.below(10) as usize;
            let machines = 2 + rng.below(3) as usize;
            let costs: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 5.0)).collect();
            let opt = optimal_makespan_small(&costs, machines);
            let lpt = lpt_makespan(&costs, machines);
            let bound = 4.0 / 3.0 - 1.0 / (3.0 * machines as f64);
            assert!(lpt <= opt * bound + 1e-9, "lpt {lpt} opt {opt} bound {bound}");
            assert!(lpt >= opt - 1e-9);
        }
    }

    #[test]
    fn makespan_lower_bounds_hold() {
        let mut rng = Rng::new(131);
        let costs: Vec<f64> = (0..200).map(|_| rng.range_f64(0.1, 2.0)).collect();
        let machines = 16;
        let mk = lpt_makespan(&costs, machines);
        let sum: f64 = costs.iter().sum();
        let maxc = costs.iter().cloned().fold(0.0, f64::max);
        assert!(mk >= sum / machines as f64 - 1e-9);
        assert!(mk >= maxc - 1e-9);
        // many small tiles ⇒ near-perfect balance (paper's justification for
        // the T ≈ Σc/P approximation)
        assert!(mk <= sum / machines as f64 * 1.1);
    }

    #[test]
    fn assignment_is_consistent() {
        let costs = [5.0, 3.0, 3.0, 2.0, 2.0];
        let (mk, assign) = lpt_assign(&costs, 2);
        let mut loads = [0.0f64; 2];
        for (i, &m) in assign.iter().enumerate() {
            loads[m] += costs[i];
        }
        assert!((loads.iter().cloned().fold(0.0, f64::max) - mk).abs() < 1e-12);
        assert!((mk - 8.0).abs() < 1e-9, "optimal split 8/7, got {mk}");
    }
}
