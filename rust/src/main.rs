//! mxmoe CLI — leader entrypoint.
//!
//! Subcommands:
//!   gen-corpus      write the synthetic corpus MXT (build-time input of
//!                   the JAX trainer; rust is the source of truth)
//!   gen-mini-model  write the deterministic `ci-mini` checkpoint (seeded
//!                   random init, serving-shape experts) so CI exercises
//!                   `make models`-gated paths without training
//!   allocate        run calibration + sensitivity + the MCKP allocator on
//!                   a trained mini model and dump the Tab.-7-style plan
//!   serve           pointer to the serving driver example
//!   info            print model registry + environment

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use mxmoe::alloc::{allocate, calibrate, measure_sensitivity, AllocatorConfig, Granularity};
use mxmoe::costmodel::GpuSpec;
use mxmoe::data::{Corpus, CorpusSpec};
use mxmoe::moe::{ModelConfig, MoeLm};
use mxmoe::quant::SchemeRegistry;
use mxmoe::ser::MxtFile;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal flag parser: `--key value` pairs after the subcommand.
struct Args {
    cmd: String,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse() -> Result<Args> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "info".to_string());
        let mut flags = HashMap::new();
        while let Some(k) = it.next() {
            let key = k
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got '{k}'"))?
                .to_string();
            let v = it.next().with_context(|| format!("--{key} needs a value"))?;
            flags.insert(key, v);
        }
        Ok(Args { cmd, flags })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
            None => Ok(default),
        }
    }

    fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be a number")),
            None => Ok(default),
        }
    }
}

fn run() -> Result<()> {
    let args = Args::parse()?;
    match args.cmd.as_str() {
        "gen-corpus" => gen_corpus(&args),
        "gen-mini-model" => gen_mini_model(&args),
        "allocate" => cmd_allocate(&args),
        "serve" => {
            println!("run: cargo run --release --example serve_mixed_precision");
            Ok(())
        }
        "info" | "--help" | "-h" => {
            println!("mxmoe {} — MxMoE reproduction (see README.md)", mxmoe::version());
            println!("\nmodels:");
            for c in ModelConfig::all_minis() {
                println!(
                    "  {:14} experts={}+{} topk={} hidden={} inter={} params={:.1}M",
                    c.name,
                    c.n_experts,
                    c.n_shared,
                    c.topk,
                    c.hidden,
                    c.inter,
                    c.param_count() as f64 / 1e6
                );
            }
            println!("\ncommands: gen-corpus | gen-mini-model | allocate | serve | info");
            Ok(())
        }
        other => bail!("unknown command '{other}' (try: info)"),
    }
}

/// `make mini-model`: a deterministic tiny `MoeLm` checkpoint (seeded
/// random init — no training) in the exact MXT layout `make models`
/// produces, so model-gated tests and examples run in CI. Pure function of
/// the model registry + RNG + serializer: CI caches the output on a hash
/// of those sources.
fn gen_mini_model(args: &Args) -> Result<()> {
    let name = args.get("model", "ci-mini");
    let cfg = ModelConfig::by_name(&name)?;
    let out = PathBuf::from(
        args.flags
            .get("out")
            .cloned()
            .unwrap_or_else(|| format!("artifacts/model_{name}.mxt")),
    );
    let mut rng = mxmoe::util::Rng::new(mxmoe::harness::MINI_MODEL_SEED);
    let lm = MoeLm::random(&cfg, &mut rng);
    if let Some(dir) = out.parent() {
        std::fs::create_dir_all(dir)?;
    }
    mxmoe::harness::save_model_mxt(&lm, &out)?;
    println!(
        "wrote {} ({} — {:.2}M params, seed {:#x})",
        out.display(),
        cfg.name,
        cfg.param_count() as f64 / 1e6,
        mxmoe::harness::MINI_MODEL_SEED
    );
    Ok(())
}

fn gen_corpus(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.get("out", "artifacts/corpus.mxt"));
    let spec = CorpusSpec {
        vocab: args.get_usize("vocab", 512)?,
        seed: args.get_usize("seed", 1234)? as u64,
        ..Default::default()
    };
    let train_len = args.get_usize("train-len", 400_000)?;
    let valid_len = args.get_usize("valid-len", 60_000)?;
    let corpus = Corpus::generate(&spec, train_len, valid_len);
    if let Some(dir) = out.parent() {
        std::fs::create_dir_all(dir)?;
    }
    corpus.save(&out)?;
    println!(
        "wrote {} (train {} tokens, valid {}, vocab {})",
        out.display(),
        train_len,
        valid_len,
        spec.vocab
    );
    Ok(())
}

fn load_model(args: &Args) -> Result<(ModelConfig, MoeLm, Corpus)> {
    let name = args.get("model", "qwen15-mini");
    let dir = PathBuf::from(args.get("artifacts", "artifacts"));
    let cfg = ModelConfig::by_name(&name)?;
    let weights = MxtFile::load(&dir.join(format!("model_{name}.mxt")))
        .context("load model weights (run `make models` first)")?;
    let lm = MoeLm::load_mxt(&cfg, &weights)?;
    let corpus = Corpus::load(&dir.join("corpus.mxt")).context("load corpus.mxt")?;
    Ok((cfg, lm, corpus))
}

fn cmd_allocate(args: &Args) -> Result<()> {
    let (cfg, lm, corpus) = load_model(args)?;
    let r = args.get_f64("r", 0.75)?;
    let bits = args.get_f64("bits", 5.0)?;
    let gran = match args.get("granularity", "linear").as_str() {
        "linear" => Granularity::LinearBlock,
        "expert" => Granularity::Expert,
        g => bail!("unknown granularity '{g}'"),
    };
    let n_calib = args.get_usize("calib-seqs", 16)?;
    let seqs = corpus.sequences("train", cfg.seq_len);
    let calib: Vec<&[u32]> = seqs.iter().take(n_calib).copied().collect();

    eprintln!("calibrating on {} sequences...", calib.len());
    let stats = calibrate(&lm, &calib, None)?;
    eprintln!("measuring sensitivity...");
    let registry = if bits <= 4.5 {
        SchemeRegistry::weight_only()
    } else {
        SchemeRegistry::weight_activation()
    };
    let sens = measure_sensitivity(&lm, &stats, &registry)?;
    eprintln!("solving MCKP (r={r}, target {bits} bits)...");
    let alloc = allocate(
        &lm,
        &GpuSpec::rtx4090(),
        &registry,
        &stats,
        &sens,
        &AllocatorConfig { r, target_avg_bits: bits, granularity: gran, batch_tokens: 512 },
    )?;
    println!("{}", alloc.to_json().pretty());
    eprintln!(
        "avg weight bits {:.3}, avg act bits {:.3}",
        alloc.avg_weight_bits(&cfg),
        alloc.avg_act_bits(&cfg)
    );
    Ok(())
}
