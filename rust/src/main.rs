//! mxmoe CLI — leader entrypoint.
//!
//! Subcommands:
//!   gen-corpus      write the synthetic corpus MXT (build-time input of
//!                   the JAX trainer; rust is the source of truth)
//!   gen-mini-model  write the deterministic `ci-mini` checkpoint (seeded
//!                   random init, serving-shape experts) so CI exercises
//!                   `make models`-gated paths without training
//!   allocate        run calibration + sensitivity + the MCKP allocator on
//!                   a trained mini model and dump the Tab.-7-style plan
//!   serve           pointer to the serving driver example
//!   trace-dump      run a traced serving pipeline (online replan + decode)
//!                   and export the Chrome trace / JSONL / Prometheus text
//!   trace-validate  validate a Chrome trace-event file the way CI does
//!   scenario        run | list | validate the declarative workload
//!                   scenarios in scenarios/ (DESIGN.md §Scenario-Engine);
//!                   `run` emits BENCH_scenario_<name>.json with an SLO
//!                   verdict and exits non-zero on a fail verdict
//!   bench-validate  schema-check every BENCH_*.json in a directory
//!                   (shared mxmoe-bench-v1 envelope + scenario verdict
//!                   blocks) and fail on any fail verdict
//!   bench-compare   diff two mxmoe-bench-v1 files metric by metric with a
//!                   regression threshold; warn-only unless --enforce true
//!   status          fetch /v1/status from a running server and render the
//!                   fleet snapshot + latest plan provenance
//!   info            print model registry + environment

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use mxmoe::alloc::{allocate, calibrate, measure_sensitivity, AllocatorConfig, Granularity};
use mxmoe::costmodel::GpuSpec;
use mxmoe::data::{Corpus, CorpusSpec};
use mxmoe::moe::{ModelConfig, MoeLm};
use mxmoe::quant::SchemeRegistry;
use mxmoe::ser::MxtFile;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal flag parser: `--key value` pairs after the subcommand.
struct Args {
    cmd: String,
    /// Bare positional operands; only `bench-compare` takes any
    /// (`<old.json> <new.json>`), every other command is flags-only.
    pos: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse() -> Result<Args> {
        let mut it = std::env::args().skip(1).peekable();
        let mut cmd = it.next().unwrap_or_else(|| "info".to_string());
        // command groups take one bare subaction ("scenario run") before
        // the strict --flag pairs
        if cmd == "scenario" {
            if let Some(sub) = it.peek().filter(|a| !a.starts_with("--")).cloned() {
                it.next();
                cmd = format!("{cmd} {sub}");
            }
        }
        let mut pos = Vec::new();
        if cmd == "bench-compare" {
            while let Some(a) = it.peek().filter(|a| !a.starts_with("--")).cloned() {
                it.next();
                pos.push(a);
            }
        }
        let mut flags = HashMap::new();
        while let Some(k) = it.next() {
            let key = k
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got '{k}'"))?
                .to_string();
            let v = it.next().with_context(|| format!("--{key} needs a value"))?;
            flags.insert(key, v);
        }
        Ok(Args { cmd, pos, flags })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
            None => Ok(default),
        }
    }

    fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be a number")),
            None => Ok(default),
        }
    }
}

fn run() -> Result<()> {
    let args = Args::parse()?;
    match args.cmd.as_str() {
        "gen-corpus" => gen_corpus(&args),
        "gen-mini-model" => gen_mini_model(&args),
        "allocate" => cmd_allocate(&args),
        "serve" => {
            println!("run: cargo run --release --example serve_mixed_precision");
            Ok(())
        }
        "trace-dump" => cmd_trace_dump(&args),
        "trace-validate" => cmd_trace_validate(&args),
        "scenario run" => cmd_scenario_run(&args),
        "scenario list" => cmd_scenario_list(),
        "scenario validate" => cmd_scenario_validate(&args),
        "scenario" => bail!("scenario needs a subaction: run | list | validate"),
        "bench-validate" => cmd_bench_validate(&args),
        "bench-compare" => cmd_bench_compare(&args),
        "status" => cmd_status(&args),
        "info" | "--help" | "-h" => {
            println!("mxmoe {} — MxMoE reproduction (see README.md)", mxmoe::version());
            println!("\nmodels:");
            for c in ModelConfig::all_minis() {
                println!(
                    "  {:14} experts={}+{} topk={} hidden={} inter={} params={:.1}M",
                    c.name,
                    c.n_experts,
                    c.n_shared,
                    c.topk,
                    c.hidden,
                    c.inter,
                    c.param_count() as f64 / 1e6
                );
            }
            println!(
                "\ncommands: gen-corpus | gen-mini-model | allocate | serve | \
                 trace-dump | trace-validate | scenario run|list|validate | \
                 bench-validate | bench-compare | status | info"
            );
            Ok(())
        }
        other => bail!("unknown command '{other}' (try: info)"),
    }
}

/// `make mini-model`: a deterministic tiny `MoeLm` checkpoint (seeded
/// random init — no training) in the exact MXT layout `make models`
/// produces, so model-gated tests and examples run in CI. Pure function of
/// the model registry + RNG + serializer: CI caches the output on a hash
/// of those sources.
fn gen_mini_model(args: &Args) -> Result<()> {
    let name = args.get("model", "ci-mini");
    let cfg = ModelConfig::by_name(&name)?;
    let out = PathBuf::from(
        args.flags
            .get("out")
            .cloned()
            .unwrap_or_else(|| format!("artifacts/model_{name}.mxt")),
    );
    let mut rng = mxmoe::util::Rng::new(mxmoe::harness::MINI_MODEL_SEED);
    let lm = MoeLm::random(&cfg, &mut rng);
    if let Some(dir) = out.parent() {
        std::fs::create_dir_all(dir)?;
    }
    mxmoe::harness::save_model_mxt(&lm, &out)?;
    println!(
        "wrote {} ({} — {:.2}M params, seed {:#x})",
        out.display(),
        cfg.name,
        cfg.param_count() as f64 / 1e6,
        mxmoe::harness::MINI_MODEL_SEED
    );
    Ok(())
}

fn gen_corpus(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.get("out", "artifacts/corpus.mxt"));
    let spec = CorpusSpec {
        vocab: args.get_usize("vocab", 512)?,
        seed: args.get_usize("seed", 1234)? as u64,
        ..Default::default()
    };
    let train_len = args.get_usize("train-len", 400_000)?;
    let valid_len = args.get_usize("valid-len", 60_000)?;
    let corpus = Corpus::generate(&spec, train_len, valid_len);
    if let Some(dir) = out.parent() {
        std::fs::create_dir_all(dir)?;
    }
    corpus.save(&out)?;
    println!(
        "wrote {} (train {} tokens, valid {}, vocab {})",
        out.display(),
        train_len,
        valid_len,
        spec.vocab
    );
    Ok(())
}

fn load_model(args: &Args) -> Result<(ModelConfig, MoeLm, Corpus)> {
    let name = args.get("model", "qwen15-mini");
    let dir = PathBuf::from(args.get("artifacts", "artifacts"));
    let cfg = ModelConfig::by_name(&name)?;
    let weights = MxtFile::load(&dir.join(format!("model_{name}.mxt")))
        .context("load model weights (run `make models` first)")?;
    let lm = MoeLm::load_mxt(&cfg, &weights)?;
    let corpus = Corpus::load(&dir.join("corpus.mxt")).context("load corpus.mxt")?;
    Ok((cfg, lm, corpus))
}

/// `trace-dump`: run the whole serving pipeline — typed admission,
/// continuous batching, KV-cached decode, online replan + hot-swap — with
/// lifecycle tracing on, then export the merged trace as Chrome
/// trace-event JSON (open at <https://ui.perfetto.dev>), JSONL, and a
/// Prometheus-style text snapshot, and validate the Chrome file the same
/// way CI does.
fn cmd_trace_dump(args: &Args) -> Result<()> {
    use mxmoe::alloc::activation_frequencies;
    use mxmoe::coordinator::{slo_class_name, Cluster, ClusterConfig, OnlineConfig, ServeConfig};
    use mxmoe::harness::{mixed_runtime_plan, require_artifacts, save_model_mxt};
    use mxmoe::obs::{validate_chrome_trace, TraceConfig};
    use mxmoe::serve::{Priority, QosClass, ReplanConfig, Replanner, ServeRequest};
    use std::time::Duration;

    let Some(artifacts) = require_artifacts() else {
        bail!("AOT artifacts not built — run `make artifacts` first");
    };
    let out = PathBuf::from(args.get("out", "artifacts/trace.json"));
    let replicas = args.get_usize("replicas", 2)?;
    let n_score = args.get_usize("requests", 24)?;
    let n_gen = args.get_usize("generate", 4)?;

    // serving-shape model (hidden=128, inter=64 — the tile shapes the AOT
    // export ships); seeded random init, no training needed for tracing
    let cfg = ModelConfig {
        name: "trace-dump".into(),
        vocab: 64,
        hidden: 128,
        layers: 2,
        heads: 4,
        n_experts: 4,
        n_shared: 1,
        topk: 2,
        inter: 64,
        dense_first: false,
        seq_len: 16,
    };
    let mut rng = mxmoe::util::Rng::new(0x7ACE);
    let lm = MoeLm::random(&cfg, &mut rng);
    let weights = std::env::temp_dir().join("mxmoe_trace_dump.mxt");
    save_model_mxt(&lm, &weights)?;

    // calibration → sensitivity → replanner; booting from the scrambled
    // mixed plan means the forced re-solve below actually changes slots,
    // so the dump records a real hot-swap (stage + install spans)
    let calib: Vec<Vec<u32>> = (0..8)
        .map(|_| (0..cfg.seq_len).map(|_| rng.below(cfg.vocab as u64) as u32).collect())
        .collect();
    let calib_refs: Vec<&[u32]> = calib.iter().map(|s| s.as_slice()).collect();
    eprintln!("calibrating + measuring sensitivity...");
    let stats = calibrate(&lm, &calib_refs, None)?;
    let registry = SchemeRegistry::weight_activation();
    let sens = measure_sensitivity(&lm, &stats, &registry)?;
    let replanner = Replanner {
        gpu: GpuSpec::rtx4090(),
        registry,
        sens,
        cfg: ReplanConfig {
            drift_threshold: 0.0, // replan on any drift: the dump must show one
            min_tokens_between: 1,
            alloc: AllocatorConfig {
                r: 0.75,
                target_avg_bits: 5.0,
                granularity: Granularity::LinearBlock,
                batch_tokens: 512,
            },
        },
    };

    eprintln!("starting {replicas}-replica traced cluster...");
    let cluster = Cluster::start_online(
        cfg.clone(),
        weights,
        artifacts,
        mixed_runtime_plan(&cfg),
        ClusterConfig {
            replicas,
            serve: ServeConfig {
                max_batch_seqs: 4,
                max_wait: Duration::from_millis(2),
                trace: TraceConfig::on(),
                ..Default::default()
            },
            ..Default::default()
        },
        OnlineConfig {
            replanner,
            baseline: activation_frequencies(&stats),
            ewma_alpha: Some(0.25),
        },
    )?;

    let qos = [QosClass::Interactive, QosClass::Standard, QosClass::Batch];
    let mut tickets = Vec::new();
    for i in 0..n_score {
        let seq: Vec<u32> =
            (0..cfg.seq_len).map(|_| rng.below(cfg.vocab as u64) as u32).collect();
        let mut req = ServeRequest::new(seq).qos(qos[i % qos.len()]);
        if i % 3 == 0 {
            req = req.priority(Priority::High).deadline(Duration::from_secs(30));
        }
        tickets.push(cluster.submit_request(req)?);
    }
    for _ in 0..n_gen {
        let prompt: Vec<u32> = (0..8).map(|_| rng.below(cfg.vocab as u64) as u32).collect();
        tickets.push(cluster.generate(prompt, 8, vec![])?);
    }
    for t in &tickets {
        t.wait_timeout(Duration::from_secs(600))?;
    }
    let report = cluster.shutdown();

    if let Some(dir) = out.parent() {
        std::fs::create_dir_all(dir)?;
    }
    report.trace.write_chrome_trace(&out)?;
    let jsonl = out.with_extension("jsonl");
    report.trace.write_jsonl(&jsonl)?;
    let prom = out.with_extension("prom");
    std::fs::write(&prom, mxmoe::obs::export::prometheus_text(&report.flatten()))?;

    let check = validate_chrome_trace(&std::fs::read_to_string(&out)?)?;
    let replans: usize = report.replicas.iter().map(|r| r.replans).sum();
    let swaps: usize = report.replicas.iter().map(|r| r.swaps).sum();
    println!(
        "wrote {} ({} events: {} async pairs, {} spans, {} instants), {}, {}",
        out.display(),
        check.events,
        check.begins,
        check.completes,
        check.instants,
        jsonl.display(),
        prom.display()
    );
    println!(
        "pipeline: {} served, {} replan(s), {} hot-swap(s), {} trace event(s) dropped",
        report.total_requests(),
        replans,
        swaps,
        report.trace.dropped
    );
    for (i, s) in report.slo_by_class().iter().enumerate() {
        if s.served > 0 {
            println!(
                "slo[{:11}] served {:3}  hit-rate {:.2}  queue {:.1}ms  compute {:.1}ms  \
                 stream {:.1}ms",
                slo_class_name(i),
                s.served,
                s.hit_rate(),
                1e3 * s.queue_s,
                1e3 * s.compute_s,
                1e3 * s.stream_s
            );
        }
    }
    for (g, n) in report.served_by_generation() {
        println!("served-bits: plan generation {g} served {n} request(s)");
    }
    Ok(())
}

/// `trace-validate`: CI-grade structural check of a Chrome trace-event
/// file — well-formed JSON, required fields, non-decreasing timestamps,
/// and matched async begin/end pairs per request id.
fn cmd_trace_validate(args: &Args) -> Result<()> {
    use mxmoe::obs::validate_chrome_trace;

    let path = PathBuf::from(args.get("trace", "artifacts/trace.json"));
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("read {} (run `mxmoe trace-dump` first)", path.display()))?;
    let check = validate_chrome_trace(&text)?;
    println!(
        "{}: OK — {} events ({} async begins, {} async ends, {} complete spans, {} instants)",
        path.display(),
        check.events,
        check.begins,
        check.ends,
        check.completes,
        check.instants
    );
    Ok(())
}

/// `scenario run`: replay one spec (`--name`) or the whole checked-in
/// suite against a mini-model cluster, write one
/// `BENCH_scenario_<name>.json` per scenario into `--out-dir`, and exit
/// non-zero if any SLO verdict fails. `--mode smoke` reports wall-clock
/// checks without enforcing them (the CI setting); the default `full`
/// mode enforces everything.
fn cmd_scenario_run(args: &Args) -> Result<()> {
    use mxmoe::harness::scenario::{list_specs, load_named_spec, run_scenario, RunOptions};

    let smoke = match args.get("mode", "full").as_str() {
        "full" => false,
        "smoke" => true,
        m => bail!("unknown --mode '{m}' (full|smoke)"),
    };
    let out_dir = PathBuf::from(args.get("out-dir", "."));
    std::fs::create_dir_all(&out_dir)?;
    let specs = match args.flags.get("name") {
        Some(name) => vec![load_named_spec(name)?],
        None => list_specs()?,
    };
    ensure_artifacts_for_scenarios()?;
    let opts = RunOptions { smoke, dispatch_threads: None };
    let mut failed = Vec::new();
    for spec in &specs {
        eprintln!(
            "running scenario '{}' ({} ticks, {} replica(s))...",
            spec.name, spec.ticks, spec.replicas
        );
        let outcome = run_scenario(spec, &opts)?;
        let path = outcome.write(&out_dir)?;
        let l = &outcome.ledger;
        println!(
            "{:18} {:4}  arrivals {:3}  admitted {:3}  served {:3}  shed {:3}  \
             cancelled {:2}  failed {:2}  replans {:2}  ({:.1}s) -> {}",
            spec.name,
            outcome.verdict.status().to_uppercase(),
            l.arrivals,
            l.admitted,
            l.responses,
            l.shed(),
            l.cancelled,
            l.failed,
            outcome.slo.replans,
            outcome.elapsed_s,
            path.display()
        );
        for c in outcome.verdict.checks.iter().filter(|c| !c.pass) {
            println!(
                "  {} check '{}': {} {} {}",
                if c.enforced { "FAIL" } else { "warn (unenforced)" },
                c.name,
                c.value,
                c.op,
                c.bound
            );
        }
        if !outcome.verdict.passed() {
            failed.push(spec.name.clone());
        }
    }
    if !failed.is_empty() {
        bail!("{} scenario verdict(s) failed: {}", failed.len(), failed.join(", "));
    }
    Ok(())
}

fn ensure_artifacts_for_scenarios() -> Result<()> {
    if mxmoe::harness::require_artifacts().is_none() {
        bail!("AOT artifacts not built — run `make artifacts` first");
    }
    Ok(())
}

/// `scenario list`: one line per checked-in spec.
fn cmd_scenario_list() -> Result<()> {
    use mxmoe::harness::scenario::{list_specs, scenarios_dir};

    let specs = list_specs()?;
    println!("{} scenario(s) in {}:", specs.len(), scenarios_dir().display());
    for s in &specs {
        println!(
            "  {:20} seed {:4}  ticks {:3}  replicas {}  {}  {}",
            s.name,
            s.seed,
            s.ticks,
            s.replicas,
            if s.deterministic { "deterministic " } else { "best-effort   " },
            s.description
        );
    }
    Ok(())
}

/// `scenario validate`: parse + semantic-validate every spec (or one via
/// `--spec <path>`) and round-trip it through its JSON encoding.
fn cmd_scenario_validate(args: &Args) -> Result<()> {
    use mxmoe::harness::scenario::{list_specs, load_spec, scenarios_dir, ScenarioSpec};

    let specs = match args.flags.get("spec") {
        Some(p) => vec![load_spec(&PathBuf::from(p))?],
        None => list_specs()?,
    };
    for s in &specs {
        let back = ScenarioSpec::parse(&s.to_json().pretty())
            .with_context(|| format!("scenario '{}' does not round-trip", s.name))?;
        if back != *s {
            bail!("scenario '{}' round-trips to a different spec", s.name);
        }
        println!("{:20} OK", s.name);
    }
    println!("{} scenario(s) valid (dir: {})", specs.len(), scenarios_dir().display());
    Ok(())
}

/// `bench-validate`: schema-check every `BENCH_*.json` under `--dir`
/// against the shared `mxmoe-bench-v1` envelope (plus the full
/// ledger/SLO/verdict block for scenario benches) and exit non-zero on a
/// malformed file or a `fail` verdict.
fn cmd_bench_validate(args: &Args) -> Result<()> {
    use mxmoe::harness::scenario::validate_bench_json;

    let dir = PathBuf::from(args.get("dir", "."));
    let mut paths: Vec<PathBuf> = std::fs::read_dir(&dir)
        .with_context(|| format!("read {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
        })
        .collect();
    paths.sort();
    if paths.is_empty() {
        bail!("no BENCH_*.json files under {}", dir.display());
    }
    let mut fail_verdicts = Vec::new();
    for p in &paths {
        let name = p.file_name().unwrap().to_string_lossy().to_string();
        let text = std::fs::read_to_string(p)?;
        let check =
            validate_bench_json(&text).with_context(|| format!("{name} failed validation"))?;
        let verdict = check.verdict.as_deref().unwrap_or("-");
        println!(
            "{name:40} bench={:24} smoke={:5} verdict={verdict}",
            check.bench, check.smoke
        );
        if check.verdict.as_deref() == Some("fail") {
            fail_verdicts.push(name);
        }
    }
    if !fail_verdicts.is_empty() {
        bail!("{} fail verdict(s): {}", fail_verdicts.len(), fail_verdicts.join(", "));
    }
    println!("{} bench file(s) valid", paths.len());
    Ok(())
}

/// Numeric leaves of a bench JSON as dotted paths (`slo.per_class[0]
/// .p99_ms`). Subtrees that are not point-comparable metrics — the
/// `timeseries` block, per-check verdict rows, the seed — are skipped,
/// as are non-finite values (`Json::num` serialises those as null
/// anyway).
fn flatten_metrics(j: &mxmoe::ser::Json, prefix: &str, out: &mut Vec<(String, f64)>) {
    use mxmoe::ser::Json;

    match j {
        Json::Num(x) => {
            if x.is_finite() {
                out.push((prefix.to_string(), *x));
            }
        }
        Json::Obj(m) => {
            for (k, v) in m {
                if matches!(k.as_str(), "schema" | "seed" | "timeseries" | "checks") {
                    continue;
                }
                let path = if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
                flatten_metrics(v, &path, out);
            }
        }
        Json::Arr(v) => {
            for (i, item) in v.iter().enumerate() {
                flatten_metrics(item, &format!("{prefix}[{i}]"), out);
            }
        }
        _ => {}
    }
}

/// Direction a metric regresses in, by name: `Some(true)` = higher is
/// worse (latency-like), `Some(false)` = lower is worse
/// (throughput-like), `None` = no known direction (reported, never a
/// regression). Worse-if-up is checked first so e.g. `shed_rate` reads
/// as a shed metric despite the `rate` suffix.
fn higher_is_worse(path: &str) -> Option<bool> {
    const WORSE_UP: &[&str] = &[
        "p50", "p99", "latency", "elapsed", "overhead", "wait", "miss", "shed", "rejected",
        "failed", "cancelled", "preempt", "dropped", "kills",
    ];
    const WORSE_DOWN: &[&str] =
        &["tps", "throughput", "rate", "hit", "served", "admitted", "responses", "tokens"];
    let p = path.to_ascii_lowercase();
    if WORSE_UP.iter().any(|w| p.contains(w)) {
        return Some(true);
    }
    if WORSE_DOWN.iter().any(|w| p.contains(w)) {
        return Some(false);
    }
    None
}

/// `bench-compare <old.json> <new.json>`: metric-by-metric diff of two
/// `mxmoe-bench-v1` files. Numeric leaves are flattened to dotted paths
/// and compared wherever both files carry them; a metric whose name
/// implies a direction moving the wrong way by more than `--threshold`
/// percent is a regression. Warn-only by default (CI runs it against the
/// previous run's artifacts on a best-effort basis); `--enforce true`
/// exits non-zero on any regression.
fn cmd_bench_compare(args: &Args) -> Result<()> {
    use std::collections::HashSet;

    use mxmoe::harness::scenario::BENCH_SCHEMA;
    use mxmoe::ser::Json;

    let [old_path, new_path] = args.pos.as_slice() else {
        bail!("bench-compare needs exactly two files: <old.json> <new.json>");
    };
    let threshold = args.get_f64("threshold", 10.0)?;
    let enforce = matches!(args.get("enforce", "false").as_str(), "true" | "1");
    let load = |path: &str| -> Result<Json> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        let schema = j.get("schema").and_then(Json::as_str).unwrap_or("<missing>");
        if schema != BENCH_SCHEMA {
            bail!("{path}: schema '{schema}' is not '{BENCH_SCHEMA}'");
        }
        Ok(j)
    };
    let old = load(old_path)?;
    let new = load(new_path)?;
    let kind = |j: &Json| j.get("bench").and_then(Json::as_str).unwrap_or("?").to_string();
    let (ok, nk) = (kind(&old), kind(&new));
    if ok != nk {
        bail!("cannot compare bench '{ok}' against bench '{nk}'");
    }

    let mut old_m = Vec::new();
    flatten_metrics(&old, "", &mut old_m);
    let mut new_m = Vec::new();
    flatten_metrics(&new, "", &mut new_m);
    let old_map: HashMap<&str, f64> = old_m.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    let new_keys: HashSet<&str> = new_m.iter().map(|(k, _)| k.as_str()).collect();

    println!("bench '{ok}': {old_path} -> {new_path} (threshold {threshold}%)");
    let mut regressions = Vec::new();
    let mut compared = 0usize;
    for (path, n) in &new_m {
        let Some(&o) = old_map.get(path.as_str()) else { continue };
        compared += 1;
        let delta_pct = if o == *n {
            0.0
        } else if o == 0.0 {
            f64::INFINITY * (*n - o).signum()
        } else {
            100.0 * (*n - o) / o.abs()
        };
        let verdict = match higher_is_worse(path) {
            Some(true) if delta_pct > threshold => "REGRESSION",
            Some(false) if delta_pct < -threshold => "REGRESSION",
            Some(_) if delta_pct.abs() > threshold => "improved",
            _ => "ok",
        };
        if verdict == "REGRESSION" {
            regressions.push(path.clone());
        }
        println!("  {verdict:10} {path:44} {o} -> {n} ({delta_pct:+.1}%)");
    }
    let added = new_m.len() - compared;
    let removed = old_m.iter().filter(|(k, _)| !new_keys.contains(k.as_str())).count();
    println!(
        "compared {compared} metric(s), {added} new, {removed} removed: {} regression(s)",
        regressions.len()
    );
    if regressions.is_empty() {
        println!("verdict: pass");
    } else if enforce {
        bail!("{} metric regression(s): {}", regressions.len(), regressions.join(", "));
    } else {
        println!("verdict: warn (not enforced — pass `--enforce true` to fail on regressions)");
    }
    Ok(())
}

/// `status`: fetch `/v1/status` from a running mxmoe HTTP server and
/// render the fleet snapshot — admission/decode/KV counters, per-class
/// SLO, the sampled time series' latest values, and the latest plan's
/// provenance (which experts changed scheme and why).
fn cmd_status(args: &Args) -> Result<()> {
    use std::io::{Read as _, Write as _};
    use std::net::TcpStream;

    use mxmoe::ser::Json;

    let url = args.get("url", "127.0.0.1:8080");
    let addr = url.strip_prefix("http://").unwrap_or(&url).trim_end_matches('/').to_string();
    let mut stream =
        TcpStream::connect(&addr).with_context(|| format!("connect to {addr} (is it serving?)"))?;
    write!(stream, "GET /v1/status HTTP/1.1\r\nhost: {addr}\r\nconnection: close\r\n\r\n")?;
    let mut reply = String::new();
    stream.read_to_string(&mut reply).context("read /v1/status reply")?;
    let status = reply.split(' ').nth(1).unwrap_or("<none>");
    if status != "200" {
        bail!("GET /v1/status returned HTTP {status}");
    }
    let body = reply.split_once("\r\n\r\n").map_or("", |(_, b)| b);
    let j = Json::parse(body).map_err(|e| anyhow::anyhow!("status JSON: {e}"))?;
    let version = j.get("version").and_then(Json::as_str).unwrap_or("<missing>");
    if version != "mxmoe-status-v1" {
        bail!("unexpected status version '{version}' (want mxmoe-status-v1)");
    }

    let report = j.get("report").context("status JSON has no 'report' object")?;
    let num = |k: &str| report.get(k).and_then(Json::as_f64).unwrap_or(0.0);
    println!("{addr} — generation {:.0}, {:.0} replica(s)", num("generation"), num("replicas"));
    println!(
        "  requests {:.0}  admitted {:.0}  cancelled {:.0}  failed {:.0}  generations {:.0}",
        num("requests"),
        num("admitted"),
        num("cancelled"),
        num("failed"),
        num("generations")
    );
    println!(
        "  rejected: queue_full {:.0}  deadline {:.0}  quota {:.0}  kv {:.0}",
        num("rejected_queue_full"),
        num("rejected_deadline"),
        num("rejected_quota"),
        num("rejected_kv")
    );
    println!(
        "  decode {:.1} tok/s  throughput {:.1} tok/s  replans {:.0}  swaps {:.0}",
        num("decode_tps"),
        num("throughput_tps"),
        num("replans"),
        num("swaps")
    );
    println!(
        "  kv {:.0}/{:.0} tokens ({:.0} shared) @ {:.1} bits  preemptions {:.0}",
        num("kv_used_tokens"),
        num("kv_budget_tokens"),
        num("kv_shared_tokens"),
        num("kv_avg_bits"),
        num("kv_preemptions")
    );
    for c in report.get("slo").and_then(Json::as_arr).unwrap_or(&[]) {
        let served = c.get("served").and_then(Json::as_f64).unwrap_or(0.0);
        if served == 0.0 {
            continue;
        }
        println!(
            "  slo[{:11}] served {:4.0}  hit-rate {:.2}",
            c.get("class").and_then(Json::as_str).unwrap_or("?"),
            served,
            c.get("hit_rate").and_then(Json::as_f64).unwrap_or(1.0)
        );
    }

    let series = j.get("series").and_then(Json::as_arr).unwrap_or(&[]);
    if series.is_empty() {
        println!("series: none (sampler off — enable the cluster sample config)");
    } else {
        println!("series ({}):", series.len());
        for s in series {
            let name = s.get("name").and_then(Json::as_str).unwrap_or("?");
            let points = s.get("points").and_then(Json::as_arr).unwrap_or(&[]);
            let last = points
                .last()
                .and_then(Json::as_arr)
                .and_then(|p| p.get(1))
                .and_then(Json::as_f64);
            match last {
                Some(v) => println!("  {name:28} last {v:10.2}  ({} point(s))", points.len()),
                None => println!("  {name:28} (no samples)"),
            }
        }
    }

    let plans = j.get("plans").and_then(Json::as_arr).unwrap_or(&[]);
    match plans.last() {
        None => println!("plans: none recorded"),
        Some(p) => {
            let pn = |k: &str| p.get(k).and_then(Json::as_f64).unwrap_or(0.0);
            println!(
                "latest plan: replica {:.0} generation {:.0} trigger {} drift {:.3} r {:.2}  \
                 {:.2} -> {:.2} bits  {:.0}/{:.0} slot(s) changed",
                pn("replica"),
                pn("generation"),
                p.get("trigger").and_then(Json::as_str).unwrap_or("?"),
                pn("drift"),
                pn("r"),
                pn("bits_before"),
                pn("bits_after"),
                pn("changed"),
                pn("slots")
            );
            let decisions = p.get("decisions").and_then(Json::as_arr).unwrap_or(&[]);
            for d in decisions {
                if !d.get("changed").and_then(Json::as_bool).unwrap_or(false) {
                    continue;
                }
                let dn = |k: &str| d.get(k).and_then(Json::as_f64).unwrap_or(0.0);
                println!(
                    "  layer {:.0} expert {:.0}{}: {} -> {}  (sens {:.4}, freq {:.4}, {:.1} bits)",
                    dn("layer"),
                    dn("expert"),
                    if d.get("shared").and_then(Json::as_bool).unwrap_or(false) {
                        " (shared)"
                    } else {
                        ""
                    },
                    d.get("prev").and_then(Json::as_str).unwrap_or("—"),
                    d.get("scheme").and_then(Json::as_str).unwrap_or("?"),
                    dn("sensitivity"),
                    dn("freq"),
                    dn("bits")
                );
            }
        }
    }
    Ok(())
}

fn cmd_allocate(args: &Args) -> Result<()> {
    let (cfg, lm, corpus) = load_model(args)?;
    let r = args.get_f64("r", 0.75)?;
    let bits = args.get_f64("bits", 5.0)?;
    let gran = match args.get("granularity", "linear").as_str() {
        "linear" => Granularity::LinearBlock,
        "expert" => Granularity::Expert,
        g => bail!("unknown granularity '{g}'"),
    };
    let n_calib = args.get_usize("calib-seqs", 16)?;
    let seqs = corpus.sequences("train", cfg.seq_len);
    let calib: Vec<&[u32]> = seqs.iter().take(n_calib).copied().collect();

    eprintln!("calibrating on {} sequences...", calib.len());
    let stats = calibrate(&lm, &calib, None)?;
    eprintln!("measuring sensitivity...");
    let registry = if bits <= 4.5 {
        SchemeRegistry::weight_only()
    } else {
        SchemeRegistry::weight_activation()
    };
    let sens = measure_sensitivity(&lm, &stats, &registry)?;
    eprintln!("solving MCKP (r={r}, target {bits} bits)...");
    let alloc = allocate(
        &lm,
        &GpuSpec::rtx4090(),
        &registry,
        &stats,
        &sens,
        &AllocatorConfig { r, target_avg_bits: bits, granularity: gran, batch_tokens: 512 },
    )?;
    println!("{}", alloc.to_json().pretty());
    eprintln!(
        "avg weight bits {:.3}, avg act bits {:.3}",
        alloc.avg_weight_bits(&cfg),
        alloc.avg_act_bits(&cfg)
    );
    Ok(())
}
