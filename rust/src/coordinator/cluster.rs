//! Multi-replica sharded serving (DESIGN.md §Sharded-Serving): one
//! admission queue feeding N engine replicas through an expert-affinity
//! router.
//!
//! The router thread owns the [`ContinuousBatcher`]: it admits requests,
//! cuts batches on the same cap/budget/deadline policy the single-engine
//! server used ([`ContinuousBatcher::time_to_cut`] makes a past-deadline
//! tail re-cut immediately, never waiting on the next arrival), then
//! routes each batch to the replica whose *plan* fits it best:
//!
//! * **Affinity** ([`affinity_score`]): project the batch's per-expert row
//!   counts from the cluster-aggregated live activation frequencies, tile
//!   them through [`dispatch::fill_estimate`], and weight each expert's
//!   projected fill by the relative throughput of the runtime family the
//!   replica's plan assigns it. Replicas whose plans put the batch's hot
//!   experts on dense, low-precision waves score highest.
//! * **Load** ([`choose_replica`]): the score is discounted by the
//!   replica's backlog, and the work-stealing deques
//!   ([`crate::serve::replica::WorkQueues`]) are the fallback — an idle
//!   replica steals the oldest batch of the deepest peer, so a scoring
//!   mistake costs latency, never starvation.
//!
//! Replicas may hold *different* precision plans: under online serving
//! each replica replans from its own telemetry, and the status board keeps
//! the router's scoring current as plans drift apart.

use std::path::PathBuf;
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::alloc::Allocation;
use crate::moe::ModelConfig;
use crate::obs::{
    record_sample, Deadline, EventKind, Observatory, Outcome, ProvenanceLedger, SampleConfig,
    Sampler, SpanCollector, TraceClock, TraceConfig, TraceLog, Track,
};
use crate::runtime::dispatch;
use crate::runtime::RuntimeScheme;
use crate::ser::MxtFile;
use crate::serve::decode::DecodePolicy;
use crate::serve::queue::{ContinuousBatcher, GenSpec, RequestKind};
use crate::serve::replan::Replanner;
use crate::serve::replica::{
    replica_main, ReplicaOnline, ReplicaSpec, ReplicaStatus, RoutedBatch, WorkQueues,
};
use crate::serve::request::{
    Admission, AdmissionConfig, AdmissionState, AdmitArgs, ServeKind, ServeRequest, Ticket,
};
use crate::serve::{Request, Response};

use super::metrics::{ClusterReport, ReplicaReport, RouterStats, ServerReport};
use super::server::ServeConfig;

/// Everything the online loop needs beyond the static plans: the
/// workload-independent replanner and the calibration frequency vector
/// that seeds every replica's drift baseline.
pub struct OnlineConfig {
    pub replanner: Replanner,
    /// Per-layer routed-expert calibration frequencies
    /// ([`crate::alloc::activation_frequencies`]).
    pub baseline: Vec<Vec<f64>>,
    /// Telemetry EWMA step; `None` keeps the engine default.
    pub ewma_alpha: Option<f64>,
}

/// Router scoring knobs.
#[derive(Clone, Copy, Debug)]
pub struct AffinityConfig {
    /// Backlog discount: a replica's affinity score is divided by
    /// `1 + queue_penalty × (queued + in-flight batches)`, so affinity
    /// wins among comparably-loaded replicas and load wins under
    /// imbalance.
    pub queue_penalty: f64,
}

impl Default for AffinityConfig {
    fn default() -> Self {
        AffinityConfig { queue_penalty: 0.5 }
    }
}

/// Cluster shape + batching policy.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Engine replicas (worker threads, one PJRT client each).
    pub replicas: usize,
    pub serve: ServeConfig,
    pub affinity: AffinityConfig,
    /// Bounded-admission policy for the front door (queue-depth bounds,
    /// blocking-submit budget, projected-deadline shedding, per-class
    /// quota).
    pub admission: AdmissionConfig,
    /// Grouped-dispatch worker threads per replica (`None` = engine
    /// default). Results are bit-identical for any value ≥ 1.
    pub dispatch_threads: Option<usize>,
    /// Per-replica decode-loop sizing (step row budget, active-sequence
    /// cap, KV reservation budget).
    pub decode: DecodePolicy,
    /// Observatory sampler switch + cadence (off by default: no sampler
    /// thread is spawned and the registry stays empty).
    pub sample: SampleConfig,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            replicas: 1,
            serve: ServeConfig::default(),
            affinity: AffinityConfig::default(),
            admission: AdmissionConfig::default(),
            dispatch_threads: None,
            decode: DecodePolicy::default(),
            sample: SampleConfig::default(),
        }
    }
}

/// Roofline-derived relative serving throughput of a runtime family,
/// fp16 ≡ 1 — the *fallback* the router scores with until live wave
/// telemetry warms up ([`measured_speeds`]). Mirrors the cost model's
/// ordering on GroupGEMM shapes (lower-precision tiles move fewer bytes
/// and finish sooner); the absolute values only need to rank replicas,
/// not predict wall-clock.
pub fn scheme_speed(s: RuntimeScheme) -> f64 {
    match s {
        RuntimeScheme::Fp16 => 1.0,
        RuntimeScheme::W4A16 => 1.8,
        RuntimeScheme::W8A8 => 2.2,
        RuntimeScheme::W4A4 => 3.2,
    }
}

/// Useful rows a runtime family must have executed before its measured
/// rate replaces the roofline constant — throughput estimated from fewer
/// rows is dominated by per-wave launch noise.
pub const SPEED_WARMUP_ROWS: usize = 2048;

fn scheme_index(s: RuntimeScheme) -> usize {
    RuntimeScheme::ALL.iter().position(|&x| x == s).unwrap()
}

/// Relative per-family serving speeds the affinity scorer weighs with:
/// measured from live wave latency telemetry where warmed up, the
/// [`scheme_speed`] roofline constants elsewhere.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SchemeSpeeds {
    rel: [f64; 4],
}

impl SchemeSpeeds {
    /// Pure roofline constants (cold boot, or single-replica fast path).
    pub fn fallback() -> SchemeSpeeds {
        let mut rel = [0.0f64; 4];
        for &s in &RuntimeScheme::ALL {
            rel[scheme_index(s)] = scheme_speed(s);
        }
        SchemeSpeeds { rel }
    }

    pub fn speed(&self, s: RuntimeScheme) -> f64 {
        self.rel[scheme_index(s)]
    }

    /// Build from measured `(scheme, useful_rows, busy_s)` wave totals.
    /// Families past [`SPEED_WARMUP_ROWS`] switch to their measured
    /// rows/second, re-based so the best-measured family keeps its
    /// roofline constant — measured and constant entries stay mutually
    /// comparable even when fp16 never runs (an all-quantized plan).
    /// Families below the warmup bar keep the constants.
    pub fn from_measurements(rows: &[(RuntimeScheme, usize, f64)]) -> SchemeSpeeds {
        let mut agg = [(0usize, 0.0f64); 4]; // (rows, busy_s) per family
        for &(s, r, busy) in rows {
            let a = &mut agg[scheme_index(s)];
            a.0 += r;
            a.1 += busy;
        }
        // anchor: the warmed-up family with the most measured rows
        let anchor = RuntimeScheme::ALL
            .iter()
            .copied()
            .filter(|&s| {
                let (r, busy) = agg[scheme_index(s)];
                r >= SPEED_WARMUP_ROWS && busy > 0.0
            })
            .max_by_key(|&s| agg[scheme_index(s)].0);
        let Some(anchor) = anchor else {
            return SchemeSpeeds::fallback();
        };
        let (ar, abusy) = agg[scheme_index(anchor)];
        let anchor_rate = ar as f64 / abusy;
        let mut out = SchemeSpeeds::fallback();
        for &s in &RuntimeScheme::ALL {
            let (r, busy) = agg[scheme_index(s)];
            if r >= SPEED_WARMUP_ROWS && busy > 0.0 {
                let rate = r as f64 / busy;
                // re-base to the anchor's constant; clamp against
                // degenerate timing samples
                out.rel[scheme_index(s)] =
                    (scheme_speed(anchor) * rate / anchor_rate).clamp(0.1, 10.0);
            }
        }
        out
    }
}

/// Cluster-wide measured speeds: wave totals summed across every
/// replica's published [`ReplicaStatus::scheme_rows`], then
/// [`SchemeSpeeds::from_measurements`]. Before warmup this degrades to
/// the roofline constants.
pub fn measured_speeds(status: &[Mutex<ReplicaStatus>]) -> SchemeSpeeds {
    let mut rows: Vec<(RuntimeScheme, usize, f64)> = Vec::new();
    for s in status {
        rows.extend_from_slice(&s.lock().unwrap().scheme_rows);
    }
    SchemeSpeeds::from_measurements(&rows)
}

/// Per-scheme wave totals summed across replica statuses: at most one
/// `(family, useful_rows, busy_s)` tuple per runtime family. The
/// observatory sampler needs this shape — `record_sample` feeds each
/// family into a single `wave_rows_*_total` / `wave_busy_s_*` series, so
/// a raw concat of per-replica rows would push several different
/// "totals" into one series at the same instant and corrupt its deltas.
pub fn sum_scheme_rows(statuses: &[ReplicaStatus]) -> Vec<(RuntimeScheme, usize, f64)> {
    let mut agg = [(0usize, 0.0f64); 4]; // (rows, busy_s) per family
    let mut seen = [false; 4];
    for st in statuses {
        for &(s, r, busy) in &st.scheme_rows {
            let i = scheme_index(s);
            agg[i].0 += r;
            agg[i].1 += busy;
            seen[i] = true;
        }
    }
    RuntimeScheme::ALL
        .iter()
        .copied()
        .filter(|&s| seen[scheme_index(s)])
        .map(|s| (s, agg[scheme_index(s)].0, agg[scheme_index(s)].1))
        .collect()
}

/// Expert-affinity score of routing a `batch_tokens`-token batch to a
/// replica whose plan is `schemes` (`[block_pos][slot]`, routed then
/// shared), given the cluster's live routed-expert frequencies `freqs`
/// (`[block_pos][routed expert]`, normalized per layer).
///
/// Per layer: each routed expert's projected row count is
/// `batch_tokens × topk × freq`, tiled through
/// [`dispatch::fill_estimate`]; shared experts see every token. The score
/// is the row-weighted mean of `fill × speed` — i.e. the projected
/// useful wave throughput of this batch on this replica's plan — averaged
/// over layers, with `speeds` supplying the per-family weights (measured
/// where warmed up, roofline constants elsewhere). Higher is better; the
/// value is deterministic in its inputs.
pub fn affinity_score(
    batch_tokens: usize,
    topk: usize,
    freqs: &[Vec<f64>],
    schemes: &[Vec<RuntimeScheme>],
    speeds: &SchemeSpeeds,
) -> f64 {
    if batch_tokens == 0 {
        return 0.0;
    }
    let mut total = 0.0;
    let mut layers = 0usize;
    for (lf, ls) in freqs.iter().zip(schemes) {
        let n_routed = lf.len().min(ls.len());
        let mut weighted = 0.0; // Σ rows · fill · speed
        let mut rows_sum = 0.0; // Σ rows
        for e in 0..n_routed {
            let rows = (batch_tokens * topk) as f64 * lf[e].max(0.0);
            let r = rows.round() as usize;
            if r == 0 {
                continue;
            }
            let fill = dispatch::fill_estimate(r).fill_ratio();
            weighted += rows * fill * speeds.speed(ls[e]);
            rows_sum += rows;
        }
        for &s in &ls[n_routed..] {
            // shared experts run the whole batch
            let fill = dispatch::fill_estimate(batch_tokens).fill_ratio();
            weighted += batch_tokens as f64 * fill * speeds.speed(s);
            rows_sum += batch_tokens as f64;
        }
        if rows_sum > 0.0 {
            total += weighted / rows_sum;
            layers += 1;
        }
    }
    if layers == 0 {
        0.0
    } else {
        total / layers as f64
    }
}

/// Pick the replica with the best backlog-discounted affinity score.
/// Deterministic: ties break to the lowest replica index.
pub fn choose_replica(scores: &[f64], backlogs: &[usize], queue_penalty: f64) -> usize {
    assert!(!scores.is_empty());
    assert_eq!(scores.len(), backlogs.len());
    let mut best = 0usize;
    let mut best_v = f64::NEG_INFINITY;
    for (i, (&s, &b)) in scores.iter().zip(backlogs).enumerate() {
        let v = s / (1.0 + queue_penalty * b as f64);
        if v > best_v {
            best = i;
            best_v = v;
        }
    }
    best
}

/// Token-weighted cluster aggregate of the replicas' live frequency
/// estimates — the router's proxy for which experts the next batch will
/// hit. Before traffic, every replica publishes its boot distribution, so
/// the aggregate degrades to that.
fn cluster_freqs(status: &[Mutex<ReplicaStatus>]) -> Vec<Vec<f64>> {
    let snaps: Vec<(f64, Vec<Vec<f64>>)> = status
        .iter()
        .map(|s| {
            let g = s.lock().unwrap();
            (g.observed_tokens.max(1) as f64, g.live_freqs.clone())
        })
        .collect();
    let layers = snaps.first().map_or(0, |(_, f)| f.len());
    let mut out = Vec::with_capacity(layers);
    for l in 0..layers {
        let experts = snaps[0].1[l].len();
        let mut acc = vec![0.0f64; experts];
        let mut wsum = 0.0f64;
        for (w, f) in &snaps {
            if f.len() != layers || f[l].len() != experts {
                continue; // replica mid-publish with a different shape
            }
            for (a, v) in acc.iter_mut().zip(&f[l]) {
                *a += w * v;
            }
            wsum += w;
        }
        if wsum > 0.0 {
            for a in acc.iter_mut() {
                *a /= wsum;
            }
        }
        out.push(acc);
    }
    out
}

/// Handle to a running replica cluster.
pub struct Cluster {
    tx: mpsc::Sender<Request>,
    admission: Arc<AdmissionState>,
    admission_cfg: AdmissionConfig,
    /// Shared status board (same Arc the router scores against) — the
    /// front door reads each replica's published KV pool headroom to gate
    /// Generate admissions when the page pool is the bottleneck.
    status: Arc<Vec<Mutex<ReplicaStatus>>>,
    /// Shared work deques — retained so the front door can kill and
    /// revive individual replicas mid-run (scenario fault injection).
    queues: Arc<WorkQueues>,
    /// Boot-time spawn ingredients, kept so a killed replica can be
    /// restarted under its original id with an identical [`ReplicaSpec`].
    respawn: RespawnContext,
    router: Option<thread::JoinHandle<RouterStats>>,
    workers: Vec<(usize, thread::JoinHandle<ReplicaReport>)>,
    /// Reports from workers joined before shutdown (replica restarts) —
    /// merged into the final [`ClusterReport`] alongside the live set.
    finished: Vec<ReplicaReport>,
    /// Time-series registry the sampler thread (when enabled) folds live
    /// snapshots into; always allocated so `/v1/status` has a stable shape.
    observatory: Arc<Observatory>,
    /// Plan-provenance ledger shared with every replica's engine.
    provenance: Arc<ProvenanceLedger>,
    /// The polling thread behind [`Self::observatory`]; `None` when
    /// sampling is off (the off path spawns nothing).
    sampler: Option<Sampler>,
}

/// Everything a worker thread is built from, beyond the shared handles.
/// One copy lives on the [`Cluster`] so `restart_replica` can rebuild a
/// [`ReplicaSpec`] identical to the boot-time one.
struct RespawnContext {
    cfg: ModelConfig,
    weights: Arc<MxtFile>,
    artifacts: PathBuf,
    allocation: Allocation,
    online: Option<Arc<ReplicaOnline>>,
    dispatch_threads: Option<usize>,
    decode: DecodePolicy,
    clock: TraceClock,
    trace: TraceConfig,
    provenance: Arc<ProvenanceLedger>,
}

impl RespawnContext {
    fn spawn_worker(
        &self,
        id: usize,
        queues: &Arc<WorkQueues>,
        status: &Arc<Vec<Mutex<ReplicaStatus>>>,
        admission: &Arc<AdmissionState>,
    ) -> thread::JoinHandle<ReplicaReport> {
        let spec = ReplicaSpec {
            id,
            cfg: self.cfg.clone(),
            weights: self.weights.clone(),
            artifacts: self.artifacts.clone(),
            allocation: self.allocation.clone(),
            online: self.online.clone(),
            dispatch_threads: self.dispatch_threads,
            decode: self.decode.clone(),
            clock: self.clock.clone(),
            trace: self.trace,
            provenance: Some(self.provenance.clone()),
        };
        let q = queues.clone();
        let st = status.clone();
        let adm = admission.clone();
        thread::Builder::new()
            .name(format!("mxmoe-replica-{id}"))
            .spawn(move || replica_main(spec, q, st, adm))
            .expect("spawn replica thread")
    }
}

impl Cluster {
    /// Start a static-plan cluster: every replica boots the same
    /// allocation and serves it unchanged.
    pub fn start(
        cfg: ModelConfig,
        weights_path: PathBuf,
        artifacts: PathBuf,
        allocation: Allocation,
        cluster_cfg: ClusterConfig,
    ) -> Result<Cluster> {
        Cluster::spawn(cfg, weights_path, artifacts, allocation, cluster_cfg, None)
    }

    /// Start a cluster with per-replica online re-allocation: each replica
    /// tracks its own telemetry against the shared calibration baseline
    /// and replans independently, so plans may diverge to match the
    /// traffic each replica actually serves.
    pub fn start_online(
        cfg: ModelConfig,
        weights_path: PathBuf,
        artifacts: PathBuf,
        allocation: Allocation,
        cluster_cfg: ClusterConfig,
        online: OnlineConfig,
    ) -> Result<Cluster> {
        Cluster::spawn(cfg, weights_path, artifacts, allocation, cluster_cfg, Some(online))
    }

    fn spawn(
        cfg: ModelConfig,
        weights_path: PathBuf,
        artifacts: PathBuf,
        allocation: Allocation,
        cluster_cfg: ClusterConfig,
        online: Option<OnlineConfig>,
    ) -> Result<Cluster> {
        assert!(cluster_cfg.replicas >= 1, "a cluster needs at least one replica");
        // load weights once on the caller thread (errors surface here, not
        // inside a worker); replicas share the file and build their own
        // models/engines from it
        let weights = Arc::new(MxtFile::load(&weights_path)?);
        let online = online.map(|o| {
            Arc::new(ReplicaOnline {
                replanner: o.replanner,
                baseline: o.baseline,
                ewma_alpha: o.ewma_alpha,
            })
        });
        let n = cluster_cfg.replicas;
        let queues = WorkQueues::new(n);
        let admission = AdmissionState::new(n);
        // one clock for every track: admission, router and replica spans
        // stamp microseconds from the same origin, so the merged trace
        // lines up in Perfetto without per-thread skew correction
        let clock = TraceClock::new();
        let trace = cluster_cfg.serve.trace;
        admission.enable_trace(clock.clone(), trace);
        let status: Arc<Vec<Mutex<ReplicaStatus>>> = Arc::new(
            (0..n).map(|_| Mutex::new(ReplicaStatus::boot(&cfg, &allocation))).collect(),
        );
        let observatory = Arc::new(Observatory::new(cluster_cfg.sample.capacity));
        let provenance = Arc::new(ProvenanceLedger::default());
        let respawn = RespawnContext {
            cfg,
            weights,
            artifacts,
            allocation,
            online,
            dispatch_threads: cluster_cfg.dispatch_threads,
            decode: cluster_cfg.decode.clone(),
            clock: clock.clone(),
            trace,
            provenance: provenance.clone(),
        };
        let mut workers = Vec::with_capacity(n);
        for id in 0..n {
            workers.push((id, respawn.spawn_worker(id, &queues, &status, &admission)));
        }
        let (tx, rx) = mpsc::channel::<Request>();
        let policy = cluster_cfg.serve.policy();
        let affinity = cluster_cfg.affinity;
        let topk = respawn.cfg.topk;
        let adm = admission.clone();
        let tracer = SpanCollector::new(clock, Track::Router, trace);
        let status_board = status.clone();
        let router_queues = queues.clone();
        let router = thread::Builder::new()
            .name("mxmoe-router".into())
            .spawn(move || {
                router_loop(rx, policy, &router_queues, &status, &adm, affinity, topk, tracer)
            })
            .expect("spawn router thread");
        // Sampler thread: polls the same live surfaces the HTTP scrape
        // reads (status board + admission counters) — serving threads
        // never see it. Off by default: nothing is spawned.
        let sampler = if cluster_cfg.sample.enabled {
            let obs = observatory.clone();
            let st = status_board.clone();
            let adm = admission.clone();
            let q = queues.clone();
            Some(Sampler::spawn(cluster_cfg.sample.interval(), move |t_s| {
                let statuses: Vec<ReplicaStatus> =
                    st.iter().map(|s| s.lock().unwrap().clone()).collect();
                let report = ServerReport::live(&adm.report(), &statuses);
                let rows = sum_scheme_rows(&statuses);
                let (queued_requests, _queued_tokens) = adm.queued();
                let queued_batches: usize = q.depths().iter().sum();
                record_sample(&obs, t_s, &report, queued_requests, queued_batches, &rows);
            }))
        } else {
            None
        };
        Ok(Cluster {
            tx,
            admission,
            admission_cfg: cluster_cfg.admission,
            status: status_board,
            queues,
            respawn,
            router: Some(router),
            workers,
            finished: Vec::new(),
            observatory,
            provenance,
            sampler,
        })
    }

    /// Front-door KV gate for Generate requests: when every replica's
    /// published page pool lacks room for the prompt's pages plus one
    /// decode-headroom page, the request would only queue behind a full
    /// pool, so it is turned away with a `retry_after` derived from the
    /// fastest replica's page-release rate. Disengaged until replicas
    /// publish a nonzero KV budget (boot, or decode disabled), and an
    /// idle pool always admits — the decode scheduler's sole-sequence
    /// overflow path owns oversized prompts.
    fn kv_backpressure(&self, prompt_tokens: usize) -> Option<Duration> {
        let mut deficit = usize::MAX;
        let mut release_tps = 0.0f64;
        for s in self.status.iter() {
            let st = s.lock().unwrap();
            if st.kv_budget_tokens == 0 {
                return None;
            }
            let page = st.kv_page_size.max(1);
            let needed = prompt_tokens.div_ceil(page) * page + page;
            if needed <= st.kv_free_tokens || st.kv_free_tokens >= st.kv_budget_tokens {
                return None;
            }
            deficit = deficit.min(needed - st.kv_free_tokens);
            release_tps = release_tps.max(st.kv_release_tps);
        }
        let retry = if release_tps > 0.0 {
            Duration::from_secs_f64(deficit as f64 / release_tps)
        } else {
            // release rate not warmed up yet: a short default, clamped by
            // the admission layer either way
            Duration::from_millis(50)
        };
        Some(retry)
    }

    /// Reject malformed requests before they touch admission accounting.
    fn validate(req: &ServeRequest) -> Result<()> {
        if matches!(req.kind, ServeKind::Generate { .. }) && req.tokens.is_empty() {
            anyhow::bail!("generate: empty prompt");
        }
        Ok(())
    }

    /// Non-blocking typed submission: either a [`Ticket`] or a
    /// load-shedding rejection (queue-depth bound, class quota, projected
    /// deadline miss) with a `retry_after` estimate. Generation requests
    /// ([`ServeRequest::generate`]) get a streaming ticket.
    pub fn try_submit(&self, req: ServeRequest) -> Result<Admission> {
        Cluster::validate(&req)?;
        if matches!(req.kind, ServeKind::Generate { .. }) {
            if let Some(retry) = self.kv_backpressure(req.tokens.len()) {
                let (reason, retry_after, id) = self.admission.reject_kv(retry);
                return Ok(Admission::Rejected { id, reason, retry_after });
            }
        }
        let privileged = req.is_privileged();
        let qos = req.qos.map_or("none", |q| q.name());
        let priority = req.priority.name();
        match self.admission.try_admit_for(
            &self.admission_cfg,
            req.tokens.len(),
            req.ttl,
            privileged,
            qos,
            priority,
        ) {
            Err((reason, retry_after, id)) => Ok(Admission::Rejected { id, reason, retry_after }),
            Ok(id) => self.enqueue(req, id).map(Admission::Admitted),
        }
    }

    /// Burst-atomic submission (the scenario replay driver's front door):
    /// every request in `reqs` is decided under **one** admission lock
    /// acquisition, in order, so no concurrent cut/drain can interleave
    /// with the burst — the admit/reject pattern is a pure function of
    /// the pre-burst queue state and the burst itself. Per-request
    /// outcomes come back positionally. The Generate KV gate runs per
    /// request *before* the burst lock (it reads the replica status
    /// board, not the admission queue), mirroring
    /// [`try_submit`](Self::try_submit).
    pub fn try_submit_burst(&self, reqs: Vec<ServeRequest>) -> Result<Vec<Admission>> {
        for req in &reqs {
            Cluster::validate(req)?;
        }
        let kv: Vec<_> = reqs
            .iter()
            .map(|req| {
                if matches!(req.kind, ServeKind::Generate { .. }) {
                    self.kv_backpressure(req.tokens.len())
                        .map(|retry| self.admission.reject_kv(retry))
                } else {
                    None
                }
            })
            .collect();
        let args: Vec<AdmitArgs> = reqs
            .iter()
            .zip(&kv)
            .filter(|(_, kv)| kv.is_none())
            .map(|(req, _)| AdmitArgs {
                tokens: req.tokens.len(),
                ttl: req.ttl,
                privileged: req.is_privileged(),
                qos: req.qos.map_or("none", |q| q.name()),
                priority: req.priority.name(),
            })
            .collect();
        let mut decisions =
            self.admission.try_admit_burst(&self.admission_cfg, &args).into_iter();
        let mut out = Vec::with_capacity(reqs.len());
        for (req, kv) in reqs.into_iter().zip(kv) {
            if let Some((reason, retry_after, id)) = kv {
                out.push(Admission::Rejected { id, reason, retry_after });
                continue;
            }
            match decisions.next().expect("one decision per KV-passed request") {
                Err((reason, retry_after, id)) => {
                    out.push(Admission::Rejected { id, reason, retry_after })
                }
                // an enqueue error (cluster closed mid-burst) aborts this
                // request's admission inside enqueue and propagates; later
                // burst entries are moot once the router is gone
                Ok(id) => out.push(Admission::Admitted(self.enqueue(req, id)?)),
            }
        }
        Ok(out)
    }

    /// Typed submission that blocks for queue room up to the admission
    /// config's `submit_budget`. Errors when the budget expires while the
    /// queue is still full, when the projected wait already blows the
    /// request's deadline, or when the cluster is shutting down.
    pub fn submit_request(&self, req: ServeRequest) -> Result<Ticket> {
        Cluster::validate(&req)?;
        let privileged = req.is_privileged();
        let qos = req.qos.map_or("none", |q| q.name());
        let priority = req.priority.name();
        match self.admission.admit_blocking_for(
            &self.admission_cfg,
            req.tokens.len(),
            req.ttl,
            privileged,
            qos,
            priority,
        ) {
            Err((reason, retry_after, _id)) => Err(anyhow::anyhow!(
                "admission rejected ({reason:?}, retry after {retry_after:?})"
            )),
            Ok(id) => self.enqueue(req, id),
        }
    }

    fn enqueue(&self, req: ServeRequest, id: u64) -> Result<Ticket> {
        let ServeRequest { tokens, priority, ttl, qos, kind } = req;
        let n_tokens = tokens.len();
        let arrived = Instant::now();
        let (reply, rx) = mpsc::channel();
        let cancel = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let (kind, stream_rx) = match kind {
            ServeKind::Score => (RequestKind::Score, None),
            ServeKind::Generate { max_new_tokens, stop } => {
                let (stream, stream_rx) = mpsc::channel();
                (
                    RequestKind::Generate(GenSpec { max_new_tokens, stop, stream }),
                    Some(stream_rx),
                )
            }
        };
        let request = Request {
            id,
            tokens,
            reply,
            arrived,
            priority,
            deadline: ttl.map(|d| arrived + d),
            qos,
            kind,
            cancelled: cancel.clone(),
        };
        if self.tx.send(request).is_err() {
            self.admission.abort_admit(id, n_tokens);
            anyhow::bail!("cluster closed");
        }
        Ok(Ticket { rx, cancel, id, stream: stream_rx })
    }

    /// Legacy untyped submission; returns the raw reply receiver. A thin
    /// shim over [`submit_request`](Self::submit_request) with a default
    /// [`ServeRequest`] (Normal priority, no deadline, no QoS class) —
    /// responses are bit-identical to the typed path.
    pub fn submit(&self, tokens: Vec<u32>) -> Result<mpsc::Receiver<Response>> {
        self.submit_request(ServeRequest::new(tokens)).map(Ticket::into_receiver)
    }

    /// KV-cached generation with token streaming (DESIGN.md §Decode-Loop):
    /// shorthand for [`submit_request`](Self::submit_request) with
    /// [`ServeRequest::generate`].
    pub fn generate(&self, prompt: Vec<u32>, max_new_tokens: usize, stop: Vec<u32>) -> Result<Ticket> {
        self.submit_request(ServeRequest::generate(prompt, max_new_tokens, stop))
    }

    /// Front-door accounting so far (admitted / rejected / cancelled).
    pub fn admission_report(&self) -> crate::serve::request::AdmissionReport {
        self.admission.report()
    }

    /// Live mid-run [`ServerReport`] snapshot — admission counters plus
    /// the replica status board ([`ServerReport::live`]). The full report
    /// (latency percentiles, wave telemetry, trace) still only exists at
    /// [`shutdown`](Self::shutdown); this one backs the HTTP front door's
    /// `GET /metrics` scrape, which cannot wait for the run to end.
    pub fn live_report(&self) -> ServerReport {
        let statuses: Vec<ReplicaStatus> =
            self.status.iter().map(|s| s.lock().unwrap().clone()).collect();
        ServerReport::live(&self.admission.report(), &statuses)
    }

    /// The cluster's time-series registry: populated by the sampler when
    /// [`ClusterConfig::sample`] is enabled, otherwise empty (but always
    /// present, so status surfaces have a stable shape).
    pub fn observatory(&self) -> Arc<Observatory> {
        self.observatory.clone()
    }

    /// The cluster's plan-provenance ledger: one record per installed
    /// plan (boot + every replan), answering "why does expert (l,e) run
    /// at its scheme right now?" via [`ProvenanceLedger::explain`].
    pub fn provenance(&self) -> Arc<ProvenanceLedger> {
        self.provenance.clone()
    }

    /// Admission queue occupancy right now, as `(seqs, tokens)`. Reaches
    /// `(0, 0)` only once every admitted request has been cut into a batch
    /// *and* cancelled stragglers have been shed — the scenario replay
    /// driver polls this to quiesce between virtual ticks.
    pub fn queued(&self) -> (usize, usize) {
        self.admission.queued()
    }

    /// Number of replica slots (live or dead).
    pub fn replicas(&self) -> usize {
        self.status.len()
    }

    /// Fault injection: ask replica `id`'s worker to stop serving. The
    /// worker observes the kill flag at its loop top (or is woken out of
    /// a blocked pop), fails its in-flight decode sequences through the
    /// normal accounting ([`crate::serve::decode::DecodeScheduler::evict_all`]),
    /// marks itself dead, and exits. Batches still queued on the killed
    /// deque stay stealable by the survivors. Idempotent; does not wait
    /// for the worker — [`restart_replica`](Self::restart_replica) or
    /// [`shutdown`](Self::shutdown) joins it.
    pub fn kill_replica(&self, id: usize) {
        assert!(id < self.status.len(), "replica {id} out of range");
        self.queues.request_kill(id);
    }

    /// Restart a killed replica under its original id: join the old
    /// worker (its report is retained and merged at shutdown), reset the
    /// status-board entry to boot state, clear the dead/kill flags, and
    /// spawn a fresh worker from the boot-time spawn ingredients. The
    /// join is mandatory — two workers must never share a replica id.
    pub fn restart_replica(&mut self, id: usize) -> Result<()> {
        anyhow::ensure!(id < self.status.len(), "replica {id} out of range");
        anyhow::ensure!(
            self.queues.kill_requested(id),
            "replica {id} was not killed; nothing to restart"
        );
        if let Some(pos) = self.workers.iter().position(|(wid, _)| *wid == id) {
            let (_, handle) = self.workers.remove(pos);
            self.finished.push(handle.join().expect("replica thread panicked"));
        }
        *self.status[id].lock().unwrap() =
            ReplicaStatus::boot(&self.respawn.cfg, &self.respawn.allocation);
        self.queues.revive(id);
        self.workers.push((
            id,
            self.respawn.spawn_worker(id, &self.queues, &self.status, &self.admission),
        ));
        Ok(())
    }

    /// Close admission, drain every queue, and collect the cluster report.
    /// The per-thread span rings (admission, router, every replica) are
    /// merged here into one time-ordered [`TraceLog`] — the only place
    /// trace events from different threads ever meet.
    pub fn shutdown(mut self) -> ClusterReport {
        // Stop sampling first: a final deterministic teardown tick is not
        // worth racing the replica joins below.
        if let Some(sampler) = self.sampler.take() {
            sampler.stop();
        }
        drop(self.tx);
        let router =
            self.router.take().unwrap().join().expect("router thread panicked");
        let mut replicas: Vec<ReplicaReport> = self.finished.drain(..).collect();
        replicas.extend(
            self.workers.drain(..).map(|(_, h)| h.join().expect("replica thread panicked")),
        );
        // a restarted id yields two reports (pre-kill + post-restart);
        // the stable sort keeps them adjacent in lifetime order
        replicas.sort_by_key(|r| r.id);
        let mut parts = vec![
            self.admission.take_trace(),
            (router.trace.clone(), router.trace_dropped),
        ];
        parts.extend(replicas.iter().map(|r| (r.trace.clone(), r.trace_dropped)));
        let trace = TraceLog::merge(parts);
        ClusterReport { replicas, router, admission: self.admission.report(), trace }
    }
}

#[allow(clippy::too_many_arguments)]
fn router_loop(
    rx: mpsc::Receiver<Request>,
    policy: crate::serve::BatchPolicy,
    queues: &WorkQueues,
    status: &[Mutex<ReplicaStatus>],
    admission: &AdmissionState,
    affinity: AffinityConfig,
    topk: usize,
    mut tracer: SpanCollector,
) -> RouterStats {
    let start = Instant::now();
    let n = status.len();
    let mut batcher = ContinuousBatcher::new(policy);
    let mut stats = RouterStats::new(n);
    let mut closed = false;
    loop {
        // admit: block for the first request only when nothing is queued
        if batcher.depth() == 0 {
            if closed {
                break;
            }
            match rx.recv() {
                Ok(r) => batcher.push(r),
                Err(_) => break, // channel closed, queue drained
            }
        }
        if !closed {
            // drain whatever already arrived while the last batch was cut
            loop {
                match rx.try_recv() {
                    Ok(r) => batcher.push(r),
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        closed = true;
                        break;
                    }
                }
            }
            // wait for stragglers only as long as the cut policy allows:
            // time_to_cut is None the moment a cap is hit or the oldest
            // request (including a tail left by a token-budget cut) is past
            // its deadline — a past-deadline tail never waits for arrivals
            while !closed {
                match batcher.time_to_cut(Instant::now()) {
                    None => break,
                    Some(wait) => match rx.recv_timeout(wait) {
                        Ok(r) => batcher.push(r),
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                        Err(mpsc::RecvTimeoutError::Disconnected) => closed = true,
                    },
                }
            }
        }
        // back-pressure: a cut no replica can start only fragments load
        // into deque-queued slivers. Wait until some live replica is idle
        // — the legacy single-engine loop got adaptive batch sizing for
        // free by cutting strictly between batches; this is its cluster
        // generalization — then merge whatever arrived meanwhile into the
        // cut so batches grow under load instead of multiplying.
        if !queues.wait_for_capacity() {
            break; // every replica died at boot: nothing can ever execute
        }
        if !closed {
            loop {
                match rx.try_recv() {
                    Ok(r) => batcher.push(r),
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        closed = true;
                        break;
                    }
                }
            }
        }
        // cancellation is shed at the cut: dead requests release their
        // admission slots and are never routed — each shed id gets its
        // terminal span here, on the router track
        let shed = batcher.shed_cancelled(Instant::now());
        if !shed.is_empty() {
            let shed_tokens: usize = shed.iter().map(|s| s.tokens).sum();
            admission.note_shed_at_cut(shed.len(), shed_tokens);
            stats.shed_cancelled += shed.len();
            for s in &shed {
                tracer.instant(
                    s.id,
                    EventKind::Terminal {
                        outcome: Outcome::Shed,
                        qos: s.qos,
                        queue_us: s.queued.as_micros() as u64,
                        compute_us: 0,
                        stream_us: 0,
                        generation: 0,
                        deadline: Deadline::None,
                        tokens: s.tokens,
                    },
                );
            }
        }
        stats.max_queue_depth = stats.max_queue_depth.max(batcher.depth());
        let batch = batcher.take_batch(Instant::now());
        if batch.is_empty() {
            continue;
        }
        let cut_tokens: usize = batch.iter().map(|r| r.tokens.len()).sum();
        admission.note_cut(batch.len(), cut_tokens);
        stats.last_planned_fill = dispatch::fill_estimate(cut_tokens).fill_ratio();
        tracer.instant(
            0,
            EventKind::BatchCut {
                seqs: batch.len(),
                tokens: cut_tokens,
                fill: stats.last_planned_fill,
            },
        );
        // ---- route: affinity score per replica, discounted by backlog ----
        let chosen = if n == 1 {
            0 // single-replica façade: scoring is overhead with one answer
        } else {
            let freqs = cluster_freqs(status);
            // measured per-family speeds where wave telemetry warmed up,
            // roofline constants elsewhere
            let speeds = measured_speeds(status);
            let backlogs = queues.loads(); // queued + in-flight
            let scores: Vec<f64> = status
                .iter()
                .map(|s| {
                    affinity_score(cut_tokens, topk, &freqs, &s.lock().unwrap().schemes, &speeds)
                })
                .collect();
            choose_replica(&scores, &backlogs, affinity.queue_penalty)
        };
        stats.batches += 1;
        stats.routed[chosen] += 1;
        if tracer.enabled() {
            for r in &batch {
                tracer.instant(r.id, EventKind::Routed { replica: chosen });
            }
        }
        queues.push(chosen, RoutedBatch { requests: batch });
    }
    queues.close();
    stats.elapsed_s = start.elapsed().as_secs_f64();
    (stats.trace, stats.trace_dropped) = tracer.drain();
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform_freqs(layers: usize, experts: usize) -> Vec<Vec<f64>> {
        vec![vec![1.0 / experts as f64; experts]; layers]
    }

    #[test]
    fn speed_ranking_matches_the_cost_model_ordering() {
        assert!(scheme_speed(RuntimeScheme::W4A4) > scheme_speed(RuntimeScheme::W8A8));
        assert!(scheme_speed(RuntimeScheme::W8A8) > scheme_speed(RuntimeScheme::W4A16));
        assert!(scheme_speed(RuntimeScheme::W4A16) > scheme_speed(RuntimeScheme::Fp16));
        assert_eq!(scheme_speed(RuntimeScheme::Fp16), 1.0);
        // the fallback table mirrors the constants exactly
        let f = SchemeSpeeds::fallback();
        for &s in &RuntimeScheme::ALL {
            assert_eq!(f.speed(s), scheme_speed(s));
        }
    }

    #[test]
    fn measured_speeds_fall_back_before_warmup() {
        // nothing measured
        assert_eq!(SchemeSpeeds::from_measurements(&[]), SchemeSpeeds::fallback());
        // everything under the warmup row bar keeps the constants
        let cold = SchemeSpeeds::from_measurements(&[
            (RuntimeScheme::Fp16, SPEED_WARMUP_ROWS - 1, 0.5),
            (RuntimeScheme::W4A4, 10, 0.001),
        ]);
        assert_eq!(cold, SchemeSpeeds::fallback());
    }

    #[test]
    fn measured_speeds_track_observed_rates_and_rebase_to_the_anchor() {
        // fp16 measured at 1e6 rows/s, w4a4 at 4e6 rows/s: w4a4 comes out
        // 4× fp16 (live hardware says so), overriding the 3.2× constant
        let m = SchemeSpeeds::from_measurements(&[
            (RuntimeScheme::Fp16, 100_000, 0.1),
            (RuntimeScheme::W4A4, 40_000, 0.01),
        ]);
        // anchor = fp16 (most rows) keeps its constant 1.0
        assert!((m.speed(RuntimeScheme::Fp16) - 1.0).abs() < 1e-12);
        assert!((m.speed(RuntimeScheme::W4A4) - 4.0).abs() < 1e-9);
        // unmeasured families keep the constants
        assert_eq!(m.speed(RuntimeScheme::W8A8), scheme_speed(RuntimeScheme::W8A8));
        assert_eq!(m.speed(RuntimeScheme::W4A16), scheme_speed(RuntimeScheme::W4A16));
    }

    #[test]
    fn measured_speeds_work_without_fp16_traffic() {
        // all-quantized plan: fp16 never runs. The anchor (w8a8, most
        // rows) keeps its constant and w4a4 scales relative to it.
        let m = SchemeSpeeds::from_measurements(&[
            (RuntimeScheme::W8A8, 80_000, 0.1), // 8e5 rows/s
            (RuntimeScheme::W4A4, 40_000, 0.025), // 1.6e6 rows/s = 2× anchor
        ]);
        assert!((m.speed(RuntimeScheme::W8A8) - scheme_speed(RuntimeScheme::W8A8)).abs() < 1e-12);
        assert!(
            (m.speed(RuntimeScheme::W4A4) - 2.0 * scheme_speed(RuntimeScheme::W8A8)).abs() < 1e-9
        );
        assert_eq!(m.speed(RuntimeScheme::Fp16), 1.0, "unmeasured fp16 keeps its constant");
    }

    #[test]
    fn measured_speeds_can_flip_the_routing_preference() {
        // constants say w4a4 ≫ fp16; live telemetry says this hardware
        // runs w4a4 *slower* (e.g. dequant-bound) — the measured table
        // must flip the affinity preference between two replicas
        let freqs = vec![vec![0.9, 0.1]];
        let hot_w4a4 = vec![vec![RuntimeScheme::W4A4, RuntimeScheme::Fp16]];
        let hot_fp16 = vec![vec![RuntimeScheme::Fp16, RuntimeScheme::W4A4]];
        let constants = SchemeSpeeds::fallback();
        assert!(
            affinity_score(64, 1, &freqs, &hot_w4a4, &constants)
                > affinity_score(64, 1, &freqs, &hot_fp16, &constants)
        );
        let measured = SchemeSpeeds::from_measurements(&[
            (RuntimeScheme::Fp16, 100_000, 0.05), // 2e6 rows/s
            (RuntimeScheme::W4A4, 100_000, 0.2),  // 5e5 rows/s
        ]);
        assert!(
            affinity_score(64, 1, &freqs, &hot_fp16, &measured)
                > affinity_score(64, 1, &freqs, &hot_w4a4, &measured),
            "measured slowness must override the roofline constant"
        );
    }

    #[test]
    fn cluster_measured_speeds_aggregate_replica_rows() {
        use crate::quant::QuantScheme;
        let cfg = ModelConfig {
            name: "speeds".into(),
            vocab: 32,
            hidden: 16,
            layers: 2,
            heads: 2,
            n_experts: 2,
            n_shared: 0,
            topk: 1,
            inter: 8,
            dense_first: false,
            seq_len: 8,
        };
        let alloc = Allocation::uniform(&cfg, QuantScheme::FP16);
        let a = Mutex::new(ReplicaStatus::boot(&cfg, &alloc));
        let b = Mutex::new(ReplicaStatus::boot(&cfg, &alloc));
        assert_eq!(measured_speeds(&[]), SchemeSpeeds::fallback(), "no replicas: constants");
        // each replica alone is under the warmup bar; together they clear it
        a.lock().unwrap().scheme_rows = vec![(RuntimeScheme::Fp16, SPEED_WARMUP_ROWS / 2, 0.1)];
        b.lock().unwrap().scheme_rows = vec![(RuntimeScheme::Fp16, SPEED_WARMUP_ROWS / 2, 0.1)];
        let status = vec![a, b];
        assert_eq!(
            measured_speeds(&status[..1]),
            SchemeSpeeds::fallback(),
            "one replica's rows stay under warmup"
        );
        let m = measured_speeds(&status);
        assert!((m.speed(RuntimeScheme::Fp16) - 1.0).abs() < 1e-12, "anchored at fp16");
    }

    #[test]
    fn sum_scheme_rows_totals_each_family_once() {
        use crate::quant::QuantScheme;
        let cfg = ModelConfig {
            name: "rows".into(),
            vocab: 32,
            hidden: 16,
            layers: 2,
            heads: 2,
            n_experts: 2,
            n_shared: 0,
            topk: 1,
            inter: 8,
            dense_first: false,
            seq_len: 8,
        };
        let alloc = Allocation::uniform(&cfg, QuantScheme::FP16);
        let mut a = ReplicaStatus::boot(&cfg, &alloc);
        let mut b = ReplicaStatus::boot(&cfg, &alloc);
        a.scheme_rows =
            vec![(RuntimeScheme::Fp16, 100, 0.5), (RuntimeScheme::W4A4, 40, 0.1)];
        b.scheme_rows = vec![(RuntimeScheme::Fp16, 60, 0.25)];
        let rows = sum_scheme_rows(&[a, b]);
        // one tuple per family — the sampler feeds each family into one
        // counter series, so duplicates would corrupt its deltas
        assert_eq!(
            rows,
            vec![(RuntimeScheme::Fp16, 160, 0.75), (RuntimeScheme::W4A4, 40, 0.1)]
        );
        assert!(sum_scheme_rows(&[]).is_empty());
    }

    #[test]
    fn affinity_prefers_low_precision_on_hot_experts() {
        // expert 0 carries 90% of the routing mass; the replica that
        // serves it in w4a4 must outscore the one serving it in fp16,
        // even though both plans hold the same scheme multiset
        let freqs = vec![vec![0.9, 0.1]];
        let hot_fast = vec![vec![RuntimeScheme::W4A4, RuntimeScheme::Fp16]];
        let hot_slow = vec![vec![RuntimeScheme::Fp16, RuntimeScheme::W4A4]];
        let speeds = SchemeSpeeds::fallback();
        let a = affinity_score(64, 1, &freqs, &hot_fast, &speeds);
        let b = affinity_score(64, 1, &freqs, &hot_slow, &speeds);
        assert!(a > b, "hot-expert-fast {a} must beat hot-expert-slow {b}");
    }

    #[test]
    fn affinity_penalizes_ragged_hot_experts() {
        // same plan, different batch sizes: 64 tokens tile exactly, 65
        // tokens leave a near-empty ragged tile on every expert — the
        // projected fill (and score) must drop
        let freqs = vec![vec![0.5, 0.5]];
        let plan = vec![vec![RuntimeScheme::W8A8, RuntimeScheme::W8A8]];
        let speeds = SchemeSpeeds::fallback();
        let dense = affinity_score(128, 1, &freqs, &plan, &speeds);
        let ragged = affinity_score(130, 1, &freqs, &plan, &speeds);
        assert!(
            dense > ragged,
            "dense-tiling batch {dense} must outscore ragged {ragged}"
        );
    }

    #[test]
    fn affinity_counts_shared_experts() {
        // plans identical on routed experts, different on the shared slot
        let freqs = uniform_freqs(1, 2);
        let shared_fast =
            vec![vec![RuntimeScheme::Fp16, RuntimeScheme::Fp16, RuntimeScheme::W4A4]];
        let shared_slow =
            vec![vec![RuntimeScheme::Fp16, RuntimeScheme::Fp16, RuntimeScheme::Fp16]];
        assert!(
            affinity_score(64, 2, &freqs, &shared_fast, &SchemeSpeeds::fallback())
                > affinity_score(64, 2, &freqs, &shared_slow, &SchemeSpeeds::fallback())
        );
    }

    #[test]
    fn affinity_is_deterministic_and_bounded() {
        let freqs = vec![vec![0.7, 0.2, 0.1], vec![0.1, 0.1, 0.8]];
        let plan = vec![
            vec![RuntimeScheme::W4A4, RuntimeScheme::Fp16, RuntimeScheme::W8A8],
            vec![RuntimeScheme::W4A16, RuntimeScheme::W8A8, RuntimeScheme::Fp16],
        ];
        let speeds = SchemeSpeeds::fallback();
        let a = affinity_score(68, 2, &freqs, &plan, &speeds);
        let b = affinity_score(68, 2, &freqs, &plan, &speeds);
        assert_eq!(a, b, "scoring must be reproducible");
        assert!(a > 0.0 && a <= scheme_speed(RuntimeScheme::W4A4), "{a}");
        assert_eq!(affinity_score(0, 2, &freqs, &plan, &speeds), 0.0, "empty batch scores 0");
    }

    #[test]
    fn choose_replica_discounts_backlog_and_breaks_ties_low() {
        // equal scores: lowest index wins
        assert_eq!(choose_replica(&[1.0, 1.0, 1.0], &[0, 0, 0], 0.5), 0);
        // backlog discounts: a deep queue loses to an idle replica with a
        // slightly worse score
        assert_eq!(choose_replica(&[1.2, 1.0], &[4, 0], 0.5), 1);
        // zero penalty: pure affinity
        assert_eq!(choose_replica(&[1.2, 1.0], &[4, 0], 0.0), 0);
    }

    #[test]
    fn cluster_freqs_weights_by_observed_tokens() {
        use crate::quant::QuantScheme;
        let cfg = ModelConfig {
            name: "freqs".into(),
            vocab: 32,
            hidden: 16,
            layers: 2,
            heads: 2,
            n_experts: 2,
            n_shared: 0,
            topk: 1,
            inter: 8,
            dense_first: false,
            seq_len: 8,
        };
        let alloc = Allocation::uniform(&cfg, QuantScheme::FP16);
        let a = Mutex::new(ReplicaStatus::boot(&cfg, &alloc));
        let b = Mutex::new(ReplicaStatus::boot(&cfg, &alloc));
        {
            // replica a saw 3× the traffic, all of it on expert 0
            let mut g = a.lock().unwrap();
            g.live_freqs = vec![vec![1.0, 0.0]];
            g.observed_tokens = 300;
        }
        {
            let mut g = b.lock().unwrap();
            g.live_freqs = vec![vec![0.0, 1.0]];
            g.observed_tokens = 100;
        }
        let status = vec![a, b];
        let f = cluster_freqs(&status);
        assert_eq!(f.len(), 1);
        assert!((f[0][0] - 0.75).abs() < 1e-12, "token-weighted mean: {:?}", f[0]);
        assert!((f[0][1] - 0.25).abs() < 1e-12);
    }
}
