//! Serving metrics: latency distribution + throughput counters + grouped-
//! dispatch wave telemetry (occupancy, fill, latency percentiles).

use std::collections::BTreeMap;
use std::time::Instant;

use crate::runtime::WaveReport;
use crate::util::stats::Summary;

/// Aggregated wave counters for one runtime scheme family.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SchemeWaveStats {
    /// Waves executed under this scheme.
    pub waves: usize,
    /// Tile executions (wave members) — the scheme's occupancy.
    pub items: usize,
    /// Rows shipped to PJRT, padding included.
    pub padded_rows: usize,
    /// Useful (non-padding) rows.
    pub useful_rows: usize,
    /// Summed member execute time.
    pub busy_s: f64,
}

impl SchemeWaveStats {
    /// Useful fraction of this scheme's shipped rows.
    pub fn fill_ratio(&self) -> f64 {
        if self.padded_rows == 0 {
            return 1.0;
        }
        self.useful_rows as f64 / self.padded_rows as f64
    }
}

/// Rolling serving metrics (single-threaded engine owns it).
pub struct Metrics {
    start: Instant,
    latencies: Vec<f64>,
    queue_waits: Vec<f64>,
    pub tokens: usize,
    pub requests: usize,
    pub batches: usize,
    pub expert_calls: usize,
    /// Tile rows shipped to PJRT (incl. padding).
    pub padded_tokens: usize,
    /// Useful (non-padding) tile rows.
    pub useful_rows: usize,
    /// Expert slots hot-swapped to a new runtime family.
    pub swaps: usize,
    /// Drift-triggered MCKP re-solves.
    pub replans: usize,
    /// Telemetry drift score at the last check (total variation, [0,1]).
    pub last_drift: f64,
    /// Deepest admission queue observed at a batch cut.
    pub max_queue_depth: usize,
    /// Grouped block dispatches executed (plan → wave → scatter cycles).
    pub grouped_dispatches: usize,
    /// Waves executed across all grouped dispatches.
    pub waves: usize,
    /// Most waves in flight in a single grouped dispatch (the concurrency
    /// the mixed-precision plan actually exposed).
    pub max_concurrent_waves: usize,
    /// Batcher fill estimate at the last batch cut (planner-fed).
    pub last_planned_fill: f64,
    /// Sliding window of per-wave wall-clock samples. Waves accrue far
    /// faster than requests (several per MoE block per batch), so this is
    /// a bounded ring — percentiles reflect the most recent
    /// [`WAVE_LATENCY_WINDOW`] waves, not all-time history.
    wave_latencies: Vec<f64>,
    wave_latency_cursor: usize,
    scheme_waves: BTreeMap<&'static str, SchemeWaveStats>,
}

/// Wave-latency samples retained for percentile reporting.
pub const WAVE_LATENCY_WINDOW: usize = 4096;

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            start: Instant::now(),
            latencies: Vec::new(),
            queue_waits: Vec::new(),
            tokens: 0,
            requests: 0,
            batches: 0,
            expert_calls: 0,
            padded_tokens: 0,
            useful_rows: 0,
            swaps: 0,
            replans: 0,
            last_drift: 0.0,
            max_queue_depth: 0,
            grouped_dispatches: 0,
            waves: 0,
            max_concurrent_waves: 0,
            last_planned_fill: 1.0,
            wave_latencies: Vec::new(),
            wave_latency_cursor: 0,
            scheme_waves: BTreeMap::new(),
        }
    }

    /// Fold one grouped dispatch's wave report into the counters
    /// (tile/padding totals included, mirroring what the sequential path
    /// counts per call).
    pub fn record_dispatch(&mut self, report: &WaveReport) {
        self.grouped_dispatches += 1;
        self.waves += report.waves.len();
        self.max_concurrent_waves = self.max_concurrent_waves.max(report.waves.len());
        self.expert_calls += report.items();
        self.padded_tokens += report.padded_rows();
        self.useful_rows += report.useful_rows();
        for w in &report.waves {
            if self.wave_latencies.len() < WAVE_LATENCY_WINDOW {
                self.wave_latencies.push(w.elapsed_s);
            } else {
                self.wave_latencies[self.wave_latency_cursor] = w.elapsed_s;
                self.wave_latency_cursor = (self.wave_latency_cursor + 1) % WAVE_LATENCY_WINDOW;
            }
            let s = self.scheme_waves.entry(w.scheme.name()).or_default();
            s.waves += 1;
            s.items += w.items;
            s.padded_rows += w.padded_rows;
            s.useful_rows += w.useful_rows;
            s.busy_s += w.busy_s;
        }
    }

    /// Planner-fed batcher fill estimate at a batch cut.
    pub fn note_planned_fill(&mut self, fill_ratio: f64) {
        self.last_planned_fill = fill_ratio;
    }

    /// Wave wall-clock distribution (first launch → last completion per
    /// wave) over the most recent [`WAVE_LATENCY_WINDOW`] waves.
    pub fn wave_latency_summary(&self) -> Option<Summary> {
        if self.wave_latencies.is_empty() {
            None
        } else {
            Some(Summary::of(&self.wave_latencies))
        }
    }

    /// Per-scheme wave occupancy/fill, keyed by runtime family name.
    pub fn scheme_wave_stats(&self) -> &BTreeMap<&'static str, SchemeWaveStats> {
        &self.scheme_waves
    }

    /// Useful fraction of rows shipped by grouped dispatches.
    pub fn wave_fill_ratio(&self) -> f64 {
        let padded: usize = self.scheme_waves.values().map(|s| s.padded_rows).sum();
        if padded == 0 {
            return 1.0;
        }
        let useful: usize = self.scheme_waves.values().map(|s| s.useful_rows).sum();
        useful as f64 / padded as f64
    }

    pub fn record_request(&mut self, latency_s: f64, tokens: usize) {
        self.latencies.push(latency_s);
        self.tokens += tokens;
        self.requests += 1;
    }

    pub fn record_queue_wait(&mut self, wait_s: f64) {
        self.queue_waits.push(wait_s);
    }

    pub fn note_queue_depth(&mut self, depth: usize) {
        self.max_queue_depth = self.max_queue_depth.max(depth);
    }

    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn throughput_tps(&self) -> f64 {
        self.tokens as f64 / self.elapsed().max(1e-9)
    }

    pub fn latency_summary(&self) -> Option<Summary> {
        if self.latencies.is_empty() {
            None
        } else {
            Some(Summary::of(&self.latencies))
        }
    }

    /// Queue-wait distribution (admission → batch cut).
    pub fn queue_wait_summary(&self) -> Option<Summary> {
        if self.queue_waits.is_empty() {
            None
        } else {
            Some(Summary::of(&self.queue_waits))
        }
    }

    /// Fraction of expert-tile rows that were padding (tile-fill quality of
    /// the batcher — the quantity slice-K/tile selection fights on GPU).
    pub fn padding_ratio(&self) -> f64 {
        if self.padded_tokens == 0 {
            return 0.0;
        }
        1.0 - self.useful_rows as f64 / self.padded_tokens as f64
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.record_request(0.010, 128);
        m.record_request(0.020, 128);
        assert_eq!(m.requests, 2);
        assert_eq!(m.tokens, 256);
        let s = m.latency_summary().unwrap();
        assert!((s.mean - 0.015).abs() < 1e-9);
    }

    #[test]
    fn wave_counters_accumulate() {
        use crate::runtime::{RuntimeScheme, WaveStats};
        let mut m = Metrics::new();
        assert!(m.wave_latency_summary().is_none());
        assert_eq!(m.wave_fill_ratio(), 1.0);
        let report = WaveReport {
            waves: vec![
                WaveStats {
                    scheme: RuntimeScheme::Fp16,
                    tile_m: 64,
                    items: 2,
                    padded_rows: 128,
                    useful_rows: 128,
                    elapsed_s: 0.004,
                    busy_s: 0.006,
                },
                WaveStats {
                    scheme: RuntimeScheme::W4A4,
                    tile_m: 4,
                    items: 1,
                    padded_rows: 4,
                    useful_rows: 1,
                    elapsed_s: 0.001,
                    busy_s: 0.001,
                },
            ],
            elapsed_s: 0.005,
        };
        m.record_dispatch(&report);
        m.record_dispatch(&report);
        assert_eq!(m.grouped_dispatches, 2);
        assert_eq!(m.waves, 4);
        assert_eq!(m.max_concurrent_waves, 2);
        assert_eq!(m.expert_calls, 6);
        assert_eq!(m.padded_tokens, 264);
        assert_eq!(m.useful_rows, 258);
        let fp16 = m.scheme_wave_stats()["fp16"];
        assert_eq!((fp16.waves, fp16.items), (2, 4));
        assert!((fp16.fill_ratio() - 1.0).abs() < 1e-12);
        let w44 = m.scheme_wave_stats()["w4a4"];
        assert!((w44.fill_ratio() - 0.25).abs() < 1e-12);
        assert!((m.wave_fill_ratio() - 258.0 / 264.0).abs() < 1e-12);
        assert_eq!(m.wave_latency_summary().unwrap().n, 4);
        m.note_planned_fill(0.75);
        assert_eq!(m.last_planned_fill, 0.75);
    }

    #[test]
    fn wave_latency_window_is_bounded() {
        use crate::runtime::{RuntimeScheme, WaveStats};
        let mut m = Metrics::new();
        let wave = |elapsed_s: f64| WaveStats {
            scheme: RuntimeScheme::Fp16,
            tile_m: 4,
            items: 1,
            padded_rows: 4,
            useful_rows: 4,
            elapsed_s,
            busy_s: elapsed_s,
        };
        for i in 0..(WAVE_LATENCY_WINDOW + 100) {
            m.record_dispatch(&WaveReport { waves: vec![wave(i as f64)], elapsed_s: 0.0 });
        }
        let s = m.wave_latency_summary().unwrap();
        assert_eq!(s.n, WAVE_LATENCY_WINDOW, "ring must cap retained samples");
        // the earliest samples were overwritten by the newest
        assert!(s.min >= 100.0 - 1e-9, "oldest surviving sample is {}", s.min);
        assert_eq!(m.waves, WAVE_LATENCY_WINDOW + 100, "counters still see every wave");
    }

    #[test]
    fn online_counters() {
        let mut m = Metrics::new();
        assert!(m.queue_wait_summary().is_none());
        m.record_queue_wait(0.002);
        m.record_queue_wait(0.004);
        assert!((m.queue_wait_summary().unwrap().mean - 0.003).abs() < 1e-9);
        m.note_queue_depth(3);
        m.note_queue_depth(1);
        assert_eq!(m.max_queue_depth, 3);
        m.swaps += 2;
        m.replans += 1;
        m.last_drift = 0.4;
        assert_eq!((m.swaps, m.replans), (2, 1));
    }
}
