//! Serving metrics: latency distribution + throughput counters.

use std::time::Instant;

use crate::util::stats::Summary;

/// Rolling serving metrics (single-threaded engine owns it).
pub struct Metrics {
    start: Instant,
    latencies: Vec<f64>,
    queue_waits: Vec<f64>,
    pub tokens: usize,
    pub requests: usize,
    pub batches: usize,
    pub expert_calls: usize,
    /// Tile rows shipped to PJRT (incl. padding).
    pub padded_tokens: usize,
    /// Useful (non-padding) tile rows.
    pub useful_rows: usize,
    /// Expert slots hot-swapped to a new runtime family.
    pub swaps: usize,
    /// Drift-triggered MCKP re-solves.
    pub replans: usize,
    /// Telemetry drift score at the last check (total variation, [0,1]).
    pub last_drift: f64,
    /// Deepest admission queue observed at a batch cut.
    pub max_queue_depth: usize,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            start: Instant::now(),
            latencies: Vec::new(),
            queue_waits: Vec::new(),
            tokens: 0,
            requests: 0,
            batches: 0,
            expert_calls: 0,
            padded_tokens: 0,
            useful_rows: 0,
            swaps: 0,
            replans: 0,
            last_drift: 0.0,
            max_queue_depth: 0,
        }
    }

    pub fn record_request(&mut self, latency_s: f64, tokens: usize) {
        self.latencies.push(latency_s);
        self.tokens += tokens;
        self.requests += 1;
    }

    pub fn record_queue_wait(&mut self, wait_s: f64) {
        self.queue_waits.push(wait_s);
    }

    pub fn note_queue_depth(&mut self, depth: usize) {
        self.max_queue_depth = self.max_queue_depth.max(depth);
    }

    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn throughput_tps(&self) -> f64 {
        self.tokens as f64 / self.elapsed().max(1e-9)
    }

    pub fn latency_summary(&self) -> Option<Summary> {
        if self.latencies.is_empty() {
            None
        } else {
            Some(Summary::of(&self.latencies))
        }
    }

    /// Queue-wait distribution (admission → batch cut).
    pub fn queue_wait_summary(&self) -> Option<Summary> {
        if self.queue_waits.is_empty() {
            None
        } else {
            Some(Summary::of(&self.queue_waits))
        }
    }

    /// Fraction of expert-tile rows that were padding (tile-fill quality of
    /// the batcher — the quantity slice-K/tile selection fights on GPU).
    pub fn padding_ratio(&self) -> f64 {
        if self.padded_tokens == 0 {
            return 0.0;
        }
        1.0 - self.useful_rows as f64 / self.padded_tokens as f64
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.record_request(0.010, 128);
        m.record_request(0.020, 128);
        assert_eq!(m.requests, 2);
        assert_eq!(m.tokens, 256);
        let s = m.latency_summary().unwrap();
        assert!((s.mean - 0.015).abs() < 1e-9);
    }

    #[test]
    fn online_counters() {
        let mut m = Metrics::new();
        assert!(m.queue_wait_summary().is_none());
        m.record_queue_wait(0.002);
        m.record_queue_wait(0.004);
        assert!((m.queue_wait_summary().unwrap().mean - 0.003).abs() < 1e-9);
        m.note_queue_depth(3);
        m.note_queue_depth(1);
        assert_eq!(m.max_queue_depth, 3);
        m.swaps += 2;
        m.replans += 1;
        m.last_drift = 0.4;
        assert_eq!((m.swaps, m.replans), (2, 1));
    }
}
