//! Serving metrics: latency distribution + throughput counters + grouped-
//! dispatch wave telemetry (occupancy, fill, latency percentiles) — plus
//! the cluster view: per-replica reports and their aggregation into a
//! single [`ServerReport`] (DESIGN.md §Sharded-Serving). Since the QoS
//! redesign (DESIGN.md §Serving-API) the counters also split queue waits
//! by [`Priority`], track the served QoS mix, carry admission/rejection/
//! cancellation totals, and keep a bounded replan history with the
//! per-layer drift vector for replan observability. The tracing redesign
//! (DESIGN.md §Observability) adds per-class SLO accounting (deadline-hit
//! rate + time-in-stage breakdown), served-bits attribution (requests per
//! plan generation), and an embedded [`SpanCollector`] so wave spans are
//! recorded where the wave report already lands — every sample vector
//! here is ring-bounded, and cluster aggregation merges per-replica
//! [`Summary`]s instead of concatenating raw samples at report time.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::obs::{Deadline, EventKind, SpanCollector, Track, TraceEvent, TraceLog};
use crate::runtime::{RuntimeScheme, WaveReport};
use crate::serve::kvcache::KvOccupancy;
use crate::serve::replica::ReplicaStatus;
use crate::serve::request::{AdmissionReport, Priority, QosClass};
use crate::util::stats::Summary;

/// Aggregated wave counters for one runtime scheme family.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SchemeWaveStats {
    /// Waves executed under this scheme.
    pub waves: usize,
    /// Tile executions (wave members) — the scheme's occupancy.
    pub items: usize,
    /// Rows shipped to PJRT, padding included.
    pub padded_rows: usize,
    /// Useful (non-padding) rows.
    pub useful_rows: usize,
    /// Summed member execute time.
    pub busy_s: f64,
}

impl SchemeWaveStats {
    /// Useful fraction of this scheme's shipped rows.
    pub fn fill_ratio(&self) -> f64 {
        if self.padded_rows == 0 {
            return 1.0;
        }
        self.useful_rows as f64 / self.padded_rows as f64
    }
}

/// Per-QoS-class SLO accounting: served count, deadline verdicts, and the
/// summed time-in-stage breakdown (queue vs compute vs stream), seconds.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SloClassStats {
    /// Requests served under this class.
    pub served: usize,
    /// Served before their deadline.
    pub deadline_hit: usize,
    /// Served after their deadline.
    pub deadline_miss: usize,
    /// Summed admission → execution-start wait.
    pub queue_s: f64,
    /// Summed execution-start → finish compute time.
    pub compute_s: f64,
    /// Summed first-streamed-token → finish streaming time (decode only).
    pub stream_s: f64,
}

impl SloClassStats {
    /// Deadline-hit rate over requests that carried a deadline (1.0 when
    /// none did — an absent deadline is never a miss).
    pub fn hit_rate(&self) -> f64 {
        let judged = self.deadline_hit + self.deadline_miss;
        if judged == 0 {
            1.0
        } else {
            self.deadline_hit as f64 / judged as f64
        }
    }

    /// Fold another replica's class stats into this one.
    pub fn accumulate(&mut self, other: &SloClassStats) {
        self.served += other.served;
        self.deadline_hit += other.deadline_hit;
        self.deadline_miss += other.deadline_miss;
        self.queue_s += other.queue_s;
        self.compute_s += other.compute_s;
        self.stream_s += other.stream_s;
    }
}

/// SLO accounting slots: the three QoS classes plus "no class set".
pub const SLO_CLASSES: usize = 4;

/// `slo` array index for a request's (optional) QoS class.
pub fn slo_class_index(qos: Option<QosClass>) -> usize {
    qos.map_or(SLO_CLASSES - 1, |q| q.index())
}

/// Display name per SLO slot (index = [`slo_class_index`]).
pub fn slo_class_name(i: usize) -> &'static str {
    ["interactive", "standard", "batch", "none"][i]
}

/// One entry of the bounded replan history: what triggered a re-solve and
/// what it changed (replan observability — exported through
/// [`ReplicaReport`] and [`ClusterReport`]).
#[derive(Clone, Copy, Debug)]
pub struct ReplanEvent {
    /// Seconds since engine start (the replica's monotonic clock).
    pub at_s: f64,
    /// Worst-layer TV drift that triggered the re-solve.
    pub drift: f64,
    /// Slots whose runtime family changed.
    pub changes: usize,
    /// Slots actually hot-swapped.
    pub swapped: usize,
    /// Accuracy/perf exponent the re-solve ran with (QoS-blended).
    pub r: f64,
    /// Average stored weight bits before → after: the budget-axis score
    /// delta of the new plan.
    pub bits_before: f64,
    pub bits_after: f64,
    /// Plan generation after the swap.
    pub generation: u64,
}

/// Replan-history entries retained per replica (bounded ring: the newest
/// [`REPLAN_HISTORY`] events survive).
pub const REPLAN_HISTORY: usize = 64;

/// Rolling serving metrics (single-threaded engine owns it).
pub struct Metrics {
    start: Instant,
    /// Request-latency ring (most recent [`REQUEST_LATENCY_WINDOW`]).
    latencies: Vec<f64>,
    latency_cursor: usize,
    /// Queue-wait ring (most recent [`QUEUE_WAIT_WINDOW`]).
    queue_waits: Vec<f64>,
    queue_wait_cursor: usize,
    /// Queue-wait samples split by request priority (same clock as
    /// `queue_waits`; index = `Priority::index()`; each ring bounded by
    /// [`QUEUE_WAIT_WINDOW`]).
    queue_waits_by_priority: [Vec<f64>; 3],
    queue_wait_priority_cursors: [usize; 3],
    /// Request-latency samples split by SLO class (same clock as
    /// `latencies`; index = [`slo_class_index`]; each ring bounded by
    /// [`REQUEST_LATENCY_WINDOW`]) — feeds the per-class percentiles the
    /// scenario verdicts judge.
    latencies_by_class: [Vec<f64>; SLO_CLASSES],
    latency_class_cursors: [usize; SLO_CLASSES],
    /// Requests served per QoS class (`None` counts as `Standard`).
    pub qos_served: [usize; 3],
    /// Per-class SLO accounting (index = [`slo_class_index`]).
    pub slo: [SloClassStats; SLO_CLASSES],
    /// Served-bits attribution: plan generation → requests it served.
    served_by_generation: BTreeMap<u64, usize>,
    /// Lifecycle-span sink for this replica's thread (disabled and empty
    /// unless the owner installs an enabled collector — recording is a
    /// branch + ring write, no locks).
    tracer: SpanCollector,
    /// Cancelled requests shed before execution on this replica.
    pub shed_cancelled: usize,
    /// Per-layer TV drift at the last telemetry check (replan
    /// observability — `last_drift` is this vector's max).
    pub drift_vector: Vec<f64>,
    replan_history: Vec<ReplanEvent>,
    pub tokens: usize,
    pub requests: usize,
    pub batches: usize,
    pub expert_calls: usize,
    /// Tile rows shipped to PJRT (incl. padding).
    pub padded_tokens: usize,
    /// Useful (non-padding) tile rows.
    pub useful_rows: usize,
    /// Expert slots hot-swapped to a new runtime family.
    pub swaps: usize,
    /// Drift-triggered MCKP re-solves.
    pub replans: usize,
    /// Telemetry drift score at the last check (total variation, [0,1]).
    pub last_drift: f64,
    /// Deepest admission queue observed at a batch cut.
    pub max_queue_depth: usize,
    /// Grouped block dispatches executed (plan → wave → scatter cycles).
    pub grouped_dispatches: usize,
    /// Waves executed across all grouped dispatches.
    pub waves: usize,
    /// Most waves in flight in a single grouped dispatch (the concurrency
    /// the mixed-precision plan actually exposed).
    pub max_concurrent_waves: usize,
    /// Batcher fill estimate at the last batch cut (planner-fed).
    pub last_planned_fill: f64,
    /// Sliding window of per-wave wall-clock samples. Waves accrue far
    /// faster than requests (several per MoE block per batch), so this is
    /// a bounded ring — percentiles reflect the most recent
    /// [`WAVE_LATENCY_WINDOW`] waves, not all-time history.
    wave_latencies: Vec<f64>,
    wave_latency_cursor: usize,
    scheme_waves: BTreeMap<&'static str, SchemeWaveStats>,
    // ---- decode loop (DESIGN.md §Decode-Loop) ----
    /// Mixed prefill/decode steps executed.
    pub decode_steps: usize,
    /// Prompt rows prefilled through the step loop.
    pub prefill_rows: usize,
    /// Single-token decode rows executed.
    pub decode_rows: usize,
    /// Tokens generated and streamed to tickets.
    pub generated_tokens: usize,
    /// Generations completed (stop-token or length).
    pub generations: usize,
    /// Per-step wall-clock ring (steps accrue per token — bounded like the
    /// wave ring).
    step_latencies: Vec<f64>,
    step_latency_cursor: usize,
    /// KV pool occupancy at the last publish: reserved / peak / budget
    /// tokens.
    pub kv_reserved_tokens: usize,
    pub kv_peak_tokens: usize,
    pub kv_budget_tokens: usize,
    /// Tokens actually materialized in KV pages (lazy paging means this
    /// trails `kv_reserved_tokens` until a sequence fills its reservation).
    pub kv_used_tokens: usize,
    /// Tokens served from refcount-shared prefix pages (counted once per
    /// extra reference — physical savings, not logical coverage).
    pub kv_shared_tokens: usize,
    /// Average bits per stored KV element across live physical pages
    /// (32.0 when quantization is off or the pool is empty).
    pub kv_avg_bits: f64,
    /// Generations preempted (pages reclaimed, replayed later) because
    /// the page pool ran dry mid-decode.
    pub kv_preemptions: usize,
}

/// Wave-latency samples retained for percentile reporting.
pub const WAVE_LATENCY_WINDOW: usize = 4096;

/// Decode-step latency samples retained for percentile reporting.
pub const STEP_LATENCY_WINDOW: usize = 4096;

/// Request-latency samples retained for percentile reporting (long runs
/// would otherwise grow the vector without bound).
pub const REQUEST_LATENCY_WINDOW: usize = 8192;

/// Queue-wait samples retained for percentile reporting (both the overall
/// ring and each per-priority ring).
pub const QUEUE_WAIT_WINDOW: usize = 8192;

/// Push into a bounded ring: fill to `cap`, then overwrite oldest-first.
fn push_ring(buf: &mut Vec<f64>, cursor: &mut usize, cap: usize, v: f64) {
    if buf.len() < cap {
        buf.push(v);
    } else {
        buf[*cursor] = v;
        *cursor = (*cursor + 1) % cap;
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            start: Instant::now(),
            latencies: Vec::new(),
            latency_cursor: 0,
            queue_waits: Vec::new(),
            queue_wait_cursor: 0,
            queue_waits_by_priority: [Vec::new(), Vec::new(), Vec::new()],
            queue_wait_priority_cursors: [0; 3],
            latencies_by_class: std::array::from_fn(|_| Vec::new()),
            latency_class_cursors: [0; SLO_CLASSES],
            qos_served: [0; 3],
            slo: [SloClassStats::default(); SLO_CLASSES],
            served_by_generation: BTreeMap::new(),
            tracer: SpanCollector::disabled(Track::Replica(0)),
            shed_cancelled: 0,
            drift_vector: Vec::new(),
            replan_history: Vec::new(),
            tokens: 0,
            requests: 0,
            batches: 0,
            expert_calls: 0,
            padded_tokens: 0,
            useful_rows: 0,
            swaps: 0,
            replans: 0,
            last_drift: 0.0,
            max_queue_depth: 0,
            grouped_dispatches: 0,
            waves: 0,
            max_concurrent_waves: 0,
            last_planned_fill: 1.0,
            wave_latencies: Vec::new(),
            wave_latency_cursor: 0,
            scheme_waves: BTreeMap::new(),
            decode_steps: 0,
            prefill_rows: 0,
            decode_rows: 0,
            generated_tokens: 0,
            generations: 0,
            step_latencies: Vec::new(),
            step_latency_cursor: 0,
            kv_reserved_tokens: 0,
            kv_peak_tokens: 0,
            kv_budget_tokens: 0,
            kv_used_tokens: 0,
            kv_shared_tokens: 0,
            kv_avg_bits: 32.0,
            kv_preemptions: 0,
        }
    }

    /// Fold one decode step into the counters: `prefill` + `decode` useful
    /// rows, `emitted` streamed tokens, `finished` completed generations,
    /// and the step wall clock (ring-bounded).
    pub fn record_decode_step(
        &mut self,
        prefill: usize,
        decode: usize,
        emitted: usize,
        finished: usize,
        elapsed_s: f64,
    ) {
        self.decode_steps += 1;
        self.prefill_rows += prefill;
        self.decode_rows += decode;
        self.generated_tokens += emitted;
        self.generations += finished;
        push_ring(
            &mut self.step_latencies,
            &mut self.step_latency_cursor,
            STEP_LATENCY_WINDOW,
            elapsed_s,
        );
    }

    /// Snapshot the replica's KV pool occupancy (published per step).
    pub fn note_kv_occupancy(&mut self, occ: &KvOccupancy) {
        self.kv_reserved_tokens = occ.reserved_tokens;
        self.kv_peak_tokens = occ.peak_tokens;
        self.kv_budget_tokens = occ.budget_tokens;
        self.kv_used_tokens = occ.used_tokens;
        self.kv_shared_tokens = occ.shared_tokens;
        self.kv_avg_bits = occ.avg_kv_bits;
    }

    /// Count generations preempted by the decode scheduler this step
    /// (pages reclaimed for an older sequence; the victim replays later).
    pub fn record_kv_preemptions(&mut self, n: usize) {
        self.kv_preemptions += n;
    }

    /// Raw per-step wall-clock samples in the ring (unordered).
    pub fn step_latency_samples(&self) -> &[f64] {
        &self.step_latencies
    }

    /// Decode-step wall-clock distribution over the most recent
    /// [`STEP_LATENCY_WINDOW`] steps.
    pub fn step_latency_summary(&self) -> Option<Summary> {
        if self.step_latencies.is_empty() {
            None
        } else {
            Some(Summary::of(&self.step_latencies))
        }
    }

    /// Fold one grouped dispatch's wave report into the counters
    /// (tile/padding totals included, mirroring what the sequential path
    /// counts per call).
    pub fn record_dispatch(&mut self, report: &WaveReport) {
        self.grouped_dispatches += 1;
        self.waves += report.waves.len();
        self.max_concurrent_waves = self.max_concurrent_waves.max(report.waves.len());
        self.expert_calls += report.items();
        self.padded_tokens += report.padded_rows();
        self.useful_rows += report.useful_rows();
        for w in &report.waves {
            push_ring(
                &mut self.wave_latencies,
                &mut self.wave_latency_cursor,
                WAVE_LATENCY_WINDOW,
                w.elapsed_s,
            );
            let s = self.scheme_waves.entry(w.scheme.name()).or_default();
            s.waves += 1;
            s.items += w.items;
            s.padded_rows += w.padded_rows;
            s.useful_rows += w.useful_rows;
            s.busy_s += w.busy_s;
        }
        if self.tracer.enabled() {
            // Place each wave span at its measured offset inside the
            // dispatch window ending now.
            let now = self.tracer.now_us();
            let dispatch_start = now.saturating_sub((report.elapsed_s * 1e6) as u64);
            for w in &report.waves {
                self.tracer.span(
                    dispatch_start + (w.start_s * 1e6) as u64,
                    (w.elapsed_s * 1e6) as u64,
                    0,
                    EventKind::Wave {
                        scheme: w.scheme.name(),
                        tile_m: w.tile_m,
                        items: w.items,
                        rows: w.useful_rows,
                        padded: w.padded_rows,
                    },
                );
            }
        }
    }

    /// Install this replica's lifecycle-span sink (replaces the default
    /// disabled collector).
    pub fn set_tracer(&mut self, tracer: SpanCollector) {
        self.tracer = tracer;
    }

    /// The replica's span sink, for the owning loop to record lifecycle
    /// events (terminals, decode steps, replan phases).
    pub fn tracer(&mut self) -> &mut SpanCollector {
        &mut self.tracer
    }

    /// Drain the recorded spans (oldest first) and the overwrite count.
    pub fn take_trace(&mut self) -> (Vec<TraceEvent>, usize) {
        self.tracer.drain()
    }

    /// Planner-fed batcher fill estimate at a batch cut.
    pub fn note_planned_fill(&mut self, fill_ratio: f64) {
        self.last_planned_fill = fill_ratio;
    }

    /// Raw request-latency samples (cluster-level percentile merges).
    pub fn latencies(&self) -> &[f64] {
        &self.latencies
    }

    /// Raw queue-wait samples (cluster-level percentile merges).
    pub fn queue_waits(&self) -> &[f64] {
        &self.queue_waits
    }

    /// Raw wave wall-clock samples retained in the ring (unordered —
    /// suitable for percentile merges only).
    pub fn wave_latency_samples(&self) -> &[f64] {
        &self.wave_latencies
    }

    /// Wave wall-clock distribution (first launch → last completion per
    /// wave) over the most recent [`WAVE_LATENCY_WINDOW`] waves.
    pub fn wave_latency_summary(&self) -> Option<Summary> {
        if self.wave_latencies.is_empty() {
            None
        } else {
            Some(Summary::of(&self.wave_latencies))
        }
    }

    /// Per-scheme wave occupancy/fill, keyed by runtime family name.
    pub fn scheme_wave_stats(&self) -> &BTreeMap<&'static str, SchemeWaveStats> {
        &self.scheme_waves
    }

    /// Useful fraction of rows shipped by grouped dispatches.
    pub fn wave_fill_ratio(&self) -> f64 {
        let padded: usize = self.scheme_waves.values().map(|s| s.padded_rows).sum();
        if padded == 0 {
            return 1.0;
        }
        let useful: usize = self.scheme_waves.values().map(|s| s.useful_rows).sum();
        useful as f64 / padded as f64
    }

    pub fn record_request(&mut self, latency_s: f64, tokens: usize) {
        push_ring(&mut self.latencies, &mut self.latency_cursor, REQUEST_LATENCY_WINDOW, latency_s);
        self.tokens += tokens;
        self.requests += 1;
    }

    pub fn record_queue_wait(&mut self, wait_s: f64, priority: Priority) {
        push_ring(&mut self.queue_waits, &mut self.queue_wait_cursor, QUEUE_WAIT_WINDOW, wait_s);
        let p = priority.index();
        push_ring(
            &mut self.queue_waits_by_priority[p],
            &mut self.queue_wait_priority_cursors[p],
            QUEUE_WAIT_WINDOW,
            wait_s,
        );
    }

    /// Record a served request's end-to-end latency against its SLO class
    /// (ring-bounded; same clock as the overall latency ring — callers
    /// pair this with [`record_request`](Self::record_request)).
    pub fn record_class_latency(&mut self, qos: Option<QosClass>, latency_s: f64) {
        let c = slo_class_index(qos);
        push_ring(
            &mut self.latencies_by_class[c],
            &mut self.latency_class_cursors[c],
            REQUEST_LATENCY_WINDOW,
            latency_s,
        );
    }

    /// Latency distribution per SLO class (`None` where a class saw no
    /// traffic). What [`ReplicaReport`] ships instead of samples.
    pub fn latency_by_class_summary(&self) -> [Option<Summary>; SLO_CLASSES] {
        std::array::from_fn(|i| {
            let v = &self.latencies_by_class[i];
            (!v.is_empty()).then(|| Summary::of(v))
        })
    }

    /// Queue-wait samples per priority level (index = `Priority::index()`).
    pub fn queue_waits_by_priority(&self) -> &[Vec<f64>; 3] {
        &self.queue_waits_by_priority
    }

    /// Queue-wait distribution per priority level (`None` where a level
    /// saw no traffic). What [`ReplicaReport`] ships instead of samples.
    pub fn queue_wait_by_priority_summary(&self) -> [Option<Summary>; 3] {
        let s = |v: &Vec<f64>| (!v.is_empty()).then(|| Summary::of(v));
        [
            s(&self.queue_waits_by_priority[0]),
            s(&self.queue_waits_by_priority[1]),
            s(&self.queue_waits_by_priority[2]),
        ]
    }

    /// Fold one served request into the per-class SLO accounting and the
    /// served-bits attribution (which plan generation served it).
    pub fn note_slo(
        &mut self,
        qos: Option<QosClass>,
        deadline: Deadline,
        queue_s: f64,
        compute_s: f64,
        stream_s: f64,
        generation: u64,
    ) {
        let s = &mut self.slo[slo_class_index(qos)];
        s.served += 1;
        match deadline {
            Deadline::Hit => s.deadline_hit += 1,
            Deadline::Miss => s.deadline_miss += 1,
            Deadline::None => {}
        }
        s.queue_s += queue_s;
        s.compute_s += compute_s;
        s.stream_s += stream_s;
        *self.served_by_generation.entry(generation).or_insert(0) += 1;
    }

    /// Requests served per plan generation, ascending by generation.
    pub fn served_by_generation(&self) -> Vec<(u64, usize)> {
        self.served_by_generation.iter().map(|(g, n)| (*g, *n)).collect()
    }

    /// Count one served request against its QoS class (`None` counts as
    /// `Standard` — the class is a hint, not a requirement).
    pub fn note_qos(&mut self, qos: Option<QosClass>) {
        self.qos_served[qos.unwrap_or(QosClass::Standard).index()] += 1;
    }

    /// Append to the bounded replan history (oldest entries drop once
    /// [`REPLAN_HISTORY`] is reached).
    pub fn note_replan(&mut self, event: ReplanEvent) {
        if self.replan_history.len() >= REPLAN_HISTORY {
            self.replan_history.remove(0);
        }
        self.replan_history.push(event);
    }

    pub fn replan_history(&self) -> &[ReplanEvent] {
        &self.replan_history
    }

    pub fn note_queue_depth(&mut self, depth: usize) {
        self.max_queue_depth = self.max_queue_depth.max(depth);
    }

    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn throughput_tps(&self) -> f64 {
        self.tokens as f64 / self.elapsed().max(1e-9)
    }

    pub fn latency_summary(&self) -> Option<Summary> {
        if self.latencies.is_empty() {
            None
        } else {
            Some(Summary::of(&self.latencies))
        }
    }

    /// Queue-wait distribution (admission → batch cut).
    pub fn queue_wait_summary(&self) -> Option<Summary> {
        if self.queue_waits.is_empty() {
            None
        } else {
            Some(Summary::of(&self.queue_waits))
        }
    }

    /// Fraction of expert-tile rows that were padding (tile-fill quality of
    /// the batcher — the quantity slice-K/tile selection fights on GPU).
    pub fn padding_ratio(&self) -> f64 {
        if self.padded_tokens == 0 {
            return 0.0;
        }
        1.0 - self.useful_rows as f64 / self.padded_tokens as f64
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------- cluster view ----------------

/// Final statistics of one replica worker, assembled at thread exit.
/// Distributions travel as [`Summary`]s — the cluster view combines them
/// with [`Summary::merge`] (exact moments, weighted percentiles) instead
/// of concatenating every replica's raw samples at report time.
#[derive(Clone, Debug)]
pub struct ReplicaReport {
    pub id: usize,
    pub requests: usize,
    pub tokens: usize,
    /// Batches this replica executed (routed to it or stolen by it).
    pub executed_batches: usize,
    /// Of `executed_batches`, how many were stolen from a peer's deque.
    pub stolen_batches: usize,
    pub expert_calls: usize,
    /// Tile rows shipped to PJRT (incl. padding), both dispatch modes.
    pub padded_rows: usize,
    pub useful_rows: usize,
    pub waves: usize,
    pub max_concurrent_waves: usize,
    /// Rows shipped by grouped waves only (wave-fill aggregation).
    pub wave_padded_rows: usize,
    pub wave_useful_rows: usize,
    /// Deepest *own* work deque observed at a pop.
    pub max_queue_depth: usize,
    pub swaps: usize,
    pub replans: usize,
    pub last_drift: f64,
    /// Per-layer TV drift at the last telemetry check.
    pub drift_vector: Vec<f64>,
    /// Bounded replan history (newest [`REPLAN_HISTORY`] events).
    pub replan_history: Vec<ReplanEvent>,
    /// Cancelled requests shed before execution on this replica.
    pub shed_cancelled: usize,
    /// Requests served per QoS class (`None` counted as `Standard`).
    pub qos_served: [usize; 3],
    /// Per-class SLO accounting (index = [`slo_class_index`]).
    pub slo: [SloClassStats; SLO_CLASSES],
    /// Served-bits attribution: plan generation → requests it served.
    pub served_by_generation: Vec<(u64, usize)>,
    /// Queue-wait distribution per priority (index = `Priority::index()`).
    pub queue_wait_by_priority: [Option<Summary>; 3],
    /// End-to-end latency distribution per SLO class (index =
    /// [`slo_class_index`]; `None` where a class saw no traffic).
    pub latency_by_class: [Option<Summary>; SLO_CLASSES],
    /// Final hot-swap generation of this replica's plan.
    pub generation: u64,
    pub scheme_counts: Vec<(RuntimeScheme, usize)>,
    pub latency: Option<Summary>,
    pub queue_wait: Option<Summary>,
    pub wave_latency: Option<Summary>,
    // ---- decode loop ----
    /// Mixed prefill/decode steps this replica executed.
    pub decode_steps: usize,
    /// Prompt rows prefilled through the step loop.
    pub prefill_rows: usize,
    /// Single-token decode rows executed.
    pub decode_rows: usize,
    /// Tokens generated and streamed.
    pub generated_tokens: usize,
    /// Generations completed (stop-token or length).
    pub generations: usize,
    /// Per-step wall-clock distribution (over the bounded ring).
    pub step_latency: Option<Summary>,
    /// KV reservation high-water mark / budget (tokens).
    pub kv_peak_tokens: usize,
    pub kv_budget_tokens: usize,
    /// Tokens materialized in KV pages at the final publish (lazy paging
    /// trails reservations).
    pub kv_used_tokens: usize,
    /// Tokens served from refcount-shared prefix pages (physical savings).
    pub kv_shared_tokens: usize,
    /// Average bits per stored KV element across live physical pages.
    pub kv_avg_bits: f64,
    /// Generations preempted for pages and replayed.
    pub kv_preemptions: usize,
    /// Engine lifetime (build → report), seconds.
    pub elapsed_s: f64,
    /// Lifecycle spans recorded on this replica's track (empty when
    /// tracing is off), plus how many the bounded ring overwrote.
    pub trace: Vec<TraceEvent>,
    pub trace_dropped: usize,
}

/// Final statistics of the router thread: admission-queue behavior plus
/// where batches went.
#[derive(Clone, Debug)]
pub struct RouterStats {
    /// Batches cut and routed.
    pub batches: usize,
    /// Batches routed to each replica by affinity (steals move them later).
    pub routed: Vec<usize>,
    /// Deepest admission queue observed at a batch cut.
    pub max_queue_depth: usize,
    /// Cancelled requests shed at batch cuts (never routed).
    pub shed_cancelled: usize,
    /// Planner-projected tile fill of the last batch cut.
    pub last_planned_fill: f64,
    /// Router lifetime (first admission poll → queue close), seconds.
    pub elapsed_s: f64,
    /// Spans recorded on the router track (batch cuts, routing decisions,
    /// cut-time sheds), plus how many the bounded ring overwrote.
    pub trace: Vec<TraceEvent>,
    pub trace_dropped: usize,
}

impl RouterStats {
    pub fn new(replicas: usize) -> RouterStats {
        RouterStats {
            batches: 0,
            routed: vec![0; replicas],
            max_queue_depth: 0,
            shed_cancelled: 0,
            last_planned_fill: 1.0,
            elapsed_s: 0.0,
            trace: Vec::new(),
            trace_dropped: 0,
        }
    }
}

/// Everything a cluster run produced: per-replica reports plus the router
/// view. [`flatten`](ClusterReport::flatten) folds it into the legacy
/// single-engine [`ServerReport`] shape.
#[derive(Clone, Debug)]
pub struct ClusterReport {
    pub replicas: Vec<ReplicaReport>,
    pub router: RouterStats,
    /// Front-door accounting: admitted / rejected (queue-full,
    /// deadline-unmeetable) / cancelled / failed. For a drained shutdown,
    /// `admission.admitted == total_requests() + admission.cancelled +
    /// admission.failed`.
    pub admission: AdmissionReport,
    /// Merged lifecycle trace: admission + router + replica spans on one
    /// timeline (empty when tracing was off). Export with
    /// [`TraceLog::write_chrome_trace`] / [`TraceLog::write_jsonl`].
    pub trace: TraceLog,
}

impl ClusterReport {
    pub fn total_requests(&self) -> usize {
        self.replicas.iter().map(|r| r.requests).sum()
    }

    pub fn total_tokens(&self) -> usize {
        self.replicas.iter().map(|r| r.tokens).sum()
    }

    pub fn total_steals(&self) -> usize {
        self.replicas.iter().map(|r| r.stolen_batches).sum()
    }

    /// Queue-wait p99 per priority level, per-replica summaries merged
    /// (0.0 where a level saw no traffic). Index = `Priority::index()`.
    pub fn queue_wait_p99_by_priority(&self) -> [f64; 3] {
        let mut out = [0.0f64; 3];
        for (i, slot) in out.iter_mut().enumerate() {
            let parts: Vec<Summary> = self
                .replicas
                .iter()
                .filter_map(|r| r.queue_wait_by_priority[i].clone())
                .collect();
            let merged = Summary::merge(&parts);
            if merged.n > 0 {
                *slot = merged.p99;
            }
        }
        out
    }

    /// End-to-end latency distribution per SLO class, per-replica
    /// summaries merged (`None` where a class saw no traffic). Index =
    /// [`slo_class_index`]. The scenario verdicts read p50/p99 from here.
    pub fn latency_by_class(&self) -> [Option<Summary>; SLO_CLASSES] {
        std::array::from_fn(|i| {
            let parts: Vec<Summary> = self
                .replicas
                .iter()
                .filter_map(|r| r.latency_by_class[i].clone())
                .collect();
            let m = Summary::merge(&parts);
            (m.n > 0).then_some(m)
        })
    }

    /// Cluster-wide per-class SLO accounting (summed over replicas).
    pub fn slo_by_class(&self) -> [SloClassStats; SLO_CLASSES] {
        let mut out = [SloClassStats::default(); SLO_CLASSES];
        for r in &self.replicas {
            for (a, b) in out.iter_mut().zip(&r.slo) {
                a.accumulate(b);
            }
        }
        out
    }

    /// Cluster-wide served-bits attribution: plan generation → requests
    /// it served, summed over replicas, ascending by generation.
    pub fn served_by_generation(&self) -> Vec<(u64, usize)> {
        let mut by_gen: BTreeMap<u64, usize> = BTreeMap::new();
        for r in &self.replicas {
            for (g, n) in &r.served_by_generation {
                *by_gen.entry(*g).or_insert(0) += *n;
            }
        }
        by_gen.into_iter().collect()
    }

    /// Per-layer drift, worst replica per layer (replicas may disagree on
    /// layer count mid-publish; the vector covers the longest).
    pub fn drift_vector(&self) -> Vec<f64> {
        let layers = self.replicas.iter().map(|r| r.drift_vector.len()).max().unwrap_or(0);
        let mut out = vec![0.0f64; layers];
        for r in &self.replicas {
            for (o, &d) in out.iter_mut().zip(&r.drift_vector) {
                *o = o.max(d);
            }
        }
        out
    }

    /// All replicas' replan events, oldest first (per-replica clocks —
    /// ordering across replicas is approximate).
    pub fn replan_history(&self) -> Vec<(usize, ReplanEvent)> {
        let mut events: Vec<(usize, ReplanEvent)> = self
            .replicas
            .iter()
            .flat_map(|r| r.replan_history.iter().map(move |e| (r.id, *e)))
            .collect();
        events.sort_by(|a, b| a.1.at_s.partial_cmp(&b.1.at_s).unwrap_or(std::cmp::Ordering::Equal));
        events
    }

    /// Cluster throughput over the longest-lived replica's wall clock
    /// (replicas run concurrently, so summing elapsed would double-count).
    pub fn throughput_tps(&self) -> f64 {
        let wall = self.replicas.iter().map(|r| r.elapsed_s).fold(0.0f64, f64::max);
        self.total_tokens() as f64 / wall.max(1e-9)
    }

    /// Decode throughput: generated tokens over the longest-lived
    /// replica's wall clock.
    pub fn decode_tps(&self) -> f64 {
        let wall = self.replicas.iter().map(|r| r.elapsed_s).fold(0.0f64, f64::max);
        self.replicas.iter().map(|r| r.generated_tokens).sum::<usize>() as f64 / wall.max(1e-9)
    }

    /// Merge the per-replica reports into the legacy single-engine report
    /// shape: sums for counters, [`Summary::merge`]d percentiles for
    /// distributions (no raw-sample concatenation), maxima for high-water
    /// marks.
    pub fn flatten(&self) -> ServerReport {
        let merged = |pick: fn(&ReplicaReport) -> Option<Summary>| {
            let parts: Vec<Summary> = self.replicas.iter().filter_map(pick).collect();
            let m = Summary::merge(&parts);
            (m.n > 0).then_some(m)
        };
        let lat = merged(|r| r.latency.clone());
        let qw = merged(|r| r.queue_wait.clone());
        let wl = merged(|r| r.wave_latency.clone());
        let sl = merged(|r| r.step_latency.clone());
        let padded: usize = self.replicas.iter().map(|r| r.padded_rows).sum();
        let useful: usize = self.replicas.iter().map(|r| r.useful_rows).sum();
        let wave_padded: usize = self.replicas.iter().map(|r| r.wave_padded_rows).sum();
        let wave_useful: usize = self.replicas.iter().map(|r| r.wave_useful_rows).sum();
        ServerReport {
            requests: self.total_requests(),
            tokens: self.total_tokens(),
            throughput_tps: self.throughput_tps(),
            p50_latency_s: lat.as_ref().map(|s| s.p50).unwrap_or(0.0),
            p99_latency_s: lat.as_ref().map(|s| s.p99).unwrap_or(0.0),
            p50_queue_wait_s: qw.as_ref().map(|s| s.p50).unwrap_or(0.0),
            expert_calls: self.replicas.iter().map(|r| r.expert_calls).sum(),
            padding_ratio: if padded == 0 {
                0.0
            } else {
                1.0 - useful as f64 / padded as f64
            },
            waves: self.replicas.iter().map(|r| r.waves).sum(),
            max_concurrent_waves: self
                .replicas
                .iter()
                .map(|r| r.max_concurrent_waves)
                .max()
                .unwrap_or(0),
            wave_fill_ratio: if wave_padded == 0 {
                1.0
            } else {
                wave_useful as f64 / wave_padded as f64
            },
            p50_wave_s: wl.as_ref().map(|s| s.p50).unwrap_or(0.0),
            last_planned_fill: self.router.last_planned_fill,
            max_queue_depth: self.router.max_queue_depth,
            replans: self.replicas.iter().map(|r| r.replans).sum(),
            replan_events: self.replicas.iter().map(|r| r.replan_history.len()).sum(),
            swaps: self.replicas.iter().map(|r| r.swaps).sum(),
            last_drift: self.replicas.iter().map(|r| r.last_drift).fold(0.0, f64::max),
            drift_vector: self.drift_vector(),
            generation: self.replicas.iter().map(|r| r.generation).max().unwrap_or(0),
            replicas: self.replicas.len(),
            stolen_batches: self.total_steals(),
            admitted: self.admission.admitted,
            rejected_queue_full: self.admission.rejected_queue_full,
            rejected_deadline: self.admission.rejected_deadline,
            rejected_quota: self.admission.rejected_quota,
            cancelled: self.admission.cancelled,
            failed: self.admission.failed,
            decode_steps: self.replicas.iter().map(|r| r.decode_steps).sum(),
            prefill_rows: self.replicas.iter().map(|r| r.prefill_rows).sum(),
            decode_rows: self.replicas.iter().map(|r| r.decode_rows).sum(),
            generated_tokens: self.replicas.iter().map(|r| r.generated_tokens).sum(),
            generations: self.replicas.iter().map(|r| r.generations).sum(),
            decode_tps: self.decode_tps(),
            p50_step_s: sl.as_ref().map(|s| s.p50).unwrap_or(0.0),
            kv_peak_tokens: self.replicas.iter().map(|r| r.kv_peak_tokens).max().unwrap_or(0),
            kv_used_tokens: self.replicas.iter().map(|r| r.kv_used_tokens).sum(),
            kv_shared_tokens: self.replicas.iter().map(|r| r.kv_shared_tokens).sum(),
            kv_budget_tokens: self.replicas.iter().map(|r| r.kv_budget_tokens).sum(),
            kv_avg_bits: {
                // Weight each replica's average by its materialized tokens;
                // an idle cluster reports full-precision (32.0).
                let used: usize = self.replicas.iter().map(|r| r.kv_used_tokens).sum();
                if used == 0 {
                    32.0
                } else {
                    self.replicas
                        .iter()
                        .map(|r| r.kv_avg_bits * r.kv_used_tokens as f64)
                        .sum::<f64>()
                        / used as f64
                }
            },
            kv_preemptions: self.replicas.iter().map(|r| r.kv_preemptions).sum(),
            rejected_kv: self.admission.rejected_kv,
            queue_wait_p99_by_priority: self.queue_wait_p99_by_priority(),
            qos_served: {
                let mut q = [0usize; 3];
                for r in &self.replicas {
                    for (a, b) in q.iter_mut().zip(&r.qos_served) {
                        *a += b;
                    }
                }
                q
            },
            slo_by_class: self.slo_by_class(),
            served_by_generation: self.served_by_generation(),
            http: HttpReport::default(),
            trace: self.trace.clone(),
        }
    }
}

/// HTTP front-door counters (DESIGN.md §HTTP-Front-Door). Zero unless the
/// report passed through a running [`crate::serve::http::HttpServer`] —
/// in-process clusters have no wire, so [`ClusterReport::flatten`] leaves
/// the default.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HttpReport {
    /// Connections accepted and handled.
    pub connections: usize,
    /// Connections turned away at the handler-pool bound (503 + Retry-After
    /// before the request line is even read).
    pub rejected_busy: usize,
    /// Client disconnects observed mid-response (each cancels its ticket).
    pub disconnects: usize,
    /// SSE events written across all streams.
    pub sse_events: usize,
    /// Response bytes written (headers + bodies + SSE frames).
    pub bytes_out: usize,
    /// Peak concurrently live connections.
    pub peak_connections: usize,
}

/// Final statistics returned at shutdown — the cluster-wide view in the
/// shape the single-engine server has always reported (a 1-replica cluster
/// reproduces the old numbers).
#[derive(Clone, Debug, Default)]
pub struct ServerReport {
    pub requests: usize,
    pub tokens: usize,
    pub throughput_tps: f64,
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
    pub p50_queue_wait_s: f64,
    pub expert_calls: usize,
    pub padding_ratio: f64,
    /// Waves executed by grouped dispatch (0 under sequential mode).
    pub waves: usize,
    /// Most waves in flight in one grouped dispatch, over all replicas.
    pub max_concurrent_waves: usize,
    /// Useful fraction of rows shipped by grouped dispatch.
    pub wave_fill_ratio: f64,
    /// p50 wave wall-clock, seconds (0 when no waves ran).
    pub p50_wave_s: f64,
    /// Planner-projected tile fill of the last batch cut.
    pub last_planned_fill: f64,
    /// Deepest admission queue observed at a batch cut.
    pub max_queue_depth: usize,
    /// Drift-triggered MCKP re-solves (summed over replicas).
    pub replans: usize,
    /// Replan-history entries retained across replicas (≤ replans when
    /// the bounded ring wrapped).
    pub replan_events: usize,
    /// Expert slots hot-swapped to a new runtime family (summed).
    pub swaps: usize,
    /// Worst per-replica telemetry drift at the last check.
    pub last_drift: f64,
    /// Per-layer drift, worst replica per layer.
    pub drift_vector: Vec<f64>,
    /// Highest replica plan generation (0 = every boot plan served
    /// throughout).
    pub generation: u64,
    /// Engine replicas that served this run.
    pub replicas: usize,
    /// Batches executed by a different replica than the router chose.
    pub stolen_batches: usize,
    /// Requests admitted at the front door (ticket issued).
    pub admitted: usize,
    /// Requests turned away at the queue-depth bound.
    pub rejected_queue_full: usize,
    /// Requests turned away on projected deadline miss.
    pub rejected_deadline: usize,
    /// Unprivileged requests shed by the class quota (admission fairness).
    pub rejected_quota: usize,
    /// Admitted requests cancelled before producing a response.
    pub cancelled: usize,
    /// Admitted requests dropped by a failed batch forward (engine error).
    pub failed: usize,
    /// Mixed prefill/decode steps executed across replicas.
    pub decode_steps: usize,
    /// Prompt rows prefilled through the decode loop.
    pub prefill_rows: usize,
    /// Single-token decode rows executed.
    pub decode_rows: usize,
    /// Tokens generated and streamed to tickets.
    pub generated_tokens: usize,
    /// Generations completed (stop-token or length).
    pub generations: usize,
    /// Decode throughput: generated tokens / wall-clock, tokens/s.
    pub decode_tps: f64,
    /// p50 decode-step wall-clock, seconds (0 when no steps ran).
    pub p50_step_s: f64,
    /// KV reservation high-water mark, worst replica (tokens).
    pub kv_peak_tokens: usize,
    /// Tokens materialized in KV pages at shutdown, summed over replicas.
    pub kv_used_tokens: usize,
    /// Tokens served from refcount-shared prefix pages, summed (each extra
    /// reference to a physical page counts its filled positions once).
    pub kv_shared_tokens: usize,
    /// KV page-pool capacity, summed over replicas (0 = unpaged decode).
    pub kv_budget_tokens: usize,
    /// Average bits per stored KV element, weighted by each replica's
    /// materialized tokens (32.0 when no pages were live).
    pub kv_avg_bits: f64,
    /// Generations preempted for pages and replayed, summed over replicas.
    pub kv_preemptions: usize,
    /// Generate requests turned away because the KV page pool was the
    /// bottleneck (retry-after derived from the page-release rate).
    pub rejected_kv: usize,
    /// Queue-wait p99 per priority level (index = `Priority::index()`).
    pub queue_wait_p99_by_priority: [f64; 3],
    /// Requests served per QoS class (`None` counted as `Standard`).
    pub qos_served: [usize; 3],
    /// Per-class SLO accounting: deadline-hit rate + time-in-stage
    /// breakdown (index = [`slo_class_index`]; the last slot collects
    /// requests with no class set).
    pub slo_by_class: [SloClassStats; SLO_CLASSES],
    /// Served-bits attribution: plan generation → requests it served.
    pub served_by_generation: Vec<(u64, usize)>,
    /// HTTP front-door counters (default/zero for in-process clusters).
    pub http: HttpReport,
    /// Merged lifecycle trace (empty when tracing was off).
    pub trace: TraceLog,
}

impl ServerReport {
    /// A live mid-run snapshot for scrape-shaped consumers (the HTTP front
    /// door's `GET /metrics` and the observatory sampler): admission
    /// counters from the front door plus progress counters, KV occupancy
    /// and SLO accounting from the replica status board. Distribution
    /// fields (latency percentiles, wave telemetry) are only assembled at
    /// shutdown and read zero here; `kv_avg_bits` is used-token-weighted
    /// across replicas and reports full precision when nothing is
    /// resident, matching the idle-cluster convention.
    pub fn live(admission: &AdmissionReport, statuses: &[ReplicaStatus]) -> ServerReport {
        let kv_used: usize = statuses.iter().map(|s| s.kv_used_tokens).sum();
        ServerReport {
            requests: statuses.iter().map(|s| s.requests_done).sum(),
            tokens: statuses.iter().map(|s| s.tokens_done).sum(),
            swaps: statuses.iter().map(|s| s.swaps).sum(),
            replans: statuses.iter().map(|s| s.replans).sum(),
            generation: statuses.iter().map(|s| s.generation).max().unwrap_or(0),
            replicas: statuses.len(),
            admitted: admission.admitted,
            rejected_queue_full: admission.rejected_queue_full,
            rejected_deadline: admission.rejected_deadline,
            rejected_quota: admission.rejected_quota,
            rejected_kv: admission.rejected_kv,
            cancelled: admission.cancelled,
            failed: admission.failed,
            generated_tokens: statuses.iter().map(|s| s.generated_tokens).sum(),
            generations: statuses.iter().map(|s| s.generations_done).sum(),
            kv_preemptions: statuses.iter().map(|s| s.kv_preemptions).sum(),
            kv_used_tokens: kv_used,
            kv_shared_tokens: statuses.iter().map(|s| s.kv_shared_tokens).sum(),
            kv_budget_tokens: statuses.iter().map(|s| s.kv_budget_tokens).sum(),
            kv_avg_bits: if kv_used == 0 {
                32.0
            } else {
                statuses
                    .iter()
                    .map(|s| s.kv_avg_bits * s.kv_used_tokens as f64)
                    .sum::<f64>()
                    / kv_used as f64
            },
            slo_by_class: {
                let mut slo = [SloClassStats::default(); SLO_CLASSES];
                for s in statuses {
                    for (a, b) in slo.iter_mut().zip(&s.slo) {
                        a.accumulate(b);
                    }
                }
                slo
            },
            qos_served: {
                let mut q = [0usize; 3];
                for s in statuses {
                    for (a, b) in q.iter_mut().zip(&s.qos_served) {
                        *a += b;
                    }
                }
                q
            },
            ..ServerReport::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.record_request(0.010, 128);
        m.record_request(0.020, 128);
        assert_eq!(m.requests, 2);
        assert_eq!(m.tokens, 256);
        let s = m.latency_summary().unwrap();
        assert!((s.mean - 0.015).abs() < 1e-9);
    }

    #[test]
    fn wave_counters_accumulate() {
        use crate::runtime::{RuntimeScheme, WaveStats};
        let mut m = Metrics::new();
        assert!(m.wave_latency_summary().is_none());
        assert_eq!(m.wave_fill_ratio(), 1.0);
        let report = WaveReport {
            waves: vec![
                WaveStats {
                    scheme: RuntimeScheme::Fp16,
                    tile_m: 64,
                    items: 2,
                    padded_rows: 128,
                    useful_rows: 128,
                    start_s: 0.0,
                    elapsed_s: 0.004,
                    busy_s: 0.006,
                },
                WaveStats {
                    scheme: RuntimeScheme::W4A4,
                    tile_m: 4,
                    items: 1,
                    padded_rows: 4,
                    useful_rows: 1,
                    start_s: 0.004,
                    elapsed_s: 0.001,
                    busy_s: 0.001,
                },
            ],
            elapsed_s: 0.005,
        };
        m.record_dispatch(&report);
        m.record_dispatch(&report);
        assert_eq!(m.grouped_dispatches, 2);
        assert_eq!(m.waves, 4);
        assert_eq!(m.max_concurrent_waves, 2);
        assert_eq!(m.expert_calls, 6);
        assert_eq!(m.padded_tokens, 264);
        assert_eq!(m.useful_rows, 258);
        let fp16 = m.scheme_wave_stats()["fp16"];
        assert_eq!((fp16.waves, fp16.items), (2, 4));
        assert!((fp16.fill_ratio() - 1.0).abs() < 1e-12);
        let w44 = m.scheme_wave_stats()["w4a4"];
        assert!((w44.fill_ratio() - 0.25).abs() < 1e-12);
        assert!((m.wave_fill_ratio() - 258.0 / 264.0).abs() < 1e-12);
        assert_eq!(m.wave_latency_summary().unwrap().n, 4);
        m.note_planned_fill(0.75);
        assert_eq!(m.last_planned_fill, 0.75);
    }

    #[test]
    fn wave_latency_window_is_bounded() {
        use crate::runtime::{RuntimeScheme, WaveStats};
        let mut m = Metrics::new();
        let wave = |elapsed_s: f64| WaveStats {
            scheme: RuntimeScheme::Fp16,
            tile_m: 4,
            items: 1,
            padded_rows: 4,
            useful_rows: 4,
            start_s: 0.0,
            elapsed_s,
            busy_s: elapsed_s,
        };
        for i in 0..(WAVE_LATENCY_WINDOW + 100) {
            m.record_dispatch(&WaveReport { waves: vec![wave(i as f64)], elapsed_s: 0.0 });
        }
        let s = m.wave_latency_summary().unwrap();
        assert_eq!(s.n, WAVE_LATENCY_WINDOW, "ring must cap retained samples");
        // the earliest samples were overwritten by the newest
        assert!(s.min >= 100.0 - 1e-9, "oldest surviving sample is {}", s.min);
        assert_eq!(m.waves, WAVE_LATENCY_WINDOW + 100, "counters still see every wave");
    }

    #[test]
    fn cluster_report_flattens_to_the_legacy_shape() {
        let replica = |id: usize, lat: f64| ReplicaReport {
            id,
            requests: 2,
            tokens: 100,
            executed_batches: 2,
            stolen_batches: id, // replica 1 stole one batch
            expert_calls: 10,
            padded_rows: 64,
            useful_rows: 48,
            waves: 3,
            max_concurrent_waves: 2 + id,
            wave_padded_rows: 32,
            wave_useful_rows: 24,
            max_queue_depth: 1,
            swaps: 5,
            replans: 1,
            last_drift: 0.1 * (id + 1) as f64,
            drift_vector: vec![0.1 * (id + 1) as f64, 0.05],
            replan_history: vec![ReplanEvent {
                at_s: 1.0,
                drift: 0.2,
                changes: 3,
                swapped: 3,
                r: 0.75,
                bits_before: 5.0,
                bits_after: 4.8,
                generation: 1,
            }],
            shed_cancelled: id,
            qos_served: [id, 2, 0],
            slo: {
                let mut s = [SloClassStats::default(); SLO_CLASSES];
                s[1] = SloClassStats {
                    served: 2,
                    deadline_hit: 1,
                    deadline_miss: 1,
                    queue_s: 0.002,
                    compute_s: 0.020,
                    stream_s: 0.010,
                };
                s
            },
            served_by_generation: vec![(id as u64, 2)],
            queue_wait_by_priority: [
                None,
                Some(Summary::of(&[0.001])),
                Some(Summary::of(&[0.0005])),
            ],
            latency_by_class: [None, Some(Summary::of(&[lat, lat])), None, None],
            generation: id as u64,
            scheme_counts: vec![(RuntimeScheme::Fp16, 4)],
            latency: Some(Summary::of(&[lat, lat])),
            queue_wait: Some(Summary::of(&[0.001])),
            wave_latency: Some(Summary::of(&[0.002])),
            decode_steps: 4,
            prefill_rows: 12,
            decode_rows: 6,
            generated_tokens: 8,
            generations: 2,
            step_latency: Some(Summary::of(&[0.003, 0.004])),
            kv_peak_tokens: 40 + id,
            kv_budget_tokens: 128,
            kv_used_tokens: 20 + id,
            kv_shared_tokens: 8,
            kv_avg_bits: if id == 0 { 32.0 } else { 8.0 },
            kv_preemptions: id,
            elapsed_s: 2.0,
            trace: vec![],
            trace_dropped: 0,
        };
        let report = ClusterReport {
            replicas: vec![replica(0, 0.010), replica(1, 0.030)],
            router: RouterStats {
                batches: 4,
                routed: vec![3, 1],
                max_queue_depth: 7,
                shed_cancelled: 1,
                last_planned_fill: 0.9,
                elapsed_s: 2.0,
                trace: vec![],
                trace_dropped: 0,
            },
            admission: AdmissionReport {
                admitted: 7,
                rejected_queue_full: 2,
                rejected_deadline: 1,
                rejected_quota: 1,
                rejected_kv: 1,
                cancelled: 3,
                failed: 0,
            },
            trace: TraceLog::empty(),
        };
        assert_eq!(report.total_requests(), 4);
        assert_eq!(report.total_tokens(), 200);
        assert_eq!(report.total_steals(), 1);
        assert!((report.throughput_tps() - 100.0).abs() < 1e-9, "200 tok / 2 s wall");
        let flat = report.flatten();
        assert_eq!(flat.requests, 4);
        assert_eq!(flat.tokens, 200);
        assert_eq!(flat.replicas, 2);
        assert_eq!(flat.stolen_batches, 1);
        assert_eq!(flat.expert_calls, 20);
        assert_eq!(flat.waves, 6);
        assert_eq!(flat.max_concurrent_waves, 3, "max over replicas");
        assert_eq!(flat.max_queue_depth, 7, "admission depth comes from the router");
        assert!((flat.last_planned_fill - 0.9).abs() < 1e-12);
        assert_eq!((flat.swaps, flat.replans), (10, 2));
        assert!((flat.last_drift - 0.2).abs() < 1e-12, "worst replica drift");
        assert_eq!(flat.generation, 1, "highest replica generation");
        // QoS-redesign fields: admission totals pass through, drift vector
        // takes the worst replica per layer, per-priority p99 merges
        // replica samples, qos counts sum
        assert_eq!((flat.admitted, flat.cancelled), (7, 3));
        assert_eq!((flat.rejected_queue_full, flat.rejected_deadline), (2, 1));
        assert_eq!(flat.replan_events, 2);
        assert_eq!(flat.drift_vector, vec![0.2, 0.05]);
        assert_eq!(flat.qos_served, [1, 4, 0]);
        assert_eq!(flat.queue_wait_p99_by_priority[0], 0.0, "no Low samples");
        assert!((flat.queue_wait_p99_by_priority[1] - 0.001).abs() < 1e-12);
        assert!((flat.queue_wait_p99_by_priority[2] - 0.0005).abs() < 1e-12);
        let hist = report.replan_history();
        assert_eq!(hist.len(), 2, "events from both replicas, merged");
        assert!((flat.padding_ratio - (1.0 - 48.0 / 64.0 * 1.0)).abs() < 1e-9);
        assert!((flat.wave_fill_ratio - 48.0 / 64.0).abs() < 1e-12);
        // percentiles merge samples across replicas, not averages of summaries
        assert!(flat.p50_latency_s >= 0.010 && flat.p50_latency_s <= 0.030);
        // decode-loop fields: counters sum, kv peak takes the worst
        // replica, throughput is tokens over the longest wall clock
        assert_eq!(flat.rejected_quota, 1);
        assert_eq!((flat.decode_steps, flat.generated_tokens), (8, 16));
        assert_eq!((flat.prefill_rows, flat.decode_rows), (24, 12));
        assert_eq!(flat.generations, 4);
        assert_eq!(flat.kv_peak_tokens, 41);
        // paged-kv fields: used/shared sum, avg bits weighted by used
        // tokens, preemptions sum, kv rejects pass through from admission
        assert_eq!((flat.kv_used_tokens, flat.kv_shared_tokens), (41, 16));
        let expect_bits = (32.0 * 20.0 + 8.0 * 21.0) / 41.0;
        assert!((flat.kv_avg_bits - expect_bits).abs() < 1e-9);
        assert_eq!((flat.kv_preemptions, flat.rejected_kv), (1, 1));
        assert!((flat.decode_tps - 16.0 / 2.0).abs() < 1e-9);
        assert!(flat.p50_step_s >= 0.003 && flat.p50_step_s <= 0.004);
        // SLO accounting sums per class; served-bits attribution merges
        // generation histograms across replicas
        assert_eq!(flat.slo_by_class[1].served, 4);
        assert_eq!(flat.slo_by_class[1].deadline_hit, 2);
        assert!((flat.slo_by_class[1].hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(flat.slo_by_class[0].served, 0);
        assert!((flat.slo_by_class[0].hit_rate() - 1.0).abs() < 1e-12);
        // per-class latency merges replica summaries; untouched classes
        // stay None
        let by_class = report.latency_by_class();
        assert!(by_class[0].is_none() && by_class[2].is_none() && by_class[3].is_none());
        let standard = by_class[1].as_ref().unwrap();
        assert_eq!(standard.n, 4);
        assert!(standard.p99 >= 0.010 && standard.p99 <= 0.030);
        assert_eq!(flat.served_by_generation, vec![(0, 2), (1, 2)]);
        assert!(flat.trace.is_empty(), "no tracing in this synthetic report");
    }

    #[test]
    fn decode_step_counters_and_bounded_ring() {
        let mut m = Metrics::new();
        assert!(m.step_latency_summary().is_none());
        m.record_decode_step(6, 0, 1, 0, 0.002);
        m.record_decode_step(0, 4, 4, 2, 0.001);
        assert_eq!(m.decode_steps, 2);
        assert_eq!((m.prefill_rows, m.decode_rows), (6, 4));
        assert_eq!((m.generated_tokens, m.generations), (5, 2));
        assert_eq!(m.step_latency_summary().unwrap().n, 2);
        m.note_kv_occupancy(&KvOccupancy {
            reserved_tokens: 10,
            budget_tokens: 100,
            seqs: 2,
            peak_tokens: 30,
            used_tokens: 7,
            shared_tokens: 3,
            avg_kv_bits: 16.0,
            ..Default::default()
        });
        assert_eq!(
            (m.kv_reserved_tokens, m.kv_peak_tokens, m.kv_budget_tokens),
            (10, 30, 100)
        );
        assert_eq!((m.kv_used_tokens, m.kv_shared_tokens), (7, 3));
        assert!((m.kv_avg_bits - 16.0).abs() < 1e-12);
        m.record_kv_preemptions(2);
        assert_eq!(m.kv_preemptions, 2);
        // ring caps retained samples; counters still see every step
        for _ in 0..STEP_LATENCY_WINDOW + 50 {
            m.record_decode_step(0, 1, 1, 0, 0.001);
        }
        assert_eq!(m.step_latency_samples().len(), STEP_LATENCY_WINDOW);
        assert_eq!(m.decode_steps, 2 + STEP_LATENCY_WINDOW + 50);
    }

    #[test]
    fn online_counters() {
        let mut m = Metrics::new();
        assert!(m.queue_wait_summary().is_none());
        m.record_queue_wait(0.002, Priority::Normal);
        m.record_queue_wait(0.004, Priority::High);
        assert!((m.queue_wait_summary().unwrap().mean - 0.003).abs() < 1e-9);
        assert_eq!(m.queue_waits_by_priority()[Priority::Normal.index()], vec![0.002]);
        assert_eq!(m.queue_waits_by_priority()[Priority::High.index()], vec![0.004]);
        assert!(m.queue_waits_by_priority()[Priority::Low.index()].is_empty());
        m.note_queue_depth(3);
        m.note_queue_depth(1);
        assert_eq!(m.max_queue_depth, 3);
        m.swaps += 2;
        m.replans += 1;
        m.last_drift = 0.4;
        assert_eq!((m.swaps, m.replans), (2, 1));
    }

    #[test]
    fn qos_counts_default_to_standard() {
        let mut m = Metrics::new();
        m.note_qos(Some(QosClass::Interactive));
        m.note_qos(None);
        m.note_qos(Some(QosClass::Batch));
        m.note_qos(None);
        assert_eq!(m.qos_served, [1, 2, 1]);
    }

    #[test]
    fn replan_history_is_bounded() {
        let mut m = Metrics::new();
        let ev = |i: usize| ReplanEvent {
            at_s: i as f64,
            drift: 0.2,
            changes: 1,
            swapped: 1,
            r: 0.75,
            bits_before: 5.0,
            bits_after: 5.0,
            generation: i as u64,
        };
        for i in 0..REPLAN_HISTORY + 10 {
            m.note_replan(ev(i));
        }
        let h = m.replan_history();
        assert_eq!(h.len(), REPLAN_HISTORY, "ring caps retained events");
        assert_eq!(h[0].generation, 10, "oldest events dropped first");
        assert_eq!(h.last().unwrap().generation, (REPLAN_HISTORY + 9) as u64);
    }

    #[test]
    fn request_and_queue_wait_rings_are_bounded() {
        let mut m = Metrics::new();
        for i in 0..REQUEST_LATENCY_WINDOW + 10 {
            m.record_request(i as f64, 1);
        }
        let s = m.latency_summary().unwrap();
        assert_eq!(s.n, REQUEST_LATENCY_WINDOW, "latency ring caps samples");
        assert!(s.min >= 10.0 - 1e-9, "oldest latencies overwritten, min is {}", s.min);
        assert_eq!(m.requests, REQUEST_LATENCY_WINDOW + 10, "counters see every request");
        for i in 0..QUEUE_WAIT_WINDOW + 5 {
            m.record_queue_wait(i as f64, Priority::High);
        }
        assert_eq!(m.queue_wait_summary().unwrap().n, QUEUE_WAIT_WINDOW);
        let by_pri = m.queue_wait_by_priority_summary();
        assert_eq!(by_pri[Priority::High.index()].as_ref().unwrap().n, QUEUE_WAIT_WINDOW);
        assert!(by_pri[Priority::Low.index()].is_none());
        assert!(m.queue_wait_summary().unwrap().min >= 5.0 - 1e-9);
    }

    #[test]
    fn slo_accounting_tracks_classes_deadlines_and_generations() {
        let mut m = Metrics::new();
        m.note_slo(Some(QosClass::Interactive), Deadline::Hit, 0.001, 0.010, 0.002, 0);
        m.note_slo(Some(QosClass::Interactive), Deadline::Miss, 0.002, 0.020, 0.004, 1);
        m.note_slo(None, Deadline::None, 0.003, 0.030, 0.0, 1);
        let inter = &m.slo[slo_class_index(Some(QosClass::Interactive))];
        assert_eq!(inter.served, 2);
        assert_eq!((inter.deadline_hit, inter.deadline_miss), (1, 1));
        assert!((inter.hit_rate() - 0.5).abs() < 1e-12);
        let unclassified = &m.slo[slo_class_index(None)];
        assert_eq!(unclassified.served, 1);
        assert!((unclassified.hit_rate() - 1.0).abs() < 1e-12, "no deadline is never a miss");
        assert!((unclassified.queue_s - 0.003).abs() < 1e-12);
        assert_eq!(m.served_by_generation(), vec![(0, 1), (1, 2)]);
        assert_eq!(slo_class_name(0), "interactive");
        assert_eq!(slo_class_name(SLO_CLASSES - 1), "none");
        // per-class latency rings: samples land on the request's class,
        // unclassified traffic on the last slot
        m.record_class_latency(Some(QosClass::Interactive), 0.010);
        m.record_class_latency(Some(QosClass::Interactive), 0.020);
        m.record_class_latency(None, 0.030);
        let by_class = m.latency_by_class_summary();
        assert_eq!(by_class[0].as_ref().unwrap().n, 2);
        assert!(by_class[1].is_none() && by_class[2].is_none());
        assert!((by_class[SLO_CLASSES - 1].as_ref().unwrap().mean - 0.030).abs() < 1e-12);
    }

    #[test]
    fn record_dispatch_emits_wave_spans_only_when_tracing() {
        use crate::obs::{TraceClock, TraceConfig};
        use crate::runtime::{RuntimeScheme, WaveStats};
        let report = WaveReport {
            waves: vec![WaveStats {
                scheme: RuntimeScheme::Fp16,
                tile_m: 16,
                items: 2,
                padded_rows: 32,
                useful_rows: 30,
                start_s: 0.0,
                elapsed_s: 0.001,
                busy_s: 0.001,
            }],
            elapsed_s: 0.001,
        };
        let mut m = Metrics::new();
        m.record_dispatch(&report);
        assert!(m.take_trace().0.is_empty(), "default tracer records nothing");
        m.set_tracer(SpanCollector::new(TraceClock::new(), Track::Replica(3), TraceConfig::on()));
        m.record_dispatch(&report);
        let (events, dropped) = m.take_trace();
        assert_eq!(dropped, 0);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].track, Track::Replica(3));
        match &events[0].kind {
            EventKind::Wave { scheme, rows, padded, .. } => {
                assert_eq!(*scheme, "fp16");
                assert_eq!((*rows, *padded), (30, 32));
            }
            other => panic!("expected a wave span, got {other:?}"),
        }
    }
}
