//! L3 serving coordinator: request queue, dynamic batcher, expert
//! grouping/padding, PJRT dispatch and metrics.
//!
//! This is the system half of MxMoE (§4.3): routing and batching live in
//! rust, expert FFN compute runs through the AOT PJRT executables — one
//! executable per (runtime scheme, tile_m), dispatched per the
//! mixed-precision allocation. Python is nowhere on this path.

pub mod engine;
pub mod metrics;
pub mod server;

pub use engine::ServingEngine;
pub use metrics::Metrics;
pub use server::{Request, Response, ServeConfig, Server};
