//! L3 serving coordinator: request queue, continuous batcher, expert
//! grouping/padding, PJRT dispatch and metrics.
//!
//! This is the system half of MxMoE (§4.3): routing and batching live in
//! rust, expert FFN compute runs through the AOT PJRT executables — one
//! executable per (runtime scheme, tile_m), dispatched per the
//! mixed-precision allocation. Python is nowhere on this path.
//!
//! The coordinator is built on the [`crate::serve`] subsystem: batch
//! cutting comes from [`crate::serve::queue`], the live expert table from
//! [`crate::serve::hotswap`], and [`Server::start_online`] runs the
//! telemetry → drift → replan → hot-swap loop between batches
//! (DESIGN.md §Online-Serving).

pub mod engine;
pub mod metrics;
pub mod server;

pub use engine::{uniform_engine, ServingEngine};
pub use metrics::Metrics;
pub use server::{OnlineConfig, Request, Response, ServeConfig, Server, ServerReport};
