//! L3 serving coordinator: request queue, continuous batcher, expert
//! grouping/padding, PJRT dispatch and metrics.
//!
//! This is the system half of MxMoE (§4.3): routing and batching live in
//! rust, expert FFN compute runs through the AOT PJRT executables — one
//! executable per (runtime scheme, tile_m), dispatched per the
//! mixed-precision allocation. Python is nowhere on this path.
//!
//! The coordinator is built on the [`crate::serve`] subsystem: batch
//! cutting comes from [`crate::serve::queue`], the live expert table from
//! [`crate::serve::hotswap`], and the online loop runs each replica's
//! telemetry → drift → replan → hot-swap cycle between batches
//! (DESIGN.md §Online-Serving). Since DESIGN.md §Sharded-Serving the
//! serve queue shards across N engine replicas: [`cluster`] owns the
//! admission queue and the expert-affinity router, [`Server`] remains the
//! 1-replica façade.

pub mod cluster;
pub mod engine;
pub mod metrics;
pub mod server;

pub use cluster::{
    affinity_score, choose_replica, measured_speeds, scheme_speed, AffinityConfig, Cluster,
    ClusterConfig, OnlineConfig, SchemeSpeeds,
};
pub use engine::{uniform_engine, ReplanStaging, ServingEngine};
pub use metrics::{
    slo_class_index, slo_class_name, ClusterReport, HttpReport, Metrics, ReplanEvent,
    ReplicaReport, RouterStats, ServerReport, SloClassStats, SLO_CLASSES,
};
pub use server::{Request, Response, ServeConfig, Server};
