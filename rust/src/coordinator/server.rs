//! Request server: admission queue + continuous batcher in front of the
//! engine, with an optional online re-allocation loop.
//!
//! The engine (and its PJRT handles) are not `Send`, so the server thread
//! *builds* the engine locally and owns it for its lifetime; clients talk
//! over channels. Batch cutting is delegated to
//! [`crate::serve::queue::ContinuousBatcher`]: batches close on the
//! sequence cap, the tile-set token budget, or the oldest request's wait
//! deadline, and a token-budget cut leaves the tail queued — nothing is
//! dropped, including across hot-swaps. When started with
//! [`Server::start_online`], the loop runs the engine's
//! telemetry → drift → replan → hot-swap cycle between batches.

use std::path::PathBuf;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::alloc::Allocation;
use crate::moe::{ModelConfig, MoeLm};
use crate::ser::MxtFile;
use crate::serve::queue::{BatchPolicy, ContinuousBatcher};
use crate::serve::replan::Replanner;
pub use crate::serve::queue::{Request, Response};

use super::engine::ServingEngine;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub max_batch_seqs: usize,
    /// Concatenated-token budget per batch (tile-set sizing; see
    /// [`crate::runtime::TILE_MS`]).
    pub max_batch_tokens: usize,
    pub max_wait: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let p = BatchPolicy::default();
        ServeConfig {
            max_batch_seqs: p.max_seqs,
            max_batch_tokens: p.max_tokens,
            max_wait: p.max_wait,
        }
    }
}

impl ServeConfig {
    fn policy(&self) -> BatchPolicy {
        BatchPolicy {
            max_seqs: self.max_batch_seqs,
            max_tokens: self.max_batch_tokens,
            max_wait: self.max_wait,
        }
    }
}

/// Everything the online loop needs beyond the static-plan server: the
/// workload-independent replanner and the calibration frequency vector
/// that seeds the drift baseline.
pub struct OnlineConfig {
    pub replanner: Replanner,
    /// Per-layer routed-expert calibration frequencies
    /// ([`crate::alloc::activation_frequencies`]).
    pub baseline: Vec<Vec<f64>>,
    /// Telemetry EWMA step; `None` keeps the engine default.
    pub ewma_alpha: Option<f64>,
}

/// Handle to a running server thread.
pub struct Server {
    tx: mpsc::Sender<Request>,
    handle: Option<thread::JoinHandle<ServerReport>>,
}

/// Final statistics returned at shutdown.
#[derive(Clone, Debug)]
pub struct ServerReport {
    pub requests: usize,
    pub tokens: usize,
    pub throughput_tps: f64,
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
    pub p50_queue_wait_s: f64,
    pub expert_calls: usize,
    pub padding_ratio: f64,
    /// Waves executed by grouped dispatch (0 under sequential mode).
    pub waves: usize,
    /// Most waves in flight in one grouped dispatch.
    pub max_concurrent_waves: usize,
    /// Useful fraction of rows shipped by grouped dispatch.
    pub wave_fill_ratio: f64,
    /// p50 wave wall-clock, seconds (0 when no waves ran).
    pub p50_wave_s: f64,
    /// Planner-projected tile fill of the last batch cut.
    pub last_planned_fill: f64,
    /// Deepest admission queue observed at a batch cut.
    pub max_queue_depth: usize,
    /// Drift-triggered MCKP re-solves (0 for static-plan serving).
    pub replans: usize,
    /// Expert slots hot-swapped to a new runtime family.
    pub swaps: usize,
    /// Telemetry drift at the last check.
    pub last_drift: f64,
    /// Final plan generation (0 = the boot plan served throughout).
    pub generation: u64,
}

impl Server {
    /// Start a static-plan server thread: loads weights, builds the engine
    /// with the given allocation, then serves until the request channel
    /// closes.
    pub fn start(
        cfg: ModelConfig,
        weights_path: PathBuf,
        artifacts: PathBuf,
        allocation: Allocation,
        serve_cfg: ServeConfig,
    ) -> Result<Server> {
        Server::spawn(cfg, weights_path, artifacts, allocation, serve_cfg, None)
    }

    /// Start a server with the online re-allocation loop enabled: live
    /// activation telemetry is compared against `online.baseline`, and on
    /// drift the precision plan is re-solved and hot-swapped without
    /// dropping queued requests.
    pub fn start_online(
        cfg: ModelConfig,
        weights_path: PathBuf,
        artifacts: PathBuf,
        allocation: Allocation,
        serve_cfg: ServeConfig,
        online: OnlineConfig,
    ) -> Result<Server> {
        Server::spawn(cfg, weights_path, artifacts, allocation, serve_cfg, Some(online))
    }

    fn spawn(
        cfg: ModelConfig,
        weights_path: PathBuf,
        artifacts: PathBuf,
        allocation: Allocation,
        serve_cfg: ServeConfig,
        online: Option<OnlineConfig>,
    ) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Request>();
        let handle = thread::spawn(move || {
            let weights = MxtFile::load(&weights_path).expect("load weights");
            let lm = MoeLm::load_mxt(&cfg, &weights).expect("build model");
            let mut engine =
                ServingEngine::new(lm, &artifacts, &allocation).expect("build engine");
            let replanner = online.map(|o| {
                engine.set_baseline(o.baseline);
                if let Some(a) = o.ewma_alpha {
                    engine.set_telemetry_alpha(a);
                }
                o.replanner
            });
            serve_loop(&mut engine, rx, &serve_cfg, replanner.as_ref());
            let m = engine.metrics();
            let lat = m.latency_summary();
            let qw = m.queue_wait_summary();
            ServerReport {
                requests: m.requests,
                tokens: m.tokens,
                throughput_tps: m.throughput_tps(),
                p50_latency_s: lat.as_ref().map(|s| s.p50).unwrap_or(0.0),
                p99_latency_s: lat.as_ref().map(|s| s.p99).unwrap_or(0.0),
                p50_queue_wait_s: qw.as_ref().map(|s| s.p50).unwrap_or(0.0),
                expert_calls: m.expert_calls,
                padding_ratio: m.padding_ratio(),
                waves: m.waves,
                max_concurrent_waves: m.max_concurrent_waves,
                wave_fill_ratio: m.wave_fill_ratio(),
                p50_wave_s: m.wave_latency_summary().map(|s| s.p50).unwrap_or(0.0),
                last_planned_fill: m.last_planned_fill,
                max_queue_depth: m.max_queue_depth,
                replans: m.replans,
                swaps: m.swaps,
                last_drift: m.last_drift,
                generation: engine.generation(),
            }
        });
        Ok(Server { tx, handle: Some(handle) })
    }

    /// Submit a request; returns the reply receiver.
    pub fn submit(&self, tokens: Vec<u32>) -> Result<mpsc::Receiver<Response>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request { tokens, reply, arrived: Instant::now() })
            .map_err(|_| anyhow::anyhow!("server closed"))?;
        Ok(rx)
    }

    /// Close the queue and collect the final report.
    pub fn shutdown(mut self) -> ServerReport {
        drop(self.tx);
        self.handle.take().unwrap().join().expect("server thread panicked")
    }
}

fn serve_loop(
    engine: &mut ServingEngine,
    rx: mpsc::Receiver<Request>,
    cfg: &ServeConfig,
    replanner: Option<&Replanner>,
) {
    let mut batcher = ContinuousBatcher::new(cfg.policy());
    let mut closed = false;
    loop {
        // admit: block for the first request only when nothing is queued
        if batcher.depth() == 0 {
            if closed {
                return;
            }
            match rx.recv() {
                Ok(r) => batcher.push(r),
                Err(_) => return, // channel closed, queue drained
            }
        }
        if !closed {
            // drain whatever is already queued (requests that arrived while
            // the previous batch was executing must not serve as singletons
            // — §Perf)
            loop {
                match rx.try_recv() {
                    Ok(r) => batcher.push(r),
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        closed = true;
                        break;
                    }
                }
            }
            // then wait for stragglers until a cut condition holds
            while !closed && !batcher.ready(Instant::now()) {
                let deadline = batcher.oldest_deadline().expect("non-empty queue");
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    break;
                }
                match rx.recv_timeout(left) {
                    Ok(r) => batcher.push(r),
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        closed = true;
                        break;
                    }
                }
            }
        }
        engine.metrics_mut().note_queue_depth(batcher.depth());
        let batch = batcher.take_batch();
        if batch.is_empty() {
            continue;
        }
        // planner-fed fill estimate of the batch actually cut (the whole
        // queue may be deeper than one cut; see ContinuousBatcher::
        // fill_estimate for the queue-wide projection)
        let cut_tokens: usize = batch.iter().map(|r| r.tokens.len()).sum();
        let planned_fill = crate::runtime::dispatch::fill_estimate(cut_tokens).fill_ratio();
        engine.metrics_mut().note_planned_fill(planned_fill);
        process_batch(engine, batch);
        // the online loop runs strictly between batches: in-flight work
        // always completes on the generation it started on
        if let Some(rp) = replanner {
            match engine.maybe_replan(rp) {
                Ok(Some(outcome)) => {
                    eprintln!(
                        "replan: drift {:.3} → {} slot(s) changed, {} swapped (gen {})",
                        outcome.drift,
                        outcome.changes,
                        outcome.swapped,
                        engine.generation()
                    );
                }
                Ok(None) => {}
                Err(e) => eprintln!("replan failed (serving continues on old plan): {e:#}"),
            }
        }
    }
}

fn process_batch(engine: &mut ServingEngine, batch: Vec<Request>) {
    let cut_at = Instant::now();
    let generation = engine.generation();
    let seqs: Vec<&[u32]> = batch.iter().map(|r| r.tokens.as_slice()).collect();
    match engine.forward_batch(&seqs) {
        Ok(logits_batch) => {
            for (req, logits) in batch.iter().zip(logits_batch) {
                let t = req.tokens.len();
                // argmax of the final position
                let last = logits.row(t - 1);
                let mut best = 0usize;
                for i in 1..last.len() {
                    if last[i] > last[best] {
                        best = i;
                    }
                }
                // mean next-token NLL
                let mut nll = 0.0f64;
                for pos in 0..t - 1 {
                    let row = logits.row(pos);
                    let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
                    let z: f64 = row.iter().map(|&v| ((v as f64) - m).exp()).sum();
                    nll -= (logits.at(pos, req.tokens[pos + 1] as usize) as f64 - m) - z.ln();
                }
                let latency = req.arrived.elapsed();
                let queue_wait = cut_at.saturating_duration_since(req.arrived);
                let metrics = engine.metrics_mut();
                metrics.record_request(latency.as_secs_f64(), req.tokens.len());
                metrics.record_queue_wait(queue_wait.as_secs_f64());
                let _ = req.reply.send(Response {
                    next_token: best as u32,
                    mean_nll: nll / (t - 1).max(1) as f64,
                    latency,
                    queue_wait,
                    generation,
                });
            }
        }
        Err(e) => {
            eprintln!("batch failed: {e:#}");
        }
    }
}
