//! Request server: the single-engine façade over the replica cluster.
//!
//! Since DESIGN.md §Sharded-Serving, batching, routing and execution live
//! in [`super::cluster`]: the server is a 1-replica cluster, kept as the
//! stable entry point for callers that want one engine behind one queue.
//! The engine (and its PJRT handles) is not `Send`, so the replica thread
//! *builds* the engine locally and owns it for its lifetime; clients talk
//! over channels. Batch cutting is delegated to
//! [`crate::serve::queue::ContinuousBatcher`]: batches close on the
//! sequence cap, the tile-set token budget, or the oldest request's wait
//! deadline, and a token-budget cut leaves the tail queued — nothing is
//! dropped, including across hot-swaps, and a past-deadline tail re-cuts
//! immediately ([`crate::serve::queue::ContinuousBatcher::time_to_cut`]).
//! When started with
//! [`Server::start_online`], the replica runs the engine's
//! telemetry → drift → replan → hot-swap cycle between batches.

use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Duration;

use anyhow::Result;

use crate::alloc::Allocation;
use crate::moe::ModelConfig;
use crate::obs::TraceConfig;
use crate::serve::queue::BatchPolicy;
pub use crate::serve::queue::{Request, Response};
pub use crate::serve::request::{
    Admission, AdmissionConfig, FinishReason, ServeRequest, StreamEvent, Ticket,
};

use super::cluster::{Cluster, ClusterConfig};
pub use super::cluster::OnlineConfig;
pub use super::metrics::ServerReport;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub max_batch_seqs: usize,
    /// Concatenated-token budget per batch (tile-set sizing; see
    /// [`crate::runtime::TILE_MS`]).
    pub max_batch_tokens: usize,
    pub max_wait: Duration,
    /// Priority-aging quantum: a queued request gains one priority level
    /// per `aging` waited (starvation control for low priority).
    pub aging: Duration,
    /// Lifecycle-span tracing (DESIGN.md §Observability): off by default;
    /// flipping it on needs no rebuild and changes no served bits.
    pub trace: TraceConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let p = BatchPolicy::default();
        ServeConfig {
            max_batch_seqs: p.max_seqs,
            max_batch_tokens: p.max_tokens,
            max_wait: p.max_wait,
            aging: p.aging,
            trace: TraceConfig::default(),
        }
    }
}

impl ServeConfig {
    pub(crate) fn policy(&self) -> BatchPolicy {
        BatchPolicy {
            max_seqs: self.max_batch_seqs,
            max_tokens: self.max_batch_tokens,
            max_wait: self.max_wait,
            aging: self.aging,
        }
    }
}

/// Handle to a running 1-replica cluster.
pub struct Server {
    cluster: Cluster,
}

impl Server {
    /// Start a static-plan server: loads weights, builds the engine with
    /// the given allocation on a replica thread, then serves until the
    /// request channel closes.
    pub fn start(
        cfg: ModelConfig,
        weights_path: PathBuf,
        artifacts: PathBuf,
        allocation: Allocation,
        serve_cfg: ServeConfig,
    ) -> Result<Server> {
        let cluster = Cluster::start(
            cfg,
            weights_path,
            artifacts,
            allocation,
            ClusterConfig { serve: serve_cfg, ..ClusterConfig::default() },
        )?;
        Ok(Server { cluster })
    }

    /// Start a server with the online re-allocation loop enabled: live
    /// activation telemetry is compared against `online.baseline`, and on
    /// drift the precision plan is re-solved and hot-swapped without
    /// dropping queued requests.
    pub fn start_online(
        cfg: ModelConfig,
        weights_path: PathBuf,
        artifacts: PathBuf,
        allocation: Allocation,
        serve_cfg: ServeConfig,
        online: OnlineConfig,
    ) -> Result<Server> {
        let cluster = Cluster::start_online(
            cfg,
            weights_path,
            artifacts,
            allocation,
            ClusterConfig { serve: serve_cfg, ..ClusterConfig::default() },
            online,
        )?;
        Ok(Server { cluster })
    }

    /// Legacy untyped submission; returns the reply receiver. A thin shim
    /// over [`submit_request`](Self::submit_request) — see
    /// [`Cluster::submit`].
    pub fn submit(&self, tokens: Vec<u32>) -> Result<mpsc::Receiver<Response>> {
        self.cluster.submit(tokens)
    }

    /// Typed submission: blocks for queue room up to the admission
    /// budget, returns a cancellable [`Ticket`].
    pub fn submit_request(&self, req: ServeRequest) -> Result<Ticket> {
        self.cluster.submit_request(req)
    }

    /// Non-blocking typed submission with load-shedding
    /// ([`Admission::Rejected`] under overload).
    pub fn try_submit(&self, req: ServeRequest) -> Result<Admission> {
        self.cluster.try_submit(req)
    }

    /// KV-cached generation with token streaming (DESIGN.md §Decode-Loop):
    /// shorthand for [`submit_request`](Self::submit_request) with
    /// [`ServeRequest::generate`]. The ticket streams tokens as decode
    /// steps land ([`Ticket::wait_event`]) and still yields a final
    /// [`Response`].
    pub fn generate(&self, prompt: Vec<u32>, max_new_tokens: usize, stop: Vec<u32>) -> Result<Ticket> {
        self.cluster.generate(prompt, max_new_tokens, stop)
    }

    /// Close the queue and collect the final report (the cluster view
    /// flattened to the legacy single-engine shape).
    pub fn shutdown(self) -> ServerReport {
        self.cluster.shutdown().flatten()
    }
}
