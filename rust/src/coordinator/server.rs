//! Request server: queue + dynamic batcher in front of the engine.
//!
//! The engine (and its PJRT handles) are not `Send`, so the server thread
//! *builds* the engine locally and owns it for its lifetime; clients talk
//! over channels. The batcher implements the classic dynamic-batching
//! policy: close a batch when it reaches `max_batch_seqs` or when the
//! oldest queued request has waited `max_wait`.

use std::path::PathBuf;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::alloc::Allocation;
use crate::moe::{ModelConfig, MoeLm};
use crate::ser::MxtFile;

use super::engine::ServingEngine;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub max_batch_seqs: usize,
    pub max_wait: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { max_batch_seqs: 8, max_wait: Duration::from_millis(20) }
    }
}

/// A scoring request: token sequence in, next-token prediction + NLL out.
pub struct Request {
    pub tokens: Vec<u32>,
    pub reply: mpsc::Sender<Response>,
    pub arrived: Instant,
}

/// Response: argmax continuation of the last position + mean next-token
/// NLL over the sequence (the serving analogue of scoring).
#[derive(Clone, Debug)]
pub struct Response {
    pub next_token: u32,
    pub mean_nll: f64,
    pub latency: Duration,
}

/// Handle to a running server thread.
pub struct Server {
    tx: mpsc::Sender<Request>,
    handle: Option<thread::JoinHandle<ServerReport>>,
}

/// Final statistics returned at shutdown.
#[derive(Clone, Debug)]
pub struct ServerReport {
    pub requests: usize,
    pub tokens: usize,
    pub throughput_tps: f64,
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
    pub expert_calls: usize,
    pub padding_ratio: f64,
}

impl Server {
    /// Start the server thread: loads weights, builds the engine with the
    /// given allocation, then serves until the request channel closes.
    pub fn start(
        cfg: ModelConfig,
        weights_path: PathBuf,
        artifacts: PathBuf,
        allocation: Allocation,
        serve_cfg: ServeConfig,
    ) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Request>();
        let handle = thread::spawn(move || {
            let weights = MxtFile::load(&weights_path).expect("load weights");
            let lm = MoeLm::load_mxt(&cfg, &weights).expect("build model");
            let mut engine =
                ServingEngine::new(lm, &artifacts, &allocation).expect("build engine");
            serve_loop(&mut engine, rx, &serve_cfg);
            let lat = engine.metrics.latency_summary();
            ServerReport {
                requests: engine.metrics.requests,
                tokens: engine.metrics.tokens,
                throughput_tps: engine.metrics.throughput_tps(),
                p50_latency_s: lat.as_ref().map(|s| s.p50).unwrap_or(0.0),
                p99_latency_s: lat.as_ref().map(|s| s.p99).unwrap_or(0.0),
                expert_calls: engine.metrics.expert_calls,
                padding_ratio: engine.metrics.padding_ratio(),
            }
        });
        Ok(Server { tx, handle: Some(handle) })
    }

    /// Submit a request; returns the reply receiver.
    pub fn submit(&self, tokens: Vec<u32>) -> Result<mpsc::Receiver<Response>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request { tokens, reply, arrived: Instant::now() })
            .map_err(|_| anyhow::anyhow!("server closed"))?;
        Ok(rx)
    }

    /// Close the queue and collect the final report.
    pub fn shutdown(mut self) -> ServerReport {
        drop(self.tx);
        self.handle.take().unwrap().join().expect("server thread panicked")
    }
}

fn serve_loop(engine: &mut ServingEngine, rx: mpsc::Receiver<Request>, cfg: &ServeConfig) {
    loop {
        // block for the first request of the batch
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // channel closed
        };
        let mut batch = vec![first];
        // drain whatever is already queued (requests that arrived while the
        // previous batch was executing must not serve as singletons — §Perf)
        while batch.len() < cfg.max_batch_seqs {
            match rx.try_recv() {
                Ok(r) => batch.push(r),
                Err(_) => break,
            }
        }
        // then wait up to max_wait from *now* for stragglers
        if batch.len() < cfg.max_batch_seqs {
            let deadline = Instant::now() + cfg.max_wait;
            while batch.len() < cfg.max_batch_seqs {
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    break;
                }
                match rx.recv_timeout(left) {
                    Ok(r) => batch.push(r),
                    Err(_) => break,
                }
            }
        }
        process_batch(engine, batch);
    }
}

fn process_batch(engine: &mut ServingEngine, batch: Vec<Request>) {
    let seqs: Vec<&[u32]> = batch.iter().map(|r| r.tokens.as_slice()).collect();
    match engine.forward_batch(&seqs) {
        Ok(logits_batch) => {
            for (req, logits) in batch.iter().zip(logits_batch) {
                let t = req.tokens.len();
                // argmax of the final position
                let last = logits.row(t - 1);
                let mut best = 0usize;
                for i in 1..last.len() {
                    if last[i] > last[best] {
                        best = i;
                    }
                }
                // mean next-token NLL
                let mut nll = 0.0f64;
                for pos in 0..t - 1 {
                    let row = logits.row(pos);
                    let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
                    let z: f64 = row.iter().map(|&v| ((v as f64) - m).exp()).sum();
                    nll -= (logits.at(pos, req.tokens[pos + 1] as usize) as f64 - m) - z.ln();
                }
                let latency = req.arrived.elapsed();
                engine
                    .metrics
                    .record_request(latency.as_secs_f64(), req.tokens.len());
                let _ = req.reply.send(Response {
                    next_token: best as u32,
                    mean_nll: nll / (t - 1).max(1) as f64,
                    latency,
                });
            }
        }
        Err(e) => {
            eprintln!("batch failed: {e:#}");
        }
    }
}
