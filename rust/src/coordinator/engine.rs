//! The serving engine: native attention/routing + PJRT expert dispatch.

use std::path::Path;

use anyhow::Result;

use crate::alloc::Allocation;
use crate::moe::block::MoeBlock;
use crate::moe::{route, ModelConfig, MoeLm};
use crate::runtime::{pick_tile, PreparedExpert, Runtime, RuntimeScheme, TILE_MS};
use crate::tensor::Matrix;

use super::metrics::Metrics;

/// Per-(MoE-layer, expert) runtime assignment + prepared weight literals.
struct ExpertSlot {
    scheme: RuntimeScheme,
    prepared: PreparedExpert,
}

/// The engine owns the model, the PJRT runtime, and the prepared
/// mixed-precision expert artifacts. Single-threaded by design: the CPU
/// PJRT client parallelizes internally (XLA intra-op pool plays the role
/// of the SM array; the task queue discipline mirrors the fused tile
/// scheduler — see DESIGN.md §Hardware-Adaptation).
pub struct ServingEngine {
    pub lm: MoeLm,
    runtime: Runtime,
    /// `slots[block_pos][expert]` — routed then shared, per MoE layer.
    slots: Vec<Vec<ExpertSlot>>,
    pub metrics: Metrics,
}

impl ServingEngine {
    /// Build from a trained model + allocation. Quantizes every expert to
    /// its allocated runtime family and pre-compiles the executables.
    pub fn new(lm: MoeLm, artifacts: &Path, allocation: &Allocation) -> Result<ServingEngine> {
        let runtime = Runtime::cpu(artifacts)?;
        runtime.warmup_expert_ffn()?;
        let mut slots = Vec::new();
        for (pos, (_, block)) in lm.moe_blocks().iter().enumerate() {
            let mut layer_slots = Vec::new();
            for e in 0..block.total_experts() {
                // map the allocated (possibly per-linear) schemes to the
                // expert's runtime family: take the gate linear's family
                // (runtime executables are per-expert uniform; per-linear
                // mixing within an expert is an accuracy-side refinement)
                let scheme = RuntimeScheme::from_quant(&allocation.schemes[pos][e][0]);
                let prepared = PreparedExpert::prepare(block.expert_at(e), scheme)?;
                layer_slots.push(ExpertSlot { scheme, prepared });
            }
            slots.push(layer_slots);
        }
        Ok(ServingEngine { lm, runtime, slots, metrics: Metrics::new() })
    }

    pub fn platform(&self) -> String {
        self.runtime.platform()
    }

    /// Scheme histogram for reporting.
    pub fn scheme_counts(&self) -> Vec<(RuntimeScheme, usize)> {
        let mut counts = Vec::new();
        for s in RuntimeScheme::ALL {
            let n = self
                .slots
                .iter()
                .flat_map(|l| l.iter())
                .filter(|slot| slot.scheme == s)
                .count();
            if n > 0 {
                counts.push((s, n));
            }
        }
        counts
    }

    /// Run one expert's FFN over `m` rows via PJRT, chunking into the
    /// exported tile sizes and cropping padding.
    fn run_expert(&mut self, block_pos: usize, expert: usize, x: &Matrix) -> Result<Matrix> {
        let slot = &self.slots[block_pos][expert];
        let hidden = x.cols;
        let mut out = Matrix::zeros(x.rows, hidden);
        let mut r0 = 0;
        while r0 < x.rows {
            let remaining = x.rows - r0;
            // greedy decomposition: largest whole tile ≤ remaining, so
            // 68 tokens run as 64 + 4 instead of one padded 256-tile
            // (§Perf: padding 98% → ~2% on the serving path)
            let tile_m = TILE_MS
                .iter()
                .rev()
                .copied()
                .find(|&t| t <= remaining)
                .unwrap_or_else(|| pick_tile(remaining));
            let rows = remaining.min(tile_m);
            // pad to tile_m
            let mut xt = Matrix::zeros(tile_m, hidden);
            xt.data[..rows * hidden].copy_from_slice(&x.data[r0 * hidden..(r0 + rows) * hidden]);
            let y = self
                .runtime
                .run_expert_ffn(slot.scheme, tile_m, &xt, &slot.prepared.literals)?;
            out.data[r0 * hidden..(r0 + rows) * hidden]
                .copy_from_slice(&y.data[..rows * hidden]);
            self.metrics.expert_calls += 1;
            self.metrics.padded_tokens += tile_m;
            self.metrics.useful_rows += rows;
            r0 += rows;
        }
        Ok(out)
    }

    /// MoE block forward with PJRT expert dispatch (the hook body).
    fn moe_forward(&mut self, block_pos: usize, block: &MoeBlock, x: &Matrix) -> Result<Matrix> {
        let routing = route(x, &block.w_router, block.topk);
        let mut out = Matrix::zeros(x.rows, x.cols);
        for (e, (tokens, weights)) in routing.per_expert.iter().enumerate() {
            if tokens.is_empty() {
                continue;
            }
            let xe = x.gather_rows(tokens);
            let ye = self.run_expert(block_pos, e, &xe)?;
            out.scatter_add_rows(tokens, &ye, weights);
        }
        for si in 0..block.shared.len() {
            let ys = self.run_expert(block_pos, block.experts.len() + si, x)?;
            out.add_scaled(&ys, 1.0);
        }
        Ok(out)
    }

    /// Forward a batch of sequences; expert FFNs run on PJRT with
    /// cross-request token batching. Returns per-sequence logits.
    pub fn forward_batch(&mut self, batch: &[&[u32]]) -> Result<Vec<Matrix>> {
        // layer-position bookkeeping: map transformer layer → block pos
        let block_pos: std::collections::HashMap<usize, usize> = self
            .lm
            .moe_blocks()
            .iter()
            .enumerate()
            .map(|(pos, (l, _))| (*l, pos))
            .collect();
        let lm = unsafe { &*(&self.lm as *const MoeLm) }; // split borrow: lm is not mutated
        let mut err: Option<anyhow::Error> = None;
        let logits = lm.forward_batch_with_moe(batch, |l, block, x| {
            if err.is_some() {
                return Matrix::zeros(x.rows, x.cols);
            }
            match self.moe_forward(block_pos[&l], block, x) {
                Ok(y) => y,
                Err(e) => {
                    err = Some(e);
                    Matrix::zeros(x.rows, x.cols)
                }
            }
        });
        match err {
            Some(e) => Err(e),
            None => {
                self.metrics.batches += 1;
                Ok(logits)
            }
        }
    }
}

/// Convenience: uniform-precision engine (baseline rows of Fig. 5).
pub fn uniform_engine(
    lm: MoeLm,
    artifacts: &Path,
    scheme: crate::quant::QuantScheme,
) -> Result<ServingEngine> {
    let cfg: ModelConfig = lm.cfg.clone();
    let alloc = Allocation::uniform(&cfg, scheme);
    ServingEngine::new(lm, artifacts, &alloc)
}
