//! The serving engine: native attention/routing + PJRT expert dispatch,
//! rewired on top of [`crate::serve`] — the slot table lives in
//! [`crate::serve::hotswap`], live routing statistics feed
//! [`crate::serve::telemetry`], and [`maybe_replan`](ServingEngine::maybe_replan)
//! closes the telemetry → drift → re-solve → hot-swap loop.

use std::path::Path;
use std::sync::Arc;
use std::thread;

use anyhow::Result;

use crate::alloc::{Allocation, SensitivityTable};
use crate::moe::block::MoeBlock;
use crate::moe::router::Routing;
use crate::moe::{route, ModelConfig, MoeLm, StepSeq};
use crate::obs::provenance::{self, PlanContext, PlanRecord, PlanTrigger, ProvenanceLedger};
use crate::obs::EventKind;
use crate::runtime::dispatch::{self, ExpertInput};
use crate::runtime::{
    tile_decompose, DispatchMode, DispatchPlan, ExpertWork, Runtime, RuntimeScheme,
};
use crate::serve::hotswap::{StagedSwap, SwapStagingJob};
use crate::serve::replan::{diff_plans, ReplanOutcome, Replanner};
use crate::serve::request::QosClass;
use crate::serve::telemetry::{ActivationTelemetry, DEFAULT_EWMA_ALPHA};
use crate::serve::{SlotChange, SlotTable};
use crate::tensor::Matrix;
use crate::util::threadpool::default_threads;

use super::metrics::{Metrics, ReplanEvent};

/// The mutable serving state the MoE hook needs: PJRT runtime, the live
/// slot table, metrics and telemetry. Split out of [`ServingEngine`] so the
/// batched forward can borrow the (immutable) model and this (mutable)
/// dispatch state disjointly — no `unsafe` aliasing.
pub struct ExpertDispatcher {
    runtime: Runtime,
    slots: SlotTable,
    pub metrics: Metrics,
    pub telemetry: ActivationTelemetry,
    mode: DispatchMode,
    threads: usize,
}

impl ExpertDispatcher {
    /// Run one expert's FFN over `m` rows via PJRT, chunking into the
    /// exported tile sizes and cropping padding (the sequential reference
    /// path — the grouped pipeline must match it bit-for-bit).
    fn run_expert(&mut self, block_pos: usize, expert: usize, x: &Matrix) -> Result<Matrix> {
        // resolve the slot once per expert, not once per tile
        let slot = self.slots.slot(block_pos, expert);
        let scheme = slot.scheme;
        let literals = &slot.prepared.literals;
        let hidden = x.cols;
        let mut out = Matrix::zeros(x.rows, hidden);
        let mut r0 = 0;
        let mut calls = 0usize;
        let mut padded = 0usize;
        for tile_m in tile_decompose(x.rows) {
            let rows = (x.rows - r0).min(tile_m);
            // pad to tile_m
            let mut xt = Matrix::zeros(tile_m, hidden);
            xt.data[..rows * hidden].copy_from_slice(&x.data[r0 * hidden..(r0 + rows) * hidden]);
            let y = self.runtime.run_expert_ffn(scheme, tile_m, &xt, literals)?;
            out.data[r0 * hidden..(r0 + rows) * hidden]
                .copy_from_slice(&y.data[..rows * hidden]);
            calls += 1;
            padded += tile_m;
            r0 += rows;
        }
        self.metrics.expert_calls += calls;
        self.metrics.padded_tokens += padded;
        self.metrics.useful_rows += r0;
        Ok(out)
    }

    /// MoE block forward with PJRT expert dispatch (the hook body). Also
    /// feeds the routed activation counts into the live telemetry.
    fn moe_forward(&mut self, block_pos: usize, block: &MoeBlock, x: &Matrix) -> Result<Matrix> {
        let routing = route(x, &block.w_router, block.topk);
        self.telemetry.record(block_pos, &routing.activation_counts());
        match self.mode {
            DispatchMode::Sequential => self.moe_forward_sequential(block_pos, block, x, &routing),
            DispatchMode::Grouped => self.moe_forward_grouped(block_pos, block, x, &routing),
        }
    }

    /// Legacy expert-at-a-time dispatch.
    fn moe_forward_sequential(
        &mut self,
        block_pos: usize,
        block: &MoeBlock,
        x: &Matrix,
        routing: &Routing,
    ) -> Result<Matrix> {
        let mut out = Matrix::zeros(x.rows, x.cols);
        for (e, (tokens, weights)) in routing.per_expert.iter().enumerate() {
            if tokens.is_empty() {
                continue;
            }
            let xe = x.gather_rows(tokens);
            let ye = self.run_expert(block_pos, e, &xe)?;
            out.scatter_add_rows(tokens, &ye, weights);
        }
        for si in 0..block.shared.len() {
            let ys = self.run_expert(block_pos, block.experts.len() + si, x)?;
            out.add_scaled(&ys, 1.0);
        }
        Ok(out)
    }

    /// Grouped dispatch (DESIGN.md §GroupGEMM-Dispatch): plan the whole
    /// block's (expert, tile) work items, execute same-executable waves
    /// concurrently, then scatter results back in a fixed order — bit-for-
    /// bit identical to the sequential path, independent of thread count.
    fn moe_forward_grouped(
        &mut self,
        block_pos: usize,
        block: &MoeBlock,
        x: &Matrix,
        routing: &Routing,
    ) -> Result<Matrix> {
        let n_routed = block.experts.len();
        // ---- plan: one work entry per active expert ----
        let mut work: Vec<ExpertWork> = Vec::new();
        let mut gathered: Vec<Matrix> = Vec::new();
        for (e, (tokens, _)) in routing.per_expert.iter().enumerate() {
            if tokens.is_empty() {
                continue;
            }
            work.push(ExpertWork {
                expert: e,
                scheme: self.slots.slot(block_pos, e).scheme,
                rows: tokens.len(),
            });
            gathered.push(x.gather_rows(tokens));
        }
        let n_routed_work = work.len();
        for si in 0..block.shared.len() {
            let e = n_routed + si;
            work.push(ExpertWork {
                expert: e,
                scheme: self.slots.slot(block_pos, e).scheme,
                rows: x.rows,
            });
        }
        let plan = DispatchPlan::plan(&work);

        // ---- execute: all waves in flight on the worker pool ----
        let inputs: Vec<ExpertInput<'_>> = work
            .iter()
            .enumerate()
            .map(|(wi, w)| ExpertInput {
                x: if wi < n_routed_work { &gathered[wi] } else { x },
                literals: &self.slots.slot(block_pos, w.expert).prepared.literals,
            })
            .collect();
        let (outputs, report) = dispatch::execute(&self.runtime, &plan, &inputs, self.threads)?;
        drop(inputs);

        // ---- scatter: plan items are already in (work entry, row) order,
        // so one linear pass reproduces the sequential accumulation order
        let mut out = Matrix::zeros(x.rows, x.cols);
        let (identity, ones): (Vec<usize>, Vec<f32>) = if work.len() > n_routed_work {
            ((0..x.rows).collect(), vec![1.0f32; x.rows])
        } else {
            (Vec::new(), Vec::new())
        };
        for (ii, item) in plan.items.iter().enumerate() {
            let w = &work[item.input];
            let span = item.row0..item.row0 + item.rows;
            if w.expert < n_routed {
                let (tokens, weights) = &routing.per_expert[w.expert];
                out.scatter_add_rows(&tokens[span.clone()], &outputs[ii], &weights[span]);
            } else {
                // shared expert: rows map 1:1 onto the block input,
                // accumulated with weight 1.0 exactly like the sequential
                // path's `add_scaled(_, 1.0)`
                out.scatter_add_rows(&identity[span.clone()], &outputs[ii], &ones[span]);
            }
        }
        self.metrics.record_dispatch(&report);
        Ok(out)
    }
}

/// The engine owns the model, the PJRT runtime, and the prepared
/// mixed-precision expert artifacts. Expert FFNs dispatch as grouped
/// mixed-precision waves (DESIGN.md §GroupGEMM-Dispatch): the whole
/// block's (expert, tile) work items are planned up front and executed
/// concurrently, with PJRT executions of different precisions in flight
/// simultaneously. Batches still run serially with respect to each other,
/// so a hot-swap applied between batches never tears a batch across plan
/// generations.
pub struct ServingEngine {
    pub lm: MoeLm,
    allocation: Allocation,
    dispatch: ExpertDispatcher,
    /// Transformer layer index → MoE block position, fixed at
    /// construction (the architecture never changes at serve time) so the
    /// per-batch/per-step forwards don't rebuild it on the hot path.
    block_pos: std::collections::HashMap<usize, usize>,
    /// `telemetry.observed_tokens` at the last replan (hysteresis anchor).
    tokens_at_last_replan: usize,
    /// Shared plan-provenance ledger + this engine's replica id (fleet
    /// observatory; `None` = no recording, zero cost).
    provenance: Option<(Arc<ProvenanceLedger>, usize)>,
}

impl ServingEngine {
    /// Build from a trained model + allocation. Quantizes every expert to
    /// its allocated runtime family and pre-compiles the executables. The
    /// telemetry baseline starts uniform; feed the calibration frequency
    /// vector via [`set_baseline`](Self::set_baseline) for meaningful
    /// drift scores.
    pub fn new(lm: MoeLm, artifacts: &Path, allocation: &Allocation) -> Result<ServingEngine> {
        let runtime = Runtime::cpu_warmed(artifacts)?;
        let slots = SlotTable::build(&lm, allocation)?;
        let telemetry =
            ActivationTelemetry::uniform(slots.n_layers(), lm.cfg.n_experts, DEFAULT_EWMA_ALPHA);
        let block_pos = lm
            .moe_blocks()
            .iter()
            .enumerate()
            .map(|(pos, (l, _))| (*l, pos))
            .collect();
        Ok(ServingEngine {
            lm,
            allocation: allocation.clone(),
            dispatch: ExpertDispatcher {
                runtime,
                slots,
                metrics: Metrics::new(),
                telemetry,
                mode: DispatchMode::default(),
                threads: default_threads(),
            },
            block_pos,
            tokens_at_last_replan: 0,
            provenance: None,
        })
    }

    /// Attach the shared plan-provenance ledger; `replica` stamps this
    /// engine's records. Until attached, nothing is recorded.
    pub fn set_provenance(&mut self, ledger: Arc<ProvenanceLedger>, replica: usize) {
        self.provenance = Some((ledger, replica));
    }

    /// Measured useful rows/s per runtime family from wave telemetry
    /// (families that have not executed a wave yet are absent).
    fn measured_scheme_speeds(&self) -> Vec<(RuntimeScheme, f64)> {
        let stats = self.dispatch.metrics.scheme_wave_stats();
        RuntimeScheme::ALL
            .iter()
            .filter_map(|s| {
                stats
                    .get(s.name())
                    .filter(|st| st.busy_s > 0.0)
                    .map(|st| (*s, st.useful_rows as f64 / st.busy_s))
            })
            .collect()
    }

    /// Record the boot plan into the provenance ledger so "why does expert
    /// (l,e) run at its scheme?" is answerable before any replan fires.
    /// No-op unless [`set_provenance`](Self::set_provenance) was called.
    pub fn record_boot_provenance(&self, sens: Option<&SensitivityTable>, r: f64) {
        let Some((ledger, replica)) = &self.provenance else {
            return;
        };
        let speeds = self.measured_scheme_speeds();
        let mut rec = provenance::build_record(
            *replica,
            PlanTrigger::Boot,
            &PlanContext {
                cfg: &self.lm.cfg,
                alloc: &self.allocation,
                prev: None,
                freqs: self.dispatch.telemetry.live(),
                sens,
                speeds: &speeds,
                r,
                drift: 0.0,
            },
        );
        rec.generation = self.generation();
        rec.at_s = self.dispatch.metrics.elapsed();
        ledger.record(rec);
    }

    pub fn platform(&self) -> String {
        self.dispatch.runtime.platform()
    }

    /// How expert FFNs are dispatched (grouped waves by default).
    pub fn dispatch_mode(&self) -> DispatchMode {
        self.dispatch.mode
    }

    /// Switch between grouped-wave and sequential reference dispatch.
    /// Outputs are bit-for-bit identical either way; sequential exists for
    /// equivalence tests and as the baseline of
    /// `benches/bench_group_dispatch.rs`.
    pub fn set_dispatch_mode(&mut self, mode: DispatchMode) {
        self.dispatch.mode = mode;
    }

    /// Worker threads for grouped dispatch (results are identical for any
    /// value ≥ 1; this only changes how many PJRT executions are in
    /// flight).
    pub fn set_dispatch_threads(&mut self, threads: usize) {
        self.dispatch.threads = threads.max(1);
    }

    pub fn metrics(&self) -> &Metrics {
        &self.dispatch.metrics
    }

    pub fn metrics_mut(&mut self) -> &mut Metrics {
        &mut self.dispatch.metrics
    }

    pub fn telemetry(&self) -> &ActivationTelemetry {
        &self.dispatch.telemetry
    }

    /// The currently-serving allocation.
    pub fn allocation(&self) -> &Allocation {
        &self.allocation
    }

    /// Current plan generation (bumps on every hot-swap).
    pub fn generation(&self) -> u64 {
        self.dispatch.slots.generation()
    }

    /// Runtime family currently serving `(block_pos, expert)`.
    pub fn scheme_of(&self, block_pos: usize, expert: usize) -> RuntimeScheme {
        self.dispatch.slots.slot(block_pos, expert).scheme
    }

    /// Scheme histogram for reporting.
    pub fn scheme_counts(&self) -> Vec<(RuntimeScheme, usize)> {
        self.dispatch.slots.scheme_counts()
    }

    /// Snapshot of the live plan: runtime family per
    /// `[block_pos][expert slot]` (routed then shared) — the replica's
    /// contribution to the router's affinity scoring.
    pub fn plan_schemes(&self) -> Vec<Vec<RuntimeScheme>> {
        self.dispatch.slots.scheme_table()
    }

    /// Seed the drift baseline (and live estimate) with the calibration
    /// activation-frequency vector the offline allocation was solved with.
    /// The shape is validated here, at startup — one vector per MoE layer,
    /// one entry per *routed* expert (shared experts see every token and
    /// are not tracked) — so a malformed baseline fails loudly before any
    /// request is served rather than panicking mid-batch.
    pub fn set_baseline(&mut self, freqs: Vec<Vec<f64>>) {
        assert_eq!(
            freqs.len(),
            self.dispatch.slots.n_layers(),
            "baseline must have one frequency vector per MoE layer"
        );
        for (pos, f) in freqs.iter().enumerate() {
            assert_eq!(
                f.len(),
                self.lm.cfg.n_experts,
                "baseline layer {pos}: one entry per routed expert expected \
                 (shared experts are not tracked)"
            );
        }
        self.dispatch.telemetry.reset(freqs);
    }

    /// Tune the telemetry EWMA step (workload-dependent; higher = faster
    /// drift response, noisier).
    pub fn set_telemetry_alpha(&mut self, alpha: f64) {
        self.dispatch.telemetry.set_alpha(alpha);
    }

    /// Forward a batch of sequences; expert FFNs run on PJRT with
    /// cross-request token batching. Returns per-sequence logits.
    pub fn forward_batch(&mut self, batch: &[&[u32]]) -> Result<Vec<Matrix>> {
        // disjoint field borrows: the model is read-only during the pass,
        // all mutation goes through the dispatcher
        let block_pos = &self.block_pos;
        let lm = &self.lm;
        let dispatch = &mut self.dispatch;
        let mut err: Option<anyhow::Error> = None;
        let logits = lm.forward_batch_with_moe(batch, |l, block, x| {
            if err.is_some() {
                return Matrix::zeros(x.rows, x.cols);
            }
            match dispatch.moe_forward(block_pos[&l], block, x) {
                Ok(y) => y,
                Err(e) => {
                    err = Some(e);
                    Matrix::zeros(x.rows, x.cols)
                }
            }
        });
        match err {
            Some(e) => Err(e),
            None => {
                self.dispatch.metrics.batches += 1;
                Ok(logits)
            }
        }
    }

    /// Incremental forward of one mixed prefill/decode step: attention
    /// runs natively against each sequence's KV cache, expert FFNs
    /// dispatch as grouped mixed-precision waves over the *concatenated*
    /// step rows — and every step's routing feeds the live activation
    /// telemetry, so replanning sees decode-time expert frequencies.
    /// Returns per-sequence logits for the new positions.
    pub fn forward_step_batch(&mut self, seqs: &mut [StepSeq<'_>]) -> Result<Vec<Matrix>> {
        let block_pos = &self.block_pos;
        let lm = &self.lm;
        let dispatch = &mut self.dispatch;
        let mut err: Option<anyhow::Error> = None;
        let logits = lm.forward_step_batch_with_moe(seqs, |l, block, x| {
            if err.is_some() {
                return Matrix::zeros(x.rows, x.cols);
            }
            match dispatch.moe_forward(block_pos[&l], block, x) {
                Ok(y) => y,
                Err(e) => {
                    err = Some(e);
                    Matrix::zeros(x.rows, x.cols)
                }
            }
        });
        match err {
            Some(e) => Err(e),
            None => Ok(logits),
        }
    }

    /// Install a new allocation: hot-swap exactly the slots in `changes`
    /// (two-phase, so failure leaves the old plan serving) and adopt the
    /// allocation as current. Returns the number of slots swapped.
    pub fn install_plan(&mut self, allocation: Allocation, changes: &[SlotChange]) -> Result<usize> {
        let swapped = self.dispatch.slots.apply(&self.lm, changes)?;
        self.allocation = allocation;
        self.dispatch.metrics.swaps += swapped;
        Ok(swapped)
    }

    /// Effective accuracy/perf exponent for the next re-solve: the
    /// configured `r` pulled toward each served [`QosClass`]'s hint,
    /// traffic-weighted (`Standard`/unclassified traffic keeps the
    /// default). An all-interactive stream lowers `r` (favor throughput);
    /// an all-batch stream raises it (favor accuracy) — the QoS-tuning
    /// direction, driven by what this replica actually served.
    pub fn qos_effective_r(&self, default_r: f64) -> f64 {
        let counts = self.dispatch.metrics.qos_served;
        let total: usize = counts.iter().sum();
        if total == 0 {
            return default_r;
        }
        let mut acc = 0.0;
        for (&n, &class) in counts.iter().zip(QosClass::ALL.iter()) {
            acc += n as f64 * class.r_hint().unwrap_or(default_r);
        }
        acc / total as f64
    }

    /// The online loop body (DESIGN.md §Online-Serving): check drift, and
    /// if it crossed the threshold (with token hysteresis satisfied),
    /// re-solve the MCKP on live frequencies warm-started from the current
    /// plan — with the accuracy/perf exponent blended from the served QoS
    /// mix — hot-swap the delta, and rebaseline the telemetry. Call
    /// strictly between batches. Returns `None` when no replan triggered.
    /// Every check refreshes the per-layer drift vector; every triggered
    /// replan appends to the bounded history (replan observability).
    ///
    /// Synchronous composition of
    /// [`maybe_begin_replan`](Self::maybe_begin_replan) +
    /// [`finish_replan`](Self::finish_replan) — the serving loop uses the
    /// split form so re-quantization happens off the serving thread.
    pub fn maybe_replan(&mut self, replanner: &Replanner) -> Result<Option<ReplanOutcome>> {
        match self.maybe_begin_replan(replanner)? {
            Some(staging) => self.finish_replan(staging).map(Some),
            None => Ok(None),
        }
    }

    /// Drift check + MCKP re-solve, with the expensive slot
    /// re-quantization handed to a detached staging worker thread. The
    /// solve itself (warm-started, near-linear) runs inline; the returned
    /// [`ReplanStaging`] is polled between batches/decode steps and handed
    /// to [`finish_replan`](Self::finish_replan) once
    /// [`finished`](ReplanStaging::finished) — serving never stalls on
    /// quantization. At most one staging should be in flight per engine;
    /// the hysteresis anchor is set here, so a failing solve backs off
    /// instead of re-solving every batch.
    pub fn maybe_begin_replan(&mut self, replanner: &Replanner) -> Result<Option<ReplanStaging>> {
        let drift = self.dispatch.telemetry.max_drift();
        self.dispatch.metrics.last_drift = drift;
        self.dispatch.metrics.drift_vector = self.dispatch.telemetry.drifts();
        if drift < replanner.cfg.drift_threshold {
            return Ok(None);
        }
        let observed = self.dispatch.telemetry.observed_tokens;
        if observed - self.tokens_at_last_replan < replanner.cfg.min_tokens_between {
            return Ok(None);
        }
        // anchor marks the replan *attempt*: a failing solve/swap backs off
        // for min_tokens_between instead of re-solving on every batch
        self.tokens_at_last_replan = observed;
        let solve_start_us = self.dispatch.metrics.tracer().now_us();
        let freqs = self.dispatch.telemetry.live().to_vec();
        let r = self.qos_effective_r(replanner.cfg.alloc.r);
        let new_alloc = replanner.replan_with_r(&self.lm.cfg, &freqs, &self.allocation, Some(r))?;
        let changes = diff_plans(&self.allocation, &new_alloc);
        {
            let t = self.dispatch.metrics.tracer();
            let now = t.now_us();
            t.span(
                solve_start_us,
                now.saturating_sub(solve_start_us),
                0,
                EventKind::ReplanSolve { drift, changes: changes.len() },
            );
        }
        let job = SwapStagingJob::collect(&self.lm, &self.dispatch.slots, &changes);
        let handle = thread::Builder::new()
            .name("mxmoe-swap-staging".into())
            .spawn(move || job.run())
            .expect("spawn staging thread");
        // Decompose the solve's score terms now, while the inputs it
        // actually weighed (live freqs, sensitivity, wave speeds, blended
        // r) are in hand; generation/time are stamped at install.
        let provenance = self.provenance.as_ref().map(|(_, replica)| {
            let speeds = self.measured_scheme_speeds();
            provenance::build_record(
                *replica,
                PlanTrigger::Replan,
                &PlanContext {
                    cfg: &self.lm.cfg,
                    alloc: &new_alloc,
                    prev: Some(&self.allocation),
                    freqs: &freqs,
                    sens: Some(&replanner.sens),
                    speeds: &speeds,
                    r,
                    drift,
                },
            )
        });
        Ok(Some(ReplanStaging {
            handle,
            drift,
            r,
            changes: changes.len(),
            bits_before: self.allocation.avg_weight_bits(&self.lm.cfg),
            bits_after: new_alloc.avg_weight_bits(&self.lm.cfg),
            allocation: new_alloc,
            provenance,
        }))
    }

    /// Join a staging job and apply the generation-counted slot flip on
    /// this (engine) thread: literal creation + install, telemetry
    /// rebaseline, replan metrics. Blocks if the worker is still
    /// quantizing — poll [`ReplanStaging::finished`] to avoid that. On
    /// error the old plan keeps serving untouched.
    pub fn finish_replan(&mut self, staging: ReplanStaging) -> Result<ReplanOutcome> {
        let ReplanStaging {
            handle,
            drift,
            r,
            changes,
            bits_before,
            bits_after,
            allocation,
            provenance,
        } = staging;
        let staged: StagedSwap = handle
            .join()
            .map_err(|_| anyhow::anyhow!("swap staging thread panicked"))??;
        let staging_s = staged.staging_s();
        let install_start_us = self.dispatch.metrics.tracer().now_us();
        let swapped = self.dispatch.slots.install_staged(staged)?;
        self.allocation = allocation;
        self.dispatch.metrics.swaps += swapped;
        self.dispatch.telemetry.rebaseline();
        let generation = self.dispatch.slots.generation();
        let m = &mut self.dispatch.metrics;
        m.replans += 1;
        let at_s = m.elapsed();
        m.note_replan(ReplanEvent {
            at_s,
            drift,
            changes,
            swapped,
            r,
            bits_before,
            bits_after,
            generation,
        });
        // swap spans: the off-thread staging window (measured duration,
        // ending at the install poll) and the engine-thread slot flip
        let t = m.tracer();
        let stage_us = (staging_s * 1e6) as u64;
        t.span(
            install_start_us.saturating_sub(stage_us),
            stage_us,
            0,
            EventKind::SwapStage { changes },
        );
        let now = t.now_us();
        t.span(
            install_start_us,
            now.saturating_sub(install_start_us),
            0,
            EventKind::SwapInstall { swapped, generation },
        );
        if let (Some((ledger, _)), Some(mut rec)) = (&self.provenance, provenance) {
            rec.generation = generation;
            rec.at_s = at_s;
            ledger.record(rec);
        }
        Ok(ReplanOutcome { drift, changes, swapped })
    }
}

/// A replan whose slot re-quantization is running on a staging worker
/// thread. Poll [`finished`](Self::finished) between batches/steps, then
/// hand to [`ServingEngine::finish_replan`] for the engine-thread flip.
pub struct ReplanStaging {
    handle: thread::JoinHandle<Result<StagedSwap>>,
    drift: f64,
    r: f64,
    changes: usize,
    bits_before: f64,
    bits_after: f64,
    allocation: Allocation,
    /// Decomposed per-slot score terms for the provenance ledger
    /// (`None` when no ledger is attached).
    provenance: Option<PlanRecord>,
}

impl ReplanStaging {
    /// True once the staging worker has exited (join will not block).
    pub fn finished(&self) -> bool {
        self.handle.is_finished()
    }

    /// Slots the re-solve changed (what the worker is re-quantizing).
    pub fn changes(&self) -> usize {
        self.changes
    }
}

/// Convenience: uniform-precision engine (baseline rows of Fig. 5).
pub fn uniform_engine(
    lm: MoeLm,
    artifacts: &Path,
    scheme: crate::quant::QuantScheme,
) -> Result<ServingEngine> {
    let cfg: ModelConfig = lm.cfg.clone();
    let alloc = Allocation::uniform(&cfg, scheme);
    ServingEngine::new(lm, artifacts, &alloc)
}
