//! Shared experiment harness: artifact loading, quantized-model
//! construction, and evaluation helpers used by `benches/` and `examples/`.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::alloc::{Allocation, CalibrationStats};
use crate::data::Corpus;
use crate::eval::{perplexity_quantized, probe_accuracy, ProbeReport};
use crate::moe::block::{HadamardCtx, QuantizedMoeBlock, WeightQuantizer};
use crate::moe::lm::Ffn;
use crate::moe::{ModelConfig, MoeLm};
use crate::ser::MxtFile;
use crate::util::Rng;

/// Repo-relative artifacts directory.
pub fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// `MXMOE_FAST=1` shrinks evaluation workloads (CI mode).
pub fn fast_mode() -> bool {
    std::env::var("MXMOE_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Load a trained mini model (errors if `make models` hasn't run).
pub fn load_model(name: &str) -> Result<(ModelConfig, MoeLm)> {
    let cfg = ModelConfig::by_name(name)?;
    let path = artifacts_dir().join(format!("model_{name}.mxt"));
    let weights = MxtFile::load(&path)
        .with_context(|| format!("{path:?} — run `make models` first"))?;
    Ok((cfg.clone(), MoeLm::load_mxt(&cfg, &weights)?))
}

pub fn load_corpus() -> Result<Corpus> {
    Corpus::load(&artifacts_dir().join("corpus.mxt")).context("run `make corpus` first")
}

/// Which weight quantizer an experiment row uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantMethod {
    /// Plain round-to-nearest.
    Rtn,
    /// GPTQ on calibration Hessians.
    Gptq,
    /// Random Hadamard rotation then GPTQ (the paper's MxMoE/GPTQ* setting).
    HadamardGptq,
    /// Random Hadamard rotation then RTN (QuaRot baseline).
    HadamardRtn,
}

/// Hadamard sign vectors shared between calibration and quantization for a
/// given seed (rotated Hessians must match rotated weights).
pub fn hadamard_signs_for_seed(cfg: &ModelConfig, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed ^ 0x48414441);
    (
        crate::quant::hadamard::random_signs(cfg.hidden, &mut rng),
        crate::quant::hadamard::random_signs(cfg.inter, &mut rng),
    )
}

/// Build the quantized-block replacement map for `lm` under `allocation`,
/// quantizing with `method`. For Hadamard* methods, `stats` must come from
/// [`crate::alloc::calibrate`] called with [`hadamard_signs_for_seed`] of
/// the same `seed` (so the GPTQ Hessians live in the rotated basis).
pub fn build_quantized(
    lm: &MoeLm,
    allocation: &Allocation,
    method: QuantMethod,
    stats: &CalibrationStats,
    seed: u64,
) -> Result<Vec<QuantizedMoeBlock>> {
    let signs = hadamard_signs_for_seed(&lm.cfg, seed);
    let mut out = Vec::new();
    for (pos, (layer, block)) in lm.moe_blocks().iter().enumerate() {
        debug_assert_eq!(*layer, allocation.layers[pos]);
        let hadamard = match method {
            QuantMethod::HadamardGptq | QuantMethod::HadamardRtn => Some(HadamardCtx {
                signs_hidden: signs.0.clone(),
                signs_inter: signs.1.clone(),
            }),
            _ => None,
        };
        let quantizer = match method {
            QuantMethod::Rtn | QuantMethod::HadamardRtn => WeightQuantizer::Rtn,
            QuantMethod::Gptq | QuantMethod::HadamardGptq => WeightQuantizer::Gptq {
                hessians: &stats.layers[pos].hessians,
                damp: 0.01,
            },
        };
        out.push(QuantizedMoeBlock::build(
            block,
            &allocation.schemes[pos],
            &quantizer,
            hadamard,
        )?);
    }
    Ok(out)
}

/// Accuracy report of one experiment row.
#[derive(Clone, Debug)]
pub struct AccuracyReport {
    pub ppl: f64,
    pub probes: ProbeReport,
    pub avg_wbits: f64,
    pub avg_abits: f64,
}

/// Evaluate a quantized configuration: perplexity on held-out sequences +
/// the probe suite.
pub fn evaluate(
    lm: &MoeLm,
    corpus: &Corpus,
    allocation: &Allocation,
    blocks: &[QuantizedMoeBlock],
    n_eval_seqs: usize,
    n_probe_cases: usize,
) -> AccuracyReport {
    let replacements: HashMap<usize, &QuantizedMoeBlock> = allocation
        .layers
        .iter()
        .zip(blocks)
        .map(|(l, b)| (*l, b))
        .collect();
    let seqs = corpus.sequences("valid", lm.cfg.seq_len);
    let eval: Vec<&[u32]> = seqs.iter().take(n_eval_seqs).copied().collect();
    let ppl = perplexity_quantized(lm, &eval, &replacements);
    let probes = probe_accuracy(lm, corpus, &replacements, n_probe_cases, 7);
    AccuracyReport {
        ppl,
        probes,
        avg_wbits: allocation.avg_weight_bits(&lm.cfg),
        avg_abits: allocation.avg_act_bits(&lm.cfg),
    }
}

/// fp32 baseline (no replacement map).
pub fn evaluate_fp32(lm: &MoeLm, corpus: &Corpus, n_eval_seqs: usize, n_probe_cases: usize) -> AccuracyReport {
    let seqs = corpus.sequences("valid", lm.cfg.seq_len);
    let eval: Vec<&[u32]> = seqs.iter().take(n_eval_seqs).copied().collect();
    let ppl = perplexity_quantized(lm, &eval, &HashMap::new());
    let probes = probe_accuracy(lm, corpus, &HashMap::new(), n_probe_cases, 7);
    AccuracyReport { ppl, probes, avg_wbits: 16.0, avg_abits: 16.0 }
}

/// Tokens-per-expert workloads of the MoE layers of a model for the
/// simulator benches (from calibration activation frequencies, scaled to
/// `batch_tokens`).
pub fn expert_token_workload(
    stats: &CalibrationStats,
    cfg: &ModelConfig,
    batch_tokens: usize,
) -> Vec<Vec<usize>> {
    stats
        .layers
        .iter()
        .map(|ls| {
            let total: usize = ls.activation_counts.iter().sum();
            let mut tokens: Vec<usize> = ls
                .activation_counts
                .iter()
                .map(|&c| {
                    ((c as f64 / total.max(1) as f64) * batch_tokens as f64 * cfg.topk as f64)
                        .round() as usize
                })
                .collect();
            // shared experts see every token
            tokens.extend(std::iter::repeat(batch_tokens).take(cfg.n_shared));
            tokens
        })
        .collect()
}

/// Pretty table printer (pipe-separated, fixed width).
pub fn print_row(cells: &[String], widths: &[usize]) {
    let line: Vec<String> = cells
        .iter()
        .zip(widths)
        .map(|(c, w)| format!("{c:>w$}", w = w))
        .collect();
    println!("| {} |", line.join(" | "));
}

impl Ffn {
    /// convenience used by benches
    pub fn is_moe(&self) -> bool {
        matches!(self, Ffn::Moe(_))
    }
}
