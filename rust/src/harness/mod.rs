//! Shared experiment harness: artifact loading, quantized-model
//! construction, and evaluation helpers used by `benches/` and `examples/`.

pub mod scenario;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::alloc::{Allocation, CalibrationStats};
use crate::data::Corpus;
use crate::eval::{perplexity_quantized, probe_accuracy, ProbeReport};
use crate::moe::block::{HadamardCtx, QuantizedMoeBlock, WeightQuantizer};
use crate::moe::lm::Ffn;
use crate::moe::{ModelConfig, MoeLm};
use crate::quant::QuantScheme;
use crate::ser::mxt::MxtTensor;
use crate::ser::MxtFile;
use crate::util::Rng;

/// Repo-relative artifacts directory.
pub fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Artifact gate for tests and benches: `Some(dir)` when the AOT HLO
/// export is present, `None` to skip. Under `MXMOE_REQUIRE_ARTIFACTS=1`
/// (CI, after `make artifacts`) a missing export is a hard failure instead
/// of a silent self-skip — the gated paths must *run* there, and a broken
/// artifact build must turn the gate red rather than green-by-skipping.
pub fn require_artifacts() -> Option<PathBuf> {
    let dir = artifacts_dir();
    // probe one tile per runtime family: a partial export (interrupted
    // `make artifacts`) must read as "not built", not as a serving bug
    let probe = [
        "smoke_matmul.hlo.txt",
        "expert_ffn_fp16_m16.hlo.txt",
        "expert_ffn_w4a16_m16.hlo.txt",
        "expert_ffn_w8a8_m16.hlo.txt",
        "expert_ffn_w4a4_m16.hlo.txt",
    ];
    if probe.iter().all(|f| dir.join(f).exists()) {
        return Some(dir);
    }
    if std::env::var("MXMOE_REQUIRE_ARTIFACTS").map(|v| v == "1").unwrap_or(false) {
        panic!(
            "MXMOE_REQUIRE_ARTIFACTS=1 but {dir:?} lacks the AOT export \
             (missing one of {probe:?}) — run `make artifacts`"
        );
    }
    None
}

/// `MXMOE_FAST=1` shrinks evaluation workloads (CI mode).
pub fn fast_mode() -> bool {
    std::env::var("MXMOE_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Load a trained mini model (errors if `make models` hasn't run).
pub fn load_model(name: &str) -> Result<(ModelConfig, MoeLm)> {
    let cfg = ModelConfig::by_name(name)?;
    let path = artifacts_dir().join(format!("model_{name}.mxt"));
    let weights = MxtFile::load(&path)
        .with_context(|| format!("{path:?} — run `make models` first"))?;
    Ok((cfg.clone(), MoeLm::load_mxt(&cfg, &weights)?))
}

/// Seed of the deterministic `ci-mini` checkpoint (`make mini-model`) —
/// shared by the generator (`mxmoe gen-mini-model`) and anything that
/// wants to re-derive the same weights in-process.
pub const MINI_MODEL_SEED: u64 = 0x4D69_6E69; // "Mini"

/// Model-artifact gate for tests that exercise `make models`-shaped paths:
/// `Some((cfg, model))` when the `ci-mini` checkpoint exists (written by
/// `make mini-model` — deterministic seeded init, no training), `None` to
/// self-skip. Under `MXMOE_REQUIRE_MINI_MODEL=1` (CI, after the cached
/// `make mini-model` step) a missing checkpoint is a hard failure, so the
/// gated paths must actually run there.
pub fn require_mini_model() -> Option<(ModelConfig, MoeLm)> {
    let path = artifacts_dir().join("model_ci-mini.mxt");
    if !path.exists() {
        if std::env::var("MXMOE_REQUIRE_MINI_MODEL").map(|v| v == "1").unwrap_or(false) {
            panic!(
                "MXMOE_REQUIRE_MINI_MODEL=1 but {path:?} missing — run `make mini-model`"
            );
        }
        return None;
    }
    match load_model("ci-mini") {
        Ok(x) => Some(x),
        Err(e) => panic!("mini-model checkpoint present but unreadable: {e:#}"),
    }
}

pub fn load_corpus() -> Result<Corpus> {
    Corpus::load(&artifacts_dir().join("corpus.mxt")).context("run `make corpus` first")
}

/// Serialize a model to the MXT tensor layout [`MoeLm::load_mxt`]
/// expects — the single home of the tensor-naming scheme for tests and
/// benches that feed throwaway serving models to `Server`/`Cluster`.
pub fn save_model_mxt(lm: &MoeLm, path: &Path) -> Result<()> {
    let cfg = &lm.cfg;
    let mut f = MxtFile::new();
    let m = |m: &crate::tensor::Matrix| MxtTensor::from_f32(vec![m.rows, m.cols], &m.data);
    f.insert("embed", m(&lm.embed));
    f.insert("head", m(&lm.head));
    f.insert("ln_f", MxtTensor::from_f32(vec![cfg.hidden], &lm.ln_f));
    for (l, layer) in lm.layers.iter().enumerate() {
        let p = |s: &str| format!("layers.{l}.{s}");
        f.insert(&p("ln1"), MxtTensor::from_f32(vec![cfg.hidden], &layer.ln1));
        f.insert(&p("ln2"), MxtTensor::from_f32(vec![cfg.hidden], &layer.ln2));
        for (n, w) in [("wq", &layer.wq), ("wk", &layer.wk), ("wv", &layer.wv), ("wo", &layer.wo)]
        {
            f.insert(&p(n), m(w));
        }
        if let Ffn::Moe(b) = &layer.ffn {
            f.insert(&p("router"), m(&b.w_router));
            for (e, ew) in b.experts.iter().enumerate() {
                f.insert(&p(&format!("expert.{e}.gate")), m(&ew.gate));
                f.insert(&p(&format!("expert.{e}.up")), m(&ew.up));
                f.insert(&p(&format!("expert.{e}.down")), m(&ew.down));
            }
            for (s, ew) in b.shared.iter().enumerate() {
                f.insert(&p(&format!("shared.{s}.gate")), m(&ew.gate));
                f.insert(&p(&format!("shared.{s}.up")), m(&ew.up));
                f.insert(&p(&format!("shared.{s}.down")), m(&ew.down));
            }
        }
    }
    f.save(path)
}

/// A plan that spreads all four runtime families across the expert grid —
/// the standard mixed-precision fixture of the dispatch/cluster tests and
/// benches (every MoE block plans ≥ 4 distinct-executable waves).
pub fn mixed_runtime_plan(cfg: &ModelConfig) -> Allocation {
    let fams = [QuantScheme::FP16, QuantScheme::W4A16, QuantScheme::W8A8, QuantScheme::W4A4];
    let mut plan = Allocation::uniform(cfg, QuantScheme::FP16);
    for (pos, block) in plan.schemes.iter_mut().enumerate() {
        for (e, schemes) in block.iter_mut().enumerate() {
            *schemes = [fams[(pos + e) % fams.len()]; 3];
        }
    }
    plan
}

/// Which weight quantizer an experiment row uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantMethod {
    /// Plain round-to-nearest.
    Rtn,
    /// GPTQ on calibration Hessians.
    Gptq,
    /// Random Hadamard rotation then GPTQ (the paper's MxMoE/GPTQ* setting).
    HadamardGptq,
    /// Random Hadamard rotation then RTN (QuaRot baseline).
    HadamardRtn,
}

/// Hadamard sign vectors shared between calibration and quantization for a
/// given seed (rotated Hessians must match rotated weights).
pub fn hadamard_signs_for_seed(cfg: &ModelConfig, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed ^ 0x48414441);
    (
        crate::quant::hadamard::random_signs(cfg.hidden, &mut rng),
        crate::quant::hadamard::random_signs(cfg.inter, &mut rng),
    )
}

/// Build the quantized-block replacement map for `lm` under `allocation`,
/// quantizing with `method`. For Hadamard* methods, `stats` must come from
/// [`crate::alloc::calibrate`] called with [`hadamard_signs_for_seed`] of
/// the same `seed` (so the GPTQ Hessians live in the rotated basis).
pub fn build_quantized(
    lm: &MoeLm,
    allocation: &Allocation,
    method: QuantMethod,
    stats: &CalibrationStats,
    seed: u64,
) -> Result<Vec<QuantizedMoeBlock>> {
    let signs = hadamard_signs_for_seed(&lm.cfg, seed);
    let mut out = Vec::new();
    for (pos, (layer, block)) in lm.moe_blocks().iter().enumerate() {
        debug_assert_eq!(*layer, allocation.layers[pos]);
        let hadamard = match method {
            QuantMethod::HadamardGptq | QuantMethod::HadamardRtn => Some(HadamardCtx {
                signs_hidden: signs.0.clone(),
                signs_inter: signs.1.clone(),
            }),
            _ => None,
        };
        let quantizer = match method {
            QuantMethod::Rtn | QuantMethod::HadamardRtn => WeightQuantizer::Rtn,
            QuantMethod::Gptq | QuantMethod::HadamardGptq => WeightQuantizer::Gptq {
                hessians: &stats.layers[pos].hessians,
                damp: 0.01,
            },
        };
        out.push(QuantizedMoeBlock::build(
            block,
            &allocation.schemes[pos],
            &quantizer,
            hadamard,
        )?);
    }
    Ok(out)
}

/// Accuracy report of one experiment row.
#[derive(Clone, Debug)]
pub struct AccuracyReport {
    pub ppl: f64,
    pub probes: ProbeReport,
    pub avg_wbits: f64,
    pub avg_abits: f64,
}

/// Evaluate a quantized configuration: perplexity on held-out sequences +
/// the probe suite.
pub fn evaluate(
    lm: &MoeLm,
    corpus: &Corpus,
    allocation: &Allocation,
    blocks: &[QuantizedMoeBlock],
    n_eval_seqs: usize,
    n_probe_cases: usize,
) -> AccuracyReport {
    let replacements: HashMap<usize, &QuantizedMoeBlock> = allocation
        .layers
        .iter()
        .zip(blocks)
        .map(|(l, b)| (*l, b))
        .collect();
    let seqs = corpus.sequences("valid", lm.cfg.seq_len);
    let eval: Vec<&[u32]> = seqs.iter().take(n_eval_seqs).copied().collect();
    let ppl = perplexity_quantized(lm, &eval, &replacements);
    let probes = probe_accuracy(lm, corpus, &replacements, n_probe_cases, 7);
    AccuracyReport {
        ppl,
        probes,
        avg_wbits: allocation.avg_weight_bits(&lm.cfg),
        avg_abits: allocation.avg_act_bits(&lm.cfg),
    }
}

/// fp32 baseline (no replacement map).
pub fn evaluate_fp32(lm: &MoeLm, corpus: &Corpus, n_eval_seqs: usize, n_probe_cases: usize) -> AccuracyReport {
    let seqs = corpus.sequences("valid", lm.cfg.seq_len);
    let eval: Vec<&[u32]> = seqs.iter().take(n_eval_seqs).copied().collect();
    let ppl = perplexity_quantized(lm, &eval, &HashMap::new());
    let probes = probe_accuracy(lm, corpus, &HashMap::new(), n_probe_cases, 7);
    AccuracyReport { ppl, probes, avg_wbits: 16.0, avg_abits: 16.0 }
}

/// Tokens-per-expert workloads of the MoE layers of a model for the
/// simulator benches (from calibration activation frequencies, scaled to
/// `batch_tokens`).
pub fn expert_token_workload(
    stats: &CalibrationStats,
    cfg: &ModelConfig,
    batch_tokens: usize,
) -> Vec<Vec<usize>> {
    stats
        .layers
        .iter()
        .map(|ls| {
            let total: usize = ls.activation_counts.iter().sum();
            let mut tokens: Vec<usize> = ls
                .activation_counts
                .iter()
                .map(|&c| {
                    ((c as f64 / total.max(1) as f64) * batch_tokens as f64 * cfg.topk as f64)
                        .round() as usize
                })
                .collect();
            // shared experts see every token
            tokens.extend(std::iter::repeat(batch_tokens).take(cfg.n_shared));
            tokens
        })
        .collect()
}

/// Pretty table printer (pipe-separated, fixed width).
pub fn print_row(cells: &[String], widths: &[usize]) {
    let line: Vec<String> = cells
        .iter()
        .zip(widths)
        .map(|(c, w)| format!("{c:>w$}", w = w))
        .collect();
    println!("| {} |", line.join(" | "));
}

impl Ffn {
    /// convenience used by benches
    pub fn is_moe(&self) -> bool {
        matches!(self, Ffn::Moe(_))
    }
}
