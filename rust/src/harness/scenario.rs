//! Scenario engine (DESIGN.md §Scenario-Engine): trace-driven workload
//! simulation with SLO verdicts.
//!
//! A **scenario** is a declarative JSON spec (checked into `scenarios/`)
//! describing an offered workload against a mini-model [`Cluster`]:
//! arrival curves (constant / diurnal / flash-crowd spike), a QoS-class
//! mix schedule, prompt-length and score-vs-generate distributions, a
//! routing-distribution drift schedule (prompt tokens sampled from a
//! moving vocab band, which deterministically skews expert routing),
//! cancel storms, and mid-run replica kill/restart events.
//!
//! The replay driver is **tick-quiesced**: virtual time advances in
//! integer ticks, and between ticks the cluster is drained to a known
//! state (every non-cancelled admitted request has reached a terminal,
//! the admission queue is empty). Arrivals inside a tick are submitted
//! **burst-atomically** ([`Cluster::try_submit_burst`]), so the
//! admit/reject pattern is a pure function of the spec and its seed —
//! not of thread scheduling. That is the determinism contract:
//!
//! * `deterministic: true` specs (no cancels, no kills, no deadlines)
//!   reproduce the **entire ledger** — same spec + seed ⇒ identical
//!   admission and termination counts across runs and across dispatch
//!   thread counts.
//! * Specs with cancels or kills still pin the admission-side ledger and
//!   the accounting identity `admitted == responses + cancelled +
//!   failed`; only the served/cancelled *split* may move (a cancel can
//!   race an already-sent reply).
//!
//! Each run emits one `BENCH_scenario_<name>.json` with the shared
//! `mxmoe-bench-v1` envelope plus an **SLO verdict block**: per-class
//! latency percentiles, deadline-hit rate, shed/reject counts by reason,
//! replan count, KV preemptions and average bits served, and a list of
//! pass/fail checks. Ledger-derived checks are always enforced;
//! wall-clock checks (latency, hit rate) are reported always but only
//! enforced in full (non-smoke) mode, so shared CI runners cannot flake
//! the gate.

use std::path::{Path, PathBuf};
use std::sync::mpsc::RecvTimeoutError;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::{
    slo_class_name, Cluster, ClusterConfig, ClusterReport, OnlineConfig, ServeConfig, SLO_CLASSES,
};
use crate::moe::{ModelConfig, MoeLm};
use crate::obs::{ObservatorySnapshot, SampleConfig};
use crate::runtime::RuntimeScheme;
use crate::ser::Json;
use crate::serve::{Admission, AdmissionConfig, DecodePolicy, Priority, QosClass, ServeRequest};
use crate::util::Rng;

use super::{artifacts_dir, mixed_runtime_plan, require_artifacts, save_model_mxt, MINI_MODEL_SEED};

/// Spec schema tag (`"schema"` key of every scenario file).
pub const SCENARIO_SCHEMA: &str = "mxmoe-scenario-v1";
/// Envelope schema tag shared by every `BENCH_*.json` the repo emits.
pub const BENCH_SCHEMA: &str = "mxmoe-bench-v1";

/// Per-ticket and per-tick drain budget: a quiesce that outlives this is
/// a stall (lost request, router wedge), not a slow machine.
const QUIESCE_BUDGET: Duration = Duration::from_secs(120);

/// Real-time gap between the sub-bursts of one tick (`sub_bursts > 1`):
/// long enough for the previous sub-burst's admitted work to start
/// decoding and claim KV pages, short enough that a tick stays cheap.
const SUB_BURST_GAP: Duration = Duration::from_millis(20);

// ---------------------------------------------------------------------------
// Spec types
// ---------------------------------------------------------------------------

/// Offered-load curve, in requests per tick (fractional rates accumulate
/// across ticks via a carry, so e.g. 0.5/tick yields one arrival every
/// other tick).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalCurve {
    /// Flat rate.
    Constant { rate: f64 },
    /// `rate · (1 + amplitude · sin(2π·tick/period))`, clamped at 0.
    Diurnal { rate: f64, amplitude: f64, period: f64 },
    /// `rate` outside the spike window, `spike_rate` inside
    /// `[spike_start, spike_start + spike_len)`.
    Spike { rate: f64, spike_rate: f64, spike_start: usize, spike_len: usize },
}

impl ArrivalCurve {
    /// Offered rate at `tick`, requests per tick.
    pub fn rate_at(&self, tick: usize) -> f64 {
        match *self {
            ArrivalCurve::Constant { rate } => rate,
            ArrivalCurve::Diurnal { rate, amplitude, period } => {
                let phase = 2.0 * std::f64::consts::PI * tick as f64 / period;
                (rate * (1.0 + amplitude * phase.sin())).max(0.0)
            }
            ArrivalCurve::Spike { rate, spike_rate, spike_start, spike_len } => {
                if tick >= spike_start && tick < spike_start + spike_len {
                    spike_rate
                } else {
                    rate
                }
            }
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            ArrivalCurve::Constant { .. } => "constant",
            ArrivalCurve::Diurnal { .. } => "diurnal",
            ArrivalCurve::Spike { .. } => "spike",
        }
    }
}

/// QoS-class mix from `from_tick` until the next phase: relative weights
/// of Interactive / Standard / Batch arrivals.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MixPhase {
    pub from_tick: usize,
    pub interactive: f64,
    pub standard: f64,
    pub batch: f64,
}

/// Routing-drift phase: from `from_tick` on, prompt tokens are sampled
/// uniformly from the vocab band `[band.0, band.1)` (fractions of the
/// vocab). Narrowing or moving the band deterministically shifts which
/// experts the router activates — the drift signal the online replanner
/// reacts to.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DriftPhase {
    pub from_tick: usize,
    pub band: (f64, f64),
}

/// Cancel storm: at `tick`, each arrival is cancelled right after
/// admission with probability `fraction` (decided by the schedule RNG,
/// so the *requested* cancels are deterministic).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CancelStorm {
    pub tick: usize,
    pub fraction: f64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicaAction {
    Kill,
    Restart,
}

/// Mid-run fault injection: kill or restart replica `replica` at the
/// start of `tick` (before that tick's arrivals).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReplicaEvent {
    pub tick: usize,
    pub action: ReplicaAction,
    pub replica: usize,
}

/// Online-replan knobs; presence turns the scenario's cluster into
/// [`Cluster::start_online`] (calibration + sensitivity + MCKP replanner,
/// mirroring `mxmoe trace-dump`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OnlineKnobs {
    pub drift_threshold: f64,
    pub min_tokens_between: usize,
}

/// Admission front-door knobs the scenario runs under.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdmissionKnobs {
    pub max_queued_seqs: usize,
    pub max_queued_tokens: usize,
    pub privileged_reserve: f64,
    pub auto_reserve: bool,
}

impl Default for AdmissionKnobs {
    fn default() -> AdmissionKnobs {
        AdmissionKnobs {
            max_queued_seqs: 64,
            max_queued_tokens: 8192,
            privileged_reserve: 0.0,
            auto_reserve: false,
        }
    }
}

/// Decode/KV-pool knobs the scenario's replicas run under; defaults
/// mirror [`DecodePolicy`]. Shrinking the pool is how the KV-exhaustion
/// scenarios trip the admission backpressure gate and the decode
/// scheduler's preempt-youngest path.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DecodeKnobs {
    pub kv_budget_tokens: usize,
    pub kv_page_size: usize,
    pub max_active_seqs: usize,
}

impl Default for DecodeKnobs {
    fn default() -> DecodeKnobs {
        let d = DecodePolicy::default();
        DecodeKnobs {
            kv_budget_tokens: d.kv_budget_tokens,
            kv_page_size: d.kv_page_size,
            max_active_seqs: d.max_active_seqs,
        }
    }
}

/// SLO bounds of the verdict block. Ledger-derived bounds are enforced
/// in every mode; `min_hit_rate` / `max_p99_ms` are wall-clock and only
/// enforced in full (non-smoke) runs, as are `min_kv_shed` /
/// `min_preemptions` (whether the KV gate trips depends on how much
/// decode is still in flight when a sub-burst lands).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SloBounds {
    pub max_shed_rate: Option<f64>,
    pub min_served: Option<usize>,
    pub min_replans: Option<usize>,
    pub min_queue_full: Option<usize>,
    pub min_quota: Option<usize>,
    pub min_kv_shed: Option<usize>,
    pub min_preemptions: Option<usize>,
    pub min_hit_rate: Option<f64>,
    /// `(QosClass index, bound in ms)` pairs.
    pub max_p99_ms: Vec<(usize, f64)>,
}

/// One declarative workload scenario (`scenarios/<name>.json`).
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    pub description: String,
    pub seed: u64,
    pub ticks: usize,
    pub replicas: usize,
    /// `true` promises full-ledger reproducibility; [`validate`] then
    /// forbids the racy ingredients (cancels, kills, deadlines, online
    /// replan).
    pub deterministic: bool,
    pub arrival: ArrivalCurve,
    /// Sub-bursts a tick's arrivals are split into, landing
    /// [`SUB_BURST_GAP`] apart with **no quiesce between them** — later
    /// sub-bursts see whatever KV the earlier ones still hold, which is
    /// the only way the kv-exhausted admission gate can trip in a
    /// scenario. `1` (the default) is the classic burst-atomic tick;
    /// `deterministic: true` requires it.
    pub sub_bursts: usize,
    pub mix: Vec<MixPhase>,
    /// Inclusive prompt-length range.
    pub prompt_tokens: (usize, usize),
    /// Fraction of arrivals that are KV-cached generations (the rest
    /// score).
    pub generate_fraction: f64,
    pub max_new_tokens: usize,
    /// Per-QoS-class deadline (ms), indexed by [`QosClass::index`].
    pub deadline_ms: [Option<u64>; 3],
    pub cancel_storms: Vec<CancelStorm>,
    pub drift: Vec<DriftPhase>,
    pub replica_events: Vec<ReplicaEvent>,
    pub online: Option<OnlineKnobs>,
    /// Observatory sampler interval (ms); presence turns the cluster's
    /// time-series sampler on and adds a `timeseries` block to the bench
    /// JSON. The sampler only reads cluster state, so it is allowed in
    /// deterministic specs — the ledger must be bit-identical either way
    /// (asserted in `tests/observatory.rs`).
    pub sample_interval_ms: Option<u64>,
    pub admission: AdmissionKnobs,
    pub decode: DecodeKnobs,
    pub slo: SloBounds,
}

// ---------------------------------------------------------------------------
// Spec JSON I/O
// ---------------------------------------------------------------------------

fn opt_f64(j: &Json, key: &str) -> Result<Option<f64>> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => Ok(Some(v.as_f64().with_context(|| format!("'{key}' must be a number"))?)),
    }
}

fn opt_usize(j: &Json, key: &str) -> Result<Option<usize>> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => Ok(Some(
            v.as_usize().with_context(|| format!("'{key}' must be a non-negative integer"))?,
        )),
    }
}

fn opt_bool(j: &Json, key: &str) -> Result<Option<bool>> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => Ok(Some(v.as_bool().with_context(|| format!("'{key}' must be a bool"))?)),
    }
}

fn req_arr<'a>(j: &'a Json, key: &str) -> Result<&'a [Json]> {
    j.get(key)
        .and_then(Json::as_arr)
        .with_context(|| format!("'{key}' must be an array"))
}

fn known_keys(j: &Json, what: &str, allowed: &[&str]) -> Result<()> {
    if let Json::Obj(m) = j {
        for k in m.keys() {
            ensure!(allowed.contains(&k.as_str()), "unknown {what} key '{k}'");
        }
        Ok(())
    } else {
        bail!("{what} must be an object")
    }
}

impl ScenarioSpec {
    /// Parse a spec from JSON text; structural errors (wrong types,
    /// unknown keys, missing fields) surface here, semantic errors in
    /// [`validate`](Self::validate) — `parse` runs both.
    pub fn parse(text: &str) -> Result<ScenarioSpec> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("scenario JSON: {e}"))?;
        let spec = ScenarioSpec::from_json(&j)?;
        spec.validate()?;
        Ok(spec)
    }

    pub fn from_json(j: &Json) -> Result<ScenarioSpec> {
        known_keys(
            j,
            "scenario",
            &[
                "schema", "name", "description", "seed", "ticks", "replicas", "deterministic",
                "arrival", "sub_bursts", "mix", "prompt_tokens", "generate_fraction",
                "max_new_tokens", "deadline_ms", "cancel_storms", "drift", "replica_events",
                "online", "sample_interval_ms", "admission", "decode", "slo",
            ],
        )?;
        let schema = j.req_str("schema")?;
        ensure!(schema == SCENARIO_SCHEMA, "schema must be '{SCENARIO_SCHEMA}', got '{schema}'");

        let arrival = {
            let a = j.get("arrival").context("'arrival' is required")?;
            known_keys(
                a,
                "arrival",
                &["curve", "rate", "amplitude", "period", "spike_rate", "spike_start", "spike_len"],
            )?;
            let rate = a.req_f64("rate")?;
            match a.req_str("curve")? {
                "constant" => ArrivalCurve::Constant { rate },
                "diurnal" => ArrivalCurve::Diurnal {
                    rate,
                    amplitude: a.req_f64("amplitude")?,
                    period: a.req_f64("period")?,
                },
                "spike" => ArrivalCurve::Spike {
                    rate,
                    spike_rate: a.req_f64("spike_rate")?,
                    spike_start: a.req_usize("spike_start")?,
                    spike_len: a.req_usize("spike_len")?,
                },
                c => bail!("unknown arrival curve '{c}' (constant|diurnal|spike)"),
            }
        };

        let mix = req_arr(j, "mix")?
            .iter()
            .map(|p| {
                known_keys(p, "mix phase", &["from_tick", "interactive", "standard", "batch"])?;
                Ok(MixPhase {
                    from_tick: p.req_usize("from_tick")?,
                    interactive: opt_f64(p, "interactive")?.unwrap_or(0.0),
                    standard: opt_f64(p, "standard")?.unwrap_or(0.0),
                    batch: opt_f64(p, "batch")?.unwrap_or(0.0),
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let prompt_tokens = {
            let p = j.get("prompt_tokens").context("'prompt_tokens' is required")?;
            known_keys(p, "prompt_tokens", &["min", "max"])?;
            (p.req_usize("min")?, p.req_usize("max")?)
        };

        let mut deadline_ms = [None; 3];
        if let Some(d) = j.get("deadline_ms") {
            known_keys(d, "deadline_ms", &["interactive", "standard", "batch"])?;
            for q in QosClass::ALL {
                deadline_ms[q.index()] = opt_usize(d, q.name())?.map(|ms| ms as u64);
            }
        }

        let cancel_storms = match j.get("cancel_storms") {
            None => Vec::new(),
            Some(_) => req_arr(j, "cancel_storms")?
                .iter()
                .map(|s| {
                    known_keys(s, "cancel storm", &["tick", "fraction"])?;
                    Ok(CancelStorm { tick: s.req_usize("tick")?, fraction: s.req_f64("fraction")? })
                })
                .collect::<Result<Vec<_>>>()?,
        };

        let drift = match j.get("drift") {
            None => Vec::new(),
            Some(_) => req_arr(j, "drift")?
                .iter()
                .map(|p| {
                    known_keys(p, "drift phase", &["from_tick", "band"])?;
                    let band = p
                        .get("band")
                        .and_then(Json::as_arr)
                        .filter(|b| b.len() == 2)
                        .context("'band' must be a [lo, hi] array")?;
                    let lo = band[0].as_f64().context("band lo must be a number")?;
                    let hi = band[1].as_f64().context("band hi must be a number")?;
                    Ok(DriftPhase { from_tick: p.req_usize("from_tick")?, band: (lo, hi) })
                })
                .collect::<Result<Vec<_>>>()?,
        };

        let replica_events = match j.get("replica_events") {
            None => Vec::new(),
            Some(_) => req_arr(j, "replica_events")?
                .iter()
                .map(|e| {
                    known_keys(e, "replica event", &["tick", "action", "replica"])?;
                    let action = match e.req_str("action")? {
                        "kill" => ReplicaAction::Kill,
                        "restart" => ReplicaAction::Restart,
                        a => bail!("unknown replica action '{a}' (kill|restart)"),
                    };
                    Ok(ReplicaEvent {
                        tick: e.req_usize("tick")?,
                        action,
                        replica: e.req_usize("replica")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?,
        };

        let online = match j.get("online") {
            None => None,
            Some(o) => {
                known_keys(o, "online", &["drift_threshold", "min_tokens_between"])?;
                Some(OnlineKnobs {
                    drift_threshold: opt_f64(o, "drift_threshold")?.unwrap_or(0.0),
                    min_tokens_between: opt_usize(o, "min_tokens_between")?.unwrap_or(1),
                })
            }
        };

        let admission = match j.get("admission") {
            None => AdmissionKnobs::default(),
            Some(a) => {
                known_keys(
                    a,
                    "admission",
                    &["max_queued_seqs", "max_queued_tokens", "privileged_reserve", "auto_reserve"],
                )?;
                let d = AdmissionKnobs::default();
                AdmissionKnobs {
                    max_queued_seqs: opt_usize(a, "max_queued_seqs")?.unwrap_or(d.max_queued_seqs),
                    max_queued_tokens: opt_usize(a, "max_queued_tokens")?
                        .unwrap_or(d.max_queued_tokens),
                    privileged_reserve: opt_f64(a, "privileged_reserve")?
                        .unwrap_or(d.privileged_reserve),
                    auto_reserve: opt_bool(a, "auto_reserve")?.unwrap_or(d.auto_reserve),
                }
            }
        };

        let decode = match j.get("decode") {
            None => DecodeKnobs::default(),
            Some(d) => {
                known_keys(d, "decode", &["kv_budget_tokens", "kv_page_size", "max_active_seqs"])?;
                let dd = DecodeKnobs::default();
                DecodeKnobs {
                    kv_budget_tokens: opt_usize(d, "kv_budget_tokens")?
                        .unwrap_or(dd.kv_budget_tokens),
                    kv_page_size: opt_usize(d, "kv_page_size")?.unwrap_or(dd.kv_page_size),
                    max_active_seqs: opt_usize(d, "max_active_seqs")?.unwrap_or(dd.max_active_seqs),
                }
            }
        };

        let slo = match j.get("slo") {
            None => SloBounds::default(),
            Some(s) => {
                known_keys(
                    s,
                    "slo",
                    &[
                        "max_shed_rate", "min_served", "min_replans", "min_queue_full",
                        "min_quota", "min_kv_shed", "min_preemptions", "min_hit_rate",
                        "max_p99_ms",
                    ],
                )?;
                let mut max_p99_ms = Vec::new();
                if let Some(p) = s.get("max_p99_ms") {
                    known_keys(p, "max_p99_ms", &["interactive", "standard", "batch", "none"])?;
                    for i in 0..SLO_CLASSES {
                        if let Some(ms) = opt_f64(p, slo_class_name(i))? {
                            max_p99_ms.push((i, ms));
                        }
                    }
                }
                SloBounds {
                    max_shed_rate: opt_f64(s, "max_shed_rate")?,
                    min_served: opt_usize(s, "min_served")?,
                    min_replans: opt_usize(s, "min_replans")?,
                    min_queue_full: opt_usize(s, "min_queue_full")?,
                    min_quota: opt_usize(s, "min_quota")?,
                    min_kv_shed: opt_usize(s, "min_kv_shed")?,
                    min_preemptions: opt_usize(s, "min_preemptions")?,
                    min_hit_rate: opt_f64(s, "min_hit_rate")?,
                    max_p99_ms,
                }
            }
        };

        Ok(ScenarioSpec {
            name: j.req_str("name")?.to_string(),
            description: j.get("description").and_then(Json::as_str).unwrap_or("").to_string(),
            seed: j.req_usize("seed")? as u64,
            ticks: j.req_usize("ticks")?,
            replicas: j.req_usize("replicas")?,
            deterministic: opt_bool(j, "deterministic")?.unwrap_or(false),
            arrival,
            sub_bursts: opt_usize(j, "sub_bursts")?.unwrap_or(1),
            mix,
            prompt_tokens,
            generate_fraction: opt_f64(j, "generate_fraction")?.unwrap_or(0.0),
            max_new_tokens: opt_usize(j, "max_new_tokens")?.unwrap_or(4),
            deadline_ms,
            cancel_storms,
            drift,
            replica_events,
            online,
            sample_interval_ms: opt_usize(j, "sample_interval_ms")?.map(|ms| ms as u64),
            admission,
            decode,
            slo,
        })
    }

    /// Inverse of [`from_json`](Self::from_json); `scenario validate`
    /// round-trips every checked-in spec through this.
    pub fn to_json(&self) -> Json {
        let arrival = match self.arrival {
            ArrivalCurve::Constant { rate } => {
                Json::obj(vec![("curve", Json::str("constant")), ("rate", Json::num(rate))])
            }
            ArrivalCurve::Diurnal { rate, amplitude, period } => Json::obj(vec![
                ("curve", Json::str("diurnal")),
                ("rate", Json::num(rate)),
                ("amplitude", Json::num(amplitude)),
                ("period", Json::num(period)),
            ]),
            ArrivalCurve::Spike { rate, spike_rate, spike_start, spike_len } => Json::obj(vec![
                ("curve", Json::str("spike")),
                ("rate", Json::num(rate)),
                ("spike_rate", Json::num(spike_rate)),
                ("spike_start", Json::num(spike_start as f64)),
                ("spike_len", Json::num(spike_len as f64)),
            ]),
        };
        let mut pairs = vec![
            ("schema", Json::str(SCENARIO_SCHEMA)),
            ("name", Json::str(&self.name)),
            ("description", Json::str(&self.description)),
            ("seed", Json::num(self.seed as f64)),
            ("ticks", Json::num(self.ticks as f64)),
            ("replicas", Json::num(self.replicas as f64)),
            ("deterministic", Json::Bool(self.deterministic)),
            ("arrival", arrival),
            (
                "mix",
                Json::arr(self.mix.iter().map(|p| {
                    Json::obj(vec![
                        ("from_tick", Json::num(p.from_tick as f64)),
                        ("interactive", Json::num(p.interactive)),
                        ("standard", Json::num(p.standard)),
                        ("batch", Json::num(p.batch)),
                    ])
                })),
            ),
            (
                "prompt_tokens",
                Json::obj(vec![
                    ("min", Json::num(self.prompt_tokens.0 as f64)),
                    ("max", Json::num(self.prompt_tokens.1 as f64)),
                ]),
            ),
            ("generate_fraction", Json::num(self.generate_fraction)),
            ("max_new_tokens", Json::num(self.max_new_tokens as f64)),
        ];
        if self.sub_bursts != 1 {
            pairs.push(("sub_bursts", Json::num(self.sub_bursts as f64)));
        }
        if self.deadline_ms.iter().any(Option::is_some) {
            let mut d = Vec::new();
            for q in QosClass::ALL {
                if let Some(ms) = self.deadline_ms[q.index()] {
                    d.push((q.name(), Json::num(ms as f64)));
                }
            }
            pairs.push(("deadline_ms", Json::obj(d)));
        }
        if !self.cancel_storms.is_empty() {
            pairs.push((
                "cancel_storms",
                Json::arr(self.cancel_storms.iter().map(|s| {
                    Json::obj(vec![
                        ("tick", Json::num(s.tick as f64)),
                        ("fraction", Json::num(s.fraction)),
                    ])
                })),
            ));
        }
        if !self.drift.is_empty() {
            pairs.push((
                "drift",
                Json::arr(self.drift.iter().map(|p| {
                    Json::obj(vec![
                        ("from_tick", Json::num(p.from_tick as f64)),
                        ("band", Json::arr(vec![Json::num(p.band.0), Json::num(p.band.1)])),
                    ])
                })),
            ));
        }
        if !self.replica_events.is_empty() {
            pairs.push((
                "replica_events",
                Json::arr(self.replica_events.iter().map(|e| {
                    Json::obj(vec![
                        ("tick", Json::num(e.tick as f64)),
                        (
                            "action",
                            Json::str(match e.action {
                                ReplicaAction::Kill => "kill",
                                ReplicaAction::Restart => "restart",
                            }),
                        ),
                        ("replica", Json::num(e.replica as f64)),
                    ])
                })),
            ));
        }
        if let Some(o) = self.online {
            pairs.push((
                "online",
                Json::obj(vec![
                    ("drift_threshold", Json::num(o.drift_threshold)),
                    ("min_tokens_between", Json::num(o.min_tokens_between as f64)),
                ]),
            ));
        }
        if let Some(ms) = self.sample_interval_ms {
            pairs.push(("sample_interval_ms", Json::num(ms as f64)));
        }
        pairs.push((
            "admission",
            Json::obj(vec![
                ("max_queued_seqs", Json::num(self.admission.max_queued_seqs as f64)),
                ("max_queued_tokens", Json::num(self.admission.max_queued_tokens as f64)),
                ("privileged_reserve", Json::num(self.admission.privileged_reserve)),
                ("auto_reserve", Json::Bool(self.admission.auto_reserve)),
            ]),
        ));
        if self.decode != DecodeKnobs::default() {
            pairs.push((
                "decode",
                Json::obj(vec![
                    ("kv_budget_tokens", Json::num(self.decode.kv_budget_tokens as f64)),
                    ("kv_page_size", Json::num(self.decode.kv_page_size as f64)),
                    ("max_active_seqs", Json::num(self.decode.max_active_seqs as f64)),
                ]),
            ));
        }
        let mut slo = Vec::new();
        if let Some(x) = self.slo.max_shed_rate {
            slo.push(("max_shed_rate", Json::num(x)));
        }
        if let Some(x) = self.slo.min_served {
            slo.push(("min_served", Json::num(x as f64)));
        }
        if let Some(x) = self.slo.min_replans {
            slo.push(("min_replans", Json::num(x as f64)));
        }
        if let Some(x) = self.slo.min_queue_full {
            slo.push(("min_queue_full", Json::num(x as f64)));
        }
        if let Some(x) = self.slo.min_quota {
            slo.push(("min_quota", Json::num(x as f64)));
        }
        if let Some(x) = self.slo.min_kv_shed {
            slo.push(("min_kv_shed", Json::num(x as f64)));
        }
        if let Some(x) = self.slo.min_preemptions {
            slo.push(("min_preemptions", Json::num(x as f64)));
        }
        if let Some(x) = self.slo.min_hit_rate {
            slo.push(("min_hit_rate", Json::num(x)));
        }
        if !self.slo.max_p99_ms.is_empty() {
            slo.push((
                "max_p99_ms",
                Json::obj(
                    self.slo
                        .max_p99_ms
                        .iter()
                        .map(|(i, ms)| (slo_class_name(*i), Json::num(*ms)))
                        .collect(),
                ),
            ));
        }
        if !slo.is_empty() {
            pairs.push(("slo", Json::obj(slo)));
        }
        Json::obj(pairs)
    }

    /// Semantic validation — and the home of the determinism contract:
    /// a `deterministic: true` spec may not carry cancel storms, replica
    /// events, deadlines, or online replan, because each of those makes
    /// part of the ledger timing-dependent.
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.name.is_empty(), "name must be non-empty");
        ensure!(
            self.name.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_'),
            "name '{}' must be [a-z0-9_]+ (it names the BENCH file)",
            self.name
        );
        ensure!(self.ticks >= 1, "ticks must be >= 1");
        ensure!(self.replicas >= 1, "replicas must be >= 1");
        ensure!(self.sub_bursts >= 1, "sub_bursts must be >= 1");
        if let Some(ms) = self.sample_interval_ms {
            ensure!(ms >= 1, "sample_interval_ms must be >= 1");
        }
        ensure!(self.decode.kv_page_size >= 1, "decode.kv_page_size must be >= 1");
        ensure!(self.decode.max_active_seqs >= 1, "decode.max_active_seqs must be >= 1");
        match self.arrival {
            ArrivalCurve::Constant { rate } => ensure!(rate > 0.0, "arrival rate must be > 0"),
            ArrivalCurve::Diurnal { rate, amplitude, period } => {
                ensure!(rate > 0.0, "arrival rate must be > 0");
                ensure!((0.0..=1.0).contains(&amplitude), "amplitude must be in [0, 1]");
                ensure!(period > 0.0, "period must be > 0");
            }
            ArrivalCurve::Spike { rate, spike_rate, spike_start, spike_len } => {
                ensure!(rate >= 0.0 && spike_rate > 0.0, "spike rates must be non-negative");
                ensure!(spike_len >= 1, "spike_len must be >= 1");
                ensure!(spike_start < self.ticks, "spike_start must be inside the run");
            }
        }
        ensure!(!self.mix.is_empty(), "mix needs at least one phase");
        ensure!(self.mix[0].from_tick == 0, "first mix phase must start at tick 0");
        for (i, p) in self.mix.iter().enumerate() {
            ensure!(
                p.interactive >= 0.0 && p.standard >= 0.0 && p.batch >= 0.0,
                "mix weights must be non-negative"
            );
            ensure!(p.interactive + p.standard + p.batch > 0.0, "mix phase {i} has zero mass");
            if i > 0 {
                ensure!(
                    p.from_tick > self.mix[i - 1].from_tick,
                    "mix phases must be in strictly increasing tick order"
                );
            }
        }
        ensure!(
            self.prompt_tokens.0 >= 1 && self.prompt_tokens.0 <= self.prompt_tokens.1,
            "prompt_tokens must satisfy 1 <= min <= max"
        );
        ensure!(
            (0.0..=1.0).contains(&self.generate_fraction),
            "generate_fraction must be in [0, 1]"
        );
        if self.generate_fraction > 0.0 {
            ensure!(self.max_new_tokens >= 1, "max_new_tokens must be >= 1 when generating");
        }
        for s in &self.cancel_storms {
            ensure!(s.tick < self.ticks, "cancel storm tick {} outside the run", s.tick);
            ensure!((0.0..=1.0).contains(&s.fraction), "cancel fraction must be in [0, 1]");
        }
        for (i, p) in self.drift.iter().enumerate() {
            ensure!(
                0.0 <= p.band.0 && p.band.0 < p.band.1 && p.band.1 <= 1.0,
                "drift band must satisfy 0 <= lo < hi <= 1"
            );
            if i > 0 {
                ensure!(
                    p.from_tick > self.drift[i - 1].from_tick,
                    "drift phases must be in strictly increasing tick order"
                );
            }
        }
        // replay the kill/restart timeline: events must be tick-ordered,
        // kill only live replicas, restart only dead ones, and at least
        // one replica must stay alive (a fully dead cluster closes the
        // router and the rest of the scenario cannot run)
        let mut dead = vec![false; self.replicas];
        let mut last_tick = 0usize;
        for e in &self.replica_events {
            ensure!(e.tick < self.ticks, "replica event tick {} outside the run", e.tick);
            ensure!(
                e.replica < self.replicas,
                "replica event targets replica {} of {}",
                e.replica,
                self.replicas
            );
            ensure!(e.tick >= last_tick, "replica events must be in tick order");
            last_tick = e.tick;
            match e.action {
                ReplicaAction::Kill => {
                    ensure!(!dead[e.replica], "replica {} killed twice", e.replica);
                    dead[e.replica] = true;
                }
                ReplicaAction::Restart => {
                    ensure!(dead[e.replica], "replica {} restarted while alive", e.replica);
                    dead[e.replica] = false;
                }
            }
            ensure!(
                dead.iter().any(|d| !d),
                "every replica dead at tick {} — at least one must stay alive",
                e.tick
            );
        }
        ensure!(
            (0.0..1.0).contains(&self.admission.privileged_reserve),
            "privileged_reserve must be in [0, 1)"
        );
        if let Some(r) = self.slo.max_shed_rate {
            ensure!((0.0..=1.0).contains(&r), "max_shed_rate must be in [0, 1]");
        }
        if let Some(r) = self.slo.min_hit_rate {
            ensure!((0.0..=1.0).contains(&r), "min_hit_rate must be in [0, 1]");
        }
        if self.deterministic {
            ensure!(
                self.cancel_storms.is_empty(),
                "deterministic scenario cannot have cancel storms (served/cancelled split races)"
            );
            ensure!(
                self.replica_events.is_empty(),
                "deterministic scenario cannot have replica events (eviction timing races)"
            );
            ensure!(
                self.deadline_ms.iter().all(Option::is_none),
                "deterministic scenario cannot set deadlines (projected-miss sheds are wall-clock)"
            );
            ensure!(
                self.online.is_none(),
                "deterministic scenario cannot replan online (replan timing is wall-clock)"
            );
            ensure!(
                self.slo.min_replans.is_none(),
                "deterministic scenario cannot bound replans"
            );
            ensure!(
                self.sub_bursts == 1,
                "deterministic scenario cannot split ticks into sub-bursts \
                 (burst-atomic admission is the determinism anchor)"
            );
            ensure!(
                self.slo.min_kv_shed.is_none() && self.slo.min_preemptions.is_none(),
                "deterministic scenario cannot bound KV sheds or preemptions \
                 (pool occupancy at admission time is wall-clock)"
            );
        } else if self.slo.min_replans.is_some() {
            ensure!(self.online.is_some(), "min_replans needs 'online' replanning enabled");
        }
        Ok(())
    }

    /// Kills/restarts the spec schedules — the verdict pins the ledger to
    /// these counts.
    fn expected_faults(&self) -> (usize, usize) {
        let kills = self
            .replica_events
            .iter()
            .filter(|e| e.action == ReplicaAction::Kill)
            .count();
        (kills, self.replica_events.len() - kills)
    }
}

// ---------------------------------------------------------------------------
// Deterministic schedule
// ---------------------------------------------------------------------------

#[derive(Clone, Debug, PartialEq)]
struct ArrivalPlan {
    tokens: Vec<u32>,
    qos: QosClass,
    generate: bool,
    cancel: bool,
}

#[derive(Clone, Debug, PartialEq)]
struct TickPlan {
    arrivals: Vec<ArrivalPlan>,
    events: Vec<ReplicaEvent>,
}

fn mix_at(spec: &ScenarioSpec, tick: usize) -> &MixPhase {
    spec.mix.iter().rev().find(|p| p.from_tick <= tick).unwrap_or(&spec.mix[0])
}

fn band_at(spec: &ScenarioSpec, tick: usize) -> (f64, f64) {
    spec.drift
        .iter()
        .rev()
        .find(|p| p.from_tick <= tick)
        .map(|p| p.band)
        .unwrap_or((0.0, 1.0))
}

/// Expand a spec into its per-tick arrival/cancel/fault plan. Pure
/// function of (spec, vocab): a single sequentially-consumed RNG seeded
/// from `spec.seed`, fractional-rate carry accumulation, and schedules
/// resolved per tick — this is where determinism is manufactured.
fn build_schedule(spec: &ScenarioSpec, vocab: usize) -> Vec<TickPlan> {
    let mut rng = Rng::new(spec.seed);
    let mut carry = 0.0f64;
    (0..spec.ticks)
        .map(|tick| {
            carry += spec.arrival.rate_at(tick);
            let n = carry.floor() as usize;
            carry -= n as f64;
            let mix = *mix_at(spec, tick);
            let (blo, bhi) = band_at(spec, tick);
            let lo_tok = (blo * vocab as f64) as u32;
            let hi_tok = ((bhi * vocab as f64) as u32).clamp(lo_tok + 1, vocab as u32);
            let storm = spec.cancel_storms.iter().find(|s| s.tick == tick);
            let arrivals = (0..n)
                .map(|_| {
                    let qos = QosClass::ALL
                        [rng.weighted(&[mix.interactive, mix.standard, mix.batch])];
                    let span = (spec.prompt_tokens.1 - spec.prompt_tokens.0 + 1) as u64;
                    let len = spec.prompt_tokens.0 + rng.below(span) as usize;
                    let tokens = (0..len)
                        .map(|_| lo_tok + rng.below((hi_tok - lo_tok) as u64) as u32)
                        .collect();
                    let generate = rng.next_f64() < spec.generate_fraction;
                    let cancel = storm.is_some_and(|s| rng.next_f64() < s.fraction);
                    ArrivalPlan { tokens, qos, generate, cancel }
                })
                .collect();
            let events =
                spec.replica_events.iter().filter(|e| e.tick == tick).copied().collect();
            TickPlan { arrivals, events }
        })
        .collect()
}

fn to_request(spec: &ScenarioSpec, a: &ArrivalPlan) -> ServeRequest {
    let mut req = if a.generate {
        ServeRequest::generate(a.tokens.clone(), spec.max_new_tokens, vec![])
    } else {
        ServeRequest::new(a.tokens.clone())
    };
    req = req.qos(a.qos);
    if a.qos == QosClass::Batch {
        req = req.priority(Priority::Low);
    }
    if let Some(ms) = spec.deadline_ms[a.qos.index()] {
        req = req.deadline(Duration::from_millis(ms));
    }
    req
}

// ---------------------------------------------------------------------------
// Ledger, verdict, outcome
// ---------------------------------------------------------------------------

/// Admission/termination accounting of one scenario run. For
/// `deterministic: true` specs the whole struct reproduces bit-for-bit;
/// for cancel/kill specs the admission-side fields and the identity
/// `admitted == responses + cancelled + failed` are still pinned.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Ledger {
    pub arrivals: usize,
    pub admitted: usize,
    pub rejected_queue_full: usize,
    pub rejected_deadline: usize,
    pub rejected_quota: usize,
    pub rejected_kv: usize,
    pub cancel_requested: usize,
    pub responses: usize,
    pub cancelled: usize,
    pub failed: usize,
    pub kills: usize,
    pub restarts: usize,
}

impl Ledger {
    /// Shed at the front door, all reject reasons.
    pub fn shed(&self) -> usize {
        self.rejected_queue_full + self.rejected_deadline + self.rejected_quota + self.rejected_kv
    }

    /// Requests that reached *some* terminal past admission.
    pub fn terminated(&self) -> usize {
        self.responses + self.cancelled + self.failed
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("arrivals", Json::num(self.arrivals as f64)),
            ("admitted", Json::num(self.admitted as f64)),
            ("rejected_queue_full", Json::num(self.rejected_queue_full as f64)),
            ("rejected_deadline", Json::num(self.rejected_deadline as f64)),
            ("rejected_quota", Json::num(self.rejected_quota as f64)),
            ("rejected_kv", Json::num(self.rejected_kv as f64)),
            ("cancel_requested", Json::num(self.cancel_requested as f64)),
            ("responses", Json::num(self.responses as f64)),
            ("cancelled", Json::num(self.cancelled as f64)),
            ("failed", Json::num(self.failed as f64)),
            ("kills", Json::num(self.kills as f64)),
            ("restarts", Json::num(self.restarts as f64)),
        ])
    }
}

/// One verdict line: `value op bound`. Unenforced checks (wall-clock
/// bounds in smoke mode) are reported but cannot fail the verdict.
#[derive(Clone, Debug, PartialEq)]
pub struct Check {
    pub name: String,
    pub value: f64,
    pub bound: f64,
    pub op: &'static str,
    pub pass: bool,
    pub enforced: bool,
}

impl Check {
    fn new(
        name: impl Into<String>,
        value: f64,
        bound: f64,
        op: &'static str,
        enforced: bool,
    ) -> Check {
        let pass = match op {
            "<=" => value <= bound,
            ">=" => value >= bound,
            "==" => value == bound,
            _ => unreachable!("check op"),
        };
        Check { name: name.into(), value, bound, op, pass, enforced }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("value", Json::num(self.value)),
            ("bound", Json::num(self.bound)),
            ("op", Json::str(self.op)),
            ("pass", Json::Bool(self.pass)),
            ("enforced", Json::Bool(self.enforced)),
        ])
    }
}

/// SLO verdict: fails iff any *enforced* check fails.
#[derive(Clone, Debug, PartialEq)]
pub struct Verdict {
    pub checks: Vec<Check>,
}

impl Verdict {
    pub fn passed(&self) -> bool {
        self.checks.iter().all(|c| c.pass || !c.enforced)
    }

    pub fn status(&self) -> &'static str {
        if self.passed() {
            "pass"
        } else {
            "fail"
        }
    }
}

/// Per-QoS-class slice of the SLO block.
#[derive(Clone, Debug, PartialEq)]
pub struct ClassSlo {
    pub class: &'static str,
    pub served: usize,
    pub deadline_hit: usize,
    pub deadline_miss: usize,
    pub p50_ms: Option<f64>,
    pub p99_ms: Option<f64>,
}

/// Everything a scenario run reports besides the ledger.
#[derive(Clone, Debug, PartialEq)]
pub struct SloBlock {
    pub per_class: Vec<ClassSlo>,
    /// Hit rate over every deadline-judged request; 1.0 when nothing was
    /// judged.
    pub deadline_hit_rate: f64,
    pub replans: usize,
    pub kv_preemptions: usize,
    /// Slot-weighted average weight bits of the final serving plans.
    pub avg_weight_bits: f64,
    pub kv_avg_bits: f64,
}

/// Result of one scenario run, ready for JSON emission.
#[derive(Clone, Debug)]
pub struct ScenarioOutcome {
    pub name: String,
    pub seed: u64,
    pub deterministic: bool,
    pub smoke: bool,
    pub ticks: usize,
    pub replicas: usize,
    pub ledger: Ledger,
    pub slo: SloBlock,
    pub verdict: Verdict,
    pub elapsed_s: f64,
    /// Observatory snapshot taken just before shutdown; `Some` iff the
    /// spec set `sample_interval_ms`. Serialised as the bench JSON's
    /// `timeseries` block.
    pub timeseries: Option<ObservatorySnapshot>,
}

fn scheme_weight_bits(s: RuntimeScheme) -> f64 {
    match s {
        RuntimeScheme::Fp16 => 16.0,
        RuntimeScheme::W4A16 => 4.0,
        RuntimeScheme::W8A8 => 8.0,
        RuntimeScheme::W4A4 => 4.0,
    }
}

fn avg_plan_bits(report: &ClusterReport) -> f64 {
    let (mut num, mut den) = (0.0f64, 0usize);
    for r in &report.replicas {
        for (s, n) in &r.scheme_counts {
            num += scheme_weight_bits(*s) * *n as f64;
            den += n;
        }
    }
    if den == 0 {
        0.0
    } else {
        num / den as f64
    }
}

fn build_slo_block(report: &ClusterReport) -> SloBlock {
    let flat = report.flatten();
    let slo = report.slo_by_class();
    let lat = report.latency_by_class();
    let per_class = (0..SLO_CLASSES)
        .map(|i| ClassSlo {
            class: slo_class_name(i),
            served: slo[i].served,
            deadline_hit: slo[i].deadline_hit,
            deadline_miss: slo[i].deadline_miss,
            p50_ms: lat[i].as_ref().map(|s| s.p50 * 1e3),
            p99_ms: lat[i].as_ref().map(|s| s.p99 * 1e3),
        })
        .collect();
    let judged: usize = slo.iter().map(|s| s.deadline_hit + s.deadline_miss).sum();
    let hits: usize = slo.iter().map(|s| s.deadline_hit).sum();
    SloBlock {
        per_class,
        deadline_hit_rate: if judged == 0 { 1.0 } else { hits as f64 / judged as f64 },
        replans: flat.replans,
        kv_preemptions: flat.kv_preemptions,
        avg_weight_bits: avg_plan_bits(report),
        kv_avg_bits: flat.kv_avg_bits,
    }
}

fn compute_verdict(spec: &ScenarioSpec, smoke: bool, ledger: &Ledger, slo: &SloBlock) -> Verdict {
    let mut checks = Vec::new();
    // the accounting identity is the anchor: every admitted request must
    // reach exactly one terminal (response, cancelled, failed)
    checks.push(Check::new(
        "ledger_balanced",
        ledger.terminated() as f64,
        ledger.admitted as f64,
        "==",
        true,
    ));
    let (kills, restarts) = spec.expected_faults();
    checks.push(Check::new("kills", ledger.kills as f64, kills as f64, "==", true));
    checks.push(Check::new("restarts", ledger.restarts as f64, restarts as f64, "==", true));
    if let Some(x) = spec.slo.max_shed_rate {
        let rate = ledger.shed() as f64 / ledger.arrivals.max(1) as f64;
        checks.push(Check::new("shed_rate", rate, x, "<=", true));
    }
    if let Some(x) = spec.slo.min_served {
        checks.push(Check::new("served", ledger.responses as f64, x as f64, ">=", true));
    }
    if let Some(x) = spec.slo.min_queue_full {
        checks.push(Check::new(
            "queue_full_rejects",
            ledger.rejected_queue_full as f64,
            x as f64,
            ">=",
            true,
        ));
    }
    if let Some(x) = spec.slo.min_quota {
        checks.push(Check::new(
            "quota_rejects",
            ledger.rejected_quota as f64,
            x as f64,
            ">=",
            true,
        ));
    }
    if let Some(x) = spec.slo.min_replans {
        checks.push(Check::new("replans", slo.replans as f64, x as f64, ">=", true));
    }
    // KV-pressure bounds: whether the gate trips (and how often decode
    // preempts) depends on how much earlier work is still holding pages
    // when a sub-burst lands — wall-clock, so enforced only in full runs
    if let Some(x) = spec.slo.min_kv_shed {
        checks.push(Check::new(
            "kv_shed_rejects",
            ledger.rejected_kv as f64,
            x as f64,
            ">=",
            !smoke,
        ));
    }
    if let Some(x) = spec.slo.min_preemptions {
        checks.push(Check::new(
            "kv_preemptions",
            slo.kv_preemptions as f64,
            x as f64,
            ">=",
            !smoke,
        ));
    }
    // wall-clock bounds: reported in every mode, enforced only in full
    // runs (shared CI runners must not flake the gate)
    if let Some(x) = spec.slo.min_hit_rate {
        checks.push(Check::new("deadline_hit_rate", slo.deadline_hit_rate, x, ">=", !smoke));
    }
    for (i, ms) in &spec.slo.max_p99_ms {
        let value = slo.per_class[*i].p99_ms.unwrap_or(0.0);
        checks.push(Check::new(format!("p99_{}_ms", slo_class_name(*i)), value, *ms, "<=", !smoke));
    }
    Verdict { checks }
}

// ---------------------------------------------------------------------------
// Replay driver
// ---------------------------------------------------------------------------

/// Driver knobs that are not part of the spec (and deliberately excluded
/// from the determinism contract's inputs — the ledger must not depend
/// on them).
#[derive(Clone, Debug, Default)]
pub struct RunOptions {
    /// Smoke mode: wall-clock checks reported but not enforced.
    pub smoke: bool,
    /// Per-replica dispatch-pool override; the determinism test sweeps
    /// this to prove thread-count independence.
    pub dispatch_threads: Option<usize>,
}

/// Model under test: the cached `ci-mini` checkpoint when present
/// (CI path), else the identical checkpoint re-derived in-process from
/// [`MINI_MODEL_SEED`] and written to a per-scenario temp file.
fn model_source(scenario: &str) -> Result<(ModelConfig, MoeLm, PathBuf)> {
    let mini = artifacts_dir().join("model_ci-mini.mxt");
    if mini.exists() {
        let (cfg, lm) = super::load_model("ci-mini")?;
        return Ok((cfg, lm, mini));
    }
    if std::env::var("MXMOE_REQUIRE_MINI_MODEL").map(|v| v == "1").unwrap_or(false) {
        bail!("MXMOE_REQUIRE_MINI_MODEL=1 but {mini:?} missing — run `make mini-model`");
    }
    let cfg = ModelConfig::by_name("ci-mini")?;
    let lm = MoeLm::random(&cfg, &mut Rng::new(MINI_MODEL_SEED));
    let path = std::env::temp_dir().join(format!("mxmoe_scenario_{scenario}.mxt"));
    save_model_mxt(&lm, &path)?;
    Ok((cfg, lm, path))
}

/// Replay `spec` against a fresh mini-model cluster and compute its
/// verdict. Requires the AOT artifacts (`make artifacts`); callers gate
/// with [`require_artifacts`] to self-skip locally.
pub fn run_scenario(spec: &ScenarioSpec, opts: &RunOptions) -> Result<ScenarioOutcome> {
    spec.validate()?;
    let Some(artifacts) = require_artifacts() else {
        bail!("AOT artifacts not built — run `make artifacts` first");
    };
    let (cfg, lm, weights) = model_source(&spec.name)?;
    let schedule = build_schedule(spec, cfg.vocab);

    let cluster_cfg = ClusterConfig {
        replicas: spec.replicas,
        serve: ServeConfig {
            max_batch_seqs: 4,
            max_wait: Duration::from_millis(2),
            ..Default::default()
        },
        admission: AdmissionConfig {
            max_queued_seqs: spec.admission.max_queued_seqs,
            max_queued_tokens: spec.admission.max_queued_tokens,
            privileged_reserve: spec.admission.privileged_reserve,
            auto_reserve: spec.admission.auto_reserve,
            // projected-miss sheds depend on a wall-clock service-rate
            // EWMA; deterministic specs must not take that path
            shed_on_projected_miss: !spec.deterministic,
            ..Default::default()
        },
        dispatch_threads: opts.dispatch_threads,
        sample: match spec.sample_interval_ms {
            Some(ms) => SampleConfig { enabled: true, interval_ms: ms, ..Default::default() },
            None => SampleConfig::default(),
        },
        decode: DecodePolicy {
            kv_budget_tokens: spec.decode.kv_budget_tokens,
            kv_page_size: spec.decode.kv_page_size,
            max_active_seqs: spec.decode.max_active_seqs,
            ..Default::default()
        },
        ..Default::default()
    };

    let t0 = Instant::now();
    let mut cluster = match spec.online {
        Some(knobs) => {
            // calibration → sensitivity → replanner, as `mxmoe trace-dump`
            use crate::alloc::{
                activation_frequencies, calibrate, measure_sensitivity, AllocatorConfig,
                Granularity,
            };
            use crate::costmodel::GpuSpec;
            use crate::quant::SchemeRegistry;
            use crate::serve::{ReplanConfig, Replanner};

            let mut crng = Rng::new(spec.seed ^ 0xCA11_B8A7);
            let calib: Vec<Vec<u32>> = (0..8)
                .map(|_| (0..cfg.seq_len).map(|_| crng.below(cfg.vocab as u64) as u32).collect())
                .collect();
            let calib_refs: Vec<&[u32]> = calib.iter().map(|s| s.as_slice()).collect();
            let stats = calibrate(&lm, &calib_refs, None)?;
            let registry = SchemeRegistry::weight_activation();
            let sens = measure_sensitivity(&lm, &stats, &registry)?;
            let replanner = Replanner {
                gpu: GpuSpec::rtx4090(),
                registry,
                sens,
                cfg: ReplanConfig {
                    drift_threshold: knobs.drift_threshold,
                    min_tokens_between: knobs.min_tokens_between,
                    alloc: AllocatorConfig {
                        r: 0.75,
                        target_avg_bits: 5.0,
                        granularity: Granularity::LinearBlock,
                        batch_tokens: 512,
                    },
                },
            };
            Cluster::start_online(
                cfg.clone(),
                weights,
                artifacts,
                mixed_runtime_plan(&cfg),
                cluster_cfg,
                OnlineConfig {
                    replanner,
                    baseline: activation_frequencies(&stats),
                    ewma_alpha: Some(0.25),
                },
            )?
        }
        None => Cluster::start(
            cfg.clone(),
            weights,
            artifacts,
            mixed_runtime_plan(&cfg),
            cluster_cfg,
        )?,
    };
    drop(lm);

    let mut arrivals = 0usize;
    let mut cancel_requested = 0usize;
    let mut kills = 0usize;
    let mut restarts = 0usize;
    for plan in &schedule {
        for ev in &plan.events {
            match ev.action {
                ReplicaAction::Kill => {
                    cluster.kill_replica(ev.replica);
                    kills += 1;
                }
                ReplicaAction::Restart => {
                    cluster.restart_replica(ev.replica)?;
                    restarts += 1;
                }
            }
        }
        arrivals += plan.arrivals.len();
        // `sub_bursts == 1` is the classic burst-atomic tick; more split
        // the arrivals into chunks landing SUB_BURST_GAP apart with no
        // quiesce between, so later chunks contend with whatever KV the
        // earlier ones still hold (the kv-exhausted gate's trigger)
        let chunk_len = plan.arrivals.len().div_ceil(spec.sub_bursts).max(1);
        let mut live = Vec::new();
        for (bi, chunk) in plan.arrivals.chunks(chunk_len).enumerate() {
            if bi > 0 {
                std::thread::sleep(SUB_BURST_GAP);
            }
            let reqs: Vec<ServeRequest> = chunk.iter().map(|a| to_request(spec, a)).collect();
            for (a, adm) in chunk.iter().zip(cluster.try_submit_burst(reqs)?) {
                match adm {
                    Admission::Admitted(t) => {
                        if a.cancel {
                            t.cancel();
                            cancel_requested += 1;
                            // keep the ticket alive until the tick drains
                            // so the replica's reply (if the cancel lost
                            // the race) has a live channel
                            live.push((t, true));
                        } else {
                            live.push((t, false));
                        }
                    }
                    Admission::Rejected { .. } => {} // counted by the admission report
                }
            }
        }
        // quiesce, half 1: every non-cancelled admitted request reaches a
        // terminal. A disconnected reply channel is a terminal too — the
        // kill path drops evicted requests (reply senders close).
        for (t, cancelled) in &live {
            if *cancelled {
                continue;
            }
            match t.rx.recv_timeout(QUIESCE_BUDGET) {
                Ok(_) | Err(RecvTimeoutError::Disconnected) => {}
                Err(RecvTimeoutError::Timeout) => {
                    bail!("scenario '{}' stalled waiting on request {}", spec.name, t.id())
                }
            }
        }
        // quiesce, half 2: cancelled stragglers hold admission-queue
        // slots until the router sheds them at the next batch cut
        let drain_t0 = Instant::now();
        while cluster.queued() != (0, 0) {
            ensure!(
                drain_t0.elapsed() < QUIESCE_BUDGET,
                "scenario '{}' admission queue failed to drain (queued {:?})",
                spec.name,
                cluster.queued()
            );
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    let elapsed_s = t0.elapsed().as_secs_f64();
    let obs = spec.sample_interval_ms.map(|_| cluster.observatory());
    let report = cluster.shutdown();
    let timeseries = obs.map(|o| o.snapshot());

    let flat = report.flatten();
    let ledger = Ledger {
        arrivals,
        admitted: flat.admitted,
        rejected_queue_full: flat.rejected_queue_full,
        rejected_deadline: flat.rejected_deadline,
        rejected_quota: flat.rejected_quota,
        rejected_kv: flat.rejected_kv,
        cancel_requested,
        responses: report.total_requests(),
        cancelled: flat.cancelled,
        failed: flat.failed,
        kills,
        restarts,
    };
    let slo = build_slo_block(&report);
    let verdict = compute_verdict(spec, opts.smoke, &ledger, &slo);
    Ok(ScenarioOutcome {
        name: spec.name.clone(),
        seed: spec.seed,
        deterministic: spec.deterministic,
        smoke: opts.smoke,
        ticks: spec.ticks,
        replicas: spec.replicas,
        ledger,
        slo,
        verdict,
        elapsed_s,
        timeseries,
    })
}

// ---------------------------------------------------------------------------
// BENCH emission + shared bench-file validation
// ---------------------------------------------------------------------------

/// `timeseries` block of a sampled run: every recorded series with its
/// full `[t_s, v]` point list (ring-bounded, so a scenario's worth fits
/// comfortably) plus the fixed-bucket histograms.
fn timeseries_json(snap: &ObservatorySnapshot) -> Json {
    let series = Json::arr(snap.series.iter().map(|s| {
        Json::obj(vec![
            ("name", Json::str(&s.name)),
            ("kind", Json::str(s.kind.name())),
            ("pushed", Json::num(s.pushed as f64)),
            (
                "points",
                Json::arr(
                    s.points
                        .iter()
                        .map(|p| Json::arr(vec![Json::num(p.t_s), Json::num(p.v)])),
                ),
            ),
        ])
    }));
    let histograms = Json::arr(snap.histograms.iter().map(|h| {
        Json::obj(vec![
            ("name", Json::str(&h.name)),
            ("bounds", Json::arr(h.bounds.iter().map(|b| Json::num(*b)))),
            ("counts", Json::arr(h.counts.iter().map(|c| Json::num(*c as f64)))),
            ("sum", Json::num(h.sum)),
            ("count", Json::num(h.count as f64)),
        ])
    }));
    Json::obj(vec![("series", series), ("histograms", histograms)])
}

impl ScenarioOutcome {
    /// Full `BENCH_scenario_<name>.json` body (the `mxmoe-bench-v1`
    /// envelope plus ledger, SLO block, and verdict).
    pub fn to_json(&self) -> Json {
        let per_class = Json::arr(self.slo.per_class.iter().map(|c| {
            Json::obj(vec![
                ("class", Json::str(c.class)),
                ("served", Json::num(c.served as f64)),
                ("deadline_hit", Json::num(c.deadline_hit as f64)),
                ("deadline_miss", Json::num(c.deadline_miss as f64)),
                ("p50_ms", c.p50_ms.map_or(Json::Null, Json::num)),
                ("p99_ms", c.p99_ms.map_or(Json::Null, Json::num)),
            ])
        }));
        let mut pairs = vec![
            ("schema", Json::str(BENCH_SCHEMA)),
            ("bench", Json::str("scenario")),
            ("smoke", Json::Bool(self.smoke)),
            ("scenario", Json::str(&self.name)),
            ("seed", Json::num(self.seed as f64)),
            ("deterministic", Json::Bool(self.deterministic)),
            ("ticks", Json::num(self.ticks as f64)),
            ("replicas", Json::num(self.replicas as f64)),
            ("elapsed_s", Json::num(self.elapsed_s)),
            ("ledger", self.ledger.to_json()),
            (
                "slo",
                Json::obj(vec![
                    ("per_class", per_class),
                    ("deadline_hit_rate", Json::num(self.slo.deadline_hit_rate)),
                    ("shed", Json::num(self.ledger.shed() as f64)),
                    (
                        "shed_rate",
                        Json::num(
                            self.ledger.shed() as f64 / self.ledger.arrivals.max(1) as f64,
                        ),
                    ),
                    ("replans", Json::num(self.slo.replans as f64)),
                    ("kv_preemptions", Json::num(self.slo.kv_preemptions as f64)),
                    ("avg_weight_bits", Json::num(self.slo.avg_weight_bits)),
                    ("kv_avg_bits", Json::num(self.slo.kv_avg_bits)),
                ]),
            ),
            (
                "verdict",
                Json::obj(vec![
                    ("status", Json::str(self.verdict.status())),
                    ("checks", Json::arr(self.verdict.checks.iter().map(Check::to_json))),
                ]),
            ),
        ];
        if let Some(ts) = &self.timeseries {
            pairs.push(("timeseries", timeseries_json(ts)));
        }
        Json::obj(pairs)
    }

    /// Write `BENCH_scenario_<name>.json` into `dir`.
    pub fn write(&self, dir: &Path) -> Result<PathBuf> {
        let path = dir.join(format!("BENCH_scenario_{}.json", self.name));
        std::fs::write(&path, self.to_json().pretty())
            .with_context(|| format!("write {}", path.display()))?;
        Ok(path)
    }
}

/// What `mxmoe bench-validate` learned about one `BENCH_*.json`.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchFileCheck {
    pub bench: String,
    pub smoke: bool,
    /// `Some("pass" | "fail")` for scenario files, `None` for plain
    /// metric benches.
    pub verdict: Option<String>,
}

/// Shared schema check for every `BENCH_*.json` the repo emits: the
/// `mxmoe-bench-v1` envelope (all benches) plus the ledger/SLO/verdict
/// block (scenario benches). A file with `"skipped": true` (artifacts
/// not built) only needs the envelope.
pub fn validate_bench_json(text: &str) -> Result<BenchFileCheck> {
    let j = Json::parse(text).map_err(|e| anyhow::anyhow!("bench JSON: {e}"))?;
    let schema = j.req_str("schema")?;
    ensure!(schema == BENCH_SCHEMA, "schema must be '{BENCH_SCHEMA}', got '{schema}'");
    let bench = j.req_str("bench")?.to_string();
    let smoke = j
        .get("smoke")
        .and_then(Json::as_bool)
        .context("'smoke' must be a bool")?;
    let skipped = opt_bool(&j, "skipped")?.unwrap_or(false);
    if bench != "scenario" || skipped {
        return Ok(BenchFileCheck { bench, smoke, verdict: None });
    }
    j.req_str("scenario")?;
    j.req_usize("seed")?;
    let ledger = j.get("ledger").context("scenario bench needs a 'ledger' object")?;
    for k in [
        "arrivals", "admitted", "rejected_queue_full", "rejected_deadline", "rejected_quota",
        "rejected_kv", "cancel_requested", "responses", "cancelled", "failed", "kills", "restarts",
    ] {
        ledger.req_usize(k)?;
    }
    let slo = j.get("slo").context("scenario bench needs an 'slo' object")?;
    slo.req_f64("deadline_hit_rate")?;
    slo.req_usize("replans")?;
    slo.req_usize("kv_preemptions")?;
    let verdict = j.get("verdict").context("scenario bench needs a 'verdict' object")?;
    let status = verdict.req_str("status")?;
    ensure!(
        status == "pass" || status == "fail",
        "verdict status must be pass|fail, got '{status}'"
    );
    let checks = verdict
        .get("checks")
        .and_then(Json::as_arr)
        .context("'verdict.checks' must be an array")?;
    for c in checks {
        c.req_str("name")?;
        c.req_f64("value")?;
        c.req_f64("bound")?;
        c.req_str("op")?;
        c.get("pass").and_then(Json::as_bool).context("check 'pass' must be a bool")?;
        c.get("enforced").and_then(Json::as_bool).context("check 'enforced' must be a bool")?;
    }
    Ok(BenchFileCheck { bench, smoke, verdict: Some(status.to_string()) })
}

// ---------------------------------------------------------------------------
// Spec discovery
// ---------------------------------------------------------------------------

/// Repo-relative `scenarios/` directory (the checked-in spec suite).
pub fn scenarios_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("scenarios")
}

/// Load and fully validate one spec file.
pub fn load_spec(path: &Path) -> Result<ScenarioSpec> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
    ScenarioSpec::parse(&text).with_context(|| format!("invalid scenario {}", path.display()))
}

/// Load spec `name` from [`scenarios_dir`].
pub fn load_named_spec(name: &str) -> Result<ScenarioSpec> {
    load_spec(&scenarios_dir().join(format!("{name}.json")))
}

/// Every checked-in spec, sorted by file name.
pub fn list_specs() -> Result<Vec<ScenarioSpec>> {
    let dir = scenarios_dir();
    let mut paths: Vec<PathBuf> = std::fs::read_dir(&dir)
        .with_context(|| format!("read {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    paths.sort();
    paths.iter().map(|p| load_spec(p)).collect()
}

// ---------------------------------------------------------------------------
// Tests (pure — the cluster-driving tests live in tests/scenario_replay.rs)
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal_spec() -> ScenarioSpec {
        ScenarioSpec {
            name: "unit".into(),
            description: "unit fixture".into(),
            seed: 7,
            ticks: 10,
            replicas: 1,
            deterministic: true,
            arrival: ArrivalCurve::Constant { rate: 2.5 },
            sub_bursts: 1,
            mix: vec![MixPhase { from_tick: 0, interactive: 0.5, standard: 0.3, batch: 0.2 }],
            prompt_tokens: (4, 12),
            generate_fraction: 0.25,
            max_new_tokens: 4,
            deadline_ms: [None; 3],
            cancel_storms: vec![],
            drift: vec![],
            replica_events: vec![],
            online: None,
            sample_interval_ms: None,
            admission: AdmissionKnobs::default(),
            decode: DecodeKnobs::default(),
            slo: SloBounds { max_shed_rate: Some(0.0), min_served: Some(25), ..Default::default() },
        }
    }

    #[test]
    fn spec_round_trips_through_json() {
        let mut spec = minimal_spec();
        spec.deterministic = false;
        spec.deadline_ms[QosClass::Interactive.index()] = Some(30_000);
        spec.cancel_storms = vec![CancelStorm { tick: 4, fraction: 0.5 }];
        spec.drift = vec![
            DriftPhase { from_tick: 0, band: (0.0, 1.0) },
            DriftPhase { from_tick: 5, band: (0.0, 0.25) },
        ];
        spec.replica_events = vec![
            ReplicaEvent { tick: 2, action: ReplicaAction::Kill, replica: 1 },
            ReplicaEvent { tick: 5, action: ReplicaAction::Restart, replica: 1 },
        ];
        spec.replicas = 2;
        spec.online = Some(OnlineKnobs { drift_threshold: 0.0, min_tokens_between: 1 });
        spec.slo.max_p99_ms = vec![(0, 2000.0)];
        spec.sub_bursts = 4;
        spec.decode = DecodeKnobs { kv_budget_tokens: 64, kv_page_size: 16, max_active_seqs: 2 };
        spec.slo.min_kv_shed = Some(1);
        spec.slo.min_preemptions = Some(1);
        spec.sample_interval_ms = Some(50);
        spec.validate().unwrap();
        let text = spec.to_json().pretty();
        let back = ScenarioSpec::parse(&text).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn malformed_specs_are_rejected() {
        // not JSON at all
        assert!(ScenarioSpec::parse("{nope").is_err());
        // wrong schema tag
        let bad = minimal_spec().to_json().pretty().replace(SCENARIO_SCHEMA, "bogus-v9");
        assert!(ScenarioSpec::parse(&bad).unwrap_err().to_string().contains("schema"));
        // unknown key
        let mut j = minimal_spec().to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("typo_key".into(), Json::num(1.0));
        }
        let err = ScenarioSpec::parse(&j.pretty()).unwrap_err();
        assert!(format!("{err:#}").contains("typo_key"));
        // wrong type for a field
        let bad = minimal_spec().to_json().pretty().replace("\"ticks\": 10", "\"ticks\": \"ten\"");
        assert!(ScenarioSpec::parse(&bad).is_err());
    }

    #[test]
    fn determinism_contract_is_validated() {
        let mut spec = minimal_spec();
        spec.cancel_storms = vec![CancelStorm { tick: 1, fraction: 0.5 }];
        let err = spec.validate().unwrap_err().to_string();
        assert!(err.contains("deterministic"), "{err}");
        spec.cancel_storms.clear();
        spec.deadline_ms[0] = Some(1000);
        assert!(spec.validate().is_err());
        spec.deadline_ms[0] = None;
        spec.online = Some(OnlineKnobs { drift_threshold: 0.0, min_tokens_between: 1 });
        assert!(spec.validate().is_err());
        spec.online = None;
        // sub-bursts break burst-atomic admission; KV-pressure bounds are
        // wall-clock — both are deterministic-mode contraband
        spec.sub_bursts = 2;
        assert!(spec.validate().unwrap_err().to_string().contains("sub-bursts"));
        spec.sub_bursts = 1;
        spec.slo.min_kv_shed = Some(1);
        assert!(spec.validate().unwrap_err().to_string().contains("KV"));
        spec.slo.min_kv_shed = None;
        spec.online = Some(OnlineKnobs { drift_threshold: 0.0, min_tokens_between: 1 });
        spec.deterministic = false;
        spec.validate().unwrap();
        spec.sub_bursts = 2;
        spec.slo.min_preemptions = Some(1);
        spec.validate().unwrap();
    }

    #[test]
    fn replica_event_timeline_is_validated() {
        let mut spec = minimal_spec();
        spec.deterministic = false;
        spec.replicas = 2;
        // killing both replicas leaves nobody alive
        spec.replica_events = vec![
            ReplicaEvent { tick: 1, action: ReplicaAction::Kill, replica: 0 },
            ReplicaEvent { tick: 2, action: ReplicaAction::Kill, replica: 1 },
        ];
        assert!(spec.validate().unwrap_err().to_string().contains("alive"));
        // restart-before-kill is incoherent
        spec.replica_events =
            vec![ReplicaEvent { tick: 1, action: ReplicaAction::Restart, replica: 0 }];
        assert!(spec.validate().is_err());
        // kill then restart is fine
        spec.replica_events = vec![
            ReplicaEvent { tick: 1, action: ReplicaAction::Kill, replica: 1 },
            ReplicaEvent { tick: 3, action: ReplicaAction::Restart, replica: 1 },
        ];
        spec.validate().unwrap();
    }

    #[test]
    fn arrival_curves_and_carry_accumulate_exactly() {
        let c = ArrivalCurve::Constant { rate: 2.5 };
        assert_eq!(c.rate_at(0), 2.5);
        let s = ArrivalCurve::Spike { rate: 1.0, spike_rate: 12.0, spike_start: 3, spike_len: 2 };
        assert_eq!(s.rate_at(2), 1.0);
        assert_eq!(s.rate_at(3), 12.0);
        assert_eq!(s.rate_at(4), 12.0);
        assert_eq!(s.rate_at(5), 1.0);
        let d = ArrivalCurve::Diurnal { rate: 4.0, amplitude: 1.0, period: 8.0 };
        assert_eq!(d.rate_at(0), 4.0); // sin(0) = 0
        assert!(d.rate_at(2) > 7.9); // peak of the sine
        // fractional carry: 2.5/tick × 10 ticks = exactly 25 arrivals
        let spec = minimal_spec();
        let total: usize = build_schedule(&spec, 64).iter().map(|t| t.arrivals.len()).sum();
        assert_eq!(total, 25);
    }

    #[test]
    fn schedule_is_deterministic_and_honors_phases() {
        let mut spec = minimal_spec();
        spec.deterministic = false;
        spec.drift = vec![
            DriftPhase { from_tick: 0, band: (0.0, 0.5) },
            DriftPhase { from_tick: 5, band: (0.5, 1.0) },
        ];
        spec.cancel_storms = vec![CancelStorm { tick: 7, fraction: 1.0 }];
        let a = build_schedule(&spec, 64);
        let b = build_schedule(&spec, 64);
        assert_eq!(a, b, "same spec + seed must yield the identical schedule");
        // drift bands bound the sampled tokens
        for (tick, plan) in a.iter().enumerate() {
            for arr in &plan.arrivals {
                for &t in &arr.tokens {
                    if tick < 5 {
                        assert!(t < 32, "tick {tick}: token {t} outside band [0, 0.5)");
                    } else {
                        assert!((32..64).contains(&t), "tick {tick}: token {t} outside band");
                    }
                }
            }
        }
        // a fraction-1.0 storm flags every arrival of its tick, no other
        for (tick, plan) in a.iter().enumerate() {
            for arr in &plan.arrivals {
                assert_eq!(arr.cancel, tick == 7);
            }
        }
    }

    #[test]
    fn verdict_enforces_ledger_checks_and_defers_wall_clock_in_smoke() {
        let mut spec = minimal_spec();
        spec.slo.min_hit_rate = Some(0.99);
        spec.deterministic = false;
        let ledger = Ledger {
            arrivals: 25,
            admitted: 25,
            responses: 25,
            ..Default::default()
        };
        let slo = SloBlock {
            per_class: (0..SLO_CLASSES)
                .map(|i| ClassSlo {
                    class: slo_class_name(i),
                    served: 0,
                    deadline_hit: 0,
                    deadline_miss: 0,
                    p50_ms: None,
                    p99_ms: None,
                })
                .collect(),
            deadline_hit_rate: 0.5, // violates min_hit_rate
            replans: 0,
            kv_preemptions: 0,
            avg_weight_bits: 8.0,
            kv_avg_bits: 8.0,
        };
        // smoke: wall-clock miss reported but not enforced
        let v = compute_verdict(&spec, true, &ledger, &slo);
        assert_eq!(v.status(), "pass");
        let hr = v.checks.iter().find(|c| c.name == "deadline_hit_rate").unwrap();
        assert!(!hr.pass && !hr.enforced);
        // full mode: enforced, so the verdict fails
        assert_eq!(compute_verdict(&spec, false, &ledger, &slo).status(), "fail");
        // a broken ledger fails in any mode
        let broken = Ledger { responses: 24, ..ledger };
        let v = compute_verdict(&spec, true, &broken, &slo);
        assert_eq!(v.status(), "fail");
        assert!(!v.checks.iter().find(|c| c.name == "ledger_balanced").unwrap().pass);
    }

    #[test]
    fn bench_json_validation_accepts_outcomes_and_rejects_garbage() {
        let spec = minimal_spec();
        let outcome = ScenarioOutcome {
            name: spec.name.clone(),
            seed: spec.seed,
            deterministic: true,
            smoke: true,
            ticks: spec.ticks,
            replicas: 1,
            ledger: Ledger { arrivals: 25, admitted: 25, responses: 25, ..Default::default() },
            slo: SloBlock {
                per_class: vec![],
                deadline_hit_rate: 1.0,
                replans: 0,
                kv_preemptions: 0,
                avg_weight_bits: 8.0,
                kv_avg_bits: 8.0,
            },
            verdict: Verdict {
                checks: vec![Check::new("ledger_balanced", 25.0, 25.0, "==", true)],
            },
            elapsed_s: 0.1,
            timeseries: Some(ObservatorySnapshot::default()),
        };
        let checked = validate_bench_json(&outcome.to_json().pretty()).unwrap();
        assert_eq!(checked.bench, "scenario");
        assert_eq!(checked.verdict.as_deref(), Some("pass"));
        // a plain metric bench only needs the envelope
        let legacy = Json::obj(vec![
            ("schema", Json::str(BENCH_SCHEMA)),
            ("bench", Json::str("admission")),
            ("smoke", Json::Bool(true)),
            ("p99_s", Json::num(0.01)),
        ]);
        assert_eq!(validate_bench_json(&legacy.pretty()).unwrap().verdict, None);
        // missing envelope → rejected
        assert!(validate_bench_json("{\"bench\": \"x\"}").is_err());
        // scenario bench without a verdict → rejected
        let mut j = outcome.to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("verdict");
        }
        assert!(validate_bench_json(&j.pretty()).is_err());
    }
}
