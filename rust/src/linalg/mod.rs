//! Small dense linear-algebra kernels needed by GPTQ: Cholesky
//! factorization, triangular solves, and SPD inversion with diagonal
//! damping (the `percdamp` trick from the GPTQ reference implementation).

use crate::tensor::Matrix;
use anyhow::{bail, Result};

/// Cholesky factorization `A = L·Lᵀ` of a symmetric positive-definite
/// matrix; returns lower-triangular `L`. Fails on non-SPD input.
pub fn cholesky(a: &Matrix) -> Result<Matrix> {
    if a.rows != a.cols {
        bail!("cholesky: matrix not square");
    }
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.at(i, j) as f64;
            for k in 0..j {
                s -= l.at(i, k) as f64 * l.at(j, k) as f64;
            }
            if i == j {
                if s <= 0.0 {
                    bail!("cholesky: not positive definite at pivot {i} (s={s})");
                }
                *l.at_mut(i, j) = s.sqrt() as f32;
            } else {
                *l.at_mut(i, j) = (s / l.at(j, j) as f64) as f32;
            }
        }
    }
    Ok(l)
}

/// Solve `L·x = b` for lower-triangular `L` (forward substitution).
pub fn solve_lower(l: &Matrix, b: &[f32]) -> Vec<f32> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut x = vec![0.0f32; n];
    for i in 0..n {
        let mut s = b[i] as f64;
        for k in 0..i {
            s -= l.at(i, k) as f64 * x[k] as f64;
        }
        x[i] = (s / l.at(i, i) as f64) as f32;
    }
    x
}

/// Solve `Lᵀ·x = b` for lower-triangular `L` (backward substitution).
pub fn solve_lower_t(l: &Matrix, b: &[f32]) -> Vec<f32> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut s = b[i] as f64;
        for k in i + 1..n {
            s -= l.at(k, i) as f64 * x[k] as f64;
        }
        x[i] = (s / l.at(i, i) as f64) as f32;
    }
    x
}

/// Invert an SPD matrix via Cholesky: `A⁻¹ = L⁻ᵀ·L⁻¹`.
pub fn spd_inverse(a: &Matrix) -> Result<Matrix> {
    let n = a.rows;
    let l = cholesky(a)?;
    let mut inv = Matrix::zeros(n, n);
    let mut e = vec![0.0f32; n];
    for c in 0..n {
        e.iter_mut().for_each(|v| *v = 0.0);
        e[c] = 1.0;
        let y = solve_lower(&l, &e);
        let x = solve_lower_t(&l, &y);
        for r in 0..n {
            *inv.at_mut(r, c) = x[r];
        }
    }
    Ok(inv)
}

/// GPTQ Hessian preparation: `H ← H + mean(diag(H))·damp·I`, handle dead
/// columns (zero diagonal → 1), then return the **upper Cholesky factor of
/// H⁻¹** (`U` with `H⁻¹ = Uᵀ·U`... stored as the standard GPTQ
/// `Cholesky(H⁻¹, upper=True)`), which the GPTQ update loop consumes.
pub fn gptq_hinv_cholesky(h: &Matrix, damp: f32) -> Result<Matrix> {
    let n = h.rows;
    let mut hh = h.clone();
    let mean_diag: f64 = (0..n).map(|i| hh.at(i, i) as f64).sum::<f64>() / n as f64;
    let lambda = (mean_diag * damp as f64).max(1e-8) as f32;
    for i in 0..n {
        if hh.at(i, i) == 0.0 {
            *hh.at_mut(i, i) = 1.0;
        }
        *hh.at_mut(i, i) += lambda;
    }
    let inv = spd_inverse(&hh)?;
    // upper factor: inv = Uᵀ U with U upper triangular ⇔ L = Uᵀ lower
    let l = cholesky(&inv)?;
    Ok(l.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matrix::matmul_nt;
    use crate::util::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> Matrix {
        let a = Matrix::randn(n, n, 1.0, rng);
        // A·Aᵀ + n·I is SPD
        let mut s = matmul_nt(&a, &a);
        for i in 0..n {
            *s.at_mut(i, i) += n as f32;
        }
        s
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(8);
        let a = random_spd(12, &mut rng);
        let l = cholesky(&a).unwrap();
        let lt = l.transpose();
        let recon = matmul_nt(&l, &lt.transpose());
        for (x, y) in recon.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-2, "{x} vs {y}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eig −1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn solves_invert_l() {
        let mut rng = Rng::new(9);
        let a = random_spd(8, &mut rng);
        let l = cholesky(&a).unwrap();
        let b: Vec<f32> = (0..8).map(|i| i as f32 - 3.0).collect();
        let y = solve_lower(&l, &b);
        // check L·y = b
        for i in 0..8 {
            let mut s = 0.0;
            for k in 0..=i {
                s += l.at(i, k) * y[k];
            }
            assert!((s - b[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn spd_inverse_is_inverse() {
        let mut rng = Rng::new(10);
        let a = random_spd(10, &mut rng);
        let inv = spd_inverse(&a).unwrap();
        let prod = matmul_nt(&a, &inv.transpose()); // a · inv
        for r in 0..10 {
            for c in 0..10 {
                let expect = if r == c { 1.0 } else { 0.0 };
                assert!((prod.at(r, c) - expect).abs() < 1e-3, "({r},{c})");
            }
        }
    }

    #[test]
    fn gptq_hinv_cholesky_is_upper() {
        let mut rng = Rng::new(11);
        let h = random_spd(6, &mut rng);
        let u = gptq_hinv_cholesky(&h, 0.01).unwrap();
        for r in 1..6 {
            for c in 0..r {
                assert_eq!(u.at(r, c), 0.0, "not upper at ({r},{c})");
            }
        }
        assert!(u.at(0, 0) > 0.0);
    }

    #[test]
    fn gptq_hinv_handles_dead_columns() {
        // zero diagonal entry (dead input channel) must not break
        let mut h = Matrix::zeros(4, 4);
        for i in 0..3 {
            *h.at_mut(i, i) = 2.0;
        }
        let u = gptq_hinv_cholesky(&h, 0.01).unwrap();
        assert!(u.data.iter().all(|v| v.is_finite()));
    }
}
