//! Minimal strict JSON implementation (RFC 8259 subset: numbers parsed as
//! f64; `\uXXXX` escapes combine surrogate pairs and reject lone
//! surrogates).
//!
//! Used for: model/deployment configs, allocation-plan dumps (Table 7),
//! experiment records in EXPERIMENTS.md generation, and coordinator metrics.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so dumps are
/// deterministic and diffable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ----- constructors -----
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ----- accessors -----
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field helpers that produce a useful error message.
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing/ill-typed number field '{key}'"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing/ill-typed integer field '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/ill-typed string field '{key}'"))
    }

    // ----- parsing -----
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ----- writing -----
    /// Compact single-line encoding.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty-printed encoding with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x.fract() == 0.0 && x.abs() < 1e15 {
            let _ = fmt::Write::write_fmt(out, format_args!("{}", x as i64));
        } else {
            let _ = fmt::Write::write_fmt(out, format_args!("{x}"));
        }
    } else {
        // JSON has no NaN/Inf; emit null (documented lossy behaviour)
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Recursion cap for nested arrays/objects. The parser recurses once per
/// nesting level, so without a cap a small hostile body (`[[[[…`) can
/// overflow the stack of whatever thread parses it — HTTP handler
/// threads run on deliberately small stacks.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    /// Parse exactly four hex digits starting at byte offset `at`.
    fn hex4(&self, at: usize) -> Result<u32, JsonError> {
        if at + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[at..at + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        if !hex.bytes().all(|c| c.is_ascii_hexdigit()) {
            return Err(self.err("bad \\u escape"));
        }
        u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        Ok(())
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        self.enter()?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        self.enter()?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.hex4(self.pos + 1)?;
                            match cp {
                                0xD800..=0xDBFF => {
                                    // High surrogate: must be immediately
                                    // followed by an escaped low surrogate.
                                    if self.b.get(self.pos + 5) != Some(&b'\\')
                                        || self.b.get(self.pos + 6) != Some(&b'u')
                                    {
                                        return Err(self.err("unpaired high surrogate"));
                                    }
                                    let low = self.hex4(self.pos + 7)?;
                                    if !(0xDC00..=0xDFFF).contains(&low) {
                                        return Err(self.err("unpaired high surrogate"));
                                    }
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (low - 0xDC00);
                                    s.push(char::from_u32(combined).expect("valid supplementary"));
                                    self.pos += 10;
                                }
                                0xDC00..=0xDFFF => {
                                    return Err(self.err("unpaired low surrogate"));
                                }
                                _ => {
                                    s.push(char::from_u32(cp).expect("non-surrogate BMP scalar"));
                                    self.pos += 4;
                                }
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a":1,"b":[true,null,"x\ny"],"c":{"d":-2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01abc").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("[1] extra").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v, Json::Str("Aé".to_string()));
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v, Json::Str("Aé".to_string()));
    }

    #[test]
    fn surrogate_pairs_combine() {
        // U+1F600 GRINNING FACE via its UTF-16 escape pair.
        let v = Json::parse(r#""\uD83D\uDE00""#).unwrap();
        assert_eq!(v, Json::Str("\u{1F600}".to_string()));
        // Pair at the end of a longer string, mixed-case hex digits.
        let v = Json::parse(r#""x\ud83d\ude00""#).unwrap();
        assert_eq!(v, Json::Str("x\u{1F600}".to_string()));
    }

    #[test]
    fn lone_surrogates_rejected() {
        assert!(Json::parse(r#""\ud83d""#).is_err()); // high, nothing after
        assert!(Json::parse(r#""\ud83d!""#).is_err()); // high, raw char after
        assert!(Json::parse(r#""\ud83dA""#).is_err()); // high + non-low
        assert!(Json::parse(r#""\ude00""#).is_err()); // bare low
        assert!(Json::parse(r#""\ud83d\ud83d""#).is_err()); // high + high
    }

    #[test]
    fn nesting_depth_capped() {
        // A deep-but-legal document parses…
        let deep = "[".repeat(100) + &"]".repeat(100);
        assert!(Json::parse(&deep).is_ok());
        // …a hostile one errors instead of overflowing the stack.
        let hostile = "[".repeat(100_000);
        let err = Json::parse(&hostile).unwrap_err();
        assert!(err.to_string().contains("nesting too deep"));
        let hostile_obj = r#"{"a":"#.repeat(100_000) + "1";
        assert!(Json::parse(&hostile_obj).is_err());
        // depth is released on the way out: siblings at depth 1 don't
        // accumulate
        let wide = "[".to_string() + &"[],".repeat(300) + "[]]";
        assert!(Json::parse(&wide).is_ok());
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("name", Json::str("mxmoe")),
            ("bits", Json::arr([Json::num(2.0), Json::num(4.0)])),
        ]);
        let pretty = v.pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn integers_dump_without_fraction() {
        assert_eq!(Json::num(3.0).dump(), "3");
        assert_eq!(Json::num(3.5).dump(), "3.5");
    }

    #[test]
    fn req_helpers() {
        let v = Json::parse(r#"{"n":4,"s":"x"}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 4);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert!(v.req_f64("missing").is_err());
    }
}
