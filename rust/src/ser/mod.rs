//! Serialization substrates (offline environment: no serde).
//!
//! * [`json`] — a small, strict JSON value model + parser + writer used for
//!   configs, allocation plans, and experiment records.
//! * [`jsonwire`] — an incremental, ASCII-safe JSON writer for the HTTP
//!   front door's streaming wire format (DESIGN.md §HTTP-Front-Door).
//! * [`mxt`] — the MXT binary tensor container: the interchange format
//!   between the build-time Python side (`python/compile/io_mxt.py`) and the
//!   rust runtime (trained weights, calibration corpora).

pub mod json;
pub mod jsonwire;
pub mod mxt;

pub use json::Json;
pub use jsonwire::JsonWriter;
pub use mxt::{MxtFile, MxtTensor};
