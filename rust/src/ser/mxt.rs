//! MXT — the minimal tensor container shared between the build-time Python
//! side and the rust runtime.
//!
//! Layout (all little-endian):
//! ```text
//! magic   b"MXT1"
//! u32     tensor count
//! per tensor:
//!   u32       name length, then UTF-8 name bytes
//!   u8        dtype  (0 = f32, 1 = i8, 2 = i32, 3 = u8)
//!   u32       ndim, then u64 × ndim shape
//!   u64       payload length in bytes, then payload
//! ```
//! Python writer: `python/compile/io_mxt.py` (kept byte-compatible by the
//! integration test `tests/mxt_roundtrip.rs` + `python/tests/test_io_mxt.py`).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 4] = b"MXT1";

/// Element type of an [`MxtTensor`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I8,
    I32,
    U8,
}

impl Dtype {
    fn code(self) -> u8 {
        match self {
            Dtype::F32 => 0,
            Dtype::I8 => 1,
            Dtype::I32 => 2,
            Dtype::U8 => 3,
        }
    }

    fn from_code(c: u8) -> Result<Dtype> {
        Ok(match c {
            0 => Dtype::F32,
            1 => Dtype::I8,
            2 => Dtype::I32,
            3 => Dtype::U8,
            _ => bail!("unknown MXT dtype code {c}"),
        })
    }

    pub fn size(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::I8 | Dtype::U8 => 1,
        }
    }
}

/// One named tensor: shape + raw little-endian payload.
#[derive(Clone, Debug)]
pub struct MxtTensor {
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl MxtTensor {
    pub fn from_f32(shape: Vec<usize>, values: &[f32]) -> MxtTensor {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        MxtTensor { dtype: Dtype::F32, shape, data }
    }

    pub fn from_i32(shape: Vec<usize>, values: &[i32]) -> MxtTensor {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        MxtTensor { dtype: Dtype::I32, shape, data }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn to_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != Dtype::F32 {
            bail!("tensor is {:?}, expected F32", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn to_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != Dtype::I32 {
            bail!("tensor is {:?}, expected I32", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// A parsed MXT file: an ordered map of named tensors.
#[derive(Clone, Debug, Default)]
pub struct MxtFile {
    pub tensors: BTreeMap<String, MxtTensor>,
}

impl MxtFile {
    pub fn new() -> MxtFile {
        MxtFile::default()
    }

    pub fn insert(&mut self, name: &str, t: MxtTensor) {
        self.tensors.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Result<&MxtTensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("MXT tensor '{name}' not found"))
    }

    /// Convenience: fetch a named tensor as f32 values + shape.
    pub fn f32(&self, name: &str) -> Result<(Vec<usize>, Vec<f32>)> {
        let t = self.get(name)?;
        Ok((t.shape.clone(), t.to_f32()?))
    }

    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        w.write_all(MAGIC)?;
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in &self.tensors {
            let expected = t.numel() * t.dtype.size();
            if t.data.len() != expected {
                bail!("tensor '{name}': payload {} != shape implies {expected}", t.data.len());
            }
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            w.write_all(&[t.dtype.code()])?;
            w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for &d in &t.shape {
                w.write_all(&(d as u64).to_le_bytes())?;
            }
            w.write_all(&(t.data.len() as u64).to_le_bytes())?;
            w.write_all(&t.data)?;
        }
        Ok(())
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("create {path:?}"))?,
        );
        self.write_to(&mut f)?;
        Ok(())
    }

    pub fn read_from<R: Read>(r: &mut R) -> Result<MxtFile> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic).context("read MXT magic")?;
        if &magic != MAGIC {
            bail!("bad MXT magic {magic:?}");
        }
        let count = read_u32(r)? as usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            let name_len = read_u32(r)? as usize;
            if name_len > 1 << 16 {
                bail!("unreasonable MXT name length {name_len}");
            }
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let name = String::from_utf8(name).context("MXT name utf-8")?;
            let mut code = [0u8; 1];
            r.read_exact(&mut code)?;
            let dtype = Dtype::from_code(code[0])?;
            let ndim = read_u32(r)? as usize;
            if ndim > 8 {
                bail!("unreasonable MXT rank {ndim}");
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u64(r)? as usize);
            }
            let len = read_u64(r)? as usize;
            let expected = shape.iter().product::<usize>() * dtype.size();
            if len != expected {
                bail!("tensor '{name}': payload {len} != shape implies {expected}");
            }
            let mut data = vec![0u8; len];
            r.read_exact(&mut data)?;
            tensors.insert(name, MxtTensor { dtype, shape, data });
        }
        Ok(MxtFile { tensors })
    }

    pub fn load(path: &Path) -> Result<MxtFile> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {path:?}"))?,
        );
        MxtFile::read_from(&mut f)
    }
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_in_memory() {
        let mut f = MxtFile::new();
        f.insert("w", MxtTensor::from_f32(vec![2, 3], &[1.0, -2.0, 3.5, 0.0, 1e-7, 9.0]));
        f.insert("ids", MxtTensor::from_i32(vec![4], &[1, -1, 7, 0]));
        let mut buf = Vec::new();
        f.write_to(&mut buf).unwrap();
        let g = MxtFile::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(g.tensors.len(), 2);
        let (shape, vals) = g.f32("w").unwrap();
        assert_eq!(shape, vec![2, 3]);
        assert_eq!(vals, vec![1.0, -2.0, 3.5, 0.0, 1e-7, 9.0]);
        assert_eq!(g.get("ids").unwrap().to_i32().unwrap(), vec![1, -1, 7, 0]);
    }

    #[test]
    fn rejects_bad_magic() {
        let buf = b"NOPE\x00\x00\x00\x00".to_vec();
        assert!(MxtFile::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn rejects_shape_payload_mismatch() {
        let mut f = MxtFile::new();
        f.insert(
            "w",
            MxtTensor { dtype: Dtype::F32, shape: vec![3], data: vec![0u8; 4] },
        );
        let mut buf = Vec::new();
        assert!(f.write_to(&mut buf).is_err());
    }

    #[test]
    fn empty_file_roundtrips() {
        let f = MxtFile::new();
        let mut buf = Vec::new();
        f.write_to(&mut buf).unwrap();
        let g = MxtFile::read_from(&mut buf.as_slice()).unwrap();
        assert!(g.tensors.is_empty());
    }
}
