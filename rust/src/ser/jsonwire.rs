//! Incremental JSON wire writer (DESIGN.md §HTTP-Front-Door).
//!
//! [`crate::ser::Json::dump`] builds a value tree and then serializes it —
//! fine for configs and reports, wasteful on the token-streaming hot path
//! where the HTTP front door emits one small event object per generated
//! token across thousands of live connections. [`JsonWriter`] is the
//! streaming complement: push-style begin/key/value calls appending
//! straight into a reusable buffer, one allocation amortized across a
//! whole connection.
//!
//! Escaping is stricter than the tree writer's: the wire output is
//! **ASCII-safe**. Every control character becomes `\uXXXX` (or the short
//! `\n`/`\r`/`\t` forms), and every non-ASCII scalar is escaped too —
//! BMP chars as one `\uXXXX`, astral-plane chars as a UTF-16 surrogate
//! pair (`\ud83d\ude00` for U+1F600). The emitted bytes are therefore 7-bit clean:
//! immune to transport re-encoding, safe to embed in SSE `data:` lines
//! (no raw newlines can appear inside a string), and exactly inverse to
//! the strict surrogate-pair parsing in [`crate::ser::json`].

use std::fmt::Write as _;

/// One open container on the writer stack.
#[derive(Clone, Copy, PartialEq)]
enum Frame {
    /// Object: commas are emitted by [`JsonWriter::key`].
    Obj { first: bool },
    /// Array: commas are emitted before each value.
    Arr { first: bool },
}

/// Push-style JSON writer over a reusable `String` buffer.
///
/// Usage: `begin_obj` / `key` + one value call / `end_obj`, then
/// [`JsonWriter::finish`] to borrow the bytes. [`JsonWriter::reset`]
/// clears the buffer for the next message without freeing it.
///
/// Misuse (a value where a key is required, unbalanced `end_*`) panics:
/// the server composes messages from static shapes, so a malformed
/// emission is a programming error, not an input error.
pub struct JsonWriter {
    out: String,
    stack: Vec<Frame>,
}

impl Default for JsonWriter {
    fn default() -> Self {
        Self::new()
    }
}

impl JsonWriter {
    pub fn new() -> Self {
        JsonWriter { out: String::with_capacity(256), stack: Vec::with_capacity(8) }
    }

    /// Clear the buffer for the next message, keeping its allocation.
    pub fn reset(&mut self) {
        self.out.clear();
        self.stack.clear();
    }

    /// Borrow the finished message. Panics if a container is still open.
    pub fn finish(&self) -> &str {
        assert!(self.stack.is_empty(), "JsonWriter: unclosed container");
        &self.out
    }

    // ----- containers -----

    pub fn begin_obj(&mut self) {
        self.value_prelude();
        self.out.push('{');
        self.stack.push(Frame::Obj { first: true });
    }

    pub fn end_obj(&mut self) {
        match self.stack.pop() {
            Some(Frame::Obj { .. }) => self.out.push('}'),
            _ => panic!("JsonWriter: end_obj without open object"),
        }
    }

    pub fn begin_arr(&mut self) {
        self.value_prelude();
        self.out.push('[');
        self.stack.push(Frame::Arr { first: true });
    }

    pub fn end_arr(&mut self) {
        match self.stack.pop() {
            Some(Frame::Arr { .. }) => self.out.push(']'),
            _ => panic!("JsonWriter: end_arr without open array"),
        }
    }

    /// Object key; must be followed by exactly one value call.
    pub fn key(&mut self, k: &str) {
        match self.stack.last_mut() {
            Some(Frame::Obj { first }) => {
                if !*first {
                    self.out.push(',');
                }
                *first = false;
            }
            _ => panic!("JsonWriter: key outside object"),
        }
        escape_into(&mut self.out, k);
        self.out.push(':');
    }

    // ----- scalar values -----

    pub fn str_val(&mut self, s: &str) {
        self.value_prelude();
        escape_into(&mut self.out, s);
    }

    pub fn u64_val(&mut self, x: u64) {
        self.value_prelude();
        let _ = write!(self.out, "{x}");
    }

    pub fn f64_val(&mut self, x: f64) {
        self.value_prelude();
        if x.is_finite() {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                let _ = write!(self.out, "{}", x as i64);
            } else {
                let _ = write!(self.out, "{x}");
            }
        } else {
            // JSON has no NaN/Inf; same lossy rule as the tree writer.
            self.out.push_str("null");
        }
    }

    pub fn bool_val(&mut self, b: bool) {
        self.value_prelude();
        self.out.push_str(if b { "true" } else { "false" });
    }

    pub fn null_val(&mut self) {
        self.value_prelude();
        self.out.push_str("null");
    }

    // ----- key+value shorthands -----

    pub fn field_str(&mut self, k: &str, v: &str) {
        self.key(k);
        self.str_val(v);
    }

    pub fn field_u64(&mut self, k: &str, v: u64) {
        self.key(k);
        self.u64_val(v);
    }

    pub fn field_f64(&mut self, k: &str, v: f64) {
        self.key(k);
        self.f64_val(v);
    }

    pub fn field_bool(&mut self, k: &str, v: bool) {
        self.key(k);
        self.bool_val(v);
    }

    /// Comma separation for a value in array context. Object values are
    /// separated by [`JsonWriter::key`]; a bare top-level value needs
    /// nothing.
    fn value_prelude(&mut self) {
        if let Some(Frame::Arr { first }) = self.stack.last_mut() {
            if !*first {
                self.out.push(',');
            }
            *first = false;
        }
    }
}

/// Append `s` as a quoted JSON string with ASCII-safe escaping: control
/// chars and every non-ASCII scalar as `\uXXXX`, astral-plane scalars as
/// surrogate pairs. Output contains only printable ASCII.
pub fn escape_into(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{20}'..='\u{7e}' => out.push(c),
            c => {
                let mut units = [0u16; 2];
                for u in c.encode_utf16(&mut units) {
                    let _ = write!(out, "\\u{:04x}", u);
                }
            }
        }
    }
    out.push('"');
}

/// Convenience: escape `s` into a fresh String.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    escape_into(&mut out, s);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ser::Json;

    #[test]
    fn writer_output_parses_back() {
        let mut w = JsonWriter::new();
        w.begin_obj();
        w.field_str("event", "token");
        w.field_u64("index", 3);
        w.key("tokens");
        w.begin_arr();
        w.u64_val(1);
        w.u64_val(2);
        w.end_arr();
        w.field_f64("nll", 0.25);
        w.field_bool("done", false);
        w.key("extra");
        w.null_val();
        w.end_obj();
        let v = Json::parse(w.finish()).unwrap();
        assert_eq!(v.req_str("event").unwrap(), "token");
        assert_eq!(v.req_usize("index").unwrap(), 3);
        assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("done").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("extra"), Some(&Json::Null));
    }

    #[test]
    fn reset_reuses_buffer() {
        let mut w = JsonWriter::new();
        for i in 0..3u64 {
            w.reset();
            w.begin_obj();
            w.field_u64("i", i);
            w.end_obj();
            assert_eq!(w.finish(), format!("{{\"i\":{i}}}"));
        }
    }

    #[test]
    fn nested_arrays_and_objects_separate_correctly() {
        let mut w = JsonWriter::new();
        w.begin_arr();
        w.begin_obj();
        w.field_u64("a", 1);
        w.end_obj();
        w.begin_obj();
        w.field_u64("a", 2);
        w.end_obj();
        w.begin_arr();
        w.end_arr();
        w.end_arr();
        assert_eq!(w.finish(), r#"[{"a":1},{"a":2},[]]"#);
    }

    #[test]
    fn escape_is_ascii_safe_and_roundtrips() {
        // Every control char, the JSON specials, BMP + astral non-ASCII.
        let mut src = String::new();
        for b in 0u8..0x20 {
            src.push(b as char);
        }
        src.push_str("\"\\/ plain ASCII é Ω \u{1F600} \u{10FFFF}");
        let wire = escape(&src);
        assert!(wire.bytes().all(|b| (0x20..0x7f).contains(&b)), "ascii-safe: {wire}");
        let back = Json::parse(&wire).unwrap();
        assert_eq!(back, Json::Str(src));
    }

    #[test]
    fn astral_chars_become_surrogate_pairs() {
        assert_eq!(escape("\u{1F600}"), r#""\ud83d\ude00""#);
        assert_eq!(escape("\u{e9}"), r#""\u00e9""#);
    }
}
