//! `serve-http`: stand up a mini-model cluster behind the HTTP streaming
//! front door (DESIGN.md §HTTP-Front-Door) and serve until killed.
//!
//! ```text
//! cargo run --release --bin serve-http -- --replicas 2 --addr 127.0.0.1:8080
//! curl -s localhost:8080/healthz
//! curl -sN localhost:8080/v1/generate -d '{"tokens":[1,2,3],"max_new_tokens":8}'
//! ```
//!
//! Requires the AOT artifacts (`make artifacts`). Uses the cached
//! `ci-mini` checkpoint when present (`make mini-model`), else a seeded
//! random one — same model-source policy as the scenario engine.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use mxmoe::coordinator::{Cluster, ClusterConfig, ServeConfig};
use mxmoe::harness::{self, mixed_runtime_plan, save_model_mxt, MINI_MODEL_SEED};
use mxmoe::moe::{ModelConfig, MoeLm};
use mxmoe::obs::{SampleConfig, TraceConfig};
use mxmoe::serve::{HttpConfig, HttpServer};
use mxmoe::util::Rng;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// `--key value` pairs, same shape as the `mxmoe` CLI.
struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse() -> Result<Args> {
        let mut it = std::env::args().skip(1);
        let mut flags = HashMap::new();
        while let Some(k) = it.next() {
            if k == "--help" || k == "-h" {
                println!(
                    "serve-http: HTTP front door over a mini-model cluster\n\n\
                     flags:\n  \
                     --addr ADDR             bind address (default 127.0.0.1:8080)\n  \
                     --replicas N            engine replicas (default 2)\n  \
                     --max-connections N     concurrent connection bound (default 2048)\n  \
                     --trace on|off          http-track span collection (default off)\n  \
                     --sample-ms N           observatory sampler interval, ms (default 0 = off)"
                );
                std::process::exit(0);
            }
            let key = k
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got '{k}'"))?
                .to_string();
            let v = it.next().with_context(|| format!("--{key} needs a value"))?;
            flags.insert(key, v);
        }
        Ok(Args { flags })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
            None => Ok(default),
        }
    }
}

/// Same checkpoint policy as the scenario engine: the cached `ci-mini`
/// MXT when built, else a seeded random one written to a temp path.
fn model_source() -> Result<(ModelConfig, PathBuf)> {
    let mini = harness::artifacts_dir().join("model_ci-mini.mxt");
    if mini.exists() {
        let (cfg, _) = harness::load_model("ci-mini")?;
        return Ok((cfg, mini));
    }
    let cfg = ModelConfig::by_name("ci-mini")?;
    let lm = MoeLm::random(&cfg, &mut Rng::new(MINI_MODEL_SEED));
    let path = std::env::temp_dir().join("mxmoe_serve_http.mxt");
    save_model_mxt(&lm, &path)?;
    Ok((cfg, path))
}

fn run() -> Result<()> {
    let args = Args::parse()?;
    let Some(artifacts) = harness::require_artifacts() else {
        bail!("AOT artifacts not built — run `make artifacts` first");
    };
    let addr = args.get("addr", "127.0.0.1:8080");
    let replicas = args.get_usize("replicas", 2)?;
    let max_connections = args.get_usize("max-connections", 2048)?;
    let trace = match args.get("trace", "off").as_str() {
        "on" => TraceConfig::on(),
        "off" => TraceConfig::default(),
        other => bail!("unknown --trace '{other}' (on|off)"),
    };
    let sample = match args.get_usize("sample-ms", 0)? {
        0 => SampleConfig::default(),
        ms => SampleConfig { enabled: true, interval_ms: ms as u64, ..Default::default() },
    };

    let (cfg, weights) = model_source()?;
    eprintln!("starting {replicas}-replica cluster ({})...", cfg.name);
    let cluster = Cluster::start(
        cfg.clone(),
        weights,
        artifacts,
        mixed_runtime_plan(&cfg),
        ClusterConfig {
            replicas,
            serve: ServeConfig {
                max_batch_seqs: 4,
                max_wait: Duration::from_millis(2),
                ..Default::default()
            },
            sample,
            ..Default::default()
        },
    )?;

    let server = HttpServer::start(
        Arc::new(cluster),
        HttpConfig { addr, max_connections, trace, ..HttpConfig::default() },
    )?;
    println!("serving on http://{}", server.addr());
    println!("  GET  /healthz");
    println!("  GET  /metrics");
    println!("  GET  /v1/status");
    println!("  GET  /debug");
    println!("  POST /v1/score          {{\"tokens\":[...]}}");
    println!("  POST /v1/generate       {{\"tokens\":[...],\"max_new_tokens\":N}}  (SSE)");
    println!("  POST /v1/cancel/<id>");
    loop {
        std::thread::park();
    }
}
