//! Hardware specifications of the modeled GPUs.

use crate::quant::scheme::QuantScheme;

/// Public datasheet constants of a target GPU.
#[derive(Clone, Debug)]
pub struct GpuSpec {
    pub name: String,
    /// Streaming multiprocessors (the paper's `P`).
    pub sms: usize,
    /// HBM/GDDR bandwidth, bytes per second.
    pub mem_bw: f64,
    /// Dense fp16 tensor-core throughput, FLOP/s (fp16 accumulate).
    pub fp16_flops: f64,
    /// Dense int8 tensor-core throughput, OP/s.
    pub int8_ops: f64,
    /// Dense int4 throughput, OP/s (0 if unsupported).
    pub int4_ops: f64,
    /// Kernel launch overhead, seconds (sequential-launch penalty).
    pub launch_overhead: f64,
    /// Shared memory per SM, bytes (resource-configuration constraint).
    pub smem_per_sm: usize,
    /// Max warps per SM.
    pub max_warps: usize,
}

impl GpuSpec {
    /// Nvidia RTX 4090 (AD102): the paper's testbed.
    pub fn rtx4090() -> GpuSpec {
        GpuSpec {
            name: "rtx4090".into(),
            sms: 128,
            mem_bw: 1.008e12,
            fp16_flops: 165.2e12,
            int8_ops: 660.6e12,
            int4_ops: 1321.2e12,
            launch_overhead: 4e-6,
            smem_per_sm: 100 * 1024,
            max_warps: 48,
        }
    }

    /// Nvidia A100-SXM4-80G (no int4 tensor-core path exposed by the paper's
    /// kernel set; FP8 unsupported — §4.2.1's example).
    pub fn a100() -> GpuSpec {
        GpuSpec {
            name: "a100".into(),
            sms: 108,
            mem_bw: 2.039e12,
            fp16_flops: 312e12,
            int8_ops: 624e12,
            int4_ops: 1248e12,
            launch_overhead: 4e-6,
            smem_per_sm: 164 * 1024,
            max_warps: 64,
        }
    }

    /// Peak MAC throughput (OP/s, counting mul+add as 2 ops) of the
    /// arithmetic path a scheme executes on.
    pub fn peak_ops(&self, s: &QuantScheme) -> f64 {
        if s.weight_only() || s.is_fp16() {
            // weight-only dequantizes to fp16 and uses the fp16 pipeline
            self.fp16_flops
        } else if s.wbits <= 4 && s.abits <= 4 && self.int4_ops > 0.0 {
            self.int4_ops
        } else {
            // 5–8 bit weight-activation runs on the int8 path
            self.int8_ops
        }
    }

    /// Per-SM share of peak compute for a scheme.
    pub fn sm_ops(&self, s: &QuantScheme) -> f64 {
        self.peak_ops(s) / self.sms as f64
    }

    /// Per-SM share of memory bandwidth when all SMs stream concurrently.
    pub fn sm_bw(&self) -> f64 {
        self.mem_bw / self.sms as f64
    }
}

/// Bytes moved by a GEMM `[m, n, k]` under scheme `s`: quantized weights
/// (+ per-group metadata), activations at their own precision, fp16 output.
pub fn gemm_bytes(s: &QuantScheme, m: usize, n: usize, k: usize) -> f64 {
    let w_bytes = s.avg_weight_bits(k) / 8.0 * (n * k) as f64;
    let a_bytes = s.avg_act_bits(k) / 8.0 * (m * k) as f64;
    let o_bytes = 2.0 * (m * n) as f64;
    w_bytes + a_bytes + o_bytes
}

/// MAC operations of a GEMM (×2 for multiply-add).
pub fn gemm_ops(m: usize, n: usize, k: usize) -> f64 {
    2.0 * (m as f64) * (n as f64) * (k as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_to_pipeline_mapping() {
        let g = GpuSpec::rtx4090();
        assert_eq!(g.peak_ops(&QuantScheme::FP16), g.fp16_flops);
        assert_eq!(g.peak_ops(&QuantScheme::W4A16), g.fp16_flops);
        assert_eq!(g.peak_ops(&QuantScheme::W8A8), g.int8_ops);
        assert_eq!(g.peak_ops(&QuantScheme::W4A4), g.int4_ops);
        assert_eq!(g.peak_ops(&QuantScheme::W5A5), g.int8_ops);
    }

    #[test]
    fn bytes_scale_with_bits() {
        let (m, n, k) = (64, 2816, 2048);
        let b16 = gemm_bytes(&QuantScheme::FP16, m, n, k);
        let b4 = gemm_bytes(&QuantScheme::W4A16, m, n, k);
        // weight-dominated: 4-bit weights ≈ 1/4 the traffic of fp16
        assert!(b4 < 0.35 * b16, "b4 {b4} vs b16 {b16}");
    }

    #[test]
    fn sm_shares_partition_totals() {
        let g = GpuSpec::rtx4090();
        assert!((g.sm_bw() * g.sms as f64 - g.mem_bw).abs() < 1.0);
        assert!((g.sm_ops(&QuantScheme::W8A8) * g.sms as f64 - g.int8_ops).abs() < 1.0);
    }
}
