//! Tile configurations and per-tile cost (`c_t` of §4.2.2).
//!
//! A GEMM `[m, n, k]` is decomposed into CTA tiles `[bm, bn]` sweeping the
//! full `k` (optionally sliced by `slice_k`). Per-tile runtime is the tile
//! roofline: max(compute at the scheme's MMA efficiency, memory at the
//! per-SM bandwidth share), plus a small fixed scheduling overhead.

use crate::quant::scheme::QuantScheme;

use super::gpu::GpuSpec;
use super::micro::{mma_efficiency, Specialization};

/// A CTA tile configuration (the paper's `t ∈ T`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TileConfig {
    pub bm: usize,
    pub bn: usize,
    pub bk: usize,
    /// k-dimension split factor (slice-K, §4.3): >1 adds parallelism for
    /// small GEMMs at the price of a partial-sum reduction pass.
    pub slice_k: usize,
    /// Warps per CTA — the resource-consistency quantity of Fig. 4.
    pub warps: usize,
}

impl TileConfig {
    /// Shared-memory footprint in bytes: double-buffered A and B panels at
    /// the operand precisions (weight bits) + fp32 accumulator spill space.
    pub fn smem_bytes(&self, s: &QuantScheme) -> usize {
        let w_bits = if s.is_fp16() { 16 } else { s.wbits as usize };
        let a_bits = if s.abits >= 16 { 16 } else { s.abits as usize };
        let a_panel = self.bm * self.bk * a_bits / 8;
        let b_panel = self.bn * self.bk * w_bits / 8;
        2 * (a_panel + b_panel)
    }
}

/// Fixed per-tile scheduling/epilogue overhead (seconds).
const TILE_OVERHEAD: f64 = 0.4e-6;
/// Extra cost factor of the slice-K partial-sum reduction.
const SLICE_K_REDUCE: f64 = 0.06;

/// Candidate tile configurations for a scheme — mirrors the shapes the
/// paper's generator emits (weight-only kernels favour skinny `bm`,
/// weight-activation kernels favour large square tiles; group-128 schemes
/// cannot use `bk > 128`).
pub fn tile_candidates(s: &QuantScheme) -> Vec<TileConfig> {
    let mut out = Vec::new();
    let bks: &[usize] = if s.wgroup > 0 { &[64, 128] } else { &[64, 128, 256] };
    let shapes: &[(usize, usize, usize)] = if s.weight_only() && !s.is_fp16() {
        // low-m friendly shapes (decode/memory-bound regime)
        &[(16, 128, 4), (32, 128, 4), (64, 128, 4), (64, 256, 8), (128, 128, 8)]
    } else {
        &[(64, 128, 4), (128, 128, 8), (128, 256, 8), (64, 64, 4), (256, 128, 8)]
    };
    for &(bm, bn, warps) in shapes {
        for &bk in bks {
            for slice_k in [1usize, 2, 4] {
                out.push(TileConfig { bm, bn, bk, slice_k, warps });
            }
        }
    }
    out
}

/// Compute-time (seconds, on one SM) and HBM bytes of ONE tile of a GEMM
/// `[m, n, k]` under `s` with configuration `t`. The tile computes a
/// `[bm, bn]` output block over `k / slice_k` of the reduction dimension.
/// The simulator combines these under a launch-level roofline; the scalar
/// [`tile_cost`] below is the ILP's `c_t`.
pub fn tile_compute_bytes(
    gpu: &GpuSpec,
    s: &QuantScheme,
    t: &TileConfig,
    k: usize,
    spec: Specialization,
) -> (f64, f64) {
    let keff = (k as f64 / t.slice_k as f64).max(1.0);
    let ops = 2.0 * t.bm as f64 * t.bn as f64 * keff;
    let compute = ops / (gpu.sm_ops(s) * mma_efficiency(s, spec)) + TILE_OVERHEAD;
    // bytes: weight panel + activation panel + output block (fp16)
    let w_bytes = s.avg_weight_bits(k) / 8.0 * t.bn as f64 * keff;
    let a_bytes = s.avg_act_bits(k) / 8.0 * t.bm as f64 * keff;
    let o_bytes = 2.0 * t.bm as f64 * t.bn as f64;
    let reduce = if t.slice_k > 1 { SLICE_K_REDUCE * o_bytes * t.slice_k as f64 } else { 0.0 };
    (compute, w_bytes + a_bytes + o_bytes + reduce)
}

/// How many SMs' worth of streaming saturates HBM (CUDA microbenchmark
/// folklore: ~8–16 SMs; used as the single-SM bandwidth ceiling).
pub const SATURATING_SMS: f64 = 8.0;

/// Launch-level roofline over a set of tiles `(compute_s, bytes)`:
///
/// * compute term — LPT makespan of per-tile SM-compute costs,
/// * aggregate memory term — total bytes / device bandwidth,
/// * streaming floor — LPT makespan of per-tile bytes at the single-SM
///   streaming ceiling (binds only when the launch underfills the GPU,
///   the sequential-per-expert pathology of §3.3).
pub fn launch_roofline(gpu: &GpuSpec, compute: &[f64], bytes: &[f64]) -> f64 {
    let cmk = crate::sched::lpt_makespan(compute, gpu.sms);
    let total_bytes: f64 = bytes.iter().sum();
    let memory = total_bytes / gpu.mem_bw;
    let sm_max_bw = gpu.mem_bw * SATURATING_SMS / gpu.sms as f64;
    let floor_costs: Vec<f64> = bytes.iter().map(|b| b / sm_max_bw).collect();
    let stream_floor = crate::sched::lpt_makespan(&floor_costs, gpu.sms);
    cmk.max(memory).max(stream_floor)
}

/// Scalar per-tile cost (the ILP's `c_t`, §4.2.2): roofline with the
/// all-SMs-streaming bandwidth share — the regime the approximation
/// `T ≈ Σ c / P` assumes (tile count ≫ SM count).
pub fn tile_cost(
    gpu: &GpuSpec,
    s: &QuantScheme,
    t: &TileConfig,
    k: usize,
    spec: Specialization,
) -> f64 {
    let (compute, bytes) = tile_compute_bytes(gpu, s, t, k, spec);
    compute.max(bytes / gpu.sm_bw())
}

/// Number of tiles a GEMM `[m, n, k]` decomposes into under `t`.
pub fn tile_count(m: usize, n: usize, t: &TileConfig) -> usize {
    let mt = (m + t.bm - 1) / t.bm;
    let nt = (n + t.bn - 1) / t.bn;
    mt * nt * t.slice_k
}

/// Best (total-cost, config) for a GEMM `[m, n, k]` under scheme `s`,
/// optionally restricted to configs with exactly `warps` warps per CTA
/// (the fused-kernel resource-consistency constraint).
pub fn best_tile(
    gpu: &GpuSpec,
    s: &QuantScheme,
    m: usize,
    n: usize,
    k: usize,
    warps: Option<usize>,
    spec: Specialization,
) -> (f64, TileConfig) {
    let mut best: Option<(f64, TileConfig)> = None;
    for t in tile_candidates(s) {
        if let Some(w) = warps {
            if t.warps != w {
                continue;
            }
        }
        if t.smem_bytes(s) > gpu.smem_per_sm {
            continue;
        }
        let total = tile_cost(gpu, s, &t, k, spec) * tile_count(m, n, &t) as f64;
        if best.map_or(true, |(c, _)| total < c) {
            best = Some((total, t));
        }
    }
    best.expect("no feasible tile config")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidates_respect_group_constraint() {
        for t in tile_candidates(&QuantScheme::W4A4G128) {
            assert!(t.bk <= 128, "group-128 scheme cannot tile bk={}", t.bk);
        }
        assert!(tile_candidates(&QuantScheme::W4A4).iter().any(|t| t.bk == 256));
    }

    #[test]
    fn cost_increases_with_k() {
        let g = GpuSpec::rtx4090();
        let t = TileConfig { bm: 128, bn: 128, bk: 64, slice_k: 1, warps: 8 };
        let c1 = tile_cost(&g, &QuantScheme::W8A8, &t, 1024, Specialization::Specialized);
        let c2 = tile_cost(&g, &QuantScheme::W8A8, &t, 4096, Specialization::Specialized);
        assert!(c2 > c1 * 3.0);
    }

    #[test]
    fn small_m_prefers_weight_only_small_bm() {
        // memory-bound: the chosen tile for m=16 should have small bm
        let g = GpuSpec::rtx4090();
        let (_, t) = best_tile(&g, &QuantScheme::W4A16, 16, 2816, 2048, None, Specialization::Specialized);
        assert!(t.bm <= 32, "chose bm={}", t.bm);
    }

    #[test]
    fn slice_k_helps_tiny_gemm_total_tiles() {
        // slice-K multiplies the tile count, providing SM parallelism
        let t1 = TileConfig { bm: 64, bn: 128, bk: 64, slice_k: 1, warps: 4 };
        let t4 = TileConfig { slice_k: 4, ..t1 };
        assert_eq!(tile_count(64, 128, &t1), 1);
        assert_eq!(tile_count(64, 128, &t4), 4);
    }

    #[test]
    fn padding_waste_appears_in_tile_count() {
        let t = TileConfig { bm: 128, bn: 128, bk: 64, slice_k: 1, warps: 8 };
        assert_eq!(tile_count(1, 128, &t), 1); // 1 token still costs a full tile
        assert_eq!(tile_count(129, 128, &t), 2);
    }

    #[test]
    fn smem_fits_on_4090() {
        let g = GpuSpec::rtx4090();
        for s in [QuantScheme::FP16, QuantScheme::W4A4, QuantScheme::W8A8] {
            let (_, t) = best_tile(&g, &s, 512, 2816, 2048, None, Specialization::Specialized);
            assert!(t.smem_bytes(&s) <= g.smem_per_sm);
        }
    }
}
