//! Micro-kernel efficiency model (App. A.2, Tab. 6).
//!
//! A tuned GEMM kernel achieves only a fraction of datasheet peak; how big
//! a fraction depends on the dequant/rescale work fused into the MAC loop
//! and on whether the kernel is *specialized* for one quantization scheme or
//! *unified* across several. Unification costs twice (App. A.2):
//!
//! 1. runtime condition checks in the MAC loop prevent full unrolling
//!    (`BRANCH_PENALTY`), and
//! 2. the group-size-constrained pipeline forbids the larger `tile_k`
//!    configurations, cutting software-pipelining depth
//!    (`PIPELINE_CONSTRAINT_PENALTY`, only for group-quantized schemes).
//!
//! The base efficiencies are standard achieved/peak ratios for tuned
//! CUTLASS/Marlin-class kernels; the penalties are chosen from the pipeline
//! reasoning above. Together they reproduce the *shape* of Tab. 6
//! (specialized ≫ unified, and group-128 hit hardest) without copying its
//! absolute numbers.

use crate::quant::scheme::QuantScheme;

/// Specialized (per-scheme micro-kernel) vs unified (one kernel for all).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Specialization {
    Specialized,
    Unified,
}

/// Loss from un-unrollable runtime branches in the MAC loop.
const BRANCH_PENALTY: f64 = 0.87;
/// Loss from the restricted tile-k / pipeline depth under unification.
const PIPELINE_CONSTRAINT_PENALTY: f64 = 0.72;

/// Fraction of `GpuSpec::peak_ops` a tuned kernel achieves in its MAC loop.
pub fn mma_efficiency(s: &QuantScheme, spec: Specialization) -> f64 {
    // base: specialized, compute-bound efficiency
    let group_rescale = s.wgroup > 0 && !s.weight_only();
    let base = if s.is_fp16() {
        0.85 // plain CUTLASS fp16
    } else if s.weight_only() {
        0.80 // fused dequant into fp16 MMA (Marlin-class)
    } else if group_rescale {
        0.52 // per-group int rescale inside the MAC loop (Atom-class)
    } else {
        0.81 // per-channel int MMA, rescale at epilogue
    };
    match spec {
        Specialization::Specialized => base,
        Specialization::Unified => {
            let mut e = base * BRANCH_PENALTY;
            if group_rescale {
                e *= PIPELINE_CONSTRAINT_PENALTY;
            }
            e
        }
    }
}

/// Achieved TOPS for a compute-bound square GEMM (Tab. 6's metric).
pub fn achieved_tops(peak_ops: f64, s: &QuantScheme, spec: Specialization) -> f64 {
    peak_ops * mma_efficiency(s, spec) / 1e12
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::gpu::GpuSpec;

    #[test]
    fn specialized_beats_unified_everywhere() {
        for s in [QuantScheme::W4A4, QuantScheme::W4A4G128, QuantScheme::W8A8, QuantScheme::W4A16] {
            assert!(
                mma_efficiency(&s, Specialization::Specialized)
                    > mma_efficiency(&s, Specialization::Unified),
                "{s}"
            );
        }
    }

    #[test]
    fn group128_pays_double_penalty() {
        let pc_ratio = mma_efficiency(&QuantScheme::W4A4, Specialization::Unified)
            / mma_efficiency(&QuantScheme::W4A4, Specialization::Specialized);
        let g_ratio = mma_efficiency(&QuantScheme::W4A4G128, Specialization::Unified)
            / mma_efficiency(&QuantScheme::W4A4G128, Specialization::Specialized);
        assert!(g_ratio < pc_ratio, "group kernels must degrade more under unification");
    }

    #[test]
    fn table6_shape_holds() {
        // paper Tab. 6 (RTX-4090, [8192³]): specialized per-channel ≈ 1070
        // TOPS, g128 ≈ 667; unified ≈ 929 / 412. We require the same ordering
        // and roughly the same ratios (±25%).
        let g = GpuSpec::rtx4090();
        let pc_s = achieved_tops(g.int4_ops, &QuantScheme::W4A4, Specialization::Specialized);
        let pc_u = achieved_tops(g.int4_ops, &QuantScheme::W4A4, Specialization::Unified);
        let g_s = achieved_tops(g.int4_ops, &QuantScheme::W4A4G128, Specialization::Specialized);
        let g_u = achieved_tops(g.int4_ops, &QuantScheme::W4A4G128, Specialization::Unified);
        assert!(pc_s > pc_u && pc_u > g_s && g_s > g_u, "{pc_s} {pc_u} {g_s} {g_u}");
        let close = |x: f64, r: f64| (x / r - 1.0).abs() < 0.25;
        assert!(close(pc_s, 1070.5), "pc specialized {pc_s}");
        assert!(close(pc_u, 929.2), "pc unified {pc_u}");
        assert!(close(g_s, 667.3), "g128 specialized {g_s}");
        assert!(close(g_u, 412.0), "g128 unified {g_u}");
    }
}
