//! GPU cost model: hardware specs, roofline analysis, tile-level cost
//! estimation and the micro-kernel efficiency model.
//!
//! This is the performance-side substitution for the paper's RTX-4090
//! testbed (DESIGN.md §2): tile costs are derived analytically from public
//! hardware constants (bandwidth, tensor-core throughput per precision, SM
//! count) instead of on-device profiling. The model reproduces the paper's
//! roofline crossovers (W4A16 vs W8A8 at A≈83, W2A16 vs W4A4 at A≈42 —
//! verified by unit tests in `roofline.rs`), which is the property the
//! bitwidth allocator actually depends on.

pub mod gpu;
pub mod micro;
pub mod roofline;
pub mod tile;

pub use gpu::GpuSpec;
pub use micro::{mma_efficiency, Specialization};
pub use roofline::{crossover_m, gemm_time, preferred_scheme};
pub use tile::{tile_cost, tile_candidates, TileConfig};
