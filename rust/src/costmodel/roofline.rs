//! Whole-GEMM roofline analysis (§3.2, Fig. 1b left).
//!
//! For `[m, n, k]` with `n, k ≫ m`, arithmetic intensity reduces to
//! `A ≈ m`; the preferred scheme flips from weight-only (memory-bound
//! regime) to weight-activation (compute-bound regime) at a crossover `m`.
//! The tests pin the two crossovers the paper reports for the RTX-4090:
//! W4A16 vs W8A8 at A≈83 and W2A16 vs W4A4 at A≈42 — our analytic model
//! lands on both from public datasheet constants alone.

use crate::quant::scheme::QuantScheme;

use super::gpu::{gemm_bytes, gemm_ops, GpuSpec};
use super::micro::{mma_efficiency, Specialization};

/// Whole-GEMM execution time under the roofline, at realistic (tuned-kernel)
/// MMA efficiency.
pub fn gemm_time(gpu: &GpuSpec, s: &QuantScheme, m: usize, n: usize, k: usize) -> f64 {
    let eff = mma_efficiency(s, Specialization::Specialized);
    let compute = gemm_ops(m, n, k) / (gpu.peak_ops(s) * eff);
    let memory = gemm_bytes(s, m, n, k) / gpu.mem_bw;
    compute.max(memory)
}

/// Idealized datasheet roofline (efficiency = 1) — the analysis of Fig. 1b,
/// which is where the paper's A≈83 / A≈42 crossovers come from.
pub fn gemm_time_ideal(gpu: &GpuSpec, s: &QuantScheme, m: usize, n: usize, k: usize) -> f64 {
    let compute = gemm_ops(m, n, k) / gpu.peak_ops(s);
    let memory = gemm_bytes(s, m, n, k) / gpu.mem_bw;
    compute.max(memory)
}

/// Throughput in (fp16-equivalent) TFLOP/s for reporting.
pub fn gemm_tflops(gpu: &GpuSpec, s: &QuantScheme, m: usize, n: usize, k: usize) -> f64 {
    gemm_ops(m, n, k) / gemm_time(gpu, s, m, n, k) / 1e12
}

/// The scheme among `candidates` with the lowest modeled time.
pub fn preferred_scheme<'a>(
    gpu: &GpuSpec,
    candidates: &'a [QuantScheme],
    m: usize,
    n: usize,
    k: usize,
) -> &'a QuantScheme {
    candidates
        .iter()
        .min_by(|a, b| {
            gemm_time(gpu, a, m, n, k)
                .partial_cmp(&gemm_time(gpu, b, m, n, k))
                .unwrap()
        })
        .expect("no candidates")
}

/// Smallest `m` at which `b` becomes at least as fast as `a` on the ideal
/// roofline (`None` if `a` wins over the whole sweep). `n, k` fixed large.
pub fn crossover_m(gpu: &GpuSpec, a: &QuantScheme, b: &QuantScheme, n: usize, k: usize) -> Option<usize> {
    (1..=4096).find(|&m| gemm_time_ideal(gpu, b, m, n, k) <= gemm_time_ideal(gpu, a, m, n, k))
}

#[cfg(test)]
mod tests {
    use super::*;

    const N: usize = 8192;
    const K: usize = 8192;

    #[test]
    fn paper_crossover_w4a16_vs_w8a8() {
        // paper: "W4A16 outperforms W8A8 when A < 83"
        let g = GpuSpec::rtx4090();
        let m = crossover_m(&g, &QuantScheme::W4A16, &QuantScheme::W8A8, N, K)
            .expect("W8A8 must win eventually");
        assert!((70..=95).contains(&m), "crossover at m={m}, paper says ≈83");
    }

    #[test]
    fn paper_crossover_w2a16_vs_w4a4() {
        // paper: "W2A16 outperforms W4A4 when A < 42"
        let g = GpuSpec::rtx4090();
        let m = crossover_m(&g, &QuantScheme::W2A16G128, &QuantScheme::W4A4, N, K)
            .expect("W4A4 must win eventually");
        assert!((34..=50).contains(&m), "crossover at m={m}, paper says ≈42");
    }

    #[test]
    fn memory_bound_regime_prefers_weight_only() {
        let g = GpuSpec::rtx4090();
        let cands = [QuantScheme::W4A16, QuantScheme::W8A8];
        assert_eq!(preferred_scheme(&g, &cands, 8, N, K), &QuantScheme::W4A16);
        assert_eq!(preferred_scheme(&g, &cands, 1024, N, K), &QuantScheme::W8A8);
    }

    #[test]
    fn low_precision_never_slower_at_fixed_path() {
        // W4A4 ≥ W8A8 ≥ FP16 in throughput for compute-bound shapes
        let g = GpuSpec::rtx4090();
        let t4 = gemm_time(&g, &QuantScheme::W4A4, 2048, N, K);
        let t8 = gemm_time(&g, &QuantScheme::W8A8, 2048, N, K);
        let t16 = gemm_time(&g, &QuantScheme::FP16, 2048, N, K);
        assert!(t4 < t8 && t8 < t16);
    }

    #[test]
    fn tflops_bounded_by_peak() {
        let g = GpuSpec::rtx4090();
        for m in [1usize, 16, 128, 2048] {
            let tf = gemm_tflops(&g, &QuantScheme::FP16, m, N, K);
            assert!(tf <= g.fp16_flops / 1e12 + 1e-9);
            assert!(tf > 0.0);
        }
    }
}
