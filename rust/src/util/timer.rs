//! Wall-clock timing helpers for the hand-rolled bench harness.

use std::time::Instant;

use super::stats::Summary;

/// Simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    /// Elapsed seconds since start.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds since start.
    pub fn ms(&self) -> f64 {
        self.secs() * 1e3
    }

    /// Elapsed microseconds since start.
    pub fn us(&self) -> f64 {
        self.secs() * 1e6
    }
}

/// Measure `f` `iters` times after `warmup` unmeasured runs; returns the
/// per-iteration wall-clock summary in **seconds**. Criterion-lite for the
/// `harness = false` bench binaries.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        f();
        samples.push(t.secs());
    }
    Summary::of(&samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.secs();
        let b = t.secs();
        assert!(b >= a);
    }

    #[test]
    fn bench_counts_iters() {
        let mut runs = 0usize;
        let s = bench(2, 5, || runs += 1);
        assert_eq!(runs, 7);
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }
}
