//! Small self-contained utilities: deterministic RNG, timing, summary
//! statistics, and a scoped thread pool.
//!
//! The build environment is offline, so these replace `rand`, `criterion`'s
//! statistics and `rayon` with dependency-free equivalents. All randomness in
//! the library flows through [`Rng`] so experiments are reproducible from a
//! single seed.

pub mod rng;
pub mod stats;
pub mod threadpool;
pub mod timer;

pub use rng::Rng;
pub use stats::Summary;
pub use threadpool::ThreadPool;
pub use timer::Timer;
