//! A minimal scoped work-stealing-free thread pool.
//!
//! Two entry points:
//! * [`ThreadPool::run`] — submit boxed jobs, wait for all to finish
//!   (coordinator worker pool, simulator SM workers).
//! * [`parallel_for`] — data-parallel loop over an index range using scoped
//!   threads (matmul row blocks, calibration batches). No allocation per
//!   element; chunks are balanced statically.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Job(Job),
    Shutdown,
}

/// Long-lived pool of worker threads fed over an mpsc channel.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, std::sync::Condvar)>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> ThreadPool {
        assert!(threads > 0);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let workers = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                thread::spawn(move || loop {
                    let msg = { rx.lock().unwrap().recv() };
                    match msg {
                        Ok(Msg::Job(job)) => {
                            job();
                            let (lock, cv) = &*pending;
                            let mut p = lock.lock().unwrap();
                            *p -= 1;
                            if *p == 0 {
                                cv.notify_all();
                            }
                        }
                        Ok(Msg::Shutdown) | Err(_) => break,
                    }
                })
            })
            .collect();
        ThreadPool { tx, workers, pending }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job; does not block.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx.send(Msg::Job(Box::new(f))).expect("pool closed");
    }

    /// Block until every submitted job has completed.
    pub fn wait(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }

    /// Submit a batch and wait for all of it.
    pub fn run<F: FnOnce() + Send + 'static>(&self, jobs: Vec<F>) {
        for j in jobs {
            self.submit(j);
        }
        self.wait();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Default parallelism: physical cores as reported by the OS, capped so the
/// test environment doesn't oversubscribe.
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Data-parallel `for i in 0..n` with dynamic chunk self-scheduling over
/// scoped threads. `body(i)` must be safe to run concurrently for distinct
/// `i`. Used on the matmul/calibration hot paths; falls back to serial for
/// tiny `n`.
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, body: F) {
    parallel_for_threads(n, default_threads(), body)
}

/// As [`parallel_for_threads`], but each worker thread owns a mutable
/// scratch state built once by `init` and threaded through every index that
/// worker executes. This is the buffer-reuse entry point for the grouped
/// GroupGEMM dispatch (`runtime::dispatch`): a worker pads every tile it
/// runs into the same scratch buffer instead of allocating per tile.
/// Scheduling is the same dynamic chunked self-scheduling as
/// [`parallel_for_threads`]; which worker runs which index is
/// non-deterministic, so `body` must produce results that do not depend on
/// the state's history beyond what `init` established.
pub fn parallel_for_with_state<S, I, F>(n: usize, threads: usize, init: I, body: F)
where
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads == 1 || n < 2 {
        let mut state = init();
        for i in 0..n {
            body(&mut state, i);
        }
        return;
    }
    // chunk ~4 tasks per thread for load balance without contention
    let chunk = ((n + threads * 4 - 1) / (threads * 4)).max(1);
    let counter = AtomicUsize::new(0);
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                let mut state = init();
                loop {
                    let start = counter.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = (start + chunk).min(n);
                    for i in start..end {
                        body(&mut state, i);
                    }
                }
            });
        }
    });
}

/// As [`parallel_for`] with an explicit thread count (benchmarks sweep
/// this). Stateless façade over [`parallel_for_with_state`] so the
/// chunked self-scheduling lives in exactly one place.
pub fn parallel_for_threads<F: Fn(usize) + Sync>(n: usize, threads: usize, body: F) {
    parallel_for_with_state(n, threads, || (), |_, i| body(i));
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        let jobs: Vec<_> = (0..100)
            .map(|_| {
                let c = Arc::clone(&counter);
                move || {
                    c.fetch_add(1, Ordering::SeqCst);
                }
            })
            .collect();
        pool.run(jobs);
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_wait_is_reusable() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for round in 1..=3u64 {
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.wait();
            assert_eq!(counter.load(Ordering::SeqCst), round * 10);
        }
    }

    #[test]
    fn parallel_for_covers_every_index_once() {
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for(n, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_for_with_state_covers_and_reuses() {
        let n = 1000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let inits = AtomicU64::new(0);
        parallel_for_with_state(
            n,
            4,
            || {
                inits.fetch_add(1, Ordering::SeqCst);
                Vec::<u8>::with_capacity(64)
            },
            |scratch, i| {
                scratch.clear();
                scratch.resize(8, 0);
                hits[i].fetch_add(1, Ordering::SeqCst);
            },
        );
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
        // one state per worker, not per index
        assert!(inits.load(Ordering::SeqCst) <= 4);
    }

    #[test]
    fn parallel_for_zero_and_one() {
        parallel_for(0, |_| panic!("must not run"));
        let hit = AtomicU64::new(0);
        parallel_for(1, |i| {
            assert_eq!(i, 0);
            hit.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hit.load(Ordering::SeqCst), 1);
    }
}
