//! Deterministic xoshiro256** RNG.
//!
//! All stochastic components (corpus synthesis, calibration sampling,
//! randomized Hadamard sign flips, property tests) take an explicit [`Rng`]
//! so every experiment in EXPERIMENTS.md is reproducible from its seed.

/// xoshiro256** 1.0 by Blackman & Vigna (public domain reference
/// implementation, ported). High-quality, fast, and dependency-free.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that nearby integer seeds give uncorrelated
    /// streams (the xoshiro authors' recommended seeding procedure).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        Rng { s }
    }

    /// Derive an independent child stream (used to hand per-thread /
    /// per-layer RNGs out of one experiment seed).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.next_f64();
            return (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
        }
    }

    /// Standard normal as f32 (weight init, corpus noise).
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Random sign in `{-1.0, +1.0}` (randomized Hadamard diagonal).
    #[inline]
    pub fn sign(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted sample over zero mass");
        let mut u = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_coverage() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            // each bucket ~10000; allow 10% slack
            assert!((9_000..11_000).contains(&c), "biased bucket: {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weighted_respects_mass() {
        let mut r = Rng::new(13);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.6..3.4).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
