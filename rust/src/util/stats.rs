//! Summary statistics for benchmark/metric reporting (replaces criterion's
//! estimators in this offline environment).

/// Summary of a sample: mean, stddev, min/max and selected percentiles.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of(empty)");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 0.50),
            p90: percentile(&sorted, 0.90),
            p99: percentile(&sorted, 0.99),
        }
    }

    /// An empty summary (identity element of [`Summary::merge`]).
    pub fn empty() -> Summary {
        Summary {
            n: 0,
            mean: 0.0,
            std: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            p50: 0.0,
            p90: 0.0,
            p99: 0.0,
        }
    }

    /// Combine per-replica summaries into a cluster summary without
    /// concatenating raw samples. `n`, `mean`, `std` (via pairwise moment
    /// combination), `min`, `max` are exact; percentiles are the
    /// sample-count-weighted average of the parts' percentiles — an
    /// approximation that is exact when the parts are identically
    /// distributed, documented in DESIGN.md §Observability.
    pub fn merge(parts: &[Summary]) -> Summary {
        let parts: Vec<&Summary> = parts.iter().filter(|s| s.n > 0).collect();
        if parts.is_empty() {
            return Summary::empty();
        }
        let n: usize = parts.iter().map(|s| s.n).sum();
        let mean = parts.iter().map(|s| s.mean * s.n as f64).sum::<f64>() / n as f64;
        // combined M2 = Σ[(nᵢ−1)·stdᵢ² + nᵢ·(meanᵢ−mean)²]
        let m2: f64 = parts
            .iter()
            .map(|s| {
                (s.n.saturating_sub(1)) as f64 * s.std * s.std
                    + s.n as f64 * (s.mean - mean) * (s.mean - mean)
            })
            .sum();
        let std = if n > 1 { (m2 / (n - 1) as f64).sqrt() } else { 0.0 };
        let wavg = |f: fn(&Summary) -> f64| {
            parts.iter().map(|s| f(s) * s.n as f64).sum::<f64>() / n as f64
        };
        Summary {
            n,
            mean,
            std,
            min: parts.iter().map(|s| s.min).fold(f64::INFINITY, f64::min),
            max: parts.iter().map(|s| s.max).fold(f64::NEG_INFINITY, f64::max),
            p50: wavg(|s| s.p50),
            p90: wavg(|s| s.p90),
            p99: wavg(|s| s.p99),
        }
    }
}

/// Linear-interpolated percentile over a pre-sorted slice, `q` in `[0,1]`.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean (used for cross-task accuracy aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile(&sorted, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&sorted, 0.0), 0.0);
        assert_eq!(percentile(&sorted, 1.0), 10.0);
    }

    #[test]
    fn geomean_matches_hand() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn single_sample() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p99, 7.0);
    }

    #[test]
    fn merge_matches_concatenation_on_moments() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [10.0, 20.0, 30.0];
        let merged = Summary::merge(&[Summary::of(&a), Summary::of(&b)]);
        let mut all = a.to_vec();
        all.extend_from_slice(&b);
        let exact = Summary::of(&all);
        assert_eq!(merged.n, exact.n);
        assert!((merged.mean - exact.mean).abs() < 1e-12);
        assert!((merged.std - exact.std).abs() < 1e-12);
        assert_eq!(merged.min, exact.min);
        assert_eq!(merged.max, exact.max);
    }

    #[test]
    fn merge_percentiles_exact_for_identical_parts() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let s = Summary::of(&xs);
        let merged = Summary::merge(&[s.clone(), s.clone(), s.clone()]);
        assert!((merged.p50 - s.p50).abs() < 1e-12);
        assert!((merged.p99 - s.p99).abs() < 1e-12);
    }

    #[test]
    fn merge_skips_empty_parts() {
        let s = Summary::of(&[2.0, 4.0]);
        let merged = Summary::merge(&[Summary::empty(), s.clone()]);
        assert_eq!(merged.n, 2);
        assert!((merged.mean - s.mean).abs() < 1e-12);
        assert_eq!(Summary::merge(&[]).n, 0);
        assert_eq!(Summary::merge(&[Summary::empty()]).n, 0);
    }
}
