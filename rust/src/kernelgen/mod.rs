//! Mixed-precision Group-GEMM execution-plan generation (§4.3).
//!
//! This is the TPU/simulator analogue of the paper's kernel generator: it
//! takes the per-linear-block GEMM problems of an MoE block (shapes from
//! routing, schemes from the allocator) and emits a *fused* tile-task list
//! under the CUDA resource-consistency constraints:
//!
//! * **warp-count consistency** (Fig. 4): every micro-kernel in the fused
//!   launch must use the same warps/CTA — the generator enumerates warp
//!   counts and keeps the cheapest feasible one;
//! * **shared-memory maximum**: the fused launch reserves the max smem of
//!   the selected tile configs (tracked for reporting);
//! * **slice-K**: the tile candidates include k-split variants, which the
//!   per-problem optimizer picks exactly when they pay (small GEMMs).

use crate::costmodel::gpu::GpuSpec;
use crate::costmodel::micro::Specialization;
use crate::costmodel::tile::{
    best_tile, launch_roofline, tile_compute_bytes, tile_cost, tile_count, TileConfig,
};
use crate::quant::scheme::QuantScheme;

/// One linear-block GEMM sub-problem of an MoE block.
#[derive(Clone, Debug)]
pub struct GemmProblem {
    pub expert: usize,
    /// 0 = gate, 1 = up, 2 = down.
    pub linear: usize,
    /// Tokens routed to this expert (`m`).
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub scheme: QuantScheme,
}

/// A scheduled tile task.
#[derive(Clone, Copy, Debug)]
pub struct TileTask {
    pub problem: usize,
    /// Scalar roofline cost (ILP granularity, scheduling key).
    pub cost: f64,
    /// Pure SM-compute seconds (launch-roofline compute term).
    pub compute: f64,
    /// HBM bytes moved (launch-roofline memory term).
    pub bytes: f64,
}

/// A fused (single-launch) execution plan.
#[derive(Clone, Debug)]
pub struct ExecutionPlan {
    pub tiles: Vec<TileTask>,
    /// Chosen tile config per problem.
    pub configs: Vec<TileConfig>,
    /// Warps/CTA shared by every micro-kernel in the launch.
    pub warp_count: usize,
    /// Shared-memory reservation of the fused kernel (max over configs).
    pub smem_bytes: usize,
    /// Kernel launches this plan needs (1 = horizontally fused).
    pub launches: usize,
}

impl ExecutionPlan {
    pub fn total_tile_cost(&self) -> f64 {
        self.tiles.iter().map(|t| t.cost).sum()
    }

    pub fn tile_costs(&self) -> Vec<f64> {
        self.tiles.iter().map(|t| t.cost).collect()
    }

    pub fn total_bytes(&self) -> f64 {
        self.tiles.iter().map(|t| t.bytes).sum()
    }

    pub fn compute_costs(&self) -> Vec<f64> {
        self.tiles.iter().map(|t| t.compute).collect()
    }

    pub fn byte_costs(&self) -> Vec<f64> {
        self.tiles.iter().map(|t| t.bytes).collect()
    }
}

/// Build the expert GEMM problems of one MoE block from per-expert token
/// counts and per-(expert, linear) schemes. `hidden`/`inter` give the
/// gate/up (`[inter, hidden]`) and down (`[hidden, inter]`) shapes.
pub fn moe_problems(
    tokens_per_expert: &[usize],
    schemes: &[[QuantScheme; 3]],
    hidden: usize,
    inter: usize,
) -> Vec<GemmProblem> {
    assert_eq!(tokens_per_expert.len(), schemes.len());
    let mut out = Vec::new();
    for (e, &m) in tokens_per_expert.iter().enumerate() {
        if m == 0 {
            continue;
        }
        for (j, (n, k)) in [(inter, hidden), (inter, hidden), (hidden, inter)].iter().enumerate() {
            out.push(GemmProblem {
                expert: e,
                linear: j,
                m,
                n: *n,
                k: *k,
                scheme: schemes[e][j],
            });
        }
    }
    out
}

/// Candidate warp counts for the fused launch.
const WARP_CHOICES: [usize; 3] = [4, 8, 16];

/// Generate the fused mixed-precision Group-GEMM plan: per-problem optimal
/// tiles under a common warp count, one kernel launch total.
pub fn fused_plan(gpu: &GpuSpec, problems: &[GemmProblem], spec: Specialization) -> ExecutionPlan {
    assert!(!problems.is_empty());
    let mut best: Option<ExecutionPlan> = None;
    for &warps in &WARP_CHOICES {
        let mut tiles = Vec::new();
        let mut configs = Vec::new();
        let mut feasible = true;
        let mut smem = 0usize;
        for (pi, p) in problems.iter().enumerate() {
            // some (scheme, warp) pairs have no candidate: infeasible
            let has = crate::costmodel::tile::tile_candidates(&p.scheme)
                .iter()
                .any(|t| t.warps == warps && t.smem_bytes(&p.scheme) <= gpu.smem_per_sm);
            if !has {
                feasible = false;
                break;
            }
            let (_, cfg) = best_tile(gpu, &p.scheme, p.m, p.n, p.k, Some(warps), spec);
            let per_tile = tile_cost(gpu, &p.scheme, &cfg, p.k, spec);
            let (compute, bytes) = tile_compute_bytes(gpu, &p.scheme, &cfg, p.k, spec);
            let count = tile_count(p.m, p.n, &cfg);
            for _ in 0..count {
                tiles.push(TileTask { problem: pi, cost: per_tile, compute, bytes });
            }
            smem = smem.max(cfg.smem_bytes(&p.scheme));
            configs.push(cfg);
        }
        if !feasible {
            continue;
        }
        let plan = ExecutionPlan { tiles, configs, warp_count: warps, smem_bytes: smem, launches: 1 };
        if best.as_ref().map_or(true, |b| plan.total_tile_cost() < b.total_tile_cost()) {
            best = Some(plan);
        }
    }
    best.expect("no feasible warp count for fused plan")
}

/// Per-problem plans — the sequential baseline (one launch per problem,
/// vLLM-Marlin-MoE style). With only one GEMM per launch, the tile choice
/// must fight GPU underfill, so each problem picks the config minimizing
/// its *launch-level roofline* (Marlin's striped partitioning intent),
/// not the aggregate tile cost.
pub fn sequential_plans(gpu: &GpuSpec, problems: &[GemmProblem], spec: Specialization) -> Vec<ExecutionPlan> {
    problems
        .iter()
        .enumerate()
        .map(|(pi, p)| {
            let mut best: Option<(f64, ExecutionPlan)> = None;
            for cfg in crate::costmodel::tile::tile_candidates(&p.scheme) {
                if cfg.smem_bytes(&p.scheme) > gpu.smem_per_sm {
                    continue;
                }
                let per_tile = tile_cost(gpu, &p.scheme, &cfg, p.k, spec);
                let (compute, bytes) = tile_compute_bytes(gpu, &p.scheme, &cfg, p.k, spec);
                let count = tile_count(p.m, p.n, &cfg);
                let plan = ExecutionPlan {
                    tiles: (0..count)
                        .map(|_| TileTask { problem: pi, cost: per_tile, compute, bytes })
                        .collect(),
                    configs: vec![cfg],
                    warp_count: cfg.warps,
                    smem_bytes: cfg.smem_bytes(&p.scheme),
                    launches: 1,
                };
                let t = launch_roofline(gpu, &plan.compute_costs(), &plan.byte_costs());
                if best.as_ref().map_or(true, |(bt, _)| t < *bt) {
                    best = Some((t, plan));
                }
            }
            best.expect("no feasible tile config").1
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn problems_512() -> Vec<GemmProblem> {
        // Fig. 2 workload: 60 experts, [N,K] = [2816, 2048], 512 tokens top-4
        let tokens = vec![34usize; 60];
        let schemes = vec![[QuantScheme::W4A16; 3]; 60];
        moe_problems(&tokens, &schemes, 2048, 2816)
    }

    #[test]
    fn moe_problems_shapes() {
        let p = problems_512();
        assert_eq!(p.len(), 180);
        assert_eq!((p[0].n, p[0].k), (2816, 2048)); // gate
        assert_eq!((p[2].n, p[2].k), (2048, 2816)); // down
        // zero-token experts vanish
        let mut tokens = vec![8usize; 4];
        tokens[2] = 0;
        let q = moe_problems(&tokens, &vec![[QuantScheme::FP16; 3]; 4], 64, 128);
        assert_eq!(q.len(), 9);
    }

    #[test]
    fn fused_plan_single_launch_uniform_warps() {
        let gpu = GpuSpec::rtx4090();
        let plan = fused_plan(&gpu, &problems_512(), Specialization::Specialized);
        assert_eq!(plan.launches, 1);
        assert!(WARP_CHOICES.contains(&plan.warp_count));
        assert!(plan.tiles.len() > gpu.sms, "tiles should exceed SM count");
        assert!(plan.smem_bytes <= gpu.smem_per_sm);
    }

    #[test]
    fn mixed_precision_fuses() {
        let gpu = GpuSpec::rtx4090();
        let tokens = vec![100usize, 5, 200, 1];
        let schemes = vec![
            [QuantScheme::W8A8; 3],
            [QuantScheme::W4A16; 3],
            [QuantScheme::W4A4; 3],
            [QuantScheme::W2A16G128; 3],
        ];
        let probs = moe_problems(&tokens, &schemes, 2048, 2816);
        let plan = fused_plan(&gpu, &probs, Specialization::Specialized);
        assert_eq!(plan.launches, 1);
        assert_eq!(plan.configs.len(), probs.len());
        // every config shares the warp count
        assert!(plan.configs.iter().all(|c| c.warps == plan.warp_count));
    }

    #[test]
    fn sequential_plans_one_per_problem() {
        let gpu = GpuSpec::rtx4090();
        let probs = problems_512();
        let plans = sequential_plans(&gpu, &probs, Specialization::Specialized);
        assert_eq!(plans.len(), probs.len());
    }

    #[test]
    fn small_gemm_uses_slice_k() {
        // a 1-token expert over a big K: the chosen launch plan must be at
        // least as good as every slice_k = 1 alternative (slice-K exists
        // precisely to parallelize this shape)
        let gpu = GpuSpec::rtx4090();
        let sp = Specialization::Specialized;
        let probs = vec![GemmProblem {
            expert: 0,
            linear: 0,
            m: 1,
            n: 256,
            k: 8192,
            scheme: QuantScheme::W4A16,
        }];
        let plans = sequential_plans(&gpu, &probs, sp);
        let chosen = launch_roofline(&gpu, &plans[0].compute_costs(), &plans[0].byte_costs());
        for cfg in crate::costmodel::tile::tile_candidates(&probs[0].scheme) {
            if cfg.slice_k != 1 {
                continue;
            }
            let (c, b) = tile_compute_bytes(&gpu, &probs[0].scheme, &cfg, probs[0].k, sp);
            let n = tile_count(probs[0].m, probs[0].n, &cfg);
            let t = launch_roofline(&gpu, &vec![c; n], &vec![b; n]);
            assert!(chosen <= t + 1e-12, "chosen {chosen} worse than slice_k=1 cfg {cfg:?} {t}");
        }
    }
}
