//! Synthetic corpus substrate (the WikiText-2 substitution, see DESIGN.md §2).
//!
//! A Zipf-weighted first-order Markov chain over a small vocabulary produces
//! sequences with realistic statistical structure: skewed unigram
//! frequencies, strongly-preferred bigrams, and long-range "topic" drift via
//! regime switching. Mini MoE LMs trained on it develop the expert
//! specialization and heterogeneous activation patterns the paper exploits.

pub mod corpus;

pub use corpus::{Corpus, CorpusSpec};
