//! Zipf–Markov synthetic corpus generator + container.
//!
//! Rust is the source of truth: `mxmoe gen-corpus` writes the corpus (train
//! and validation token streams plus the empirical bigram table) to an MXT
//! file; the JAX trainer (`python/compile/train_lm.py`) and all rust
//! evaluation/calibration paths load the same file, so both sides see
//! exactly the same data.

use anyhow::Result;
use std::path::Path;

use crate::ser::mxt::{MxtFile, MxtTensor};
use crate::util::Rng;

/// Generation parameters.
#[derive(Clone, Debug)]
pub struct CorpusSpec {
    pub vocab: usize,
    /// Latent "topic" regimes; switching creates long-range structure.
    pub regimes: usize,
    /// Zipf exponent of the successor distributions.
    pub zipf_s: f64,
    /// Per-step probability of switching regime.
    pub switch_p: f64,
    pub seed: u64,
}

impl Default for CorpusSpec {
    fn default() -> CorpusSpec {
        CorpusSpec { vocab: 512, regimes: 8, zipf_s: 1.2, switch_p: 0.01, seed: 1234 }
    }
}

/// Generated corpus: token streams + empirical bigram counts.
pub struct Corpus {
    pub spec_vocab: usize,
    pub train: Vec<u32>,
    pub valid: Vec<u32>,
    /// Row-major `[vocab, vocab]` bigram counts over train.
    pub bigram: Vec<u32>,
}

impl Corpus {
    /// Deterministically generate a corpus.
    pub fn generate(spec: &CorpusSpec, train_len: usize, valid_len: usize) -> Corpus {
        let mut rng = Rng::new(spec.seed);
        let v = spec.vocab;
        // Zipf weights over successor *ranks* (shared shape everywhere).
        let zipf: Vec<f64> = (1..=32.min(v)).map(|r| 1.0 / (r as f64).powf(spec.zipf_s)).collect();
        // Global popularity permutation: candidate draws are skewed toward
        // low popularity indices (u³ draw), so unigram frequencies are
        // Zipf-like regardless of regime.
        let pop_perm: Vec<u32> = {
            let mut p: Vec<u32> = (0..v as u32).collect();
            let mut r = Rng::new(spec.seed ^ 0xDEADBEEF);
            r.shuffle(&mut p);
            p
        };
        // Successor draw for (regime, token): pick a Zipf rank, then map it
        // to a stable candidate token. Ranks 0–3 are regime-independent
        // (core bigrams every regime shares, which makes the corpus's top
        // successors strongly predictable); deeper ranks are regime-flavored.
        let succ = |regime: usize, tok: u32, rng: &mut Rng| -> u32 {
            let pick = rng.weighted(&zipf);
            let seed = if pick < 4 {
                spec.seed ^ (tok as u64).wrapping_mul(0xC2B2AE3D27D4EB4F)
            } else {
                spec.seed
                    ^ (tok as u64).wrapping_mul(0xC2B2AE3D27D4EB4F)
                    ^ (regime as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15)
            };
            let mut h = Rng::new(seed);
            // walk `pick+1` skewed draws so each rank maps to a stable token
            let mut cand = 0usize;
            for _ in 0..=pick {
                let u = h.next_f64();
                cand = ((u * u * u) * v as f64) as usize;
            }
            pop_perm[cand.min(v - 1)]
        };
        let gen_stream = |len: usize, rng: &mut Rng| -> Vec<u32> {
            let mut out = Vec::with_capacity(len);
            let mut tok = rng.below(v as u64) as u32;
            let mut regime = rng.below(spec.regimes as u64) as usize;
            for _ in 0..len {
                out.push(tok);
                if rng.next_f64() < spec.switch_p {
                    regime = rng.below(spec.regimes as u64) as usize;
                }
                tok = succ(regime, tok, rng);
            }
            out
        };
        let train = gen_stream(train_len, &mut rng);
        let valid = gen_stream(valid_len, &mut rng);
        let mut bigram = vec![0u32; v * v];
        for w in train.windows(2) {
            bigram[w[0] as usize * v + w[1] as usize] += 1;
        }
        Corpus { spec_vocab: v, train, valid, bigram }
    }

    /// Non-overlapping sequences of `seq_len` from a split.
    pub fn sequences<'a>(&'a self, split: &str, seq_len: usize) -> Vec<&'a [u32]> {
        let stream: &[u32] = match split {
            "train" => &self.train,
            "valid" => &self.valid,
            other => panic!("unknown split '{other}'"),
        };
        stream.chunks_exact(seq_len).collect()
    }

    /// Most likely successor of `tok` (bigram probe ground truth).
    pub fn top_successor(&self, tok: u32) -> u32 {
        let v = self.spec_vocab;
        let row = &self.bigram[tok as usize * v..(tok as usize + 1) * v];
        row.iter().enumerate().max_by_key(|(_, &c)| c).map(|(i, _)| i as u32).unwrap_or(0)
    }

    /// Total bigram observations of `tok` (to filter rare probe anchors).
    pub fn successor_mass(&self, tok: u32) -> u32 {
        let v = self.spec_vocab;
        self.bigram[tok as usize * v..(tok as usize + 1) * v].iter().sum()
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = MxtFile::new();
        let as_i32 = |xs: &[u32]| xs.iter().map(|&x| x as i32).collect::<Vec<_>>();
        f.insert("train", MxtTensor::from_i32(vec![self.train.len()], &as_i32(&self.train)));
        f.insert("valid", MxtTensor::from_i32(vec![self.valid.len()], &as_i32(&self.valid)));
        f.insert(
            "bigram",
            MxtTensor::from_i32(vec![self.spec_vocab, self.spec_vocab], &as_i32(&self.bigram)),
        );
        f.save(path)
    }

    pub fn load(path: &Path) -> Result<Corpus> {
        let f = MxtFile::load(path)?;
        let train: Vec<u32> = f.get("train")?.to_i32()?.iter().map(|&x| x as u32).collect();
        let valid: Vec<u32> = f.get("valid")?.to_i32()?.iter().map(|&x| x as u32).collect();
        let bt = f.get("bigram")?;
        let vocab = bt.shape[0];
        let bigram: Vec<u32> = bt.to_i32()?.iter().map(|&x| x as u32).collect();
        Ok(Corpus { spec_vocab: vocab, train, valid, bigram })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let spec = CorpusSpec::default();
        let a = Corpus::generate(&spec, 2000, 500);
        let b = Corpus::generate(&spec, 2000, 500);
        assert_eq!(a.train, b.train);
        assert_eq!(a.valid, b.valid);
    }

    #[test]
    fn tokens_in_vocab() {
        let spec = CorpusSpec { vocab: 64, ..Default::default() };
        let c = Corpus::generate(&spec, 5000, 100);
        assert!(c.train.iter().all(|&t| (t as usize) < 64));
    }

    #[test]
    fn corpus_has_predictable_bigrams() {
        // Markov structure ⇒ top successor carries a large share of mass
        let c = Corpus::generate(&CorpusSpec::default(), 50_000, 100);
        let mut predictable = 0;
        let mut checked = 0;
        for tok in 0..512u32 {
            let mass = c.successor_mass(tok);
            if mass < 50 {
                continue;
            }
            checked += 1;
            let top = c.top_successor(tok);
            let top_count = c.bigram[tok as usize * 512 + top as usize];
            if top_count as f64 / mass as f64 > 0.15 {
                predictable += 1;
            }
        }
        assert!(checked > 20, "too few frequent tokens: {checked}");
        assert!(
            predictable as f64 / checked as f64 > 0.8,
            "{predictable}/{checked} predictable"
        );
    }

    #[test]
    fn unigram_distribution_is_skewed() {
        let c = Corpus::generate(&CorpusSpec::default(), 50_000, 100);
        let mut counts = vec![0usize; 512];
        for &t in &c.train {
            counts[t as usize] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let top10: usize = counts[..10].iter().sum();
        assert!(
            top10 as f64 / 50_000.0 > 0.08,
            "corpus not Zipf-skewed: top10 share {}",
            top10 as f64 / 50_000.0
        );
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("mxmoe_corpus_test.mxt");
        let c = Corpus::generate(&CorpusSpec { vocab: 32, ..Default::default() }, 1000, 200);
        c.save(&dir).unwrap();
        let c2 = Corpus::load(&dir).unwrap();
        assert_eq!(c.train, c2.train);
        assert_eq!(c.valid, c2.valid);
        assert_eq!(c.bigram, c2.bigram);
        let _ = std::fs::remove_file(&dir);
    }

    #[test]
    fn sequences_chunking() {
        let c = Corpus::generate(&CorpusSpec { vocab: 32, ..Default::default() }, 1000, 205);
        let seqs = c.sequences("valid", 50);
        assert_eq!(seqs.len(), 4);
        assert!(seqs.iter().all(|s| s.len() == 50));
    }
}
