//! Admission queue + continuous batcher.
//!
//! Whole-sequence scoring requests are coalesced into token batches sized
//! to the exported tile set ([`crate::runtime::TILE_MS`]): while one batch
//! executes, arrivals accumulate here, and the next batch is cut along
//! three axes — sequence cap, concatenated-token budget (default: the
//! largest exported tile, so every MoE layer's concatenated dispatch fills
//! whole tiles instead of padding a fresh one), and the oldest request's
//! wait deadline. Requests are never dropped: a token-budget cut leaves the
//! tail queued for the next batch, which is what makes the batcher
//! "continuous" rather than a one-shot gather.
//!
//! The policy decisions are pure functions of (queue, now) so they unit-
//! test without threads; the server loop in [`crate::coordinator::server`]
//! owns the channel mechanics.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::runtime::dispatch::{self, FillEstimate};
use crate::runtime::TILE_MS;

/// A scoring request: token sequence in, next-token prediction + NLL out.
pub struct Request {
    pub tokens: Vec<u32>,
    pub reply: mpsc::Sender<Response>,
    pub arrived: Instant,
}

/// Response: argmax continuation of the last position + mean next-token
/// NLL over the sequence (the serving analogue of scoring).
#[derive(Clone, Debug)]
pub struct Response {
    pub next_token: u32,
    pub mean_nll: f64,
    /// End-to-end latency (admission → reply).
    pub latency: Duration,
    /// Time spent queued before the batch was cut.
    pub queue_wait: Duration,
    /// Plan generation that served this request (bumps on hot-swap).
    pub generation: u64,
}

/// Batch-cut policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max sequences per batch.
    pub max_seqs: usize,
    /// Concatenated-token budget per batch (tile-set sizing).
    pub max_tokens: usize,
    /// Max time the oldest queued request may wait before the batch is cut.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_seqs: 8,
            max_tokens: *TILE_MS.last().unwrap(),
            max_wait: Duration::from_millis(20),
        }
    }
}

/// FIFO admission queue with tile-aware batch cutting.
pub struct ContinuousBatcher {
    policy: BatchPolicy,
    pending: VecDeque<Request>,
    /// Running token total of `pending` (keeps `ready()` O(1) under deep
    /// backlogs).
    pending_tokens: usize,
}

impl ContinuousBatcher {
    pub fn new(policy: BatchPolicy) -> ContinuousBatcher {
        assert!(policy.max_seqs >= 1);
        assert!(policy.max_tokens >= 1);
        ContinuousBatcher { policy, pending: VecDeque::new(), pending_tokens: 0 }
    }

    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    /// Admit a request (never blocks, never drops).
    pub fn push(&mut self, r: Request) {
        self.pending_tokens += r.tokens.len();
        self.pending.push_back(r);
    }

    /// Queued sequence count.
    pub fn depth(&self) -> usize {
        self.pending.len()
    }

    /// Total queued tokens.
    pub fn queued_tokens(&self) -> usize {
        self.pending_tokens
    }

    /// Tile fill the dispatch planner projects for the current queue if it
    /// were cut as one batch: every MoE layer dispatches the batch's
    /// concatenated tokens, so the planner's decomposition of the queued
    /// token total is the batch's fill estimate. This is the single source
    /// of truth shared with `runtime::dispatch` — the batcher no longer
    /// re-derives tile math from `TILE_MS`.
    pub fn fill_estimate(&self) -> FillEstimate {
        dispatch::fill_estimate(self.pending_tokens)
    }

    /// When the oldest queued request's wait deadline expires.
    pub fn oldest_deadline(&self) -> Option<Instant> {
        self.pending.front().map(|r| r.arrived + self.policy.max_wait)
    }

    /// Should a batch be cut now? True when the sequence cap is reached,
    /// the token budget is filled, or the oldest request has waited out
    /// `max_wait`. An empty queue is never ready.
    pub fn ready(&self, now: Instant) -> bool {
        if self.pending.is_empty() {
            return false;
        }
        self.pending.len() >= self.policy.max_seqs
            || self.queued_tokens() >= self.policy.max_tokens
            || self.oldest_deadline().map_or(false, |d| now >= d)
    }

    /// How long the serve loop may wait for stragglers before the next cut
    /// MUST happen: `None` means cut immediately (a cap is hit or the
    /// oldest queued request is already past its deadline — including a
    /// tail left behind by a token-budget cut), `Some(d)` means a cut is
    /// due in at most `d` even if nothing else arrives. This is the single
    /// wait-policy entry point for the router loop: because the returned
    /// duration is bounded by the oldest deadline, a past-deadline tail can
    /// never sit waiting for the next arrival.
    ///
    /// Panics on an empty queue — with nothing queued there is no deadline
    /// to honor and the caller should block on admission instead.
    pub fn time_to_cut(&self, now: Instant) -> Option<Duration> {
        let deadline = self.oldest_deadline().expect("time_to_cut on an empty queue");
        if self.ready(now) {
            return None;
        }
        let left = deadline.saturating_duration_since(now);
        if left.is_zero() {
            None
        } else {
            Some(left)
        }
    }

    /// Cut a batch: FIFO prefix of the queue, stopping before the sequence
    /// cap or token budget is exceeded. Always takes at least one request
    /// (an oversized single sequence still has to run — the engine tiles
    /// it), and leaves the rest queued for the next cut.
    pub fn take_batch(&mut self) -> Vec<Request> {
        let mut batch = Vec::new();
        let mut tokens = 0usize;
        while let Some(front) = self.pending.front() {
            let t = front.tokens.len();
            if !batch.is_empty() && tokens + t > self.policy.max_tokens {
                break;
            }
            tokens += t;
            self.pending_tokens -= t;
            batch.push(self.pending.pop_front().unwrap());
            if batch.len() >= self.policy.max_seqs {
                break;
            }
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(n_tokens: usize, arrived: Instant) -> Request {
        // tests never send a reply, so the receiver can drop immediately
        let (reply, _) = mpsc::channel();
        Request { tokens: vec![0u32; n_tokens], reply, arrived }
    }

    fn policy(max_seqs: usize, max_tokens: usize, wait_ms: u64) -> BatchPolicy {
        BatchPolicy {
            max_seqs,
            max_tokens,
            max_wait: Duration::from_millis(wait_ms),
        }
    }

    #[test]
    fn empty_queue_is_never_ready() {
        let b = ContinuousBatcher::new(BatchPolicy::default());
        assert!(!b.ready(Instant::now()));
        assert_eq!(b.depth(), 0);
        assert_eq!(b.queued_tokens(), 0);
        assert!(b.oldest_deadline().is_none());
    }

    #[test]
    fn seq_cap_cuts_batch() {
        let now = Instant::now();
        let mut b = ContinuousBatcher::new(policy(3, 1_000_000, 1000));
        for _ in 0..2 {
            b.push(req(10, now));
        }
        assert!(!b.ready(now));
        b.push(req(10, now));
        assert!(b.ready(now));
        let batch = b.take_batch();
        assert_eq!(batch.len(), 3);
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn token_budget_splits_fifo_without_dropping() {
        let now = Instant::now();
        let mut b = ContinuousBatcher::new(policy(100, 64, 1000));
        for n in [24usize, 24, 24, 24] {
            b.push(req(n, now));
        }
        assert!(b.ready(now), "96 tokens ≥ 64 budget");
        assert_eq!(b.queued_tokens(), 96);
        let first = b.take_batch();
        // 24 + 24 = 48 fits; adding a third (72) would exceed 64
        assert_eq!(first.len(), 2);
        assert_eq!(b.depth(), 2, "tail stays queued, not dropped");
        assert_eq!(b.queued_tokens(), 48, "running token counter tracks the tail");
        let second = b.take_batch();
        assert_eq!(second.len(), 2);
        assert_eq!(b.depth(), 0);
        assert_eq!(b.queued_tokens(), 0);
    }

    #[test]
    fn oversized_single_request_still_runs() {
        let now = Instant::now();
        let mut b = ContinuousBatcher::new(policy(8, 64, 1000));
        b.push(req(500, now));
        assert!(b.ready(now), "token budget exceeded by a single sequence");
        let batch = b.take_batch();
        assert_eq!(batch.len(), 1, "must take at least one");
        assert_eq!(batch[0].tokens.len(), 500);
    }

    #[test]
    fn wait_deadline_cuts_partial_batch() {
        let now = Instant::now();
        let mut b = ContinuousBatcher::new(policy(8, 256, 20));
        b.push(req(4, now));
        assert!(!b.ready(now), "fresh request, under caps");
        let later = now + Duration::from_millis(25);
        assert!(b.ready(later), "oldest waited past max_wait");
        assert_eq!(b.take_batch().len(), 1);
    }

    #[test]
    fn fill_estimate_tracks_queue() {
        let now = Instant::now();
        let mut b = ContinuousBatcher::new(policy(100, 1_000_000, 1000));
        assert_eq!(b.fill_estimate().fill_ratio(), 1.0, "empty queue is trivially full");
        b.push(req(68, now)); // 64 + 4, zero padding
        let est = b.fill_estimate();
        assert_eq!(est.useful_rows, 68);
        assert_eq!(est.padded_rows, 68);
        assert_eq!(est.tiles, 2);
        b.push(req(3, now)); // 71 → 64 + 4 + 4: one padding row
        let est = b.fill_estimate();
        assert_eq!(est.useful_rows, 71);
        assert_eq!(est.padded_rows, 72);
        assert!(est.fill_ratio() < 1.0);
        b.take_batch();
        assert_eq!(b.fill_estimate().useful_rows, 0);
    }

    #[test]
    fn budget_cut_with_past_deadline_tail_recuts_immediately() {
        // Regression: a token-budget cut that leaves a past-deadline
        // request queued must re-cut on the next loop iteration, not wait
        // for another arrival. Both requests arrived at t0; by t0+25ms the
        // 20ms deadline has long passed, the budget cut takes only the
        // first request, and the tail (which also arrived at t0) must be
        // immediately cuttable.
        let t0 = Instant::now();
        let mut b = ContinuousBatcher::new(policy(100, 64, 20));
        b.push(req(60, t0));
        b.push(req(10, t0));
        let now = t0 + Duration::from_millis(25);
        assert!(b.ready(now));
        assert_eq!(b.time_to_cut(now), None, "deadline passed — cut now");
        let first = b.take_batch();
        assert_eq!(first.len(), 1, "60 + 10 > 64: budget splits the queue");
        assert_eq!(b.depth(), 1, "tail stays queued");
        // the tail is already past its deadline: no straggler wait allowed
        assert!(b.ready(now), "past-deadline tail must be ready");
        assert_eq!(
            b.time_to_cut(now),
            None,
            "past-deadline tail must re-cut without waiting for an arrival"
        );
        assert_eq!(b.take_batch().len(), 1);
    }

    #[test]
    fn time_to_cut_bounds_the_straggler_wait() {
        let t0 = Instant::now();
        let mut b = ContinuousBatcher::new(policy(8, 256, 20));
        b.push(req(4, t0));
        // fresh request: wait at most the remaining deadline
        let wait = b.time_to_cut(t0).expect("under caps — wait for stragglers");
        assert!(wait <= Duration::from_millis(20));
        assert!(wait > Duration::from_millis(15), "nearly the full window at t0: {wait:?}");
        // at the deadline the wait collapses to an immediate cut
        assert_eq!(b.time_to_cut(t0 + Duration::from_millis(20)), None);
        // a cap being hit also cuts immediately, deadline or not
        for _ in 0..7 {
            b.push(req(4, t0));
        }
        assert_eq!(b.time_to_cut(t0), None, "seq cap reached");
    }

    #[test]
    fn fifo_order_preserved() {
        let now = Instant::now();
        let mut b = ContinuousBatcher::new(policy(2, 1_000_000, 1000));
        for n in [1usize, 2, 3, 4] {
            b.push(req(n, now));
        }
        let first = b.take_batch();
        let second = b.take_batch();
        assert_eq!(first.iter().map(|r| r.tokens.len()).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(second.iter().map(|r| r.tokens.len()).collect::<Vec<_>>(), vec![3, 4]);
    }
}
