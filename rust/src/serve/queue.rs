//! Admission queue + continuous batcher.
//!
//! Whole-sequence scoring requests are coalesced into token batches sized
//! to the exported tile set ([`crate::runtime::TILE_MS`]): while one batch
//! executes, arrivals accumulate here, and the next batch is cut along
//! three axes — sequence cap, concatenated-token budget (default: the
//! largest exported tile, so every MoE layer's concatenated dispatch fills
//! whole tiles instead of padding a fresh one), and the earliest queued
//! cut deadline. Requests are never dropped by the cut itself: a
//! token-budget cut leaves the tail queued for the next batch, which is
//! what makes the batcher "continuous" rather than a one-shot gather.
//! (Cancelled requests *are* dropped — [`ContinuousBatcher::shed_cancelled`]
//! runs before every cut so dead work never reaches a replica.)
//!
//! Since the QoS redesign (DESIGN.md §Serving-API) the cut is not FIFO:
//!
//! * Requests whose *per-request* deadline has passed go first, earliest
//!   deadline first — a deadline-expired request is never reordered
//!   behind a fresh arrival, whatever its priority. (The `max_wait`
//!   straggler window only decides *when* to cut; under backlog every
//!   request blows it, so it must not demote the cut order to FIFO.)
//! * The rest order by aged priority: base [`Priority`] plus one level
//!   per [`BatchPolicy::aging`] waited, so `High` cuts ahead of `Normal`
//!   but a waiting `Low` climbs one level per quantum and cannot starve.
//!   Arrival order breaks ties, so an all-`Normal` stream degrades to the
//!   legacy FIFO exactly.
//! * Each request's cut deadline is `arrived + max_wait`, clamped by its
//!   per-request deadline when one was set — deadline-carrying requests
//!   are cut early enough to have a chance, instead of waiting out the
//!   global straggler window.
//!
//! The policy decisions are pure functions of (queue, now) so they unit-
//! test without threads; the router loop in [`crate::coordinator::cluster`]
//! owns the channel mechanics.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::runtime::dispatch::{self, FillEstimate};
use crate::runtime::TILE_MS;

use super::request::{Priority, QosClass, StreamEvent};

/// Decode-side parameters of a routed generation request: the generation
/// budget, the stop set, and the sender half of the ticket's token stream.
pub struct GenSpec {
    pub max_new_tokens: usize,
    pub stop: Vec<u32>,
    /// Streams [`StreamEvent`]s to the ticket as decode steps land. Send
    /// errors are ignored — a dropped ticket abandons its stream.
    pub stream: mpsc::Sender<StreamEvent>,
}

/// What the replica does with a routed request.
pub enum RequestKind {
    /// Whole-sequence scoring: one engine forward, one [`Response`].
    Score,
    /// KV-cached generation on the replica's decode scheduler
    /// (DESIGN.md §Decode-Loop).
    Generate(GenSpec),
}

impl RequestKind {
    pub fn is_generate(&self) -> bool {
        matches!(self, RequestKind::Generate(_))
    }
}

/// A serving request: token sequence in; next-token prediction + NLL out
/// (scoring), or a streamed generation (decode). Built by the cluster
/// front door from a [`super::request::ServeRequest`]; tests construct it
/// directly (the fields are plain data).
pub struct Request {
    /// Admission-assigned id (0 for direct construction in tests).
    pub id: u64,
    pub tokens: Vec<u32>,
    pub reply: mpsc::Sender<Response>,
    pub arrived: Instant,
    pub priority: Priority,
    /// Absolute response deadline, when the client set one.
    pub deadline: Option<Instant>,
    pub qos: Option<QosClass>,
    pub kind: RequestKind,
    /// Set by [`super::request::Ticket::cancel`]; checked at every cut,
    /// pop, decode step and reply.
    pub cancelled: Arc<AtomicBool>,
}

impl Request {
    /// A plain `Normal`-priority scoring request with no deadline or QoS
    /// class — what the legacy `submit` shim produces.
    pub fn new(tokens: Vec<u32>, reply: mpsc::Sender<Response>) -> Request {
        Request {
            id: 0,
            tokens,
            reply,
            arrived: Instant::now(),
            priority: Priority::Normal,
            deadline: None,
            qos: None,
            kind: RequestKind::Score,
            cancelled: Arc::new(AtomicBool::new(false)),
        }
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Acquire)
    }

    /// When this request must be cut by: the straggler window from
    /// arrival, clamped by the per-request deadline when one is set.
    pub fn cut_deadline(&self, max_wait: Duration) -> Instant {
        let d = self.arrived + max_wait;
        match self.deadline {
            Some(dl) => d.min(dl),
            None => d,
        }
    }

    /// True when the *client's* deadline has passed. Only real
    /// per-request deadlines count here — the `max_wait` straggler window
    /// decides when to cut ([`ContinuousBatcher::time_to_cut`]), never the
    /// cut *order*: under backlog every queued request blows `max_wait`,
    /// and letting that demote the order would collapse priority
    /// scheduling back to FIFO exactly when it matters.
    pub fn deadline_expired(&self, now: Instant) -> bool {
        self.deadline.map_or(false, |d| d <= now)
    }

    /// Priority with aging: the base level plus one level per `aging`
    /// waited. Monotone in wait time, so a queued `Low` eventually
    /// outranks fresh `High` arrivals instead of starving behind them.
    pub fn effective_priority(&self, now: Instant, aging: Duration) -> f64 {
        let waited = now.saturating_duration_since(self.arrived).as_secs_f64();
        self.priority.index() as f64 + waited / aging.as_secs_f64().max(1e-9)
    }
}

/// Response: argmax continuation of the last position + mean next-token
/// NLL over the sequence (the serving analogue of scoring).
#[derive(Clone, Debug)]
pub struct Response {
    pub next_token: u32,
    pub mean_nll: f64,
    /// End-to-end latency (admission → reply).
    pub latency: Duration,
    /// Time spent queued before the batch was cut.
    pub queue_wait: Duration,
    /// Plan generation that served this request (bumps on hot-swap).
    pub generation: u64,
}

/// Batch-cut policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max sequences per batch.
    pub max_seqs: usize,
    /// Concatenated-token budget per batch (tile-set sizing).
    pub max_tokens: usize,
    /// Max time a queued request may wait before the batch is cut.
    pub max_wait: Duration,
    /// Priority-aging quantum: a waiting request gains one priority level
    /// per `aging` elapsed (starvation control for `Low` under sustained
    /// `High` load).
    pub aging: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_seqs: 8,
            max_tokens: *TILE_MS.last().unwrap(),
            max_wait: Duration::from_millis(20),
            aging: Duration::from_millis(250),
        }
    }
}

/// One request dropped by [`ContinuousBatcher::shed_cancelled`]: what the
/// router needs to record an attributable terminal span for the shed.
#[derive(Clone, Copy, Debug)]
pub struct ShedInfo {
    /// Admission-assigned request id.
    pub id: u64,
    pub tokens: usize,
    /// Time the request sat queued before the shed.
    pub queued: Duration,
    /// QoS class name (`"none"` when unset).
    pub qos: &'static str,
}

/// Priority- and deadline-aware admission queue with tile-aware batch
/// cutting.
pub struct ContinuousBatcher {
    policy: BatchPolicy,
    /// Arrival order (the cut reorders; the backlog itself stays FIFO so
    /// tie-breaks are stable).
    pending: VecDeque<Request>,
    /// Running token total of `pending` (keeps `ready()` O(1) under deep
    /// backlogs).
    pending_tokens: usize,
    /// Cached earliest cut deadline over `pending` (a request's cut
    /// deadline is fixed at admission, so the min only shrinks on push —
    /// O(1) per arrival — and is recomputed once per removal).
    min_deadline: Option<Instant>,
}

impl ContinuousBatcher {
    pub fn new(policy: BatchPolicy) -> ContinuousBatcher {
        assert!(policy.max_seqs >= 1);
        assert!(policy.max_tokens >= 1);
        ContinuousBatcher {
            policy,
            pending: VecDeque::new(),
            pending_tokens: 0,
            min_deadline: None,
        }
    }

    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    /// Admit a request (never blocks — bounding happens at the cluster
    /// front door, before the request reaches the batcher).
    pub fn push(&mut self, r: Request) {
        self.pending_tokens += r.tokens.len();
        let d = r.cut_deadline(self.policy.max_wait);
        self.min_deadline = Some(self.min_deadline.map_or(d, |m| m.min(d)));
        self.pending.push_back(r);
    }

    /// Re-derive the cached min cut deadline after removals.
    fn recompute_min_deadline(&mut self) {
        self.min_deadline =
            self.pending.iter().map(|r| r.cut_deadline(self.policy.max_wait)).min();
    }

    /// Queued sequence count.
    pub fn depth(&self) -> usize {
        self.pending.len()
    }

    /// Total queued tokens.
    pub fn queued_tokens(&self) -> usize {
        self.pending_tokens
    }

    /// Drop every cancelled request from the queue; returns one
    /// [`ShedInfo`] per shed request (id, tokens, queued time) so the
    /// router can record attributable terminal spans, not just counts.
    /// Runs before each cut so cancelled work is never routed.
    pub fn shed_cancelled(&mut self, now: Instant) -> Vec<ShedInfo> {
        let mut shed = Vec::new();
        self.pending.retain(|r| {
            if r.is_cancelled() {
                shed.push(ShedInfo {
                    id: r.id,
                    tokens: r.tokens.len(),
                    queued: now.saturating_duration_since(r.arrived),
                    qos: r.qos.map_or("none", |q| q.name()),
                });
                false
            } else {
                true
            }
        });
        self.pending_tokens -= shed.iter().map(|s| s.tokens).sum::<usize>();
        if !shed.is_empty() {
            self.recompute_min_deadline();
        }
        shed
    }

    /// Tile fill the dispatch planner projects for the current queue if it
    /// were cut as one batch: every MoE layer dispatches the batch's
    /// concatenated tokens, so the planner's decomposition of the queued
    /// token total is the batch's fill estimate. This is the single source
    /// of truth shared with `runtime::dispatch` — the batcher no longer
    /// re-derives tile math from `TILE_MS`.
    pub fn fill_estimate(&self) -> FillEstimate {
        dispatch::fill_estimate(self.pending_tokens)
    }

    /// Earliest cut deadline over the whole queue — *not* the front's:
    /// with per-request deadlines a tight-deadline request can sit behind
    /// earlier arrivals, and its deadline still bounds the next cut.
    /// O(1): served from the cached minimum.
    pub fn next_cut_deadline(&self) -> Option<Instant> {
        self.min_deadline
    }

    /// Should a batch be cut now? True when the sequence cap is reached,
    /// the token budget is filled, or any queued request has reached its
    /// cut deadline. An empty queue is never ready.
    pub fn ready(&self, now: Instant) -> bool {
        if self.pending.is_empty() {
            return false;
        }
        self.pending.len() >= self.policy.max_seqs
            || self.queued_tokens() >= self.policy.max_tokens
            || self.next_cut_deadline().map_or(false, |d| now >= d)
    }

    /// How long the serve loop may wait for stragglers before the next cut
    /// MUST happen: `None` means cut immediately (a cap is hit or some
    /// queued request is already past its cut deadline — including a
    /// tail left behind by a token-budget cut), `Some(d)` means a cut is
    /// due in at most `d` even if nothing else arrives. This is the single
    /// wait-policy entry point for the router loop: because the returned
    /// duration is bounded by the earliest deadline anywhere in the queue,
    /// a past-deadline request can never sit waiting for the next arrival
    /// — wherever it sits in arrival order.
    ///
    /// Panics on an empty queue — with nothing queued there is no deadline
    /// to honor and the caller should block on admission instead.
    pub fn time_to_cut(&self, now: Instant) -> Option<Duration> {
        let deadline = self.next_cut_deadline().expect("time_to_cut on an empty queue");
        if self.ready(now) {
            return None;
        }
        let left = deadline.saturating_duration_since(now);
        if left.is_zero() {
            None
        } else {
            Some(left)
        }
    }

    /// Cut a batch, stopping before the sequence cap or token budget is
    /// exceeded. Selection order: requests whose *per-request* deadline
    /// has passed first (earliest deadline first — a deadline-expired
    /// request is never reordered behind a fresh arrival), then
    /// descending aged priority with arrival order breaking ties. The
    /// `max_wait` straggler window deliberately does not join the
    /// expired-first rule: under backlog every queued request blows
    /// `max_wait`, and counting that as "expired" would collapse the cut
    /// back to FIFO exactly when priority matters. Always takes at least
    /// one request (an oversized single sequence still has to run — the
    /// engine tiles it), and leaves the rest queued for the next cut.
    pub fn take_batch(&mut self, now: Instant) -> Vec<Request> {
        if self.pending.is_empty() {
            return Vec::new();
        }
        let aging = self.policy.aging;
        let mut order: Vec<usize> = (0..self.pending.len()).collect();
        order.sort_by(|&a, &b| {
            let (ra, rb) = (&self.pending[a], &self.pending[b]);
            match (ra.deadline_expired(now), rb.deadline_expired(now)) {
                (true, false) => std::cmp::Ordering::Less,
                (false, true) => std::cmp::Ordering::Greater,
                (true, true) => ra.deadline.cmp(&rb.deadline).then(a.cmp(&b)),
                (false, false) => rb
                    .effective_priority(now, aging)
                    .partial_cmp(&ra.effective_priority(now, aging))
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b)),
            }
        });
        let mut take = vec![false; self.pending.len()];
        let mut tokens = 0usize;
        let mut n = 0usize;
        for &i in &order {
            let t = self.pending[i].tokens.len();
            if n > 0 && tokens + t > self.policy.max_tokens {
                break;
            }
            take[i] = true;
            tokens += t;
            n += 1;
            if n >= self.policy.max_seqs {
                break;
            }
        }
        // extract in selection order; the remainder keeps arrival order
        let mut slots: Vec<Option<Request>> = self.pending.drain(..).map(Some).collect();
        let mut batch = Vec::with_capacity(n);
        for &i in &order {
            if take[i] {
                batch.push(slots[i].take().unwrap());
            }
        }
        self.pending = slots.into_iter().flatten().collect();
        self.pending_tokens -= tokens;
        self.recompute_min_deadline();
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(n_tokens: usize, arrived: Instant) -> Request {
        // tests never send a reply, so the receiver can drop immediately
        let (reply, _) = mpsc::channel();
        Request { arrived, ..Request::new(vec![0u32; n_tokens], reply) }
    }

    fn prio_req(n_tokens: usize, arrived: Instant, priority: Priority) -> Request {
        Request { priority, ..req(n_tokens, arrived) }
    }

    fn policy(max_seqs: usize, max_tokens: usize, wait_ms: u64) -> BatchPolicy {
        BatchPolicy {
            max_seqs,
            max_tokens,
            max_wait: Duration::from_millis(wait_ms),
            aging: Duration::from_millis(250),
        }
    }

    fn lens(batch: &[Request]) -> Vec<usize> {
        batch.iter().map(|r| r.tokens.len()).collect()
    }

    #[test]
    fn empty_queue_is_never_ready() {
        let b = ContinuousBatcher::new(BatchPolicy::default());
        assert!(!b.ready(Instant::now()));
        assert_eq!(b.depth(), 0);
        assert_eq!(b.queued_tokens(), 0);
        assert!(b.next_cut_deadline().is_none());
    }

    #[test]
    fn seq_cap_cuts_batch() {
        let now = Instant::now();
        let mut b = ContinuousBatcher::new(policy(3, 1_000_000, 1000));
        for _ in 0..2 {
            b.push(req(10, now));
        }
        assert!(!b.ready(now));
        b.push(req(10, now));
        assert!(b.ready(now));
        let batch = b.take_batch(now);
        assert_eq!(batch.len(), 3);
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn token_budget_splits_without_dropping() {
        let now = Instant::now();
        let mut b = ContinuousBatcher::new(policy(100, 64, 1000));
        for n in [24usize, 24, 24, 24] {
            b.push(req(n, now));
        }
        assert!(b.ready(now), "96 tokens ≥ 64 budget");
        assert_eq!(b.queued_tokens(), 96);
        let first = b.take_batch(now);
        // 24 + 24 = 48 fits; adding a third (72) would exceed 64
        assert_eq!(first.len(), 2);
        assert_eq!(b.depth(), 2, "tail stays queued, not dropped");
        assert_eq!(b.queued_tokens(), 48, "running token counter tracks the tail");
        let second = b.take_batch(now);
        assert_eq!(second.len(), 2);
        assert_eq!(b.depth(), 0);
        assert_eq!(b.queued_tokens(), 0);
    }

    #[test]
    fn oversized_single_request_still_runs() {
        let now = Instant::now();
        let mut b = ContinuousBatcher::new(policy(8, 64, 1000));
        b.push(req(500, now));
        assert!(b.ready(now), "token budget exceeded by a single sequence");
        let batch = b.take_batch(now);
        assert_eq!(batch.len(), 1, "must take at least one");
        assert_eq!(batch[0].tokens.len(), 500);
    }

    #[test]
    fn wait_deadline_cuts_partial_batch() {
        let now = Instant::now();
        let mut b = ContinuousBatcher::new(policy(8, 256, 20));
        b.push(req(4, now));
        assert!(!b.ready(now), "fresh request, under caps");
        let later = now + Duration::from_millis(25);
        assert!(b.ready(later), "oldest waited past max_wait");
        assert_eq!(b.take_batch(later).len(), 1);
    }

    #[test]
    fn fill_estimate_tracks_queue() {
        let now = Instant::now();
        let mut b = ContinuousBatcher::new(policy(100, 1_000_000, 1000));
        assert_eq!(b.fill_estimate().fill_ratio(), 1.0, "empty queue is trivially full");
        b.push(req(68, now)); // 64 + 4, zero padding
        let est = b.fill_estimate();
        assert_eq!(est.useful_rows, 68);
        assert_eq!(est.padded_rows, 68);
        assert_eq!(est.tiles, 2);
        b.push(req(3, now)); // 71 → 64 + 4 + 4: one padding row
        let est = b.fill_estimate();
        assert_eq!(est.useful_rows, 71);
        assert_eq!(est.padded_rows, 72);
        assert!(est.fill_ratio() < 1.0);
        b.take_batch(now);
        assert_eq!(b.fill_estimate().useful_rows, 0);
    }

    #[test]
    fn budget_cut_with_past_deadline_tail_recuts_immediately() {
        // Regression: a token-budget cut that leaves a past-deadline
        // request queued must re-cut on the next loop iteration, not wait
        // for another arrival. Both requests arrived at t0; by t0+25ms the
        // 20ms deadline has long passed, the budget cut takes only the
        // first request, and the tail (which also arrived at t0) must be
        // immediately cuttable.
        let t0 = Instant::now();
        let mut b = ContinuousBatcher::new(policy(100, 64, 20));
        b.push(req(60, t0));
        b.push(req(10, t0));
        let now = t0 + Duration::from_millis(25);
        assert!(b.ready(now));
        assert_eq!(b.time_to_cut(now), None, "deadline passed — cut now");
        let first = b.take_batch(now);
        assert_eq!(first.len(), 1, "60 + 10 > 64: budget splits the queue");
        assert_eq!(b.depth(), 1, "tail stays queued");
        // the tail is already past its deadline: no straggler wait allowed
        assert!(b.ready(now), "past-deadline tail must be ready");
        assert_eq!(
            b.time_to_cut(now),
            None,
            "past-deadline tail must re-cut without waiting for an arrival"
        );
        assert_eq!(b.take_batch(now).len(), 1);
    }

    #[test]
    fn time_to_cut_bounds_the_straggler_wait() {
        let t0 = Instant::now();
        let mut b = ContinuousBatcher::new(policy(8, 256, 20));
        b.push(req(4, t0));
        // fresh request: wait at most the remaining deadline
        let wait = b.time_to_cut(t0).expect("under caps — wait for stragglers");
        assert!(wait <= Duration::from_millis(20));
        assert!(wait > Duration::from_millis(15), "nearly the full window at t0: {wait:?}");
        // at the deadline the wait collapses to an immediate cut
        assert_eq!(b.time_to_cut(t0 + Duration::from_millis(20)), None);
        // a cap being hit also cuts immediately, deadline or not
        for _ in 0..7 {
            b.push(req(4, t0));
        }
        assert_eq!(b.time_to_cut(t0), None, "seq cap reached");
    }

    #[test]
    fn fifo_order_preserved_for_uniform_priority() {
        let now = Instant::now();
        let mut b = ContinuousBatcher::new(policy(2, 1_000_000, 1000));
        for n in [1usize, 2, 3, 4] {
            b.push(req(n, now));
        }
        let first = b.take_batch(now);
        let second = b.take_batch(now);
        assert_eq!(lens(&first), vec![1, 2]);
        assert_eq!(lens(&second), vec![3, 4]);
    }

    #[test]
    fn high_priority_cuts_ahead_of_earlier_normal() {
        let now = Instant::now();
        let mut b = ContinuousBatcher::new(policy(2, 1_000_000, 1000));
        b.push(prio_req(1, now, Priority::Normal));
        b.push(prio_req(2, now, Priority::Low));
        b.push(prio_req(3, now, Priority::High));
        b.push(prio_req(4, now, Priority::High));
        let first = b.take_batch(now);
        assert_eq!(lens(&first), vec![3, 4], "both High requests cut first, in arrival order");
        let second = b.take_batch(now);
        assert_eq!(lens(&second), vec![1, 2], "then Normal before Low");
    }

    #[test]
    fn aging_lifts_a_waiting_low_past_fresh_high() {
        let t0 = Instant::now();
        let mut b = ContinuousBatcher::new(BatchPolicy {
            aging: Duration::from_millis(100),
            ..policy(1, 1_000_000, 10_000)
        });
        // Low arrived long ago: 3 aging quanta ⇒ effective ≈ 0 + 3 = 3,
        // beating a fresh High's 2.
        b.push(prio_req(1, t0, Priority::Low));
        let now = t0 + Duration::from_millis(300);
        b.push(prio_req(2, now, Priority::High));
        assert_eq!(lens(&b.take_batch(now)), vec![1], "aged Low outranks fresh High");
        assert_eq!(lens(&b.take_batch(now)), vec![2]);
        // without the wait, High wins
        let mut b = ContinuousBatcher::new(policy(1, 1_000_000, 10_000));
        b.push(prio_req(1, now, Priority::Low));
        b.push(prio_req(2, now, Priority::High));
        assert_eq!(lens(&b.take_batch(now)), vec![2]);
    }

    #[test]
    fn expired_request_behind_fresh_one_cuts_first() {
        // Regression (ISSUE 4 bugfix): a deadline-expired request sitting
        // *behind* a fresh arrival in the queue must never be reordered
        // behind it at the cut — and its deadline, not the front's, bounds
        // time_to_cut.
        let now = Instant::now();
        let mut b = ContinuousBatcher::new(policy(1, 1_000_000, 1000));
        // front: fresh Normal, no deadline, 1000ms straggler window left
        b.push(req(1, now));
        // behind it: a request whose per-request deadline already passed
        let expired = Request {
            deadline: Some(now - Duration::from_millis(5)),
            ..prio_req(2, now - Duration::from_millis(30), Priority::Low)
        };
        b.push(expired);
        assert!(b.ready(now), "expired request makes the queue ready");
        assert_eq!(b.time_to_cut(now), None, "mid-queue expiry forces an immediate cut");
        assert_eq!(lens(&b.take_batch(now)), vec![2], "expired request cuts first");
        assert_eq!(lens(&b.take_batch(now)), vec![1]);
    }

    #[test]
    fn per_request_deadline_clamps_the_cut_window() {
        let now = Instant::now();
        let mut b = ContinuousBatcher::new(policy(8, 1_000_000, 1000));
        b.push(Request {
            deadline: Some(now + Duration::from_millis(50)),
            ..req(4, now)
        });
        let wait = b.time_to_cut(now).expect("not yet due");
        assert!(
            wait <= Duration::from_millis(50),
            "deadline clamps the 1000ms straggler window: {wait:?}"
        );
        // two expired requests cut earliest-deadline-first
        let mut b = ContinuousBatcher::new(policy(2, 1_000_000, 1000));
        b.push(Request { deadline: Some(now - Duration::from_millis(1)), ..req(1, now) });
        b.push(Request { deadline: Some(now - Duration::from_millis(9)), ..req(2, now) });
        assert_eq!(lens(&b.take_batch(now)), vec![2, 1], "earliest expiry first");
    }

    #[test]
    fn shed_cancelled_drops_only_cancelled() {
        let now = Instant::now();
        let mut b = ContinuousBatcher::new(policy(8, 1_000_000, 1000));
        let keep = req(3, now);
        let dead1 = req(5, now);
        let dead2 = req(7, now);
        dead1.cancelled.store(true, Ordering::Release);
        dead2.cancelled.store(true, Ordering::Release);
        b.push(dead1);
        b.push(keep);
        b.push(dead2);
        assert_eq!(b.queued_tokens(), 15);
        let shed = b.shed_cancelled(now);
        assert_eq!(shed.len(), 2);
        assert_eq!(shed.iter().map(|s| s.tokens).sum::<usize>(), 12);
        assert!(shed.iter().all(|s| s.qos == "none"));
        assert_eq!(b.depth(), 1);
        assert_eq!(b.queued_tokens(), 3);
        assert_eq!(lens(&b.take_batch(now)), vec![3]);
        assert!(b.shed_cancelled(now).is_empty(), "idempotent on a clean queue");
    }

    #[test]
    fn shed_info_carries_id_and_queued_time() {
        let t0 = Instant::now();
        let mut b = ContinuousBatcher::new(policy(8, 1_000_000, 1000));
        let dead = Request { id: 42, qos: Some(QosClass::Interactive), ..req(5, t0) };
        dead.cancelled.store(true, Ordering::Release);
        b.push(dead);
        let now = t0 + Duration::from_millis(30);
        let shed = b.shed_cancelled(now);
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].id, 42);
        assert_eq!(shed[0].tokens, 5);
        assert_eq!(shed[0].qos, "interactive");
        assert!(shed[0].queued >= Duration::from_millis(30));
    }
}
