//! Live activation telemetry: per-(layer, expert) routed-token frequency
//! tracking with EWMA decay, plus drift detection against the calibration
//! frequency vector the offline allocator was solved with.
//!
//! Drift is measured as total-variation distance `½ Σ |live − baseline|`
//! per layer, so it lives in `[0, 1]` and grows monotonically as routing
//! mass moves away from the calibration distribution — the trigger signal
//! for the online MCKP re-solve ([`crate::serve::replan`]).

/// Default EWMA step: each recorded batch moves the live estimate 10% of
/// the way toward the batch's empirical frequency vector.
pub const DEFAULT_EWMA_ALPHA: f64 = 0.1;

/// Per-layer routed-expert frequency tracker.
pub struct ActivationTelemetry {
    /// EWMA step in `(0, 1]`: weight of the newest batch.
    alpha: f64,
    /// Calibration (or post-replan) reference distribution per layer.
    baseline: Vec<Vec<f64>>,
    /// EWMA of observed per-batch frequency vectors per layer.
    live: Vec<Vec<f64>>,
    /// Total routed token-assignments observed (drives replan hysteresis).
    pub observed_tokens: usize,
    /// Number of `record` calls that carried at least one assignment.
    pub updates: usize,
}

/// Normalize counts to a distribution; all-zero input yields uniform.
fn normalize(v: &[f64]) -> Vec<f64> {
    let total: f64 = v.iter().sum();
    if total <= 0.0 {
        return vec![1.0 / v.len().max(1) as f64; v.len()];
    }
    v.iter().map(|&x| x / total).collect()
}

impl ActivationTelemetry {
    /// Tracker seeded with per-layer baseline frequency vectors (normalized
    /// internally). The live estimate starts at the baseline, so drift is 0
    /// until real traffic arrives.
    pub fn new(baseline: Vec<Vec<f64>>, alpha: f64) -> ActivationTelemetry {
        assert!(alpha > 0.0 && alpha <= 1.0, "EWMA alpha must be in (0, 1]");
        let baseline: Vec<Vec<f64>> = baseline.iter().map(|v| normalize(v)).collect();
        ActivationTelemetry {
            alpha,
            live: baseline.clone(),
            baseline,
            observed_tokens: 0,
            updates: 0,
        }
    }

    /// Uniform baseline: no calibration vector available.
    pub fn uniform(n_layers: usize, n_experts: usize, alpha: f64) -> ActivationTelemetry {
        ActivationTelemetry::new(vec![vec![1.0; n_experts.max(1)]; n_layers], alpha)
    }

    /// Baseline from calibration activation counts.
    pub fn from_counts(counts: &[Vec<usize>], alpha: f64) -> ActivationTelemetry {
        ActivationTelemetry::new(
            counts
                .iter()
                .map(|layer| layer.iter().map(|&c| c as f64).collect())
                .collect(),
            alpha,
        )
    }

    pub fn n_layers(&self) -> usize {
        self.live.len()
    }

    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    pub fn set_alpha(&mut self, alpha: f64) {
        assert!(alpha > 0.0 && alpha <= 1.0, "EWMA alpha must be in (0, 1]");
        self.alpha = alpha;
    }

    /// Fold one batch's routed activation counts for layer `pos` into the
    /// live estimate. Empty batches (no assignments) are no-ops.
    pub fn record(&mut self, pos: usize, counts: &[usize]) {
        let total: usize = counts.iter().sum();
        if total == 0 {
            return;
        }
        let live = &mut self.live[pos];
        assert_eq!(live.len(), counts.len(), "expert count mismatch at layer {pos}");
        for (l, &c) in live.iter_mut().zip(counts) {
            let f = c as f64 / total as f64;
            *l = (1.0 - self.alpha) * *l + self.alpha * f;
        }
        self.observed_tokens += total;
        self.updates += 1;
    }

    /// Live frequency estimate for layer `pos`.
    pub fn freqs(&self, pos: usize) -> &[f64] {
        &self.live[pos]
    }

    /// All layers' live frequency vectors (the replanner's weight input).
    pub fn live(&self) -> &[Vec<f64>] {
        &self.live
    }

    pub fn baseline(&self, pos: usize) -> &[f64] {
        &self.baseline[pos]
    }

    /// Total-variation distance between live and baseline at layer `pos`,
    /// in `[0, 1]`.
    pub fn drift(&self, pos: usize) -> f64 {
        0.5 * self.live[pos]
            .iter()
            .zip(&self.baseline[pos])
            .map(|(l, b)| (l - b).abs())
            .sum::<f64>()
    }

    /// Worst-layer drift (the replan trigger).
    pub fn max_drift(&self) -> f64 {
        (0..self.live.len()).map(|p| self.drift(p)).fold(0.0, f64::max)
    }

    /// Per-layer drift vector (replan observability; `max_drift` is its
    /// maximum).
    pub fn drifts(&self) -> Vec<f64> {
        (0..self.live.len()).map(|p| self.drift(p)).collect()
    }

    /// After a successful replan the live distribution becomes the new
    /// reference: drift resets to 0 and accumulates against the plan that
    /// is now actually serving.
    pub fn rebaseline(&mut self) {
        self.baseline = self.live.clone();
    }

    /// Replace both baseline and live estimate (engine startup with a
    /// calibration vector).
    pub fn reset(&mut self, baseline: Vec<Vec<f64>>) {
        let baseline: Vec<Vec<f64>> = baseline.iter().map(|v| normalize(v)).collect();
        self.live = baseline.clone();
        self.baseline = baseline;
        self.observed_tokens = 0;
        self.updates = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_decay_math() {
        // uniform baseline over 4 experts; hammer expert 0 with alpha = 0.5
        let mut t = ActivationTelemetry::uniform(1, 4, 0.5);
        assert_eq!(t.freqs(0), &[0.25; 4]);
        t.record(0, &[8, 0, 0, 0]);
        // 0.5·0.25 + 0.5·1.0 = 0.625
        assert!((t.freqs(0)[0] - 0.625).abs() < 1e-12);
        t.record(0, &[8, 0, 0, 0]);
        // 0.5·0.625 + 0.5·1.0 = 0.8125
        assert!((t.freqs(0)[0] - 0.8125).abs() < 1e-12);
        // closed form after k identical updates: 1 − (1−α)^k · (1 − f₀)
        let mut t2 = ActivationTelemetry::uniform(1, 4, 0.5);
        for _ in 0..6 {
            t2.record(0, &[8, 0, 0, 0]);
        }
        let expect = 1.0 - 0.5f64.powi(6) * 0.75;
        assert!((t2.freqs(0)[0] - expect).abs() < 1e-12);
        assert_eq!(t2.observed_tokens, 48);
        assert_eq!(t2.updates, 6);
    }

    #[test]
    fn live_estimate_stays_normalized() {
        let mut t = ActivationTelemetry::uniform(2, 5, 0.3);
        t.record(0, &[3, 1, 0, 0, 4]);
        t.record(1, &[0, 0, 9, 1, 0]);
        for pos in 0..2 {
            let s: f64 = t.freqs(pos).iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "layer {pos} sum {s}");
        }
    }

    #[test]
    fn drift_zero_before_traffic_and_bounded() {
        let t = ActivationTelemetry::from_counts(&[vec![10, 30, 60]], 0.2);
        assert_eq!(t.drift(0), 0.0);
        let mut t = t;
        for _ in 0..200 {
            t.record(0, &[100, 0, 0]);
        }
        let d = t.drift(0);
        assert!(d > 0.0 && d <= 1.0, "{d}");
        // converged to one-hot: TV distance to [0.1, 0.3, 0.6] is 0.9
        assert!((d - 0.9).abs() < 1e-6, "{d}");
    }

    #[test]
    fn drift_score_monotone_as_mass_moves_away() {
        // keep recording a distribution progressively further from the
        // baseline; each EWMA step must increase drift
        let mut t = ActivationTelemetry::from_counts(&[vec![50, 50, 0, 0]], 0.25);
        let mut last = t.drift(0);
        for _ in 0..20 {
            t.record(0, &[0, 0, 50, 50]);
            let d = t.drift(0);
            assert!(d > last, "drift not monotone: {d} after {last}");
            last = d;
        }
        assert_eq!(t.max_drift(), t.drift(0));
    }

    #[test]
    fn empty_batch_is_noop() {
        let mut t = ActivationTelemetry::uniform(1, 3, 0.5);
        let before = t.freqs(0).to_vec();
        t.record(0, &[0, 0, 0]);
        assert_eq!(t.freqs(0), before.as_slice());
        assert_eq!(t.updates, 0);
    }

    #[test]
    fn rebaseline_resets_drift() {
        let mut t = ActivationTelemetry::uniform(1, 4, 0.5);
        for _ in 0..5 {
            t.record(0, &[9, 1, 0, 0]);
        }
        assert!(t.drift(0) > 0.1);
        t.rebaseline();
        assert_eq!(t.drift(0), 0.0);
        // and keeps tracking from the new reference
        t.record(0, &[0, 0, 0, 9]);
        assert!(t.drift(0) > 0.0);
    }

    #[test]
    fn max_drift_picks_worst_layer() {
        let mut t = ActivationTelemetry::uniform(3, 4, 1.0);
        t.record(1, &[10, 0, 0, 0]); // alpha 1.0: live jumps to one-hot
        assert!((t.max_drift() - t.drift(1)).abs() < 1e-12);
        assert_eq!(t.drift(0), 0.0);
        assert_eq!(t.drift(2), 0.0);
    }
}
