//! Online serving subsystem: continuous batching + live activation
//! telemetry + dynamic precision re-allocation (DESIGN.md §Online-Serving).
//!
//! The offline half of MxMoE solves the precision allocation once against a
//! fixed calibration trace; this module closes the co-design loop at serve
//! time. Production routing distributions drift, and §3's insight — expert
//! activation frequency shapes the optimal mixed-precision configuration —
//! applies to the *live* workload, not the calibration snapshot:
//!
//! ```text
//!           requests ──► [queue]  continuous batcher (tile-set-sized)
//!                            │
//!                            ▼
//!                     engine forward  ──►  [telemetry]  EWMA per-(layer,
//!                            │                expert) activation frequency
//!                            │                        │ drift vs calibration
//!                            ▼                        ▼
//!                       responses            [replan]  warm-started MCKP
//!                                             re-solve on live frequencies
//!                                                     │ delta plan
//!                                                     ▼
//!                                            [hotswap]  re-prepare changed
//!                                             expert slots, generation++
//! ```
//!
//! The coordinator ([`crate::coordinator`]) is rewired on top of these
//! pieces. Since DESIGN.md §Sharded-Serving the loop runs per replica:
//! [`replica`] holds the engine worker threads (one PJRT client, one plan,
//! one telemetry/replan loop each) plus the work-stealing deques and the
//! status board the router scores against. Since DESIGN.md §Decode-Loop
//! the loop also runs at *token* granularity: [`kvcache`] holds each
//! sequence's per-layer K/V state, and [`decode`] schedules mixed
//! prefill/decode steps (tile-budget cut, token streaming, step-granular
//! cancellation) between queue pops — so decode-time expert routing
//! reaches the telemetry the replanner solves on. Since DESIGN.md
//! §HTTP-Front-Door, [`http`] exposes the whole stack over the network:
//! SSE token streaming, disconnect-as-cancel, and admission sheds as
//! 429/503 + `Retry-After`. Everything except the worker body is
//! engine-agnostic and unit-testable without a PJRT runtime.

pub mod decode;
pub mod hotswap;
pub mod http;
pub mod kvcache;
pub mod queue;
pub mod replan;
pub mod replica;
pub mod request;
pub mod telemetry;

pub use decode::{
    kv_quant_from_allocation, DecodePolicy, DecodeScheduler, DecodeStats, FinishedGen,
    StepOutcome,
};
pub use hotswap::{SlotChange, SlotTable, StagedSwap};
pub use http::{HttpBackend, HttpConfig, HttpServer};
pub use kvcache::{KvCache, KvOccupancy, KvPageScheme, KvQuantConfig, SeqKv, KV_PAGE_SIZE};
pub use queue::{
    BatchPolicy, ContinuousBatcher, GenSpec, Request, RequestKind, Response, ShedInfo,
};
pub use replan::{diff_plans, ReplanConfig, ReplanOutcome, Replanner};
pub use replica::{ReplicaOnline, ReplicaSpec, ReplicaStatus, RoutedBatch, WorkQueues};
pub use request::{
    Admission, AdmissionConfig, AdmissionReport, AdmissionState, FinishReason, Priority,
    QosClass, RejectReason, ServeKind, ServeRequest, StreamEvent, Ticket,
};
pub use telemetry::ActivationTelemetry;
