//! Paged per-sequence KV cache + the replica-local page pool
//! (DESIGN.md §KV-Paging, §Decode-Loop).
//!
//! [`SeqKv`] is the incremental-attention state of one sequence: for every
//! transformer layer, the post-RoPE key rows and raw value rows of every
//! position processed so far. Storage is a *page table* rather than one
//! contiguous buffer: fixed-size token pages ([`KV_PAGE_SIZE`] positions,
//! tile-aligned with [`crate::runtime::TILE_MS`]), each holding all layers'
//! K/V for its position range. [`crate::moe::MoeLm::forward_step`] appends
//! the new positions' K/V and attends over the cached prefix by gathering
//! through the page table in position order — the arithmetic (score order,
//! softmax shape, accumulation order) is untouched, so fp32-mode paging is
//! bit-identical to the pre-paging contiguous cache.
//!
//! [`KvCache`] is the pool a replica's decode scheduler allocates from.
//! Three co-designed mechanisms turn the KV token budget into many more
//! concurrent generations than worst-case contiguous reservation allowed:
//!
//! * **Lazy allocation** — admission claims only the prompt's pages plus
//!   one decode-headroom page; later pages are claimed between steps
//!   ([`KvCache::grow`]). When the pool runs dry the scheduler preempts
//!   the *youngest* active generation (deterministic, no deadlock — the
//!   oldest sequence can always force progress).
//! * **Prefix sharing** — sealed pages that cover whole prompt blocks are
//!   published under a content hash of the token prefix (K/V at position
//!   `p` is a pure function of tokens `0..=p`, so a full page across all
//!   layers is a pure function of its token prefix). A later sequence
//!   whose prompt starts with the same blocks holds the same physical
//!   pages ([`std::sync::Arc`] refcounted); it diverges onto private pages
//!   at the first non-matching block (copy-on-divergence). The share map
//!   is keyed per plan generation — a hot-swap invalidates it, because
//!   K/V computed under the old plan no longer match fresh prefills.
//! * **Page quantization** — pages the current step appends to stay fp32;
//!   sealed (full) pages may be group-quantized in place with the
//!   activation-quant machinery ([`crate::quant::uniform`]), per layer
//!   from a [`KvQuantConfig`] derived from calibration sensitivity. The
//!   fp32 default keeps decode bit-identical; quantized-page mode is a
//!   measured accuracy/memory trade reported as average KV bits.
//!
//! Plain data throughout: no engine, no PJRT — unit-testable anywhere.

use std::collections::HashMap;
use std::sync::{Arc, Weak};
use std::time::Instant;

use crate::quant::scheme::GroupSize;
use crate::quant::uniform::{fake_quant_slice, qparams, GroupSpec};
use crate::tensor::Matrix;

/// Default page size in token positions. 16 sits on the exported tile grid
/// (`TILE_MS = [4, 16, 64, 256]`): one full page of decode rows fills a
/// 16-tile exactly, and prompt chunks cut against the tile grid land on
/// page boundaries more often than not.
pub const KV_PAGE_SIZE: usize = 16;

/// Per-layer quantization scheme for sealed KV pages: bit width + group
/// size along the hidden axis (paper convention: −1 ⇒ one group per row).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KvPageScheme {
    pub bits: u8,
    pub group: GroupSize,
}

/// Per-layer sealed-page quantization plan (`schemes[l]` = transformer
/// layer `l`). Built uniformly or from calibration sensitivity: layers the
/// calibration pass found sensitive keep more KV bits, mirroring how the
/// MCKP weight plan spends its bit budget.
#[derive(Clone, Debug, PartialEq)]
pub struct KvQuantConfig {
    pub schemes: Vec<KvPageScheme>,
}

impl KvQuantConfig {
    /// The same scheme for every transformer layer.
    pub fn uniform(layers: usize, bits: u8, group: GroupSize) -> KvQuantConfig {
        KvQuantConfig { schemes: vec![KvPageScheme { bits, group }; layers] }
    }

    /// Select per layer from calibration sensitivity scores (one per
    /// transformer layer, higher = more damage when quantized): layers at
    /// or above the median score get `hi`, the rest `lo` — bits go where
    /// the calibration pass says they matter.
    pub fn from_sensitivity(
        scores: &[f64],
        lo: KvPageScheme,
        hi: KvPageScheme,
    ) -> KvQuantConfig {
        assert!(!scores.is_empty());
        let mut sorted: Vec<f64> = scores.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sorted.len() / 2];
        KvQuantConfig {
            schemes: scores
                .iter()
                .map(|&s| if s >= median { hi } else { lo })
                .collect(),
        }
    }

    /// Mean stored bits per KV value under this plan.
    pub fn avg_bits(&self) -> f64 {
        if self.schemes.is_empty() {
            return 32.0;
        }
        self.schemes.iter().map(|s| s.bits as f64).sum::<f64>() / self.schemes.len() as f64
    }
}

/// Storage mode of one page.
#[derive(Clone, Copy, Debug, PartialEq)]
enum PageMode {
    /// Raw f32 rows — the only mode appends target.
    Fp32,
    /// Sealed and fake-quantized in place (`avg_bits` = mean bits/value
    /// over layers): reads stay `&[f32]`, accounting reports the bits.
    Quantized { avg_bits: f64 },
}

/// One physical page: all layers' K/V for `size` consecutive positions,
/// row-major `[layer][slot][hidden]`. Shared between sequences via `Arc`
/// when it covers a common prompt prefix.
#[derive(Clone, Debug)]
pub struct PageData {
    k: Vec<f32>,
    v: Vec<f32>,
    /// Committed positions (uniform across layers — bumped at
    /// [`SeqKv::advance`], so a page is *sealed* once `filled == size`).
    filled: usize,
    mode: PageMode,
    n_layers: usize,
    hidden: usize,
    size: usize,
}

impl PageData {
    fn new(n_layers: usize, hidden: usize, size: usize) -> PageData {
        PageData {
            k: vec![0.0; n_layers * size * hidden],
            v: vec![0.0; n_layers * size * hidden],
            filled: 0,
            mode: PageMode::Fp32,
            n_layers,
            hidden,
            size,
        }
    }

    #[inline]
    fn row_off(&self, layer: usize, slot: usize) -> usize {
        (layer * self.size + slot) * self.hidden
    }

    #[inline]
    fn k_row(&self, layer: usize, slot: usize) -> &[f32] {
        let o = self.row_off(layer, slot);
        &self.k[o..o + self.hidden]
    }

    #[inline]
    fn v_row(&self, layer: usize, slot: usize) -> &[f32] {
        let o = self.row_off(layer, slot);
        &self.v[o..o + self.hidden]
    }

    /// Fake-quantize every layer's K/V rows in place per `cfg` (group-wise
    /// asymmetric min-max, the activation convention). Idempotent via the
    /// mode flag.
    fn quantize(&mut self, cfg: &KvQuantConfig) {
        if matches!(self.mode, PageMode::Quantized { .. }) {
            return;
        }
        debug_assert_eq!(cfg.schemes.len(), self.n_layers);
        for (l, s) in cfg.schemes.iter().enumerate() {
            let spec = GroupSpec::new(self.hidden, s.group);
            for slot in 0..self.size {
                let o = self.row_off(l, slot);
                for g in 0..spec.num_groups() {
                    let r = o + g * spec.group..o + (g + 1) * spec.group;
                    let pk = qparams(&self.k[r.clone()], s.bits, false);
                    fake_quant_slice(&mut self.k[r.clone()], &pk);
                    let pv = qparams(&self.v[r.clone()], s.bits, false);
                    fake_quant_slice(&mut self.v[r], &pv);
                }
            }
        }
        self.mode = PageMode::Quantized { avg_bits: cfg.avg_bits() };
    }

    fn avg_bits(&self) -> f64 {
        match self.mode {
            PageMode::Fp32 => 32.0,
            PageMode::Quantized { avg_bits } => avg_bits,
        }
    }
}

/// FNV-1a 64 over a token block, chained from the previous block's hash —
/// the content key of a prompt-prefix page. Chaining makes the key a
/// function of the *whole* prefix `tokens[0..(b+1)*page]`, which is the
/// soundness condition for sharing (K/V at position `p` depends on every
/// token `0..=p`).
fn chain_hash(prev: u64, tokens: &[u32]) -> u64 {
    let mut h = prev ^ 0xcbf2_9ce4_8422_2325;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// The KV state of one sequence across all transformer layers: a page
/// table of refcounted pages. The read/append API is unchanged from the
/// contiguous cache, so the decode step path stays bit-identical in fp32
/// mode.
#[derive(Clone, Debug)]
pub struct SeqKv {
    pages: Vec<Arc<PageData>>,
    /// Positions cached so far (uniform across layers between steps).
    len: usize,
    /// Position allowance: standalone caches keep the requested capacity
    /// exactly (strict overflow panics); pool-backed caches track
    /// `pages.len() * page_size` and grow between steps.
    capacity: usize,
    page_size: usize,
    n_layers: usize,
    hidden: usize,
    /// Positions pre-populated by shared prefix pages at allocation —
    /// appends below this mark skip the write (the content is already
    /// there, and writing would break the physical sharing).
    shared_prefix: usize,
    /// Chain hashes of the prompt's full blocks (index = page index) —
    /// what [`KvCache::seal`] registers in the share map.
    block_keys: Vec<u64>,
    /// Pages already processed by [`KvCache::seal`].
    sealed_pages: usize,
}

impl SeqKv {
    /// Reserve a standalone cache of `capacity` positions (eager pages, no
    /// pool accounting) for a model with `layers` transformer layers and
    /// `hidden` channels — the direct-use constructor tests and the
    /// engine-less decode paths rely on.
    pub fn new(layers: usize, hidden: usize, capacity: usize) -> SeqKv {
        SeqKv::with_page_size(layers, hidden, capacity, KV_PAGE_SIZE)
    }

    /// [`new`](Self::new) with an explicit page size (tests exercise tiny
    /// pages to force many page-boundary crossings).
    pub fn with_page_size(
        layers: usize,
        hidden: usize,
        capacity: usize,
        page_size: usize,
    ) -> SeqKv {
        assert!(layers >= 1 && hidden >= 1 && page_size >= 1);
        let n_pages = capacity.div_ceil(page_size);
        SeqKv {
            pages: (0..n_pages)
                .map(|_| Arc::new(PageData::new(layers, hidden, page_size)))
                .collect(),
            len: 0,
            capacity,
            page_size,
            n_layers: layers,
            hidden,
            shared_prefix: 0,
            block_keys: Vec::new(),
            sealed_pages: 0,
        }
    }

    /// Positions cached so far — the absolute position of the next token.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Pages currently in the table.
    pub fn pages_held(&self) -> usize {
        self.pages.len()
    }

    /// Positions pre-populated by shared prefix pages at allocation.
    pub fn shared_prefix(&self) -> usize {
        self.shared_prefix
    }

    /// Append `k_new`/`v_new` (`[s, hidden]`, post-RoPE keys) to `layer`'s
    /// cache at positions `len..len + s`. Every layer of a step must
    /// append the same number of rows; [`advance`](Self::advance) commits
    /// the shared length afterwards. Rows that land on positions a shared
    /// prefix page already holds are *skipped* — the content is a pure
    /// function of the token prefix, so the freshly computed rows are the
    /// rows already there (bit-identical in fp32 mode, debug-asserted).
    pub fn append(&mut self, layer: usize, k_new: &Matrix, v_new: &Matrix) {
        assert_eq!(k_new.rows, v_new.rows);
        assert_eq!(k_new.cols, self.hidden, "hidden mismatch");
        assert!(
            self.len + k_new.rows <= self.capacity,
            "kv overflow: {} + {} > {}",
            self.len,
            k_new.rows,
            self.capacity
        );
        let (h, ps) = (self.hidden, self.page_size);
        for r in 0..k_new.rows {
            let pos = self.len + r;
            let (pi, slot) = (pos / ps, pos % ps);
            if self.pages[pi].filled > slot {
                // pre-populated by a shared prefix page: skip the write so
                // the physical copy stays shared
                #[cfg(debug_assertions)]
                if matches!(self.pages[pi].mode, PageMode::Fp32) {
                    let have = self.pages[pi].k_row(layer, slot);
                    debug_assert!(
                        have.iter()
                            .zip(k_new.row(r))
                            .all(|(a, b)| a.to_bits() == b.to_bits()),
                        "shared prefix page diverged from recomputed keys"
                    );
                }
                continue;
            }
            // private page in practice (only sealed full pages are ever
            // shared); make_mut is the copy-on-write backstop
            let page = Arc::make_mut(&mut self.pages[pi]);
            let o = page.row_off(layer, slot);
            page.k[o..o + h].copy_from_slice(k_new.row(r));
            page.v[o..o + h].copy_from_slice(v_new.row(r));
        }
    }

    /// Commit `s` appended positions after every layer has appended its
    /// rows for the step, bumping the fill level of the pages covered.
    pub fn advance(&mut self, s: usize) {
        assert!(self.len + s <= self.capacity);
        let from = self.len / self.page_size;
        self.len += s;
        for pi in from..self.len.div_ceil(self.page_size) {
            let fill = (self.len - pi * self.page_size).min(self.page_size);
            if self.pages[pi].filled < fill {
                Arc::make_mut(&mut self.pages[pi]).filled = fill;
            }
        }
    }

    /// One cached key row, gathered through the page table.
    #[inline]
    pub fn key_row(&self, layer: usize, pos: usize) -> &[f32] {
        self.pages[pos / self.page_size].k_row(layer, pos % self.page_size)
    }

    #[inline]
    pub fn value_row(&self, layer: usize, pos: usize) -> &[f32] {
        self.pages[pos / self.page_size].v_row(layer, pos % self.page_size)
    }

    /// The contiguous run of key rows starting at `pos` within its page,
    /// clipped to `upto` (exclusive): `(rows, n)` with `n ≥ 1` row of
    /// `hidden` floats each. The attention gather walks the cached prefix
    /// page-run-by-page-run in position order — same rows, same order,
    /// fewer page lookups than a per-position gather.
    #[inline]
    pub fn key_run(&self, layer: usize, pos: usize, upto: usize) -> (&[f32], usize) {
        let (pi, slot) = (pos / self.page_size, pos % self.page_size);
        let n = (upto - pos).min(self.page_size - slot);
        let page = &self.pages[pi];
        let o = page.row_off(layer, slot);
        (&page.k[o..o + n * self.hidden], n)
    }

    #[inline]
    pub fn value_run(&self, layer: usize, pos: usize, upto: usize) -> (&[f32], usize) {
        let (pi, slot) = (pos / self.page_size, pos % self.page_size);
        let n = (upto - pos).min(self.page_size - slot);
        let page = &self.pages[pi];
        let o = page.row_off(layer, slot);
        (&page.v[o..o + n * self.hidden], n)
    }
}

/// Occupancy snapshot of a [`KvCache`] pool. `reserved_tokens` counts
/// *physical* page tokens (shared pages once), `used_tokens` the positions
/// actually appended by live sequences — the gap between the two is the
/// laziness win, and `shared_tokens` the extra logical tokens served by
/// shared physical pages (the prefix-reuse win).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct KvOccupancy {
    /// Physical tokens held by live pages.
    pub reserved_tokens: usize,
    /// Reservation budget of the pool.
    pub budget_tokens: usize,
    /// Live sequences holding pages.
    pub seqs: usize,
    /// High-water mark of `reserved_tokens` over the pool's lifetime.
    pub peak_tokens: usize,
    /// Positions actually appended by live sequences (real fill; overlaid
    /// by the decode scheduler, which owns the sequence lengths).
    pub used_tokens: usize,
    /// Extra logical tokens served by shared physical pages.
    pub shared_tokens: usize,
    /// Sequences freed over the pool's lifetime (exact-accounting check:
    /// every alloc is matched by exactly one free).
    pub freed_seqs: usize,
    /// Mean stored bits per live KV value (32.0 = everything fp32).
    pub avg_kv_bits: f64,
}

impl KvOccupancy {
    /// Reserved fraction of the budget, in `[0, 1]` (can exceed 1 while a
    /// single oversized generation runs on the oversized-when-alone rule).
    pub fn ratio(&self) -> f64 {
        if self.budget_tokens == 0 {
            return 0.0;
        }
        self.reserved_tokens as f64 / self.budget_tokens as f64
    }

    /// Used fraction of the budget, in `[0, 1]` — the real fill.
    pub fn used_ratio(&self) -> f64 {
        if self.budget_tokens == 0 {
            return 0.0;
        }
        self.used_tokens as f64 / self.budget_tokens as f64
    }
}

/// EWMA step for the page-release rate (admission backpressure derives
/// `retry_after` from it).
const RELEASE_ALPHA: f64 = 0.3;

/// Replica-local paged KV pool: token-budgeted in whole pages, with lazy
/// growth, prefix sharing, and sealed-page quantization (module docs).
pub struct KvCache {
    n_layers: usize,
    hidden: usize,
    page_size: usize,
    budget_tokens: usize,
    budget_pages: usize,
    physical_pages: usize,
    peak_pages: usize,
    seqs: usize,
    freed_seqs: usize,
    /// Extra refs outstanding on shared pages (Σ over pages of refs − 1).
    shared_refs: usize,
    quant: Option<KvQuantConfig>,
    quant_pages: usize,
    quant_bits_sum: f64,
    /// Content hash → sealed page, per share epoch. `Weak`: the map never
    /// keeps a page alive — physical accounting stays exact, and a prefix
    /// is reusable exactly while some live sequence still holds it.
    share: HashMap<u64, Weak<PageData>>,
    /// Plan generation the share map is valid for — K/V computed under an
    /// old plan must not seed prefills under a new one.
    epoch: u64,
    /// EWMA of page-release throughput, tokens/second (0 until the first
    /// free) — the admission front door turns pool-full rejections into
    /// `retry_after` hints with it.
    release_tps: f64,
    last_free_at: Option<Instant>,
}

impl KvCache {
    /// Pool with the default page size and no sealed-page quantization —
    /// fp32 paging, bit-identical to the pre-paging decode.
    pub fn new(n_layers: usize, hidden: usize, budget_tokens: usize) -> KvCache {
        KvCache::with_config(n_layers, hidden, budget_tokens, KV_PAGE_SIZE, None)
    }

    pub fn with_config(
        n_layers: usize,
        hidden: usize,
        budget_tokens: usize,
        page_size: usize,
        quant: Option<KvQuantConfig>,
    ) -> KvCache {
        assert!(n_layers >= 1 && hidden >= 1 && budget_tokens >= 1 && page_size >= 1);
        if let Some(q) = &quant {
            assert_eq!(q.schemes.len(), n_layers, "one KV scheme per transformer layer");
        }
        KvCache {
            n_layers,
            hidden,
            page_size,
            budget_tokens,
            budget_pages: (budget_tokens / page_size).max(1),
            physical_pages: 0,
            peak_pages: 0,
            seqs: 0,
            freed_seqs: 0,
            shared_refs: 0,
            quant,
            quant_pages: 0,
            quant_bits_sum: 0.0,
            share: HashMap::new(),
            epoch: 0,
            release_tps: 0.0,
            last_free_at: None,
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Unclaimed pages under the budget.
    pub fn free_pages(&self) -> usize {
        self.budget_pages.saturating_sub(self.physical_pages)
    }

    /// Unclaimed tokens under the budget.
    pub fn free_tokens(&self) -> usize {
        self.free_pages() * self.page_size
    }

    /// EWMA page-release rate, tokens/second (0 until the first free).
    pub fn release_tps(&self) -> f64 {
        self.release_tps
    }

    /// Invalidate the prefix-share map when the serving plan generation
    /// moves (hot-swap): pages computed under the old plan are no longer
    /// bit-compatible with fresh prefills.
    pub fn set_share_epoch(&mut self, epoch: u64) {
        if epoch != self.epoch {
            self.epoch = epoch;
            self.share.clear();
        }
    }

    fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_size).max(1)
    }

    fn claim_pages(&mut self, n: usize) {
        self.physical_pages += n;
        self.peak_pages = self.peak_pages.max(self.physical_pages);
    }

    /// Lazily allocate a sequence cache covering `capacity` positions
    /// (prompt + decode headroom — NOT the worst case; later pages come
    /// from [`grow`](Self::grow)). Full prompt blocks whose chained
    /// content hash matches a sealed page in the share map reuse that
    /// physical page. `None` when the fresh pages needed don't fit the
    /// budget (the caller keeps the sequence pending) — unless the pool is
    /// empty, where an oversized claim is still granted so every
    /// generation eventually runs (the batcher's oversized-single rule).
    pub fn alloc_seq(&mut self, prompt: &[u32], capacity: usize) -> Option<SeqKv> {
        let capacity = capacity.max(1);
        let total_pages = self.pages_for(capacity);
        // chained hashes of the prompt's full blocks
        let full_blocks = (prompt.len() / self.page_size).min(total_pages);
        let mut block_keys = Vec::with_capacity(full_blocks);
        let mut h = self.epoch ^ 0x9e37_79b9_7f4a_7c15;
        for b in 0..full_blocks {
            h = chain_hash(h, &prompt[b * self.page_size..(b + 1) * self.page_size]);
            block_keys.push(h);
        }
        // contiguous shared prefix: stop at the first miss
        let mut shared: Vec<Arc<PageData>> = Vec::new();
        for key in &block_keys {
            let Some(page) = self.share.get(key).and_then(Weak::upgrade) else { break };
            if page.filled < self.page_size {
                break;
            }
            shared.push(page);
        }
        let fresh = total_pages - shared.len();
        if fresh > self.free_pages() && self.seqs > 0 {
            return None;
        }
        self.claim_pages(fresh);
        self.shared_refs += shared.len();
        self.seqs += 1;
        let shared_prefix = shared.len() * self.page_size;
        let mut pages = shared;
        pages.extend(
            (0..fresh).map(|_| Arc::new(PageData::new(self.n_layers, self.hidden, self.page_size))),
        );
        Some(SeqKv {
            pages,
            len: 0,
            capacity: total_pages * self.page_size,
            page_size: self.page_size,
            n_layers: self.n_layers,
            hidden: self.hidden,
            shared_prefix,
            block_keys,
            sealed_pages: 0,
        })
    }

    /// Grow `kv`'s page table until it covers `positions`. `false` when
    /// the budget cannot hold the next page (the scheduler preempts the
    /// youngest sequence and retries, or defers the rows).
    pub fn grow(&mut self, kv: &mut SeqKv, positions: usize) -> bool {
        while kv.capacity < positions {
            if self.free_pages() == 0 {
                return false;
            }
            self.claim_pages(1);
            kv.pages
                .push(Arc::new(PageData::new(self.n_layers, self.hidden, self.page_size)));
            kv.capacity = kv.pages.len() * self.page_size;
        }
        true
    }

    /// [`grow`](Self::grow) past the budget — the no-deadlock escape hatch
    /// for the *oldest* sequence once no younger victim remains. Bounded:
    /// at most one sequence can be over budget, exactly like the
    /// oversized-when-empty admission rule.
    pub fn grow_force(&mut self, kv: &mut SeqKv, positions: usize) {
        while kv.capacity < positions {
            self.claim_pages(1);
            kv.pages
                .push(Arc::new(PageData::new(self.n_layers, self.hidden, self.page_size)));
            kv.capacity = kv.pages.len() * self.page_size;
        }
    }

    /// Seal `kv`'s newly completed pages (between steps): quantize them in
    /// place when a [`KvQuantConfig`] is set (pages still being appended
    /// to stay fp32), and publish prompt-block pages in the share map so
    /// later identical prompts hold the same physical copy.
    pub fn seal(&mut self, kv: &mut SeqKv) {
        let complete = kv.len / self.page_size;
        for pi in kv.sealed_pages..complete {
            if pi * self.page_size >= kv.shared_prefix {
                // freshly filled by this sequence (shared-prefix pages were
                // sealed by their origin sequence)
                if let Some(cfg) = &self.quant {
                    if let Some(page) = Arc::get_mut(&mut kv.pages[pi]) {
                        page.quantize(cfg);
                        self.quant_pages += 1;
                        self.quant_bits_sum += cfg.avg_bits();
                    }
                }
                if let Some(&key) = kv.block_keys.get(pi) {
                    self.share.insert(key, Arc::downgrade(&kv.pages[pi]));
                }
            }
            kv.sealed_pages = pi + 1;
        }
    }

    /// Return a sequence's pages to the pool (finished, cancelled, failed
    /// or preempted generations — the step scheduler calls this between
    /// steps). Accounting is exact: a physical page is released only when
    /// its last holder drops it; dropping an extra ref to a shared page
    /// releases a share, not a page. Underflow debug-asserts (the
    /// double-free class `saturating_sub` used to mask).
    pub fn free(&mut self, kv: SeqKv) {
        let mut released = 0usize;
        for page in &kv.pages {
            if Arc::strong_count(page) == 1 {
                released += 1;
                if let PageMode::Quantized { avg_bits } = page.mode {
                    debug_assert!(self.quant_pages > 0, "quantized-page accounting underflow");
                    self.quant_pages = self.quant_pages.saturating_sub(1);
                    self.quant_bits_sum = (self.quant_bits_sum - avg_bits).max(0.0);
                }
            } else {
                debug_assert!(self.shared_refs > 0, "shared-ref accounting underflow");
                self.shared_refs = self.shared_refs.saturating_sub(1);
            }
        }
        debug_assert!(
            self.physical_pages >= released,
            "page accounting underflow: freeing {released} of {}",
            self.physical_pages
        );
        self.physical_pages = self.physical_pages.saturating_sub(released);
        debug_assert!(self.seqs > 0, "freeing a sequence the pool never allocated");
        self.seqs = self.seqs.saturating_sub(1);
        self.freed_seqs += 1;
        // release-rate EWMA (tokens/second) for admission retry hints
        let now = Instant::now();
        if let Some(t0) = self.last_free_at {
            let dt = now.duration_since(t0).as_secs_f64().max(1e-3);
            let sample = (released * self.page_size) as f64 / dt;
            self.release_tps = if self.release_tps == 0.0 {
                sample
            } else {
                (1.0 - RELEASE_ALPHA) * self.release_tps + RELEASE_ALPHA * sample
            };
        }
        self.last_free_at = Some(now);
        drop(kv);
    }

    /// Mean stored bits per live KV value (32.0 when empty or all-fp32).
    pub fn avg_kv_bits(&self) -> f64 {
        if self.physical_pages == 0 {
            return 32.0;
        }
        let fp32 = (self.physical_pages - self.quant_pages.min(self.physical_pages)) as f64;
        (self.quant_bits_sum + 32.0 * fp32) / self.physical_pages as f64
    }

    pub fn occupancy(&self) -> KvOccupancy {
        KvOccupancy {
            reserved_tokens: self.physical_pages * self.page_size,
            budget_tokens: self.budget_tokens,
            seqs: self.seqs,
            peak_tokens: self.peak_pages * self.page_size,
            used_tokens: 0, // overlaid by the scheduler (owner of seq lengths)
            shared_tokens: self.shared_refs * self.page_size,
            freed_seqs: self.freed_seqs,
            avg_kv_bits: self.avg_kv_bits(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn seqkv_append_advance_and_views() {
        let mut rng = Rng::new(0xCAFE);
        let mut kv = SeqKv::new(2, 8, 16);
        assert!(kv.is_empty());
        assert_eq!((kv.n_layers(), kv.capacity()), (2, 16));
        let k0 = Matrix::randn(3, 8, 1.0, &mut rng);
        let v0 = Matrix::randn(3, 8, 1.0, &mut rng);
        kv.append(0, &k0, &v0);
        kv.append(1, &k0, &v0);
        // before advance the appended rows are visible per position
        assert_eq!(kv.key_row(0, 2), k0.row(2));
        kv.advance(3);
        assert_eq!(kv.len(), 3);
        assert_eq!(kv.key_row(0, 1), k0.row(1));
        assert_eq!(kv.value_row(1, 2), v0.row(2));
        // a second step appends after the committed prefix
        let k1 = Matrix::randn(1, 8, 1.0, &mut rng);
        kv.append(0, &k1, &k1);
        kv.append(1, &k1, &k1);
        kv.advance(1);
        assert_eq!(kv.len(), 4);
        assert_eq!(kv.key_row(0, 3), k1.row(0));
    }

    #[test]
    fn seqkv_paged_rows_cross_page_boundaries() {
        // page size 2, 7 positions → 4 pages; every row lands in the right
        // page slot and runs clip at page boundaries
        let mut rng = Rng::new(0xCAFF);
        let mut kv = SeqKv::with_page_size(1, 4, 7, 2);
        assert_eq!(kv.pages_held(), 4);
        let k = Matrix::randn(7, 4, 1.0, &mut rng);
        let v = Matrix::randn(7, 4, 1.0, &mut rng);
        kv.append(0, &k, &v);
        kv.advance(7);
        for pos in 0..7 {
            assert_eq!(kv.key_row(0, pos), k.row(pos), "pos {pos}");
            assert_eq!(kv.value_row(0, pos), v.row(pos), "pos {pos}");
        }
        // key_run walks page runs in position order, covering every row
        let mut pos = 0usize;
        let mut gathered: Vec<f32> = Vec::new();
        while pos < 7 {
            let (rows, n) = kv.key_run(0, pos, 7);
            assert!(n >= 1 && n <= 2, "runs clip at the 2-position page");
            gathered.extend_from_slice(rows);
            pos += n;
        }
        assert_eq!(gathered, k.data);
    }

    #[test]
    #[should_panic(expected = "kv overflow")]
    fn seqkv_overflow_panics() {
        let mut kv = SeqKv::new(1, 4, 2);
        let rows = Matrix::zeros(3, 4);
        kv.append(0, &rows, &rows);
    }

    #[test]
    fn pool_lazy_alloc_and_exact_free_accounting() {
        let mut pool = KvCache::with_config(2, 8, 64, 16, None);
        // a 40-position prompt claims 3 pages (48 tokens), not 40+max_new
        let prompt: Vec<u32> = (0..40).collect();
        let a = pool.alloc_seq(&prompt, 40).expect("fits");
        let occ = pool.occupancy();
        assert_eq!((occ.reserved_tokens, occ.seqs), (48, 1));
        // the 4th page exists under the budget; the 5th does not
        let mut a = a;
        assert!(pool.grow(&mut a, 64));
        assert_eq!(pool.occupancy().reserved_tokens, 64);
        assert!(!pool.grow(&mut a, 65), "budget exhausted");
        pool.free(a);
        let occ = pool.occupancy();
        assert_eq!((occ.reserved_tokens, occ.seqs, occ.freed_seqs), (0, 0, 1));
        assert_eq!(occ.peak_tokens, 64, "high-water mark survives frees");
    }

    #[test]
    fn pool_grants_one_oversized_sequence_when_empty() {
        let mut pool = KvCache::with_config(1, 4, 16, 16, None);
        let prompt: Vec<u32> = (0..50).collect();
        let big = pool.alloc_seq(&prompt, 50).expect("oversized single sequence must run");
        assert_eq!(pool.occupancy().reserved_tokens, 64, "4 pages of 16");
        assert!(pool.alloc_seq(&[1, 2], 3).is_none(), "pool over budget: nothing else fits");
        pool.free(big);
        assert!(pool.alloc_seq(&[1, 2], 3).is_some());
    }

    /// Fill a pool-backed cache with deterministic rows for `n` positions
    /// (stand-in for real prefill; content is any pure function of the
    /// position so shared-page skip-writes stay consistent).
    fn fill(kv: &mut SeqKv, layers: usize, hidden: usize, n: usize) {
        for _ in 0..n {
            let pos = kv.len();
            let row: Vec<f32> = (0..hidden).map(|c| (pos * hidden + c) as f32).collect();
            let m = Matrix { rows: 1, cols: hidden, data: row };
            for l in 0..layers {
                kv.append(l, &m, &m);
            }
            kv.advance(1);
        }
    }

    #[test]
    fn identical_prompt_prefixes_share_physical_pages() {
        let mut pool = KvCache::with_config(1, 4, 16 * 16, 16, None);
        let prompt: Vec<u32> = (0..32).map(|t| t as u32).collect();
        // sequence A prefills and seals both prompt pages
        let mut a = pool.alloc_seq(&prompt, 33).expect("alloc a");
        assert_eq!(a.shared_prefix(), 0, "nothing to share yet");
        fill(&mut a, 1, 4, 32);
        pool.seal(&mut a);
        let before = pool.occupancy().reserved_tokens;
        // sequence B with the same prompt holds A's physical pages
        let mut b = pool.alloc_seq(&prompt, 33).expect("alloc b");
        assert_eq!(b.shared_prefix(), 32, "both full prompt blocks shared");
        assert_eq!(
            pool.occupancy().reserved_tokens,
            before + 16,
            "only B's tail page is new physical memory"
        );
        assert_eq!(pool.occupancy().shared_tokens, 32);
        // B prefilling over the shared pages skips the writes but reads the
        // same content
        fill(&mut b, 1, 4, 32);
        assert_eq!(b.key_row(0, 5), a.key_row(0, 5));
        // frees in either order keep the accounting exact
        pool.free(a);
        assert_eq!(pool.occupancy().shared_tokens, 0, "B's copy is now the only ref");
        assert!(pool.occupancy().reserved_tokens >= 48 - 16);
        pool.free(b);
        assert_eq!(pool.occupancy().reserved_tokens, 0);
    }

    #[test]
    fn diverging_prompts_copy_at_the_divergent_block() {
        let mut pool = KvCache::with_config(1, 4, 16 * 16, 16, None);
        let a_prompt: Vec<u32> = (0..32).collect();
        let mut b_prompt = a_prompt.clone();
        b_prompt[20] = 999; // diverges inside block 1
        let mut a = pool.alloc_seq(&a_prompt, 32).unwrap();
        fill(&mut a, 1, 4, 32);
        pool.seal(&mut a);
        let b = pool.alloc_seq(&b_prompt, 32).unwrap();
        assert_eq!(b.shared_prefix(), 16, "block 0 shared, block 1 private");
        // divergent content never reaches A's page
        let mut b = b;
        fill(&mut b, 1, 4, 32);
        assert_eq!(b.key_row(0, 3), a.key_row(0, 3), "shared block identical");
        pool.free(a);
        pool.free(b);
        assert_eq!(pool.occupancy().reserved_tokens, 0);
    }

    #[test]
    fn share_map_epoch_invalidates_on_plan_swap() {
        let mut pool = KvCache::with_config(1, 4, 256, 16, None);
        let prompt: Vec<u32> = (0..16).collect();
        let mut a = pool.alloc_seq(&prompt, 17).unwrap();
        fill(&mut a, 1, 4, 16);
        pool.seal(&mut a);
        pool.set_share_epoch(1);
        let b = pool.alloc_seq(&prompt, 17).unwrap();
        assert_eq!(b.shared_prefix(), 0, "old-plan pages must not seed new prefills");
        pool.free(a);
        pool.free(b);
    }

    #[test]
    fn sealed_pages_quantize_and_report_avg_bits() {
        let quant = KvQuantConfig::uniform(2, 4, -1);
        let mut pool = KvCache::with_config(2, 8, 256, 16, Some(quant));
        let prompt: Vec<u32> = (0..16).collect();
        let mut a = pool.alloc_seq(&prompt, 20).unwrap();
        assert_eq!(pool.avg_kv_bits(), 32.0, "nothing sealed yet");
        fill(&mut a, 2, 8, 18);
        pool.seal(&mut a);
        // one of two pages sealed+quantized: avg = (4 + 32) / 2
        assert!((pool.avg_kv_bits() - 18.0).abs() < 1e-9);
        let occ = pool.occupancy();
        assert!((occ.avg_kv_bits - 18.0).abs() < 1e-9);
        // quantized rows are decodable approximations, not the raw values
        let raw: Vec<f32> = (0..8).map(|c| (5 * 8 + c) as f32).collect();
        assert_ne!(a.key_row(0, 5), &raw[..], "sealed page was fake-quantized");
        pool.free(a);
        assert_eq!(pool.avg_kv_bits(), 32.0, "quant accounting drains with the page");
    }

    #[test]
    fn quant_config_from_sensitivity_spends_bits_on_sensitive_layers() {
        let lo = KvPageScheme { bits: 4, group: -1 };
        let hi = KvPageScheme { bits: 8, group: -1 };
        let cfg = KvQuantConfig::from_sensitivity(&[0.1, 0.9, 0.2, 0.8], lo, hi);
        assert_eq!(
            cfg.schemes.iter().map(|s| s.bits).collect::<Vec<_>>(),
            vec![4, 8, 4, 8]
        );
        assert!((cfg.avg_bits() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn release_rate_warms_after_frees() {
        let mut pool = KvCache::with_config(1, 4, 256, 16, None);
        assert_eq!(pool.release_tps(), 0.0);
        let a = pool.alloc_seq(&[1, 2, 3], 4).unwrap();
        let b = pool.alloc_seq(&[4, 5, 6], 4).unwrap();
        pool.free(a);
        pool.free(b);
        assert!(pool.release_tps() > 0.0, "EWMA warmed by the second free");
    }
}
