//! Per-sequence KV cache + the replica-local budgeted slot pool
//! (DESIGN.md §Decode-Loop).
//!
//! [`SeqKv`] is the incremental-attention state of one sequence: for every
//! transformer layer, the post-RoPE key rows and raw value rows of every
//! position processed so far. [`crate::moe::MoeLm::forward_step`] appends
//! the new positions' K/V and attends over the cached prefix, which is what
//! makes a decode step O(1) model passes instead of re-forwarding the whole
//! sequence — and, because every op on the step path is row-independent,
//! bit-identical to the whole-sequence forward.
//!
//! [`KvCache`] is the pool a replica's decode scheduler allocates from: a
//! token budget (not a slot count — sequences reserve `prompt +
//! max_new_tokens` capacity up front, so admission can never strand a
//! generation mid-decode without cache room), occupancy accounting for the
//! metrics, and explicit [`free`](KvCache::free) so a cancelled or finished
//! generation returns its reservation between decode steps.
//!
//! Plain data throughout: no engine, no PJRT — unit-testable anywhere.

use crate::tensor::Matrix;

/// One layer's cached keys/values: `[capacity, hidden]` row-major, filled
/// to `SeqKv::len` rows. Keys are stored *after* RoPE so a decode step
/// never re-rotates the prefix.
#[derive(Clone, Debug)]
pub struct LayerKv {
    pub k: Matrix,
    pub v: Matrix,
}

/// The KV state of one sequence across all transformer layers.
#[derive(Clone, Debug)]
pub struct SeqKv {
    layers: Vec<LayerKv>,
    /// Positions cached so far (uniform across layers between steps).
    len: usize,
    /// Reserved rows per layer.
    capacity: usize,
}

impl SeqKv {
    /// Reserve a cache of `capacity` positions for a model with `layers`
    /// transformer layers and `hidden` channels.
    pub fn new(layers: usize, hidden: usize, capacity: usize) -> SeqKv {
        SeqKv {
            layers: (0..layers)
                .map(|_| LayerKv {
                    k: Matrix::zeros(capacity, hidden),
                    v: Matrix::zeros(capacity, hidden),
                })
                .collect(),
            len: 0,
            capacity,
        }
    }

    /// Positions cached so far — the absolute position of the next token.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Append `k_new`/`v_new` (`[s, hidden]`, post-RoPE keys) to `layer`'s
    /// cache. Every layer of a step must append the same number of rows;
    /// [`advance`](Self::advance) commits the shared length afterwards.
    pub fn append(&mut self, layer: usize, k_new: &Matrix, v_new: &Matrix) {
        assert_eq!(k_new.rows, v_new.rows);
        let l = &mut self.layers[layer];
        assert_eq!(k_new.cols, l.k.cols, "hidden mismatch");
        assert!(
            self.len + k_new.rows <= self.capacity,
            "kv overflow: {} + {} > {}",
            self.len,
            k_new.rows,
            self.capacity
        );
        let h = l.k.cols;
        l.k.data[self.len * h..(self.len + k_new.rows) * h].copy_from_slice(&k_new.data);
        l.v.data[self.len * h..(self.len + v_new.rows) * h].copy_from_slice(&v_new.data);
    }

    /// Commit `s` appended positions after every layer has appended its
    /// rows for the step.
    pub fn advance(&mut self, s: usize) {
        assert!(self.len + s <= self.capacity);
        self.len += s;
    }

    /// Cached key rows of `layer` (`[len + pending, hidden]` view,
    /// `pending` = rows appended this step but not yet advanced — the
    /// attention of the appending step reads them through `upto`).
    pub fn keys(&self, layer: usize, upto: usize) -> &[f32] {
        let l = &self.layers[layer];
        &l.k.data[..upto * l.k.cols]
    }

    pub fn values(&self, layer: usize, upto: usize) -> &[f32] {
        let l = &self.layers[layer];
        &l.v.data[..upto * l.v.cols]
    }

    /// One cached key row.
    pub fn key_row(&self, layer: usize, pos: usize) -> &[f32] {
        self.layers[layer].k.row(pos)
    }

    pub fn value_row(&self, layer: usize, pos: usize) -> &[f32] {
        self.layers[layer].v.row(pos)
    }
}

/// Occupancy snapshot of a [`KvCache`] pool.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KvOccupancy {
    /// Tokens reserved by live sequences.
    pub reserved_tokens: usize,
    /// Reservation budget of the pool.
    pub budget_tokens: usize,
    /// Live sequences holding a reservation.
    pub seqs: usize,
    /// High-water mark of `reserved_tokens` over the pool's lifetime.
    pub peak_tokens: usize,
}

impl KvOccupancy {
    /// Reserved fraction of the budget, in `[0, 1]`.
    pub fn ratio(&self) -> f64 {
        if self.budget_tokens == 0 {
            return 0.0;
        }
        self.reserved_tokens as f64 / self.budget_tokens as f64
    }
}

/// Replica-local KV reservation pool. Token-budgeted rather than
/// slot-counted: a sequence reserves its worst-case length (prompt +
/// max_new_tokens) at admission, so a generation admitted to the decode
/// loop can always run to completion — backpressure happens *before*
/// prefill, never mid-decode.
pub struct KvCache {
    n_layers: usize,
    hidden: usize,
    budget_tokens: usize,
    reserved_tokens: usize,
    seqs: usize,
    peak_tokens: usize,
}

impl KvCache {
    pub fn new(n_layers: usize, hidden: usize, budget_tokens: usize) -> KvCache {
        assert!(n_layers >= 1 && hidden >= 1 && budget_tokens >= 1);
        KvCache {
            n_layers,
            hidden,
            budget_tokens,
            reserved_tokens: 0,
            seqs: 0,
            peak_tokens: 0,
        }
    }

    /// Try to reserve a `capacity`-position cache. `None` when the budget
    /// cannot hold it (the caller keeps the sequence pending). A single
    /// over-budget sequence is still granted when the pool is empty —
    /// an oversized generation must run eventually, exactly like the
    /// batcher's oversized-single-request rule.
    pub fn alloc(&mut self, capacity: usize) -> Option<SeqKv> {
        assert!(capacity >= 1);
        if self.reserved_tokens + capacity > self.budget_tokens && self.seqs > 0 {
            return None;
        }
        self.reserved_tokens += capacity;
        self.seqs += 1;
        self.peak_tokens = self.peak_tokens.max(self.reserved_tokens);
        Some(SeqKv::new(self.n_layers, self.hidden, capacity))
    }

    /// Return a sequence's reservation to the pool (finished, cancelled or
    /// failed generations — the step scheduler calls this between steps).
    pub fn free(&mut self, kv: SeqKv) {
        self.reserved_tokens = self.reserved_tokens.saturating_sub(kv.capacity());
        self.seqs = self.seqs.saturating_sub(1);
    }

    pub fn occupancy(&self) -> KvOccupancy {
        KvOccupancy {
            reserved_tokens: self.reserved_tokens,
            budget_tokens: self.budget_tokens,
            seqs: self.seqs,
            peak_tokens: self.peak_tokens,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn seqkv_append_advance_and_views() {
        let mut rng = Rng::new(0xCAFE);
        let mut kv = SeqKv::new(2, 8, 16);
        assert!(kv.is_empty());
        assert_eq!((kv.n_layers(), kv.capacity()), (2, 16));
        let k0 = Matrix::randn(3, 8, 1.0, &mut rng);
        let v0 = Matrix::randn(3, 8, 1.0, &mut rng);
        kv.append(0, &k0, &v0);
        kv.append(1, &k0, &v0);
        // before advance the appended rows are visible through `upto`
        assert_eq!(kv.keys(0, 3), &k0.data[..]);
        kv.advance(3);
        assert_eq!(kv.len(), 3);
        assert_eq!(kv.key_row(0, 1), k0.row(1));
        assert_eq!(kv.value_row(1, 2), v0.row(2));
        // a second step appends after the committed prefix
        let k1 = Matrix::randn(1, 8, 1.0, &mut rng);
        kv.append(0, &k1, &k1);
        kv.append(1, &k1, &k1);
        kv.advance(1);
        assert_eq!(kv.len(), 4);
        assert_eq!(kv.key_row(0, 3), k1.row(0));
        assert_eq!(kv.keys(0, 4).len(), 4 * 8);
    }

    #[test]
    #[should_panic(expected = "kv overflow")]
    fn seqkv_overflow_panics() {
        let mut kv = SeqKv::new(1, 4, 2);
        let rows = Matrix::zeros(3, 4);
        kv.append(0, &rows, &rows);
    }

    #[test]
    fn pool_budget_reserves_and_frees() {
        let mut pool = KvCache::new(2, 8, 100);
        let a = pool.alloc(60).expect("fits");
        assert_eq!(pool.occupancy().reserved_tokens, 60);
        assert!(pool.alloc(60).is_none(), "61..120 > budget");
        let b = pool.alloc(40).expect("exactly fills the budget");
        let occ = pool.occupancy();
        assert_eq!((occ.reserved_tokens, occ.seqs), (100, 2));
        assert!((occ.ratio() - 1.0).abs() < 1e-12);
        pool.free(a);
        assert_eq!(pool.occupancy().reserved_tokens, 40);
        let c = pool.alloc(60).expect("freed reservation is reusable");
        pool.free(b);
        pool.free(c);
        let occ = pool.occupancy();
        assert_eq!((occ.reserved_tokens, occ.seqs), (0, 0));
        assert_eq!(occ.peak_tokens, 100, "high-water mark survives frees");
    }

    #[test]
    fn pool_grants_one_oversized_sequence_when_empty() {
        let mut pool = KvCache::new(1, 4, 10);
        let big = pool.alloc(50).expect("oversized single sequence must run");
        assert_eq!(pool.occupancy().reserved_tokens, 50);
        assert!(pool.alloc(1).is_none(), "pool over budget: nothing else fits");
        pool.free(big);
        assert!(pool.alloc(10).is_some());
    }
}
