//! Hot-swapping expert runtime schemes behind a generation counter.
//!
//! A delta plan from the replanner names the `(layer, expert)` slots whose
//! runtime family changed. Applying it re-prepares *only* those slots'
//! weight literals (via [`crate::runtime::expert_weights`]) — the rest of
//! the table is untouched, so a swap costs O(changed experts), not a full
//! engine rebuild. Preparation is two-phase: every changed slot is
//! re-quantized first, and only if all succeed is the table mutated and
//! the generation bumped — a failed swap leaves the serving plan intact.
//!
//! The engine processes batches serially, and swaps are applied strictly
//! between batches, so a batch always runs entirely on one generation:
//! requests in flight when the delta lands finish on the old plan, and the
//! generation stamped into each response records which plan served it.

use anyhow::Result;

use crate::alloc::Allocation;
use crate::moe::MoeLm;
use crate::runtime::{PreparedExpert, RuntimeScheme};

/// One slot's scheme transition in a delta plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotChange {
    /// MoE-block position (index into the engine's slot table, not the
    /// transformer layer index).
    pub block_pos: usize,
    pub expert: usize,
    pub old: RuntimeScheme,
    pub new: RuntimeScheme,
}

/// Per-(MoE-layer, expert) runtime assignment + prepared weight literals.
pub struct ExpertSlot {
    pub scheme: RuntimeScheme,
    pub prepared: PreparedExpert,
    /// Generation at which this slot's literals were (re-)prepared.
    pub generation: u64,
}

/// The engine's live expert table: `slots[block_pos][expert]`, routed then
/// shared per MoE layer, plus the plan generation counter.
pub struct SlotTable {
    slots: Vec<Vec<ExpertSlot>>,
    generation: u64,
}

impl SlotTable {
    /// Quantize + lay out every expert per the allocation (generation 0).
    /// The allocated (possibly per-linear) schemes map to the expert's
    /// runtime family via the gate linear — runtime executables are
    /// per-expert uniform; per-linear mixing within an expert is an
    /// accuracy-side refinement.
    pub fn build(lm: &MoeLm, allocation: &Allocation) -> Result<SlotTable> {
        let mut slots = Vec::new();
        for (pos, (_, block)) in lm.moe_blocks().iter().enumerate() {
            let mut layer_slots = Vec::new();
            for e in 0..block.total_experts() {
                let scheme = RuntimeScheme::from_quant(&allocation.schemes[pos][e][0]);
                let prepared = PreparedExpert::prepare(block.expert_at(e), scheme)?;
                layer_slots.push(ExpertSlot { scheme, prepared, generation: 0 });
            }
            slots.push(layer_slots);
        }
        Ok(SlotTable { slots, generation: 0 })
    }

    pub fn slot(&self, block_pos: usize, expert: usize) -> &ExpertSlot {
        &self.slots[block_pos][expert]
    }

    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn n_layers(&self) -> usize {
        self.slots.len()
    }

    /// Snapshot of the live scheme table: runtime family per
    /// `[block_pos][expert slot]` (routed then shared). What a replica
    /// publishes for the router's expert-affinity scoring.
    pub fn scheme_table(&self) -> Vec<Vec<RuntimeScheme>> {
        self.slots
            .iter()
            .map(|layer| layer.iter().map(|s| s.scheme).collect())
            .collect()
    }

    /// Scheme histogram for reporting.
    pub fn scheme_counts(&self) -> Vec<(RuntimeScheme, usize)> {
        let mut counts = Vec::new();
        for s in RuntimeScheme::ALL {
            let n = self
                .slots
                .iter()
                .flat_map(|l| l.iter())
                .filter(|slot| slot.scheme == s)
                .count();
            if n > 0 {
                counts.push((s, n));
            }
        }
        counts
    }

    /// Apply a delta plan: re-prepare exactly the changed slots, then bump
    /// the generation. Returns the number of slots actually swapped.
    /// No-op changes (`old == new`, or the slot already carries `new`) are
    /// skipped; a preparation failure mutates nothing.
    pub fn apply(&mut self, lm: &MoeLm, changes: &[SlotChange]) -> Result<usize> {
        let blocks = lm.moe_blocks();
        // phase 1: quantize + lay out all changed experts (fallible)
        let mut staged: Vec<(usize, usize, RuntimeScheme, PreparedExpert)> = Vec::new();
        for ch in changes {
            let slot = &self.slots[ch.block_pos][ch.expert];
            debug_assert_eq!(
                slot.scheme, ch.old,
                "delta plan raced: slot ({}, {}) is {:?}, delta expected {:?}",
                ch.block_pos, ch.expert, slot.scheme, ch.old
            );
            if slot.scheme == ch.new {
                continue;
            }
            let (_, block) = blocks[ch.block_pos];
            let prepared = PreparedExpert::prepare(block.expert_at(ch.expert), ch.new)?;
            staged.push((ch.block_pos, ch.expert, ch.new, prepared));
        }
        if staged.is_empty() {
            return Ok(0);
        }
        // phase 2: install (infallible) under a fresh generation
        self.generation += 1;
        let swapped = staged.len();
        for (pos, e, scheme, prepared) in staged {
            self.slots[pos][e] = ExpertSlot { scheme, prepared, generation: self.generation };
        }
        Ok(swapped)
    }
}
