//! Hot-swapping expert runtime schemes behind a generation counter.
//!
//! A delta plan from the replanner names the `(layer, expert)` slots whose
//! runtime family changed. Applying it re-prepares *only* those slots'
//! weight literals (via [`crate::runtime::expert_weights`]) — the rest of
//! the table is untouched, so a swap costs O(changed experts), not a full
//! engine rebuild. Preparation is two-phase: every changed slot is
//! re-quantized first, and only if all succeed is the table mutated and
//! the generation bumped — a failed swap leaves the serving plan intact.
//!
//! Since the decode redesign the two phases can run on *different
//! threads*: [`SwapStagingJob`] clones the changed experts' weights out of
//! the model and re-quantizes them anywhere (the payloads are plain `Send`
//! data — no literals, no PJRT), and only the generation-counted
//! [`SlotTable::install_staged`] flip runs on the engine thread. That
//! hides swap latency behind serving instead of stalling the batch loop on
//! re-quantization ([`crate::coordinator::engine::ServingEngine::maybe_begin_replan`]).
//!
//! The engine processes batches (and decode steps) serially, and swaps are
//! applied strictly between them, so a batch always runs entirely on one
//! generation: requests in flight when the delta lands finish on the old
//! plan, and the generation stamped into each response records which plan
//! served it.

use anyhow::Result;

use crate::alloc::Allocation;
use crate::moe::{ExpertWeights, MoeLm};
use crate::runtime::{PreparedExpert, QuantizedExpertData, RuntimeScheme};

/// One slot's scheme transition in a delta plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotChange {
    /// MoE-block position (index into the engine's slot table, not the
    /// transformer layer index).
    pub block_pos: usize,
    pub expert: usize,
    pub old: RuntimeScheme,
    pub new: RuntimeScheme,
}

/// Per-(MoE-layer, expert) runtime assignment + prepared weight literals.
pub struct ExpertSlot {
    pub scheme: RuntimeScheme,
    pub prepared: PreparedExpert,
    /// Generation at which this slot's literals were (re-)prepared.
    pub generation: u64,
}

/// The engine's live expert table: `slots[block_pos][expert]`, routed then
/// shared per MoE layer, plus the plan generation counter.
pub struct SlotTable {
    slots: Vec<Vec<ExpertSlot>>,
    generation: u64,
}

impl SlotTable {
    /// Quantize + lay out every expert per the allocation (generation 0).
    /// The allocated (possibly per-linear) schemes map to the expert's
    /// runtime family via the gate linear — runtime executables are
    /// per-expert uniform; per-linear mixing within an expert is an
    /// accuracy-side refinement.
    pub fn build(lm: &MoeLm, allocation: &Allocation) -> Result<SlotTable> {
        let mut slots = Vec::new();
        for (pos, (_, block)) in lm.moe_blocks().iter().enumerate() {
            let mut layer_slots = Vec::new();
            for e in 0..block.total_experts() {
                let scheme = RuntimeScheme::from_quant(&allocation.schemes[pos][e][0]);
                let prepared = PreparedExpert::prepare(block.expert_at(e), scheme)?;
                layer_slots.push(ExpertSlot { scheme, prepared, generation: 0 });
            }
            slots.push(layer_slots);
        }
        Ok(SlotTable { slots, generation: 0 })
    }

    pub fn slot(&self, block_pos: usize, expert: usize) -> &ExpertSlot {
        &self.slots[block_pos][expert]
    }

    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn n_layers(&self) -> usize {
        self.slots.len()
    }

    /// Snapshot of the live scheme table: runtime family per
    /// `[block_pos][expert slot]` (routed then shared). What a replica
    /// publishes for the router's expert-affinity scoring.
    pub fn scheme_table(&self) -> Vec<Vec<RuntimeScheme>> {
        self.slots
            .iter()
            .map(|layer| layer.iter().map(|s| s.scheme).collect())
            .collect()
    }

    /// Scheme histogram for reporting.
    pub fn scheme_counts(&self) -> Vec<(RuntimeScheme, usize)> {
        let mut counts = Vec::new();
        for s in RuntimeScheme::ALL {
            let n = self
                .slots
                .iter()
                .flat_map(|l| l.iter())
                .filter(|slot| slot.scheme == s)
                .count();
            if n > 0 {
                counts.push((s, n));
            }
        }
        counts
    }

    /// Apply a delta plan: re-prepare exactly the changed slots, then bump
    /// the generation. Returns the number of slots actually swapped.
    /// No-op changes (`old == new`, or the slot already carries `new`) are
    /// skipped; a preparation failure mutates nothing. This is the
    /// synchronous composition of [`SwapStagingJob`] + [`install_staged`](Self::install_staged)
    /// — the replica loop runs the two halves on different threads instead.
    pub fn apply(&mut self, lm: &MoeLm, changes: &[SlotChange]) -> Result<usize> {
        let staged = SwapStagingJob::collect(lm, self, changes).run()?;
        self.install_staged(staged)
    }

    /// Install an off-thread-staged swap: materialize the literals (cheap
    /// bulk copies) and flip the slots under a fresh generation. Two-phase
    /// like [`apply`](Self::apply): a literal-creation failure mutates
    /// nothing. Returns the number of slots swapped.
    pub fn install_staged(&mut self, staged: StagedSwap) -> Result<usize> {
        let mut prepared: Vec<(usize, usize, RuntimeScheme, PreparedExpert)> = Vec::new();
        for (pos, e, scheme, data) in staged.slots {
            prepared.push((pos, e, scheme, data.into_prepared()?));
        }
        if prepared.is_empty() {
            return Ok(0);
        }
        self.generation += 1;
        let swapped = prepared.len();
        for (pos, e, scheme, p) in prepared {
            self.slots[pos][e] = ExpertSlot { scheme, prepared: p, generation: self.generation };
        }
        Ok(swapped)
    }
}

/// The off-thread half of a hot-swap: the changed slots' *cloned* expert
/// weights, so [`run`](Self::run) borrows nothing from the live model and
/// can execute on any worker thread while the engine keeps serving.
pub struct SwapStagingJob {
    changes: Vec<(SlotChange, ExpertWeights)>,
}

/// A finished staging job: quantized payloads per changed slot, ready for
/// the engine thread's generation-counted flip
/// ([`SlotTable::install_staged`]). Plain `Send` data.
pub struct StagedSwap {
    slots: Vec<(usize, usize, RuntimeScheme, QuantizedExpertData)>,
    /// Wall clock the staging worker spent re-quantizing (trace span).
    staging_s: f64,
}

impl StagedSwap {
    /// Slots this swap will flip.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Off-thread re-quantization wall clock.
    pub fn staging_s(&self) -> f64 {
        self.staging_s
    }
}

impl SwapStagingJob {
    /// Snapshot everything the staging worker needs: the changed experts'
    /// weights (cloned) and their target schemes. No-op changes — the slot
    /// already carries the target family — are dropped here, against the
    /// *current* table.
    pub fn collect(lm: &MoeLm, table: &SlotTable, changes: &[SlotChange]) -> SwapStagingJob {
        let blocks = lm.moe_blocks();
        let mut out = Vec::new();
        for ch in changes {
            let slot = &table.slots[ch.block_pos][ch.expert];
            debug_assert_eq!(
                slot.scheme, ch.old,
                "delta plan raced: slot ({}, {}) is {:?}, delta expected {:?}",
                ch.block_pos, ch.expert, slot.scheme, ch.old
            );
            if slot.scheme == ch.new {
                continue;
            }
            let (_, block) = blocks[ch.block_pos];
            out.push((*ch, block.expert_at(ch.expert).clone()));
        }
        SwapStagingJob { changes: out }
    }

    /// Changed slots this job will re-quantize.
    pub fn len(&self) -> usize {
        self.changes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.changes.is_empty()
    }

    /// Re-quantize every changed expert (CPU-heavy, fallible; callable on
    /// a worker thread — `self` owns its weights).
    pub fn run(self) -> Result<StagedSwap> {
        let start = std::time::Instant::now();
        let mut slots = Vec::with_capacity(self.changes.len());
        for (ch, weights) in self.changes {
            let data = QuantizedExpertData::quantize(&weights, ch.new)?;
            slots.push((ch.block_pos, ch.expert, ch.new, data));
        }
        Ok(StagedSwap { slots, staging_s: start.elapsed().as_secs_f64() })
    }
}
