//! Drift-triggered incremental re-allocation.
//!
//! When live telemetry drifts past a threshold, the MCKP allocation
//! (Eq. 7) is re-solved with the *live* activation frequencies as the
//! runtime-model weights — the sensitivity table Δ and the memory budget
//! are workload-independent and reused from calibration time, so a replan
//! costs one near-linear MCKP solve, not a calibration pass. The solve is
//! warm-started from the currently-serving plan
//! ([`crate::alloc::solve_mckp_warm`]), which guarantees the new plan is
//! never worse than the incumbent under the observed workload. The diff
//! between old and new plans becomes a delta of [`SlotChange`]s for the
//! hot-swapper.

use anyhow::Result;

use crate::alloc::{allocate_with_frequencies, Allocation, AllocatorConfig, SensitivityTable};
use crate::costmodel::gpu::GpuSpec;
use crate::moe::ModelConfig;
use crate::quant::scheme::SchemeRegistry;
use crate::runtime::RuntimeScheme;

use super::hotswap::SlotChange;

/// When and how aggressively to re-solve.
#[derive(Clone, Debug)]
pub struct ReplanConfig {
    /// Total-variation drift that triggers a re-solve.
    pub drift_threshold: f64,
    /// Hysteresis: minimum routed token-assignments observed between
    /// consecutive replans (prevents thrashing on noisy small batches).
    pub min_tokens_between: usize,
    /// Allocator settings for the re-solve (same `r`, budget and
    /// granularity as the offline solve unless deliberately changed).
    pub alloc: AllocatorConfig,
}

impl Default for ReplanConfig {
    fn default() -> Self {
        ReplanConfig {
            drift_threshold: 0.15,
            min_tokens_between: 2048,
            alloc: AllocatorConfig::default(),
        }
    }
}

/// Everything a re-solve needs that is workload-independent: the hardware
/// model, the scheme registry and the calibration-time sensitivity table.
pub struct Replanner {
    pub gpu: GpuSpec,
    pub registry: SchemeRegistry,
    pub sens: SensitivityTable,
    pub cfg: ReplanConfig,
}

impl Replanner {
    /// Re-solve the allocation with live frequencies as weights, warm-
    /// started from the currently-serving plan.
    pub fn replan(
        &self,
        model: &ModelConfig,
        freqs: &[Vec<f64>],
        current: &Allocation,
    ) -> Result<Allocation> {
        self.replan_with_r(model, freqs, current, None)
    }

    /// Like [`replan`](Self::replan), with the accuracy/perf exponent `r`
    /// overridden — the QoS path: the engine blends the served
    /// [`crate::serve::QosClass`] mix into an effective `r` and re-solves
    /// with it instead of the static config value.
    pub fn replan_with_r(
        &self,
        model: &ModelConfig,
        freqs: &[Vec<f64>],
        current: &Allocation,
        r: Option<f64>,
    ) -> Result<Allocation> {
        let mut alloc = self.cfg.alloc.clone();
        if let Some(r) = r {
            alloc.r = r;
        }
        allocate_with_frequencies(
            model,
            &self.gpu,
            &self.registry,
            &self.sens,
            freqs,
            &alloc,
            Some(current),
        )
    }
}

/// What a triggered replan did (reported through the serving metrics).
#[derive(Clone, Copy, Debug)]
pub struct ReplanOutcome {
    /// Drift score that triggered the re-solve.
    pub drift: f64,
    /// Slots whose runtime family changed (size of the delta plan).
    pub changes: usize,
    /// Slots actually re-prepared by the hot-swapper.
    pub swapped: usize,
}

/// Diff two allocations at runtime-family granularity: one [`SlotChange`]
/// per (layer, expert) whose serving executable family differs. Per-linear
/// scheme changes that map to the same runtime family produce no change —
/// the runtime serves families, not exact schemes.
pub fn diff_plans(old: &Allocation, new: &Allocation) -> Vec<SlotChange> {
    assert_eq!(old.schemes.len(), new.schemes.len(), "plan layer count mismatch");
    let mut changes = Vec::new();
    for (pos, (olds, news)) in old.schemes.iter().zip(&new.schemes).enumerate() {
        assert_eq!(olds.len(), news.len(), "plan expert count mismatch at layer {pos}");
        for (e, (o, n)) in olds.iter().zip(news).enumerate() {
            let of = RuntimeScheme::from_quant(&o[0]);
            let nf = RuntimeScheme::from_quant(&n[0]);
            if of != nf {
                changes.push(SlotChange { block_pos: pos, expert: e, old: of, new: nf });
            }
        }
    }
    changes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::Granularity;
    use crate::quant::QuantScheme;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            vocab: 32,
            hidden: 16,
            layers: 2,
            heads: 2,
            n_experts: 4,
            n_shared: 1,
            topk: 2,
            inter: 8,
            dense_first: false,
            seq_len: 12,
        }
    }

    /// Sensitivity table with zero Δ everywhere (shape-only stand-in: the
    /// replanner must not need a live calibration pass).
    fn zero_sens(cfg: &ModelConfig, registry: &SchemeRegistry) -> SensitivityTable {
        let schemes: Vec<QuantScheme> =
            registry.schemes.iter().copied().filter(|s| !s.is_fp16()).collect();
        let total = cfg.n_experts + cfg.n_shared;
        let n_blocks = cfg.moe_layers().len();
        SensitivityTable {
            delta: (0..n_blocks)
                .map(|_| {
                    (0..total)
                        .map(|_| {
                            [
                                vec![0.0; schemes.len()],
                                vec![0.0; schemes.len()],
                                vec![0.0; schemes.len()],
                            ]
                        })
                        .collect()
                })
                .collect(),
            schemes,
        }
    }

    fn replanner(cfg: &ModelConfig) -> Replanner {
        let registry = SchemeRegistry::weight_activation();
        let sens = zero_sens(cfg, &registry);
        Replanner {
            gpu: GpuSpec::rtx4090(),
            registry,
            sens,
            cfg: ReplanConfig {
                drift_threshold: 0.1,
                min_tokens_between: 0,
                alloc: AllocatorConfig {
                    r: 0.5,
                    target_avg_bits: 6.0,
                    granularity: Granularity::Expert,
                    batch_tokens: 128,
                },
            },
        }
    }

    #[test]
    fn replan_produces_well_formed_allocation() {
        let cfg = tiny_cfg();
        let rp = replanner(&cfg);
        let current = Allocation::uniform(&cfg, QuantScheme::W8A8);
        let freqs = vec![vec![0.25; 4]; 2];
        let plan = rp.replan(&cfg, &freqs, &current).unwrap();
        assert_eq!(plan.layers, cfg.moe_layers());
        assert_eq!(plan.schemes.len(), 2);
        for layer in &plan.schemes {
            assert_eq!(layer.len(), 5); // 4 routed + 1 shared
        }
        // budget respected: average bits within the 6-bit target + overhead
        assert!(plan.avg_weight_bits(&cfg) <= 6.0 + 0.5);
    }

    #[test]
    fn replan_warm_start_is_stable_under_unchanged_frequencies() {
        // re-solving with the same frequencies as the incumbent plan must
        // not oscillate: the warm start keeps the incumbent when it is
        // still among the best candidates
        let cfg = tiny_cfg();
        let rp = replanner(&cfg);
        let freqs = vec![vec![0.4, 0.4, 0.1, 0.1], vec![0.25; 4]];
        let base = Allocation::uniform(&cfg, QuantScheme::W8A8);
        let plan1 = rp.replan(&cfg, &freqs, &base).unwrap();
        let plan2 = rp.replan(&cfg, &freqs, &plan1).unwrap();
        assert!(diff_plans(&plan1, &plan2).is_empty(), "replan oscillated");
    }

    #[test]
    fn replan_with_r_override_is_well_formed_and_leaves_config_untouched() {
        let cfg = tiny_cfg();
        let rp = replanner(&cfg);
        let current = Allocation::uniform(&cfg, QuantScheme::W8A8);
        let freqs = vec![vec![0.7, 0.1, 0.1, 0.1], vec![0.25; 4]];
        // a QoS-blended exponent overrides the solve without mutating the
        // replanner's own config
        let plan = rp.replan_with_r(&cfg, &freqs, &current, Some(0.9)).unwrap();
        assert_eq!(plan.schemes.len(), 2);
        assert!((rp.cfg.alloc.r - 0.5).abs() < 1e-12, "config r untouched");
        // None falls back to the configured exponent (same as replan)
        let a = rp.replan_with_r(&cfg, &freqs, &current, None).unwrap();
        let b = rp.replan(&cfg, &freqs, &current).unwrap();
        assert!(diff_plans(&a, &b).is_empty());
    }

    #[test]
    fn diff_detects_family_changes_only() {
        let cfg = tiny_cfg();
        let a = Allocation::uniform(&cfg, QuantScheme::FP16);
        let b = Allocation::uniform(&cfg, QuantScheme::W8A8);
        let d = diff_plans(&a, &b);
        assert_eq!(d.len(), 2 * 5, "every slot changes family");
        for ch in &d {
            assert_eq!(ch.old, RuntimeScheme::Fp16);
            assert_eq!(ch.new, RuntimeScheme::W8A8);
        }
        assert!(diff_plans(&a, &a).is_empty());
        // same runtime family, different exact scheme ⇒ no delta
        let c1 = Allocation::uniform(&cfg, QuantScheme::W4A4);
        let c2 = Allocation::uniform(&cfg, QuantScheme::W4A4G128);
        assert!(diff_plans(&c1, &c2).is_empty());
    }
}
