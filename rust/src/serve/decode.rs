//! Token-level decode scheduler: per-step continuous batching over KV-cached
//! generations (DESIGN.md §Decode-Loop).
//!
//! The serve loop used to batch whole-sequence scoring requests; decode-time
//! activation skew — the regime where MoE expert imbalance is most extreme —
//! never reached the batcher or the telemetry. This module closes that gap:
//! a replica owns one `DecodeScheduler`, and between queue pops it runs the
//! loop at *token* granularity. Each step:
//!
//! ```text
//!   reap cancelled (evict seq, free KV)        ── step-granular cancellation
//!   promote pending → active (KV reservation)  ── admission, FIFO
//!   assemble: 1 decode row per decoding seq
//!           + FIFO prefill chunks, cut against the tile grid
//!             via dispatch::fill_estimate      ── the tile-budget cut
//!   exec: one mixed batch through the engine   ── expert rows concatenated
//!   emit: greedy token per sequence → stream   ── tokens land immediately
//!   retire: stop-token / max-token / failure   ── KV freed between steps
//! ```
//!
//! Because one step mixes prefill chunks and single-token decode rows from
//! many sequences, the per-layer MoE dispatch sees a concatenated batch and
//! fills tiles across sequences — a lone decode row costs a padded 4-tile,
//! eight decoding sequences cost two dense ones. Per-step expert routing
//! flows into the activation telemetry through the engine hook, so the
//! online replanner finally sees decode-time frequencies.
//!
//! The scheduler is engine-agnostic: [`step`](DecodeScheduler::step) takes
//! the forward as a closure over [`StepSeq`] batches, so everything here
//! unit-tests against the native model without a PJRT runtime.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::moe::{ModelConfig, StepSeq};
use crate::runtime::dispatch::{self, FillEstimate};
use crate::runtime::TILE_MS;
use crate::tensor::Matrix;

use super::kvcache::{KvCache, KvOccupancy, SeqKv};
use super::queue::{GenSpec, Request, RequestKind};
use super::request::{FinishReason, StreamEvent};

/// Decode-loop sizing knobs (per replica).
#[derive(Clone, Copy, Debug)]
pub struct DecodePolicy {
    /// Row budget per step: decode rows plus prefill-chunk rows. Default:
    /// the largest exported tile, mirroring the batcher's token budget.
    pub max_step_rows: usize,
    /// Sequences in the step loop at once; the rest wait in admission
    /// order.
    pub max_active_seqs: usize,
    /// KV reservation budget (tokens) — a sequence reserves
    /// `prompt + max_new_tokens` up front, so admission is the only
    /// backpressure point and a running generation never stalls on cache
    /// room.
    pub kv_budget_tokens: usize,
}

impl Default for DecodePolicy {
    fn default() -> Self {
        DecodePolicy {
            max_step_rows: *TILE_MS.last().unwrap(),
            max_active_seqs: 16,
            kv_budget_tokens: 1 << 16,
        }
    }
}

/// Cumulative decode-loop counters (published to the status board and the
/// final replica report).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DecodeStats {
    /// Mixed steps executed (≥ 1 row each).
    pub steps: usize,
    /// Prompt rows prefilled.
    pub prefill_rows: usize,
    /// Single-token decode rows executed.
    pub decode_rows: usize,
    /// Tokens emitted to ticket streams.
    pub generated_tokens: usize,
    /// Generations finished by stop-token or length.
    pub generations: usize,
    /// Generations evicted by cancellation (pending or active).
    pub cancelled: usize,
    /// Generations dropped by a failed engine step.
    pub failed: usize,
}

/// A generation that completed this step (stop-token or length). The
/// replica turns it into the final [`super::queue::Response`] — unless the
/// request was cancelled at the very last moment, in which case the reply
/// is suppressed exactly like a scoring request's.
pub struct FinishedGen {
    pub request: Request,
    pub reason: FinishReason,
    /// Tokens generated (also the count streamed to the ticket).
    pub generated: usize,
    /// Last generated token — for `max_new_tokens == 0`, the argmax
    /// continuation of the prompt (never streamed), so the final
    /// [`super::queue::Response`] matches the scoring path exactly.
    pub last_token: Option<u32>,
    /// Teacher-forced mean next-token NLL over the prompt — the scoring
    /// semantics, so a `max_new_tokens == 0` generation degrades to
    /// exactly a scoring request.
    pub mean_prompt_nll: f64,
    /// Admission → first prefill row.
    pub queue_wait: Duration,
    /// First prefill row → retirement (the compute window of the request's
    /// lifecycle span).
    pub compute: Duration,
    /// First streamed token → retirement (the streaming window; zero when
    /// nothing was streamed).
    pub stream: Duration,
}

/// What one [`DecodeScheduler::step`] call did.
#[derive(Default)]
pub struct StepOutcome {
    /// Useful rows fed this step (0 = the scheduler was idle).
    pub rows: usize,
    pub prefill_rows: usize,
    pub decode_rows: usize,
    /// Tokens emitted to streams this step.
    pub tokens_emitted: usize,
    /// Planner fill estimate of the assembled step.
    pub fill: Option<FillEstimate>,
    /// Generations that finished (stop-token / length).
    pub finished: Vec<FinishedGen>,
    /// Generations reaped by cancellation between steps — KV freed, no
    /// response will ever be sent.
    pub cancelled: Vec<Request>,
    /// Generations dropped because the engine step failed — no response.
    pub failed: Vec<Request>,
}

enum Phase {
    Prefill,
    Decoding,
}

struct ActiveSeq {
    req: Request,
    kv: SeqKv,
    /// Prompt rows prefilled so far.
    consumed: usize,
    generated: Vec<u32>,
    /// Σ teacher-forced next-token NLL over prefilled prompt positions.
    nll_sum: f64,
    /// Argmax continuation of the prompt when `max_new_tokens == 0`
    /// (scoring parity for the final response; never streamed).
    final_argmax: Option<u32>,
    first_step_at: Option<Instant>,
    /// When the first token hit the stream (stream-time accounting).
    first_token_at: Option<Instant>,
    done: Option<FinishReason>,
}

impl ActiveSeq {
    fn spec(&self) -> &GenSpec {
        match &self.req.kind {
            RequestKind::Generate(s) => s,
            RequestKind::Score => unreachable!("decode scheduler only holds generations"),
        }
    }

    fn phase(&self) -> Phase {
        if self.consumed < self.req.tokens.len() {
            Phase::Prefill
        } else {
            Phase::Decoding
        }
    }
}

/// Largest `take ≤ want` whose step total `rows + take` decomposes into
/// whole exported tiles (zero projected padding), falling back to `want`
/// when no aligned total exists. Padding in the tile grid is always
/// `< TILE_MS[0]` rows, so the scan is a handful of iterations.
fn trim_to_tiles(rows: usize, want: usize) -> usize {
    let mut t = want;
    while t > 1 && dispatch::fill_estimate(rows + t).padded_rows > rows + t {
        t -= 1;
    }
    if dispatch::fill_estimate(rows + t).padded_rows > rows + t {
        want
    } else {
        t
    }
}

/// Greedy next token — the same strict-`>` argmax the scoring path uses.
fn argmax(row: &[f32]) -> u32 {
    let mut best = 0usize;
    for i in 1..row.len() {
        if row[i] > row[best] {
            best = i;
        }
    }
    best as u32
}

/// Per-replica token-level generation scheduler. Owns the KV pool, the
/// pending/active sequence sets, and the step assembly policy; the engine
/// stays outside (injected per step), which keeps this engine-agnostic and
/// unit-testable without artifacts.
pub struct DecodeScheduler {
    policy: DecodePolicy,
    pool: KvCache,
    pending: VecDeque<Request>,
    active: Vec<ActiveSeq>,
    stats: DecodeStats,
}

impl DecodeScheduler {
    pub fn new(cfg: &ModelConfig, policy: DecodePolicy) -> DecodeScheduler {
        DecodeScheduler {
            pool: KvCache::new(cfg.layers, cfg.hidden, policy.kv_budget_tokens.max(1)),
            policy,
            pending: VecDeque::new(),
            active: Vec::new(),
            stats: DecodeStats::default(),
        }
    }

    /// Take ownership of a routed generation request (pending until a KV
    /// reservation and an active slot free up, FIFO).
    pub fn admit(&mut self, req: Request) {
        debug_assert!(req.kind.is_generate(), "decode scheduler only takes generations");
        self.pending.push_back(req);
    }

    /// True while any generation is pending or mid-decode — the replica
    /// must keep stepping (and must not block on its work deque).
    pub fn has_work(&self) -> bool {
        !self.pending.is_empty() || !self.active.is_empty()
    }

    /// Pending + active generations — the replica's decode contribution to
    /// the router's load signal.
    pub fn load(&self) -> usize {
        self.pending.len() + self.active.len()
    }

    pub fn active_seqs(&self) -> usize {
        self.active.len()
    }

    pub fn pending_seqs(&self) -> usize {
        self.pending.len()
    }

    pub fn occupancy(&self) -> KvOccupancy {
        self.pool.occupancy()
    }

    pub fn stats(&self) -> DecodeStats {
        self.stats
    }

    /// Run one decode step: reap cancellations, admit pending sequences up
    /// to the KV budget, assemble the mixed prefill/decode batch cut
    /// against the tile grid, execute it through `exec`, stream the new
    /// tokens, and retire finished sequences. An engine failure fails only
    /// the sequences that were in the step (reported in
    /// [`StepOutcome::failed`]); the scheduler itself keeps serving.
    pub fn step<E>(&mut self, mut exec: E) -> StepOutcome
    where
        E: FnMut(&mut [StepSeq<'_>]) -> anyhow::Result<Vec<Matrix>>,
    {
        let mut out = StepOutcome::default();
        self.reap_cancelled(&mut out);
        self.promote_pending();
        if self.active.is_empty() {
            return out;
        }

        // ---- assemble: decode rows first (every decoding sequence
        // advances one token per step), then FIFO prefill chunks ----
        let budget = self.policy.max_step_rows.max(1);
        let mut step_tokens = vec![0usize; self.active.len()];
        let mut rows = 0usize;
        for (ai, a) in self.active.iter().enumerate() {
            if matches!(a.phase(), Phase::Decoding) && rows < budget {
                step_tokens[ai] = 1;
                rows += 1;
            }
        }
        for (ai, a) in self.active.iter().enumerate() {
            if !matches!(a.phase(), Phase::Prefill) || rows >= budget {
                continue;
            }
            let remaining = a.req.tokens.len() - a.consumed;
            let mut take = remaining.min(budget - rows);
            if take < remaining {
                // the chunk doesn't finish the prompt: align the step
                // total to a tile boundary so the ragged tail isn't paid
                // on this step *and* re-paid when the remainder runs
                take = trim_to_tiles(rows, take);
            }
            if take == 0 {
                continue;
            }
            step_tokens[ai] = take;
            rows += take;
        }
        if rows == 0 {
            return out;
        }
        out.fill = Some(dispatch::fill_estimate(rows));

        // ---- execute the mixed step ----
        let now = Instant::now();
        let mut inputs: Vec<StepSeq<'_>> = Vec::with_capacity(self.active.len());
        let mut input_seq: Vec<usize> = Vec::with_capacity(self.active.len());
        for (ai, a) in self.active.iter_mut().enumerate() {
            let n = step_tokens[ai];
            if n == 0 {
                continue;
            }
            if a.first_step_at.is_none() {
                a.first_step_at = Some(now);
            }
            let tokens: &[u32] = if a.consumed < a.req.tokens.len() {
                &a.req.tokens[a.consumed..a.consumed + n]
            } else {
                debug_assert_eq!(n, 1);
                &a.generated[a.generated.len() - 1..]
            };
            inputs.push(StepSeq { tokens, cache: &mut a.kv });
            input_seq.push(ai);
        }
        let result = exec(&mut inputs);
        drop(inputs);
        match result {
            Ok(outs) => {
                debug_assert_eq!(outs.len(), input_seq.len());
                for (k, &ai) in input_seq.iter().enumerate() {
                    self.postprocess(ai, step_tokens[ai], &outs[k], &mut out);
                }
                out.rows = rows;
                self.stats.steps += 1;
                self.stats.prefill_rows += out.prefill_rows;
                self.stats.decode_rows += out.decode_rows;
                self.stats.generated_tokens += out.tokens_emitted;
            }
            Err(e) => {
                eprintln!(
                    "decode step failed ({} sequence(s) dropped): {e:#}",
                    input_seq.len()
                );
                for &ai in &input_seq {
                    self.active[ai].done = Some(FinishReason::Failed);
                }
            }
        }
        self.retire(&mut out);
        out
    }

    /// Fold one sequence's step logits back into its state: prompt NLL and
    /// advancement for prefill rows, a greedy token (streamed immediately)
    /// for the decode row — the final prompt row doubles as the first
    /// decode row, so the first token lands with the prefill step.
    fn postprocess(&mut self, ai: usize, n: usize, logits: &Matrix, out: &mut StepOutcome) {
        let a = &mut self.active[ai];
        let prompt_len = a.req.tokens.len();
        if a.consumed < prompt_len {
            debug_assert_eq!(logits.rows, n);
            for r in 0..n {
                let pos = a.consumed + r;
                if pos + 1 < prompt_len {
                    let row = logits.row(r);
                    let m = row.iter().fold(f32::NEG_INFINITY, |acc, &b| acc.max(b)) as f64;
                    let z: f64 = row.iter().map(|&v| ((v as f64) - m).exp()).sum();
                    a.nll_sum -=
                        (logits.at(r, a.req.tokens[pos + 1] as usize) as f64 - m) - z.ln();
                }
            }
            a.consumed += n;
            out.prefill_rows += n;
            if a.consumed == prompt_len {
                // the final prompt row doubles as the first decode row
                let g = argmax(logits.row(n - 1));
                if a.spec().max_new_tokens == 0 {
                    // degenerate generation: scoring semantics — keep the
                    // argmax for the final response, stream nothing
                    a.final_argmax = Some(g);
                    a.done = Some(FinishReason::Length);
                } else {
                    emit(a, g, out);
                }
            }
        } else {
            debug_assert_eq!(n, 1);
            debug_assert_eq!(logits.rows, 1);
            out.decode_rows += 1;
            let g = argmax(logits.row(0));
            emit(a, g, out);
        }
    }

    /// Evict cancelled generations: pending ones before any KV was
    /// reserved, active ones between steps with their KV reservation
    /// freed — the token-level cancellation the batch-granular path could
    /// not offer. Streams get a terminal `Done { Cancelled }` (suppressed
    /// by the cancelled ticket, but it closes the channel deliberately).
    fn reap_cancelled(&mut self, out: &mut StepOutcome) {
        let mut kept = VecDeque::with_capacity(self.pending.len());
        while let Some(r) = self.pending.pop_front() {
            if r.is_cancelled() {
                if let RequestKind::Generate(spec) = &r.kind {
                    let _ = spec.stream.send(StreamEvent::Done {
                        reason: FinishReason::Cancelled,
                        generated: 0,
                    });
                }
                self.stats.cancelled += 1;
                out.cancelled.push(r);
            } else {
                kept.push_back(r);
            }
        }
        self.pending = kept;
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].req.is_cancelled() {
                let ActiveSeq { req, kv, generated, .. } = self.active.remove(i);
                self.pool.free(kv);
                if let RequestKind::Generate(spec) = &req.kind {
                    let _ = spec.stream.send(StreamEvent::Done {
                        reason: FinishReason::Cancelled,
                        generated: generated.len(),
                    });
                }
                self.stats.cancelled += 1;
                out.cancelled.push(req);
            } else {
                i += 1;
            }
        }
    }

    /// Move pending generations into the step loop while an active slot
    /// and a KV reservation (`prompt + max_new_tokens`) are available.
    /// FIFO with head-of-line blocking: admission order is the fairness
    /// guarantee, and the pool's oversized-when-empty rule ensures even a
    /// reservation larger than the whole budget eventually runs.
    fn promote_pending(&mut self) {
        while self.active.len() < self.policy.max_active_seqs.max(1) {
            let Some(front) = self.pending.front() else { break };
            let max_new = match &front.kind {
                RequestKind::Generate(s) => s.max_new_tokens,
                RequestKind::Score => 0,
            };
            let capacity = (front.tokens.len() + max_new).max(1);
            let Some(kv) = self.pool.alloc(capacity) else { break };
            let req = self.pending.pop_front().unwrap();
            self.active.push(ActiveSeq {
                req,
                kv,
                consumed: 0,
                generated: Vec::new(),
                nll_sum: 0.0,
                final_argmax: None,
                first_step_at: None,
                first_token_at: None,
                done: None,
            });
        }
    }

    /// Remove sequences whose terminal state was set this step, free their
    /// KV reservations, and send the terminal stream event.
    fn retire(&mut self, out: &mut StepOutcome) {
        let mut i = 0;
        while i < self.active.len() {
            let Some(reason) = self.active[i].done else {
                i += 1;
                continue;
            };
            let ActiveSeq {
                req,
                kv,
                generated,
                nll_sum,
                final_argmax,
                first_step_at,
                first_token_at,
                ..
            } = self.active.remove(i);
            self.pool.free(kv);
            if let RequestKind::Generate(spec) = &req.kind {
                let _ = spec
                    .stream
                    .send(StreamEvent::Done { reason, generated: generated.len() });
            }
            match reason {
                FinishReason::Failed => {
                    self.stats.failed += 1;
                    out.failed.push(req);
                }
                FinishReason::Cancelled => {
                    unreachable!("cancellations are reaped before the step")
                }
                FinishReason::Stop | FinishReason::Length => {
                    self.stats.generations += 1;
                    let now = Instant::now();
                    out.finished.push(FinishedGen {
                        reason,
                        generated: generated.len(),
                        last_token: generated.last().copied().or(final_argmax),
                        mean_prompt_nll: nll_sum / (req.tokens.len() - 1).max(1) as f64,
                        queue_wait: first_step_at
                            .map_or(Duration::ZERO, |t| t.saturating_duration_since(req.arrived)),
                        compute: first_step_at
                            .map_or(Duration::ZERO, |t| now.saturating_duration_since(t)),
                        stream: first_token_at
                            .map_or(Duration::ZERO, |t| now.saturating_duration_since(t)),
                        request: req,
                    });
                }
            }
        }
    }
}

/// Stream a freshly generated token and apply the termination rules
/// (stop-token, then length).
fn emit(a: &mut ActiveSeq, token: u32, out: &mut StepOutcome) {
    let index = a.generated.len();
    if a.first_token_at.is_none() {
        a.first_token_at = Some(Instant::now());
    }
    a.generated.push(token);
    let spec = match &a.req.kind {
        RequestKind::Generate(s) => s,
        RequestKind::Score => unreachable!("decode scheduler only holds generations"),
    };
    let _ = spec.stream.send(StreamEvent::Token { token, index });
    out.tokens_emitted += 1;
    if spec.stop.contains(&token) {
        a.done = Some(FinishReason::Stop);
    } else if a.generated.len() >= spec.max_new_tokens {
        a.done = Some(FinishReason::Length);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::MoeLm;
    use crate::serve::queue::Response;
    use crate::util::Rng;
    use std::sync::atomic::Ordering;
    use std::sync::{mpsc, Arc};

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "decode-test".into(),
            vocab: 32,
            hidden: 16,
            layers: 2,
            heads: 2,
            n_experts: 4,
            n_shared: 1,
            topk: 2,
            inter: 8,
            dense_first: false,
            seq_len: 12,
        }
    }

    struct GenHandle {
        stream: mpsc::Receiver<StreamEvent>,
        _reply: mpsc::Receiver<Response>,
        cancel: Arc<std::sync::atomic::AtomicBool>,
    }

    fn gen_request(prompt: Vec<u32>, max_new: usize, stop: Vec<u32>) -> (Request, GenHandle) {
        let (reply, reply_rx) = mpsc::channel();
        let (stream, stream_rx) = mpsc::channel();
        let cancel = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let req = Request {
            kind: RequestKind::Generate(GenSpec { max_new_tokens: max_new, stop, stream }),
            cancelled: cancel.clone(),
            ..Request::new(prompt, reply)
        };
        (req, GenHandle { stream: stream_rx, _reply: reply_rx, cancel })
    }

    /// One scheduler step against the native model (no PJRT): the inline
    /// closure keeps the higher-ranked `StepSeq` lifetimes inferable.
    fn native_step(sched: &mut DecodeScheduler, lm: &MoeLm) -> StepOutcome {
        sched.step(|inputs| {
            Ok(lm.forward_step_batch_with_moe(inputs, |_, block, x| block.forward(x)))
        })
    }

    /// Greedy reference: re-forward the whole growing sequence per token.
    fn reference_generate(lm: &MoeLm, prompt: &[u32], max_new: usize, stop: &[u32]) -> Vec<u32> {
        let mut seq = prompt.to_vec();
        let mut out = Vec::new();
        for _ in 0..max_new {
            let logits = lm.forward(&seq);
            let g = argmax(logits.row(seq.len() - 1));
            seq.push(g);
            out.push(g);
            if stop.contains(&g) {
                break;
            }
        }
        out
    }

    fn drain(handle: &GenHandle) -> (Vec<u32>, Option<FinishReason>) {
        let mut tokens = Vec::new();
        let mut reason = None;
        while let Ok(ev) = handle.stream.try_recv() {
            match ev {
                StreamEvent::Token { token, index } => {
                    assert_eq!(index, tokens.len(), "stream indices are dense");
                    tokens.push(token);
                }
                StreamEvent::Done { reason: r, generated } => {
                    assert_eq!(generated, tokens.len());
                    reason = Some(r);
                }
            }
        }
        (tokens, reason)
    }

    #[test]
    fn scheduler_matches_naive_reforward_generation() {
        let mut rng = Rng::new(0xD0_01);
        let cfg = tiny_cfg();
        let lm = MoeLm::random(&cfg, &mut rng);
        let prompt: Vec<u32> = (0..6).map(|_| rng.below(32) as u32).collect();
        let want = reference_generate(&lm, &prompt, 8, &[]);
        let mut sched = DecodeScheduler::new(&cfg, DecodePolicy::default());
        let (req, handle) = gen_request(prompt, 8, vec![]);
        sched.admit(req);
        let mut steps = 0;
        while sched.has_work() {
            let out = native_step(&mut sched, &lm);
            assert!(out.rows > 0 || !sched.has_work());
            steps += 1;
            assert!(steps < 100, "runaway decode loop");
        }
        let (tokens, reason) = drain(&handle);
        assert_eq!(tokens, want, "KV-cached decode must match naive re-forwarding");
        assert_eq!(reason, Some(FinishReason::Length));
        let stats = sched.stats();
        assert_eq!(stats.generations, 1);
        assert_eq!(stats.generated_tokens, 8);
        // prefill (6 rows) + one decode row per remaining token (first
        // token rides the prefill step)
        assert_eq!(stats.prefill_rows, 6);
        assert_eq!(stats.decode_rows, 7);
        assert_eq!(sched.occupancy().reserved_tokens, 0, "KV freed at retirement");
    }

    #[test]
    fn stop_token_ends_generation_early() {
        let mut rng = Rng::new(0xD0_02);
        let cfg = tiny_cfg();
        let lm = MoeLm::random(&cfg, &mut rng);
        let prompt: Vec<u32> = (0..5).map(|_| rng.below(32) as u32).collect();
        // pick the 3rd greedy token as the stop token so it must stop there
        let free_run = reference_generate(&lm, &prompt, 6, &[]);
        let stop = free_run[2];
        let want = reference_generate(&lm, &prompt, 6, &[stop]);
        assert_eq!(want.len(), 3, "reference stops at the stop token");
        let mut sched = DecodeScheduler::new(&cfg, DecodePolicy::default());
        let (req, handle) = gen_request(prompt, 6, vec![stop]);
        sched.admit(req);
        while sched.has_work() {
            native_step(&mut sched, &lm);
        }
        let (tokens, reason) = drain(&handle);
        assert_eq!(tokens, want);
        assert_eq!(*tokens.last().unwrap(), stop, "stop token itself is streamed");
        assert_eq!(reason, Some(FinishReason::Stop));
    }

    #[test]
    fn zero_max_new_tokens_degrades_to_scoring() {
        let mut rng = Rng::new(0xD0_03);
        let cfg = tiny_cfg();
        let lm = MoeLm::random(&cfg, &mut rng);
        let prompt: Vec<u32> = (0..4).map(|_| rng.below(32) as u32).collect();
        let mut sched = DecodeScheduler::new(&cfg, DecodePolicy::default());
        let (req, handle) = gen_request(prompt, 0, vec![]);
        sched.admit(req);
        let out = native_step(&mut sched, &lm);
        assert_eq!(out.finished.len(), 1);
        let fin = &out.finished[0];
        assert_eq!(fin.generated, 0);
        assert!(fin.last_token.is_some(), "scoring parity: argmax continuation kept");
        assert_eq!(fin.reason, FinishReason::Length);
        assert!(fin.mean_prompt_nll.is_finite());
        assert_eq!(fin.stream, Duration::ZERO, "nothing was streamed");
        assert!(fin.compute >= Duration::ZERO);
        let (tokens, reason) = drain(&handle);
        assert!(tokens.is_empty());
        assert_eq!(reason, Some(FinishReason::Length));
    }

    #[test]
    fn step_budget_chunks_prefill_and_mixes_decode_rows() {
        let mut rng = Rng::new(0xD0_04);
        let cfg = tiny_cfg();
        let lm = MoeLm::random(&cfg, &mut rng);
        // tiny budget: an 11-token prompt must prefill over multiple steps
        let policy = DecodePolicy { max_step_rows: 4, ..DecodePolicy::default() };
        let mut sched = DecodeScheduler::new(&cfg, policy);
        let long: Vec<u32> = (0..11).map(|_| rng.below(32) as u32).collect();
        let short: Vec<u32> = (0..2).map(|_| rng.below(32) as u32).collect();
        let want_long = reference_generate(&lm, &long, 3, &[]);
        let want_short = reference_generate(&lm, &short, 3, &[]);
        let (req_a, h_a) = gen_request(long.clone(), 3, vec![]);
        let (req_b, h_b) = gen_request(short.clone(), 3, vec![]);
        sched.admit(req_a);
        sched.admit(req_b);
        let mut saw_mixed = false;
        while sched.has_work() {
            let out = native_step(&mut sched, &lm);
            assert!(out.rows <= 4 + 1, "budget respected (±1 decode row floor)");
            if out.prefill_rows > 0 && out.decode_rows > 0 {
                saw_mixed = true;
            }
            if let Some(est) = out.fill {
                assert_eq!(est.useful_rows, out.rows);
            }
        }
        assert!(saw_mixed, "short seq decodes while long seq still prefills");
        assert_eq!(drain(&h_a).0, want_long);
        assert_eq!(drain(&h_b).0, want_short);
        assert_eq!(sched.stats().generations, 2);
    }

    #[test]
    fn cancellation_between_steps_frees_kv_and_stops_within_one_step() {
        let mut rng = Rng::new(0xD0_05);
        let cfg = tiny_cfg();
        let lm = MoeLm::random(&cfg, &mut rng);
        let prompt: Vec<u32> = (0..4).map(|_| rng.below(32) as u32).collect();
        let mut sched = DecodeScheduler::new(&cfg, DecodePolicy::default());
        let (req, handle) = gen_request(prompt, 1000, vec![]);
        sched.admit(req);
        // run two steps (prefill+first token, then one decode token)…
        native_step(&mut sched, &lm);
        native_step(&mut sched, &lm);
        let emitted_before = sched.stats().generated_tokens;
        assert!(emitted_before >= 2);
        assert!(sched.occupancy().reserved_tokens > 0);
        // …then cancel: the very next step must evict without executing
        handle.cancel.store(true, Ordering::Release);
        let out = native_step(&mut sched, &lm);
        assert_eq!(out.cancelled.len(), 1, "evicted between steps");
        assert_eq!(out.rows, 0, "no rows executed for the cancelled sequence");
        assert_eq!(sched.stats().generated_tokens, emitted_before, "no token after cancel");
        assert_eq!(sched.occupancy().reserved_tokens, 0, "KV reservation reclaimed");
        assert_eq!(sched.occupancy().seqs, 0);
        assert!(!sched.has_work());
        assert_eq!(sched.stats().cancelled, 1);
        let (_, reason) = drain(&handle);
        assert_eq!(reason, Some(FinishReason::Cancelled));
    }

    #[test]
    fn pending_cancellation_never_allocates_kv() {
        let cfg = tiny_cfg();
        let mut sched = DecodeScheduler::new(&cfg, DecodePolicy::default());
        let (req, handle) = gen_request(vec![1, 2, 3], 5, vec![]);
        handle.cancel.store(true, Ordering::Release);
        sched.admit(req);
        let out = sched.step(|_inputs: &mut [StepSeq<'_>]| -> anyhow::Result<Vec<Matrix>> {
            panic!("nothing should execute")
        });
        assert_eq!(out.cancelled.len(), 1);
        assert_eq!(sched.occupancy().peak_tokens, 0, "KV was never reserved");
        let (_, reason) = drain(&handle);
        assert_eq!(reason, Some(FinishReason::Cancelled));
    }

    #[test]
    fn kv_budget_defers_admission_until_a_slot_frees() {
        let mut rng = Rng::new(0xD0_06);
        let cfg = tiny_cfg();
        let lm = MoeLm::random(&cfg, &mut rng);
        // budget fits exactly one (4 + 2)-token reservation
        let policy = DecodePolicy { kv_budget_tokens: 6, ..DecodePolicy::default() };
        let mut sched = DecodeScheduler::new(&cfg, policy);
        let p1: Vec<u32> = (0..4).map(|_| rng.below(32) as u32).collect();
        let p2: Vec<u32> = (0..4).map(|_| rng.below(32) as u32).collect();
        let (r1, h1) = gen_request(p1.clone(), 2, vec![]);
        let (r2, h2) = gen_request(p2.clone(), 2, vec![]);
        sched.admit(r1);
        sched.admit(r2);
        native_step(&mut sched, &lm);
        assert_eq!(sched.active_seqs(), 1, "second generation waits on the KV budget");
        assert_eq!(sched.pending_seqs(), 1);
        while sched.has_work() {
            native_step(&mut sched, &lm);
        }
        assert_eq!(drain(&h1).0, reference_generate(&lm, &p1, 2, &[]));
        assert_eq!(drain(&h2).0, reference_generate(&lm, &p2, 2, &[]));
        assert_eq!(sched.occupancy().peak_tokens, 6, "reservations never overlapped");
    }

    #[test]
    fn engine_failure_drops_only_the_sequences_in_the_step() {
        let mut rng = Rng::new(0xD0_07);
        let cfg = tiny_cfg();
        let lm = MoeLm::random(&cfg, &mut rng);
        let mut sched = DecodeScheduler::new(&cfg, DecodePolicy::default());
        let (req, handle) = gen_request(vec![1, 2, 3], 5, vec![]);
        sched.admit(req);
        let out = sched.step(|_inputs: &mut [StepSeq<'_>]| -> anyhow::Result<Vec<Matrix>> {
            anyhow::bail!("injected engine failure")
        });
        assert_eq!(out.failed.len(), 1);
        assert!(out.finished.is_empty());
        assert_eq!(sched.stats().failed, 1);
        assert_eq!(sched.occupancy().reserved_tokens, 0, "failed sequence freed its KV");
        let (_, reason) = drain(&handle);
        assert_eq!(reason, Some(FinishReason::Failed));
        // the scheduler still serves after a failure
        let (req2, h2) = gen_request(vec![2, 3], 1, vec![]);
        sched.admit(req2);
        while sched.has_work() {
            native_step(&mut sched, &lm);
        }
        assert_eq!(drain(&h2).0.len(), 1);
    }

    #[test]
    fn trim_to_tiles_aligns_chunks() {
        // rows=0: a 10-row want trims to 8 (4+4 whole tiles)
        assert_eq!(trim_to_tiles(0, 10), 8);
        // already aligned wants stay
        assert_eq!(trim_to_tiles(0, 64), 64);
        assert_eq!(trim_to_tiles(4, 16), 16);
        // tiny wants that cannot align fall back unchanged
        assert_eq!(trim_to_tiles(0, 1), 1);
        assert_eq!(trim_to_tiles(2, 1), 1, "cannot align: keep progress");
        // decode rows + prefill chunk: 3 decode rows, want 9 → total 12
        assert_eq!(trim_to_tiles(3, 9), 9);
    }
}
