//! Token-level decode scheduler: per-step continuous batching over KV-cached
//! generations (DESIGN.md §Decode-Loop, §KV-Paging).
//!
//! The serve loop used to batch whole-sequence scoring requests; decode-time
//! activation skew — the regime where MoE expert imbalance is most extreme —
//! never reached the batcher or the telemetry. This module closes that gap:
//! a replica owns one `DecodeScheduler`, and between queue pops it runs the
//! loop at *token* granularity. Each step:
//!
//! ```text
//!   reap cancelled (evict seq, free KV)        ── step-granular cancellation
//!   promote: resume preempted, admit pending   ── lazy page claim, FIFO
//!   assemble: 1 decode row per decoding seq
//!           + FIFO prefill chunks, cut against the tile grid
//!             via dispatch::fill_estimate      ── the tile-budget cut
//!   claim pages for the step's rows            ── grow between steps;
//!             preempt-youngest when the pool is dry (deterministic)
//!   exec: one mixed batch through the engine   ── expert rows concatenated
//!   emit: greedy token per sequence → stream   ── tokens land immediately
//!   seal: full pages quantize + enter the prefix-share map
//!   retire: stop-token / max-token / failure   ── KV freed between steps
//! ```
//!
//! KV is paged ([`super::kvcache`]): admission claims only the prompt's
//! pages plus one decode-headroom page, and later pages are claimed between
//! steps — so concurrency is bounded by *live context*, not by the sum of
//! worst cases. When the pool runs dry mid-generation the scheduler preempts
//! the youngest active sequence (largest admission number — deterministic),
//! frees its pages, and replays it later from its kept token state: replayed
//! prefill recomputes the same K/V (bit-identical in fp32 mode), already
//! streamed tokens are never re-emitted, and the prompt NLL is recomputed to
//! the same value. The oldest sequence can always force progress past the
//! budget when it is alone, so no generation deadlocks.
//!
//! Because one step mixes prefill chunks and single-token decode rows from
//! many sequences, the per-layer MoE dispatch sees a concatenated batch and
//! fills tiles across sequences — a lone decode row costs a padded 4-tile,
//! eight decoding sequences cost two dense ones. Per-step expert routing
//! flows into the activation telemetry through the engine hook, so the
//! online replanner finally sees decode-time frequencies.
//!
//! The scheduler is engine-agnostic: [`step`](DecodeScheduler::step) takes
//! the forward as a closure over [`StepSeq`] batches, so everything here
//! unit-tests against the native model without a PJRT runtime.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::alloc::Allocation;
use crate::moe::{ModelConfig, StepSeq};
use crate::runtime::dispatch::{self, FillEstimate};
use crate::runtime::TILE_MS;
use crate::tensor::Matrix;

use super::kvcache::{KvCache, KvOccupancy, KvPageScheme, KvQuantConfig, SeqKv, KV_PAGE_SIZE};
use super::queue::{GenSpec, Request, RequestKind};
use super::request::{FinishReason, StreamEvent};

/// Decode-loop sizing knobs (per replica).
#[derive(Clone, Debug)]
pub struct DecodePolicy {
    /// Row budget per step: decode rows plus prefill-chunk rows. Default:
    /// the largest exported tile, mirroring the batcher's token budget.
    pub max_step_rows: usize,
    /// Sequences in the step loop at once; the rest wait in admission
    /// order.
    pub max_active_seqs: usize,
    /// KV page-pool budget (tokens). Admission claims only prompt pages
    /// plus one decode-headroom page; later pages are claimed between
    /// steps, with deterministic preempt-youngest when the pool runs dry.
    pub kv_budget_tokens: usize,
    /// Positions per KV page (tile-aligned; see [`KV_PAGE_SIZE`]).
    pub kv_page_size: usize,
    /// Sealed-page quantization plan (`None` = fp32 pages everywhere,
    /// bit-identical to the contiguous cache this pool replaced).
    pub kv_quant: Option<KvQuantConfig>,
}

impl Default for DecodePolicy {
    fn default() -> Self {
        DecodePolicy {
            max_step_rows: *TILE_MS.last().unwrap(),
            max_active_seqs: 16,
            kv_budget_tokens: 1 << 16,
            kv_page_size: KV_PAGE_SIZE,
            kv_quant: None,
        }
    }
}

/// Derive a sealed-page KV quantization plan from the deployed MCKP
/// weight plan: per transformer layer, the plan's mean activation bits
/// stand in for calibration sensitivity (layers the planner kept wide are
/// the layers calibration found sensitive), so KV bits land on the same
/// layers the weight bit-budget favoured. Layers without an MoE plan
/// (dense interleave) default to the `hi` scheme.
pub fn kv_quant_from_allocation(
    alloc: &Allocation,
    n_layers: usize,
    lo: KvPageScheme,
    hi: KvPageScheme,
) -> KvQuantConfig {
    let mut scores = vec![f64::MAX; n_layers];
    for (bi, &layer) in alloc.layers.iter().enumerate() {
        if layer >= n_layers {
            continue;
        }
        let schemes = &alloc.schemes[bi];
        let bits: f64 = schemes
            .iter()
            .flat_map(|e| e.iter())
            .map(|s| s.abits as f64)
            .sum();
        let n = (schemes.len() * 3).max(1);
        scores[layer] = bits / n as f64;
    }
    KvQuantConfig::from_sensitivity(&scores, lo, hi)
}

/// Cumulative decode-loop counters (published to the status board and the
/// final replica report).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DecodeStats {
    /// Mixed steps executed (≥ 1 row each).
    pub steps: usize,
    /// Prompt rows prefilled.
    pub prefill_rows: usize,
    /// Single-token decode rows executed (replayed context rows after a
    /// preemption count here too — they are re-decode work).
    pub decode_rows: usize,
    /// Tokens emitted to ticket streams.
    pub generated_tokens: usize,
    /// Generations finished by stop-token or length.
    pub generations: usize,
    /// Generations evicted by cancellation (pending, preempted or active).
    pub cancelled: usize,
    /// Generations dropped by a failed engine step.
    pub failed: usize,
    /// Preempt-youngest evictions (pages reclaimed, generation replayed
    /// later — not a terminal outcome).
    pub preemptions: usize,
}

/// A generation that completed this step (stop-token or length). The
/// replica turns it into the final [`super::queue::Response`] — unless the
/// request was cancelled at the very last moment, in which case the reply
/// is suppressed exactly like a scoring request's.
pub struct FinishedGen {
    pub request: Request,
    pub reason: FinishReason,
    /// Tokens generated (also the count streamed to the ticket).
    pub generated: usize,
    /// Last generated token — for `max_new_tokens == 0`, the argmax
    /// continuation of the prompt (never streamed), so the final
    /// [`super::queue::Response`] matches the scoring path exactly.
    pub last_token: Option<u32>,
    /// Teacher-forced mean next-token NLL over the prompt — the scoring
    /// semantics, so a `max_new_tokens == 0` generation degrades to
    /// exactly a scoring request.
    pub mean_prompt_nll: f64,
    /// Admission → first prefill row.
    pub queue_wait: Duration,
    /// First prefill row → retirement (the compute window of the request's
    /// lifecycle span).
    pub compute: Duration,
    /// First streamed token → retirement (the streaming window; zero when
    /// nothing was streamed).
    pub stream: Duration,
}

/// What one [`DecodeScheduler::step`] call did.
#[derive(Default)]
pub struct StepOutcome {
    /// Useful rows fed this step (0 = the scheduler was idle).
    pub rows: usize,
    pub prefill_rows: usize,
    pub decode_rows: usize,
    /// Tokens emitted to streams this step.
    pub tokens_emitted: usize,
    /// Planner fill estimate of the assembled step.
    pub fill: Option<FillEstimate>,
    /// Generations that finished (stop-token / length).
    pub finished: Vec<FinishedGen>,
    /// Generations reaped by cancellation between steps — KV freed, no
    /// response will ever be sent.
    pub cancelled: Vec<Request>,
    /// Generations dropped because the engine step failed — no response.
    pub failed: Vec<Request>,
    /// Request ids preempted this step to free pages for older sequences
    /// (they will be replayed — not terminal).
    pub preempted: Vec<u64>,
}

enum Phase {
    Prefill,
    Decoding,
}

struct ActiveSeq {
    req: Request,
    kv: SeqKv,
    /// Admission number — preemption victims are chosen youngest-first by
    /// this (deterministic), and resume order is oldest-first.
    admit_seq: u64,
    /// Full context: prompt ++ generated, contiguously — step inputs are
    /// `ctx[consumed..consumed + n]` whether prefilling, decoding, or
    /// replaying after a preemption.
    ctx: Vec<u32>,
    /// Context rows fed through the engine so far (resets to 0 on
    /// preemption: the replay recomputes the same K/V).
    consumed: usize,
    generated: Vec<u32>,
    /// Σ teacher-forced next-token NLL over prefilled prompt positions.
    nll_sum: f64,
    /// Argmax continuation of the prompt when `max_new_tokens == 0`
    /// (scoring parity for the final response; never streamed).
    final_argmax: Option<u32>,
    first_step_at: Option<Instant>,
    /// When the first token hit the stream (stream-time accounting).
    first_token_at: Option<Instant>,
    done: Option<FinishReason>,
}

impl ActiveSeq {
    fn spec(&self) -> &GenSpec {
        match &self.req.kind {
            RequestKind::Generate(s) => s,
            RequestKind::Score => unreachable!("decode scheduler only holds generations"),
        }
    }

    fn ctx_len(&self) -> usize {
        self.ctx.len()
    }

    fn phase(&self) -> Phase {
        // exactly one fresh context row left and it is a generated token:
        // a single-token decode row. Anything else — prompt rows, or a
        // post-preemption replay of many context rows — is prefill work.
        if !self.generated.is_empty() && self.consumed + 1 == self.ctx_len() {
            Phase::Decoding
        } else {
            Phase::Prefill
        }
    }
}

/// A preempted generation waiting to re-enter the step loop: token state
/// only, no pages. Replay recomputes K/V (and the prompt NLL) from the kept
/// context; streamed tokens are never re-emitted.
struct PreemptedSeq {
    req: Request,
    admit_seq: u64,
    ctx: Vec<u32>,
    generated: Vec<u32>,
    first_step_at: Option<Instant>,
    first_token_at: Option<Instant>,
}

/// Largest `take ≤ want` whose step total `rows + take` decomposes into
/// whole exported tiles (zero projected padding), falling back to `want`
/// when no aligned total exists. Padding in the tile grid is always
/// `< TILE_MS[0]` rows, so the scan is a handful of iterations.
fn trim_to_tiles(rows: usize, want: usize) -> usize {
    let mut t = want;
    while t > 1 && dispatch::fill_estimate(rows + t).padded_rows > rows + t {
        t -= 1;
    }
    if dispatch::fill_estimate(rows + t).padded_rows > rows + t {
        want
    } else {
        t
    }
}

/// Greedy next token — the same strict-`>` argmax the scoring path uses.
fn argmax(row: &[f32]) -> u32 {
    let mut best = 0usize;
    for i in 1..row.len() {
        if row[i] > row[best] {
            best = i;
        }
    }
    best as u32
}

/// Per-replica token-level generation scheduler. Owns the KV page pool,
/// the pending/preempted/active sequence sets, and the step assembly
/// policy; the engine stays outside (injected per step), which keeps this
/// engine-agnostic and unit-testable without artifacts.
pub struct DecodeScheduler {
    policy: DecodePolicy,
    pool: KvCache,
    pending: VecDeque<Request>,
    /// Preempted generations (token state, no pages) — resumed
    /// oldest-first, ahead of anything still pending.
    preempted: Vec<PreemptedSeq>,
    active: Vec<ActiveSeq>,
    admit_counter: u64,
    stats: DecodeStats,
}

impl DecodeScheduler {
    pub fn new(cfg: &ModelConfig, policy: DecodePolicy) -> DecodeScheduler {
        DecodeScheduler {
            pool: KvCache::with_config(
                cfg.layers,
                cfg.hidden,
                policy.kv_budget_tokens.max(1),
                policy.kv_page_size.max(1),
                policy.kv_quant.clone(),
            ),
            policy,
            pending: VecDeque::new(),
            preempted: Vec::new(),
            active: Vec::new(),
            admit_counter: 0,
            stats: DecodeStats::default(),
        }
    }

    /// Take ownership of a routed generation request (pending until prompt
    /// pages and an active slot free up, FIFO).
    pub fn admit(&mut self, req: Request) {
        debug_assert!(req.kind.is_generate(), "decode scheduler only takes generations");
        self.pending.push_back(req);
    }

    /// Evict every generation — pending, preempted and active — freeing
    /// all KV pages and closing each stream with `Done { Failed }`. The
    /// replica-kill path: the caller fails the returned requests through
    /// the normal admission accounting, so
    /// `admitted == responses + cancelled + failed` stays exact across a
    /// mid-run kill.
    pub fn evict_all(&mut self) -> Vec<Request> {
        let mut out = Vec::with_capacity(self.load());
        let pending: Vec<Request> = self.pending.drain(..).collect();
        for r in pending {
            if let RequestKind::Generate(spec) = &r.kind {
                let _ = spec
                    .stream
                    .send(StreamEvent::Done { reason: FinishReason::Failed, generated: 0 });
            }
            self.stats.failed += 1;
            out.push(r);
        }
        let preempted: Vec<PreemptedSeq> = self.preempted.drain(..).collect();
        for p in preempted {
            if let RequestKind::Generate(spec) = &p.req.kind {
                let _ = spec.stream.send(StreamEvent::Done {
                    reason: FinishReason::Failed,
                    generated: p.generated.len(),
                });
            }
            self.stats.failed += 1;
            out.push(p.req);
        }
        let active: Vec<ActiveSeq> = self.active.drain(..).collect();
        for a in active {
            self.pool.free(a.kv);
            if let RequestKind::Generate(spec) = &a.req.kind {
                let _ = spec.stream.send(StreamEvent::Done {
                    reason: FinishReason::Failed,
                    generated: a.generated.len(),
                });
            }
            self.stats.failed += 1;
            out.push(a.req);
        }
        out
    }

    /// True while any generation is pending, preempted or mid-decode — the
    /// replica must keep stepping (and must not block on its work deque).
    pub fn has_work(&self) -> bool {
        !self.pending.is_empty() || !self.preempted.is_empty() || !self.active.is_empty()
    }

    /// Pending + preempted + active generations — the replica's decode
    /// contribution to the router's load signal.
    pub fn load(&self) -> usize {
        self.pending.len() + self.preempted.len() + self.active.len()
    }

    pub fn active_seqs(&self) -> usize {
        self.active.len()
    }

    pub fn pending_seqs(&self) -> usize {
        self.pending.len()
    }

    pub fn preempted_seqs(&self) -> usize {
        self.preempted.len()
    }

    /// Pool occupancy with `used_tokens` overlaid from the live sequence
    /// lengths (the pool tracks pages; the scheduler owns the fills).
    pub fn occupancy(&self) -> KvOccupancy {
        let mut occ = self.pool.occupancy();
        occ.used_tokens = self.active.iter().map(|a| a.kv.len()).sum();
        occ
    }

    /// Unclaimed tokens under the KV page budget — the admission front
    /// door's backpressure signal.
    pub fn free_kv_tokens(&self) -> usize {
        self.pool.free_tokens()
    }

    /// EWMA page-release rate (tokens/second; 0 until warmed) — what
    /// `retry_after` hints are derived from when the pool is the
    /// bottleneck.
    pub fn kv_release_tps(&self) -> f64 {
        self.pool.release_tps()
    }

    pub fn kv_page_size(&self) -> usize {
        self.pool.page_size()
    }

    /// Invalidate the prefix-share map on a plan hot-swap (pages computed
    /// under the old plan must not seed new-plan prefills).
    pub fn set_share_epoch(&mut self, epoch: u64) {
        self.pool.set_share_epoch(epoch);
    }

    pub fn stats(&self) -> DecodeStats {
        self.stats
    }

    /// Run one decode step: reap cancellations, resume preempted and admit
    /// pending sequences up to the page budget, assemble the mixed
    /// prefill/decode batch cut against the tile grid, claim the pages the
    /// step appends into (preempting the youngest sequence when the pool
    /// runs dry), execute through `exec`, stream the new tokens, seal full
    /// pages, and retire finished sequences. An engine failure fails only
    /// the sequences that were in the step (reported in
    /// [`StepOutcome::failed`]); the scheduler itself keeps serving.
    pub fn step<E>(&mut self, mut exec: E) -> StepOutcome
    where
        E: FnMut(&mut [StepSeq<'_>]) -> anyhow::Result<Vec<Matrix>>,
    {
        let mut out = StepOutcome::default();
        self.reap_cancelled(&mut out);
        self.promote_pending();
        if self.active.is_empty() {
            return out;
        }

        // ---- assemble: decode rows first (every decoding sequence
        // advances one token per step), then FIFO prefill chunks ----
        let budget = self.policy.max_step_rows.max(1);
        let mut step_tokens = vec![0usize; self.active.len()];
        let mut rows = 0usize;
        for (ai, a) in self.active.iter().enumerate() {
            if matches!(a.phase(), Phase::Decoding) && rows < budget {
                step_tokens[ai] = 1;
                rows += 1;
            }
        }
        for (ai, a) in self.active.iter().enumerate() {
            if !matches!(a.phase(), Phase::Prefill) || rows >= budget {
                continue;
            }
            let remaining = a.ctx_len() - a.consumed;
            let mut take = remaining.min(budget - rows);
            if take < remaining {
                // the chunk doesn't finish the context: align the step
                // total to a tile boundary so the ragged tail isn't paid
                // on this step *and* re-paid when the remainder runs
                take = trim_to_tiles(rows, take);
            }
            if take == 0 {
                continue;
            }
            step_tokens[ai] = take;
            rows += take;
        }

        // ---- claim the pages this step appends into (lazy growth).
        // Oldest-first: when the pool runs dry, preempt the youngest
        // active sequence (deterministic by admission number — `active`
        // is admission-ordered, so the victim is always the last) and
        // retry; the oldest sequence alone may force past the budget, so
        // no generation deadlocks. ----
        let mut ai = 0;
        while ai < self.active.len() {
            let n = step_tokens[ai];
            if n == 0 {
                ai += 1;
                continue;
            }
            let need = self.active[ai].kv.len() + n;
            loop {
                if self.pool.grow(&mut self.active[ai].kv, need) {
                    break;
                }
                if self.active.len() - 1 > ai {
                    let victim = self.active.len() - 1;
                    step_tokens.truncate(victim);
                    self.preempt(victim, &mut out);
                } else if ai == 0 {
                    // oldest and alone: bounded overflow, exactly like the
                    // pool's oversized-when-empty admission rule
                    self.pool.grow_force(&mut self.active[ai].kv, need);
                    break;
                } else {
                    // strictly older sequences hold the pool: defer this
                    // sequence's rows until they release pages
                    step_tokens[ai] = 0;
                    break;
                }
            }
            ai += 1;
        }
        let rows: usize = step_tokens.iter().sum();
        if rows == 0 {
            return out;
        }
        out.fill = Some(dispatch::fill_estimate(rows));

        // ---- execute the mixed step ----
        let now = Instant::now();
        let mut inputs: Vec<StepSeq<'_>> = Vec::with_capacity(self.active.len());
        let mut input_seq: Vec<usize> = Vec::with_capacity(self.active.len());
        for (ai, a) in self.active.iter_mut().enumerate() {
            let n = step_tokens[ai];
            if n == 0 {
                continue;
            }
            if a.first_step_at.is_none() {
                a.first_step_at = Some(now);
            }
            let tokens: &[u32] = &a.ctx[a.consumed..a.consumed + n];
            inputs.push(StepSeq { tokens, cache: &mut a.kv });
            input_seq.push(ai);
        }
        let result = exec(&mut inputs);
        drop(inputs);
        match result {
            Ok(outs) => {
                debug_assert_eq!(outs.len(), input_seq.len());
                for (k, &ai) in input_seq.iter().enumerate() {
                    self.postprocess(ai, step_tokens[ai], &outs[k], &mut out);
                }
                out.rows = rows;
                self.stats.steps += 1;
                self.stats.prefill_rows += out.prefill_rows;
                self.stats.decode_rows += out.decode_rows;
                self.stats.generated_tokens += out.tokens_emitted;
                // seal newly completed pages: quantize (when configured)
                // and publish prompt blocks in the prefix-share map
                let pool = &mut self.pool;
                for a in self.active.iter_mut() {
                    pool.seal(&mut a.kv);
                }
            }
            Err(e) => {
                eprintln!(
                    "decode step failed ({} sequence(s) dropped): {e:#}",
                    input_seq.len()
                );
                for &ai in &input_seq {
                    self.active[ai].done = Some(FinishReason::Failed);
                }
            }
        }
        self.retire(&mut out);
        out
    }

    /// Fold one sequence's step logits back into its state: prompt NLL for
    /// prompt rows, and — when the step consumed the last fresh context
    /// row — a greedy next token, streamed immediately. The final prompt
    /// row doubles as the first decode row, so the first token lands with
    /// the prefill step; replayed context rows after a preemption advance
    /// the cache without re-emitting anything.
    fn postprocess(&mut self, ai: usize, n: usize, logits: &Matrix, out: &mut StepOutcome) {
        let a = &mut self.active[ai];
        let prompt_len = a.req.tokens.len();
        debug_assert_eq!(logits.rows, n);
        for r in 0..n {
            let pos = a.consumed + r;
            if pos < prompt_len {
                out.prefill_rows += 1;
            } else {
                out.decode_rows += 1;
            }
            if pos + 1 < prompt_len {
                let row = logits.row(r);
                let m = row.iter().fold(f32::NEG_INFINITY, |acc, &b| acc.max(b)) as f64;
                let z: f64 = row.iter().map(|&v| ((v as f64) - m).exp()).sum();
                a.nll_sum -= (logits.at(r, a.req.tokens[pos + 1] as usize) as f64 - m) - z.ln();
            }
        }
        a.consumed += n;
        if a.consumed == a.ctx_len() {
            let g = argmax(logits.row(n - 1));
            if a.spec().max_new_tokens == 0 {
                // degenerate generation: scoring semantics — keep the
                // argmax for the final response, stream nothing
                a.final_argmax = Some(g);
                a.done = Some(FinishReason::Length);
            } else {
                emit(a, g, out);
            }
        }
    }

    /// Evict cancelled generations: pending and preempted ones hold no
    /// pages, active ones are evicted between steps with their pages
    /// freed — the token-level cancellation the batch-granular path could
    /// not offer. Streams get a terminal `Done { Cancelled }` (suppressed
    /// by the cancelled ticket, but it closes the channel deliberately).
    fn reap_cancelled(&mut self, out: &mut StepOutcome) {
        let mut kept = VecDeque::with_capacity(self.pending.len());
        while let Some(r) = self.pending.pop_front() {
            if r.is_cancelled() {
                if let RequestKind::Generate(spec) = &r.kind {
                    let _ = spec.stream.send(StreamEvent::Done {
                        reason: FinishReason::Cancelled,
                        generated: 0,
                    });
                }
                self.stats.cancelled += 1;
                out.cancelled.push(r);
            } else {
                kept.push_back(r);
            }
        }
        self.pending = kept;
        let mut i = 0;
        while i < self.preempted.len() {
            if self.preempted[i].req.is_cancelled() {
                let p = self.preempted.remove(i);
                if let RequestKind::Generate(spec) = &p.req.kind {
                    let _ = spec.stream.send(StreamEvent::Done {
                        reason: FinishReason::Cancelled,
                        generated: p.generated.len(),
                    });
                }
                self.stats.cancelled += 1;
                out.cancelled.push(p.req);
            } else {
                i += 1;
            }
        }
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].req.is_cancelled() {
                let ActiveSeq { req, kv, generated, .. } = self.active.remove(i);
                self.pool.free(kv);
                if let RequestKind::Generate(spec) = &req.kind {
                    let _ = spec.stream.send(StreamEvent::Done {
                        reason: FinishReason::Cancelled,
                        generated: generated.len(),
                    });
                }
                self.stats.cancelled += 1;
                out.cancelled.push(req);
            } else {
                i += 1;
            }
        }
    }

    /// Move waiting generations into the step loop while an active slot
    /// and prompt pages are available. Preempted sequences resume first
    /// (oldest admission number — they are older than anything pending);
    /// then the pending FIFO, each claiming only `prompt + one headroom
    /// page` (the lazy reservation; later pages come from growth between
    /// steps). Head-of-line blocking on admission order is the fairness
    /// guarantee, and the pool's oversized-when-empty rule ensures even a
    /// prompt larger than the whole budget eventually runs.
    fn promote_pending(&mut self) {
        let max_active = self.policy.max_active_seqs.max(1);
        while self.active.len() < max_active && !self.preempted.is_empty() {
            let idx = (0..self.preempted.len())
                .min_by_key(|&i| self.preempted[i].admit_seq)
                .unwrap();
            // replay needs the whole kept context plus one decode row
            let capacity = self.preempted[idx].ctx.len() + 1;
            let Some(kv) = self.pool.alloc_seq(&self.preempted[idx].req.tokens, capacity)
            else {
                break;
            };
            let p = self.preempted.remove(idx);
            self.active.push(ActiveSeq {
                req: p.req,
                kv,
                admit_seq: p.admit_seq,
                ctx: p.ctx,
                consumed: 0,
                generated: p.generated,
                nll_sum: 0.0,
                final_argmax: None,
                first_step_at: p.first_step_at,
                first_token_at: p.first_token_at,
                done: None,
            });
        }
        // strict admission order: nothing pending overtakes a preempted
        // sequence still waiting for pages
        if self.preempted.is_empty() {
            while self.active.len() < max_active {
                let Some(front) = self.pending.front() else { break };
                let capacity = front.tokens.len() + 1;
                let Some(kv) = self.pool.alloc_seq(&front.tokens, capacity) else { break };
                let req = self.pending.pop_front().unwrap();
                let admit_seq = self.admit_counter;
                self.admit_counter += 1;
                self.active.push(ActiveSeq {
                    ctx: req.tokens.clone(),
                    req,
                    kv,
                    admit_seq,
                    consumed: 0,
                    generated: Vec::new(),
                    nll_sum: 0.0,
                    final_argmax: None,
                    first_step_at: None,
                    first_token_at: None,
                    done: None,
                });
            }
        }
        // keep `active` admission-ordered: assembly FIFO fairness and the
        // youngest-victim rule both read positional order
        self.active.sort_by_key(|a| a.admit_seq);
    }

    /// Preempt the active sequence at `idx`: free its pages, keep its
    /// token state for replay. Emits nothing — already streamed tokens
    /// stand, and the replay will not re-emit them.
    fn preempt(&mut self, idx: usize, out: &mut StepOutcome) {
        let a = self.active.remove(idx);
        debug_assert!(a.done.is_none(), "terminal sequences retire, not preempt");
        self.pool.free(a.kv);
        self.stats.preemptions += 1;
        out.preempted.push(a.req.id);
        self.preempted.push(PreemptedSeq {
            req: a.req,
            admit_seq: a.admit_seq,
            ctx: a.ctx,
            generated: a.generated,
            first_step_at: a.first_step_at,
            first_token_at: a.first_token_at,
        });
    }

    /// Remove sequences whose terminal state was set this step, free their
    /// pages, and send the terminal stream event.
    fn retire(&mut self, out: &mut StepOutcome) {
        let mut i = 0;
        while i < self.active.len() {
            let Some(reason) = self.active[i].done else {
                i += 1;
                continue;
            };
            let ActiveSeq {
                req,
                kv,
                generated,
                nll_sum,
                final_argmax,
                first_step_at,
                first_token_at,
                ..
            } = self.active.remove(i);
            self.pool.free(kv);
            if let RequestKind::Generate(spec) = &req.kind {
                let _ = spec
                    .stream
                    .send(StreamEvent::Done { reason, generated: generated.len() });
            }
            match reason {
                FinishReason::Failed => {
                    self.stats.failed += 1;
                    out.failed.push(req);
                }
                FinishReason::Cancelled => {
                    unreachable!("cancellations are reaped before the step")
                }
                FinishReason::Stop | FinishReason::Length => {
                    self.stats.generations += 1;
                    let now = Instant::now();
                    out.finished.push(FinishedGen {
                        reason,
                        generated: generated.len(),
                        last_token: generated.last().copied().or(final_argmax),
                        mean_prompt_nll: nll_sum / (req.tokens.len() - 1).max(1) as f64,
                        queue_wait: first_step_at
                            .map_or(Duration::ZERO, |t| t.saturating_duration_since(req.arrived)),
                        compute: first_step_at
                            .map_or(Duration::ZERO, |t| now.saturating_duration_since(t)),
                        stream: first_token_at
                            .map_or(Duration::ZERO, |t| now.saturating_duration_since(t)),
                        request: req,
                    });
                }
            }
        }
    }
}

/// Stream a freshly generated token and apply the termination rules
/// (stop-token, then length). The token also extends the contiguous
/// context, so a later preemption replay carries it.
fn emit(a: &mut ActiveSeq, token: u32, out: &mut StepOutcome) {
    let index = a.generated.len();
    if a.first_token_at.is_none() {
        a.first_token_at = Some(Instant::now());
    }
    a.generated.push(token);
    a.ctx.push(token);
    let spec = match &a.req.kind {
        RequestKind::Generate(s) => s,
        RequestKind::Score => unreachable!("decode scheduler only holds generations"),
    };
    let _ = spec.stream.send(StreamEvent::Token { token, index });
    out.tokens_emitted += 1;
    if spec.stop.contains(&token) {
        a.done = Some(FinishReason::Stop);
    } else if a.generated.len() >= spec.max_new_tokens {
        a.done = Some(FinishReason::Length);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::MoeLm;
    use crate::serve::queue::Response;
    use crate::util::Rng;
    use std::sync::atomic::Ordering;
    use std::sync::{mpsc, Arc};

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "decode-test".into(),
            vocab: 32,
            hidden: 16,
            layers: 2,
            heads: 2,
            n_experts: 4,
            n_shared: 1,
            topk: 2,
            inter: 8,
            dense_first: false,
            seq_len: 12,
        }
    }

    struct GenHandle {
        stream: mpsc::Receiver<StreamEvent>,
        _reply: mpsc::Receiver<Response>,
        cancel: Arc<std::sync::atomic::AtomicBool>,
    }

    fn gen_request(prompt: Vec<u32>, max_new: usize, stop: Vec<u32>) -> (Request, GenHandle) {
        let (reply, reply_rx) = mpsc::channel();
        let (stream, stream_rx) = mpsc::channel();
        let cancel = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let req = Request {
            kind: RequestKind::Generate(GenSpec { max_new_tokens: max_new, stop, stream }),
            cancelled: cancel.clone(),
            ..Request::new(prompt, reply)
        };
        (req, GenHandle { stream: stream_rx, _reply: reply_rx, cancel })
    }

    /// One scheduler step against the native model (no PJRT): the inline
    /// closure keeps the higher-ranked `StepSeq` lifetimes inferable.
    fn native_step(sched: &mut DecodeScheduler, lm: &MoeLm) -> StepOutcome {
        sched.step(|inputs| {
            Ok(lm.forward_step_batch_with_moe(inputs, |_, block, x| block.forward(x)))
        })
    }

    /// Greedy reference: re-forward the whole growing sequence per token.
    fn reference_generate(lm: &MoeLm, prompt: &[u32], max_new: usize, stop: &[u32]) -> Vec<u32> {
        let mut seq = prompt.to_vec();
        let mut out = Vec::new();
        for _ in 0..max_new {
            let logits = lm.forward(&seq);
            let g = argmax(logits.row(seq.len() - 1));
            seq.push(g);
            out.push(g);
            if stop.contains(&g) {
                break;
            }
        }
        out
    }

    fn drain(handle: &GenHandle) -> (Vec<u32>, Option<FinishReason>) {
        let mut tokens = Vec::new();
        let mut reason = None;
        while let Ok(ev) = handle.stream.try_recv() {
            match ev {
                StreamEvent::Token { token, index } => {
                    assert_eq!(index, tokens.len(), "stream indices are dense");
                    tokens.push(token);
                }
                StreamEvent::Done { reason: r, generated } => {
                    assert_eq!(generated, tokens.len());
                    reason = Some(r);
                }
            }
        }
        (tokens, reason)
    }

    #[test]
    fn scheduler_matches_naive_reforward_generation() {
        let mut rng = Rng::new(0xD0_01);
        let cfg = tiny_cfg();
        let lm = MoeLm::random(&cfg, &mut rng);
        let prompt: Vec<u32> = (0..6).map(|_| rng.below(32) as u32).collect();
        let want = reference_generate(&lm, &prompt, 8, &[]);
        let mut sched = DecodeScheduler::new(&cfg, DecodePolicy::default());
        let (req, handle) = gen_request(prompt, 8, vec![]);
        sched.admit(req);
        let mut steps = 0;
        while sched.has_work() {
            let out = native_step(&mut sched, &lm);
            assert!(out.rows > 0 || !sched.has_work());
            steps += 1;
            assert!(steps < 100, "runaway decode loop");
        }
        let (tokens, reason) = drain(&handle);
        assert_eq!(tokens, want, "KV-cached decode must match naive re-forwarding");
        assert_eq!(reason, Some(FinishReason::Length));
        let stats = sched.stats();
        assert_eq!(stats.generations, 1);
        assert_eq!(stats.generated_tokens, 8);
        // prefill (6 rows) + one decode row per remaining token (first
        // token rides the prefill step)
        assert_eq!(stats.prefill_rows, 6);
        assert_eq!(stats.decode_rows, 7);
        assert_eq!(sched.occupancy().reserved_tokens, 0, "KV freed at retirement");
        assert_eq!(sched.occupancy().freed_seqs, 1);
    }

    #[test]
    fn stop_token_ends_generation_early() {
        let mut rng = Rng::new(0xD0_02);
        let cfg = tiny_cfg();
        let lm = MoeLm::random(&cfg, &mut rng);
        let prompt: Vec<u32> = (0..5).map(|_| rng.below(32) as u32).collect();
        // pick the 3rd greedy token as the stop token so it must stop there
        let free_run = reference_generate(&lm, &prompt, 6, &[]);
        let stop = free_run[2];
        let want = reference_generate(&lm, &prompt, 6, &[stop]);
        assert_eq!(want.len(), 3, "reference stops at the stop token");
        let mut sched = DecodeScheduler::new(&cfg, DecodePolicy::default());
        let (req, handle) = gen_request(prompt, 6, vec![stop]);
        sched.admit(req);
        while sched.has_work() {
            native_step(&mut sched, &lm);
        }
        let (tokens, reason) = drain(&handle);
        assert_eq!(tokens, want);
        assert_eq!(*tokens.last().unwrap(), stop, "stop token itself is streamed");
        assert_eq!(reason, Some(FinishReason::Stop));
    }

    #[test]
    fn zero_max_new_tokens_degrades_to_scoring() {
        let mut rng = Rng::new(0xD0_03);
        let cfg = tiny_cfg();
        let lm = MoeLm::random(&cfg, &mut rng);
        let prompt: Vec<u32> = (0..4).map(|_| rng.below(32) as u32).collect();
        let mut sched = DecodeScheduler::new(&cfg, DecodePolicy::default());
        let (req, handle) = gen_request(prompt, 0, vec![]);
        sched.admit(req);
        let out = native_step(&mut sched, &lm);
        assert_eq!(out.finished.len(), 1);
        let fin = &out.finished[0];
        assert_eq!(fin.generated, 0);
        assert!(fin.last_token.is_some(), "scoring parity: argmax continuation kept");
        assert_eq!(fin.reason, FinishReason::Length);
        assert!(fin.mean_prompt_nll.is_finite());
        assert_eq!(fin.stream, Duration::ZERO, "nothing was streamed");
        assert!(fin.compute >= Duration::ZERO);
        let (tokens, reason) = drain(&handle);
        assert!(tokens.is_empty());
        assert_eq!(reason, Some(FinishReason::Length));
    }

    #[test]
    fn step_budget_chunks_prefill_and_mixes_decode_rows() {
        let mut rng = Rng::new(0xD0_04);
        let cfg = tiny_cfg();
        let lm = MoeLm::random(&cfg, &mut rng);
        // tiny budget: an 11-token prompt must prefill over multiple steps
        let policy = DecodePolicy { max_step_rows: 4, ..DecodePolicy::default() };
        let mut sched = DecodeScheduler::new(&cfg, policy);
        let long: Vec<u32> = (0..11).map(|_| rng.below(32) as u32).collect();
        let short: Vec<u32> = (0..2).map(|_| rng.below(32) as u32).collect();
        let want_long = reference_generate(&lm, &long, 3, &[]);
        let want_short = reference_generate(&lm, &short, 3, &[]);
        let (req_a, h_a) = gen_request(long.clone(), 3, vec![]);
        let (req_b, h_b) = gen_request(short.clone(), 3, vec![]);
        sched.admit(req_a);
        sched.admit(req_b);
        let mut saw_mixed = false;
        while sched.has_work() {
            let out = native_step(&mut sched, &lm);
            assert!(out.rows <= 4 + 1, "budget respected (±1 decode row floor)");
            if out.prefill_rows > 0 && out.decode_rows > 0 {
                saw_mixed = true;
            }
            if let Some(est) = out.fill {
                assert_eq!(est.useful_rows, out.rows);
            }
        }
        assert!(saw_mixed, "short seq decodes while long seq still prefills");
        assert_eq!(drain(&h_a).0, want_long);
        assert_eq!(drain(&h_b).0, want_short);
        assert_eq!(sched.stats().generations, 2);
    }

    #[test]
    fn cancellation_between_steps_frees_kv_and_stops_within_one_step() {
        let mut rng = Rng::new(0xD0_05);
        let cfg = tiny_cfg();
        let lm = MoeLm::random(&cfg, &mut rng);
        let prompt: Vec<u32> = (0..4).map(|_| rng.below(32) as u32).collect();
        let mut sched = DecodeScheduler::new(&cfg, DecodePolicy::default());
        let (req, handle) = gen_request(prompt, 1000, vec![]);
        sched.admit(req);
        // run two steps (prefill+first token, then one decode token)…
        native_step(&mut sched, &lm);
        native_step(&mut sched, &lm);
        let emitted_before = sched.stats().generated_tokens;
        assert!(emitted_before >= 2);
        assert!(sched.occupancy().reserved_tokens > 0);
        assert!(sched.occupancy().used_tokens > 0, "appended positions are visible");
        // …then cancel: the very next step must evict without executing
        handle.cancel.store(true, Ordering::Release);
        let out = native_step(&mut sched, &lm);
        assert_eq!(out.cancelled.len(), 1, "evicted between steps");
        assert_eq!(out.rows, 0, "no rows executed for the cancelled sequence");
        assert_eq!(sched.stats().generated_tokens, emitted_before, "no token after cancel");
        assert_eq!(sched.occupancy().reserved_tokens, 0, "KV pages reclaimed");
        assert_eq!(sched.occupancy().used_tokens, 0);
        assert_eq!(sched.occupancy().seqs, 0);
        assert!(!sched.has_work());
        assert_eq!(sched.stats().cancelled, 1);
        let (_, reason) = drain(&handle);
        assert_eq!(reason, Some(FinishReason::Cancelled));
    }

    #[test]
    fn pending_cancellation_never_allocates_kv() {
        let cfg = tiny_cfg();
        let mut sched = DecodeScheduler::new(&cfg, DecodePolicy::default());
        let (req, handle) = gen_request(vec![1, 2, 3], 5, vec![]);
        handle.cancel.store(true, Ordering::Release);
        sched.admit(req);
        let out = sched.step(|_inputs: &mut [StepSeq<'_>]| -> anyhow::Result<Vec<Matrix>> {
            panic!("nothing should execute")
        });
        assert_eq!(out.cancelled.len(), 1);
        assert_eq!(sched.occupancy().peak_tokens, 0, "KV was never reserved");
        let (_, reason) = drain(&handle);
        assert_eq!(reason, Some(FinishReason::Cancelled));
    }

    #[test]
    fn page_budget_defers_admission_until_pages_free() {
        let mut rng = Rng::new(0xD0_06);
        let cfg = tiny_cfg();
        let lm = MoeLm::random(&cfg, &mut rng);
        // two 4-token pages: one generation's lazy claim (prompt page +
        // headroom page) fills the pool exactly
        let policy = DecodePolicy {
            kv_budget_tokens: 8,
            kv_page_size: 4,
            ..DecodePolicy::default()
        };
        let mut sched = DecodeScheduler::new(&cfg, policy);
        let p1: Vec<u32> = (0..4).map(|_| rng.below(32) as u32).collect();
        let p2: Vec<u32> = (0..4).map(|_| rng.below(32) as u32).collect();
        let (r1, h1) = gen_request(p1.clone(), 2, vec![]);
        let (r2, h2) = gen_request(p2.clone(), 2, vec![]);
        sched.admit(r1);
        sched.admit(r2);
        native_step(&mut sched, &lm);
        assert_eq!(sched.active_seqs(), 1, "second generation waits on the page pool");
        assert_eq!(sched.pending_seqs(), 1);
        while sched.has_work() {
            native_step(&mut sched, &lm);
        }
        assert_eq!(drain(&h1).0, reference_generate(&lm, &p1, 2, &[]));
        assert_eq!(drain(&h2).0, reference_generate(&lm, &p2, 2, &[]));
        assert_eq!(sched.occupancy().peak_tokens, 8, "page claims never overlapped");
        assert_eq!(sched.occupancy().freed_seqs, 2, "every alloc met exactly one free");
    }

    #[test]
    fn preemption_is_deterministic_and_replay_matches_reference() {
        let mut rng = Rng::new(0xD0_08);
        let cfg = tiny_cfg();
        let lm = MoeLm::random(&cfg, &mut rng);
        // 6 pages of 4: each generation lazily claims 3 pages for its
        // 8-token prompt (+headroom) but needs 5 by the end — they cannot
        // both stay resident, so the younger one must be preempted
        let policy = DecodePolicy {
            kv_budget_tokens: 24,
            kv_page_size: 4,
            ..DecodePolicy::default()
        };
        let pa: Vec<u32> = (0..8).map(|_| rng.below(32) as u32).collect();
        let pb: Vec<u32> = (0..8).map(|_| rng.below(32) as u32).collect();
        let want_a = reference_generate(&lm, &pa, 8, &[]);
        let want_b = reference_generate(&lm, &pb, 8, &[]);
        let run = || {
            let mut sched = DecodeScheduler::new(&cfg, policy.clone());
            let (ra, ha) = gen_request(pa.clone(), 8, vec![]);
            let (rb, hb) = gen_request(pb.clone(), 8, vec![]);
            let (id_a, id_b) = (ra.id, rb.id);
            sched.admit(ra);
            sched.admit(rb);
            let mut preempt_log: Vec<(usize, u64)> = Vec::new();
            let mut steps = 0;
            while sched.has_work() {
                let out = native_step(&mut sched, &lm);
                for &id in &out.preempted {
                    preempt_log.push((steps, id));
                }
                steps += 1;
                assert!(steps < 200, "runaway decode loop");
            }
            assert_eq!(drain(&ha).0, want_a, "older generation unaffected");
            assert_eq!(drain(&hb).0, want_b, "preempted generation replays to the same tokens");
            assert!(sched.stats().preemptions >= 1, "the pool must have run dry");
            assert!(
                preempt_log.iter().all(|&(_, id)| id == id_b && id != id_a),
                "the victim is always the youngest sequence"
            );
            assert_eq!(sched.occupancy().reserved_tokens, 0);
            // normalize ids out so two runs (fresh request ids) compare
            let steps_only: Vec<usize> = preempt_log.iter().map(|&(s, _)| s).collect();
            (steps_only, sched.stats())
        };
        let (log1, stats1) = run();
        let (log2, stats2) = run();
        assert_eq!(log1, log2, "preemption schedule is deterministic");
        assert_eq!(stats1, stats2, "decode counters are deterministic");
    }

    #[test]
    fn engine_failure_drops_only_the_sequences_in_the_step() {
        let mut rng = Rng::new(0xD0_07);
        let cfg = tiny_cfg();
        let lm = MoeLm::random(&cfg, &mut rng);
        let mut sched = DecodeScheduler::new(&cfg, DecodePolicy::default());
        let (req, handle) = gen_request(vec![1, 2, 3], 5, vec![]);
        sched.admit(req);
        let out = sched.step(|_inputs: &mut [StepSeq<'_>]| -> anyhow::Result<Vec<Matrix>> {
            anyhow::bail!("injected engine failure")
        });
        assert_eq!(out.failed.len(), 1);
        assert!(out.finished.is_empty());
        assert_eq!(sched.stats().failed, 1);
        assert_eq!(sched.occupancy().reserved_tokens, 0, "failed sequence freed its KV");
        let (_, reason) = drain(&handle);
        assert_eq!(reason, Some(FinishReason::Failed));
        // the scheduler still serves after a failure
        let (req2, h2) = gen_request(vec![2, 3], 1, vec![]);
        sched.admit(req2);
        while sched.has_work() {
            native_step(&mut sched, &lm);
        }
        assert_eq!(drain(&h2).0.len(), 1);
    }

    #[test]
    fn trim_to_tiles_aligns_chunks() {
        // rows=0: a 10-row want trims to 8 (4+4 whole tiles)
        assert_eq!(trim_to_tiles(0, 10), 8);
        // already aligned wants stay
        assert_eq!(trim_to_tiles(0, 64), 64);
        assert_eq!(trim_to_tiles(4, 16), 16);
        // tiny wants that cannot align fall back unchanged
        assert_eq!(trim_to_tiles(0, 1), 1);
        assert_eq!(trim_to_tiles(2, 1), 1, "cannot align: keep progress");
        // decode rows + prefill chunk: 3 decode rows, want 9 → total 12
        assert_eq!(trim_to_tiles(3, 9), 9);
    }

    #[test]
    fn quantized_pages_trade_exactness_for_bits() {
        let mut rng = Rng::new(0xD0_09);
        let cfg = tiny_cfg();
        let lm = MoeLm::random(&cfg, &mut rng);
        let prompt: Vec<u32> = (0..8).map(|_| rng.below(32) as u32).collect();
        let policy = DecodePolicy {
            kv_page_size: 4,
            kv_quant: Some(KvQuantConfig::uniform(cfg.layers, 8, -1)),
            ..DecodePolicy::default()
        };
        let mut sched = DecodeScheduler::new(&cfg, policy);
        let (req, handle) = gen_request(prompt.clone(), 6, vec![]);
        sched.admit(req);
        let mut saw_quant = false;
        while sched.has_work() {
            native_step(&mut sched, &lm);
            let occ = sched.occupancy();
            if occ.avg_kv_bits < 32.0 {
                saw_quant = true;
            }
        }
        assert!(saw_quant, "sealed pages must report < 32 avg KV bits");
        let (tokens, reason) = drain(&handle);
        // int8 group-quantized prefix pages: generation completes with the
        // full token count (the trade is accuracy, not progress)
        assert_eq!(tokens.len(), 6);
        assert_eq!(reason, Some(FinishReason::Length));
    }
}
